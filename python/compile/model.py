"""Layer-2: the JAX model -- DLFusion fusion blocks as jittable functions.

A *fusion block* is the unit DLFusion's Algorithm 1 produces: a run of
consecutive conv layers executed as one compiled operator.  This module
builds the batched forward function for a block (calling the L1 Pallas
kernel) and for its unfused single-layer counterpart, in the exact
calling convention the Rust runtime uses:

    fn(x, w_0, b_0, w_1, b_1, ..., w_{d-1}, b_{d-1}) -> (y,)

with ``x: (N, H, W, C_0)``, ``w_l: (3, 3, C_l, C_{l+1})``, ``b_l: (C_{l+1},)``.

Only lowered at build time by ``aot.py``; Python is never on the request
path.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels.fused_conv import fused_conv_chain
from .kernels.ref import fused_conv_chain_ref


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """Static description of one fusion block artifact.

    Mirrors the Rust-side ``runtime::manifest::ArtifactSpec``; serialized into
    ``artifacts/manifest.json`` by ``aot.py``.
    """

    name: str
    batch: int
    height: int
    width: int
    channels: Tuple[int, ...]  # C_0 (input) followed by each stage's C_out
    tile: int = 16
    relu_last: bool = True
    dtype: str = "f32"

    @property
    def depth(self) -> int:
        return len(self.channels) - 1

    def input_shapes(self):
        """Shapes in the artifact's parameter order: x, then (w, b) per stage."""
        shapes = [(self.batch, self.height, self.width, self.channels[0])]
        for l in range(self.depth):
            shapes.append((3, 3, self.channels[l], self.channels[l + 1]))
            shapes.append((self.channels[l + 1],))
        return shapes

    def output_shape(self):
        return (self.batch, self.height, self.width, self.channels[-1])

    def stage_specs(self):
        """Single-layer BlockSpecs for the unfused execution of this block."""
        return [
            BlockSpec(
                name=f"{self.name}__stage{l}",
                batch=self.batch,
                height=self.height,
                width=self.width,
                channels=(self.channels[l], self.channels[l + 1]),
                tile=self.tile,
                relu_last=True if l != self.depth - 1 else self.relu_last,
                dtype=self.dtype,
            )
            for l in range(self.depth)
        ]

    def jnp_dtype(self):
        return {"f32": jnp.float32, "bf16": jnp.bfloat16}[self.dtype]

    def to_json_dict(self):
        return {
            "name": self.name,
            "batch": self.batch,
            "height": self.height,
            "width": self.width,
            "channels": list(self.channels),
            "tile": self.tile,
            "relu_last": self.relu_last,
            "dtype": self.dtype,
            "depth": self.depth,
        }


def block_forward(spec: BlockSpec, x, *params, use_kernel: bool = True):
    """Batched fused-block forward.  ``params`` = w_0, b_0, ..., interleaved."""
    depth = spec.depth
    weights = tuple(params[2 * l] for l in range(depth))
    biases = tuple(params[2 * l + 1] for l in range(depth))
    fn = fused_conv_chain if use_kernel else fused_conv_chain_ref

    def single(img):
        return fn(img, weights, biases, relu_last=spec.relu_last)

    return (jax.vmap(single)(x),)


def make_block_fn(spec: BlockSpec, *, use_kernel: bool = True):
    """Closure over the spec, suitable for ``jax.jit(...).lower``."""
    return functools.partial(block_forward, spec, use_kernel=use_kernel)


def example_args(spec: BlockSpec):
    """ShapeDtypeStructs in artifact parameter order, for AOT lowering."""
    dt = spec.jnp_dtype()
    return [jax.ShapeDtypeStruct(s, dt) for s in spec.input_shapes()]


def random_args(spec: BlockSpec, seed: int = 0):
    """Concrete random inputs (He-ish scaled) for testing a block."""
    key = jax.random.PRNGKey(seed)
    dt = spec.jnp_dtype()
    args = []
    for i, shape in enumerate(spec.input_shapes()):
        key, sub = jax.random.split(key)
        fan_in = shape[-2] * 9 if len(shape) == 4 else 1  # weights vs biases
        scale = 1.0 if i == 0 else (2.0 / max(1, fan_in)) ** 0.5
        args.append((jax.random.normal(sub, shape) * scale).astype(dt))
    return args


# ---------------------------------------------------------------------------
# The artifact catalog: every HLO program the Rust side may load.
#
# Kept deliberately small-channel / small-image so the CPU PJRT client runs
# them fast; the *performance* numbers of the paper come from the simulator,
# the artifacts prove mathematical equivalence and exercise the real
# request path.  For each fused block we also emit its per-stage single
# convs so the Rust coordinator can execute fused-vs-unfused and compare.
# ---------------------------------------------------------------------------

CATALOG: Tuple[BlockSpec, ...] = (
    # Minimal smoke block.
    BlockSpec("b1_c8_h16", batch=1, height=16, width=16, channels=(8, 8)),
    # Depth-2 and depth-3 fusion pyramids (the Fig. 7 structure).
    BlockSpec("b2_c8_h16", batch=1, height=16, width=16, channels=(8, 8, 8)),
    BlockSpec("b3_c8_h16", batch=1, height=16, width=16, channels=(8, 8, 8, 8)),
    # Channel-growing block, as in VGG-ish stages.
    BlockSpec("b2_c4_c8_c16_h16", batch=1, height=16, width=16, channels=(4, 8, 16)),
    # The e2e driver's "realistic" block: larger image, batch 2.
    BlockSpec("b2_c16_h32", batch=2, height=32, width=32, channels=(16, 16, 16)),
    # Depth-4: deepest fusion the e2e mini-net uses.
    BlockSpec("b4_c8_h16", batch=1, height=16, width=16, channels=(8, 8, 8, 8, 8)),
)


def catalog_with_stages(catalog: Sequence[BlockSpec] = CATALOG):
    """All artifacts to emit: each fused block plus its unfused stages.

    Returns (all_specs, pairs) where pairs maps fused name -> stage names.
    """
    seen = {}
    pairs = {}
    for spec in catalog:
        seen[spec.name] = spec
        stage_names = []
        if spec.depth > 1:
            for st in spec.stage_specs():
                seen.setdefault(st.name, st)
                stage_names.append(st.name)
        pairs[spec.name] = stage_names
    return list(seen.values()), pairs
