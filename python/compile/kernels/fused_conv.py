"""Layer-1 Pallas kernel: the fused-layer convolution pyramid.

This is the compute hot-spot of the DLFusion paper: a *fusion block* of
consecutive 3x3 convolutions (stride 1, SAME padding, bias + ReLU after each
stage) executed tile-wise so that intermediate feature maps never leave
on-chip memory.  Each grid program:

  1. loads one spatial *input window with halo* -- for a depth-``d`` block of
     3x3 convs the window is ``(tile + 2d) x (tile + 2d)`` -- the halo rows
     and columns are exactly the *redundant computation* of Fig. 7(a)
     (Alwani et al., "Fused-layer CNN accelerators");
  2. carries the tile through all ``d`` conv stages entirely in registers /
     scratch (VMEM on a real TPU), masking positions that fall outside the
     original image to zero after every intermediate stage so the fused chain
     is *bit-for-bit mathematically equivalent* to the unfused SAME-padded
     per-layer execution (the equivalence DLFusion's auto-fusion relies on);
  3. writes only the final ``tile x tile`` output block.

Hardware adaptation (DESIGN.md section "Hardware-Adaptation"): the MLU100's
core-local buffer maps to a VMEM tile expressed through BlockSpecs; the
channel-granular model-parallel partitioning of the paper maps to the channel
axis of the dot-product below (lowered as an MXU-friendly contraction); the
halo redundancy the paper's cost model charges is physically materialised by
the overlapping windows this kernel reads.

The kernel is always lowered with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls, and all numerics in this project run on
the CPU client from the Rust coordinator.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fused_conv_chain", "conv_stage_tile", "KERNEL_SIZE"]

# All convolutions in a DLFusion fusion block are KxK, stride 1, SAME.  The
# paper's characterization (Fig. 4(b)) shows kernel size contributes little to
# the performance variance, so like the paper's microbenchmarks we fix K=3.
KERNEL_SIZE = 3
_RADIUS = KERNEL_SIZE // 2


def conv_stage_tile(x_tile, w, b, *, apply_relu: bool):
    """One VALID 3x3 conv stage over an in-register tile.

    ``x_tile``: (h, w, cin) -- already includes the 1-pixel halo ring.
    ``w``: (3, 3, cin, cout), ``b``: (cout,).
    Returns (h-2, w-2, cout).

    The 3x3 spatial taps are unrolled into 9 (h*w, cin) x (cin, cout)
    contractions -- the shape an MXU systolic array (or the MLU100's matrix
    unit) consumes, rather than a scalar loop nest.
    """
    h, wd, cin = x_tile.shape
    oh, ow = h - 2 * _RADIUS, wd - 2 * _RADIUS
    cout = w.shape[-1]
    acc = jnp.zeros((oh * ow, cout), dtype=jnp.float32)
    for dy in range(KERNEL_SIZE):
        for dx in range(KERNEL_SIZE):
            patch = x_tile[dy : dy + oh, dx : dx + ow, :].reshape(oh * ow, cin)
            acc = acc + jax.lax.dot(
                patch.astype(jnp.float32),
                w[dy, dx].astype(jnp.float32),
                precision=jax.lax.Precision.HIGHEST,
            )
    out = acc.reshape(oh, ow, cout) + b.astype(jnp.float32)
    if apply_relu:
        out = jnp.maximum(out, 0.0)
    return out


def _border_mask(tile_h: int, tile_w: int, row0, col0, img_h: int, img_w: int):
    """1.0 inside the original image, 0.0 in the halo overhang.

    ``row0``/``col0`` are the global coordinates of the tile's (0, 0) element
    (possibly negative: halo positions hang off the image edge).  Masking
    intermediate stages to zero reproduces the zero padding the unfused
    SAME-convolution chain would apply, which is what makes arbitrary-depth
    fusion mathematically equivalent to layer-wise execution.
    """
    rows = jax.lax.broadcasted_iota(jnp.int32, (tile_h, tile_w), 0) + row0
    cols = jax.lax.broadcasted_iota(jnp.int32, (tile_h, tile_w), 1) + col0
    inside = (rows >= 0) & (rows < img_h) & (cols >= 0) & (cols < img_w)
    return inside.astype(jnp.float32)[:, :, None]


def _fused_kernel(x_ref, *refs, depth: int, tile: int, img_h: int, img_w: int,
                  relu: Sequence[bool]):
    """Pallas kernel body.  ``refs`` = w_0, b_0, ..., w_{d-1}, b_{d-1}, o_ref."""
    w_refs = [refs[2 * i] for i in range(depth)]
    b_refs = [refs[2 * i + 1] for i in range(depth)]
    o_ref = refs[-1]

    ti = pl.program_id(0)
    tj = pl.program_id(1)

    halo = depth * _RADIUS
    win = tile + 2 * halo
    # x_ref holds the zero-padded image (img + `halo` ring); the window for
    # tile (ti, tj) starts at (ti*tile, tj*tile) in padded coordinates.
    row_start = ti * tile
    col_start = tj * tile
    x_win = pl.load(
        x_ref,
        (pl.dslice(row_start, win), pl.dslice(col_start, win), slice(None)),
    )

    cur = x_win
    for stage in range(depth):
        cur = conv_stage_tile(
            cur, w_refs[stage][...], b_refs[stage][...], apply_relu=relu[stage]
        )
        if stage != depth - 1:
            # Global coords of this intermediate tile's origin: the window
            # origin in *image* coords is (ti*tile - halo); each VALID stage
            # eats one radius ring.
            off = (stage + 1) * _RADIUS
            r0 = ti * tile - halo + off
            c0 = tj * tile - halo + off
            th = tile + 2 * (halo - off)
            cur = cur * _border_mask(th, th, r0, c0, img_h, img_w)

    o_ref[...] = cur.astype(o_ref.dtype)


def _pick_tile(h: int, w: int, requested: int | None) -> int:
    """Largest tile <= requested that divides both spatial dims."""
    cap = requested if requested is not None else 16
    for t in range(min(cap, h, w), 0, -1):
        if h % t == 0 and w % t == 0:
            return t
    return 1


@functools.partial(
    jax.jit,
    static_argnames=("tile", "relu_last", "interpret"),
)
def fused_conv_chain(x, weights, biases, *, tile: int | None = None,
                     relu_last: bool = True, interpret: bool = True):
    """Run a fused chain of 3x3/s1/SAME conv(+bias, +ReLU) stages.

    Args:
      x: (H, W, C_in) single image (batch via ``jax.vmap``).
      weights: tuple of (3, 3, C_{l}, C_{l+1}) arrays.
      biases:  tuple of (C_{l+1},) arrays.
      tile: spatial tile edge (defaults to the largest divisor of H, W <= 16).
      relu_last: whether the final stage applies ReLU (intermediates always do,
        matching the conv+ReLU pairs DLFusion fuses).
      interpret: must stay True on CPU PJRT (Mosaic custom-calls cannot run).

    Returns:
      (H, W, C_out) output, same dtype as ``x``.
    """
    weights = tuple(weights)
    biases = tuple(biases)
    depth = len(weights)
    if depth == 0:
        raise ValueError("fusion block must contain at least one conv stage")
    if len(biases) != depth:
        raise ValueError("weights/biases length mismatch")
    h, w, cin = x.shape
    if weights[0].shape[2] != cin:
        raise ValueError(
            f"stage-0 weight expects C_in={weights[0].shape[2]}, got {cin}"
        )
    for l in range(1, depth):
        if weights[l].shape[2] != weights[l - 1].shape[3]:
            raise ValueError(f"channel mismatch between stages {l-1} and {l}")

    t = _pick_tile(h, w, tile)
    halo = depth * _RADIUS
    cout = weights[-1].shape[3]
    relu = [True] * (depth - 1) + [relu_last]

    xp = jnp.pad(x, ((halo, halo), (halo, halo), (0, 0)))

    grid = (h // t, w // t)
    kernel = functools.partial(
        _fused_kernel, depth=depth, tile=t, img_h=h, img_w=w, relu=relu
    )

    in_specs = [pl.BlockSpec(xp.shape, lambda i, j: (0, 0, 0))]
    for l in range(depth):
        in_specs.append(pl.BlockSpec(weights[l].shape, lambda i, j: (0, 0, 0, 0)))
        in_specs.append(pl.BlockSpec(biases[l].shape, lambda i, j: (0,)))

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((t, t, cout), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w, cout), x.dtype),
        interpret=interpret,
    )(xp, *[a for pair in zip(weights, biases) for a in pair])
    return out
