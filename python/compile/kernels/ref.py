"""Pure-jax.lax oracle for the fused conv chain.

This is the ground truth the Pallas kernel (and therefore every AOT artifact
the Rust coordinator executes) is validated against: an unfused, layer-wise
chain of SAME-padded 3x3 convolutions with bias and ReLU -- exactly what the
MLU100 would run with fusion disabled.  DLFusion's central equivalence claim
("arbitrary auto-fusion patterns that are mathematically equivalent") is
checked by asserting kernel == ref over randomized shapes in pytest.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

__all__ = ["conv2d_same_ref", "fused_conv_chain_ref"]


def conv2d_same_ref(x, w, b, *, apply_relu: bool):
    """One 3x3/s1/SAME conv + bias (+ReLU) on a single (H, W, C) image."""
    y = jax.lax.conv_general_dilated(
        x[None].astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        precision=jax.lax.Precision.HIGHEST,
    )[0]
    y = y + b.astype(jnp.float32)
    if apply_relu:
        y = jnp.maximum(y, 0.0)
    return y.astype(x.dtype)


def fused_conv_chain_ref(x, weights: Sequence, biases: Sequence,
                         *, relu_last: bool = True):
    """Layer-wise (unfused) execution of the conv chain."""
    depth = len(weights)
    cur = x
    for l in range(depth):
        cur = conv2d_same_ref(
            cur, weights[l], biases[l],
            apply_relu=(l != depth - 1) or relu_last,
        )
    return cur
