"""L1: Pallas kernel(s) for the paper's compute hot-spot (fused conv blocks)."""

from .fused_conv import fused_conv_chain, conv_stage_tile, KERNEL_SIZE
from .ref import conv2d_same_ref, fused_conv_chain_ref

__all__ = [
    "fused_conv_chain",
    "conv_stage_tile",
    "KERNEL_SIZE",
    "conv2d_same_ref",
    "fused_conv_chain_ref",
]
