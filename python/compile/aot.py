"""AOT lowering: JAX fusion blocks -> HLO *text* artifacts + manifest.

Build-time half of the three-layer architecture.  Each BlockSpec in
``model.CATALOG`` (plus its unfused per-stage convs) is jitted, lowered to
stablehlo, converted to an XlaComputation, and dumped as HLO **text** to
``artifacts/<name>.hlo.txt``.

HLO text -- NOT ``lowered.compile().serialize()`` / serialized protos -- is
the interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which the Rust side's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/README.md.

Also writes ``artifacts/manifest.json`` describing every artifact (shapes,
dtypes, fused->stage pairing) for the Rust runtime, and, for each fused
block, a deterministic input/output checksum the Rust integration tests
verify end-to-end.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_mod
from .model import BlockSpec, make_block_fn, example_args, random_args


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_block(spec: BlockSpec) -> str:
    fn = make_block_fn(spec, use_kernel=True)
    lowered = jax.jit(fn).lower(*example_args(spec))
    return to_hlo_text(lowered)


def _checksum(spec: BlockSpec, seed: int = 0):
    """Run the block in-process and fingerprint inputs/outputs.

    The Rust integration suite re-executes the artifact via PJRT with the
    same deterministic inputs (shipped as .npy-like flat f32 files) and
    asserts the outputs match this fingerprint's values.
    """
    args = random_args(spec, seed=seed)
    (out,) = make_block_fn(spec, use_kernel=False)(*args)
    out = np.asarray(out, dtype=np.float32)
    h = hashlib.sha256()
    for a in args:
        h.update(np.asarray(a, dtype=np.float32).tobytes())
    h.update(out.tobytes())
    return args, out, h.hexdigest()


def write_flat_f32(path: str, arr) -> None:
    np.asarray(arr, dtype="<f4").tofile(path)


def emit(outdir: str, verbose: bool = True) -> dict:
    os.makedirs(outdir, exist_ok=True)
    specs, pairs = model_mod.catalog_with_stages()
    manifest = {
        "format_version": 1,
        "interchange": "hlo-text",
        "artifacts": [],
        "fused_pairs": pairs,
    }
    for spec in specs:
        hlo = lower_block(spec)
        fname = f"{spec.name}.hlo.txt"
        with open(os.path.join(outdir, fname), "w") as f:
            f.write(hlo)
        entry = spec.to_json_dict()
        entry["file"] = fname
        entry["input_shapes"] = [list(s) for s in spec.input_shapes()]
        entry["output_shape"] = list(spec.output_shape())
        manifest["artifacts"].append(entry)
        if verbose:
            print(f"  lowered {spec.name}: depth={spec.depth} "
                  f"{spec.height}x{spec.width} ch={list(spec.channels)} "
                  f"({len(hlo)} chars)")

    # Golden vectors for the deepest fused block + the realistic block: the
    # Rust integration tests feed these exact inputs through PJRT.
    golden = {}
    for name in ("b2_c8_h16", "b2_c16_h32"):
        spec = next(s for s in specs if s.name == name)
        args, out, digest = _checksum(spec)
        gdir = os.path.join(outdir, "golden", name)
        os.makedirs(gdir, exist_ok=True)
        for i, a in enumerate(args):
            write_flat_f32(os.path.join(gdir, f"in{i}.f32"), a)
        write_flat_f32(os.path.join(gdir, "out.f32"), out)
        golden[name] = {
            "sha256": digest,
            "num_inputs": len(args),
            "dir": f"golden/{name}",
        }
    manifest["golden"] = golden

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    if verbose:
        print(f"wrote {len(manifest['artifacts'])} artifacts + manifest to {outdir}")
    return manifest


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--outdir", default=None, help="artifact output directory")
    p.add_argument("--out", default=None,
                   help="(compat) path like ../artifacts/model.hlo.txt; "
                        "its directory is used as --outdir")
    p.add_argument("-q", "--quiet", action="store_true")
    args = p.parse_args()
    outdir = args.outdir or (os.path.dirname(args.out) if args.out else "../artifacts")
    manifest = emit(outdir, verbose=not args.quiet)
    # Keep the Makefile's sentinel file contract: model.hlo.txt is the first
    # artifact, copied under the sentinel name.
    sentinel = os.path.join(outdir, "model.hlo.txt")
    first = os.path.join(outdir, manifest["artifacts"][0]["file"])
    with open(first) as src, open(sentinel, "w") as dst:
        dst.write(src.read())


if __name__ == "__main__":
    main()
