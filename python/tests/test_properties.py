"""Mathematical properties of the fused-conv computation (hypothesis).

Beyond pointwise kernel==oracle agreement (test_kernel.py), these pin the
algebraic structure the fusion equivalence rests on: linearity of the conv
stage, locality (receptive field), and composition depth.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.fused_conv import fused_conv_chain
from compile.kernels.ref import conv2d_same_ref, fused_conv_chain_ref


def rand(key, shape, scale=1.0):
    return jax.random.normal(key, shape) * scale


def chain(key, depth, c, h):
    ks = jax.random.split(key, 2 * depth + 1)
    x = rand(ks[0], (h, h, c))
    ws = [rand(ks[2 * i + 1], (3, 3, c, c), 0.3) for i in range(depth)]
    bs = [rand(ks[2 * i + 2], (c,), 0.1) for i in range(depth)]
    return x, ws, bs


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_conv_stage_is_linear_without_relu(seed):
    """conv(a*x + b*y) == a*conv(x) + b*conv(y) (bias cancelled)."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = rand(k1, (8, 8, 4))
    y = rand(k2, (8, 8, 4))
    w = rand(k3, (3, 3, 4, 4), 0.3)
    zero_b = jnp.zeros((4,))
    lhs = conv2d_same_ref(2.0 * x + 0.5 * y, w, zero_b, apply_relu=False)
    rhs = (2.0 * conv2d_same_ref(x, w, zero_b, apply_relu=False)
           + 0.5 * conv2d_same_ref(y, w, zero_b, apply_relu=False))
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), depth=st.integers(1, 3))
def test_receptive_field_locality(seed, depth):
    """Perturbing one pixel only changes outputs within `depth` pixels —
    the locality that makes tile-wise fusion with finite halos possible."""
    key = jax.random.PRNGKey(seed)
    x, ws, bs = chain(key, depth, 3, 12)
    y0 = np.asarray(fused_conv_chain(x, tuple(ws), tuple(bs)))
    x2 = x.at[6, 6, 0].add(3.0)
    y1 = np.asarray(fused_conv_chain(x2, tuple(ws), tuple(bs)))
    diff = np.abs(y1 - y0).sum(axis=-1)
    affected = np.argwhere(diff > 1e-6)
    if affected.size:
        d = np.abs(affected - np.array([6, 6])).max()
        assert d <= depth, f"change leaked {d} pixels for depth {depth}"


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_relu_output_nonnegative(seed):
    key = jax.random.PRNGKey(seed)
    x, ws, bs = chain(key, 2, 4, 8)
    y = np.asarray(fused_conv_chain(x, tuple(ws), tuple(bs), relu_last=True))
    assert (y >= 0.0).all()


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), split=st.integers(1, 2))
def test_fusion_composes_at_any_split(seed, split):
    """chain(d) == chain(split) ∘ chain(d - split): the property Algorithm 1
    exploits when it places a fusion boundary anywhere."""
    depth = 3
    key = jax.random.PRNGKey(seed)
    x, ws, bs = chain(key, depth, 4, 8)
    full = fused_conv_chain_ref(x, ws, bs)
    head = fused_conv_chain_ref(x, ws[:split], bs[:split], relu_last=True)
    tail = fused_conv_chain_ref(head, ws[split:], bs[split:])
    np.testing.assert_allclose(np.asarray(full), np.asarray(tail),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_kernel_translation_equivariance_interior(seed):
    """Shifting the input shifts the output (away from borders)."""
    key = jax.random.PRNGKey(seed)
    x, ws, bs = chain(key, 2, 3, 12)
    y = np.asarray(fused_conv_chain(x, tuple(ws), tuple(bs)))
    xs = jnp.roll(x, shift=2, axis=0)
    ys = np.asarray(fused_conv_chain(xs, tuple(ws), tuple(bs)))
    # Compare interiors only (borders see different padding).
    np.testing.assert_allclose(ys[6:10, 4:8], y[4:8, 4:8], rtol=1e-3, atol=1e-3)
