"""AOT pipeline: lowering to HLO text, manifest schema, golden vectors."""

import json
import os

import numpy as np
import pytest

from compile import aot
from compile.model import BlockSpec, CATALOG, catalog_with_stages


TINY = BlockSpec("t_aot", batch=1, height=8, width=8, channels=(2, 3))


class TestLowering:
    def test_hlo_text_nonempty_and_parseable_header(self):
        hlo = aot.lower_block(TINY)
        assert hlo.startswith("HloModule")
        assert "ENTRY" in hlo

    def test_hlo_text_has_dot_or_conv(self):
        # The pallas kernel unrolls conv into dots; either op proves the
        # contraction survived lowering.
        hlo = aot.lower_block(TINY)
        assert ("dot(" in hlo) or ("convolution(" in hlo)

    def test_hlo_root_is_tuple(self):
        # return_tuple=True: rust side unwraps with to_tuple1().
        hlo = aot.lower_block(TINY)
        assert "ROOT" in hlo and "tuple" in hlo

    def test_parameter_count_matches_spec(self):
        # Count parameters of the ENTRY computation only (nested computations
        # from the pallas lowering declare their own).
        hlo = aot.lower_block(TINY)
        entry = hlo[hlo.index("ENTRY"):]
        n_params = len(
            {line.split("parameter(")[1].split(")")[0]
             for line in entry.splitlines() if "parameter(" in line})
        assert n_params == len(TINY.input_shapes())


class TestEmit(object):
    @pytest.fixture(scope="class")
    def outdir(self, tmp_path_factory):
        d = tmp_path_factory.mktemp("artifacts")
        aot.emit(str(d), verbose=False)
        return str(d)

    def test_manifest_exists_and_schema(self, outdir):
        with open(os.path.join(outdir, "manifest.json")) as f:
            m = json.load(f)
        assert m["format_version"] == 1
        assert m["interchange"] == "hlo-text"
        assert len(m["artifacts"]) >= len(CATALOG)
        for a in m["artifacts"]:
            for k in ("name", "file", "depth", "channels",
                      "input_shapes", "output_shape"):
                assert k in a, f"missing {k}"

    def test_all_artifact_files_written(self, outdir):
        with open(os.path.join(outdir, "manifest.json")) as f:
            m = json.load(f)
        for a in m["artifacts"]:
            p = os.path.join(outdir, a["file"])
            assert os.path.exists(p)
            assert os.path.getsize(p) > 100

    def test_fused_pairs_reference_real_artifacts(self, outdir):
        with open(os.path.join(outdir, "manifest.json")) as f:
            m = json.load(f)
        names = {a["name"] for a in m["artifacts"]}
        for fused, stages in m["fused_pairs"].items():
            assert fused in names
            assert all(s in names for s in stages)

    def test_golden_vectors_exist_and_sized(self, outdir):
        with open(os.path.join(outdir, "manifest.json")) as f:
            m = json.load(f)
        specs, _ = catalog_with_stages()
        by_name = {s.name: s for s in specs}
        for name, g in m["golden"].items():
            spec = by_name[name]
            gdir = os.path.join(outdir, g["dir"])
            shapes = spec.input_shapes()
            assert g["num_inputs"] == len(shapes)
            for i, shape in enumerate(shapes):
                p = os.path.join(gdir, f"in{i}.f32")
                assert os.path.getsize(p) == 4 * int(np.prod(shape))
            out_p = os.path.join(gdir, "out.f32")
            assert os.path.getsize(out_p) == 4 * int(np.prod(spec.output_shape()))

    def test_golden_output_matches_ref_recompute(self, outdir):
        """Golden out.f32 replays through the ref path bit-for-bit."""
        from compile.model import block_forward, random_args
        with open(os.path.join(outdir, "manifest.json")) as f:
            m = json.load(f)
        specs, _ = catalog_with_stages()
        by_name = {s.name: s for s in specs}
        name = sorted(m["golden"])[0]
        spec = by_name[name]
        args = random_args(spec, seed=0)
        (want,) = block_forward(spec, *args, use_kernel=False)
        got = np.fromfile(
            os.path.join(outdir, m["golden"][name]["dir"], "out.f32"),
            dtype="<f4").reshape(spec.output_shape())
        np.testing.assert_allclose(got, np.asarray(want, np.float32),
                                   rtol=1e-5, atol=1e-5)

    def test_sentinel_written_by_main(self, tmp_path, monkeypatch):
        # main() with --outdir writes model.hlo.txt sentinel for the Makefile.
        import sys
        monkeypatch.setattr(sys, "argv",
                            ["aot", "--outdir", str(tmp_path), "-q"])
        aot.main()
        assert (tmp_path / "model.hlo.txt").exists()
