"""L2 correctness: fusion-block forward functions, catalog, and shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as model_mod
from compile.model import (
    BlockSpec, CATALOG, block_forward, catalog_with_stages, example_args,
    make_block_fn, random_args,
)


SMALL = BlockSpec("t_b2", batch=2, height=8, width=8, channels=(4, 6, 4))


class TestBlockSpec:
    def test_depth(self):
        assert SMALL.depth == 2

    def test_input_shapes_order(self):
        shapes = SMALL.input_shapes()
        assert shapes[0] == (2, 8, 8, 4)          # x
        assert shapes[1] == (3, 3, 4, 6)          # w0
        assert shapes[2] == (6,)                  # b0
        assert shapes[3] == (3, 3, 6, 4)          # w1
        assert shapes[4] == (4,)                  # b1

    def test_output_shape(self):
        assert SMALL.output_shape() == (2, 8, 8, 4)

    def test_stage_specs_chain_channels(self):
        stages = SMALL.stage_specs()
        assert [s.channels for s in stages] == [(4, 6), (6, 4)]
        assert all(s.batch == 2 and s.height == 8 for s in stages)

    def test_stage_specs_relu_last_propagates(self):
        spec = BlockSpec("t", batch=1, height=8, width=8,
                         channels=(4, 4, 4), relu_last=False)
        stages = spec.stage_specs()
        assert stages[0].relu_last is True
        assert stages[1].relu_last is False

    def test_json_dict_roundtrip_fields(self):
        d = SMALL.to_json_dict()
        assert d["channels"] == [4, 6, 4]
        assert d["depth"] == 2
        assert d["dtype"] == "f32"


class TestForward:
    def test_batched_forward_shape(self):
        args = random_args(SMALL, seed=1)
        (y,) = block_forward(SMALL, *args)
        assert y.shape == SMALL.output_shape()

    def test_kernel_vs_ref_path(self):
        args = random_args(SMALL, seed=2)
        (yk,) = block_forward(SMALL, *args, use_kernel=True)
        (yr,) = block_forward(SMALL, *args, use_kernel=False)
        np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                                   rtol=1e-4, atol=1e-4)

    def test_fused_equals_stagewise(self):
        """Running the fused block == feeding stages one at a time.

        This is the property the Rust coordinator checks over PJRT; assert it
        in-process first.
        """
        args = random_args(SMALL, seed=3)
        (fused,) = block_forward(SMALL, *args)
        x = args[0]
        cur = x
        for i, st in enumerate(SMALL.stage_specs()):
            (cur,) = block_forward(st, cur, args[1 + 2 * i], args[2 + 2 * i])
        np.testing.assert_allclose(np.asarray(fused), np.asarray(cur),
                                   rtol=1e-4, atol=1e-4)

    def test_example_args_match_random_args_shapes(self):
        ex = example_args(SMALL)
        rnd = random_args(SMALL)
        assert [tuple(a.shape) for a in ex] == [tuple(a.shape) for a in rnd]


class TestCatalog:
    def test_catalog_names_unique(self):
        names = [s.name for s in CATALOG]
        assert len(names) == len(set(names))

    def test_catalog_tile_divides_image(self):
        for s in CATALOG:
            assert s.height % min(s.tile, s.height) == 0

    def test_catalog_with_stages_covers_fused(self):
        specs, pairs = catalog_with_stages()
        names = {s.name for s in specs}
        for fused, stages in pairs.items():
            assert fused in names
            for st in stages:
                assert st in names

    def test_pairs_empty_for_depth1(self):
        _, pairs = catalog_with_stages()
        assert pairs["b1_c8_h16"] == []

    def test_pairs_depth_matches(self):
        specs, pairs = catalog_with_stages()
        by_name = {s.name: s for s in specs}
        for fused, stages in pairs.items():
            if stages:
                assert len(stages) == by_name[fused].depth

    def test_stage_channels_compose(self):
        specs, pairs = catalog_with_stages()
        by_name = {s.name: s for s in specs}
        for fused, stages in pairs.items():
            if not stages:
                continue
            f = by_name[fused]
            chain = [by_name[s] for s in stages]
            assert chain[0].channels[0] == f.channels[0]
            assert chain[-1].channels[-1] == f.channels[-1]
            for a, b in zip(chain, chain[1:]):
                assert a.channels[-1] == b.channels[0]
