"""L1 correctness: the Pallas fused-conv kernel vs the pure-lax oracle.

This is the core correctness signal for the whole stack: every HLO artifact
the Rust coordinator executes embeds this kernel, so kernel == ref here means
fused execution on the request path is mathematically equivalent to unfused
layer-wise execution -- DLFusion's foundational claim.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fused_conv import fused_conv_chain, conv_stage_tile, KERNEL_SIZE
from compile.kernels.ref import fused_conv_chain_ref, conv2d_same_ref


def make_chain(key, depth, channels, h, w, dtype=jnp.float32):
    """Random image + weights/biases for a depth-d chain."""
    if isinstance(channels, int):
        channels = [channels] * (depth + 1)
    assert len(channels) == depth + 1
    keys = jax.random.split(key, 2 * depth + 1)
    x = jax.random.normal(keys[0], (h, w, channels[0])).astype(dtype)
    ws, bs = [], []
    for l in range(depth):
        ws.append(
            (jax.random.normal(keys[2 * l + 1], (3, 3, channels[l], channels[l + 1]))
             * 0.3).astype(dtype))
        bs.append((jax.random.normal(keys[2 * l + 2], (channels[l + 1],)) * 0.1)
                  .astype(dtype))
    return x, ws, bs


def assert_matches(x, ws, bs, relu_last=True, tile=None, tol=1e-4):
    got = fused_conv_chain(x, tuple(ws), tuple(bs), tile=tile, relu_last=relu_last)
    want = fused_conv_chain_ref(x, ws, bs, relu_last=relu_last)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol)


class TestSingleStage:
    def test_depth1_matches_ref(self):
        x, ws, bs = make_chain(jax.random.PRNGKey(0), 1, 8, 16, 16)
        assert_matches(x, ws, bs)

    def test_depth1_no_relu(self):
        x, ws, bs = make_chain(jax.random.PRNGKey(1), 1, 8, 16, 16)
        assert_matches(x, ws, bs, relu_last=False)

    def test_conv_stage_tile_valid_conv(self):
        """The in-kernel stage is a VALID conv: compare against lax directly."""
        key = jax.random.PRNGKey(2)
        x = jax.random.normal(key, (10, 10, 4))
        w = jax.random.normal(jax.random.PRNGKey(3), (3, 3, 4, 6)) * 0.3
        b = jnp.zeros((6,))
        got = conv_stage_tile(x, w, b, apply_relu=False)
        want = jax.lax.conv_general_dilated(
            x[None], w, (1, 1), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))[0]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_nonsquare_image(self):
        x, ws, bs = make_chain(jax.random.PRNGKey(4), 1, 4, 16, 24)
        assert_matches(x, ws, bs)

    def test_single_channel(self):
        x, ws, bs = make_chain(jax.random.PRNGKey(5), 1, 1, 8, 8)
        assert_matches(x, ws, bs)


class TestFusedChain:
    @pytest.mark.parametrize("depth", [2, 3, 4])
    def test_depth_matches_ref(self, depth):
        x, ws, bs = make_chain(jax.random.PRNGKey(10 + depth), depth, 8, 16, 16)
        assert_matches(x, ws, bs)

    def test_channel_growth(self):
        x, ws, bs = make_chain(jax.random.PRNGKey(20), 3, [4, 8, 16, 8], 16, 16)
        assert_matches(x, ws, bs)

    def test_border_masking_is_exact(self):
        """The halo overhang must be re-zeroed between stages: feed an image
        whose border pixels dominate so any masking bug explodes."""
        x, ws, bs = make_chain(jax.random.PRNGKey(21), 3, 4, 12, 12)
        x = x.at[0, :, :].set(100.0).at[-1, :, :].set(-100.0)
        x = x.at[:, 0, :].set(50.0).at[:, -1, :].set(-50.0)
        assert_matches(x, ws, bs, tol=1e-3)

    def test_tile_smaller_than_halo(self):
        # tile=4 with depth=4 -> halo (4) >= tile: stresses window arithmetic.
        x, ws, bs = make_chain(jax.random.PRNGKey(22), 4, 4, 8, 8)
        assert_matches(x, ws, bs, tile=4, tol=1e-3)

    @pytest.mark.parametrize("tile", [2, 4, 8, 16])
    def test_tile_invariance(self, tile):
        """All tile sizes must produce the identical function."""
        x, ws, bs = make_chain(jax.random.PRNGKey(23), 2, 6, 16, 16)
        assert_matches(x, ws, bs, tile=tile)

    def test_no_relu_last_negative_outputs_survive(self):
        x, ws, bs = make_chain(jax.random.PRNGKey(24), 2, 4, 8, 8)
        got = fused_conv_chain(x, tuple(ws), tuple(bs), relu_last=False)
        assert np.asarray(got).min() < 0.0

    def test_zero_input_gives_bias_cascade(self):
        """x == 0 -> stage0 output is relu(b0) everywhere in the interior."""
        depth = 2
        x, ws, bs = make_chain(jax.random.PRNGKey(25), depth, 4, 12, 12)
        x = jnp.zeros_like(x)
        assert_matches(x, ws, bs)

    def test_bfloat16(self):
        x, ws, bs = make_chain(jax.random.PRNGKey(26), 2, 8, 16, 16,
                               dtype=jnp.bfloat16)
        got = fused_conv_chain(x, tuple(ws), tuple(bs))
        want = fused_conv_chain_ref(x, ws, bs)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=5e-2, atol=5e-2)


class TestValidation:
    def test_empty_chain_rejected(self):
        x = jnp.zeros((8, 8, 4))
        with pytest.raises(ValueError, match="at least one"):
            fused_conv_chain(x, (), ())

    def test_channel_mismatch_rejected(self):
        x = jnp.zeros((8, 8, 4))
        w0 = jnp.zeros((3, 3, 4, 8))
        w1 = jnp.zeros((3, 3, 4, 8))  # expects 8 in
        b = jnp.zeros((8,))
        with pytest.raises(ValueError, match="channel mismatch"):
            fused_conv_chain(x, (w0, w1), (b, b))

    def test_input_channel_mismatch_rejected(self):
        x = jnp.zeros((8, 8, 3))
        w0 = jnp.zeros((3, 3, 4, 8))
        with pytest.raises(ValueError, match="C_in"):
            fused_conv_chain(x, (w0,), (jnp.zeros((8,)),))

    def test_weight_bias_arity_mismatch_rejected(self):
        x = jnp.zeros((8, 8, 4))
        w0 = jnp.zeros((3, 3, 4, 8))
        with pytest.raises(ValueError, match="mismatch"):
            fused_conv_chain(x, (w0,), ())


@settings(max_examples=25, deadline=None)
@given(
    depth=st.integers(1, 3),
    c0=st.integers(1, 6),
    c1=st.integers(1, 6),
    h=st.sampled_from([6, 8, 12]),
    w=st.sampled_from([6, 8, 12]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_kernel_vs_ref(depth, c0, c1, h, w, seed):
    """Randomized sweep of shapes/depths: kernel == oracle everywhere."""
    channels = [c0] + [c1] * depth
    x, ws, bs = make_chain(jax.random.PRNGKey(seed), depth, channels, h, w)
    assert_matches(x, ws, bs, tol=5e-4)


@settings(max_examples=10, deadline=None)
@given(
    relu_last=st.booleans(),
    tile=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_tile_and_relu(relu_last, tile, seed):
    x, ws, bs = make_chain(jax.random.PRNGKey(seed), 2, 4, 8, 8)
    assert_matches(x, ws, bs, relu_last=relu_last, tile=tile, tol=5e-4)
