#!/usr/bin/env sh
# One-command refresh of the perf-smoke gating baseline
# (rust/ci/perf_baseline.json; see rust/ci/README.md and
# rust/docs/DESIGN.md §12 "perf-smoke gating tiers").
#
# Run this FROM A TRUSTED RUNNER-CLASS MACHINE — the recorded wall_metrics
# band gates future runs of the same hardware class, so a developer laptop
# or an offline build container would record numbers CI can never meet (or
# trivially beats). The simulated `metrics` section is machine-independent
# and bit-stable; review the diff before committing and expect ONLY
# deliberate changes there.
#
# Usage:  ci/record_baseline.sh [--threads N]      (from rust/)
#         rust/ci/record_baseline.sh [--threads N] (from the repo root)
#
# Flags are passed through to `dlfusion perf-smoke` (e.g. --threads for
# the parallel-speedup leg; default 4).

set -eu

# Resolve the crate root (this script's parent's parent) so it works from
# anywhere in the repo.
script_dir=$(CDPATH= cd -- "$(dirname -- "$0")" && pwd)
crate_dir=$(dirname -- "$script_dir")
cd "$crate_dir"

cargo run --release -- perf-smoke --write-baseline \
    --out BENCH_ci.json --baseline ci/perf_baseline.json "$@"

echo
echo "recorded ci/perf_baseline.json — review with 'git diff rust/ci/' and"
echo "commit; the simulated metrics section must only change when a PR"
echo "deliberately moves the predicted-performance surface."
