//! Criterion-replacement micro-benchmark harness (offline environment —
//! see DESIGN.md §2 environment substitutions).
//!
//! Each `rust/benches/*.rs` target (built with `harness = false`) uses
//! [`Bench`] for timed sections and the free functions for the paper-figure
//! tables it regenerates. Results land on stdout and, for every figure, as
//! CSV under `bench_out/`.

use std::time::Instant;

use crate::obs::Probe;
use crate::stats::Summary;

/// Default output directory for bench CSVs.
pub const BENCH_OUT_DIR: &str = "bench_out";

/// Timing result of one benchmarked closure.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time, milliseconds.
    pub summary: Summary,
    pub iterations: usize,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.summary.mean
    }

    /// One-line criterion-style report.
    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>10.4} ms/iter (median {:.4}, sd {:.4}, n={})",
            self.name, self.summary.mean, self.summary.median,
            self.summary.std, self.iterations
        )
    }
}

/// A named group of timed benchmarks.
pub struct Bench {
    group: String,
    warmup_iters: usize,
    sample_iters: usize,
    results: Vec<BenchResult>,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        // Honor the harness contract: `cargo bench -- --quick` style knobs
        // are not needed; defaults keep full runs < ~1 min per target.
        Bench { group: group.to_string(), warmup_iters: 3, sample_iters: 15,
                results: Vec::new() }
    }

    pub fn with_iters(mut self, warmup: usize, samples: usize) -> Self {
        self.warmup_iters = warmup;
        self.sample_iters = samples.max(2);
        self
    }

    /// Time a closure; the closure's return value is black-boxed so the
    /// optimizer cannot elide the work.
    pub fn time<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.sample_iters);
        for _ in 0..self.sample_iters {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        self.results.push(BenchResult {
            name: format!("{}/{}", self.group, name),
            summary: Summary::of(&samples),
            iterations: self.sample_iters,
        });
        self.results.last().unwrap()
    }

    /// Print all accumulated reports.
    pub fn finish(self) -> Vec<BenchResult> {
        println!("\n== timing: {} ==", self.group);
        for r in &self.results {
            println!("  {}", r.report());
        }
        self.results
    }

    /// Like [`Self::finish`], but also publishes every result through a
    /// [`Probe`] (rust/docs/DESIGN.md §14.3): a `{name}.mean_ms` sample
    /// plus one `span_us` per result, so benches and `perf-smoke` feed the
    /// same instrumentation surface as the tuner and the serving stack.
    pub fn finish_into(self, probe: &mut dyn Probe) -> Vec<BenchResult> {
        let results = self.finish();
        for r in &results {
            probe.sample(&format!("{}.mean_ms", r.name), r.mean_ms());
            probe.span_us(&r.name, r.mean_ms() * 1e3);
        }
        results
    }
}

/// `std::hint::black_box` wrapper (stable since 1.66).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Print the standard bench banner with the paper artifact being
/// regenerated.
pub fn banner(figure: &str, what: &str) {
    println!("================================================================");
    println!("  DLFusion reproduction — {figure}");
    println!("  {what}");
    println!("================================================================");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_produces_sane_stats() {
        let mut b = Bench::new("test").with_iters(1, 5);
        let r = b.time("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.summary.mean > 0.0);
        assert_eq!(r.iterations, 5);
        let all = b.finish();
        assert_eq!(all.len(), 1);
        assert!(all[0].name.starts_with("test/"));
    }

    #[test]
    fn report_contains_name_and_units() {
        let mut b = Bench::new("g").with_iters(0, 2);
        let r = b.time("x", || 1 + 1);
        let rep = r.report();
        assert!(rep.contains("g/x") && rep.contains("ms/iter"));
    }

    #[test]
    fn finish_into_publishes_through_a_probe() {
        use crate::obs::{Domain, MetricsRegistry, RegistryProbe};
        let mut b = Bench::new("g").with_iters(0, 2);
        b.time("x", || 1 + 1);
        let mut reg = MetricsRegistry::new();
        let results = {
            let mut p = RegistryProbe::new(&mut reg, Domain::Wall);
            b.finish_into(&mut p)
        };
        assert_eq!(results.len(), 1);
        assert_eq!(reg.gauge("g/x.mean_ms"), Some(results[0].mean_ms()));
        assert_eq!(reg.histogram("g/x").unwrap().count(), 1);
    }
}
