//! Hierarchical span tracing with Chrome trace-event export
//! (rust/docs/DESIGN.md §14.1).
//!
//! A [`TraceSession`] collects [`Span`]s (named intervals with a track id
//! and key/value args) and counter samples, then serializes them as Chrome
//! trace-event JSON — the `{"traceEvents": […]}` format `chrome://tracing`
//! and Perfetto load directly.
//!
//! The two-clock rule: every span is stamped with the [`Clock`] it was
//! measured on.
//!
//! - [`Clock::Sim`] spans carry *simulated* milliseconds (the serving
//!   event loop's clock). They are pure functions of the run's inputs:
//!   bit-identical run-to-run and under `--threads N`, and pinned so by
//!   rust/tests/parallel_parity.rs.
//! - [`Clock::Wall`] spans carry wall-clock microseconds (tuning phases).
//!   They are measurements of this machine and may differ every run.
//!
//! The export never mixes the two: each clock renders as its own process
//! (`pid`) with a `process_name` metadata record, so a mixed session shows
//! two clearly-labeled lanes in the viewer and a deterministic consumer
//! can filter on `pid` alone.

use crate::util::Json;

/// Which clock a span's timestamps were taken from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Clock {
    /// Simulated time (milliseconds in the discrete-event simulator).
    Sim,
    /// Wall-clock time (microseconds since the session's epoch).
    Wall,
}

impl Clock {
    /// Chrome trace `pid` for this clock's lane.
    fn pid(self) -> u64 {
        match self {
            Clock::Sim => 1,
            Clock::Wall => 2,
        }
    }

    fn process_name(self) -> &'static str {
        match self {
            Clock::Sim => "sim-time (deterministic)",
            Clock::Wall => "wall-clock (machine-dependent)",
        }
    }
}

/// One complete ("X"-phase) interval on a [`Clock`].
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub name: String,
    /// Category string (Chrome trace `cat`; used for viewer filtering).
    pub cat: String,
    pub clock: Clock,
    /// Start in microseconds on `clock` (Chrome trace `ts` is always µs;
    /// sim-time spans convert their milliseconds once, exactly, here).
    pub ts_us: f64,
    pub dur_us: f64,
    /// Track (Chrome trace `tid`): a lane within the clock's process —
    /// model index for serving spans, backend/batch lane for tuning.
    pub track: u64,
    pub args: Vec<(String, Json)>,
}

/// One sample of a named counter track ("C"-phase event).
#[derive(Debug, Clone, PartialEq)]
struct CounterSample {
    name: String,
    clock: Clock,
    ts_us: f64,
    value: f64,
}

/// A zero-duration point marker ("i"-phase event) — a moment worth seeing
/// in the viewer that occupies no interval, like a fleet shed decision.
#[derive(Debug, Clone, PartialEq)]
struct InstantMark {
    name: String,
    cat: String,
    clock: Clock,
    ts_us: f64,
    track: u64,
}

/// An in-memory trace being assembled for export.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TraceSession {
    /// Session name (rendered as the trace's `otherData.name`).
    pub name: String,
    spans: Vec<Span>,
    counters: Vec<CounterSample>,
    instants: Vec<InstantMark>,
}

impl TraceSession {
    pub fn new(name: &str) -> TraceSession {
        TraceSession { name: name.to_string(), ..TraceSession::default() }
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty() && self.counters.is_empty()
            && self.instants.is_empty()
    }

    pub fn len(&self) -> usize {
        self.spans.len() + self.counters.len() + self.instants.len()
    }

    pub fn push(&mut self, span: Span) {
        self.spans.push(span);
    }

    /// Record a simulated-time span from `[start_ms, end_ms]` on `track`.
    pub fn sim_span(&mut self, name: &str, cat: &str, track: u64, start_ms: f64,
                    end_ms: f64, args: Vec<(String, Json)>) {
        self.push(Span {
            name: name.to_string(),
            cat: cat.to_string(),
            clock: Clock::Sim,
            ts_us: start_ms * 1000.0,
            dur_us: (end_ms - start_ms) * 1000.0,
            track,
            args,
        });
    }

    /// Record a wall-clock span from `[start_us, start_us + dur_us]`.
    pub fn wall_span(&mut self, name: &str, cat: &str, track: u64, start_us: f64,
                     dur_us: f64, args: Vec<(String, Json)>) {
        self.push(Span {
            name: name.to_string(),
            cat: cat.to_string(),
            clock: Clock::Wall,
            ts_us: start_us,
            dur_us,
            track,
            args,
        });
    }

    /// Record one sample of a simulated-time counter track (rendered as a
    /// stepped area chart by the trace viewers).
    pub fn sim_counter(&mut self, name: &str, time_ms: f64, value: f64) {
        self.counters.push(CounterSample {
            name: name.to_string(),
            clock: Clock::Sim,
            ts_us: time_ms * 1000.0,
            value,
        });
    }

    /// Record a simulated-time instant marker on `track` (thread-scoped
    /// "i"-phase event: a vertical tick in the viewers).
    pub fn sim_instant(&mut self, name: &str, cat: &str, track: u64,
                       time_ms: f64) {
        self.instants.push(InstantMark {
            name: name.to_string(),
            cat: cat.to_string(),
            clock: Clock::Sim,
            ts_us: time_ms * 1000.0,
            track,
        });
    }

    fn uses_clock(&self, clock: Clock) -> bool {
        self.spans.iter().any(|s| s.clock == clock)
            || self.counters.iter().any(|c| c.clock == clock)
            || self.instants.iter().any(|i| i.clock == clock)
    }

    /// Serialize as a Chrome trace-event document. Events appear in
    /// insertion order after the per-clock `process_name` metadata, so the
    /// output is a deterministic function of the recorded spans (for
    /// [`Clock::Sim`]-only sessions, deterministic end to end).
    pub fn to_chrome_json(&self) -> Json {
        let mut events = Vec::new();
        for clock in [Clock::Sim, Clock::Wall] {
            if !self.uses_clock(clock) {
                continue;
            }
            events.push(Json::obj(vec![
                ("name", Json::Str("process_name".into())),
                ("ph", Json::Str("M".into())),
                ("pid", Json::Num(clock.pid() as f64)),
                ("tid", Json::Num(0.0)),
                ("args", Json::obj(vec![(
                    "name",
                    Json::Str(clock.process_name().into()),
                )])),
            ]));
        }
        for s in &self.spans {
            let args: Vec<(&str, Json)> =
                s.args.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
            events.push(Json::obj(vec![
                ("name", Json::Str(s.name.clone())),
                ("cat", Json::Str(s.cat.clone())),
                ("ph", Json::Str("X".into())),
                ("ts", Json::Num(s.ts_us)),
                ("dur", Json::Num(s.dur_us)),
                ("pid", Json::Num(s.clock.pid() as f64)),
                ("tid", Json::Num(s.track as f64)),
                ("args", Json::obj(args)),
            ]));
        }
        for c in &self.counters {
            events.push(Json::obj(vec![
                ("name", Json::Str(c.name.clone())),
                ("ph", Json::Str("C".into())),
                ("ts", Json::Num(c.ts_us)),
                ("pid", Json::Num(c.clock.pid() as f64)),
                ("tid", Json::Num(0.0)),
                ("args", Json::obj(vec![(c.name.as_str(), Json::Num(c.value))])),
            ]));
        }
        for i in &self.instants {
            events.push(Json::obj(vec![
                ("name", Json::Str(i.name.clone())),
                ("cat", Json::Str(i.cat.clone())),
                ("ph", Json::Str("i".into())),
                // Thread-scoped: the tick renders on its own track, not
                // across the whole process.
                ("s", Json::Str("t".into())),
                ("ts", Json::Num(i.ts_us)),
                ("pid", Json::Num(i.clock.pid() as f64)),
                ("tid", Json::Num(i.track as f64)),
            ]));
        }
        Json::obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", Json::Str("ms".into())),
            ("otherData", Json::obj(vec![("name", Json::Str(self.name.clone()))])),
        ])
    }

    /// Compact single-line serialization of [`Self::to_chrome_json`].
    pub fn to_chrome_string(&self) -> String {
        self.to_chrome_json().to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_spans_convert_ms_to_us_exactly() {
        let mut t = TraceSession::new("s");
        t.sim_span("svc", "serving", 3, 1.5, 4.0, vec![]);
        assert_eq!(t.len(), 1);
        let doc = t.to_chrome_json();
        let events = doc.get("traceEvents").as_arr().unwrap();
        // events[0] is the process_name metadata record.
        assert_eq!(events[0].get("ph").as_str(), Some("M"));
        let span = &events[1];
        assert_eq!(span.get("ph").as_str(), Some("X"));
        assert_eq!(span.get("ts").as_f64(), Some(1500.0));
        assert_eq!(span.get("dur").as_f64(), Some(2500.0));
        assert_eq!(span.get("pid").as_f64(), Some(1.0));
        assert_eq!(span.get("tid").as_f64(), Some(3.0));
    }

    #[test]
    fn clocks_render_as_separate_labeled_processes() {
        let mut t = TraceSession::new("mixed");
        t.sim_span("a", "serving", 0, 0.0, 1.0, vec![]);
        t.wall_span("b", "tuning", 0, 0.0, 50.0, vec![]);
        let doc = t.to_chrome_json();
        let events = doc.get("traceEvents").as_arr().unwrap();
        let meta: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("ph").as_str() == Some("M"))
            .collect();
        assert_eq!(meta.len(), 2);
        assert!(meta[0].get("args").get("name").as_str().unwrap()
            .contains("deterministic"));
        assert!(meta[1].get("args").get("name").as_str().unwrap()
            .contains("machine-dependent"));
        // The two spans land in different pids.
        let pids: Vec<f64> = events
            .iter()
            .filter(|e| e.get("ph").as_str() == Some("X"))
            .map(|e| e.get("pid").as_f64().unwrap())
            .collect();
        assert_eq!(pids, vec![1.0, 2.0]);
    }

    #[test]
    fn counter_samples_render_as_c_events() {
        let mut t = TraceSession::new("c");
        t.sim_counter("free_cores", 2.0, 30.0);
        let doc = t.to_chrome_json();
        let ev = doc.get("traceEvents").at(1);
        assert_eq!(ev.get("ph").as_str(), Some("C"));
        assert_eq!(ev.get("ts").as_f64(), Some(2000.0));
        assert_eq!(ev.get("args").get("free_cores").as_f64(), Some(30.0));
    }

    #[test]
    fn instant_marks_render_as_thread_scoped_i_events() {
        let mut t = TraceSession::new("i");
        t.sim_instant("shed #4", "shed", 64, 3.5);
        assert_eq!(t.len(), 1);
        assert!(!t.is_empty());
        let doc = t.to_chrome_json();
        let events = doc.get("traceEvents").as_arr().unwrap();
        // events[0] is the sim-clock process_name metadata record.
        assert_eq!(events.len(), 2);
        let ev = &events[1];
        assert_eq!(ev.get("ph").as_str(), Some("i"));
        assert_eq!(ev.get("s").as_str(), Some("t"));
        assert_eq!(ev.get("ts").as_f64(), Some(3500.0));
        assert_eq!(ev.get("pid").as_f64(), Some(1.0));
        assert_eq!(ev.get("tid").as_f64(), Some(64.0));
    }

    #[test]
    fn export_is_valid_json_and_deterministic() {
        let build = || {
            let mut t = TraceSession::new("d");
            t.sim_span("x", "serving", 1, 0.25, 0.75,
                       vec![("id".into(), Json::Num(7.0))]);
            t.sim_counter("depth", 0.25, 1.0);
            t.to_chrome_string()
        };
        let (a, b) = (build(), build());
        assert_eq!(a, b);
        let doc = Json::parse(&a).unwrap();
        assert_eq!(doc.get("displayTimeUnit").as_str(), Some("ms"));
        assert!(doc.get("traceEvents").as_arr().unwrap().len() >= 2);
    }

    #[test]
    fn empty_session_exports_no_events() {
        let t = TraceSession::new("empty");
        assert!(t.is_empty());
        let doc = t.to_chrome_json();
        assert!(doc.get("traceEvents").as_arr().unwrap().is_empty());
    }
}
