//! The unified metrics registry (rust/docs/DESIGN.md §14.2).
//!
//! Every subsystem that counts something — the cost engine's cache stats,
//! the tuner's evaluation budgets and phase timings, the serving
//! simulator's SLO report — exports into one [`MetricsRegistry`] instead
//! of growing another ad-hoc struct. The registry holds three metric
//! kinds (counters, gauges, fixed-log-bucket histograms), each registered
//! under one of two *domains*:
//!
//! - [`Domain::Sim`] — derived purely from simulated quantities (event
//!   clocks, cache-key counts, predicted latencies). Bit-identical
//!   run-to-run and across `--threads N`; CI gates on these exactly.
//! - [`Domain::Wall`] — wall-clock measurements (tuning throughput, phase
//!   timings, lock contention). Machine-dependent; exposed in a separate
//!   section so no consumer can mistake one for the other (the PR 6
//!   merged-`stats` vs `local_stats` discipline, promoted into the export
//!   format itself).
//!
//! Exposition is dual: [`MetricsRegistry::snapshot`] renders JSON through
//! [`crate::util::Json`] (`BTreeMap`-sorted keys, so deterministic
//! byte-for-byte), and [`MetricsRegistry::to_prometheus`] renders the
//! Prometheus text format with a `domain` label on every sample. The
//! `dlfusion report` command round-trips a snapshot back through
//! [`MetricsRegistry::from_snapshot`] to render it as a table.

use std::collections::BTreeMap;

use crate::util::{Json, Table};

/// Which clock a metric is derived from. The split is the repo's central
/// observability contract: `Sim` values are pure functions of the inputs
/// (pinned bit-identical by rust/tests/parallel_parity.rs), `Wall` values
/// are measurements of this machine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Domain {
    /// Deterministic: simulated time, counted work, predicted latencies.
    Sim,
    /// Machine-dependent: wall-clock durations, throughput, contention.
    Wall,
}

impl Domain {
    /// Section key used in the canonical snapshot JSON.
    pub fn key(self) -> &'static str {
        match self {
            Domain::Sim => "deterministic",
            Domain::Wall => "wall",
        }
    }

    /// Short label used in Prometheus exposition and tables.
    pub fn label(self) -> &'static str {
        match self {
            Domain::Sim => "sim",
            Domain::Wall => "wall",
        }
    }
}

/// Histogram bucket layout: log2 bounds `2^-4, 2^-3, …, 2^24` plus an
/// overflow bucket. Fixed (not data-dependent) so two histograms are
/// always mergeable and snapshots are comparable across runs.
const HIST_MIN_EXP: i32 = -4;
const HIST_NUM_BOUNDS: usize = 29;

/// A fixed-log-bucket histogram (unit-agnostic; callers pick ms, µs, …).
///
/// Bucketing uses only comparisons against exact powers of two — no
/// transcendental functions — so the bucket a value lands in is
/// deterministic everywhere.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    /// `counts[i]` = observations with `bound(i-1) < v <= bound(i)`;
    /// `counts[HIST_NUM_BOUNDS]` is the overflow bucket.
    counts: Vec<u64>,
    count: u64,
    sum: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { counts: vec![0; HIST_NUM_BOUNDS + 1], count: 0, sum: 0.0 }
    }
}

impl Histogram {
    /// Upper bound of bucket `i` (exact power of two).
    fn bound(i: usize) -> f64 {
        2f64.powi(HIST_MIN_EXP + i as i32)
    }

    pub fn observe(&mut self, v: f64) {
        let idx = (0..HIST_NUM_BOUNDS)
            .find(|&i| v <= Self::bound(i))
            .unwrap_or(HIST_NUM_BOUNDS);
        self.counts[idx] += 1;
        self.count += 1;
        self.sum += v;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.sum / self.count as f64 }
    }

    /// JSON form: `{"count", "sum", "buckets": [[le, n], …]}` with only
    /// the non-empty buckets listed (the overflow bucket's `le` is the
    /// string `"+Inf"`).
    fn to_json(&self) -> Json {
        let mut buckets = Vec::new();
        for (i, &n) in self.counts.iter().enumerate() {
            if n == 0 {
                continue;
            }
            let le = if i < HIST_NUM_BOUNDS {
                Json::Num(Self::bound(i))
            } else {
                Json::Str("+Inf".into())
            };
            buckets.push(Json::Arr(vec![le, Json::Num(n as f64)]));
        }
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("sum", Json::Num(self.sum)),
            ("buckets", Json::Arr(buckets)),
        ])
    }

    fn from_json(v: &Json) -> Option<Histogram> {
        let mut counts = vec![0u64; HIST_NUM_BOUNDS + 1];
        for b in v.get("buckets").as_arr()? {
            let n = b.at(1).as_f64()? as u64;
            let idx = match b.at(0) {
                Json::Str(s) if s == "+Inf" => HIST_NUM_BOUNDS,
                Json::Num(le) => (0..HIST_NUM_BOUNDS)
                    .find(|&i| (Self::bound(i) - le).abs() < 1e-12)?,
                _ => return None,
            };
            counts[idx] = n;
        }
        Some(Histogram {
            counts,
            count: v.get("count").as_f64()? as u64,
            sum: v.get("sum").as_f64()?,
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
enum MetricValue {
    Counter(u64),
    Gauge(f64),
    Histogram(Histogram),
}

impl MetricValue {
    fn kind(&self) -> &'static str {
        match self {
            MetricValue::Counter(_) => "counter",
            MetricValue::Gauge(_) => "gauge",
            MetricValue::Histogram(_) => "histogram",
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
struct Metric {
    domain: Domain,
    value: MetricValue,
}

/// The one registry behind `--metrics-out`, `dlfusion report`, and the
/// perf-smoke CI artifact. Name-keyed over a `BTreeMap`, so every
/// exposition walks metrics in sorted order (deterministic output).
///
/// Writing a name with a different kind (or domain) than before replaces
/// the previous registration — last writer wins, no silent partial
/// merges.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsRegistry {
    metrics: BTreeMap<String, Metric>,
    help: BTreeMap<String, String>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    pub fn is_empty(&self) -> bool {
        self.metrics.is_empty()
    }

    pub fn len(&self) -> usize {
        self.metrics.len()
    }

    /// Increment a counter (created at zero on first use).
    pub fn inc(&mut self, domain: Domain, name: &str, by: u64) {
        match self.metrics.get_mut(name) {
            Some(m) if m.domain == domain => {
                if let MetricValue::Counter(c) = &mut m.value {
                    *c += by;
                    return;
                }
                m.value = MetricValue::Counter(by);
            }
            _ => {
                self.metrics.insert(
                    name.to_string(),
                    Metric { domain, value: MetricValue::Counter(by) },
                );
            }
        }
    }

    /// Set a gauge to its current value.
    pub fn set_gauge(&mut self, domain: Domain, name: &str, v: f64) {
        self.metrics.insert(
            name.to_string(),
            Metric { domain, value: MetricValue::Gauge(v) },
        );
    }

    /// Record one observation into a histogram (created empty on first
    /// use).
    pub fn observe(&mut self, domain: Domain, name: &str, v: f64) {
        match self.metrics.get_mut(name) {
            Some(m) if m.domain == domain => {
                if let MetricValue::Histogram(h) = &mut m.value {
                    h.observe(v);
                    return;
                }
                let mut h = Histogram::default();
                h.observe(v);
                m.value = MetricValue::Histogram(h);
            }
            _ => {
                let mut h = Histogram::default();
                h.observe(v);
                self.metrics.insert(
                    name.to_string(),
                    Metric { domain, value: MetricValue::Histogram(h) },
                );
            }
        }
    }

    /// Attach a help string (emitted as `# HELP` in Prometheus text).
    pub fn describe(&mut self, name: &str, help: &str) {
        self.help.insert(name.to_string(), help.to_string());
    }

    pub fn counter(&self, name: &str) -> Option<u64> {
        match self.metrics.get(name)?.value {
            MetricValue::Counter(c) => Some(c),
            _ => None,
        }
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        match self.metrics.get(name)?.value {
            MetricValue::Gauge(g) => Some(g),
            _ => None,
        }
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        match &self.metrics.get(name)?.value {
            MetricValue::Histogram(h) => Some(h),
            _ => None,
        }
    }

    /// One domain's metrics as a flat JSON object: counters and gauges as
    /// numbers, histograms as their structured form. This is the section
    /// body the snapshot (and the perf-smoke `metrics`/`wall_metrics`
    /// sections) are built from.
    pub fn domain_json(&self, domain: Domain) -> Json {
        let mut obj = BTreeMap::new();
        for (name, m) in &self.metrics {
            if m.domain != domain {
                continue;
            }
            let v = match &m.value {
                MetricValue::Counter(c) => Json::Num(*c as f64),
                MetricValue::Gauge(g) => Json::Num(*g),
                MetricValue::Histogram(h) => h.to_json(),
            };
            obj.insert(name.clone(), v);
        }
        Json::Obj(obj)
    }

    /// The canonical snapshot: `{"deterministic": {…}, "wall": {…}}`.
    pub fn snapshot(&self) -> Json {
        Json::obj(vec![
            (Domain::Sim.key(), self.domain_json(Domain::Sim)),
            (Domain::Wall.key(), self.domain_json(Domain::Wall)),
        ])
    }

    /// Rebuild a registry from a snapshot. Accepts both the canonical
    /// section keys (`deterministic`/`wall`) and the perf-smoke CI ones
    /// (`metrics`/`wall_metrics`), so `dlfusion report` renders either
    /// artifact. Plain numbers come back as gauges (the snapshot does not
    /// distinguish them from counters); histograms round-trip exactly.
    pub fn from_snapshot(doc: &Json) -> Result<MetricsRegistry, String> {
        let mut reg = MetricsRegistry::new();
        let mut any_section = false;
        for (keys, domain) in [
            (["deterministic", "metrics"], Domain::Sim),
            (["wall", "wall_metrics"], Domain::Wall),
        ] {
            for key in keys {
                let Some(obj) = doc.get(key).as_obj() else { continue };
                any_section = true;
                for (name, v) in obj {
                    match v {
                        Json::Num(n) => reg.set_gauge(domain, name, *n),
                        Json::Obj(_) => {
                            let h = Histogram::from_json(v).ok_or_else(|| {
                                format!("metric '{name}' is not a histogram")
                            })?;
                            reg.metrics.insert(
                                name.clone(),
                                Metric { domain, value: MetricValue::Histogram(h) },
                            );
                        }
                        _ => {
                            return Err(format!(
                                "metric '{name}' has a non-numeric value"));
                        }
                    }
                }
            }
        }
        if !any_section {
            return Err("no metrics sections found (expected \
                        'deterministic'/'wall' or 'metrics'/'wall_metrics')"
                .into());
        }
        Ok(reg)
    }

    /// Prometheus text exposition. Metric names are sanitized to the
    /// Prometheus charset and prefixed `dlfusion_`; every sample carries a
    /// `domain="sim"|"wall"` label so the determinism contract survives
    /// scraping.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, m) in &self.metrics {
            let pname = prom_name(name);
            if let Some(h) = self.help.get(name) {
                out.push_str(&format!("# HELP {pname} {h}\n"));
            }
            out.push_str(&format!("# TYPE {pname} {}\n", m.value.kind()));
            let dom = m.domain.label();
            match &m.value {
                MetricValue::Counter(c) => {
                    out.push_str(&format!(
                        "{pname}{{domain=\"{dom}\"}} {}\n", fmt_num(*c as f64)));
                }
                MetricValue::Gauge(g) => {
                    out.push_str(&format!(
                        "{pname}{{domain=\"{dom}\"}} {}\n", fmt_num(*g)));
                }
                MetricValue::Histogram(h) => {
                    let mut cum = 0u64;
                    for (i, &n) in h.counts.iter().enumerate() {
                        cum += n;
                        // Skip still-empty leading buckets to keep the
                        // exposition short; cumulative counts stay exact.
                        if cum == 0 && i < HIST_NUM_BOUNDS {
                            continue;
                        }
                        let le = if i < HIST_NUM_BOUNDS {
                            fmt_num(Histogram::bound(i))
                        } else {
                            "+Inf".to_string()
                        };
                        out.push_str(&format!(
                            "{pname}_bucket{{domain=\"{dom}\",le=\"{le}\"}} {cum}\n"));
                    }
                    out.push_str(&format!(
                        "{pname}_sum{{domain=\"{dom}\"}} {}\n", fmt_num(h.sum())));
                    out.push_str(&format!(
                        "{pname}_count{{domain=\"{dom}\"}} {}\n", h.count()));
                }
            }
        }
        out
    }

    /// Render the registry as the `dlfusion report` table.
    pub fn render_table(&self) -> Table {
        let mut t = Table::new(&["metric", "domain", "kind", "value"])
            .label_first()
            .with_title("metrics snapshot");
        for (name, m) in &self.metrics {
            let value = match &m.value {
                MetricValue::Counter(c) => format!("{c}"),
                MetricValue::Gauge(g) => fmt_num(*g),
                MetricValue::Histogram(h) => format!(
                    "n={} sum={} mean={:.4}", h.count(), fmt_num(h.sum()), h.mean()),
            };
            t.row(vec![
                name.clone(),
                m.domain.label().to_string(),
                m.value.kind().to_string(),
                value,
            ]);
        }
        t
    }
}

/// Number formatting shared with [`crate::util::Json`]: integral values
/// print without a fraction, everything else via the shortest `{}` form.
/// Keeps Prometheus text byte-stable with the JSON exposition.
fn fmt_num(n: f64) -> String {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        format!("{}", n as i64)
    } else {
        format!("{n}")
    }
}

fn prom_name(name: &str) -> String {
    let mut s = String::from("dlfusion_");
    for c in name.chars() {
        if c.is_ascii_alphanumeric() {
            s.push(c);
        } else {
            s.push('_');
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_gauges_overwrite() {
        let mut r = MetricsRegistry::new();
        r.inc(Domain::Sim, "hits", 3);
        r.inc(Domain::Sim, "hits", 4);
        r.set_gauge(Domain::Wall, "rate", 1.5);
        r.set_gauge(Domain::Wall, "rate", 2.5);
        assert_eq!(r.counter("hits"), Some(7));
        assert_eq!(r.gauge("rate"), Some(2.5));
        assert_eq!(r.len(), 2);
    }

    #[test]
    fn histogram_buckets_are_deterministic_powers_of_two() {
        let mut h = Histogram::default();
        h.observe(0.05); // <= 2^-4
        h.observe(1.0); // exactly a bound
        h.observe(3.0); // (2, 4]
        h.observe(1e9); // overflow
        assert_eq!(h.count(), 4);
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.counts[4], 1, "1.0 lands on the 2^0 bound");
        assert_eq!(h.counts[6], 1, "3.0 in (2, 4]");
        assert_eq!(h.counts[HIST_NUM_BOUNDS], 1);
    }

    #[test]
    fn snapshot_sections_segregate_domains() {
        let mut r = MetricsRegistry::new();
        r.inc(Domain::Sim, "evals", 10);
        r.set_gauge(Domain::Wall, "wall_us", 123.0);
        let snap = r.snapshot();
        assert_eq!(snap.get("deterministic").get("evals").as_f64(), Some(10.0));
        assert!(snap.get("deterministic").get("wall_us").is_null());
        assert_eq!(snap.get("wall").get("wall_us").as_f64(), Some(123.0));
    }

    #[test]
    fn snapshot_roundtrips_through_from_snapshot() {
        let mut r = MetricsRegistry::new();
        r.inc(Domain::Sim, "evals", 10);
        r.observe(Domain::Wall, "lat_ms", 0.5);
        r.observe(Domain::Wall, "lat_ms", 7.0);
        let snap = r.snapshot();
        let back = MetricsRegistry::from_snapshot(&snap).unwrap();
        // Counters come back as gauges; histograms round-trip exactly.
        assert_eq!(back.gauge("evals"), Some(10.0));
        let h = back.histogram("lat_ms").unwrap();
        assert_eq!(h.count(), 2);
        assert_eq!(h.sum(), 7.5);
        assert_eq!(back.snapshot(), snap);
    }

    #[test]
    fn from_snapshot_accepts_perf_smoke_keys_and_rejects_garbage() {
        let doc = Json::parse(
            r#"{"schema": 2, "metrics": {"a_ms": 1.5}, "wall_metrics": {"b": 2}}"#,
        )
        .unwrap();
        let r = MetricsRegistry::from_snapshot(&doc).unwrap();
        assert_eq!(r.gauge("a_ms"), Some(1.5));
        assert_eq!(r.gauge("b"), Some(2.0));
        let err = MetricsRegistry::from_snapshot(&Json::parse("{}").unwrap());
        assert!(err.unwrap_err().contains("no metrics sections"));
        let bad = Json::parse(r#"{"metrics": {"x": "nope"}}"#).unwrap();
        assert!(MetricsRegistry::from_snapshot(&bad).is_err());
    }

    #[test]
    fn prometheus_text_carries_domain_labels_and_types() {
        let mut r = MetricsRegistry::new();
        r.inc(Domain::Sim, "cache.hits", 5);
        r.describe("cache.hits", "cost-engine cache hits");
        r.set_gauge(Domain::Wall, "rate", 2.5);
        r.observe(Domain::Wall, "lat", 3.0);
        let text = r.to_prometheus();
        assert!(text.contains("# HELP dlfusion_cache_hits cost-engine cache hits"));
        assert!(text.contains("# TYPE dlfusion_cache_hits counter"));
        assert!(text.contains("dlfusion_cache_hits{domain=\"sim\"} 5"));
        assert!(text.contains("dlfusion_rate{domain=\"wall\"} 2.5"));
        assert!(text.contains("dlfusion_lat_bucket{domain=\"wall\",le=\"4\"} 1"));
        assert!(text.contains("dlfusion_lat_bucket{domain=\"wall\",le=\"+Inf\"} 1"));
        assert!(text.contains("dlfusion_lat_count{domain=\"wall\"} 1"));
    }

    #[test]
    fn exposition_is_deterministic() {
        let build = || {
            let mut r = MetricsRegistry::new();
            r.set_gauge(Domain::Sim, "z", 1.0);
            r.inc(Domain::Sim, "a", 2);
            r.observe(Domain::Wall, "m", 0.25);
            r
        };
        let (a, b) = (build(), build());
        assert_eq!(a.snapshot().to_string(), b.snapshot().to_string());
        assert_eq!(a.to_prometheus(), b.to_prometheus());
    }

    #[test]
    fn report_table_lists_every_metric() {
        let mut r = MetricsRegistry::new();
        r.inc(Domain::Sim, "evals", 10);
        r.set_gauge(Domain::Wall, "rate", 2.5);
        let t = r.render_table();
        assert_eq!(t.num_rows(), 2);
        let s = t.render();
        assert!(s.contains("evals") && s.contains("sim"));
        assert!(s.contains("rate") && s.contains("wall"));
    }
}
