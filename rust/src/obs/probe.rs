//! Profiling hooks: the [`Probe`] trait (rust/docs/DESIGN.md §14.3).
//!
//! Instrumented code (the bench harness, `perf-smoke`, future fleet and
//! learned-search drivers) emits named counters and samples through a
//! `&mut dyn Probe` instead of hand-rolling yet another stats struct. The
//! two shipped sinks are [`NullProbe`] (the free default) and
//! [`RegistryProbe`] (funnels everything into a [`MetricsRegistry`] under
//! a fixed [`Domain`], which is how `perf-smoke` routes its wall
//! measurements into the unified snapshot).

use super::metrics::{Domain, MetricsRegistry};

/// A sink for instrumentation events. All methods have no-op defaults so
/// a probe implements only what it cares about.
pub trait Probe {
    /// A monotonically accumulated count (events processed, cache hits).
    fn counter(&mut self, _name: &str, _value: u64) {}

    /// A point-in-time measurement (a rate, a mean latency).
    fn sample(&mut self, _name: &str, _value: f64) {}

    /// A completed timed section, duration in microseconds.
    fn span_us(&mut self, _name: &str, _dur_us: f64) {}
}

/// The do-nothing probe: instrumentation compiles to nothing observable.
#[derive(Debug, Clone, Copy, Default)]
pub struct NullProbe;

impl Probe for NullProbe {}

/// Routes probe events into a [`MetricsRegistry`]: counters accumulate,
/// samples set gauges, spans feed a log-bucket histogram (in ms).
pub struct RegistryProbe<'a> {
    registry: &'a mut MetricsRegistry,
    domain: Domain,
}

impl<'a> RegistryProbe<'a> {
    pub fn new(registry: &'a mut MetricsRegistry, domain: Domain) -> Self {
        RegistryProbe { registry, domain }
    }
}

impl Probe for RegistryProbe<'_> {
    fn counter(&mut self, name: &str, value: u64) {
        self.registry.inc(self.domain, name, value);
    }

    fn sample(&mut self, name: &str, value: f64) {
        self.registry.set_gauge(self.domain, name, value);
    }

    fn span_us(&mut self, name: &str, dur_us: f64) {
        self.registry.observe(self.domain, name, dur_us / 1000.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn null_probe_accepts_everything() {
        let mut p = NullProbe;
        p.counter("c", 1);
        p.sample("s", 2.0);
        p.span_us("t", 3.0);
    }

    #[test]
    fn registry_probe_routes_by_event_kind() {
        let mut reg = MetricsRegistry::new();
        {
            let mut p = RegistryProbe::new(&mut reg, Domain::Wall);
            p.counter("events", 5);
            p.counter("events", 2);
            p.sample("rate", 9.5);
            p.span_us("section", 2000.0);
        }
        assert_eq!(reg.counter("events"), Some(7));
        assert_eq!(reg.gauge("rate"), Some(9.5));
        let h = reg.histogram("section").unwrap();
        assert_eq!(h.count(), 1);
        assert_eq!(h.sum(), 2.0);
    }
}
