//! Unified observability: span tracing, a metrics registry, and profiling
//! hooks (rust/docs/DESIGN.md §14).
//!
//! The repo's telemetry used to be scattered — `CostEngine` hit/miss
//! stats, tuner `SearchStats`, serving `Counters`/`LatencyRecorder`,
//! `events_processed` — each with its own struct and its own printing.
//! This module is the one layer they all export through:
//!
//! - [`trace`]: hierarchical [`Span`]s in a [`TraceSession`], exported as
//!   Chrome trace-event JSON (Perfetto-viewable) by `tune --trace-out`
//!   and `serve-sim --trace-out`;
//! - [`metrics`]: the [`MetricsRegistry`] (counters, gauges,
//!   fixed-log-bucket histograms) behind `--metrics-out` and
//!   `dlfusion report`, with JSON and Prometheus-text exposition;
//! - [`probe`]: the [`Probe`] trait benches and `perf-smoke` subscribe
//!   through.
//!
//! One rule binds all three: **every value is tagged with its clock**.
//! Simulated-time quantities ([`Clock::Sim`], [`Domain::Sim`]) are pure
//! functions of the inputs — bit-identical run-to-run and across
//! `--threads N`, pinned by rust/tests/parallel_parity.rs and gated
//! exactly in CI. Wall-clock quantities ([`Clock::Wall`],
//! [`Domain::Wall`]) are machine measurements, exported in a separate
//! section/process so the two can never be confused downstream.

pub mod metrics;
pub mod probe;
pub mod trace;

pub use metrics::{Domain, Histogram, MetricsRegistry};
pub use probe::{NullProbe, Probe, RegistryProbe};
pub use trace::{Clock, Span, TraceSession};
