//! CNML-style C++ code generation (paper Fig. 9 / Fig. 2).
//!
//! The paper's tool-chain emits C++ that drives the vendor's CNML
//! operator SDK: create each operator, fuse operators into `cnmlFusionOp_t`
//! blocks per the optimized schedule, compile each (fusion) operator with
//! its Model_Parallelism setting, and run the inference session. This module
//! reproduces that code generator against a `cnml_compat.h` header we ship
//! (the SDK itself is proprietary — DESIGN.md §2); the emitted program
//! structure is exactly the paper's Fig. 2 calling convention.

pub mod cnml;

pub use cnml::{generate_cpp, generate_header};
