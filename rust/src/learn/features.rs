//! The per-block feature schema of the learned cost model.
//!
//! Every candidate `(block, MP, batch)` point maps to one fixed-width
//! feature vector drawn from the same sources the analytic model consumes:
//! the per-layer [`crate::cost::ModelFacts`] (op counts, channel widths,
//! halos, retile barriers), the Section II.B layer features of
//! [`crate::perfmodel::features`], and the target's
//! [`crate::accel::AcceleratorSpec`]. Three derived columns pre-combine
//! workload and hardware the way Eq. 1 does — computed-GOPs over deployed
//! compute, traffic over bandwidth, per-block launch/sync overhead — so a
//! *linear* fit in log space can capture the dominant latency terms, and so
//! a model fitted on one target carries signal to another (the transfer
//! matrix of [`super::transfer`]). The raw spec columns are constant within
//! a single-target sample set — deliberately collinear with the intercept,
//! which the ridge fallback of [`crate::stats::multi_linear_fit`] absorbs.
//!
//! Everything here is arithmetic over deterministic inputs: the same
//! `(model, spec, block, mp, batch)` always yields the bit-identical vector.

use crate::accel::AcceleratorSpec;
use crate::cost::ModelFacts;
use crate::graph::Model;
use crate::perfmodel::features::layer_features;

/// Width of the feature vector (the learned model's input dimension).
pub const FEATURE_DIM: usize = 16;

/// Names of the feature columns, in order (serialized with the model so a
/// loaded file documents what it was fitted on).
pub const FEATURE_NAMES: [&str; FEATURE_DIM] = [
    "log_gops",
    "log_computed_gops",
    "redundancy",
    "layers",
    "mean_log_channels",
    "halo",
    "barriers",
    "mp",
    "log_batch",
    "mean_conv_op_count",
    "mean_conv_kernel",
    "compute_term",
    "traffic_term",
    "overhead_term",
    "log_peak_gflops",
    "log_mem_bw",
];

/// Featurize one candidate `(block [start, end), mp, batch)` point.
///
/// Panics if the range is empty or out of bounds (callers enumerate blocks
/// from the model, so a bad range is a programming error, not bad input).
pub fn block_features(model: &Model, facts: &ModelFacts, spec: &AcceleratorSpec,
                      start: usize, end: usize, mp: usize, batch: usize)
                      -> Vec<f64> {
    assert!(start < end && end <= facts.len(), "block [{start}, {end}) out of range");
    let layers = (end - start) as f64;
    let gops = facts.block_gops(start, end);
    let computed = facts.block_computed_gops(start, end, mp);
    let mut log_channels = 0.0;
    let mut traffic_bytes = 0.0;
    for i in start..end {
        let lf = facts.layer(i);
        log_channels += (lf.channels.max(1) as f64).log2();
        traffic_bytes += lf.unfused_bytes;
    }
    // Section II.B conv-layer features, averaged over the block's conv
    // layers (zero for conv-free blocks — pooling/elementwise tails).
    let mut conv_op = 0.0;
    let mut conv_kernel = 0.0;
    let mut convs = 0.0;
    for layer in &model.layers[start..end] {
        if let Some(f) = layer_features(layer) {
            conv_op += f[0];
            conv_kernel += f[2];
            convs += 1.0;
        }
    }
    if convs > 0.0 {
        conv_op /= convs;
        conv_kernel /= convs;
    }
    let b = batch as f64;
    let compute_term =
        (1.0 + computed * b / (mp as f64 * spec.peak_gflops_per_core)).log2();
    let traffic_term = (1.0 + traffic_bytes * b / (spec.mem_bw_gbps * 1e9)).log2();
    let overhead_term = (1.0
        + spec.launch_overhead_us
        + spec.sync_us_per_core * mp as f64
        + spec.fused_layer_us * layers)
        .log2();
    vec![
        (1.0 + gops).log2(),
        (1.0 + computed).log2(),
        computed / gops.max(1e-12),
        layers,
        log_channels / layers,
        facts.halo(start, end) as f64,
        facts.barriers(start, end) as f64,
        mp as f64,
        b.log2(),
        conv_op,
        conv_kernel,
        compute_term,
        traffic_term,
        overhead_term,
        (spec.num_cores as f64 * spec.peak_gflops_per_core).log2(),
        spec.mem_bw_gbps.log2(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::{Simulator, Target};
    use crate::zoo;

    #[test]
    fn feature_vector_has_declared_width_and_names() {
        assert_eq!(FEATURE_NAMES.len(), FEATURE_DIM);
        let sim = Simulator::new(Target::mlu100());
        let m = zoo::resnet18();
        let facts = ModelFacts::new(&m);
        let f = block_features(&m, &facts, &sim.spec, 0, 4, 8, 1);
        assert_eq!(f.len(), FEATURE_DIM);
        assert!(f.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn features_are_bit_deterministic() {
        let sim = Simulator::new(Target::mlu100());
        let m = zoo::resnet18();
        let facts = ModelFacts::new(&m);
        let a = block_features(&m, &facts, &sim.spec, 2, 10, 4, 2);
        let b = block_features(&m, &facts, &sim.spec, 2, 10, 4, 2);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn features_separate_mp_and_batch() {
        let sim = Simulator::new(Target::mlu100());
        let m = zoo::resnet18();
        let facts = ModelFacts::new(&m);
        let base = block_features(&m, &facts, &sim.spec, 0, 8, 4, 1);
        let wide = block_features(&m, &facts, &sim.spec, 0, 8, 16, 1);
        assert_ne!(base, wide, "MP must influence the vector");
        let batched = block_features(&m, &facts, &sim.spec, 0, 8, 4, 8);
        assert_ne!(base, batched, "batch must influence the vector");
    }

    #[test]
    fn spec_terms_differ_across_targets() {
        let m = zoo::resnet18();
        let facts = ModelFacts::new(&m);
        let a = Simulator::new(Target::mlu100());
        let b = Simulator::new(Target::edge4());
        let fa = block_features(&m, &facts, &a.spec, 0, 8, 4, 1);
        let fb = block_features(&m, &facts, &b.spec, 0, 8, 4, 1);
        assert_ne!(fa, fb, "spec-derived columns must carry the target");
    }
}
