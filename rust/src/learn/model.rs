//! Fitting, evaluating, and serializing the learned cost model.
//!
//! The model is ordinary least squares (with the ridge fallback of
//! [`crate::stats::multi_linear_fit`]) over the [`super::features`] schema,
//! fitted in *log2-latency* space — the analytic model is multiplicative
//! (compute × redundancy, traffic ÷ bandwidth), so its log is near-linear
//! in the log-scaled features. Optionally the features are PCA-reduced
//! first ([`crate::stats::Pca`], the paper's own Section II.B tool).
//!
//! A fit reports R² in the (log) fit domain and MAPE in the latency domain,
//! on both the train split and a seeded holdout split, plus the
//! **residual band**: the maximum relative prediction error observed over
//! every sample seen at fit time. The band is the uncertainty rule of
//! [`super::ActiveTuner`] — any candidate whose predicted latency lands
//! within `(1 + band)` of the predicted best cannot be ruled out by the
//! model and must be measured for real.
//!
//! Fitted models serialize to a versioned JSON text format
//! ([`LearnedCostModel::save`] / [`LearnedCostModel::load`]). Rust's float
//! formatting is shortest-roundtrip, so a save/load cycle reproduces the
//! coefficients bit for bit.

use crate::cost::CostEngine;
use crate::obs::{Domain, MetricsRegistry};
use crate::stats::{multi_linear_fit, Pca};
use crate::util::{Json, XorShiftRng};

use super::features::{block_features, FEATURE_DIM, FEATURE_NAMES};

/// File format tag and version written into every saved model.
pub const MODEL_FORMAT: &str = "dlfusion-learned-cost-model";
pub const MODEL_VERSION: u64 = 1;

/// One labelled training point: a `(block, mp, batch)` candidate, its
/// feature vector, and the cost engine's latency for it.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    pub start: usize,
    pub end: usize,
    pub mp: usize,
    pub batch: usize,
    pub features: Vec<f64>,
    pub latency_ms: f64,
}

/// Enumerate the candidate blocks of the reduced oracle space — every
/// `[i, j)` the multiple-of-four DP evaluates (size ≡ 0 mod 4, remainder
/// only at the model end, start reachable from 0), in the DP's visit order.
pub(crate) fn reduced_blocks(n: usize) -> Vec<(usize, usize)> {
    crate::search::brute::admissible_blocks(n, crate::search::brute::BlockRule::MultipleOfFour,
                                            None)
}

/// Sample the cost engine over the reduced oracle space at the given MP and
/// batch candidates: one labelled point per `(block, mp, batch)`. The
/// engine's memoization makes repeat collection free; the sample order is
/// the DP's deterministic visit order.
pub fn collect_samples(engine: &CostEngine<'_>, mps: &[usize], batches: &[usize])
                       -> Vec<Sample> {
    let model = engine.model();
    let facts = engine.facts();
    let spec = &engine.sim().spec;
    let n = facts.len();
    let mut out = Vec::new();
    for (start, end) in reduced_blocks(n) {
        for &batch in batches {
            for &mp in mps {
                let features = block_features(model, facts, spec, start, end, mp, batch);
                let latency_ms = engine.block_cost_at(start, end, mp, batch).latency_ms;
                out.push(Sample { start, end, mp, batch, features, latency_ms });
            }
        }
    }
    out
}

/// Knobs of a fit: optional PCA reduction to `pca` components, the holdout
/// fraction, and the seed of the deterministic train/holdout shuffle.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FitConfig {
    /// Project features onto this many principal components before the
    /// linear fit (`None` = fit the raw schema).
    pub pca: Option<usize>,
    /// Fraction of samples withheld from the fit for validation.
    pub holdout: f64,
    /// Seed of the shuffle that assigns samples to splits.
    pub seed: u64,
}

impl Default for FitConfig {
    fn default() -> FitConfig {
        FitConfig { pca: None, holdout: 0.25, seed: 0xd1f0 }
    }
}

/// Quality numbers of one fit. R² lives in the log2-latency fit domain;
/// MAPE is the mean `|pred - actual| / actual` in the latency domain
/// (a fraction — multiply by 100 to quote percent).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct FitReport {
    pub samples: usize,
    pub train: usize,
    pub holdout: usize,
    pub r2_train: f64,
    pub r2_holdout: f64,
    pub mape_train: f64,
    pub mape_holdout: f64,
}

/// A fitted latency predictor over the [`super::features`] schema.
#[derive(Debug, Clone)]
pub struct LearnedCostModel {
    /// Registry name of the target the training samples came from.
    pub target: String,
    /// The feature schema the weights index (pre-PCA column names).
    pub feature_names: Vec<String>,
    /// Optional PCA projection applied before the linear map.
    pub pca: Option<Pca>,
    /// Linear weights over the (possibly projected) features.
    pub weights: Vec<f64>,
    pub bias: f64,
    /// Maximum relative prediction error over every fit-time sample — the
    /// active tuner's uncertainty band.
    pub residual_band: f64,
    pub report: FitReport,
}

impl LearnedCostModel {
    /// Fit on labelled samples from `target`. Needs at least 8 samples
    /// (split-ability plus a minimally overdetermined system — collinear
    /// columns are the ridge fallback's job, sample starvation is the
    /// caller's).
    pub fn fit(target: &str, samples: &[Sample], cfg: &FitConfig)
               -> Result<LearnedCostModel, String> {
        if samples.len() < 8 {
            return Err(format!(
                "need at least 8 samples to fit a learned cost model, got {}",
                samples.len()
            ));
        }
        if let Some(k) = cfg.pca {
            if k == 0 || k > FEATURE_DIM {
                return Err(format!("PCA components must be 1..={FEATURE_DIM}, got {k}"));
            }
        }
        if !(0.0..1.0).contains(&cfg.holdout) {
            return Err(format!("holdout fraction must be in [0, 1), got {}", cfg.holdout));
        }
        let mut idx: Vec<usize> = (0..samples.len()).collect();
        let mut rng = XorShiftRng::new(cfg.seed);
        rng.shuffle(&mut idx);
        let n_hold = ((samples.len() as f64 * cfg.holdout) as usize)
            .min(samples.len().saturating_sub(4));
        let (hold_idx, train_idx) = idx.split_at(n_hold);

        let pca = cfg.pca.map(|k| {
            let rows: Vec<Vec<f64>> =
                train_idx.iter().map(|&i| samples[i].features.clone()).collect();
            let mut p = Pca::fit(&rows);
            p.components.truncate(k);
            p.eigenvalues.truncate(k);
            p
        });
        let project = |f: &[f64]| -> Vec<f64> {
            match &pca {
                Some(p) => p.transform(f),
                None => f.to_vec(),
            }
        };
        let xs: Vec<Vec<f64>> =
            train_idx.iter().map(|&i| project(&samples[i].features)).collect();
        let ys: Vec<f64> =
            train_idx.iter().map(|&i| fit_domain(samples[i].latency_ms)).collect();
        let (weights, bias) = multi_linear_fit(&xs, &ys);

        let mut model = LearnedCostModel {
            target: target.to_string(),
            feature_names: FEATURE_NAMES.iter().map(|s| s.to_string()).collect(),
            pca,
            weights,
            bias,
            residual_band: 0.0,
            report: FitReport::default(),
        };
        let (r2_train, mape_train, band_train) = model.score(samples, train_idx);
        let (r2_holdout, mape_holdout, band_hold) = model.score(samples, hold_idx);
        model.residual_band = band_train.max(band_hold);
        model.report = FitReport {
            samples: samples.len(),
            train: train_idx.len(),
            holdout: hold_idx.len(),
            r2_train,
            r2_holdout,
            mape_train,
            mape_holdout,
        };
        Ok(model)
    }

    /// Predicted latency, ms, for one feature vector.
    pub fn predict_ms(&self, features: &[f64]) -> f64 {
        let x = match &self.pca {
            Some(p) => p.transform(features),
            None => features.to_vec(),
        };
        debug_assert_eq!(x.len(), self.weights.len());
        let z: f64 = self.weights.iter().zip(&x).map(|(w, v)| w * v).sum::<f64>()
            + self.bias;
        from_fit_domain(z)
    }

    /// (R² in the fit domain, MAPE, max relative error) over the indexed
    /// subset; `(1.0, 0.0, 0.0)` for an empty subset.
    fn score(&self, samples: &[Sample], idx: &[usize]) -> (f64, f64, f64) {
        if idx.is_empty() {
            return (1.0, 0.0, 0.0);
        }
        let mut ss_res = 0.0;
        let mut mape = 0.0;
        let mut band = 0.0f64;
        let ys: Vec<f64> = idx.iter().map(|&i| fit_domain(samples[i].latency_ms)).collect();
        let mean = ys.iter().sum::<f64>() / ys.len() as f64;
        let ss_tot: f64 = ys.iter().map(|y| (y - mean).powi(2)).sum();
        for (&i, &y) in idx.iter().zip(&ys) {
            let pred_ms = self.predict_ms(&samples[i].features);
            ss_res += (fit_domain(pred_ms) - y).powi(2);
            let rel = (pred_ms - samples[i].latency_ms).abs()
                / samples[i].latency_ms.max(1e-12);
            mape += rel;
            band = band.max(rel);
        }
        let r2 = if ss_tot == 0.0 { 1.0 } else { 1.0 - ss_res / ss_tot };
        (r2, mape / idx.len() as f64, band)
    }

    /// MAPE (fraction) of this model over an arbitrary sample set — the
    /// transfer matrix's cell metric.
    pub fn mape_on(&self, samples: &[Sample]) -> f64 {
        if samples.is_empty() {
            return 0.0;
        }
        let idx: Vec<usize> = (0..samples.len()).collect();
        self.score(samples, &idx).1
    }

    /// Export fit-quality numbers into the unified registry. Everything a
    /// fit produces is a pure function of `(model, target, config)`, so it
    /// all lands in [`Domain::Sim`].
    pub fn export_metrics(&self, reg: &mut MetricsRegistry) {
        reg.inc(Domain::Sim, "learn.fit.samples", self.report.samples as u64);
        reg.set_gauge(Domain::Sim, "learn.fit.r2_train", self.report.r2_train);
        reg.set_gauge(Domain::Sim, "learn.fit.r2_holdout", self.report.r2_holdout);
        reg.set_gauge(Domain::Sim, "learn.fit.mape_train", self.report.mape_train);
        reg.set_gauge(Domain::Sim, "learn.fit.mape_holdout", self.report.mape_holdout);
        reg.set_gauge(Domain::Sim, "learn.fit.residual_band", self.residual_band);
    }

    /// Serialize to the versioned JSON document (see the module docs).
    pub fn to_json(&self) -> Json {
        let pca = match &self.pca {
            None => Json::Null,
            Some(p) => Json::obj(vec![
                ("eigenvalues", Json::arr_f64(&p.eigenvalues)),
                ("components",
                 Json::Arr(p.components.iter().map(|c| Json::arr_f64(c)).collect())),
                ("means", Json::arr_f64(&p.means)),
                ("stds", Json::arr_f64(&p.stds)),
            ]),
        };
        Json::obj(vec![
            ("format", Json::Str(MODEL_FORMAT.to_string())),
            ("version", Json::Num(MODEL_VERSION as f64)),
            ("target", Json::Str(self.target.clone())),
            ("feature_names",
             Json::Arr(self.feature_names.iter().map(|n| Json::Str(n.clone())).collect())),
            ("pca", pca),
            ("weights", Json::arr_f64(&self.weights)),
            ("bias", Json::Num(self.bias)),
            ("residual_band", Json::Num(self.residual_band)),
            ("report", Json::obj(vec![
                ("samples", Json::Num(self.report.samples as f64)),
                ("train", Json::Num(self.report.train as f64)),
                ("holdout", Json::Num(self.report.holdout as f64)),
                ("r2_train", Json::Num(self.report.r2_train)),
                ("r2_holdout", Json::Num(self.report.r2_holdout)),
                ("mape_train", Json::Num(self.report.mape_train)),
                ("mape_holdout", Json::Num(self.report.mape_holdout)),
            ])),
        ])
    }

    /// Parse the versioned JSON document; clean errors for a wrong format
    /// tag, an unsupported version, or missing/ill-typed fields.
    pub fn from_json(doc: &Json) -> Result<LearnedCostModel, String> {
        if doc.get("format").as_str() != Some(MODEL_FORMAT) {
            return Err(format!("not a {MODEL_FORMAT} file (missing format tag)"));
        }
        let version = doc.get("version").as_usize().unwrap_or(0) as u64;
        if version != MODEL_VERSION {
            return Err(format!(
                "unsupported model file version {version} (this build reads {MODEL_VERSION})"
            ));
        }
        let target = doc
            .get("target")
            .as_str()
            .ok_or("model file missing 'target'")?
            .to_string();
        let feature_names: Vec<String> = doc
            .get("feature_names")
            .as_arr()
            .ok_or("model file missing 'feature_names'")?
            .iter()
            .filter_map(|v| v.as_str().map(|s| s.to_string()))
            .collect();
        let weights = f64_vec(doc.get("weights")).ok_or("model file missing 'weights'")?;
        let bias = doc.get("bias").as_f64().ok_or("model file missing 'bias'")?;
        let residual_band = doc
            .get("residual_band")
            .as_f64()
            .ok_or("model file missing 'residual_band'")?;
        let pca = match doc.get("pca") {
            Json::Null => None,
            p => {
                let components = p
                    .get("components")
                    .as_arr()
                    .ok_or("model file pca missing 'components'")?
                    .iter()
                    .map(|row| f64_vec(row).ok_or("pca component row is not numeric"))
                    .collect::<Result<Vec<_>, _>>()?;
                Some(Pca {
                    eigenvalues: f64_vec(p.get("eigenvalues"))
                        .ok_or("model file pca missing 'eigenvalues'")?,
                    components,
                    means: f64_vec(p.get("means"))
                        .ok_or("model file pca missing 'means'")?,
                    stds: f64_vec(p.get("stds"))
                        .ok_or("model file pca missing 'stds'")?,
                })
            }
        };
        let r = doc.get("report");
        let report = FitReport {
            samples: r.get("samples").as_usize().unwrap_or(0),
            train: r.get("train").as_usize().unwrap_or(0),
            holdout: r.get("holdout").as_usize().unwrap_or(0),
            r2_train: r.get("r2_train").as_f64().unwrap_or(0.0),
            r2_holdout: r.get("r2_holdout").as_f64().unwrap_or(0.0),
            mape_train: r.get("mape_train").as_f64().unwrap_or(0.0),
            mape_holdout: r.get("mape_holdout").as_f64().unwrap_or(0.0),
        };
        Ok(LearnedCostModel {
            target,
            feature_names,
            pca,
            weights,
            bias,
            residual_band,
            report,
        })
    }

    /// Write the model to `path` as pretty-printed JSON.
    pub fn save(&self, path: &str) -> Result<(), String> {
        std::fs::write(path, self.to_json().to_pretty() + "\n")
            .map_err(|e| format!("cannot write model file '{path}': {e}"))
    }

    /// Read a model back from `path`; missing files and malformed or
    /// wrong-version documents are clean errors, never panics.
    pub fn load(path: &str) -> Result<LearnedCostModel, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read model file '{path}': {e}"))?;
        let doc = Json::parse(&text)
            .map_err(|e| format!("model file '{path}' is not valid JSON: {e}"))?;
        LearnedCostModel::from_json(&doc)
            .map_err(|e| format!("model file '{path}': {e}"))
    }

    /// Human-readable fit summary (the `learn fit` report body).
    pub fn render(&self) -> String {
        let r = &self.report;
        format!(
            "learned cost model for {}\n\
             samples: {} ({} train / {} holdout)\n\
             pca: {}\n\
             r2 (log domain): train {:.4}, holdout {:.4}\n\
             mape: train {:.2}%, holdout {:.2}%\n\
             residual band: {:.2}%\n",
            self.target,
            r.samples,
            r.train,
            r.holdout,
            match &self.pca {
                Some(p) => format!("{} components", p.components.len()),
                None => "off".to_string(),
            },
            r.r2_train,
            r.r2_holdout,
            r.mape_train * 100.0,
            r.mape_holdout * 100.0,
            self.residual_band * 100.0,
        )
    }
}

/// The fit domain: log2 latency (the analytic cost is multiplicative).
fn fit_domain(latency_ms: f64) -> f64 {
    latency_ms.max(1e-12).log2()
}

fn from_fit_domain(z: f64) -> f64 {
    z.exp2()
}

fn f64_vec(v: &Json) -> Option<Vec<f64>> {
    v.as_arr().map(|a| a.iter().filter_map(|x| x.as_f64()).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::{Simulator, Target};
    use crate::zoo;

    fn resnet_samples() -> Vec<Sample> {
        let sim = Simulator::new(Target::mlu100());
        let m = zoo::resnet18();
        let engine = CostEngine::new(&sim, &m);
        collect_samples(&engine, &sim.spec.reduced_mp_set(), &[1])
    }

    #[test]
    fn reduced_blocks_match_the_dp_space() {
        // alexnet-sized n: every (i, j) with i % 4 == 0 and the size rule.
        let blocks = reduced_blocks(10);
        assert!(blocks.contains(&(0, 4)));
        assert!(blocks.contains(&(0, 10)), "remainder block at the end");
        assert!(blocks.contains(&(8, 10)), "tail remainder from a reachable start");
        assert!(!blocks.contains(&(1, 5)), "start 1 is unreachable");
        assert!(!blocks.contains(&(0, 6)), "len 6 is not a multiple of four mid-model");
    }

    #[test]
    fn fit_learns_the_simulator() {
        let samples = resnet_samples();
        let model =
            LearnedCostModel::fit("mlu100", &samples, &FitConfig::default()).unwrap();
        let r = &model.report;
        assert!(r.samples > 100, "resnet18 reduced space has {} samples", r.samples);
        assert!(r.r2_train > 0.8, "train r2 {}", r.r2_train);
        assert!(r.r2_holdout > 0.7, "holdout r2 {}", r.r2_holdout);
        assert!(r.mape_holdout < 0.5, "holdout mape {}", r.mape_holdout);
        assert!(model.residual_band > 0.0);
    }

    #[test]
    fn pca_reduced_fit_works() {
        let samples = resnet_samples();
        let cfg = FitConfig { pca: Some(6), ..FitConfig::default() };
        let model = LearnedCostModel::fit("mlu100", &samples, &cfg).unwrap();
        assert_eq!(model.weights.len(), 6);
        assert!(model.report.r2_train > 0.5, "r2 {}", model.report.r2_train);
    }

    #[test]
    fn fit_is_bit_deterministic() {
        let samples = resnet_samples();
        let cfg = FitConfig::default();
        let a = LearnedCostModel::fit("mlu100", &samples, &cfg).unwrap();
        let b = LearnedCostModel::fit("mlu100", &samples, &cfg).unwrap();
        assert_eq!(a.bias.to_bits(), b.bias.to_bits());
        assert_eq!(a.residual_band.to_bits(), b.residual_band.to_bits());
        for (x, y) in a.weights.iter().zip(&b.weights) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn save_load_roundtrip_is_exact() {
        let samples = resnet_samples();
        let model =
            LearnedCostModel::fit("mlu100", &samples, &FitConfig::default()).unwrap();
        let dir = std::env::temp_dir().join("dlfusion_learn_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.json");
        let path = path.to_str().unwrap();
        model.save(path).unwrap();
        let back = LearnedCostModel::load(path).unwrap();
        assert_eq!(back.target, model.target);
        assert_eq!(back.weights.len(), model.weights.len());
        assert_eq!(back.bias.to_bits(), model.bias.to_bits());
        for (a, b) in model.weights.iter().zip(&back.weights) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Predictions agree bit for bit.
        let f = &samples[17].features;
        assert_eq!(model.predict_ms(f).to_bits(), back.predict_ms(f).to_bits());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn load_errors_are_clean() {
        assert!(LearnedCostModel::load("/nonexistent/model.json")
            .unwrap_err()
            .contains("cannot read"));
        let dir = std::env::temp_dir().join("dlfusion_learn_test");
        std::fs::create_dir_all(&dir).unwrap();
        let bad = dir.join("bad.json");
        std::fs::write(&bad, "{ not json").unwrap();
        assert!(LearnedCostModel::load(bad.to_str().unwrap())
            .unwrap_err()
            .contains("not valid JSON"));
        let wrong = dir.join("wrong.json");
        std::fs::write(&wrong, "{\"format\": \"other\"}").unwrap();
        assert!(LearnedCostModel::load(wrong.to_str().unwrap())
            .unwrap_err()
            .contains("format"));
        std::fs::remove_file(bad).ok();
        std::fs::remove_file(wrong).ok();
    }

    #[test]
    fn too_few_samples_is_an_error() {
        let samples = resnet_samples();
        let err =
            LearnedCostModel::fit("mlu100", &samples[..5], &FitConfig::default())
                .unwrap_err();
        assert!(err.contains("at least 8"));
    }
}
