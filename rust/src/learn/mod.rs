//! The learned cost model and active-learning tuner (ROADMAP item 4a,
//! rust/docs/DESIGN.md §16).
//!
//! The paper's Algorithm 1 is a hand-derived heuristic over two layer
//! features; this subsystem replaces hand-derivation with *fitting*: the
//! analytic cost engine is treated as an expensive oracle, a linear model
//! in log space is fitted over a deterministic per-block feature schema
//! ([`features`]), and search queries the real engine only where the model
//! is uncertain ([`ActiveTuner`], registered as `--tuner learned`).
//! [`transfer`] measures how a model fitted on one registry target predicts
//! the others — the cross-hardware generalization question every learned
//! cost model must answer.
//!
//! Everything is deterministic: fixed-seed splits, sequential walks, and
//! pure-arithmetic features, so fits, transfer matrices, and tuner
//! schedules are bit-identical across runs and `--threads` settings.
//!
//! ```no_run
//! use dlfusion::prelude::*;
//! use dlfusion::learn::{collect_samples, FitConfig, LearnedCostModel};
//!
//! let sim = Simulator::new(Target::mlu100());
//! let model = zoo::resnet18();
//! let engine = CostEngine::new(&sim, &model);
//! let samples = collect_samples(&engine, &sim.spec.reduced_mp_set(), &[1]);
//! let fitted = LearnedCostModel::fit("mlu100", &samples,
//!                                    &FitConfig::default()).expect("fit");
//! println!("{}", fitted.render());
//! ```

pub mod active;
pub mod features;
pub mod model;
pub mod transfer;

pub use active::ActiveTuner;
pub use features::{block_features, FEATURE_DIM, FEATURE_NAMES};
pub use model::{collect_samples, FitConfig, FitReport, LearnedCostModel, Sample};
pub use transfer::TransferMatrix;
