//! The active-learning tuner: a model-guided walk of the oracle DP's space.
//!
//! The reduced oracle DP sweeps every admissible block at every MP
//! candidate — `|blocks| × |MP|` real engine evaluations. [`ActiveTuner`]
//! spends a fraction of that and lands on (near-)the same schedule:
//!
//! 1. **Seed round.** Every `seed_stride`-th admissible block (the same
//!    enumeration the DP visits — [`crate::search::brute`]) is swept at
//!    every MP for real, producing labelled samples.
//! 2. **Fit.** A [`LearnedCostModel`] is fitted on the seed samples
//!    (fixed seed, deterministic split — rust/docs/DESIGN.md §16).
//! 3. **Probe round.** For every remaining block the model *predicts* the
//!    per-MP latencies; only the predicted-best MP and every MP inside the
//!    **residual band** — the uncertainty rule: predicted within
//!    `(1 + band)×` of the predicted best, where `band` is the fit's
//!    maximum relative error, clamped to `[0.25, 2.0]` — are measured for
//!    real. MPs the model confidently rules out are never evaluated.
//! 4. **DP + refine.** The usual shortest-path DP runs over the measured
//!    per-block minima; the winning partition's blocks then get a full
//!    real MP sweep (cheap: a handful of blocks) so the final schedule's
//!    MPs are exactly optimal for its cuts.
//!
//! Every number the tuner consumes is a deterministic engine value and the
//! walk is sequential, so the outcome is bit-identical across runs and
//! thread counts (`--threads` changes nothing here by construction). The
//! pruning is reported as [`TuningStats::evals_saved`] = full sweep size
//! minus real queries issued. Budget semantics: `max_evaluations` is
//! checked before every real sweep like the DP's — exceeding it aborts
//! with [`TuningError::BudgetExhausted`] (a partial walk has no usable
//! result).

use std::time::Instant;

use crate::optimizer::schedule::{Block, Schedule};
use crate::search::brute::admissible_blocks;
use crate::tuner::{Tuner, TuningContext, TuningError, TuningOutcome, TuningStats};

use super::features::block_features;
use super::model::{FitConfig, LearnedCostModel, Sample};

/// Uncertainty-band clamp: never trust the model past ruling out 2×
/// mispredictions, never probe less than a 25% near-tie margin.
const BAND_MIN: f64 = 0.25;
const BAND_MAX: f64 = 2.0;

/// The model-guided active-learning backend (`--tuner learned`).
#[derive(Debug, Clone)]
pub struct ActiveTuner {
    /// Every `seed_stride`-th candidate block is fully swept to train the
    /// surrogate; the rest are probed selectively.
    pub seed_stride: usize,
    /// Fit configuration of the per-run surrogate (fixed seed — the run
    /// must be reproducible).
    pub fit: FitConfig,
}

impl Default for ActiveTuner {
    fn default() -> ActiveTuner {
        ActiveTuner::new()
    }
}

impl ActiveTuner {
    pub fn new() -> ActiveTuner {
        ActiveTuner { seed_stride: 3, fit: FitConfig::default() }
    }

    fn tune_at_batch(&mut self, cx: &mut TuningContext<'_>)
                     -> Result<TuningOutcome, TuningError> {
        let t0 = Instant::now();
        let before = cx.engine().local_stats();
        let batch = cx.engine().batch();
        let mps = cx.checked_mps()?;
        let mask = cx.checked_cut_mask()?;
        let n = cx.model().num_layers();
        let edges = admissible_blocks(n, cx.granularity(), mask.as_deref());
        let full_space = (edges.len() * mps.len()) as u64;
        let cap = cx.budget().max_evaluations;
        let mut real_queries: u64 = 0;
        let stride = self.seed_stride.max(2);

        // Per-edge measured minimum (cost, mp); None until measured.
        let mut measured: Vec<Option<(f64, usize)>> = vec![None; edges.len()];
        let mut samples: Vec<Sample> = Vec::new();

        // A real sweep of one edge over an MP subset, with the DP's budget
        // rule (checked before the sweep, whole sweep counted).
        let mut sweep = |cx: &mut TuningContext<'_>, i: usize, j: usize,
                         probe: &[usize], real_queries: &mut u64,
                         samples: Option<&mut Vec<Sample>>|
         -> Result<(f64, usize), TuningError> {
            if let Some(cap) = cap {
                if *real_queries + probe.len() as u64 > cap {
                    return Err(TuningError::BudgetExhausted {
                        spent: *real_queries,
                        budget: cap,
                    });
                }
            }
            *real_queries += probe.len() as u64;
            let mut best = f64::INFINITY;
            let mut best_mp = probe[0];
            let mut local = Vec::new();
            for &mp in probe {
                let latency = cx.engine().block_latency(i, j, mp);
                if latency < best {
                    best = latency;
                    best_mp = mp;
                }
                local.push((mp, latency));
            }
            if let Some(out) = samples {
                let model = cx.engine().model();
                let facts = cx.engine().facts();
                let spec = &cx.engine().sim().spec;
                for (mp, latency_ms) in local {
                    out.push(Sample {
                        start: i,
                        end: j,
                        mp,
                        batch,
                        features: block_features(model, facts, spec, i, j, mp, batch),
                        latency_ms,
                    });
                }
            }
            Ok((best, best_mp))
        };

        // Seed round: full sweeps on every stride-th edge.
        for (k, &(i, j)) in edges.iter().enumerate() {
            if k % stride == 0 {
                measured[k] =
                    Some(sweep(cx, i, j, &mps, &mut real_queries, Some(&mut samples))?);
            }
        }

        // Fit the surrogate. Too-small sample sets (tiny models) fall back
        // to full sweeps — the DP itself, with zero savings.
        let surrogate = LearnedCostModel::fit(cx.target(), &samples, &self.fit).ok();
        let band = surrogate
            .as_ref()
            .map(|m| m.residual_band.clamp(BAND_MIN, BAND_MAX))
            .unwrap_or(f64::INFINITY);

        // Probe round: real evaluations only where the model is uncertain.
        for (k, &(i, j)) in edges.iter().enumerate() {
            if measured[k].is_some() {
                continue;
            }
            let probe: Vec<usize> = match &surrogate {
                None => mps.clone(),
                Some(model) => {
                    let facts = cx.engine().facts();
                    let spec = &cx.engine().sim().spec;
                    let preds: Vec<f64> = mps
                        .iter()
                        .map(|&mp| {
                            model.predict_ms(&block_features(
                                cx.engine().model(), facts, spec, i, j, mp, batch))
                        })
                        .collect();
                    let best_pred =
                        preds.iter().cloned().fold(f64::INFINITY, f64::min);
                    mps.iter()
                        .zip(&preds)
                        .filter(|(_, &p)| p <= best_pred * (1.0 + band))
                        .map(|(&mp, _)| mp)
                        .collect()
                }
            };
            measured[k] = Some(sweep(cx, i, j, &probe, &mut real_queries, None)?);
        }

        // Shortest-path DP over the measured per-edge minima.
        let mut dp = vec![f64::INFINITY; n + 1];
        let mut parent: Vec<Option<(usize, usize)>> = vec![None; n + 1];
        dp[0] = 0.0;
        for (k, &(i, j)) in edges.iter().enumerate() {
            if dp[i].is_infinite() {
                continue;
            }
            let (cost, mp) = measured[k].expect("every admissible edge was measured");
            if dp[i] + cost < dp[j] {
                dp[j] = dp[i] + cost;
                parent[j] = Some((i, mp));
            }
        }
        let mut blocks = Vec::new();
        let mut j = n;
        while j > 0 {
            let (i, mp) = parent[j].ok_or_else(|| {
                TuningError::InvalidRequest(format!(
                    "no admissible partition reaches layer {j} under the cut \
                     constraint"
                ))
            })?;
            blocks.push(Block { start: i, end: j, mp });
            j = i;
        }
        blocks.reverse();

        // Refine: the chosen partition's blocks get an exact MP decision
        // (full sweep; the probed MPs are already cached, so this costs
        // only the candidates pruning skipped on these few blocks).
        for b in blocks.iter_mut() {
            let (_, mp) = sweep(cx, b.start, b.end, &mps, &mut real_queries, None)?;
            b.mp = mp;
        }
        let schedule = Schedule::new(blocks);
        debug_assert!(schedule.validate(n, cx.sim().spec.num_cores).is_ok());
        let search_us = t0.elapsed().as_micros() as u64;
        let predicted_ms = cx.engine().schedule_cost(&schedule);

        let after = cx.engine().local_stats();
        let hits = after.hits - before.hits;
        let misses = after.misses - before.misses;
        let stats = TuningStats {
            evaluations: hits + misses,
            blocks_considered: edges.len() as u64,
            space_visited: 0,
            cache_hits: hits,
            cache_misses: misses,
            wall_us: t0.elapsed().as_micros() as u64,
            search_us,
            prewarm_us: 0,
            evals_saved: full_space.saturating_sub(real_queries),
            truncated: false,
        };
        Ok(TuningOutcome { tuner: self.name(), schedule, batch, predicted_ms, stats })
    }
}

impl Tuner for ActiveTuner {
    fn name(&self) -> String {
        "learned".into()
    }

    fn tune(&mut self, cx: &mut TuningContext<'_>) -> Result<TuningOutcome, TuningError> {
        crate::tuner::tune_over_batches(cx, |cx| self.tune_at_batch(cx))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::{Simulator, Target};
    use crate::tuner::{OracleDp, TuningRequest};
    use crate::zoo;

    #[test]
    fn active_tuner_produces_a_valid_schedule() {
        let sim = Simulator::new(Target::mlu100());
        let m = zoo::resnet18();
        let req = TuningRequest::new(&sim, &m);
        let out = req.run(&mut ActiveTuner::new()).unwrap();
        out.schedule.validate(m.num_layers(), sim.spec.num_cores).unwrap();
        assert!(out.predicted_ms > 0.0);
        assert!(out.stats.evals_saved > 0, "pruning must save something");
    }

    #[test]
    fn active_tuner_saves_evals_vs_the_dp_reference() {
        let sim = Simulator::new(Target::mlu100());
        let m = zoo::resnet18();
        let req = TuningRequest::new(&sim, &m);
        let active = req.run(&mut ActiveTuner::new()).unwrap();
        let oracle = req.run(&mut OracleDp::reduced()).unwrap();
        // Cache misses = distinct real engine computations (each fresh
        // context starts cold, so every unique query is one miss).
        assert!(active.stats.cache_misses < oracle.stats.cache_misses,
                "active {} vs oracle {}", active.stats.cache_misses,
                oracle.stats.cache_misses);
        assert!(active.predicted_ms <= oracle.predicted_ms * 1.05,
                "active {} vs oracle {}", active.predicted_ms, oracle.predicted_ms);
    }

    #[test]
    fn budget_aborts_cleanly() {
        let sim = Simulator::new(Target::mlu100());
        let m = zoo::resnet18();
        let req = TuningRequest::new(&sim, &m).max_evaluations(4);
        let err = req.run(&mut ActiveTuner::new()).unwrap_err();
        assert!(matches!(err, TuningError::BudgetExhausted { .. }));
    }

    #[test]
    fn masked_run_respects_the_cuts() {
        let sim = Simulator::new(Target::mlu100());
        let m = zoo::resnet18();
        let n = m.num_layers();
        let cuts: Vec<usize> = (0..=n).step_by(4).collect();
        let req = TuningRequest::new(&sim, &m).allowed_cuts(cuts.clone());
        let out = req.run(&mut ActiveTuner::new()).unwrap();
        for b in &out.schedule.blocks {
            assert!(cuts.contains(&b.start) || b.start == 0);
            assert!(cuts.contains(&b.end) || b.end == n);
        }
    }
}
