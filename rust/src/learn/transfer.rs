//! Cross-target transfer of the learned cost model.
//!
//! ROADMAP item 4(a)'s measurement: fit on cost-engine samples from *one*
//! registry target, then score the prediction error on every other. Each
//! matrix cell `(train, eval)` is the MAPE of the `train`-fitted model over
//! the `eval` target's sample set — features are always computed against
//! the *evaluated* target's spec, so the spec-derived columns (compute
//! ratio, traffic ratio, overheads) are what carries, or fails to carry,
//! the signal across hardware. The diagonal is in-target holdout-style
//! error; off-diagonal growth is the transfer penalty.
//!
//! Every cell is a pure function of `(model, targets, config)` — the matrix
//! is bit-identical across runs and thread counts.

use crate::accel::{Simulator, Target};
use crate::cost::CostEngine;
use crate::graph::Model;
use crate::obs::{Domain, MetricsRegistry};
use crate::util::Table;

use super::model::{collect_samples, FitConfig, LearnedCostModel, Sample};

/// The train-target × eval-target MAPE matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct TransferMatrix {
    /// Registry names, one per row (train) and column (eval).
    pub targets: Vec<String>,
    /// `mape[r][c]` = MAPE (fraction) of the model fitted on `targets[r]`,
    /// evaluated on `targets[c]`'s samples.
    pub mape: Vec<Vec<f64>>,
}

impl TransferMatrix {
    /// Fit-and-evaluate over every registry target for one workload.
    pub fn build(model: &Model, cfg: &FitConfig) -> Result<TransferMatrix, String> {
        let targets = Target::all();
        let sims: Vec<Simulator> = targets.into_iter().map(Simulator::new).collect();
        let mut names = Vec::new();
        let mut sample_sets: Vec<Vec<Sample>> = Vec::new();
        for sim in &sims {
            names.push(sim.target().to_string());
            let engine = CostEngine::new(sim, model);
            sample_sets.push(collect_samples(&engine, &sim.spec.reduced_mp_set(), &[1]));
        }
        let mut mape = Vec::new();
        for (row, name) in names.iter().enumerate() {
            let fitted = LearnedCostModel::fit(name, &sample_sets[row], cfg)?;
            mape.push(sample_sets.iter().map(|s| fitted.mape_on(s)).collect());
        }
        Ok(TransferMatrix { targets: names, mape })
    }

    /// MAPE of the model trained on `train` evaluated on `eval`, if both
    /// are in the matrix.
    pub fn cell(&self, train: &str, eval: &str) -> Option<f64> {
        let r = self.targets.iter().position(|t| t == train)?;
        let c = self.targets.iter().position(|t| t == eval)?;
        Some(self.mape[r][c])
    }

    /// Render the matrix as a table (rows = train target, columns = eval
    /// target, cells = MAPE %).
    pub fn render(&self) -> String {
        let mut header: Vec<&str> = vec!["train \\ eval"];
        for t in &self.targets {
            header.push(t);
        }
        let mut table = Table::new(&header).with_title("transfer matrix (MAPE %)")
            .label_first();
        for (t, row) in self.targets.iter().zip(&self.mape) {
            let mut cells = vec![t.clone()];
            for v in row {
                cells.push(format!("{:.2}", v * 100.0));
            }
            table.row(cells);
        }
        table.render()
    }

    /// Export every cell as a sim-domain gauge
    /// (`learn.transfer.<train>.<eval>.mape`).
    pub fn export_metrics(&self, reg: &mut MetricsRegistry) {
        for (r, train) in self.targets.iter().enumerate() {
            for (c, eval) in self.targets.iter().enumerate() {
                reg.set_gauge(Domain::Sim,
                              &format!("learn.transfer.{train}.{eval}.mape"),
                              self.mape[r][c]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo;

    #[test]
    fn matrix_covers_the_registry() {
        let m = zoo::alexnet();
        let t = TransferMatrix::build(&m, &FitConfig::default()).unwrap();
        assert_eq!(t.targets, vec!["mlu100", "mlu270", "edge4", "hbm32"]);
        assert_eq!(t.mape.len(), 4);
        assert!(t.mape.iter().all(|r| r.len() == 4));
        assert!(t.mape.iter().flatten().all(|v| v.is_finite() && *v >= 0.0));
        let rendered = t.render();
        assert!(rendered.contains("mlu270"));
    }

    #[test]
    fn matrix_is_bit_deterministic() {
        let m = zoo::alexnet();
        let cfg = FitConfig::default();
        let a = TransferMatrix::build(&m, &cfg).unwrap();
        let b = TransferMatrix::build(&m, &cfg).unwrap();
        for (ra, rb) in a.mape.iter().zip(&b.mape) {
            for (x, y) in ra.iter().zip(rb) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }
}
