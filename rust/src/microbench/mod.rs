//! Synthesized microbenchmarks (the paper's Section II methodology).
//!
//! "With those auto-generated microbenchmarks covering different
//! computational intensity and operation count, we can quickly have a
//! high-level understanding of the target hardware's computational
//! characteristics." These generators produce the layer populations behind
//! Figs. 3, 4 and 6; the benches sweep them through the simulator.

use crate::graph::layer::{ConvSpec, FcSpec, Layer, LayerKind};
use crate::util::XorShiftRng;

/// A broad conv sweep over channels × spatial size × kernel — the Fig. 3 /
/// Fig. 4(a) population (360 layers, op counts from ~1e-3 to ~60 GOPs).
pub fn conv_sweep() -> Vec<Layer> {
    let mut out = Vec::new();
    for &c in &[16usize, 32, 64, 128, 256, 512] {
        for &hw in &[7usize, 14, 28, 56, 112, 224] {
            for &k in &[1usize, 3, 5] {
                // Skip degenerate huge cases (512ch @ 224 @ 5x5 = 1.2 TOPs).
                if c * hw > 512 * 112 {
                    continue;
                }
                out.push(Layer::conv(
                    format!("mb_c{c}_s{hw}_k{k}"),
                    ConvSpec::same(c, c, hw, k),
                ));
            }
        }
    }
    out
}

/// FC sweep (the other Eq. 2 population of Section II.B).
pub fn fc_sweep() -> Vec<Layer> {
    let mut out = Vec::new();
    for &k in &[256usize, 1024, 4096, 9216] {
        for &n in &[256usize, 1000, 4096] {
            out.push(Layer::new(
                format!("mb_fc_{k}x{n}"),
                LayerKind::Fc(FcSpec { k, n }),
            ));
        }
    }
    out
}

/// Layers with (approximately) equal op count but different channel widths —
/// the Fig. 6(a) experiment. Returns `(channels, layer)` pairs including the
/// paper's `{128, 128, 56x56, 3x3}` member.
pub fn equal_ops_channel_series() -> Vec<(usize, Layer)> {
    // G = 2*h^2*9*c^2 is constant when h = 7168/c (0.925 GOPs); the series
    // spans a 32x channel range around the paper's {128,128,56x56,3x3}
    // member so the channel-partition cap actually bites at the narrow end.
    let mut out = Vec::new();
    for &c in &[8usize, 32, 64, 128, 256] {
        let h = (7168 / c).max(1);
        out.push((
            c,
            Layer::conv(format!("eq_c{c}_s{h}"), ConvSpec::same(c, c, h, 3)),
        ));
    }
    out
}

/// Fixed-channel, varying-op-count series — the Fig. 6(b) experiment.
pub fn fixed_channel_op_series(channels: usize) -> Vec<Layer> {
    [14usize, 28, 56, 112, 224]
        .iter()
        .map(|&hw| {
            Layer::conv(
                format!("fx_c{channels}_s{hw}"),
                ConvSpec::same(channels, channels, hw, 3),
            )
        })
        .collect()
}

/// The Section II.B.2 series: the VGG-19 base conv `{64,64,224x224,3x3}`
/// with its channel dimension expanded by the given factors (Fig. 4(c)).
pub fn channel_scaled_series(factors: &[usize]) -> Vec<Layer> {
    factors
        .iter()
        .map(|&f| crate::zoo::synthetic::scaled_conv_layer(f))
        .collect()
}

/// Randomized conv population for property tests and PCA robustness.
pub fn random_convs(rng: &mut XorShiftRng, n: usize) -> Vec<Layer> {
    (0..n)
        .map(|i| {
            let c_pow = rng.gen_usize(4, 9); // 16..512
            let c = 1usize << c_pow;
            let hw = *rng.choose(&[7usize, 14, 28, 56, 112]);
            let k = *rng.choose(&[1usize, 3, 5]);
            Layer::conv(format!("rnd{i}"), ConvSpec::same(c, c, hw, k))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_sweep_covers_decades() {
        let layers = conv_sweep();
        assert!(layers.len() > 50);
        let min = layers.iter().map(|l| l.op_gops()).fold(f64::MAX, f64::min);
        let max = layers.iter().map(|l| l.op_gops()).fold(0.0, f64::max);
        assert!(min < 0.01, "min {min}");
        assert!(max > 10.0, "max {max}");
    }

    #[test]
    fn equal_ops_series_is_equal_ops() {
        let series = equal_ops_channel_series();
        let gops: Vec<f64> = series.iter().map(|(_, l)| l.op_gops()).collect();
        let base = gops[0];
        for g in &gops {
            assert!((g / base - 1.0).abs() < 0.15, "{gops:?}");
        }
        // ... but spans a 32x channel range.
        assert_eq!(series.first().unwrap().0, 8);
        assert_eq!(series.last().unwrap().0, 256);
    }

    #[test]
    fn fixed_channel_series_spans_ops() {
        let s = fixed_channel_op_series(128);
        let g0 = s.first().unwrap().op_gops();
        let g1 = s.last().unwrap().op_gops();
        assert!(g1 / g0 > 100.0);
        assert!(s.iter().all(|l| l.channels() == 128));
    }

    #[test]
    fn channel_scaled_series_matches_fig4c() {
        let s = channel_scaled_series(&[1, 2, 4]);
        assert!((s[0].op_gops() - 3.7).abs() < 0.05);
        assert!((s[2].op_gops() / s[0].op_gops() - 16.0).abs() < 1e-9);
    }

    #[test]
    fn random_convs_deterministic() {
        let mut r1 = XorShiftRng::new(9);
        let mut r2 = XorShiftRng::new(9);
        assert_eq!(random_convs(&mut r1, 10), random_convs(&mut r2, 10));
    }
}
