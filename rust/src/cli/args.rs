//! Minimal subcommand + flag parser.
//!
//! Grammar: `dlfusion <command> [positionals...] [--flag[=value]|--flag value]`.
//!
//! A flag with no following value parses as the boolean `"true"` and is
//! *remembered as bare*: commands that need a value read it through
//! [`Args::flag_value`] / [`Args::flag_usize`] / [`Args::flag_f64`], which
//! turn a trailing `--target` into a "--target expects a value" usage error
//! instead of silently treating `"true"` as the value.

use std::collections::{BTreeMap, BTreeSet};

/// Parsed command line.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Args {
    pub command: String,
    pub positionals: Vec<String>,
    flags: BTreeMap<String, String>,
    /// Flags given with no value (`--name` at the end of the line or before
    /// another flag) — booleans until a command asks for a value.
    bare: BTreeSet<String>,
}

/// Parse failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError(pub String);

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for ParseError {}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, ParseError> {
        let mut it = argv.into_iter().peekable();
        let command = it
            .next()
            .ok_or_else(|| ParseError("missing command (try 'help')".into()))?;
        let mut args = Args { command, ..Default::default() };
        while let Some(a) = it.next() {
            if let Some(flag) = a.strip_prefix("--") {
                if flag.is_empty() {
                    return Err(ParseError("bare '--' not supported".into()));
                }
                if let Some((k, v)) = flag.split_once('=') {
                    if k.is_empty() {
                        return Err(ParseError("empty flag name in '--='".into()));
                    }
                    args.bare.remove(k);
                    args.flags.insert(k.to_string(), v.to_string());
                } else if matches!(it.peek(), Some(n) if !n.starts_with("--")) {
                    if let Some(v) = it.next() {
                        args.bare.remove(flag);
                        args.flags.insert(flag.to_string(), v);
                    }
                } else {
                    // Trailing flag, or a flag directly followed by another
                    // flag: boolean for now, but remembered as bare so
                    // value-flag accessors can reject it cleanly.
                    args.bare.insert(flag.to_string());
                    args.flags.insert(flag.to_string(), "true".to_string());
                }
            } else {
                args.positionals.push(a);
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_bool(&self, name: &str) -> bool {
        matches!(self.flag(name), Some("true") | Some("1") | Some("yes"))
    }

    /// Value of a flag that *requires* one: a bare `--name` (no value
    /// before the end of the line / the next flag) is a usage error rather
    /// than the implicit boolean `"true"`.
    pub fn flag_value(&self, name: &str) -> Result<Option<&str>, ParseError> {
        if self.bare.contains(name) {
            return Err(ParseError(format!("--{name} expects a value")));
        }
        Ok(self.flag(name))
    }

    pub fn flag_usize(&self, name: &str) -> Result<Option<usize>, ParseError> {
        match self.flag_value(name)? {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| ParseError(format!("--{name} expects an integer, got '{v}'"))),
        }
    }

    pub fn flag_f64(&self, name: &str) -> Result<Option<f64>, ParseError> {
        match self.flag_value(name)? {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| ParseError(format!("--{name} expects a number, got '{v}'"))),
        }
    }

    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn command_and_positionals() {
        let a = parse("optimize resnet18 extra");
        assert_eq!(a.command, "optimize");
        assert_eq!(a.positional(0), Some("resnet18"));
        assert_eq!(a.positional(1), Some("extra"));
        assert_eq!(a.positional(2), None);
    }

    #[test]
    fn flags_with_values() {
        let a = parse("simulate vgg19 --strategy 6 --out=bench_out");
        assert_eq!(a.flag("strategy"), Some("6"));
        assert_eq!(a.flag("out"), Some("bench_out"));
        assert_eq!(a.flag_usize("strategy").unwrap(), Some(6));
    }

    #[test]
    fn boolean_flags() {
        let a = parse("run --verify --requests 8");
        assert!(a.flag_bool("verify"));
        assert_eq!(a.flag_usize("requests").unwrap(), Some(8));
    }

    #[test]
    fn trailing_boolean_flag() {
        let a = parse("zoo --spec");
        assert!(a.flag_bool("spec"));
    }

    #[test]
    fn bare_value_flag_is_a_usage_error_not_a_panic() {
        // A trailing flag that should carry a value parses (it may be a
        // boolean) but value accessors reject it with a usage message.
        let a = parse("tune resnet18 --target");
        assert!(a.flag_bool("target"));
        let err = a.flag_value("target").unwrap_err();
        assert_eq!(err.to_string(), "--target expects a value");
        assert!(a.flag_usize("target").is_err());
        assert!(a.flag_f64("target").is_err());
        // Same for a bare flag in the middle of the line.
        let a = parse("serve-sim --models --rate 10");
        assert!(a.flag_value("models").is_err());
        assert_eq!(a.flag_f64("rate").unwrap(), Some(10.0));
        // An explicit value is never bare, even the literal string "true".
        let a = parse("tune x --target mlu100 --flagged=true");
        assert_eq!(a.flag_value("target").unwrap(), Some("mlu100"));
        assert_eq!(a.flag_value("flagged").unwrap(), Some("true"));
        // A later explicit value clears an earlier bare occurrence.
        let a = parse("tune x --target --target edge4");
        assert_eq!(a.flag_value("target").unwrap(), Some("edge4"));
    }

    #[test]
    fn empty_assignment_flag_errors() {
        assert!(Args::parse(["x".to_string(), "--=v".to_string()]).is_err());
    }

    #[test]
    fn bad_number_errors() {
        let a = parse("x --n abc");
        assert!(a.flag_usize("n").is_err());
    }

    #[test]
    fn missing_command_errors() {
        assert!(Args::parse(Vec::<String>::new()).is_err());
    }
}
