//! Minimal subcommand + flag parser.
//!
//! Grammar: `dlfusion <command> [positionals...] [--flag[=value]|--flag value]`.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Args {
    pub command: String,
    pub positionals: Vec<String>,
    flags: BTreeMap<String, String>,
}

/// Parse failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError(pub String);

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for ParseError {}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, ParseError> {
        let mut it = argv.into_iter().peekable();
        let command = it
            .next()
            .ok_or_else(|| ParseError("missing command (try 'help')".into()))?;
        let mut args = Args { command, ..Default::default() };
        while let Some(a) = it.next() {
            if let Some(flag) = a.strip_prefix("--") {
                if flag.is_empty() {
                    return Err(ParseError("bare '--' not supported".into()));
                }
                if let Some((k, v)) = flag.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    args.flags.insert(flag.to_string(), it.next().unwrap());
                } else {
                    args.flags.insert(flag.to_string(), "true".to_string());
                }
            } else {
                args.positionals.push(a);
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_bool(&self, name: &str) -> bool {
        matches!(self.flag(name), Some("true") | Some("1") | Some("yes"))
    }

    pub fn flag_usize(&self, name: &str) -> Result<Option<usize>, ParseError> {
        match self.flag(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| ParseError(format!("--{name} expects an integer, got '{v}'"))),
        }
    }

    pub fn flag_f64(&self, name: &str) -> Result<Option<f64>, ParseError> {
        match self.flag(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| ParseError(format!("--{name} expects a number, got '{v}'"))),
        }
    }

    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn command_and_positionals() {
        let a = parse("optimize resnet18 extra");
        assert_eq!(a.command, "optimize");
        assert_eq!(a.positional(0), Some("resnet18"));
        assert_eq!(a.positional(1), Some("extra"));
        assert_eq!(a.positional(2), None);
    }

    #[test]
    fn flags_with_values() {
        let a = parse("simulate vgg19 --strategy 6 --out=bench_out");
        assert_eq!(a.flag("strategy"), Some("6"));
        assert_eq!(a.flag("out"), Some("bench_out"));
        assert_eq!(a.flag_usize("strategy").unwrap(), Some(6));
    }

    #[test]
    fn boolean_flags() {
        let a = parse("run --verify --requests 8");
        assert!(a.flag_bool("verify"));
        assert_eq!(a.flag_usize("requests").unwrap(), Some(8));
    }

    #[test]
    fn trailing_boolean_flag() {
        let a = parse("zoo --spec");
        assert!(a.flag_bool("spec"));
    }

    #[test]
    fn bad_number_errors() {
        let a = parse("x --n abc");
        assert!(a.flag_usize("n").is_err());
    }

    #[test]
    fn missing_command_errors() {
        assert!(Args::parse(Vec::<String>::new()).is_err());
    }
}
