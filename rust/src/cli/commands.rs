//! CLI command implementations.

use super::args::Args;
use crate::accel::{Simulator, Target};
use crate::codegen;
use crate::coordinator::{self, driver, equivalence, plan};
use crate::cost::CostEngine;
use crate::graph::dag::{self, DagModel, LoadedModel};
use crate::graph::{format as dlm, LayerKind, Model};
use crate::learn;
use crate::obs::{Domain, MetricsRegistry, TraceSession};
use crate::optimizer::{self, Strategy};
use crate::perfmodel;
use crate::runtime::Runtime;
use crate::search::{AnnealConfig, BlockRule};
use crate::serving;
use crate::tuner::{self, Tuner};
use crate::util::units::{fmt_gops, fmt_ms};
use crate::util::{Json, Table};
use crate::zoo;

pub const HELP: &str = "\
dlfusion — auto-tuning layer-fusion compiler (DLFusion reproduction)

USAGE:
    dlfusion <command> [args] [--flags]

COMMANDS:
    zoo [--spec]                 list built-in models (Table II) / hardware spec
    targets                      list the hardware-target registry
    optimize <model|file.dlm>    run Algorithm 1, print the schedule
        [--strategy 1..7] [--critical GOPS]
    tune <model|file.dlm>        run one tuner backend, or --compare several,
        [--tuner NAME]           through the unified tuner API; --batch makes
        [--compare] [--iterations N] [--mps 1,2,4] [--granularity any|x4]
        [--budget-evals N]       every backend co-optimize (MP, batch) and
        [--batch 1,2,4,8]        serve the per-sample-fastest point
        [--compare-targets]      (NAME: algorithm1 strategy1..7 oracle
        [--threads N]             oracle-full oracle-constrained anneal
        [--model-file F.dlm]      exhaustive learned);
        [--metrics-out F]        --model-file reads a .dlm v1/v2 document;
        [--trace-out F]          v2 dags tune with fusion constrained to
                                 the graph's legal cut set;
                                 --compare-targets runs the one backend on
                                 every registry target instead (the cross-
                                 target analog of --compare); --threads fans
                                 the search/comparison across N workers,
                                 bit-identical to the sequential run;
                                 --metrics-out writes the unified metrics
                                 snapshot (JSON; Prometheus text if F ends
                                 in .prom), --trace-out a Chrome trace of
                                 the tuner's wall-clock phases (single-
                                 backend runs only)
    model import <file.dlm>      parse + validate a .dlm v1/v2 document
    model export <model>         write a zoo model as .dlm (v2 for dags)
        [--out FILE]
    model show <model|file.dlm>  node table, shapes, fusion-legal cuts
    simulate <model|file.dlm>    simulate all seven strategies (Fig. 10 row)
    search <model|file.dlm>      compare search costs: Algorithm 1 vs oracle
        [--iterations N]         DP vs simulated annealing (cache + wall time)
    codegen <model|file.dlm>     emit CNML-style C++ [--out DIR]
    characterize                 re-derive OpCount_critical / Eq.5 weights
    space <n>                    evaluate Eq. 4 search-space size
    trace <model|file.dlm>       per-block timeline + utilization breakdown
        [--strategy 1..7]
    run [--requests N] [--verify] end-to-end PJRT inference on mini_cnn
    serve-sim                    multi-tenant serving simulation: load-aware
        [--models a,b,..]        (MP, batch) co-allocation over the target's
        [--arrivals poisson|closed|bursty] [--rate RPS] [--requests N]
        [--policy fifo|sjf|batch] [--slo-ms MS] [--seed S] [--concurrency K]
        [--max-batch N] [--batch-wait-ms MS] core pool, then a deterministic
        [--allocator load|single] event-driven SLO report; --models mixes
        [--model-file F.dlm]     zoo names, dag variants (fusion constrained
        [--no-events]            to the graph's legal cuts), and .dlm paths;
        [--metrics-out F]        --policy batch forms per-model batches of
        [--trace-out F]          up to N requests, holding partial batches
                                 at most MS ms; --no-events skips recording
                                 the event trace (hot path; identical SLO
                                 report, but incompatible with --trace-out);
                                 --metrics-out writes the SLO report's
                                 metrics snapshot (JSON; .prom = Prometheus
                                 text), --trace-out a deterministic
                                 sim-time Chrome trace of the serving run
    serve-fleet                  fleet serving simulation: a multi-chip
        [--fleet mlu100x2,edge4x4] (heterogeneous) fleet planned per chip
        [--route round-robin|least-loaded|model-sharded] kind through the
        [--queue-cap N]          fleet-wide tuned-plan cache, a deterministic
        [--models a,b,..] [--model-file F.dlm] routing layer with admission
        [--arrivals poisson|bursty] [--rate RPS] control (--queue-cap sheds
        [--requests N] [--seed S] requests finding N already waiting), then
        [--policy fifo|sjf|batch] [--max-batch N] [--batch-wait-ms MS]
        [--slo-ms MS] [--allocator load|single] the merged SLO report with
        [--no-events]            shed accounting and a per-chip breakdown;
        [--metrics-out F]        a one-chip fleet reproduces serve-sim
        [--trace-out F]          bit-identically; open-loop arrivals only
    learn fit <model|file.dlm>   fit the learned cost model on cost-engine
        [--out FILE.json]        samples over the reduced oracle block space
        [--pca K] [--holdout F]  and print the fit report (R2, MAPE, residual
        [--seed S]               band); --out saves the versioned model file,
        [--metrics-out F]        --pca projects onto K principal components
    learn eval <model|file.dlm> <FILE.json>  score a saved model file on a
                                 workload's samples (MAPE; pass --target to
                                 measure a cross-target transfer point)
    learn transfer [model]       fit per registry target, evaluate on every
        [--pca K] [--holdout F]  other: the cross-target MAPE matrix of the
        [--seed S]               learned cost model (default workload:
        [--metrics-out F]        resnet18)
    report <snapshot.json>       render a --metrics-out snapshot as a table
        [--prom]                 (or re-emit it as Prometheus text)
    perf-smoke                   deterministic perf metrics: tuned latencies
        [--out FILE.json]        on the target + the mlu100/edge4 cross-
        [--baseline FILE.json]   target points + serving/batching throughput
        [--write-baseline]       (simulated, gated exact) plus a wall-clock
        [--threads N]            section (tuning evals/s, N-thread sweep
                                 speedup, serve events/s; tolerance-gated),
                                 written as JSON and diffed against the
                                 checked-in baseline
    help                         this text

MODELS:  resnet18 resnet50 vgg19 alexnet mobilenet mini_cnn (or a .dlm file);
         branching dag variants (tune/model/serve-sim/serve-fleet):
         resnet18-dag resnet50-dag
TARGETS: every hardware-touching command takes --target NAME (default
         mlu100; see 'targets'): zoo optimize tune simulate search codegen
         characterize trace run serve-sim perf-smoke learn fit/eval;
         serve-fleet names its chips' targets in --fleet instead; learn
         transfer always sweeps the whole registry
";

/// Execute a parsed command line; returns the process exit code.
pub fn run(args: &Args) -> i32 {
    let result = match args.command.as_str() {
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        "zoo" => cmd_zoo(args),
        "targets" => cmd_targets(),
        "optimize" => cmd_optimize(args),
        "tune" => cmd_tune(args),
        "model" => cmd_model(args),
        "simulate" => cmd_simulate(args),
        "search" => cmd_search(args),
        "codegen" => cmd_codegen(args),
        "characterize" => cmd_characterize(args),
        "space" => cmd_space(args),
        "trace" => cmd_trace(args),
        "run" => cmd_run(args),
        "serve-sim" => cmd_serve_sim(args),
        "serve-fleet" => cmd_serve_fleet(args),
        "perf-smoke" => cmd_perf_smoke(args),
        "learn" => cmd_learn(args),
        "report" => cmd_report(args),
        other => Err(format!("unknown command '{other}' (try 'help')")),
    };
    match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

/// Resolve `--target` against the registry (default: `mlu100`).
fn parse_target(args: &Args) -> Result<Target, String> {
    match args.flag_value("target").map_err(|e| e.to_string())? {
        None => Ok(Target::mlu100()),
        Some(name) => Target::by_name(name).map_err(|e| e.to_string()),
    }
}

/// The simulator for the command's `--target`.
fn parse_sim(args: &Args) -> Result<Simulator, String> {
    Ok(Simulator::new(parse_target(args)?))
}

/// A resolved tuning workload: the range-based model the cost stack
/// consumes, plus the DAG-derived cut constraint (and the source graph)
/// when the workload came from a branching `.dlm` v2 document or a DAG zoo
/// variant. `cuts: None` means every boundary is fusion-legal — the plain
/// linear-chain path.
struct LoadedWorkload {
    model: Model,
    cuts: Option<Vec<usize>>,
    dag: Option<DagModel>,
}

fn workload_from_dag(d: DagModel) -> Result<LoadedWorkload, String> {
    let lin = dag::linearize(&d).map_err(|e| format!("{}: {e}", d.name))?;
    Ok(LoadedWorkload { model: lin.model, cuts: lin.cuts, dag: Some(d) })
}

fn workload_from_loaded(loaded: LoadedModel) -> Result<LoadedWorkload, String> {
    match loaded {
        LoadedModel::Linear(model) => Ok(LoadedWorkload { model, cuts: None, dag: None }),
        LoadedModel::Dag(d) => workload_from_dag(d),
    }
}

fn workload_from_file(path: &str) -> Result<LoadedWorkload, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    workload_from_loaded(dag::load_dlm(&text).map_err(|e| format!("{path}: {e}"))?)
}

fn unknown_model(name: &str) -> String {
    format!(
        "unknown model '{name}' (known: {}; dag variants: {})",
        zoo::MODEL_NAMES.join(", "),
        zoo::DAG_MODEL_NAMES.join(", ")
    )
}

/// Resolve a workload from `--model-file PATH` or the first positional
/// (zoo name, DAG zoo name, or `*.dlm` path).
fn load_workload(args: &Args) -> Result<LoadedWorkload, String> {
    if let Some(path) = args.flag_value("model-file").map_err(|e| e.to_string())? {
        return workload_from_file(path);
    }
    let name = args
        .positional(0)
        .ok_or("missing model name or .dlm path")?;
    if name.ends_with(".dlm") {
        workload_from_file(name)
    } else if let Some(model) = zoo::by_name(name) {
        Ok(LoadedWorkload { model, cuts: None, dag: None })
    } else if let Some(d) = zoo::dag_by_name(name) {
        workload_from_dag(d)
    } else {
        Err(unknown_model(name))
    }
}

/// Workload loader for the linear-only commands (optimize, simulate,
/// search, codegen, trace): accepts anything [`load_workload`] accepts,
/// but rejects branching dags — their fusion spaces are cut-constrained
/// and only the tuner stack honors that.
fn load_model(args: &Args) -> Result<Model, String> {
    let w = load_workload(args)?;
    if w.cuts.is_some() {
        return Err(format!(
            "'{}' is a branching dag; this command runs over linear layer \
             chains — tune it with 'dlfusion tune', which constrains fusion \
             to the dag's legal cut set",
            w.model.name
        ));
    }
    Ok(w.model)
}

fn cmd_zoo(args: &Args) -> Result<(), String> {
    if args.flag_bool("spec") {
        let target = parse_target(args)?;
        let s = target.spec();
        let mut t = Table::new(&["item", "value"]).label_first()
            .with_title(&format!(
                "Table I — hardware specification (simulated target '{}')",
                target.name()));
        t.row(vec!["name".into(), s.name.clone()]);
        t.row(vec!["cores".into(), s.num_cores.to_string()]);
        t.row(vec!["peak FP16".into(),
                   format!("{:.0} TFLOPS", s.peak_gflops() / 1000.0)]);
        t.row(vec!["memory BW".into(), format!("{} GB/s", s.mem_bw_gbps)]);
        t.row(vec!["memory".into(), format!("{:.0} GiB", s.mem_bytes / (1u64 << 30) as f64)]);
        t.row(vec!["OpCount_critical".into(), fmt_gops(s.opcount_critical())]);
        println!("{t}");
        return Ok(());
    }
    let mut t = Table::new(&["network", "total conv op", "avg op", "#conv", "#layers"])
        .label_first()
        .with_title("Table II — evaluated networks");
    for m in zoo::all_models() {
        let s = m.stats();
        t.row(vec![
            m.name.clone(),
            fmt_gops(s.total_conv_gops),
            fmt_gops(s.avg_conv_gops),
            s.num_conv.to_string(),
            s.num_layers.to_string(),
        ]);
    }
    println!("{t}");
    Ok(())
}

fn cmd_targets() -> Result<(), String> {
    let mut t = Table::new(&["target", "chip", "cores", "peak", "BW",
                             "mem", "OpCount_crit", "buffer/core"])
        .label_first()
        .align(1, crate::util::table::Align::Left)
        .with_title("hardware-target registry (use --target NAME; default mlu100)");
    for target in Target::all() {
        let s = target.spec();
        t.row(vec![
            target.name().to_string(),
            s.name.clone(),
            s.num_cores.to_string(),
            format!("{:.0} TFLOPS", s.peak_gflops() / 1000.0),
            format!("{:.1} GB/s", s.mem_bw_gbps),
            format!("{:.0} GiB", s.mem_bytes / (1u64 << 30) as f64),
            fmt_gops(s.opcount_critical()),
            format!("{:.1} MiB", s.core_buffer_bytes / (1u64 << 20) as f64),
        ]);
    }
    println!("{t}");
    for target in Target::all() {
        println!("{}: {}", target.name(), target.description());
    }
    Ok(())
}

fn cmd_optimize(args: &Args) -> Result<(), String> {
    let model = load_model(args)?;
    let sim = parse_sim(args)?;
    let strategy = match args.flag_usize("strategy").map_err(|e| e.to_string())? {
        None => Strategy::DlFusion,
        Some(i) => Strategy::from_index(i).ok_or(format!("strategy must be 1..=7, got {i}"))?,
    };
    let mut params = optimizer::AlgorithmParams::for_spec(&sim.spec);
    if let Some(c) = args.flag_f64("critical").map_err(|e| e.to_string())? {
        params.opcount_critical = c;
    }
    let mut engine = CostEngine::new(&sim, &model);
    let sched = optimizer::strategies::strategy_schedule_with(&mut engine, strategy, &params);
    let report = engine.run_schedule(&sched);
    println!("model:     {}", model.name);
    println!("target:    {}", sim.target());
    println!("strategy:  {} ({})", strategy.index(), strategy.name());
    println!("schedule:  {}", sched.summary());
    println!("blocks:    {}", sched.num_blocks());
    println!("latency:   {}", fmt_ms(report.total_ms));
    println!("FPS:       {:.1}", report.fps());
    Ok(())
}

/// Resolve a `--tuner` name to a boxed backend (the library's registry,
/// shared with the tuner-factory sweep paths).
fn parse_tuner(name: &str) -> Result<Box<dyn Tuner>, String> {
    tuner::backend_by_name(name)
}

/// Worker threads for the parallel drivers (`--threads N`; `default` is 1
/// for tuning, 4 for the perf-smoke speedup leg).
fn parse_threads(args: &Args, default: usize) -> Result<usize, String> {
    match args.flag_usize("threads").map_err(|e| e.to_string())? {
        None => Ok(default),
        Some(0) => Err("--threads must be at least 1".into()),
        Some(n) => Ok(n),
    }
}

/// Parse a `--flag 1,2,4`-style comma-separated integer list.
fn parse_usize_list(args: &Args, name: &str) -> Result<Option<Vec<usize>>, String> {
    match args.flag_value(name).map_err(|e| e.to_string())? {
        None => Ok(None),
        Some(list) => list
            .split(',')
            .map(|s| s.trim().parse::<usize>())
            .collect::<Result<Vec<_>, _>>()
            .map(Some)
            .map_err(|_| format!("--{name} expects comma-separated integers, got '{list}'")),
    }
}

/// Write observability output, creating parent directories like the
/// perf-smoke writer does.
fn write_obs_file(path: &str, text: &str) -> Result<(), String> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).map_err(|e| format!("{path}: {e}"))?;
        }
    }
    std::fs::write(path, text).map_err(|e| format!("{path}: {e}"))
}

/// Honor `--metrics-out FILE`: the unified snapshot as pretty JSON, or
/// Prometheus exposition text when the path ends in `.prom`
/// (rust/docs/DESIGN.md §14.2). No flag, no output.
fn write_metrics_out(args: &Args, reg: &MetricsRegistry) -> Result<(), String> {
    let Some(path) = args.flag_value("metrics-out").map_err(|e| e.to_string())? else {
        return Ok(());
    };
    let text = if path.ends_with(".prom") {
        reg.to_prometheus()
    } else {
        reg.snapshot().to_pretty()
    };
    write_obs_file(path, &text)?;
    println!("wrote metrics snapshot ({} metrics) to {path}", reg.len());
    Ok(())
}

/// Honor `--trace-out FILE`: the session as Chrome trace-event JSON
/// (load it at chrome://tracing or ui.perfetto.dev). No flag, no output.
fn write_trace_out(args: &Args, session: &TraceSession) -> Result<(), String> {
    let Some(path) = args.flag_value("trace-out").map_err(|e| e.to_string())? else {
        return Ok(());
    };
    write_obs_file(path, &session.to_chrome_string())?;
    println!("wrote chrome trace ({} events) to {path}", session.len());
    Ok(())
}

/// `dlfusion report SNAPSHOT.json [--prom]` — re-render a `--metrics-out`
/// snapshot (or a perf-smoke `BENCH_ci.json`, whose `metrics`/`wall_metrics`
/// sections parse the same way) as a human-readable table or as Prometheus
/// exposition text.
fn cmd_report(args: &Args) -> Result<(), String> {
    let path = args
        .positional(0)
        .ok_or("usage: report <snapshot.json> [--prom]")?;
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    let doc = Json::parse(&text).map_err(|e| format!("{path}: {e}"))?;
    let reg = MetricsRegistry::from_snapshot(&doc).map_err(|e| format!("{path}: {e}"))?;
    if args.flag_bool("prom") {
        print!("{}", reg.to_prometheus());
    } else {
        println!("{}", reg.render_table());
    }
    Ok(())
}

/// `dlfusion learn <fit|eval|transfer>` — the learned-cost-model surface
/// (rust/docs/DESIGN.md §16): fit a surrogate of the cost engine, score a
/// saved model file, or build the cross-target transfer matrix.
fn cmd_learn(args: &Args) -> Result<(), String> {
    let verb = args
        .positional(0)
        .ok_or("usage: learn <fit|eval|transfer> [model|file.dlm] [--flags]")?;
    match verb {
        "fit" => cmd_learn_fit(args),
        "eval" => cmd_learn_eval(args),
        "transfer" => cmd_learn_transfer(args),
        other => Err(format!("unknown learn verb '{other}' (fit, eval, transfer)")),
    }
}

/// Resolve the learn subcommands' workload from positional `pos` (the verb
/// occupies positional 0, so the model name sits one slot later than in
/// [`load_workload`]). Dag variants linearize; the learned model samples
/// the unconstrained reduced block space either way.
fn learn_workload(args: &Args, pos: usize, usage: &str) -> Result<Model, String> {
    let name = args.positional(pos).ok_or_else(|| usage.to_string())?;
    if name.ends_with(".dlm") {
        Ok(workload_from_file(name)?.model)
    } else if let Some(model) = zoo::by_name(name) {
        Ok(model)
    } else if let Some(d) = zoo::dag_by_name(name) {
        Ok(workload_from_dag(d)?.model)
    } else {
        Err(unknown_model(name))
    }
}

/// Parse the shared fit knobs (`--pca K`, `--holdout F`, `--seed S`) on top
/// of [`learn::FitConfig::default`].
fn parse_fit_config(args: &Args) -> Result<learn::FitConfig, String> {
    let mut cfg = learn::FitConfig::default();
    if let Some(k) = args.flag_usize("pca").map_err(|e| e.to_string())? {
        cfg.pca = Some(k);
    }
    if let Some(h) = args.flag_f64("holdout").map_err(|e| e.to_string())? {
        cfg.holdout = h;
    }
    if let Some(s) = args.flag_usize("seed").map_err(|e| e.to_string())? {
        cfg.seed = s as u64;
    }
    Ok(cfg)
}

fn cmd_learn_fit(args: &Args) -> Result<(), String> {
    let model = learn_workload(
        args, 1,
        "usage: learn fit <model|file.dlm> [--target T] [--out FILE.json] \
         [--pca K] [--holdout F] [--seed S]")?;
    let sim = parse_sim(args)?;
    let cfg = parse_fit_config(args)?;
    let engine = CostEngine::new(&sim, &model);
    let samples = learn::collect_samples(&engine, &sim.spec.reduced_mp_set(), &[1]);
    let fitted = learn::LearnedCostModel::fit(sim.target(), &samples, &cfg)?;
    println!("workload: {}", model.name);
    print!("{}", fitted.render());
    if let Some(path) = args.flag_value("out").map_err(|e| e.to_string())? {
        fitted.save(path)?;
        println!("wrote model file to {path}");
    }
    let mut reg = MetricsRegistry::new();
    fitted.export_metrics(&mut reg);
    write_metrics_out(args, &reg)?;
    Ok(())
}

fn cmd_learn_eval(args: &Args) -> Result<(), String> {
    const USAGE: &str =
        "usage: learn eval <model|file.dlm> <model-file.json> [--target T]";
    let model = learn_workload(args, 1, USAGE)?;
    let path = args.positional(2).ok_or(USAGE)?;
    let fitted = learn::LearnedCostModel::load(path)?;
    let sim = parse_sim(args)?;
    let engine = CostEngine::new(&sim, &model);
    let samples = learn::collect_samples(&engine, &sim.spec.reduced_mp_set(), &[1]);
    println!("workload:   {}", model.name);
    println!("trained on: {}", fitted.target);
    println!("evaluated:  {} ({} samples)", sim.target(), samples.len());
    println!("mape:       {:.2}%", fitted.mape_on(&samples) * 100.0);
    if fitted.target != sim.target() {
        println!("(a cross-target transfer point — 'learn transfer' sweeps \
                  the full matrix)");
    }
    Ok(())
}

fn cmd_learn_transfer(args: &Args) -> Result<(), String> {
    let model = match args.positional(1) {
        None => zoo::resnet18(),
        Some(_) => learn_workload(
            args, 1,
            "usage: learn transfer [model|file.dlm] [--pca K] [--holdout F] \
             [--seed S]")?,
    };
    let cfg = parse_fit_config(args)?;
    let matrix = learn::TransferMatrix::build(&model, &cfg)?;
    println!("workload: {}", model.name);
    print!("{}", matrix.render());
    let mut reg = MetricsRegistry::new();
    matrix.export_metrics(&mut reg);
    write_metrics_out(args, &reg)?;
    Ok(())
}

/// Apply the shared tune/search flags to a request (any target's).
fn apply_request_flags<'a>(args: &Args, mut request: tuner::TuningRequest<'a>)
                           -> Result<tuner::TuningRequest<'a>, String> {
    if let Some(iters) = args.flag_usize("iterations").map_err(|e| e.to_string())? {
        request = request.anneal_config(AnnealConfig { iterations: iters, ..Default::default() });
    }
    if let Some(mps) = parse_usize_list(args, "mps")? {
        request = request.mp_candidates(mps);
    }
    if let Some(batches) = parse_usize_list(args, "batch")? {
        request = request.batch_candidates(batches);
    }
    match args.flag_value("granularity").map_err(|e| e.to_string())? {
        None => {}
        Some("any") => request = request.granularity(BlockRule::Any),
        Some("x4") | Some("mult4") => {
            request = request.granularity(BlockRule::MultipleOfFour)
        }
        Some(other) => {
            return Err(format!("--granularity expects 'any' or 'x4', got '{other}'"))
        }
    }
    if let Some(cap) = args.flag_usize("budget-evals").map_err(|e| e.to_string())? {
        request = request.max_evaluations(cap as u64);
    }
    request = request.threads(parse_threads(args, 1)?);
    Ok(request)
}

/// Build a `TuningRequest` from the shared tune/search flags.
fn parse_request<'a>(args: &Args, sim: &'a Simulator, model: &'a Model)
                     -> Result<tuner::TuningRequest<'a>, String> {
    apply_request_flags(args, tuner::TuningRequest::new(sim, model))
}

/// The default comparison panel (Algorithm 1 vs oracle DP vs annealing),
/// plus one extra backend when the user named it (skipped if it duplicates
/// a default).
fn compare_panel(extra: Option<&str>) -> Result<Vec<Box<dyn Tuner>>, String> {
    let mut tuners: Vec<Box<dyn Tuner>> = vec![
        Box::new(tuner::Algorithm1),
        Box::new(tuner::OracleDp::reduced()),
        Box::new(tuner::Annealer::new()),
    ];
    if let Some(name) = extra {
        let t = parse_tuner(name)?;
        if tuners.iter().all(|have| have.name() != t.name()) {
            tuners.push(t);
        }
    }
    Ok(tuners)
}

/// Constrain a request to the workload's fusion-legal cut set, if any.
fn with_workload_cuts<'a>(
    req: tuner::TuningRequest<'a>,
    w: &LoadedWorkload,
) -> tuner::TuningRequest<'a> {
    match &w.cuts {
        Some(c) => req.allowed_cuts(c.clone()),
        None => req,
    }
}

fn cmd_tune(args: &Args) -> Result<(), String> {
    let workload = load_workload(args)?;
    let model = &workload.model;
    let tuner_flag = args.flag_value("tuner").map_err(|e| e.to_string())?;

    // The observability exports describe one backend's run; a comparison
    // interleaves several over one shared cache, so the flags would lie.
    if (args.flag("metrics-out").is_some() || args.flag("trace-out").is_some())
        && (args.flag_bool("compare") || args.flag_bool("compare-targets"))
    {
        return Err("--metrics-out/--trace-out apply to single-backend tune \
                    runs, not --compare/--compare-targets".into());
    }

    if args.flag_bool("compare-targets") {
        if args.flag_bool("compare") {
            return Err("--compare and --compare-targets are mutually \
                        exclusive (one compares backends on one target, the \
                        other one backend across targets)".into());
        }
        // The cross-target analog of --compare: one backend, every registry
        // hardware point, the same request knobs applied to each (the
        // template's --target, if any, only anchors flag validation).
        // Targets are independent, so --threads fans them across workers
        // via the tuner factory; every row matches the sequential run.
        let name = tuner_flag.unwrap_or("algorithm1");
        let backend = parse_tuner(name)?;
        let sim = parse_sim(args)?;
        let template = with_workload_cuts(parse_request(args, &sim, model)?, &workload);
        let targets = Target::all();
        let threads = parse_threads(args, 1)?;
        let cmp = tuner::compare_targets_with(
            model, &targets,
            || tuner::backend_by_name(name).expect("name validated above"),
            &template, threads)
            .map_err(|e| e.to_string())?;
        print!("{}", cmp.render(&format!(
            "cross-target comparison — {} (tuner {})",
            model.name, backend.name())));
        return Ok(());
    }

    let sim = parse_sim(args)?;
    let request = with_workload_cuts(parse_request(args, &sim, model)?, &workload);

    if args.flag_bool("compare") {
        // The Fig. 10-style side-by-side report over one shared engine; an
        // explicit --tuner joins the default panel.
        let mut tuners = compare_panel(tuner_flag)?;
        let cmp = request.compare(&mut tuners).map_err(|e| e.to_string())?;
        let constraint = if workload.cuts.is_some() {
            " (dag-constrained fusion)"
        } else {
            ""
        };
        print!("{}", cmp.render(&format!(
            "tuner comparison — {} on {}{constraint}",
            model.name, request.target())));
        return Ok(());
    }

    let mut backend = parse_tuner(tuner_flag.unwrap_or("algorithm1"))?;
    // A named context (not `request.run`) so the engine stays reachable for
    // the --metrics-out export after the backend returns.
    let mut cx = request.context();
    let outcome = backend.tune(&mut cx).map_err(|e| e.to_string())?;
    println!("model:     {}", model.name);
    if let Some(cuts) = &workload.cuts {
        println!("graph:     branching dag — fusion constrained to {} of {} \
                  legal boundaries",
                 cuts.len(), model.num_layers() + 1);
    }
    println!("target:    {}", sim.target());
    println!("tuner:     {}", outcome.tuner);
    println!("schedule:  {}", outcome.schedule.summary());
    println!("blocks:    {}", outcome.schedule.num_blocks());
    if outcome.batch > 1 {
        println!("batch:     {} (per-sample winner of the candidate set)",
                 outcome.batch);
        println!("latency:   {} predicted per invocation, {} per sample \
                  ({:.1} FPS)",
                 fmt_ms(outcome.predicted_ms), fmt_ms(outcome.per_sample_ms()),
                 outcome.fps());
    } else {
        println!("latency:   {} predicted ({:.1} FPS)",
                 fmt_ms(outcome.predicted_ms), outcome.fps());
    }
    let st = outcome.stats;
    println!("search:    {} evaluations ({} computed, {:.0}% cache hits), {} us{}",
             st.evaluations, st.cache_misses, 100.0 * st.hit_rate(), st.wall_us,
             if st.truncated { " — budget-truncated" } else { "" });
    if st.space_visited > 0 {
        println!("space:     {} joint (fusion, MP) candidates certified",
                 st.space_visited);
    }

    // Observability exports (rust/docs/DESIGN.md §14): the unified metrics
    // snapshot (tuner outcome + cost-engine cache/shard counters) and a
    // wall-clock Chrome trace of the backend's phases. Tuning timers are
    // machine-dependent, so every span here rides the wall clock — clearly
    // segregated from the deterministic serve-sim traces.
    let mut reg = MetricsRegistry::new();
    outcome.export_metrics(&mut reg);
    cx.engine().export_metrics(&mut reg);
    write_metrics_out(args, &reg)?;
    let mut session = TraceSession::new(&format!("tune {}", model.name));
    let span_args = |phase: &str| {
        vec![("tuner".to_string(), Json::Str(outcome.tuner.clone())),
             ("phase".to_string(), Json::Str(phase.to_string()))]
    };
    let prewarm = st.prewarm_us as f64;
    let search = st.search_us.max(st.prewarm_us) as f64;
    let wall = st.wall_us.max(st.search_us) as f64;
    if st.prewarm_us > 0 {
        session.wall_span("prewarm", "tuner", 0, 0.0, prewarm,
                          span_args("parallel cache prewarm"));
    }
    session.wall_span("search", "tuner", 0, prewarm, search - prewarm,
                      span_args("schedule-producing search"));
    session.wall_span("pricing", "tuner", 0, search, wall - search,
                      span_args("final-schedule pricing + bookkeeping"));
    write_trace_out(args, &session)?;
    Ok(())
}

fn cmd_model(args: &Args) -> Result<(), String> {
    let verb = args
        .positional(0)
        .ok_or("usage: model <import|export|show> <model|file.dlm>")?;
    match verb {
        "import" => cmd_model_import(args),
        "export" => cmd_model_export(args),
        "show" => cmd_model_show(args),
        other => Err(format!("unknown model verb '{other}' (import, export, show)")),
    }
}

fn cmd_model_import(args: &Args) -> Result<(), String> {
    let path = args.positional(1).ok_or("usage: model import <file.dlm>")?;
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    match dag::load_dlm(&text).map_err(|e| format!("{path}: {e}"))? {
        LoadedModel::Linear(m) => {
            let s = m.stats();
            println!("{path}: .dlm v1 (linear chain)");
            println!("model:    {}", m.name);
            println!("layers:   {} ({} convs, {} conv work)",
                     m.num_layers(), s.num_conv, fmt_gops(s.total_conv_gops));
        }
        LoadedModel::Dag(d) => {
            let lin = dag::linearize(&d).map_err(|e| format!("{path}: {e}"))?;
            let n = lin.model.num_layers();
            println!("{path}: .dlm v2 (dag)");
            println!("model:    {}", d.name);
            println!("nodes:    {} ({} graph inputs, {} outputs)",
                     d.num_nodes(), d.inputs.len(), d.outputs.len());
            match &lin.cuts {
                None => println!("shape:    pure chain — every boundary fusion-legal"),
                Some(c) => println!("shape:    branching — {} of {} boundaries fusion-legal",
                                    c.len(), n + 1),
            }
        }
    }
    println!("ok: valid and tunable ('tune --model-file {path}')");
    Ok(())
}

fn cmd_model_export(args: &Args) -> Result<(), String> {
    let name = args
        .positional(1)
        .ok_or("usage: model export <zoo-model> [--out FILE]")?;
    let (text, what) = if let Some(m) = zoo::by_name(name) {
        (dlm::to_dlm(&m), format!("{} (.dlm v1)", m.name))
    } else if let Some(d) = zoo::dag_by_name(name) {
        (dag::to_dlm_v2(&d), format!("{} (.dlm v2)", d.name))
    } else {
        return Err(unknown_model(name));
    };
    match args.flag_value("out").map_err(|e| e.to_string())? {
        Some(path) => {
            std::fs::write(path, &text).map_err(|e| format!("{path}: {e}"))?;
            println!("wrote {what} to {path}");
        }
        None => println!("{text}"),
    }
    Ok(())
}

fn layer_op(kind: &LayerKind) -> &'static str {
    match kind {
        LayerKind::Conv(_) => "conv",
        LayerKind::Fc(_) => "fc",
        LayerKind::ReLU { .. } => "relu",
        LayerKind::BatchNorm { .. } => "batchnorm",
        LayerKind::Pool { .. } => "pool",
        LayerKind::Add { .. } => "add",
        LayerKind::Concat { .. } => "concat",
    }
}

fn cmd_model_show(args: &Args) -> Result<(), String> {
    let name = args
        .positional(1)
        .ok_or("usage: model show <model|file.dlm>")?;
    let w = if name.ends_with(".dlm") {
        workload_from_file(name)?
    } else if let Some(model) = zoo::by_name(name) {
        LoadedWorkload { model, cuts: None, dag: None }
    } else if let Some(d) = zoo::dag_by_name(name) {
        workload_from_dag(d)?
    } else {
        return Err(unknown_model(name));
    };
    match &w.dag {
        None => {
            let mut t = Table::new(&["#", "layer", "op", "out shape"])
                .label_first()
                .align(1, crate::util::table::Align::Left)
                .align(2, crate::util::table::Align::Left)
                .with_title(&format!("{} — linear chain, {} layers",
                                     w.model.name, w.model.num_layers()));
            for (i, l) in w.model.layers.iter().enumerate() {
                let sh = l.output_shape();
                t.row(vec![
                    i.to_string(),
                    l.name.clone(),
                    layer_op(&l.kind).to_string(),
                    format!("{}x{}x{}", sh.c, sh.h, sh.w),
                ]);
            }
            println!("{t}");
            println!("fusion: every layer boundary is legal (pure chain)");
        }
        Some(d) => {
            let shapes = d.value_shapes();
            let mut t = Table::new(&["node", "op", "inputs", "out shape"])
                .label_first()
                .align(0, crate::util::table::Align::Left)
                .align(1, crate::util::table::Align::Left)
                .align(2, crate::util::table::Align::Left)
                .with_title(&format!("{} — dag, {} nodes", d.name, d.num_nodes()));
            for node in &d.nodes {
                let sh = shapes[&node.name];
                t.row(vec![
                    node.name.clone(),
                    node.op.mnemonic().to_string(),
                    node.inputs.join(", "),
                    format!("{}x{}x{}", sh.c, sh.h, sh.w),
                ]);
            }
            println!("{t}");
            println!("graph inputs:  {}",
                     d.inputs.iter().map(|i| i.name.as_str())
                         .collect::<Vec<_>>().join(", "));
            println!("graph outputs: {}", d.outputs.join(", "));
            let n = w.model.num_layers();
            match &w.cuts {
                None => println!("fusion: every boundary of the {n}-layer \
                                  linearization is legal (pure chain)"),
                Some(c) => println!("fusion: {} legal boundaries of {} — {:?}",
                                    c.len(), n + 1, c),
            }
        }
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<(), String> {
    let model = load_model(args)?;
    let sim = parse_sim(args)?;
    // One request, one shared context: the seven strategies reuse every
    // block evaluation.
    let request = tuner::TuningRequest::new(&sim, &model);
    let mut cx = request.context();
    let mut t = Table::new(&["#", "strategy", "blocks", "latency", "FPS", "speedup"])
        .label_first()
        .align(1, crate::util::table::Align::Left)
        .with_title(&format!("Fig. 10 row — {} on {}", model.name, sim.target()));
    let mut base_fps = None;
    for st in Strategy::ALL {
        let out = tuner::TableStrategy(st).tune(&mut cx).map_err(|e| e.to_string())?;
        let fps = out.fps();
        let base = *base_fps.get_or_insert(fps);
        t.row(vec![
            st.index().to_string(),
            st.name().to_string(),
            out.schedule.num_blocks().to_string(),
            fmt_ms(out.predicted_ms),
            format!("{fps:.1}"),
            format!("{:.2}x", fps / base),
        ]);
    }
    println!("{t}");
    let st = cx.engine_stats();
    println!("cost engine: {} block queries, {} computed ({} cached, \
              {:.1}x fewer computations than unmemoized)",
             st.queries(), st.misses, st.hits, st.block_eval_reduction());
    Ok(())
}

fn cmd_search(args: &Args) -> Result<(), String> {
    let model = load_model(args)?;
    let sim = parse_sim(args)?;
    let request = parse_request(args, &sim, &model)?;
    let iterations = args
        .flag_usize("iterations")
        .map_err(|e| e.to_string())?
        .unwrap_or(AnnealConfig::default().iterations);

    // Declarative form of the old hand-rolled comparison: Algorithm 1, the
    // reduced oracle DP, and the annealer over one shared engine.
    let mut tuners = compare_panel(None)?;
    let cmp = request.compare(&mut tuners).map_err(|e| e.to_string())?;
    print!("{}", cmp.render(&format!(
        "Search-time comparison — {} on {} (paper Section V, annealer budget \
         {iterations} moves)", model.name, request.target())));
    // Algorithm 1's wall time here includes costing its schedule through
    // the (cold) engine, so this ratio understates the pure O(n)-pass gap
    // the paper quotes; name what is actually measured. Latencies compare
    // per sample so the line stays meaningful when --batch lets the
    // backends land on different batch sizes.
    let o = &cmp.outcomes;
    println!("oracle search costs {:.0}x the Algorithm 1 tuner's wall time \
              (schedule + block costing) for a {:.1}% per-sample latency \
              win; the annealer's memoized moves computed only {:.1}% of \
              their block queries",
             (o[1].stats.wall_us.max(1)) as f64 / (o[0].stats.wall_us.max(1)) as f64,
             100.0 * (o[0].per_sample_ms() / o[1].per_sample_ms() - 1.0),
             100.0 * (1.0 - o[2].stats.hit_rate()));
    Ok(())
}

fn cmd_codegen(args: &Args) -> Result<(), String> {
    let model = load_model(args)?;
    let sim = parse_sim(args)?;
    let sched = optimizer::dlfusion_schedule(&model, &sim.spec);
    let out = args.flag_value("out").map_err(|e| e.to_string())?.unwrap_or("generated");
    std::fs::create_dir_all(out).map_err(|e| e.to_string())?;
    let cpp_path = format!("{out}/{}_inference.cpp", model.name);
    std::fs::write(&cpp_path, codegen::generate_cpp(&model, &sched))
        .map_err(|e| e.to_string())?;
    let h_path = format!("{out}/cnml_compat.h");
    std::fs::write(&h_path, codegen::generate_header()).map_err(|e| e.to_string())?;
    println!("wrote {cpp_path}");
    println!("wrote {h_path}");
    println!("schedule: {}", sched.summary());
    Ok(())
}

fn cmd_characterize(args: &Args) -> Result<(), String> {
    let sim = parse_sim(args)?;
    println!("running microbenchmark characterization on {} ...", sim.spec.name);
    let sweep = perfmodel::critical::single_core_sweep(&sim, 48);
    let crit = perfmodel::critical::fit_opcount_critical(&sweep, 0.9);
    println!("fitted OpCount_critical: {} (paper: 10^1.25 = {})",
             fmt_gops(crit), fmt_gops(10f64.powf(1.25)));

    let layers = crate::microbench::conv_sweep();
    let ch = perfmodel::features::characterize(&sim, &layers, 1);
    let mut t = Table::new(&["feature", "|corr with perf|"])
        .label_first()
        .with_title("PCA / correlation characterization (Section II.B)");
    for (name, assoc) in perfmodel::features::FEATURE_NAMES
        .iter()
        .zip(ch.perf_association)
    {
        t.row(vec![name.to_string(), format!("{assoc:.3}")]);
    }
    println!("{t}");

    let fitted = perfmodel::mp_select::MpModel::fit(&sim, &layers);
    println!(
        "fitted Eq.5 weights: alpha={:.3} beta={:.3} bias={:.3} (paper: 0.316 / 0.659)",
        fitted.alpha, fitted.beta, fitted.bias
    );
    Ok(())
}

fn cmd_space(args: &Args) -> Result<(), String> {
    let n: usize = args
        .positional(0)
        .ok_or("usage: space <num_layers>")?
        .parse()
        .map_err(|_| "n must be an integer")?;
    if n < 2 {
        return Err("n must be >= 2".into());
    }
    let s = optimizer::space::search_space(n, 32);
    println!("Eq. 4: Space({n}) = {s} joint (fusion, MP) combinations");
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<(), String> {
    let model = load_model(args)?;
    let sim = parse_sim(args)?;
    let strategy = match args.flag_usize("strategy").map_err(|e| e.to_string())? {
        None => Strategy::DlFusion,
        Some(i) => Strategy::from_index(i).ok_or(format!("strategy must be 1..=7, got {i}"))?,
    };
    let params = optimizer::AlgorithmParams::for_spec(&sim.spec);
    let mut engine = CostEngine::new(&sim, &model);
    let sched = optimizer::strategies::strategy_schedule_with(&mut engine, strategy, &params);
    let trace = crate::accel::trace::Trace::capture(&sim, &model, &sched);
    println!("{}", trace.render());
    println!("redundant compute: {:.1}% of total;  chip utilization: {:.1}%",
             100.0 * trace.redundancy_ratio(),
             100.0 * trace.utilization(&sim));
    Ok(())
}

/// Resolve the serving mix for serve-sim/serve-fleet: `--models` is a
/// comma-separated list of zoo names, DAG zoo variants (linearized, their
/// fusion-legal cut sets threaded into the allocator sweep), or `.dlm`
/// paths; `--model-file F` adds one more file-based entry. With neither
/// flag, the pinned `resnet18,alexnet` default.
fn serving_mix(args: &Args) -> Result<serving::ModelMix, String> {
    let mut entries: Vec<(Model, Option<Vec<usize>>)> = Vec::new();
    if let Some(list) = args.flag_value("models").map_err(|e| e.to_string())? {
        for name in list.split(',') {
            let name = name.trim();
            if name.is_empty() {
                return Err(format!("--models '{list}': empty model name"));
            }
            let w = if name.ends_with(".dlm") {
                workload_from_file(name)?
            } else if let Some(model) = zoo::by_name(name) {
                LoadedWorkload { model, cuts: None, dag: None }
            } else if let Some(d) = zoo::dag_by_name(name) {
                workload_from_dag(d)?
            } else {
                return Err(unknown_model(name));
            };
            entries.push((w.model, w.cuts));
        }
    }
    if let Some(path) = args.flag_value("model-file").map_err(|e| e.to_string())? {
        let w = workload_from_file(path)?;
        entries.push((w.model, w.cuts));
    }
    if entries.is_empty() {
        for name in ["resnet18", "alexnet"] {
            entries.push((zoo::by_name(name).expect("pinned zoo model"), None));
        }
    }
    // Duplicate names would alias per-model queues, report lanes, and
    // plan-cache keys.
    for i in 0..entries.len() {
        if entries[i + 1..].iter().any(|(m, _)| m.name == entries[i].0.name) {
            return Err(format!(
                "duplicate model '{}' in the serving mix", entries[i].0.name));
        }
    }
    Ok(serving::ModelMix::uniform_with_cuts(entries))
}

/// Parse `--policy`/`--max-batch`/`--batch-wait-ms` into the dispatch
/// policy (shared by serve-sim and serve-fleet).
fn parse_dispatch_policy(args: &Args) -> Result<serving::DispatchPolicy, String> {
    let mut policy = serving::DispatchPolicy::parse(
        args.flag_value("policy").map_err(|e| e.to_string())?.unwrap_or("fifo"))?;
    let max_batch_flag = args.flag_usize("max-batch").map_err(|e| e.to_string())?;
    let batch_wait_flag = args.flag_f64("batch-wait-ms").map_err(|e| e.to_string())?;
    if let serving::DispatchPolicy::Batch { .. } = policy {
        let max_batch = max_batch_flag.unwrap_or(serving::DEFAULT_MAX_BATCH);
        if max_batch == 0 {
            return Err("--max-batch must be at least 1".into());
        }
        let max_wait_ms = batch_wait_flag.unwrap_or(serving::DEFAULT_BATCH_WAIT_MS);
        if !(max_wait_ms >= 0.0) {
            return Err(format!(
                "--batch-wait-ms must be non-negative, got {max_wait_ms}"));
        }
        policy = serving::DispatchPolicy::Batch { max_batch, max_wait_ms };
    } else if max_batch_flag.is_some() || batch_wait_flag.is_some() {
        println!("note: --max-batch/--batch-wait-ms only apply to --policy batch");
    }
    Ok(policy)
}

/// Parse `--slo-ms` (positive when given).
fn parse_slo_ms(args: &Args) -> Result<Option<f64>, String> {
    let slo_ms = args.flag_f64("slo-ms").map_err(|e| e.to_string())?;
    if let Some(slo) = slo_ms {
        if !(slo > 0.0) {
            return Err(format!("--slo-ms must be positive, got {slo}"));
        }
    }
    Ok(slo_ms)
}

/// Parse `--allocator load|single` into the load-aware toggle.
fn parse_allocator(args: &Args) -> Result<bool, String> {
    match args.flag_value("allocator").map_err(|e| e.to_string())?.unwrap_or("load") {
        "load" | "load-aware" => Ok(true),
        "single" | "single-request" => Ok(false),
        other => Err(format!("--allocator expects 'load' or 'single', got '{other}'")),
    }
}

fn cmd_serve_sim(args: &Args) -> Result<(), String> {
    let sim = parse_sim(args)?;

    // ---- validate every flag before any tuning work ----
    let mix = serving_mix(args)?;
    let rate = args.flag_f64("rate").map_err(|e| e.to_string())?.unwrap_or(200.0);
    let requests = args
        .flag_usize("requests")
        .map_err(|e| e.to_string())?
        .unwrap_or(256);
    let seed = args.flag_usize("seed").map_err(|e| e.to_string())?.unwrap_or(7) as u64;
    let slo_ms = parse_slo_ms(args)?;
    let policy = parse_dispatch_policy(args)?;
    let concurrency = args.flag_usize("concurrency").map_err(|e| e.to_string())?;
    if concurrency == Some(0) {
        return Err("--concurrency must be at least 1".into());
    }
    // The sim-time trace replays the event log, so it cannot coexist with
    // the trace-free hot path; reject the combination before any work.
    if args.flag("trace-out").is_some() && args.flag_bool("no-events") {
        return Err("--trace-out replays the recorded event trace and cannot \
                    be combined with --no-events".into());
    }
    let arrivals = args.flag_value("arrivals").map_err(|e| e.to_string())?
        .unwrap_or("poisson");
    // --rate only drives the open-loop modes, so it is validated there and
    // merely reported as inert under closed-loop arrivals.
    let open_rate = || -> Result<f64, String> {
        if rate > 0.0 {
            Ok(rate)
        } else {
            Err(format!("--rate must be positive, got {rate}"))
        }
    };
    let process = match arrivals {
        "poisson" => serving::ArrivalProcess::OpenPoisson { rate_rps: open_rate()? },
        "bursty" => {
            serving::ArrivalProcess::Bursty { rate_rps: open_rate()?, burst: 8 }
        }
        "closed" | "closed-loop" => serving::ArrivalProcess::ClosedLoop {
            concurrency: concurrency.unwrap_or(2 * sim.spec.num_cores),
        },
        other => {
            return Err(format!(
                "--arrivals expects 'poisson', 'bursty' or 'closed', got '{other}'"))
        }
    };
    // Warn about knobs the chosen arrival mode ignores instead of silently
    // accepting a sweep over an inert flag.
    let closed = matches!(process, serving::ArrivalProcess::ClosedLoop { .. });
    if closed && args.flag("rate").is_some() {
        println!("note: --rate is ignored for closed-loop arrivals \
                  (population is fixed by --concurrency)");
    } else if !closed && args.flag("concurrency").is_some() {
        println!("note: --concurrency only applies to --arrivals closed");
    }
    let load_aware = parse_allocator(args)?;

    // ---- allocate, generate, simulate, report ----
    // Under the batch policy the allocator sweeps (mp_cap, batch) so the
    // services carry engine-predicted batched latencies; otherwise the
    // batch-1 sweep (identical to the pre-batch allocator).
    let max_batch = match policy {
        serving::DispatchPolicy::Batch { max_batch, .. } => max_batch,
        _ => 1,
    };
    let plan = serving::AllocationRequest::new(&sim, &mix)
        .slo_ms(slo_ms)
        .max_batch(max_batch)
        .plan()
        .map_err(|e| e.to_string())?;
    print!("{}", plan.render());
    if let serving::DispatchPolicy::Batch { .. } = policy {
        // The batched plan's load-aware points win at their chosen batch,
        // not necessarily at batch 1, so the headline is the batched
        // capacity (the batch-1 capacity of the same points is what the
        // pool sustains if batches never form).
        println!(
            "predicted capacity on {} cores: {:.1} req/s batched load-aware \
             ({:.1} req/s if no batches form) vs {:.1} req/s at the \
             single-request optima",
            sim.spec.num_cores,
            plan.predicted_batched_capacity_rps(sim.spec.num_cores),
            plan.predicted_capacity_rps(sim.spec.num_cores, true),
            plan.predicted_capacity_rps(sim.spec.num_cores, false));
    } else {
        println!(
            "predicted capacity on {} cores: {:.1} req/s load-aware vs {:.1} \
             req/s at the single-request optima",
            sim.spec.num_cores,
            plan.predicted_capacity_rps(sim.spec.num_cores, true),
            plan.predicted_capacity_rps(sim.spec.num_cores, false));
    }
    for m in plan.models.iter().filter(|m| m.diverged()) {
        println!(
            "note: {} serves at MP {} under load (single-request optimum MP {})",
            m.name, m.load_aware.cores, m.single.cores);
    }

    let trace = serving::generate_trace(&mix, process, requests, seed);
    let cfg = serving::ClusterConfig { num_cores: sim.spec.num_cores, policy };
    // --no-events skips recording the per-instant trace (the hot serving
    // path); the SLO report below is identical either way.
    let record_events = !args.flag_bool("no-events");
    let services = plan.services(load_aware);
    let result = serving::SimulationRun::new(&cfg, &services)
        .trace(&trace)
        .closed_loop(process.closed_loop_population())
        .record_events(record_events)
        .run()?;
    println!(
        "\nsimulated {} requests ({} events{}, policy {}, seed {seed}, {} allocation)",
        result.completed.len(), result.events_processed,
        if record_events { "" } else { ", trace off" }, policy.name(),
        if load_aware { "load-aware" } else { "single-request" });
    let report = serving::SloReport::from_sim(&result, slo_ms);
    print!("{}", report.render());

    // Observability exports (rust/docs/DESIGN.md §14): everything here is
    // event-clock state — pure sim time, bit-identical across reruns and
    // thread counts — so the snapshot's wall section stays empty and the
    // trace rides the deterministic clock.
    let mut reg = MetricsRegistry::new();
    report.export_metrics(&mut reg);
    write_metrics_out(args, &reg)?;
    if args.flag("trace-out").is_some() {
        write_trace_out(args, &serving::sim_trace(&result, &services, "serve-sim"))?;
    }
    Ok(())
}

fn cmd_serve_fleet(args: &Args) -> Result<(), String> {
    // ---- validate every flag before any tuning work ----
    let fleet = serving::Fleet::parse(
        args.flag_value("fleet").map_err(|e| e.to_string())?.unwrap_or("mlu100"))?;
    let route = serving::RoutePolicy::parse(
        args.flag_value("route").map_err(|e| e.to_string())?
            .unwrap_or("least-loaded"))?;
    let queue_cap = args.flag_usize("queue-cap").map_err(|e| e.to_string())?;
    if queue_cap == Some(0) {
        return Err("--queue-cap must be at least 1".into());
    }
    let mix = serving_mix(args)?;
    let rate = args.flag_f64("rate").map_err(|e| e.to_string())?.unwrap_or(200.0);
    if !(rate > 0.0) {
        return Err(format!("--rate must be positive, got {rate}"));
    }
    let requests = args
        .flag_usize("requests")
        .map_err(|e| e.to_string())?
        .unwrap_or(256);
    let seed = args.flag_usize("seed").map_err(|e| e.to_string())?.unwrap_or(7) as u64;
    let slo_ms = parse_slo_ms(args)?;
    let policy = parse_dispatch_policy(args)?;
    let load_aware = parse_allocator(args)?;
    if args.flag("trace-out").is_some() && args.flag_bool("no-events") {
        return Err("--trace-out replays the recorded event trace and cannot \
                    be combined with --no-events".into());
    }
    let process = match args.flag_value("arrivals").map_err(|e| e.to_string())?
        .unwrap_or("poisson")
    {
        "poisson" => serving::ArrivalProcess::OpenPoisson { rate_rps: rate },
        "bursty" => serving::ArrivalProcess::Bursty { rate_rps: rate, burst: 8 },
        "closed" | "closed-loop" => {
            return Err("serve-fleet is open-loop only (--arrivals poisson or \
                        bursty); a fleet has no single concurrency gate".into())
        }
        other => {
            return Err(format!(
                "--arrivals expects 'poisson' or 'bursty', got '{other}'"))
        }
    };

    // ---- plan (through the fleet-wide tuned-plan cache), generate, run ----
    let max_batch = match policy {
        serving::DispatchPolicy::Batch { max_batch, .. } => max_batch,
        _ => 1,
    };
    let mut cache = serving::PlanCache::new();
    let plan =
        serving::plan_fleet(&fleet, &mix, slo_ms, max_batch, load_aware,
                            &mut cache)
            .map_err(|e| e.to_string())?;
    print!("{}", plan.render(load_aware));
    println!("predicted fleet capacity on {} cores: {:.1} req/s",
             plan.total_cores(), plan.predicted_capacity_rps(load_aware));

    let trace = serving::generate_trace(&mix, process, requests, seed);
    let record_events = !args.flag_bool("no-events");
    let router = serving::RouterConfig::new(route).queue_cap(queue_cap);
    let result = serving::FleetRun::new(&plan, router)
        .policy(policy)
        .trace(&trace)
        .record_events(record_events)
        .run()?;
    println!("\nsimulated {} requests on {} chips ({} completed, {} shed, \
              routing {}, policy {}, seed {seed})",
             result.offered(), plan.chips.len(), result.completed(),
             result.shed.len(), route.name(), policy.name());
    let report = serving::FleetReport::from_run(&result, &plan, slo_ms);
    print!("{}", report.render());

    // Observability exports: all sim-domain (deterministic), with per-chip
    // gauges and — when events were recorded — the per-chip trace lanes.
    let mut reg = MetricsRegistry::new();
    report.export_metrics(&mut reg);
    write_metrics_out(args, &reg)?;
    if args.flag("trace-out").is_some() {
        write_trace_out(args, &serving::fleet_trace(&result, &plan, "serve-fleet"))?;
    }
    Ok(())
}

/// The perf-smoke metric sweep (CI's `perf-smoke` job): every number is a
/// *simulated* quantity — tuned latencies and event-clock serving rates —
/// so the output is a pure function of the code, reproducible on any
/// machine, and safe to diff across commits. No wall-clock time is
/// measured or gated.
fn perf_smoke_metrics(sim: &Simulator) -> Result<Vec<(String, f64)>, String> {
    let mut metrics: Vec<(String, f64)> = Vec::new();

    // Tuned single-inference latencies, heuristic vs oracle.
    for model in [zoo::resnet50(), zoo::vgg19()] {
        let request = tuner::TuningRequest::new(sim, &model);
        let mut cx = request.context();
        let a1 = tuner::Algorithm1.tune(&mut cx).map_err(|e| e.to_string())?;
        let dp = tuner::OracleDp::reduced().tune(&mut cx).map_err(|e| e.to_string())?;
        metrics.push((format!("{}_algorithm1_ms", model.name), a1.predicted_ms));
        metrics.push((format!("{}_oracle_ms", model.name), dp.predicted_ms));
    }

    // Serving throughput/goodput on the pinned light mix.
    let mix = serving::ModelMix::uniform(zoo::by_names("resnet18,alexnet")?);
    let plan = serving::AllocationRequest::new(sim, &mix)
        .slo_ms(Some(50.0))
        .plan()
        .map_err(|e| e.to_string())?;
    let trace = serving::generate_trace(
        &mix, serving::ArrivalProcess::OpenPoisson { rate_rps: 400.0 }, 256, 7);
    let cfg = serving::ClusterConfig { num_cores: sim.spec.num_cores,
                                       policy: serving::DispatchPolicy::Fifo };
    let result = serving::SimulationRun::new(&cfg, &plan.services(true))
        .trace(&trace)
        .run()?;
    let rep = serving::SloReport::from_sim(&result, Some(50.0));
    metrics.push(("serving_fifo_throughput_rps".into(), rep.throughput_rps));
    metrics.push(("serving_fifo_goodput_rps".into(), rep.goodput_rps));

    // Dynamic batching vs FIFO goodput on the heavy mix, under overload at
    // twice the batch-1 capacity and an SLO generous to both policies.
    let mix = serving::ModelMix::uniform(zoo::by_names("vgg19,resnet18")?);
    let max_batch = serving::DEFAULT_MAX_BATCH;
    let plan = serving::AllocationRequest::new(sim, &mix)
        .max_batch(max_batch)
        .plan()
        .map_err(|e| e.to_string())?;
    let services = plan.services(true);
    let rate = 2.0 * plan.predicted_capacity_rps(sim.spec.num_cores, true);
    let slo = 3.0 * services
        .iter()
        .map(|s| s.service_at(max_batch))
        .fold(0.0, f64::max);
    let trace = serving::generate_trace(
        &mix, serving::ArrivalProcess::OpenPoisson { rate_rps: rate }, 400, 11);
    for (label, policy) in [
        ("fifo", serving::DispatchPolicy::Fifo),
        ("batch", serving::DispatchPolicy::Batch {
            max_batch,
            max_wait_ms: serving::DEFAULT_BATCH_WAIT_MS,
        }),
    ] {
        let cfg = serving::ClusterConfig { num_cores: sim.spec.num_cores, policy };
        let result = serving::SimulationRun::new(&cfg, &services)
            .trace(&trace)
            .run()?;
        let rep = serving::SloReport::from_sim(&result, Some(slo));
        metrics.push((format!("batching_{label}_goodput_rps"), rep.goodput_rps));
    }

    // Cross-target tuned latencies (rust/docs/DESIGN.md §11): the same
    // model tuned for the default chip and the edge-class point, so CI
    // tracks drift in the hardware-sensitivity surface too — a regression
    // that only shows up off the default target still moves a metric.
    for target in [Target::mlu100(), Target::edge4()] {
        let target_sim = Simulator::new(target);
        let model = zoo::resnet18();
        let request = tuner::TuningRequest::new(&target_sim, &model);
        let mut cx = request.context();
        let a1 = tuner::Algorithm1.tune(&mut cx).map_err(|e| e.to_string())?;
        let dp = tuner::OracleDp::reduced().tune(&mut cx).map_err(|e| e.to_string())?;
        metrics.push((format!("{}_{}_algorithm1_ms", target_sim.target(), model.name),
                      a1.predicted_ms));
        metrics.push((format!("{}_{}_oracle_ms", target_sim.target(), model.name),
                      dp.predicted_ms));
    }

    // Learned-cost-model quality and active-tuner pruning (rust/docs/
    // DESIGN.md §16): the holdout MAPE of the default resnet18 fit and the
    // fraction of the reference sweep the active tuner avoided. Both are
    // pure functions of the code, so they ride the exact-match gate like
    // every other simulated metric.
    {
        let model = zoo::resnet18();
        let engine = CostEngine::new(sim, &model);
        let samples =
            learn::collect_samples(&engine, &sim.spec.reduced_mp_set(), &[1]);
        let fitted = learn::LearnedCostModel::fit(
            sim.target(), &samples, &learn::FitConfig::default())?;
        metrics.push(("learned_resnet18_mape".into(), fitted.report.mape_holdout));

        let request = tuner::TuningRequest::new(sim, &model);
        let outcome = request
            .run(&mut learn::ActiveTuner::new())
            .map_err(|e| e.to_string())?;
        let full_space = samples.len().max(1) as f64;
        metrics.push(("active_evals_saved_ratio".into(),
                      outcome.stats.evals_saved as f64 / full_space));
    }
    Ok(metrics)
}

/// The wall-clock section of the perf smoke (rust/docs/DESIGN.md §12):
/// machine-dependent throughput numbers, kept in a separate JSON object so
/// the exact-match gate over the simulated metrics never sees them.
///
/// - `tuning_throughput_evals_per_s`: block evaluations per second of a
///   sequential oracle sweep over a pinned model x target grid;
/// - `parallel_speedup_x`: wall time of that sweep at 1 thread over the
///   same sweep fanned across `threads` workers (results are checked
///   bit-identical — the speedup is never bought with a different answer);
/// - `serve_events_per_s`: event-loop rate of a trace-free serving run.
fn perf_smoke_wall_metrics(sim: &Simulator, threads: usize)
                           -> Result<Vec<(String, f64)>, String> {
    use std::time::Instant;

    let models: Vec<Model> = ["resnet18", "alexnet", "mobilenet"]
        .iter()
        .map(|name| zoo::by_name(name).expect("pinned zoo model"))
        .collect();
    let targets = [Target::mlu100(), Target::edge4(), Target::hbm32()];
    let jobs: Vec<tuner::SweepJob<'_>> = models
        .iter()
        .flat_map(|m| {
            targets.iter().map(move |t| tuner::SweepJob::new(m, t.clone(), "oracle"))
        })
        .collect();
    let t0 = Instant::now();
    let seq = tuner::run_sweep(&jobs, 1);
    let seq_s = t0.elapsed().as_secs_f64().max(1e-9);
    let t1 = Instant::now();
    let par = tuner::run_sweep(&jobs, threads);
    let par_s = t1.elapsed().as_secs_f64().max(1e-9);
    let mut evals: u64 = 0;
    for (s, p) in seq.iter().zip(&par) {
        let s = s.result.as_ref().map_err(|e| e.to_string())?;
        let p = p.result.as_ref().map_err(|e| e.to_string())?;
        if s.schedule != p.schedule || s.predicted_ms != p.predicted_ms {
            return Err(format!(
                "parallel sweep diverged from sequential on {} / {}",
                s.tuner, p.tuner));
        }
        evals += s.stats.evaluations;
    }
    let mut wall = vec![
        ("tuning_throughput_evals_per_s".to_string(), evals as f64 / seq_s),
        ("parallel_speedup_x".to_string(), seq_s / par_s),
    ];

    // Trace-free event loop on a long pinned trace.
    let mix = serving::ModelMix::uniform(zoo::by_names("resnet18,alexnet")?);
    let plan = serving::AllocationRequest::new(sim, &mix)
        .slo_ms(Some(50.0))
        .plan()
        .map_err(|e| e.to_string())?;
    let trace = serving::generate_trace(
        &mix, serving::ArrivalProcess::OpenPoisson { rate_rps: 800.0 }, 20_000, 7);
    let cfg = serving::ClusterConfig { num_cores: sim.spec.num_cores,
                                       policy: serving::DispatchPolicy::Fifo };
    let services = plan.services(true);
    let t2 = Instant::now();
    let result = serving::SimulationRun::new(&cfg, &services)
        .trace(&trace)
        .record_events(false)
        .run()?;
    let serve_s = t2.elapsed().as_secs_f64().max(1e-9);
    wall.push(("serve_events_per_s".to_string(),
               result.events_processed as f64 / serve_s));
    Ok(wall)
}

fn cmd_perf_smoke(args: &Args) -> Result<(), String> {
    let out_path = args.flag_value("out").map_err(|e| e.to_string())?
        .unwrap_or("BENCH_ci.json");
    let baseline_path = args.flag_value("baseline").map_err(|e| e.to_string())?
        .unwrap_or("ci/perf_baseline.json");
    let sim = parse_sim(args)?;
    if sim.target() != "mlu100" {
        // The main-suite keys (resnet50_algorithm1_ms, …) carry mlu100
        // semantics in the checked-in baseline, so recording another
        // target's numbers under them would poison every later CI diff.
        if args.flag_bool("write-baseline") {
            return Err(format!(
                "--write-baseline records the mlu100 baseline; rerun without \
                 '--target {}' (its main-suite keys would overwrite the \
                 mlu100 numbers CI diffs against)", sim.target()));
        }
        println!("note: main-suite metrics run on --target {} (the checked-in \
                  baseline records the mlu100 default)", sim.target());
    }
    let threads = parse_threads(args, 4)?;
    let metrics = perf_smoke_metrics(&sim)?;
    let wall = perf_smoke_wall_metrics(&sim, threads)?;

    // The smoke document renders through the MetricsRegistry snapshot path
    // (rust/docs/DESIGN.md §14.2): the simulated suite lands in the
    // deterministic domain, the wall-clock suite in the wall domain, and
    // `domain_json` prints gauges as plain numbers — byte-compatible with
    // the checked-in schema-2 baseline's key set.
    let mut reg = MetricsRegistry::new();
    for (k, v) in &metrics {
        reg.set_gauge(Domain::Sim, k, *v);
    }
    for (k, v) in &wall {
        reg.set_gauge(Domain::Wall, k, *v);
    }
    let doc = Json::obj(vec![
        ("schema", Json::Num(2.0)),
        ("metrics", reg.domain_json(Domain::Sim)),
        ("wall_metrics", reg.domain_json(Domain::Wall)),
    ]);
    let write = |path: &str| -> Result<(), String> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).map_err(|e| format!("{path}: {e}"))?;
            }
        }
        std::fs::write(path, doc.to_pretty()).map_err(|e| format!("{path}: {e}"))
    };
    write(out_path)?;
    println!("wrote {out_path} ({} simulated metrics + {} wall-clock, \
              {threads}-thread sweep)",
             metrics.len(), wall.len());
    if args.flag_bool("write-baseline") {
        write(baseline_path)?;
        println!("wrote baseline {baseline_path}");
        return Ok(());
    }

    // The speedup floor is absolute, not a baseline diff, so it gates even
    // in bootstrap mode — but only where it is meaningful: on a box with
    // >= 4 cores and a >= 4-thread run (a 1-core runner can't speed up).
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut failures: Vec<String> = Vec::new();
    if cores >= 4 && threads >= 4 {
        let speedup = wall.iter().find(|(k, _)| k == "parallel_speedup_x")
            .map(|(_, v)| *v).unwrap_or(0.0);
        if speedup < 2.0 {
            failures.push(format!(
                "parallel_speedup_x = {speedup:.2} < 2.0 on a {cores}-core \
                 machine at --threads {threads}"));
        }
    } else {
        println!("note: {cores} core(s) visible at --threads {threads}; \
                  the 2.0x parallel-speedup floor is not enforced here");
    }

    // Gating diff (rust/docs/DESIGN.md §12). Simulated metrics are pure
    // functions of the code, so any recorded baseline value must match
    // EXACTLY — drift means the predicted-performance surface changed and
    // the baseline must be refreshed deliberately. Wall-clock metrics vary
    // by machine; a recorded value only fails when the current run is worse
    // than a quarter of it (the speedup ratio is floor-gated above
    // instead). Unrecorded (null/missing) entries are advisory: that is the
    // bootstrap path for a fresh baseline.
    let text = match std::fs::read_to_string(baseline_path) {
        Ok(text) => text,
        Err(_) => {
            println!("no baseline at {baseline_path}; rerun with \
                      --write-baseline (or copy {out_path} there) to start \
                      gating drift");
            return if failures.is_empty() {
                Ok(())
            } else {
                Err(failures.join("; "))
            };
        }
    };
    let base = Json::parse(&text).map_err(|e| format!("{baseline_path}: {e}"))?;
    let mut t = Table::new(&["metric", "current", "baseline", "verdict"])
        .label_first()
        .with_title("perf smoke vs baseline (gating)");
    let mut unrecorded = 0usize;
    for (name, value) in &metrics {
        let (base_text, verdict) = match base.get("metrics").get(name).as_f64() {
            None => {
                unrecorded += 1;
                ("(unrecorded)".to_string(), "bootstrap".to_string())
            }
            Some(b) if b == *value => (format!("{b:.4}"), "ok".to_string()),
            Some(b) => {
                let drift = 100.0 * (value / b - 1.0);
                failures.push(format!(
                    "{name} = {value} != baseline {b} ({drift:+.2}%)"));
                (format!("{b:.4}"), format!("FAIL {drift:+.2}%"))
            }
        };
        t.row(vec![name.clone(), format!("{value:.4}"), base_text, verdict]);
    }
    for (name, value) in &wall {
        let (base_text, verdict) = match base.get("wall_metrics").get(name).as_f64() {
            None => {
                unrecorded += 1;
                ("(unrecorded)".to_string(), "bootstrap".to_string())
            }
            Some(b) if name == "parallel_speedup_x" => {
                // Ratio of two same-machine walls: floor-gated above, the
                // baseline value is informational.
                (format!("{b:.4}"), "ok (floor-gated)".to_string())
            }
            Some(b) if *value < b / 4.0 => {
                failures.push(format!(
                    "{name} = {value:.1} is below a quarter of the baseline \
                     {b:.1} (machine-dependent band)"));
                (format!("{b:.4}"), "FAIL <x0.25".to_string())
            }
            Some(b) => (format!("{b:.4}"), "ok".to_string()),
        };
        t.row(vec![name.clone(), format!("{value:.4}"), base_text, verdict]);
    }
    println!("{t}");
    if unrecorded > 0 {
        println!("{unrecorded} metric(s) have no recorded baseline \
                  (advisory until ci/perf_baseline.json is populated with \
                  --write-baseline)");
    }
    if failures.is_empty() {
        println!("all recorded metrics within gate");
        Ok(())
    } else {
        Err(format!("perf gate failed: {}", failures.join("; ")))
    }
}

fn cmd_run(args: &Args) -> Result<(), String> {
    let requests = args
        .flag_usize("requests")
        .map_err(|e| e.to_string())?
        .unwrap_or(32);
    let verify = args.flag_bool("verify");
    let model = zoo::mini_cnn();
    let sim = parse_sim(args)?;
    // The serving path runs through the unified tuner API: one request, one
    // shared cost engine for both the schedule and the plan annotations.
    let request = tuner::TuningRequest::new(&sim, &model);
    let mut cx = request.context();
    let outcome = tuner::Algorithm1.tune(&mut cx).map_err(|e| e.to_string())?;
    let sched = outcome.schedule.clone();
    println!("model {} schedule {} (tuner {})",
             model.name, sched.summary(), outcome.tuner);

    let mut rt = Runtime::open_default().map_err(|e| e.to_string())?;
    println!("PJRT platform: {}", rt.platform());

    let eq = equivalence::check_fused_vs_unfused(&mut rt, 42).map_err(|e| e.to_string())?;
    for c in &eq.checks {
        println!(
            "  equivalence {}: max|diff| = {:.2e} [{}]",
            c.artifact, c.max_abs_diff,
            if c.passed { "ok" } else { "FAIL" }
        );
    }
    if !eq.all_passed() {
        return Err("fused-vs-unfused equivalence failed".into());
    }

    let mut ex_plan = plan::build_plan(&model, &sched, rt.manifest())?;
    plan::annotate_with_costs(&mut ex_plan, cx.engine_mut());
    let mut engine =
        coordinator::Engine::new(rt, &model, ex_plan, 7).map_err(|e| e.to_string())?;
    let cfg = driver::DriverConfig { requests, verify_each: verify, ..Default::default() };
    let tuned = driver::serve_tuned(&mut engine, &cfg, &outcome).map_err(|e| e.to_string())?;
    let report = &tuned.report;
    println!("served {} requests: {}", requests, report.latency.report());
    println!("throughput: {:.1} inferences/s (PJRT CPU wall-clock)", report.fps());
    // Whole-schedule prediction (per-step annotations drop conv-free layers
    // and re-charge per-launch overheads, so their sum is not the total).
    println!("simulator-predicted {} latency: {} per inference \
              (PJRT CPU measures numerics, not accelerator speed)",
             sim.target(), fmt_ms(tuned.predicted_ms));
    if verify {
        println!(
            "per-request equivalence: {} ok / {} failures",
            report.counters.get("equivalence_ok"),
            report.counters.get("equivalence_failures")
        );
    }
    Ok(())
}
