//! CLI command implementations.

use super::args::Args;
use crate::accel::Simulator;
use crate::codegen;
use crate::coordinator::{self, driver, equivalence, plan};
use crate::cost::CostEngine;
use crate::graph::{format as dlm, Model};
use crate::optimizer::{self, Strategy};
use crate::perfmodel;
use crate::runtime::Runtime;
use crate::search;
use crate::util::units::{fmt_gops, fmt_ms};
use crate::util::Table;
use crate::zoo;

pub const HELP: &str = "\
dlfusion — auto-tuning layer-fusion compiler (DLFusion reproduction)

USAGE:
    dlfusion <command> [args] [--flags]

COMMANDS:
    zoo [--spec]                 list built-in models (Table II) / hardware spec
    optimize <model|file.dlm>    run Algorithm 1, print the schedule
        [--strategy 1..7] [--critical GOPS]
    simulate <model|file.dlm>    simulate all seven strategies (Fig. 10 row)
    search <model|file.dlm>      compare search costs: Algorithm 1 vs oracle
        [--iterations N]         DP vs simulated annealing (cache + wall time)
    codegen <model|file.dlm>     emit CNML-style C++ [--out DIR]
    characterize                 re-derive OpCount_critical / Eq.5 weights
    space <n>                    evaluate Eq. 4 search-space size
    trace <model|file.dlm>       per-block timeline + utilization breakdown
        [--strategy 1..7]
    run [--requests N] [--verify] end-to-end PJRT inference on mini_cnn
    help                         this text

MODELS: resnet18 resnet50 vgg19 alexnet mobilenet mini_cnn (or a .dlm file)
";

/// Execute a parsed command line; returns the process exit code.
pub fn run(args: &Args) -> i32 {
    let result = match args.command.as_str() {
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        "zoo" => cmd_zoo(args),
        "optimize" => cmd_optimize(args),
        "simulate" => cmd_simulate(args),
        "search" => cmd_search(args),
        "codegen" => cmd_codegen(args),
        "characterize" => cmd_characterize(),
        "space" => cmd_space(args),
        "trace" => cmd_trace(args),
        "run" => cmd_run(args),
        other => Err(format!("unknown command '{other}' (try 'help')")),
    };
    match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn load_model(args: &Args) -> Result<Model, String> {
    let name = args
        .positional(0)
        .ok_or("missing model name or .dlm path")?;
    if name.ends_with(".dlm") {
        let text = std::fs::read_to_string(name).map_err(|e| format!("{name}: {e}"))?;
        dlm::from_dlm(&text)
    } else {
        zoo::by_name(name).ok_or_else(|| {
            format!("unknown model '{name}' (known: {})", zoo::MODEL_NAMES.join(", "))
        })
    }
}

fn cmd_zoo(args: &Args) -> Result<(), String> {
    if args.flag_bool("spec") {
        let s = crate::accel::AcceleratorSpec::mlu100();
        let mut t = Table::new(&["item", "value"]).label_first()
            .with_title("Table I — hardware specification (simulated)");
        t.row(vec!["name".into(), s.name.clone()]);
        t.row(vec!["cores".into(), s.num_cores.to_string()]);
        t.row(vec!["peak FP16".into(),
                   format!("{:.0} TFLOPS", s.peak_gflops() / 1000.0)]);
        t.row(vec!["memory BW".into(), format!("{} GB/s", s.mem_bw_gbps)]);
        t.row(vec!["memory".into(), format!("{:.0} GiB", s.mem_bytes / (1u64 << 30) as f64)]);
        t.row(vec!["OpCount_critical".into(), fmt_gops(s.opcount_critical())]);
        println!("{t}");
        return Ok(());
    }
    let mut t = Table::new(&["network", "total conv op", "avg op", "#conv", "#layers"])
        .label_first()
        .with_title("Table II — evaluated networks");
    for m in zoo::all_models() {
        let s = m.stats();
        t.row(vec![
            m.name.clone(),
            fmt_gops(s.total_conv_gops),
            fmt_gops(s.avg_conv_gops),
            s.num_conv.to_string(),
            s.num_layers.to_string(),
        ]);
    }
    println!("{t}");
    Ok(())
}

fn cmd_optimize(args: &Args) -> Result<(), String> {
    let model = load_model(args)?;
    let sim = Simulator::mlu100();
    let strategy = match args.flag_usize("strategy").map_err(|e| e.to_string())? {
        None => Strategy::DlFusion,
        Some(i) => Strategy::from_index(i).ok_or(format!("strategy must be 1..=7, got {i}"))?,
    };
    let mut params = optimizer::AlgorithmParams::for_spec(&sim.spec);
    if let Some(c) = args.flag_f64("critical").map_err(|e| e.to_string())? {
        params.opcount_critical = c;
    }
    let mut engine = CostEngine::new(&sim, &model);
    let sched = optimizer::strategies::strategy_schedule_with(&mut engine, strategy, &params);
    let report = engine.run_schedule(&sched);
    println!("model:     {}", model.name);
    println!("strategy:  {} ({})", strategy.index(), strategy.name());
    println!("schedule:  {}", sched.summary());
    println!("blocks:    {}", sched.num_blocks());
    println!("latency:   {}", fmt_ms(report.total_ms));
    println!("FPS:       {:.1}", report.fps());
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<(), String> {
    let model = load_model(args)?;
    let sim = Simulator::mlu100();
    let mut engine = CostEngine::new(&sim, &model);
    let mut t = Table::new(&["#", "strategy", "blocks", "latency", "FPS", "speedup"])
        .label_first()
        .align(1, crate::util::table::Align::Left)
        .with_title(&format!("Fig. 10 row — {}", model.name));
    let mut base_fps = None;
    for st in Strategy::ALL {
        let (sched, rep) = optimizer::run_strategy_with(&mut engine, st);
        let fps = rep.fps();
        let base = *base_fps.get_or_insert(fps);
        t.row(vec![
            st.index().to_string(),
            st.name().to_string(),
            sched.num_blocks().to_string(),
            fmt_ms(rep.total_ms),
            format!("{fps:.1}"),
            format!("{:.2}x", fps / base),
        ]);
    }
    println!("{t}");
    let st = engine.stats();
    println!("cost engine: {} block queries, {} computed ({} cached, \
              {:.1}x fewer computations than unmemoized)",
             st.queries(), st.misses, st.hits, st.block_eval_reduction());
    Ok(())
}

fn cmd_search(args: &Args) -> Result<(), String> {
    let model = load_model(args)?;
    let sim = Simulator::mlu100();
    let iterations = args
        .flag_usize("iterations")
        .map_err(|e| e.to_string())?
        .unwrap_or(search::AnnealConfig::default().iterations);

    // DLFusion's O(n) pass (no simulator evaluations at all).
    let t0 = std::time::Instant::now();
    let dlf = optimizer::dlfusion_schedule(&model, &sim.spec);
    let dlf_us = t0.elapsed().as_micros() as u64;
    let mut engine = CostEngine::new(&sim, &model);
    let dlf_ms = engine.run_schedule(&dlf).total_ms;

    // The reduced brute-force oracle (strategy 7) through the same engine.
    let (oracle, ostats) = search::oracle_schedule_with(&mut engine);
    let oracle_ms = engine.run_schedule(&oracle).total_ms;

    // Simulated annealing over the unreduced space, same engine.
    engine.reset_stats();
    let t0 = std::time::Instant::now();
    let cfg = search::AnnealConfig { iterations, ..Default::default() };
    let (_, anneal_ms) = search::annealing::anneal_with(&mut engine, &cfg, None);
    let anneal_us = t0.elapsed().as_micros() as u64;
    let astats = engine.stats();

    let mut t = Table::new(&["search", "latency", "block evals", "cache hits",
                             "computed", "wall"])
        .label_first()
        .with_title(&format!("Search-time comparison — {} (paper Section V)",
                             model.name));
    t.row(vec!["DLFusion Algorithm 1".into(), fmt_ms(dlf_ms),
               "0".into(), "-".into(), "-".into(), format!("{dlf_us} us")]);
    t.row(vec!["oracle DP (reduced)".into(), fmt_ms(oracle_ms),
               ostats.evaluations.to_string(), ostats.cache_hits.to_string(),
               ostats.cache_misses.to_string(),
               format!("{} us", ostats.wall_us)]);
    t.row(vec![format!("annealing ({iterations} moves)"), fmt_ms(anneal_ms),
               astats.queries().to_string(), astats.hits.to_string(),
               astats.misses.to_string(), format!("{anneal_us} us")]);
    println!("{t}");
    println!("oracle search costs {:.0}x DLFusion's one-pass heuristic for a \
              {:.1}% latency win; the annealer's memoized moves computed only \
              {:.1}% of their block queries",
             (ostats.wall_us.max(1)) as f64 / (dlf_us.max(1)) as f64,
             100.0 * (dlf_ms / oracle_ms - 1.0),
             100.0 * (1.0 - astats.hit_rate()));
    Ok(())
}

fn cmd_codegen(args: &Args) -> Result<(), String> {
    let model = load_model(args)?;
    let sim = Simulator::mlu100();
    let sched = optimizer::dlfusion_schedule(&model, &sim.spec);
    let out = args.flag("out").unwrap_or("generated");
    std::fs::create_dir_all(out).map_err(|e| e.to_string())?;
    let cpp_path = format!("{out}/{}_inference.cpp", model.name);
    std::fs::write(&cpp_path, codegen::generate_cpp(&model, &sched))
        .map_err(|e| e.to_string())?;
    let h_path = format!("{out}/cnml_compat.h");
    std::fs::write(&h_path, codegen::generate_header()).map_err(|e| e.to_string())?;
    println!("wrote {cpp_path}");
    println!("wrote {h_path}");
    println!("schedule: {}", sched.summary());
    Ok(())
}

fn cmd_characterize() -> Result<(), String> {
    let sim = Simulator::mlu100();
    println!("running microbenchmark characterization on {} ...", sim.spec.name);
    let sweep = perfmodel::critical::single_core_sweep(&sim, 48);
    let crit = perfmodel::critical::fit_opcount_critical(&sweep, 0.9);
    println!("fitted OpCount_critical: {} (paper: 10^1.25 = {})",
             fmt_gops(crit), fmt_gops(10f64.powf(1.25)));

    let layers = crate::microbench::conv_sweep();
    let ch = perfmodel::features::characterize(&sim, &layers, 1);
    let mut t = Table::new(&["feature", "|corr with perf|"])
        .label_first()
        .with_title("PCA / correlation characterization (Section II.B)");
    for (name, assoc) in perfmodel::features::FEATURE_NAMES
        .iter()
        .zip(ch.perf_association)
    {
        t.row(vec![name.to_string(), format!("{assoc:.3}")]);
    }
    println!("{t}");

    let fitted = perfmodel::mp_select::MpModel::fit(&sim, &layers);
    println!(
        "fitted Eq.5 weights: alpha={:.3} beta={:.3} bias={:.3} (paper: 0.316 / 0.659)",
        fitted.alpha, fitted.beta, fitted.bias
    );
    Ok(())
}

fn cmd_space(args: &Args) -> Result<(), String> {
    let n: usize = args
        .positional(0)
        .ok_or("usage: space <num_layers>")?
        .parse()
        .map_err(|_| "n must be an integer")?;
    if n < 2 {
        return Err("n must be >= 2".into());
    }
    let s = optimizer::space::search_space(n, 32);
    println!("Eq. 4: Space({n}) = {s} joint (fusion, MP) combinations");
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<(), String> {
    let model = load_model(args)?;
    let sim = Simulator::mlu100();
    let strategy = match args.flag_usize("strategy").map_err(|e| e.to_string())? {
        None => Strategy::DlFusion,
        Some(i) => Strategy::from_index(i).ok_or(format!("strategy must be 1..=7, got {i}"))?,
    };
    let params = optimizer::AlgorithmParams::for_spec(&sim.spec);
    let sched = optimizer::strategies::strategy_schedule(&sim, &model, strategy, &params);
    let trace = crate::accel::trace::Trace::capture(&sim, &model, &sched);
    println!("{}", trace.render());
    println!("redundant compute: {:.1}% of total;  chip utilization: {:.1}%",
             100.0 * trace.redundancy_ratio(),
             100.0 * trace.utilization(&sim));
    Ok(())
}

fn cmd_run(args: &Args) -> Result<(), String> {
    let requests = args
        .flag_usize("requests")
        .map_err(|e| e.to_string())?
        .unwrap_or(32);
    let verify = args.flag_bool("verify");
    let model = zoo::mini_cnn();
    let sim = Simulator::mlu100();
    let sched = optimizer::dlfusion_schedule(&model, &sim.spec);
    println!("model {} schedule {}", model.name, sched.summary());

    let mut rt = Runtime::open_default().map_err(|e| e.to_string())?;
    println!("PJRT platform: {}", rt.platform());

    let eq = equivalence::check_fused_vs_unfused(&mut rt, 42).map_err(|e| e.to_string())?;
    for c in &eq.checks {
        println!(
            "  equivalence {}: max|diff| = {:.2e} [{}]",
            c.artifact, c.max_abs_diff,
            if c.passed { "ok" } else { "FAIL" }
        );
    }
    if !eq.all_passed() {
        return Err("fused-vs-unfused equivalence failed".into());
    }

    let mut ex_plan = plan::build_plan(&model, &sched, rt.manifest())?;
    let mut cost_engine = CostEngine::new(&sim, &model);
    plan::annotate_with_costs(&mut ex_plan, &mut cost_engine);
    // Whole-schedule prediction (per-step annotations drop conv-free layers
    // and re-charge per-launch overheads, so their sum is not the total).
    let predicted_ms = cost_engine.run_schedule(&sched).total_ms;
    let mut engine =
        coordinator::Engine::new(rt, &model, ex_plan, 7).map_err(|e| e.to_string())?;
    let cfg = driver::DriverConfig { requests, verify_each: verify, ..Default::default() };
    let report = driver::serve(&mut engine, &cfg).map_err(|e| e.to_string())?;
    println!("served {} requests: {}", requests, report.latency.report());
    println!("throughput: {:.1} inferences/s (PJRT CPU wall-clock)", report.fps());
    println!("simulator-predicted MLU100 latency: {} per inference \
              (PJRT CPU measures numerics, not MLU100 speed)",
             fmt_ms(predicted_ms));
    if verify {
        println!(
            "per-request equivalence: {} ok / {} failures",
            report.counters.get("equivalence_ok"),
            report.counters.get("equivalence_failures")
        );
    }
    Ok(())
}
