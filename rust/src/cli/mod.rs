//! Command-line interface (hand-rolled arg parsing — offline environment).

pub mod args;
pub mod commands;

pub use args::{Args, ParseError};
