//! The batched request loop: serve inferences through an [`Engine`] and
//! report wall-clock latency/throughput (the real-path counterpart of the
//! simulator's FPS numbers).

use std::time::Instant;

use super::executor::Engine;
use super::metrics::{Counters, LatencyRecorder};
use crate::runtime::RuntimeError;
use crate::tuner::TuningOutcome;

/// Request-loop configuration.
#[derive(Debug, Clone, Copy)]
pub struct DriverConfig {
    /// Total requests to serve (after warmup).
    pub requests: usize,
    /// Warmup inferences (excluded from stats).
    pub warmup: usize,
    /// RNG seed for request payloads.
    pub seed: u64,
    /// Also run the unfused path each request and verify equivalence.
    pub verify_each: bool,
}

impl Default for DriverConfig {
    fn default() -> Self {
        DriverConfig { requests: 64, warmup: 4, seed: 7, verify_each: false }
    }
}

/// Request-loop outcome.
#[derive(Debug, Clone)]
pub struct DriverReport {
    pub latency: LatencyRecorder,
    pub counters: Counters,
    pub wall_ms: f64,
}

impl DriverReport {
    /// Measured throughput (requests / wall-clock second); 0.0 for an empty
    /// or zero-duration run instead of NaN/inf.
    pub fn fps(&self) -> f64 {
        let requests = self.counters.get("requests") as f64;
        if requests == 0.0 || self.wall_ms <= 0.0 {
            return 0.0;
        }
        requests / (self.wall_ms / 1e3)
    }
}

/// A request-loop report paired with the tuner outcome that produced the
/// plan, so predicted-vs-measured reporting lives in one place.
#[derive(Debug, Clone)]
pub struct TunedDriverReport {
    /// Name of the tuner backend whose schedule is being served.
    pub tuner: String,
    /// Simulator-predicted per-inference latency of that schedule, ms.
    pub predicted_ms: f64,
    pub report: DriverReport,
}

impl TunedDriverReport {
    /// Mean measured wall-clock per request over the simulator prediction
    /// (PJRT CPU measures numerics, not MLU100 speed, so this is a sanity
    /// ratio, not an accuracy claim). 0.0 — never NaN/inf — when the run
    /// served no requests or the prediction is degenerate.
    pub fn measured_over_predicted(&self) -> f64 {
        let requests = self.report.counters.get("requests") as f64;
        if requests == 0.0 || self.predicted_ms <= 0.0 {
            return 0.0;
        }
        (self.report.wall_ms / requests) / self.predicted_ms
    }
}

/// Serve a request loop for a tuned schedule: [`serve`] plus the tuner's
/// prediction folded into the report (the unified-tuner-API path the CLI
/// `run` command and the e2e example drive).
///
/// This loop serves **one image per request**, so it expects a batch-1
/// outcome (the default tuning request): a batch-tuned outcome prices
/// whole invocations, and its per-sample number assumes weight/fill/launch
/// amortization that single-image serving never receives — re-price the
/// schedule at batch 1 (`CostEngine::schedule_cost_at(.., 1)`) before
/// serving it here.
pub fn serve_tuned(engine: &mut Engine, cfg: &DriverConfig,
                   outcome: &TuningOutcome) -> Result<TunedDriverReport, RuntimeError> {
    debug_assert_eq!(outcome.batch, 1,
                     "serve_tuned drives one-image requests; re-price the \
                      schedule at batch 1 first");
    let report = serve(engine, cfg)?;
    Ok(TunedDriverReport {
        tuner: outcome.tuner.clone(),
        predicted_ms: outcome.predicted_ms,
        report,
    })
}

/// Serve `cfg.requests` single-image requests through the engine.
pub fn serve(engine: &mut Engine, cfg: &DriverConfig) -> Result<DriverReport, RuntimeError> {
    let mut latency = LatencyRecorder::new();
    let mut counters = Counters::new();

    for w in 0..cfg.warmup {
        let x = engine.random_input(cfg.seed ^ (w as u64).wrapping_mul(0x9E37));
        engine.infer(x)?;
        counters.inc("warmup");
    }

    let wall0 = Instant::now();
    for r in 0..cfg.requests {
        let x = engine.random_input(cfg.seed.wrapping_add(r as u64));
        let t0 = Instant::now();
        let y = engine.infer(x.clone())?;
        latency.record(t0.elapsed().as_secs_f64() * 1e3);
        counters.inc("requests");
        counters.add("convs", engine.plan().num_convs() as u64);
        if cfg.verify_each {
            let y2 = engine.infer_unfused(x)?;
            if y.max_abs_diff(&y2) > super::equivalence::FUSION_TOL {
                counters.inc("equivalence_failures");
            } else {
                counters.inc("equivalence_ok");
            }
        }
        // Keep the output alive so nothing is optimized away.
        std::hint::black_box(&y);
    }
    let wall_ms = wall0.elapsed().as_secs_f64() * 1e3;
    Ok(DriverReport { latency, counters, wall_ms })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_sane() {
        let c = DriverConfig::default();
        assert!(c.requests > 0);
        assert!(!c.verify_each);
    }

    #[test]
    fn tuned_report_ratio_math() {
        let mut counters = Counters::new();
        counters.add("requests", 10);
        let tuned = TunedDriverReport {
            tuner: "algorithm1".into(),
            predicted_ms: 2.0,
            report: DriverReport {
                latency: LatencyRecorder::new(),
                counters,
                wall_ms: 40.0,
            },
        };
        // 40 ms / 10 requests = 4 ms measured vs 2 ms predicted.
        assert!((tuned.measured_over_predicted() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn report_fps_math() {
        let mut latency = LatencyRecorder::new();
        latency.record(1.0);
        let mut counters = Counters::new();
        counters.add("requests", 100);
        let r = DriverReport { latency, counters, wall_ms: 2000.0 };
        assert!((r.fps() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn fps_is_zero_not_nan_for_degenerate_runs() {
        // No requests served.
        let empty = DriverReport {
            latency: LatencyRecorder::new(),
            counters: Counters::new(),
            wall_ms: 100.0,
        };
        assert_eq!(empty.fps(), 0.0);
        // Zero wall-clock (e.g. a mocked run).
        let mut counters = Counters::new();
        counters.add("requests", 10);
        let instant = DriverReport {
            latency: LatencyRecorder::new(),
            counters,
            wall_ms: 0.0,
        };
        assert_eq!(instant.fps(), 0.0);
        assert!(instant.fps().is_finite());
    }

    #[test]
    fn measured_over_predicted_guards_zero_denominators() {
        let report = |requests: u64, wall_ms: f64| {
            let mut counters = Counters::new();
            counters.add("requests", requests);
            DriverReport { latency: LatencyRecorder::new(), counters, wall_ms }
        };
        // Zero requests: no mean per request exists.
        let t = TunedDriverReport {
            tuner: "algorithm1".into(),
            predicted_ms: 2.0,
            report: report(0, 40.0),
        };
        assert_eq!(t.measured_over_predicted(), 0.0);
        // Zero (or negative) prediction: ratio undefined.
        let t = TunedDriverReport {
            tuner: "algorithm1".into(),
            predicted_ms: 0.0,
            report: report(10, 40.0),
        };
        assert_eq!(t.measured_over_predicted(), 0.0);
        assert!(t.measured_over_predicted().is_finite());
        // Zero wall-clock is a 0.0 ratio, not a NaN.
        let t = TunedDriverReport {
            tuner: "algorithm1".into(),
            predicted_ms: 2.0,
            report: report(10, 0.0),
        };
        assert_eq!(t.measured_over_predicted(), 0.0);
    }
}
