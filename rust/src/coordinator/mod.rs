//! Layer-3 coordination: the end-to-end inference driver.
//!
//! This is where the three layers meet at run time: a model is optimized by
//! [`crate::optimizer`] (Algorithm 1), the resulting schedule is mapped onto
//! the AOT artifact catalog ([`plan`]), executed numerically through the
//! PJRT runtime ([`executor`]) with fused-vs-unfused equivalence checking
//! ([`equivalence`]), and driven under a batched request loop with metrics
//! ([`driver`]). Performance numbers come from the simulator; numerics from
//! PJRT — Python is never on this path.

pub mod plan;
pub mod executor;
pub mod equivalence;
pub mod metrics;
pub mod driver;

pub use driver::{serve_tuned, DriverConfig, DriverReport, TunedDriverReport};
pub use equivalence::EquivalenceReport;
pub use executor::Engine;
pub use plan::{annotate_with_costs, ExecutionPlan, PlanStep};
