//! Mapping an optimized schedule onto the AOT artifact catalog.
//!
//! A fusion block of `d` conv layers (with interleaved ReLUs) executes as
//! the fused artifact of depth `d` matching its (channels, spatial) shape;
//! blocks deeper than any available artifact split greedily into the largest
//! available depths. The plan is the compiled form the request loop runs —
//! the analogue of the generated CNML program, but executing through PJRT.

use crate::cost::CostEngine;
use crate::graph::{LayerKind, Model};
use crate::optimizer::schedule::Schedule;
use crate::runtime::manifest::Manifest;

/// One step: run `artifact` with the weights of conv layers
/// `conv_indices` (model layer indices, in order).
#[derive(Debug, Clone, PartialEq)]
pub struct PlanStep {
    pub artifact: String,
    pub conv_indices: Vec<usize>,
    /// The schedule block this step came from.
    pub block_index: usize,
    pub mp: usize,
    /// Simulator-predicted latency of this step's layer range at `mp`, ms
    /// (0.0 until [`annotate_with_costs`] runs).
    pub predicted_ms: f64,
}

/// A fully resolved execution plan.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecutionPlan {
    pub model_name: String,
    pub steps: Vec<PlanStep>,
}

impl ExecutionPlan {
    /// Total conv layers executed (must equal the model's conv count).
    pub fn num_convs(&self) -> usize {
        self.steps.iter().map(|s| s.conv_indices.len()).sum()
    }

    /// Number of fused (depth > 1) steps.
    pub fn num_fused_steps(&self) -> usize {
        self.steps.iter().filter(|s| s.conv_indices.len() > 1).count()
    }

    /// Sum of the steps' simulator-predicted latencies (0.0 until
    /// [`annotate_with_costs`] runs). A per-step breakdown aid, not the
    /// schedule's total: steps cover only conv-bearing ranges and each one
    /// is charged its own launch/sync overheads, so this differs from
    /// `CostEngine::run_schedule(..).total_ms` — use that for whole-model
    /// predictions.
    pub fn predicted_total_ms(&self) -> f64 {
        self.steps.iter().map(|s| s.predicted_ms).sum()
    }
}

/// Fill in each step's `predicted_ms` from the shared cost engine: the
/// simulator latency of the step's layer range (first through last conv it
/// executes) at the step's MP. This is what lets the driver report
/// predicted-vs-measured numbers per request loop (the paper's Table III
/// numbers come from the same engine the optimizer searched with).
pub fn annotate_with_costs(plan: &mut ExecutionPlan, engine: &mut CostEngine) {
    for step in &mut plan.steps {
        // `build_plan` never emits conv-less steps; a hand-built plan with
        // one just keeps its 0.0 placeholder instead of panicking.
        let (Some(&first), Some(&last)) =
            (step.conv_indices.first(), step.conv_indices.last())
        else {
            continue;
        };
        step.predicted_ms = engine.block_latency(first, last + 1, step.mp);
    }
}

/// Build an execution plan for `model` under `schedule` against the
/// artifact catalog in `manifest`.
///
/// Requirements (met by [`crate::zoo::mini_cnn`]-style models): every conv
/// in the model is 3x3/s1/SAME with constant spatial size, and the catalog
/// contains artifacts for its (channels, h, w) at depth 1 (deeper variants
/// are used opportunistically).
pub fn build_plan(model: &Model, schedule: &Schedule, manifest: &Manifest)
                  -> Result<ExecutionPlan, String> {
    schedule
        .validate(model.num_layers(), usize::MAX)
        .map_err(|e| format!("invalid schedule: {e}"))?;
    let mut steps = Vec::new();
    for (bi, block) in schedule.blocks.iter().enumerate() {
        // Conv layers inside this block, in order.
        let convs: Vec<usize> = (block.start..block.end)
            .filter(|&i| matches!(model.layers[i].kind, LayerKind::Conv(_)))
            .collect();
        if convs.is_empty() {
            continue; // pure relu/add blocks are no-ops on the PJRT path
        }
        let mut rest: &[usize] = &convs;
        while !rest.is_empty() {
            let (name, taken) = best_artifact(model, rest, manifest)
                .ok_or_else(|| {
                    let i = rest[0];
                    format!(
                        "no artifact matches conv '{}' (layer {i}) of '{}'",
                        model.layers[i].name, model.name
                    )
                })?;
            steps.push(PlanStep {
                artifact: name,
                conv_indices: rest[..taken].to_vec(),
                block_index: bi,
                mp: block.mp,
                predicted_ms: 0.0,
            });
            rest = &rest[taken..];
        }
    }
    if steps.is_empty() {
        return Err(format!("model '{}' produced an empty plan", model.name));
    }
    Ok(ExecutionPlan { model_name: model.name.clone(), steps })
}

/// Find the deepest artifact that matches a prefix of `convs` (channel
/// chain, spatial size, batch 1). Returns (artifact name, convs consumed).
fn best_artifact(model: &Model, convs: &[usize], manifest: &Manifest)
                 -> Option<(String, usize)> {
    let mut best: Option<(String, usize)> = None;
    for a in &manifest.artifacts {
        if a.batch != 1 || a.depth > convs.len() {
            continue;
        }
        // Check the channel chain + spatial extents of the prefix.
        let mut ok = true;
        for (d, &li) in convs[..a.depth].iter().enumerate() {
            let LayerKind::Conv(c) = &model.layers[li].kind else { ok = false; break };
            if c.h_in != a.height
                || c.w_in != a.width
                || c.c_in != a.channels[d]
                || c.c_out != a.channels[d + 1]
                || c.k != 3
                || c.stride != 1
                || c.groups != 1
            {
                ok = false;
                break;
            }
        }
        if !ok {
            continue;
        }
        if best.as_ref().map_or(true, |(_, depth)| a.depth > *depth) {
            best = Some((a.name.clone(), a.depth));
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::schedule::{Block, Schedule};
    use crate::runtime::manifest::Manifest;
    use crate::zoo;
    use std::path::Path;

    fn manifest() -> Option<Manifest> {
        // A present-but-corrupt manifest skips these tests rather than
        // panicking the whole suite.
        Manifest::load(&crate::runtime::artifact_dir()).ok()
    }

    #[test]
    fn plans_mini_cnn_single_block() {
        let Some(m) = manifest() else { return };
        let model = zoo::mini_cnn();
        let sched = Schedule::single_block(model.num_layers(), 8);
        let plan = build_plan(&model, &sched, &m).unwrap();
        assert_eq!(plan.num_convs(), 6);
        // 6 convs with max artifact depth 4 -> 2 steps (4 + 2).
        assert_eq!(plan.steps.len(), 2);
        assert_eq!(plan.steps[0].artifact, "b4_c8_h16");
        assert_eq!(plan.steps[1].artifact, "b2_c8_h16");
    }

    #[test]
    fn plans_layerwise_as_single_stages() {
        let Some(m) = manifest() else { return };
        let model = zoo::mini_cnn();
        let sched = Schedule::layerwise(model.num_layers(), 1);
        let plan = build_plan(&model, &sched, &m).unwrap();
        assert_eq!(plan.num_convs(), 6);
        assert_eq!(plan.num_fused_steps(), 0);
        assert!(plan.steps.iter().all(|s| s.conv_indices.len() == 1));
    }

    #[test]
    fn rejects_unmatched_model() {
        let Some(m) = manifest() else { return };
        let model = zoo::alexnet(); // 11x11 convs: no artifact
        let sched = Schedule::single_block(model.num_layers(), 8);
        let err = build_plan(&model, &sched, &m).unwrap_err();
        assert!(err.contains("no artifact"), "{err}");
    }

    #[test]
    fn parse_only_manifest_plan() {
        // Synthetic manifest (no files needed): depth-2 then depth-1 split.
        let text = r#"{
          "format_version": 1, "interchange": "hlo-text",
          "artifacts": [
            {"name": "a1", "file": "a1.hlo.txt", "depth": 1, "batch": 1,
             "height": 16, "width": 16, "channels": [8, 8],
             "input_shapes": [[1,16,16,8],[3,3,8,8],[8]],
             "output_shape": [1,16,16,8]},
            {"name": "a2", "file": "a2.hlo.txt", "depth": 2, "batch": 1,
             "height": 16, "width": 16, "channels": [8, 8, 8],
             "input_shapes": [[1,16,16,8],[3,3,8,8],[8],[3,3,8,8],[8]],
             "output_shape": [1,16,16,8]}
          ],
          "fused_pairs": {}, "golden": {}
        }"#;
        let man = Manifest::parse(text, Path::new("/tmp")).unwrap();
        let model = zoo::mini_cnn(); // 6 convs
        let sched = Schedule::single_block(model.num_layers(), 4);
        let plan = build_plan(&model, &sched, &man).unwrap();
        // Greedy: 2+2+2.
        assert_eq!(plan.steps.len(), 3);
        assert!(plan.steps.iter().all(|s| s.artifact == "a2"));
    }

    #[test]
    fn annotate_fills_step_predictions() {
        let text = r#"{
          "format_version": 1, "interchange": "hlo-text",
          "artifacts": [
            {"name": "a1", "file": "a1.hlo.txt", "depth": 1, "batch": 1,
             "height": 16, "width": 16, "channels": [8, 8],
             "input_shapes": [[1,16,16,8],[3,3,8,8],[8]],
             "output_shape": [1,16,16,8]}
          ],
          "fused_pairs": {}, "golden": {}
        }"#;
        let man = Manifest::parse(text, Path::new("/tmp")).unwrap();
        let model = zoo::mini_cnn();
        let sched = Schedule::single_block(model.num_layers(), 4);
        let mut plan = build_plan(&model, &sched, &man).unwrap();
        assert_eq!(plan.predicted_total_ms(), 0.0);
        let sim = crate::accel::Simulator::new(crate::accel::Target::mlu100());
        let mut engine = crate::cost::CostEngine::new(&sim, &model);
        annotate_with_costs(&mut plan, &mut engine);
        assert!(plan.steps.iter().all(|s| s.predicted_ms > 0.0));
        assert!(plan.predicted_total_ms() > 0.0);
        // Each step's prediction is the engine's latency for its range.
        let s0 = &plan.steps[0];
        assert_eq!(
            s0.predicted_ms,
            engine.block_latency(s0.conv_indices[0],
                                 s0.conv_indices.last().unwrap() + 1, s0.mp)
        );
    }
}
