//! The inference engine: a compiled execution plan + model weights, run
//! against the PJRT runtime.

use std::collections::HashMap;

use super::plan::ExecutionPlan;
use crate::graph::{LayerKind, Model};
use crate::runtime::{Runtime, RuntimeError, Tensor};
use crate::util::XorShiftRng;

/// A ready-to-serve inference session: executables compiled, weights
/// resident (the paper's "executable inference session" after codegen+g++).
pub struct Engine {
    runtime: Runtime,
    plan: ExecutionPlan,
    /// conv layer index -> (weights HWIO, bias).
    weights: HashMap<usize, (Tensor, Tensor)>,
    input_shape: Vec<usize>,
    output_shape: Vec<usize>,
}

impl Engine {
    /// Build an engine: deterministic He-style random weights per conv layer
    /// (seeded — fused and unfused paths share the exact same parameters),
    /// and all plan artifacts compiled up front.
    pub fn new(mut runtime: Runtime, model: &Model, plan: ExecutionPlan, seed: u64)
               -> Result<Engine, RuntimeError> {
        let mut weights = HashMap::new();
        let mut rng = XorShiftRng::new(seed);
        for (i, layer) in model.layers.iter().enumerate() {
            if let LayerKind::Conv(c) = &layer.kind {
                let fan_in = (c.k * c.k * c.c_in) as f32;
                let w = Tensor::random(
                    vec![c.k, c.k, c.c_in, c.c_out],
                    &mut rng,
                    (2.0 / fan_in).sqrt(),
                );
                let b = Tensor::random(vec![c.c_out], &mut rng, 0.05);
                weights.insert(i, (w, b));
            }
        }
        // Validate the plan up front so every later lookup is infallible:
        // a malformed plan surfaces here as a RuntimeError, not a panic on
        // the request path.
        let (Some(first_step), Some(last_step)) =
            (plan.steps.first(), plan.steps.last())
        else {
            return Err(RuntimeError::InvalidPlan(format!(
                "plan for '{}' has no steps", plan.model_name)));
        };
        for step in &plan.steps {
            for &ci in &step.conv_indices {
                if !weights.contains_key(&ci) {
                    return Err(RuntimeError::InvalidPlan(format!(
                        "step '{}' of plan '{}' references conv layer {ci}, \
                         but model '{}' has no conv there",
                        step.artifact, plan.model_name, model.name)));
                }
            }
        }
        let first = runtime
            .manifest()
            .get(&first_step.artifact)
            .ok_or_else(|| RuntimeError::UnknownArtifact(first_step.artifact.clone()))?
            .clone();
        let last = runtime
            .manifest()
            .get(&last_step.artifact)
            .ok_or_else(|| RuntimeError::UnknownArtifact(last_step.artifact.clone()))?
            .clone();
        for step in &plan.steps {
            runtime.prepare(&step.artifact)?;
        }
        Ok(Engine {
            runtime,
            plan,
            weights,
            input_shape: first.input_shapes[0].clone(),
            output_shape: last.output_shape.clone(),
        })
    }

    pub fn plan(&self) -> &ExecutionPlan {
        &self.plan
    }

    /// Simulator-predicted latency of one inference through this plan, ms
    /// (0.0 unless the plan was annotated via
    /// [`super::plan::annotate_with_costs`]).
    pub fn predicted_total_ms(&self) -> f64 {
        self.plan.predicted_total_ms()
    }

    pub fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }

    pub fn output_shape(&self) -> &[usize] {
        &self.output_shape
    }

    pub fn runtime_mut(&mut self) -> &mut Runtime {
        &mut self.runtime
    }

    /// Assemble the artifact inputs for one plan step given the flowing
    /// activation. `Engine::new` validated every step's conv indices, so
    /// the error path only fires for plans mutated behind the engine's back.
    fn step_inputs(&self, step_idx: usize, activation: Tensor)
                   -> Result<Vec<Tensor>, RuntimeError> {
        let step = &self.plan.steps[step_idx];
        let mut inputs = Vec::with_capacity(1 + 2 * step.conv_indices.len());
        inputs.push(activation);
        for &ci in &step.conv_indices {
            let (w, b) = self.weights.get(&ci).ok_or_else(|| {
                RuntimeError::InvalidPlan(format!(
                    "no weights for conv layer {ci} (step '{}')", step.artifact))
            })?;
            inputs.push(w.clone());
            inputs.push(b.clone());
        }
        Ok(inputs)
    }

    /// Run one inference through the *fused* plan.
    pub fn infer(&mut self, x: Tensor) -> Result<Tensor, RuntimeError> {
        let mut cur = x;
        for si in 0..self.plan.steps.len() {
            let inputs = self.step_inputs(si, cur)?;
            let name = self.plan.steps[si].artifact.clone();
            cur = self.runtime.execute(&name, &inputs)?;
        }
        Ok(cur)
    }

    /// Run the same computation layer-wise (every fused step expanded into
    /// its per-stage artifacts) — the unfused baseline used for the
    /// mathematical-equivalence check.
    pub fn infer_unfused(&mut self, x: Tensor) -> Result<Tensor, RuntimeError> {
        let mut cur = x;
        for si in 0..self.plan.steps.len() {
            let name = self.plan.steps[si].artifact.clone();
            let fused = self.plan.steps[si].conv_indices.len() > 1;
            let inputs = self.step_inputs(si, cur)?;
            cur = if fused {
                self.runtime.execute_stagewise(&name, &inputs)?
            } else {
                self.runtime.execute(&name, &inputs)?
            };
        }
        Ok(cur)
    }

    /// A deterministic random input for this engine.
    pub fn random_input(&self, seed: u64) -> Tensor {
        let mut rng = XorShiftRng::new(seed);
        Tensor::random(self.input_shape.clone(), &mut rng, 1.0)
    }
}
