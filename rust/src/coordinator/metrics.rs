//! Request-loop metrics: counters and latency histograms.
//!
//! Both primitives export into the unified [`MetricsRegistry`]
//! (rust/docs/DESIGN.md §14.2), so ad-hoc consumers and the
//! `--metrics-out` / `dlfusion report` surface read the same numbers.

use std::cell::RefCell;

use crate::obs::{Domain, MetricsRegistry};
use crate::stats::descriptive::{percentile_sorted, Summary};

/// Online latency recorder with percentile reporting.
///
/// Percentile queries go through a lazily maintained sorted view of the
/// sample buffer: the first query after a batch of [`Self::record`] calls
/// sorts once into a cache, and every further query — single or batch — is
/// a binary-interpolation read. The old clone-and-sort-per-call path did
/// O(n log n) work on *every* query, which dominated the serving report on
/// large traces. Samples only ever append, so cache validity is exactly
/// "lengths match"; results are pinned identical to the eager path by
/// `cached_percentiles_track_new_samples`.
#[derive(Debug, Clone, Default)]
pub struct LatencyRecorder {
    samples_ms: Vec<f64>,
    sorted_cache: RefCell<Vec<f64>>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, ms: f64) {
        self.samples_ms.push(ms);
    }

    pub fn count(&self) -> usize {
        self.samples_ms.len()
    }

    /// Run `f` over the sorted sample view, (re)building the cache only
    /// when samples arrived since the last query.
    fn with_sorted<R>(&self, f: impl FnOnce(&[f64]) -> R) -> R {
        let mut cache = self.sorted_cache.borrow_mut();
        if cache.len() != self.samples_ms.len() {
            cache.clone_from(&self.samples_ms);
            cache.sort_by(|a, b| a.partial_cmp(b).unwrap());
        }
        f(&cache)
    }

    pub fn summary(&self) -> Option<Summary> {
        if self.samples_ms.is_empty() {
            None
        } else {
            Some(Summary::of(&self.samples_ms))
        }
    }

    pub fn percentile(&self, p: f64) -> Option<f64> {
        if self.samples_ms.is_empty() {
            None
        } else {
            Some(self.with_sorted(|sorted| percentile_sorted(sorted, p)))
        }
    }

    /// Batch percentile accessor, one cached-sort read for the whole list.
    /// Used by [`Self::report`] and the serving SLO report.
    pub fn percentiles(&self, ps: &[f64]) -> Option<Vec<f64>> {
        if self.samples_ms.is_empty() {
            return None;
        }
        Some(self.with_sorted(|sorted| {
            ps.iter().map(|&p| percentile_sorted(sorted, p)).collect()
        }))
    }

    /// Export `count`/`mean`/`p50`/`p95`/`p99`/`max` (ms) as gauges named
    /// `{prefix}…` into the unified registry. Percentiles reuse the cached
    /// sorted view, so this is one O(n log n) sort at most.
    pub fn export_metrics(&self, reg: &mut MetricsRegistry, domain: Domain,
                          prefix: &str) {
        reg.set_gauge(domain, &format!("{prefix}count"), self.count() as f64);
        if let Some(s) = self.summary() {
            let ps = self
                .percentiles(&[50.0, 95.0, 99.0])
                .expect("summary implies samples");
            reg.set_gauge(domain, &format!("{prefix}mean_ms"), s.mean);
            reg.set_gauge(domain, &format!("{prefix}p50_ms"), ps[0]);
            reg.set_gauge(domain, &format!("{prefix}p95_ms"), ps[1]);
            reg.set_gauge(domain, &format!("{prefix}p99_ms"), ps[2]);
            reg.set_gauge(domain, &format!("{prefix}max_ms"), s.max);
        }
    }

    /// "p50/p95/p99 mean" one-liner.
    pub fn report(&self) -> String {
        match self.summary() {
            None => "no samples".to_string(),
            Some(s) => {
                let ps = self
                    .percentiles(&[50.0, 95.0, 99.0])
                    .expect("summary implies samples");
                format!(
                    "n={} mean={:.3}ms p50={:.3}ms p95={:.3}ms p99={:.3}ms max={:.3}ms",
                    s.n, s.mean, ps[0], ps[1], ps[2], s.max
                )
            }
        }
    }
}

/// Named monotonically-increasing counters.
#[derive(Debug, Clone, Default)]
pub struct Counters {
    entries: std::collections::BTreeMap<String, u64>,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&mut self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&mut self, name: &str, v: u64) {
        // Look up by `&str` first: the `entry` API would allocate a fresh
        // `String` per call, and this runs once per event in the serving
        // loop where the key almost always exists already. The allocation
        // now happens exactly once per distinct name.
        if let Some(e) = self.entries.get_mut(name) {
            *e += v;
        } else {
            self.entries.insert(name.to_string(), v);
        }
    }

    pub fn get(&self, name: &str) -> u64 {
        self.entries.get(name).copied().unwrap_or(0)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, u64)> {
        self.entries.iter().map(|(k, &v)| (k.as_str(), v))
    }

    /// Export every counter as `{prefix}{name}` into the unified registry.
    pub fn export_metrics(&self, reg: &mut MetricsRegistry, domain: Domain,
                          prefix: &str) {
        for (name, v) in self.iter() {
            reg.inc(domain, &format!("{prefix}{name}"), v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_percentiles() {
        let mut r = LatencyRecorder::new();
        for i in 1..=100 {
            r.record(i as f64);
        }
        assert_eq!(r.count(), 100);
        assert!((r.percentile(50.0).unwrap() - 50.5).abs() < 1.0);
        assert!(r.percentile(99.0).unwrap() > 98.0);
        assert!(r.report().contains("p95"));
    }

    #[test]
    fn empty_recorder() {
        let r = LatencyRecorder::new();
        assert!(r.summary().is_none());
        assert!(r.percentiles(&[50.0]).is_none());
        assert_eq!(r.report(), "no samples");
    }

    #[test]
    fn batch_percentiles_match_single_calls() {
        let mut r = LatencyRecorder::new();
        // Deliberately unsorted insertion order.
        for v in [5.0, 1.0, 9.0, 3.0, 7.0, 2.0, 8.0, 4.0, 6.0, 10.0] {
            r.record(v);
        }
        let ps = [0.0, 25.0, 50.0, 95.0, 99.0, 100.0];
        let batch = r.percentiles(&ps).unwrap();
        for (&p, &b) in ps.iter().zip(&batch) {
            assert_eq!(b, r.percentile(p).unwrap(), "p{p}");
        }
        assert_eq!(r.percentiles(&[]).unwrap(), Vec::<f64>::new());
    }

    #[test]
    fn cached_percentiles_track_new_samples() {
        // Interleave queries (which build the sorted cache) with appends
        // (which stale it) and pin every answer to an eagerly re-sorted
        // recorder over the same samples.
        let values = [5.0, 1.0, 9.0, 3.0, 7.0, 2.0, 8.0, 4.0, 6.0, 10.0];
        let mut cached = LatencyRecorder::new();
        for (i, &v) in values.iter().enumerate() {
            cached.record(v);
            let mut eager = LatencyRecorder::new();
            for &w in &values[..=i] {
                eager.record(w);
            }
            for p in [0.0, 50.0, 90.0, 100.0] {
                assert_eq!(cached.percentile(p), eager.percentile(p),
                           "p{p} after {} samples", i + 1);
            }
            assert_eq!(cached.percentiles(&[25.0, 75.0]),
                       eager.percentiles(&[25.0, 75.0]));
            // A second query against the warm cache answers the same.
            assert_eq!(cached.percentile(50.0), eager.percentile(50.0));
        }
        assert_eq!(cached.report(), {
            let mut eager = LatencyRecorder::new();
            values.iter().for_each(|&v| eager.record(v));
            eager.report()
        });
    }

    #[test]
    fn counters_accumulate() {
        let mut c = Counters::new();
        c.inc("requests");
        c.inc("requests");
        c.add("convs", 6);
        assert_eq!(c.get("requests"), 2);
        assert_eq!(c.get("convs"), 6);
        assert_eq!(c.get("missing"), 0);
        assert_eq!(c.iter().count(), 2);
    }

    #[test]
    fn counters_export_into_registry() {
        let mut c = Counters::new();
        c.add("slo_ok", 9);
        c.inc("core_launches");
        let mut reg = MetricsRegistry::new();
        c.export_metrics(&mut reg, Domain::Sim, "serving.");
        assert_eq!(reg.counter("serving.slo_ok"), Some(9));
        assert_eq!(reg.counter("serving.core_launches"), Some(1));
    }

    #[test]
    fn latency_recorder_exports_percentile_gauges() {
        let mut r = LatencyRecorder::new();
        for i in 1..=100 {
            r.record(i as f64);
        }
        let mut reg = MetricsRegistry::new();
        r.export_metrics(&mut reg, Domain::Sim, "e2e.");
        assert_eq!(reg.gauge("e2e.count"), Some(100.0));
        assert_eq!(reg.gauge("e2e.max_ms"), Some(100.0));
        assert_eq!(reg.gauge("e2e.p50_ms"), r.percentile(50.0));
        // An empty recorder exports only its (zero) count.
        let mut reg2 = MetricsRegistry::new();
        LatencyRecorder::new().export_metrics(&mut reg2, Domain::Sim, "q.");
        assert_eq!(reg2.gauge("q.count"), Some(0.0));
        assert_eq!(reg2.gauge("q.p50_ms"), None);
    }
}
