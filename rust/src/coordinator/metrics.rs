//! Request-loop metrics: counters and latency histograms.

use crate::stats::descriptive::{percentile, Summary};

/// Online latency recorder with percentile reporting.
#[derive(Debug, Clone, Default)]
pub struct LatencyRecorder {
    samples_ms: Vec<f64>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, ms: f64) {
        self.samples_ms.push(ms);
    }

    pub fn count(&self) -> usize {
        self.samples_ms.len()
    }

    pub fn summary(&self) -> Option<Summary> {
        if self.samples_ms.is_empty() {
            None
        } else {
            Some(Summary::of(&self.samples_ms))
        }
    }

    pub fn percentile(&self, p: f64) -> Option<f64> {
        if self.samples_ms.is_empty() {
            None
        } else {
            Some(percentile(&self.samples_ms, p))
        }
    }

    /// "p50/p95/p99 mean" one-liner.
    pub fn report(&self) -> String {
        match self.summary() {
            None => "no samples".to_string(),
            Some(s) => format!(
                "n={} mean={:.3}ms p50={:.3}ms p95={:.3}ms p99={:.3}ms max={:.3}ms",
                s.n,
                s.mean,
                self.percentile(50.0).unwrap(),
                self.percentile(95.0).unwrap(),
                self.percentile(99.0).unwrap(),
                s.max
            ),
        }
    }
}

/// Named monotonically-increasing counters.
#[derive(Debug, Clone, Default)]
pub struct Counters {
    entries: std::collections::BTreeMap<String, u64>,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&mut self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&mut self, name: &str, v: u64) {
        *self.entries.entry(name.to_string()).or_insert(0) += v;
    }

    pub fn get(&self, name: &str) -> u64 {
        self.entries.get(name).copied().unwrap_or(0)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, u64)> {
        self.entries.iter().map(|(k, &v)| (k.as_str(), v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_percentiles() {
        let mut r = LatencyRecorder::new();
        for i in 1..=100 {
            r.record(i as f64);
        }
        assert_eq!(r.count(), 100);
        assert!((r.percentile(50.0).unwrap() - 50.5).abs() < 1.0);
        assert!(r.percentile(99.0).unwrap() > 98.0);
        assert!(r.report().contains("p95"));
    }

    #[test]
    fn empty_recorder() {
        let r = LatencyRecorder::new();
        assert!(r.summary().is_none());
        assert_eq!(r.report(), "no samples");
    }

    #[test]
    fn counters_accumulate() {
        let mut c = Counters::new();
        c.inc("requests");
        c.inc("requests");
        c.add("convs", 6);
        assert_eq!(c.get("requests"), 2);
        assert_eq!(c.get("convs"), 6);
        assert_eq!(c.get("missing"), 0);
        assert_eq!(c.iter().count(), 2);
    }
}
