//! Request-loop metrics: counters and latency histograms.

use crate::stats::descriptive::{percentile, percentile_sorted, Summary};

/// Online latency recorder with percentile reporting.
#[derive(Debug, Clone, Default)]
pub struct LatencyRecorder {
    samples_ms: Vec<f64>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, ms: f64) {
        self.samples_ms.push(ms);
    }

    pub fn count(&self) -> usize {
        self.samples_ms.len()
    }

    pub fn summary(&self) -> Option<Summary> {
        if self.samples_ms.is_empty() {
            None
        } else {
            Some(Summary::of(&self.samples_ms))
        }
    }

    pub fn percentile(&self, p: f64) -> Option<f64> {
        if self.samples_ms.is_empty() {
            None
        } else {
            Some(percentile(&self.samples_ms, p))
        }
    }

    /// Batch percentile accessor: sorts the sample buffer once for the
    /// whole list (three separate [`Self::percentile`] calls re-sort three
    /// times). Used by [`Self::report`] and the serving SLO report.
    pub fn percentiles(&self, ps: &[f64]) -> Option<Vec<f64>> {
        if self.samples_ms.is_empty() {
            return None;
        }
        let mut sorted = self.samples_ms.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(ps.iter().map(|&p| percentile_sorted(&sorted, p)).collect())
    }

    /// "p50/p95/p99 mean" one-liner.
    pub fn report(&self) -> String {
        match self.summary() {
            None => "no samples".to_string(),
            Some(s) => {
                let ps = self
                    .percentiles(&[50.0, 95.0, 99.0])
                    .expect("summary implies samples");
                format!(
                    "n={} mean={:.3}ms p50={:.3}ms p95={:.3}ms p99={:.3}ms max={:.3}ms",
                    s.n, s.mean, ps[0], ps[1], ps[2], s.max
                )
            }
        }
    }
}

/// Named monotonically-increasing counters.
#[derive(Debug, Clone, Default)]
pub struct Counters {
    entries: std::collections::BTreeMap<String, u64>,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&mut self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&mut self, name: &str, v: u64) {
        *self.entries.entry(name.to_string()).or_insert(0) += v;
    }

    pub fn get(&self, name: &str) -> u64 {
        self.entries.get(name).copied().unwrap_or(0)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, u64)> {
        self.entries.iter().map(|(k, &v)| (k.as_str(), v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_percentiles() {
        let mut r = LatencyRecorder::new();
        for i in 1..=100 {
            r.record(i as f64);
        }
        assert_eq!(r.count(), 100);
        assert!((r.percentile(50.0).unwrap() - 50.5).abs() < 1.0);
        assert!(r.percentile(99.0).unwrap() > 98.0);
        assert!(r.report().contains("p95"));
    }

    #[test]
    fn empty_recorder() {
        let r = LatencyRecorder::new();
        assert!(r.summary().is_none());
        assert!(r.percentiles(&[50.0]).is_none());
        assert_eq!(r.report(), "no samples");
    }

    #[test]
    fn batch_percentiles_match_single_calls() {
        let mut r = LatencyRecorder::new();
        // Deliberately unsorted insertion order.
        for v in [5.0, 1.0, 9.0, 3.0, 7.0, 2.0, 8.0, 4.0, 6.0, 10.0] {
            r.record(v);
        }
        let ps = [0.0, 25.0, 50.0, 95.0, 99.0, 100.0];
        let batch = r.percentiles(&ps).unwrap();
        for (&p, &b) in ps.iter().zip(&batch) {
            assert_eq!(b, r.percentile(p).unwrap(), "p{p}");
        }
        assert_eq!(r.percentiles(&[]).unwrap(), Vec::<f64>::new());
    }

    #[test]
    fn counters_accumulate() {
        let mut c = Counters::new();
        c.inc("requests");
        c.inc("requests");
        c.add("convs", 6);
        assert_eq!(c.get("requests"), 2);
        assert_eq!(c.get("convs"), 6);
        assert_eq!(c.get("missing"), 0);
        assert_eq!(c.iter().count(), 2);
    }
}
