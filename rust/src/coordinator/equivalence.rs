//! Fused-vs-unfused numerical equivalence — DLFusion's foundational claim
//! ("arbitrary auto-fusion patterns that are mathematically equivalent"),
//! checked on the real execution path.

use crate::runtime::{Runtime, RuntimeError, Tensor};

/// One equivalence check outcome.
#[derive(Debug, Clone, PartialEq)]
pub struct EquivalenceCheck {
    pub artifact: String,
    pub max_abs_diff: f32,
    pub passed: bool,
}

/// Aggregated equivalence report.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct EquivalenceReport {
    pub checks: Vec<EquivalenceCheck>,
}

impl EquivalenceReport {
    pub fn all_passed(&self) -> bool {
        !self.checks.is_empty() && self.checks.iter().all(|c| c.passed)
    }

    pub fn worst_diff(&self) -> f32 {
        self.checks.iter().map(|c| c.max_abs_diff).fold(0.0, f32::max)
    }
}

/// Tolerance for fused-vs-unfused f32 comparison. The two paths reassociate
/// the same dot products, so differences are a few ULPs.
pub const FUSION_TOL: f32 = 2e-4;

/// For every fused artifact with per-stage counterparts, execute both paths
/// on identical random inputs and compare.
pub fn check_fused_vs_unfused(rt: &mut Runtime, seed: u64)
                              -> Result<EquivalenceReport, RuntimeError> {
    let names: Vec<String> = rt
        .manifest()
        .fused_pairs
        .iter()
        .filter(|(_, v)| !v.is_empty())
        .map(|(k, _)| k.clone())
        .collect();
    let mut report = EquivalenceReport::default();
    for name in names {
        let inputs = rt.random_inputs(&name, seed)?;
        let fused = rt.execute(&name, &inputs)?;
        let unfused = rt.execute_stagewise(&name, &inputs)?;
        let diff = fused.max_abs_diff(&unfused);
        report.checks.push(EquivalenceCheck {
            artifact: name,
            max_abs_diff: diff,
            passed: diff <= FUSION_TOL,
        });
    }
    Ok(report)
}

/// Replay the python-recorded golden vectors: execute each golden artifact
/// with the exact inputs `aot.py` saved and compare against its saved
/// output. This pins the whole AOT chain (pallas kernel -> HLO text ->
/// PJRT) against the build-time reference.
pub fn check_golden(rt: &mut Runtime, tol: f32) -> Result<EquivalenceReport, RuntimeError> {
    let golden: Vec<(String, String, usize)> = rt
        .manifest()
        .golden
        .iter()
        .map(|(k, g)| (k.clone(), g.dir.clone(), g.num_inputs))
        .collect();
    let dir = rt.manifest().dir.clone();
    let mut report = EquivalenceReport::default();
    for (name, gdir, num_inputs) in golden {
        let spec = rt
            .manifest()
            .get(&name)
            .ok_or_else(|| RuntimeError::UnknownArtifact(name.clone()))?
            .clone();
        let gpath = dir.join(&gdir);
        let mut inputs = Vec::with_capacity(num_inputs);
        for (i, shape) in spec.input_shapes.iter().enumerate() {
            let t = Tensor::from_f32_file(&gpath.join(format!("in{i}.f32")), shape.clone())
                .map_err(|e| RuntimeError::Io(e.to_string()))?;
            inputs.push(t);
        }
        let want = Tensor::from_f32_file(&gpath.join("out.f32"), spec.output_shape.clone())
            .map_err(|e| RuntimeError::Io(e.to_string()))?;
        let got = rt.execute(&name, &inputs)?;
        let diff = got.max_abs_diff(&want);
        report.checks.push(EquivalenceCheck {
            artifact: name,
            max_abs_diff: diff,
            passed: diff <= tol,
        });
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_report_not_passed() {
        let r = EquivalenceReport::default();
        assert!(!r.all_passed());
        assert_eq!(r.worst_diff(), 0.0);
    }

    #[test]
    fn report_aggregation() {
        let r = EquivalenceReport {
            checks: vec![
                EquivalenceCheck { artifact: "a".into(), max_abs_diff: 1e-6, passed: true },
                EquivalenceCheck { artifact: "b".into(), max_abs_diff: 3e-5, passed: true },
            ],
        };
        assert!(r.all_passed());
        assert!((r.worst_diff() - 3e-5).abs() < 1e-12);
    }
}
