//! A small seeded property-testing framework (proptest substitute).
//!
//! `forall(cases, gen, prop)` draws `cases` random inputs from `gen` and
//! checks `prop` on each; on failure it reports the failing input, the seed
//! to reproduce, and — when the input type supports it — a greedy shrink to
//! a smaller counterexample. Deterministic: the seed derives from the
//! `DLFUSION_PROP_SEED` env var (default 0xD1F051).

use crate::util::XorShiftRng;

/// Value generator used by [`forall`].
pub struct Gen<'a, T> {
    make: Box<dyn Fn(&mut XorShiftRng) -> T + 'a>,
    shrink: Option<Box<dyn Fn(&T) -> Vec<T> + 'a>>,
}

impl<'a, T: std::fmt::Debug + Clone> Gen<'a, T> {
    pub fn new(make: impl Fn(&mut XorShiftRng) -> T + 'a) -> Self {
        Gen { make: Box::new(make), shrink: None }
    }

    /// Attach a shrinker: returns candidate *smaller* inputs.
    pub fn with_shrink(mut self, shrink: impl Fn(&T) -> Vec<T> + 'a) -> Self {
        self.shrink = Some(Box::new(shrink));
        self
    }
}

fn seed_from_env() -> u64 {
    std::env::var("DLFUSION_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xD1F051)
}

/// Run `prop` on `cases` random inputs. Panics with a reproducible report on
/// the first failure (after shrinking, if a shrinker is attached).
pub fn forall<T: std::fmt::Debug + Clone>(
    cases: usize,
    gen: &Gen<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let seed = seed_from_env();
    let mut rng = XorShiftRng::new(seed);
    for case in 0..cases {
        let input = (gen.make)(&mut rng);
        if let Err(msg) = prop(&input) {
            // Try to shrink.
            let (final_input, final_msg) = shrink_loop(gen, &prop, input, msg);
            panic!(
                "property failed (case {case}/{cases}, seed {seed}):\n  \
                 input: {final_input:?}\n  reason: {final_msg}\n  \
                 reproduce with DLFUSION_PROP_SEED={seed}"
            );
        }
    }
}

fn shrink_loop<T: std::fmt::Debug + Clone>(
    gen: &Gen<T>,
    prop: &impl Fn(&T) -> Result<(), String>,
    mut input: T,
    mut msg: String,
) -> (T, String) {
    let Some(shrinker) = &gen.shrink else {
        return (input, msg);
    };
    // Greedy descent, bounded.
    for _ in 0..1000 {
        let mut advanced = false;
        for cand in shrinker(&input) {
            if let Err(m) = prop(&cand) {
                input = cand;
                msg = m;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    (input, msg)
}

/// Common generators.
pub mod gens {
    use super::Gen;
    use crate::util::XorShiftRng;

    /// usize in `[lo, hi]` with shrinking toward `lo`.
    pub fn usize_range<'a>(lo: usize, hi: usize) -> Gen<'a, usize> {
        Gen::new(move |r: &mut XorShiftRng| r.gen_usize(lo, hi)).with_shrink(move |&v| {
            let mut c = Vec::new();
            if v > lo {
                c.push(lo);
                c.push(lo + (v - lo) / 2);
                c.push(v - 1);
            }
            c.dedup();
            c
        })
    }

    /// Pair of independent draws.
    pub fn pair<'a, A: std::fmt::Debug + Clone + 'a, B: std::fmt::Debug + Clone + 'a>(
        a: Gen<'a, A>,
        b: Gen<'a, B>,
    ) -> Gen<'a, (A, B)> {
        Gen::new(move |r: &mut XorShiftRng| ((a.make)(r), (b.make)(r)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let g = Gen::new(|r: &mut XorShiftRng| r.gen_usize(0, 100));
        forall(200, &g, |&x| {
            if x <= 100 { Ok(()) } else { Err("out of range".into()) }
        });
    }

    #[test]
    fn failing_property_reports_and_shrinks() {
        let g = gens::usize_range(0, 1000);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            forall(500, &g, |&x| {
                if x < 50 { Ok(()) } else { Err(format!("{x} >= 50")) }
            });
        }));
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("property failed"), "{msg}");
        assert!(msg.contains("DLFUSION_PROP_SEED"), "{msg}");
        // Shrinker walks down toward the boundary 50.
        assert!(msg.contains("input: 50"), "shrink should reach 50: {msg}");
    }

    #[test]
    fn deterministic_under_fixed_seed() {
        use std::cell::RefCell;
        let g = Gen::new(|r: &mut XorShiftRng| r.next_u64());
        let first: RefCell<Vec<u64>> = RefCell::new(Vec::new());
        forall(10, &g, |&x| {
            first.borrow_mut().push(x);
            Ok(())
        });
        let second: RefCell<Vec<u64>> = RefCell::new(Vec::new());
        forall(10, &g, |&x| {
            second.borrow_mut().push(x);
            Ok(())
        });
        assert_eq!(first.into_inner(), second.into_inner());
    }

    #[test]
    fn pair_generator_works() {
        let g = gens::pair(gens::usize_range(0, 5), gens::usize_range(10, 15));
        forall(50, &g, |&(a, b)| {
            if a <= 5 && (10..=15).contains(&b) { Ok(()) } else { Err("bad".into()) }
        });
    }
}
