//! Explicit hardware targets: a named, validated [`AcceleratorSpec`] plus
//! the registry of built-in hardware points (rust/docs/DESIGN.md §11).
//!
//! The paper's whole premise is that the optimal (MP, fusion) point is a
//! function of the *hardware* — `OpCount_critical`, bandwidth, buffer size
//! (Table I, Figs. 3–7). Historically the crate baked the MLU100 in as an
//! implicit global (a `mlu100()` constructor at every entry point); this module
//! makes the hardware point a first-class, explicit API:
//!
//! - [`Target`]: a registry name + description wrapping a spec that has
//!   passed [`SpecBuilder`]-level validation. Constructing a `Target` is the
//!   only sanctioned way to get a spec into a [`super::Simulator`]
//!   (`Simulator::new(Target)`); raw-spec construction remains available as
//!   `Simulator::from_spec` for experiments but carries the `custom` name.
//! - [`SpecBuilder`]: field-level validated construction replacing struct
//!   literals. Invalid hardware (zero cores, zero bandwidth, a per-core
//!   buffer smaller than one tile, …) is a typed [`TargetError`], not a NaN
//!   three layers later.
//! - The registry: [`Target::by_name`] / [`Target::all`] over the built-in
//!   points below. `mlu100` keeps the exact paper-calibrated values, so
//!   every pre-redesign result is bit-identical on the default target.
//!
//! | name | chip | cores | peak | BW | role |
//! |---|---|---|---|---|---|
//! | `mlu100` | MLU100-C3 | 32 | 64 TFLOPS | 102.4 GB/s | the paper's Table I point (default) |
//! | `mlu270` | MLU270-S4 | 64 | 128 TFLOPS | 153.6 GB/s | bigger-chip point |
//! | `edge4`  | Edge-4    | 4  | 2 TFLOPS   | 25.6 GB/s  | edge-class part |
//! | `hbm32`  | HBM-32    | 32 | 64 TFLOPS  | 1024 GB/s  | bandwidth-rich hypothetical |

use super::sim::Simulator;
use super::spec::AcceleratorSpec;

/// Why a hardware target could not be constructed or combined.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TargetError {
    /// [`Target::by_name`] was given a name not in the registry.
    UnknownTarget { name: String },
    /// A spec field failed [`SpecBuilder`] validation.
    InvalidSpec { field: &'static str, reason: String },
    /// A serving cluster was asked to co-schedule plans made for different
    /// hardware targets (one pool is one chip).
    MixedTargets { first: String, second: String },
}

impl std::fmt::Display for TargetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TargetError::UnknownTarget { name } => write!(
                f,
                "unknown target '{name}' (known: {})",
                Target::NAMES.join(", ")
            ),
            TargetError::InvalidSpec { field, reason } => {
                write!(f, "invalid accelerator spec: {field}: {reason}")
            }
            TargetError::MixedTargets { first, second } => write!(
                f,
                "cluster mixes hardware targets '{first}' and '{second}' \
                 (every service in one pool must be planned for one target)"
            ),
        }
    }
}

impl std::error::Error for TargetError {}

/// A named, validated hardware point: what every tuning run, serving plan,
/// and CLI invocation is explicitly *for*.
#[derive(Debug, Clone, PartialEq)]
pub struct Target {
    name: String,
    description: String,
    spec: AcceleratorSpec,
}

impl Target {
    /// Registry names, in [`Target::all`] order (`mlu100` is the default).
    pub const NAMES: &'static [&'static str] = &["mlu100", "mlu270", "edge4", "hbm32"];

    /// Prefix of the target name a [`Simulator::from_spec`] simulator
    /// reports (`custom:<spec name>`).
    pub const CUSTOM: &'static str = "custom";

    /// Look a built-in target up by registry name.
    pub fn by_name(name: &str) -> Result<Target, TargetError> {
        match name {
            "mlu100" => Ok(Target::mlu100()),
            "mlu270" => Ok(Target::mlu270()),
            "edge4" => Ok(Target::edge4()),
            "hbm32" => Ok(Target::hbm32()),
            other => Err(TargetError::UnknownTarget { name: other.to_string() }),
        }
    }

    /// Every built-in target, default first.
    pub fn all() -> Vec<Target> {
        Target::NAMES
            .iter()
            .map(|n| Target::by_name(n).expect("registry names resolve"))
            .collect()
    }

    /// A user-defined target: any registry-reserved or empty name is
    /// rejected, and the spec passes the same validation as the builder.
    pub fn custom(name: impl Into<String>, description: impl Into<String>,
                  spec: AcceleratorSpec) -> Result<Target, TargetError> {
        let name = name.into();
        if name.is_empty() {
            return Err(TargetError::InvalidSpec {
                field: "name",
                reason: "target name must be non-empty".to_string(),
            });
        }
        if Target::NAMES.contains(&name.as_str()) {
            return Err(TargetError::InvalidSpec {
                field: "name",
                reason: format!("'{name}' is a built-in registry name"),
            });
        }
        if name == Target::CUSTOM || name.starts_with("custom:") {
            return Err(TargetError::InvalidSpec {
                field: "name",
                reason: format!(
                    "'{name}' is reserved for Simulator::from_spec labels"),
            });
        }
        validate_spec(&spec)?;
        Ok(Target { name, description: description.into(), spec })
    }

    /// The Cambricon MLU100 (paper Table I) with the paper-derived
    /// calibration — the default target. The values are exactly the
    /// pre-redesign `AcceleratorSpec::mlu100()` literals, pinned by
    /// `rust/tests/target_api.rs`, so every result on this target is
    /// bit-identical to HEAD.
    pub fn mlu100() -> Target {
        Target {
            name: "mlu100".to_string(),
            description: "Cambricon MLU100 (paper Table I) — the calibrated default"
                .to_string(),
            spec: AcceleratorSpec {
                name: "MLU100-C3".to_string(),
                num_cores: 32,
                peak_gflops_per_core: 2000.0, // 64 TFLOPS FP16 total
                mem_bw_gbps: 102.4,
                mem_bytes: 8.0 * 1024.0 * 1024.0 * 1024.0,
                core_freq_ghz: 1.0,
                // Chip-wide OpCount_critical = 10^1.25 = 17.78 GOPs
                //   = 9 * fill * num_cores.
                fill_gops: 10f64.powf(1.25) / 9.0 / 32.0,
                channel_granularity: 4,
                launch_overhead_us: 20.0,
                sync_us_per_core: 5.0,
                fused_layer_us: 4.0,
                core_buffer_bytes: 2.0 * 1024.0 * 1024.0,
            },
        }
    }

    /// An MLU270-class bigger chip: twice the cores behind 1.5x the
    /// bandwidth. The per-core pipeline ramp (`fill_gops`) matches the
    /// MLU100's, so its chip-wide `OpCount_critical` is 2x the paper's —
    /// bigger chips need deeper fusion to saturate.
    pub fn mlu270() -> Target {
        Target {
            name: "mlu270".to_string(),
            description: "MLU270-class bigger chip: 64 cores, 128 TFLOPS, 153.6 GB/s"
                .to_string(),
            spec: AcceleratorSpec {
                name: "MLU270-S4".to_string(),
                num_cores: 64,
                peak_gflops_per_core: 2000.0, // 128 TFLOPS FP16 total
                mem_bw_gbps: 153.6,
                mem_bytes: 16.0 * 1024.0 * 1024.0 * 1024.0,
                core_freq_ghz: 1.0,
                // Same ~31 us per-core ramp as the MLU100.
                fill_gops: 10f64.powf(1.25) / 9.0 / 32.0,
                channel_granularity: 4,
                launch_overhead_us: 20.0,
                sync_us_per_core: 5.0,
                fused_layer_us: 4.0,
                core_buffer_bytes: 2.0 * 1024.0 * 1024.0,
            },
        }
    }

    /// An edge-class 4-core part: a quarter of the MLU100's per-core peak
    /// at a quarter of its bandwidth, smaller buffers, cheaper launches.
    /// The per-core ramp time matches the MLU100's ~31 us, which at a
    /// quarter of the per-core peak is a quarter of the fill GOPs.
    pub fn edge4() -> Target {
        Target {
            name: "edge4".to_string(),
            description: "edge-class 4-core part: 2 TFLOPS, 25.6 GB/s".to_string(),
            spec: AcceleratorSpec {
                name: "Edge-4".to_string(),
                num_cores: 4,
                peak_gflops_per_core: 500.0, // 2 TFLOPS FP16 total
                mem_bw_gbps: 25.6,
                mem_bytes: 2.0 * 1024.0 * 1024.0 * 1024.0,
                core_freq_ghz: 0.8,
                fill_gops: 10f64.powf(1.25) / 9.0 / 32.0 / 4.0,
                channel_granularity: 4,
                launch_overhead_us: 10.0,
                sync_us_per_core: 2.0,
                fused_layer_us: 4.0,
                core_buffer_bytes: 1.0 * 1024.0 * 1024.0,
            },
        }
    }

    /// A bandwidth-rich hypothetical: the MLU100's compute behind 1 TB/s of
    /// HBM-class bandwidth. Fusion's traffic savings matter far less here,
    /// so the optimal schedules shift toward shallower blocks — the
    /// hardware-sensitivity scenario the explicit-target API exists for.
    pub fn hbm32() -> Target {
        let mut spec = Target::mlu100().spec;
        spec.name = "HBM-32".to_string();
        spec.mem_bw_gbps = 1024.0;
        spec.mem_bytes = 16.0 * 1024.0 * 1024.0 * 1024.0;
        Target {
            name: "hbm32".to_string(),
            description: "bandwidth-rich hypothetical: MLU100 compute behind 1 TB/s HBM"
                .to_string(),
            spec,
        }
    }

    /// The registry name (`mlu100`, `edge4`, …, or a custom name).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// One-line description for listings.
    pub fn description(&self) -> &str {
        &self.description
    }

    /// The validated hardware spec.
    pub fn spec(&self) -> &AcceleratorSpec {
        &self.spec
    }

    /// Unwrap into the raw spec (e.g. for spec-level experiments).
    pub fn into_spec(self) -> AcceleratorSpec {
        self.spec
    }

    /// Split into `(registry name, spec)` — what [`Simulator::new`] records.
    pub fn into_parts(self) -> (String, AcceleratorSpec) {
        (self.name, self.spec)
    }

    /// A simulator of this target (`Simulator::new(self)`).
    pub fn simulator(&self) -> Simulator {
        Simulator::new(self.clone())
    }
}

impl Default for Target {
    /// The default target is the paper's MLU100.
    fn default() -> Target {
        Target::mlu100()
    }
}

impl std::fmt::Display for Target {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} ({})", self.name, self.spec.name)
    }
}

/// The smallest per-core buffer that holds one tile: one channel-granularity
/// chunk of a 32x32 fp16 spatial band. Anything smaller cannot stage even a
/// single fused intermediate, so the fusion model's buffer accounting would
/// be meaningless.
pub fn min_tile_bytes(channel_granularity: usize) -> f64 {
    (channel_granularity * 32 * 32 * 2) as f64
}

/// Widest channel-granularity the partitioner meaningfully supports: a
/// granularity beyond any real layer's channel count degenerates every
/// partition into one padded chunk.
pub const MAX_CHANNEL_GRANULARITY: usize = 256;

/// Field-level validation shared by [`SpecBuilder::build`] and
/// [`Target::custom`].
pub fn validate_spec(spec: &AcceleratorSpec) -> Result<(), TargetError> {
    fn invalid(field: &'static str, reason: String) -> TargetError {
        TargetError::InvalidSpec { field, reason }
    }
    fn positive(field: &'static str, v: f64) -> Result<(), TargetError> {
        if v.is_finite() && v > 0.0 {
            Ok(())
        } else {
            Err(invalid(field, format!("must be positive and finite, got {v}")))
        }
    }
    fn non_negative(field: &'static str, v: f64) -> Result<(), TargetError> {
        if v.is_finite() && v >= 0.0 {
            Ok(())
        } else {
            Err(invalid(field, format!("must be non-negative and finite, got {v}")))
        }
    }
    if spec.name.is_empty() {
        return Err(invalid("name", "spec name must be non-empty".to_string()));
    }
    if spec.num_cores == 0 {
        return Err(invalid("num_cores", "an accelerator has at least one core".to_string()));
    }
    positive("peak_gflops_per_core", spec.peak_gflops_per_core)?;
    positive("mem_bw_gbps", spec.mem_bw_gbps)?;
    positive("mem_bytes", spec.mem_bytes)?;
    positive("core_freq_ghz", spec.core_freq_ghz)?;
    positive("fill_gops", spec.fill_gops)?;
    if spec.channel_granularity == 0 {
        return Err(invalid(
            "channel_granularity",
            "channel partitions are at least one channel wide".to_string(),
        ));
    }
    if spec.channel_granularity > MAX_CHANNEL_GRANULARITY {
        return Err(invalid(
            "channel_granularity",
            format!(
                "{} exceeds the widest supported channel block ({})",
                spec.channel_granularity, MAX_CHANNEL_GRANULARITY
            ),
        ));
    }
    non_negative("launch_overhead_us", spec.launch_overhead_us)?;
    non_negative("sync_us_per_core", spec.sync_us_per_core)?;
    non_negative("fused_layer_us", spec.fused_layer_us)?;
    let min_tile = min_tile_bytes(spec.channel_granularity);
    let buffer_ok =
        spec.core_buffer_bytes.is_finite() && spec.core_buffer_bytes >= min_tile;
    if !buffer_ok {
        return Err(invalid(
            "core_buffer_bytes",
            format!(
                "per-core buffer {} B holds less than one tile ({} B at \
                 granularity {})",
                spec.core_buffer_bytes, min_tile, spec.channel_granularity
            ),
        ));
    }
    if spec.mem_bytes < spec.core_buffer_bytes {
        return Err(invalid(
            "mem_bytes",
            format!(
                "device memory {} B is smaller than one core's buffer {} B",
                spec.mem_bytes, spec.core_buffer_bytes
            ),
        ));
    }
    Ok(())
}

/// Validated, field-by-field [`AcceleratorSpec`] construction — the
/// replacement for struct-literal specs. Starts from the MLU100's
/// calibration so a builder only has to name what differs:
///
/// ```
/// use dlfusion::accel::{SpecBuilder, Target};
///
/// let spec = SpecBuilder::new("TwoCore-Lab")
///     .num_cores(2)
///     .mem_bw_gbps(51.2)
///     .build()
///     .expect("valid spec");
/// let target = Target::custom("lab2", "bring-up board", spec).expect("target");
/// assert_eq!(target.spec().num_cores, 2);
/// ```
#[derive(Debug, Clone)]
pub struct SpecBuilder {
    spec: AcceleratorSpec,
    /// Chip-wide `OpCount_critical` override; resolved into `fill_gops` at
    /// build time so the setter order never matters.
    opcount_critical: Option<f64>,
}

impl SpecBuilder {
    /// A builder seeded with the MLU100 calibration under `name`.
    pub fn new(name: impl Into<String>) -> SpecBuilder {
        let mut spec = Target::mlu100().into_spec();
        spec.name = name.into();
        SpecBuilder { spec, opcount_critical: None }
    }

    /// A builder seeded from an existing spec (e.g. a registry target's).
    pub fn from_spec(spec: AcceleratorSpec) -> SpecBuilder {
        SpecBuilder { spec, opcount_critical: None }
    }

    pub fn num_cores(mut self, n: usize) -> Self {
        self.spec.num_cores = n;
        self
    }

    pub fn peak_gflops_per_core(mut self, gflops: f64) -> Self {
        self.spec.peak_gflops_per_core = gflops;
        self
    }

    pub fn mem_bw_gbps(mut self, gbps: f64) -> Self {
        self.spec.mem_bw_gbps = gbps;
        self
    }

    pub fn mem_bytes(mut self, bytes: f64) -> Self {
        self.spec.mem_bytes = bytes;
        self
    }

    pub fn core_freq_ghz(mut self, ghz: f64) -> Self {
        self.spec.core_freq_ghz = ghz;
        self
    }

    /// Set the per-core pipeline-fill cost directly (GOPs per dispatch).
    pub fn fill_gops(mut self, gops: f64) -> Self {
        self.spec.fill_gops = gops;
        self.opcount_critical = None;
        self
    }

    /// Set the chip-wide `OpCount_critical` (GOPs) instead of `fill_gops`;
    /// `fill = critical / (9 * num_cores)` is derived at [`Self::build`],
    /// after every other setter, so it composes with [`Self::num_cores`] in
    /// any order.
    pub fn opcount_critical(mut self, gops: f64) -> Self {
        self.opcount_critical = Some(gops);
        self
    }

    pub fn channel_granularity(mut self, channels: usize) -> Self {
        self.spec.channel_granularity = channels;
        self
    }

    pub fn launch_overhead_us(mut self, us: f64) -> Self {
        self.spec.launch_overhead_us = us;
        self
    }

    pub fn sync_us_per_core(mut self, us: f64) -> Self {
        self.spec.sync_us_per_core = us;
        self
    }

    pub fn fused_layer_us(mut self, us: f64) -> Self {
        self.spec.fused_layer_us = us;
        self
    }

    pub fn core_buffer_bytes(mut self, bytes: f64) -> Self {
        self.spec.core_buffer_bytes = bytes;
        self
    }

    /// Validate every field and produce the spec.
    pub fn build(mut self) -> Result<AcceleratorSpec, TargetError> {
        if let Some(crit) = self.opcount_critical {
            let crit_ok = crit.is_finite() && crit > 0.0;
            if !crit_ok {
                return Err(TargetError::InvalidSpec {
                    field: "opcount_critical",
                    reason: format!("must be positive and finite, got {crit}"),
                });
            }
            if self.spec.num_cores > 0 {
                self.spec.fill_gops = crit / 9.0 / self.spec.num_cores as f64;
            }
        }
        validate_spec(&self.spec)?;
        Ok(self.spec)
    }

    /// Validate and wrap straight into a custom [`Target`].
    pub fn build_target(self, registry_name: impl Into<String>,
                        description: impl Into<String>) -> Result<Target, TargetError> {
        Target::custom(registry_name, description, self.build()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_every_name() {
        for &name in Target::NAMES {
            let t = Target::by_name(name).unwrap();
            assert_eq!(t.name(), name);
            validate_spec(t.spec()).unwrap();
        }
        assert_eq!(Target::all().len(), Target::NAMES.len());
        assert_eq!(Target::all()[0].name(), "mlu100");
        assert_eq!(Target::default().name(), "mlu100");
    }

    #[test]
    fn unknown_name_is_a_typed_error() {
        let err = Target::by_name("mlu9000").unwrap_err();
        assert_eq!(err, TargetError::UnknownTarget { name: "mlu9000".to_string() });
        assert!(err.to_string().contains("mlu100"), "{err}");
    }

    #[test]
    fn mlu100_spec_is_the_paper_point() {
        let s = Target::mlu100().into_spec();
        assert_eq!(s.num_cores, 32);
        assert_eq!(s.peak_gflops(), 64_000.0);
        assert_eq!(s.mem_bw_gbps, 102.4);
        assert!((s.opcount_critical() - 10f64.powf(1.25)).abs() < 1e-9);
    }

    #[test]
    fn registry_points_differ_where_they_should() {
        let mlu100 = Target::mlu100();
        let mlu270 = Target::mlu270();
        let edge = Target::edge4();
        let hbm = Target::hbm32();
        assert_eq!(mlu270.spec().num_cores, 2 * mlu100.spec().num_cores);
        assert_eq!(edge.spec().num_cores, 4);
        assert!(edge.spec().peak_gflops() < mlu100.spec().peak_gflops());
        // hbm32 is the mlu100 compute point behind fatter memory.
        assert_eq!(hbm.spec().peak_gflops(), mlu100.spec().peak_gflops());
        assert_eq!(hbm.spec().num_cores, mlu100.spec().num_cores);
        assert!(hbm.spec().mem_bw_gbps >= 10.0 * mlu100.spec().mem_bw_gbps);
        // The same per-core ramp means the bigger chip's chip-wide critical
        // op count doubles.
        assert!((mlu270.spec().opcount_critical()
                 - 2.0 * mlu100.spec().opcount_critical())
                    .abs()
                    < 1e-9);
    }

    #[test]
    fn builder_accepts_the_registry_points() {
        for t in Target::all() {
            let rebuilt = SpecBuilder::from_spec(t.spec().clone()).build().unwrap();
            assert_eq!(&rebuilt, t.spec());
        }
    }

    #[test]
    fn builder_rejects_each_invalid_field() {
        fn field_of(err: TargetError) -> &'static str {
            match err {
                TargetError::InvalidSpec { field, .. } => field,
                other => panic!("expected InvalidSpec, got {other:?}"),
            }
        }
        let bad = [
            (SpecBuilder::new("x").num_cores(0), "num_cores"),
            (SpecBuilder::new("x").peak_gflops_per_core(0.0), "peak_gflops_per_core"),
            (SpecBuilder::new("x").mem_bw_gbps(0.0), "mem_bw_gbps"),
            (SpecBuilder::new("x").mem_bw_gbps(-102.4), "mem_bw_gbps"),
            (SpecBuilder::new("x").mem_bytes(f64::NAN), "mem_bytes"),
            (SpecBuilder::new("x").core_freq_ghz(0.0), "core_freq_ghz"),
            (SpecBuilder::new("x").fill_gops(0.0), "fill_gops"),
            (SpecBuilder::new("x").opcount_critical(-1.0), "opcount_critical"),
            (SpecBuilder::new("x").channel_granularity(0), "channel_granularity"),
            (
                SpecBuilder::new("x").channel_granularity(MAX_CHANNEL_GRANULARITY + 1),
                "channel_granularity",
            ),
            (SpecBuilder::new("x").launch_overhead_us(-1.0), "launch_overhead_us"),
            (SpecBuilder::new("x").sync_us_per_core(f64::INFINITY), "sync_us_per_core"),
            (SpecBuilder::new("x").fused_layer_us(-0.5), "fused_layer_us"),
            (SpecBuilder::new("x").core_buffer_bytes(16.0), "core_buffer_bytes"),
            (SpecBuilder::new("x").mem_bytes(1024.0), "mem_bytes"),
            (SpecBuilder::new(""), "name"),
        ];
        for (builder, field) in bad {
            let err = builder.build().unwrap_err();
            assert_eq!(field_of(err), field);
        }
    }

    #[test]
    fn buffer_must_hold_one_tile() {
        let min = min_tile_bytes(4);
        assert!(SpecBuilder::new("x").core_buffer_bytes(min).build().is_ok());
        assert!(SpecBuilder::new("x").core_buffer_bytes(min - 1.0).build().is_err());
    }

    #[test]
    fn opcount_critical_setter_is_order_insensitive() {
        let a = SpecBuilder::new("x")
            .opcount_critical(40.0)
            .num_cores(64)
            .build()
            .unwrap();
        let b = SpecBuilder::new("x")
            .num_cores(64)
            .opcount_critical(40.0)
            .build()
            .unwrap();
        assert_eq!(a, b);
        assert!((a.opcount_critical() - 40.0).abs() < 1e-9);
    }

    #[test]
    fn custom_targets_validate_and_reject_registry_names() {
        let spec = SpecBuilder::new("Lab").num_cores(2).build().unwrap();
        let t = Target::custom("lab2", "bring-up", spec.clone()).unwrap();
        assert_eq!(t.name(), "lab2");
        assert_eq!(t.to_string(), "lab2 (Lab)");
        assert!(Target::custom("mlu100", "imposter", spec.clone()).is_err());
        assert!(Target::custom("", "anonymous", spec.clone()).is_err());
        let mut broken = spec;
        broken.num_cores = 0;
        assert!(matches!(Target::custom("lab0", "broken", broken),
                         Err(TargetError::InvalidSpec { field: "num_cores", .. })));
    }

    #[test]
    fn simulator_records_the_target_name() {
        let sim = Target::edge4().simulator();
        assert_eq!(sim.target(), "edge4");
        assert_eq!(sim.spec.num_cores, 4);
        // Raw specs carry a name + field-fingerprint label, so two
        // different custom chips never alias each other in the serving
        // guard — even when their spec *names* collide.
        let raw = Simulator::from_spec(Target::edge4().into_spec()).unwrap();
        assert!(raw.target().starts_with("custom:Edge-4#"), "{}", raw.target());
        let same = Simulator::from_spec(Target::edge4().into_spec()).unwrap();
        assert_eq!(raw.target(), same.target());
        let mut renamed = Target::mlu270().into_spec();
        renamed.name = "Edge-4".to_string();
        let impostor = Simulator::from_spec(renamed).unwrap();
        assert_ne!(raw.target(), impostor.target());
        // And from_spec validates like the builder does.
        let mut broken = Target::edge4().into_spec();
        broken.channel_granularity = 0;
        assert!(matches!(Simulator::from_spec(broken),
                         Err(TargetError::InvalidSpec { field: "channel_granularity", .. })));
        // … and the label space is reserved against registry impersonation.
        let spec = Target::edge4().into_spec();
        assert!(Target::custom("custom", "imposter", spec.clone()).is_err());
        assert!(Target::custom("custom:Edge-4", "imposter", spec).is_err());
    }
}
