//! Halo-redundancy accounting for fused blocks (Fig. 7(a)).
//!
//! A fused block executes tile-wise: the block's spatial extent is split into
//! `mp` row bands, and each core carries its band through every layer of the
//! block with intermediates kept on-chip. Because convolution needs a
//! neighbourhood, each band must be computed with a *halo* whose height at
//! layer `l` is the receptive-field reach of all downstream layers in the
//! block — rows that adjacent cores compute too. That overlap is the
//! *redundant computation* the paper trades against fusion's benefits: it
//! grows both with block depth (more downstream radii) and with MP (more
//! band boundaries), which is exactly the Fig. 7(b)/(c) behaviour.
//!
//! With MP = 1 there is a single band and no internal boundary: no redundant
//! work — matching the paper's note that "using a single core will not
//! introduce redundant computation".

use crate::graph::Layer;

/// Downstream halo requirement (in rows of each layer's *output*) for every
/// layer of a fused block.
///
/// Walking backward from the block's last layer: `H_last = 0`, and a layer
/// followed by a layer with kernel radius `r` needs `H_prev = H_next + r`
/// rows beyond its band.
///
/// At spatial-reduction layers (stride > 1, pooling) the runtime *re-tiles*
/// the fused block: cores synchronize and the band partition restarts at the
/// reduced resolution, so the halo pyramid resets instead of compounding
/// through the stride (this is also how fused-layer accelerators bound the
/// recomputation pyramid — Alwani et al. fuse within a resolution stage).
pub fn downstream_halos(layers: &[Layer]) -> Vec<usize> {
    let mut halos = vec![0usize; layers.len()];
    let mut acc = 0usize;
    for i in (0..layers.len()).rev() {
        halos[i] = acc;
        // Entering layer i from below: its own radius extends the
        // requirement imposed on whatever precedes it — unless it re-tiles.
        let stride = match &layers[i].kind {
            crate::graph::LayerKind::Conv(c) => c.stride,
            crate::graph::LayerKind::Pool { stride, .. } => *stride,
            _ => 1,
        };
        if stride > 1 {
            acc = layers[i].halo_radius();
        } else {
            acc += layers[i].halo_radius();
        }
    }
    halos
}

/// Redundancy factor for layer `l` of a fused block at MP = `mp`:
/// total rows computed across cores divided by the layer's real rows.
///
/// Each of the `mp - 1` internal band boundaries adds `2 * halo` overlap
/// rows, clamped so no core computes more than the full image.
pub fn layer_redundancy(rows: usize, halo: usize, mp: usize) -> f64 {
    assert!(rows >= 1);
    assert!(mp >= 1);
    if mp == 1 {
        return 1.0;
    }
    let band = (rows as f64 / mp as f64).ceil();
    // Rows one core computes, clamped to the image.
    let per_core = (band + 2.0 * halo as f64).min(rows as f64);
    (per_core * mp as f64) / rows as f64
}

/// Total redundancy-weighted op count (GOPs) of a fused block at MP = `mp`,
/// plus the per-layer redundancy factors.
pub fn block_redundant_gops(layers: &[Layer], mp: usize) -> (f64, Vec<f64>) {
    let halos = downstream_halos(layers);
    let mut factors = Vec::with_capacity(layers.len());
    let mut total = 0.0;
    for (layer, &halo) in layers.iter().zip(&halos) {
        let rows = layer.output_shape().h.max(1);
        let rho = layer_redundancy(rows, halo, mp);
        factors.push(rho);
        total += layer.op_gops() * rho;
    }
    (total, factors)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::layer::{ConvSpec, Layer, LayerKind, TensorShape};

    fn conv_chain(n: usize, hw: usize) -> Vec<Layer> {
        (0..n)
            .map(|i| Layer::conv(format!("c{i}"), ConvSpec::same(8, 8, hw, 3)))
            .collect()
    }

    #[test]
    fn halos_accumulate_backward() {
        // Three 3x3 convs: downstream halos are [2, 1, 0].
        let h = downstream_halos(&conv_chain(3, 56));
        assert_eq!(h, vec![2, 1, 0]);
    }

    #[test]
    fn halos_reset_at_stride_boundaries() {
        let mut layers = conv_chain(1, 56);
        layers.push(Layer::conv(
            "s2",
            ConvSpec { c_in: 8, c_out: 8, h_in: 56, w_in: 56, k: 3, stride: 2, pad: 1, groups: 1 },
        ));
        layers.push(Layer::conv("c2", ConvSpec::same(8, 8, 28, 3)));
        // From the back: acc=0; after c2: acc=1; s2 re-tiles: acc resets to
        // its own radius (1); halos = [1, 1, 0].
        assert_eq!(downstream_halos(&layers), vec![1, 1, 0]);
    }

    #[test]
    fn relu_layers_are_halo_transparent() {
        let mut layers = conv_chain(1, 56);
        layers.push(Layer::new("r", LayerKind::ReLU { shape: TensorShape::new(56, 56, 8) }));
        layers.push(Layer::conv("c1", ConvSpec::same(8, 8, 56, 3)));
        assert_eq!(downstream_halos(&layers), vec![1, 1, 0]);
    }

    #[test]
    fn single_core_no_redundancy() {
        assert_eq!(layer_redundancy(56, 10, 1), 1.0);
        let (g, factors) = block_redundant_gops(&conv_chain(8, 56), 1);
        let plain: f64 = conv_chain(8, 56).iter().map(|l| l.op_gops()).sum();
        assert!((g - plain).abs() < 1e-12);
        assert!(factors.iter().all(|&f| f == 1.0));
    }

    #[test]
    fn redundancy_grows_with_mp() {
        let mut last = 1.0;
        for mp in [2, 4, 8, 16] {
            let r = layer_redundancy(56, 2, mp);
            assert!(r >= last, "mp={mp}");
            last = r;
        }
    }

    #[test]
    fn redundancy_grows_with_depth() {
        let (g4, _) = block_redundant_gops(&conv_chain(4, 56), 4);
        let (g8, _) = block_redundant_gops(&conv_chain(8, 56), 4);
        let plain4: f64 = conv_chain(4, 56).iter().map(|l| l.op_gops()).sum();
        let plain8: f64 = conv_chain(8, 56).iter().map(|l| l.op_gops()).sum();
        // Relative redundancy (weighted) must increase with depth.
        assert!(g8 / plain8 > g4 / plain4);
    }

    #[test]
    fn clamped_at_full_image() {
        // Halo so large each core computes the whole image: factor == mp.
        let r = layer_redundancy(10, 50, 4);
        assert!((r - 4.0).abs() < 1e-12);
    }

    #[test]
    fn last_layer_never_redundant() {
        let (_, factors) = block_redundant_gops(&conv_chain(5, 56), 8);
        assert_eq!(*factors.last().unwrap(), 1.0);
    }
}
