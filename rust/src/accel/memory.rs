//! Off-chip memory traffic model.
//!
//! Layer-wise execution writes every intermediate feature map off-chip and
//! reads it back for the next layer; a fused block only touches off-chip
//! memory for the block's input, its final output, and the weights of all
//! its layers — "the output of a layer can be generated on-chip and
//! immediately reused" (Section III.B). Fusion's working set must fit the
//! per-core on-chip buffer; intermediates that overflow spill (both
//! directions), eroding the benefit.

use super::fusion::downstream_halos;
use super::spec::AcceleratorSpec;
use crate::graph::Layer;

/// Off-chip bytes moved by one *unfused* layer (input + output + weights).
pub fn unfused_layer_bytes(layer: &Layer) -> f64 {
    layer.input_shape().bytes() + layer.output_shape().bytes() + layer.weight_bytes()
}

/// Off-chip traffic of a fused block at MP = `mp`, including spills.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BlockTraffic {
    /// Block input + final output bytes.
    pub boundary_bytes: f64,
    /// Sum of all layer weights in the block.
    pub weight_bytes: f64,
    /// Intermediate bytes that exceed on-chip capacity and round-trip.
    pub spill_bytes: f64,
}

impl BlockTraffic {
    pub fn total(&self) -> f64 {
        self.boundary_bytes + self.weight_bytes + self.spill_bytes
    }
}

/// Compute the fused block's off-chip traffic.
///
/// Per-core working set at the boundary after layer `l`: the band rows
/// (`rows/mp + 2*halo`, clamped to the image) times width times channels of
/// layer `l`'s output, double-buffered (producer + consumer tiles), plus the
/// next layer's weights. Whatever exceeds `core_buffer_bytes` spills:
/// that boundary's tensor is charged a full write + read.
pub fn fused_block_traffic(spec: &AcceleratorSpec, layers: &[Layer], mp: usize) -> BlockTraffic {
    assert!(!layers.is_empty());
    let first = &layers[0];
    let last = layers.last().unwrap();
    let boundary_bytes = first.input_shape().bytes() + last.output_shape().bytes();
    let weight_bytes: f64 = layers.iter().map(|l| l.weight_bytes()).sum();

    let halos = downstream_halos(layers);
    let mut spill_bytes = 0.0;
    for l in 0..layers.len().saturating_sub(1) {
        let out = layers[l].output_shape();
        let rows = out.h.max(1) as f64;
        let band_rows = (rows / mp as f64).ceil() + 2.0 * halos[l] as f64;
        let band_rows = band_rows.min(rows);
        let band_bytes = band_rows * out.w as f64 * out.c as f64
            * crate::graph::layer::BYTES_PER_ELEM;
        let next_weights = layers[l + 1].weight_bytes() / mp as f64;
        // Producer tile + consumer tile + stage weights resident together.
        let working = 2.0 * band_bytes + next_weights;
        if working > spec.core_buffer_bytes {
            // The boundary tensor round-trips off-chip.
            spill_bytes += 2.0 * out.bytes();
        }
    }
    BlockTraffic { boundary_bytes, weight_bytes, spill_bytes }
}

/// Off-chip traffic of a fused block serving a batched invocation of
/// `batch` samples at MP = `mp`.
///
/// The amortization at the heart of the batch-aware model
/// (rust/docs/DESIGN.md §10): weights are fetched **once per invocation**
/// regardless of batch, while the boundary activations and any spilled
/// intermediates move **once per sample**. Samples stream through the fused
/// block one at a time, so the per-core working set — and therefore which
/// boundaries spill — is exactly the batch-1 computation.
pub fn fused_block_traffic_batch(spec: &AcceleratorSpec, layers: &[Layer],
                                 mp: usize, batch: usize) -> BlockTraffic {
    assert!(batch >= 1, "batch must be at least 1");
    let per_sample = fused_block_traffic(spec, layers, mp);
    if batch == 1 {
        return per_sample;
    }
    let bf = batch as f64;
    BlockTraffic {
        boundary_bytes: bf * per_sample.boundary_bytes,
        weight_bytes: per_sample.weight_bytes,
        spill_bytes: bf * per_sample.spill_bytes,
    }
}

/// Off-chip bytes moved by one *unfused* layer serving `batch` samples in
/// one invocation: activations per sample, weights once.
pub fn unfused_layer_bytes_batch(layer: &Layer, batch: usize) -> f64 {
    assert!(batch >= 1, "batch must be at least 1");
    if batch == 1 {
        return unfused_layer_bytes(layer);
    }
    batch as f64 * (layer.input_shape().bytes() + layer.output_shape().bytes())
        + layer.weight_bytes()
}

/// Transfer time in milliseconds for `bytes` at the spec's bandwidth.
pub fn transfer_ms(spec: &AcceleratorSpec, bytes: f64) -> f64 {
    bytes / (spec.mem_bw_gbps * 1e9) * 1e3
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::layer::ConvSpec;

    fn spec() -> AcceleratorSpec {
        crate::accel::Target::mlu100().into_spec()
    }

    fn small_chain(n: usize) -> Vec<Layer> {
        (0..n)
            .map(|i| Layer::conv(format!("c{i}"), ConvSpec::same(64, 64, 56, 3)))
            .collect()
    }

    fn big_chain(n: usize) -> Vec<Layer> {
        (0..n)
            .map(|i| Layer::conv(format!("c{i}"), ConvSpec::same(64, 64, 224, 3)))
            .collect()
    }

    #[test]
    fn fusion_saves_intermediate_traffic() {
        let s = spec();
        let chain = small_chain(4);
        let unfused: f64 = chain.iter().map(unfused_layer_bytes).sum();
        let fused = fused_block_traffic(&s, &chain, 4);
        assert_eq!(fused.spill_bytes, 0.0, "56x56x64 bands must fit on-chip");
        assert!(fused.total() < unfused * 0.6,
                "fused {} vs unfused {unfused}", fused.total());
    }

    #[test]
    fn large_maps_spill() {
        let s = spec();
        // 224x224x64 fp16 = 6.4 MB per map; a 1-core band is the whole map,
        // far over the 2 MiB core buffer.
        let fused = fused_block_traffic(&s, &big_chain(3), 1);
        assert!(fused.spill_bytes > 0.0);
    }

    #[test]
    fn more_cores_shrink_working_set() {
        let s = spec();
        let spill_mp1 = fused_block_traffic(&s, &big_chain(3), 1).spill_bytes;
        let spill_mp32 = fused_block_traffic(&s, &big_chain(3), 32).spill_bytes;
        assert!(spill_mp32 <= spill_mp1);
    }

    #[test]
    fn single_layer_block_boundary_only() {
        let s = spec();
        let chain = small_chain(1);
        let t = fused_block_traffic(&s, &chain, 4);
        assert_eq!(t.spill_bytes, 0.0);
        assert!((t.total() - unfused_layer_bytes(&chain[0])).abs() < 1e-9);
    }

    #[test]
    fn batched_traffic_amortizes_weights_only() {
        let s = spec();
        let chain = small_chain(4);
        let b1 = fused_block_traffic_batch(&s, &chain, 4, 1);
        assert_eq!(b1, fused_block_traffic(&s, &chain, 4), "batch 1 is the seed path");
        let b8 = fused_block_traffic_batch(&s, &chain, 4, 8);
        // Weights once; boundary scales with batch; total strictly sub-linear.
        assert_eq!(b8.weight_bytes, b1.weight_bytes);
        assert!((b8.boundary_bytes - 8.0 * b1.boundary_bytes).abs() < 1e-9);
        assert!(b8.total() < 8.0 * b1.total());
    }

    #[test]
    fn batched_unfused_layer_amortizes_weights() {
        let chain = small_chain(1);
        let l = &chain[0];
        assert_eq!(unfused_layer_bytes_batch(l, 1), unfused_layer_bytes(l));
        let b4 = unfused_layer_bytes_batch(l, 4);
        assert!(b4 < 4.0 * unfused_layer_bytes(l));
        assert!(b4 > unfused_layer_bytes(l));
    }

    #[test]
    fn transfer_time_linear() {
        let s = spec();
        let t1 = transfer_ms(&s, 102.4e9); // one second worth
        assert!((t1 - 1000.0).abs() < 1e-9);
        assert!((transfer_ms(&s, 51.2e9) - 500.0).abs() < 1e-9);
    }
}
