//! The accelerator performance-simulator substrate.
//!
//! The paper's experiments run on a physical Cambricon MLU100; this module is
//! the synthetic equivalent (DESIGN.md §2): a multi-core accelerator model
//! whose observable behaviour — achieved GFLOPS vs operation count, channel-
//! granular partitioning, fusion halo redundancy, memory round-trips — is
//! shaped by the same mechanisms the paper characterizes in Sections II–III.
//! The optimizer and oracle only ever see `(latency, GFLOPS, FPS)` through
//! [`Simulator`], the same interface a real board would give them.
//!
//! - [`spec`]: Table I hardware parameters + the calibration constants;
//! - [`target`]: named, validated hardware targets + the built-in registry
//!   (`mlu100`, `mlu270`, `edge4`, `hbm32`) — the explicit-hardware API;
//! - [`efficiency`]: the per-core op-count→efficiency saturation curve;
//! - [`partition`]: channel-granular model-parallel tensor partitioning;
//! - [`fusion`]: halo-redundancy accounting for fused blocks (Fig. 7(a));
//! - [`memory`]: off-chip traffic for unfused layers vs fused blocks;
//! - [`sim`]: the latency model combining the above, [`Simulator`].

pub mod spec;
pub mod target;
pub mod efficiency;
pub mod partition;
pub mod fusion;
pub mod memory;
pub mod sim;
pub mod trace;

pub use sim::{BlockPerf, PerfReport, Simulator};
pub use spec::AcceleratorSpec;
pub use target::{SpecBuilder, Target, TargetError};
