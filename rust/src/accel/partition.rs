//! Channel-granular model-parallel partitioning of a single operator.
//!
//! Section IV.A: "the hardware partitions the tensor on channel dimension
//! with a certain minimal partition size". Requesting MP = m splits the
//! output-channel axis into `m` chunks of `ceil(C/m)` channels; each chunk is
//! padded up to the partition granularity `g`, and chunks beyond the channel
//! count leave their cores idle. This is the mechanism behind Fig. 6(a):
//! layers with the same op count but fewer channels stop benefiting from
//! cores earlier, and mis-sized chunks waste work on pad lanes.

use super::spec::AcceleratorSpec;

/// Result of partitioning `channels` across `mp` cores.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Partition {
    /// Cores that actually received channels.
    pub active_cores: usize,
    /// Useful channels in the widest chunk.
    pub chunk_channels: usize,
    /// Chunk width after padding to the granularity (what the core computes).
    pub padded_channels: usize,
}

impl Partition {
    /// Fraction of the widest core's computed lanes that are useful.
    pub fn utilization(&self) -> f64 {
        self.chunk_channels as f64 / self.padded_channels as f64
    }

    /// Work multiplier on the critical-path core relative to an ideal
    /// `channels/mp` split: `padded / ideal`.
    pub fn work_factor(&self, channels: usize, mp: usize) -> f64 {
        let ideal = channels as f64 / mp as f64;
        self.padded_channels as f64 / ideal
    }
}

/// Partition `channels` output channels over `mp` cores with the spec's
/// minimal granularity.
pub fn partition_channels(spec: &AcceleratorSpec, channels: usize, mp: usize) -> Partition {
    assert!(mp >= 1 && mp <= spec.num_cores, "MP {mp} out of range");
    assert!(channels >= 1);
    let g = spec.channel_granularity;
    let chunk = channels.div_ceil(mp);
    let padded = chunk.div_ceil(g) * g;
    let active = channels.div_ceil(chunk);
    Partition { active_cores: active, chunk_channels: chunk, padded_channels: padded }
}

/// Per-core op count (GOPs) on the critical path when a layer of `gops`
/// total work over `channels` output channels runs at MP = `mp`.
///
/// The critical-path core computes `padded_channels` lanes out of
/// `channels`, i.e. `gops * padded / channels`.
pub fn per_core_gops(spec: &AcceleratorSpec, gops: f64, channels: usize, mp: usize) -> f64 {
    let p = partition_channels(spec, channels, mp);
    gops * p.padded_channels as f64 / channels as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> AcceleratorSpec {
        crate::accel::Target::mlu100().into_spec()
    }

    #[test]
    fn exact_split_no_padding() {
        // 64 channels over 4 cores: 16-channel chunks, granularity-aligned.
        let p = partition_channels(&spec(), 64, 4);
        assert_eq!(p.active_cores, 4);
        assert_eq!(p.chunk_channels, 16);
        assert_eq!(p.padded_channels, 16);
        assert_eq!(p.utilization(), 1.0);
    }

    #[test]
    fn oversplit_pads() {
        // 6 channels over 4 cores: 2-channel chunks padded to the
        // granularity (4).
        let p = partition_channels(&spec(), 6, 4);
        assert_eq!(p.chunk_channels, 2);
        assert_eq!(p.padded_channels, 4);
        assert_eq!(p.utilization(), 0.5);
    }

    #[test]
    fn more_cores_than_channels_idles() {
        let p = partition_channels(&spec(), 8, 32);
        assert_eq!(p.chunk_channels, 1);
        assert_eq!(p.padded_channels, 4);
        assert_eq!(p.active_cores, 8);
    }

    #[test]
    fn per_core_gops_floors_at_granularity() {
        // Beyond ceil(C/g) useful cores, per-core work stops shrinking:
        // 64 channels bottom out at 16 partitions of one granule.
        let s = spec();
        let g16 = per_core_gops(&s, 1.0, 64, 16);
        let g32 = per_core_gops(&s, 1.0, 64, 32);
        assert!((g32 - g16).abs() < 1e-12, "{g32} vs {g16}");
        let g8 = per_core_gops(&s, 1.0, 64, 8);
        assert!(g8 > g16, "below the floor, more cores still shrink work");
    }

    #[test]
    fn wide_layers_keep_scaling() {
        let s = spec();
        let g8 = per_core_gops(&s, 1.0, 512, 8);
        let g32 = per_core_gops(&s, 1.0, 512, 32);
        assert!(g32 < g8 * 0.3);
    }

    #[test]
    fn work_factor_one_when_aligned() {
        let s = spec();
        let p = partition_channels(&s, 512, 32);
        assert!((p.work_factor(512, 32) - 1.0).abs() < 1e-12);
        let p2 = partition_channels(&s, 64, 32);
        assert!(p2.work_factor(64, 32) > 1.9); // 4 padded vs 2 ideal
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn zero_mp_rejected() {
        partition_channels(&spec(), 64, 0);
    }
}
