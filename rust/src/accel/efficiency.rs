//! The per-core efficiency curve: achieved fraction of peak as a function of
//! the operation count dispatched to the core.
//!
//! The paper's single-core characterization (Fig. 4(a), Fig. 3(b)) finds
//! performance efficiency "largely determined by operation count: the higher
//! the operation count, the better performance efficiency ... once the
//! operation count reaches a critical value, the performance will not
//! increase". We model this with a Michaelis–Menten saturation
//!
//! `eta(g) = g / (g + fill)`
//!
//! which has exactly the observed shape and a clean physical reading: each
//! dispatch pays a fixed pipeline-fill cost worth `fill` GOPs, so
//! `t_compute = (g + fill) / peak` — *strictly monotone* in real work (a
//! property the simulator-invariant test suite pins down).

use super::spec::AcceleratorSpec;

/// Fraction of per-core peak achieved when a core is dispatched `gops` of
/// work in one launch.
pub fn core_efficiency(spec: &AcceleratorSpec, gops: f64) -> f64 {
    assert!(gops >= 0.0);
    gops / (gops + spec.fill_gops)
}

/// Compute time (milliseconds) for one core to retire `gops` in one launch.
pub fn core_compute_ms(spec: &AcceleratorSpec, gops: f64) -> f64 {
    assert!(gops >= 0.0);
    // (g + fill) / peak, in seconds -> ms. peak is GFLOPS = GOP/s.
    (gops + spec.fill_gops) / spec.peak_gflops_per_core * 1e3
}

/// Achieved GFLOPS for a single-core dispatch of `gops`.
pub fn core_achieved_gflops(spec: &AcceleratorSpec, gops: f64) -> f64 {
    core_efficiency(spec, gops) * spec.peak_gflops_per_core
}

/// Fraction of per-core peak achieved when one launch carries `batch`
/// samples of `gops` each. The pipeline-fill cost is paid once per launch,
/// not once per sample, so efficiency rises monotonically with batch —
/// the compute side of the amortization the batch-aware latency model
/// charges (rust/docs/DESIGN.md §10).
pub fn core_efficiency_at_batch(spec: &AcceleratorSpec, gops: f64, batch: usize) -> f64 {
    assert!(batch >= 1, "batch must be at least 1");
    core_efficiency(spec, batch as f64 * gops)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> AcceleratorSpec {
        crate::accel::Target::mlu100().into_spec()
    }

    #[test]
    fn efficiency_monotone_increasing() {
        let s = spec();
        let mut last = 0.0;
        for i in 1..200 {
            let g = i as f64 * 0.25;
            let e = core_efficiency(&s, g);
            assert!(e > last, "eta not monotone at g={g}");
            last = e;
        }
    }

    #[test]
    fn efficiency_saturates_at_critical() {
        // Per core, 90% of peak at the per-core critical op count; the
        // chip-wide OpCount_critical of Table I is num_cores times that.
        let s = spec();
        let crit = s.opcount_critical_per_core();
        let e = core_efficiency(&s, crit);
        assert!((e - 0.9).abs() < 1e-9, "eta(critical) = {e}");
        assert!(core_efficiency(&s, 10.0 * crit) > 0.98);
        assert!((s.opcount_critical() - 32.0 * crit).abs() < 1e-9);
    }

    #[test]
    fn compute_time_monotone_in_work() {
        let s = spec();
        assert!(core_compute_ms(&s, 2.0) > core_compute_ms(&s, 1.0));
        assert!(core_compute_ms(&s, 0.001) > core_compute_ms(&s, 0.0) - 1e-12);
    }

    #[test]
    fn achieved_gflops_below_peak() {
        let s = spec();
        for g in [0.01, 0.1, 1.0, 10.0, 100.0] {
            let a = core_achieved_gflops(&s, g);
            assert!(a < s.peak_gflops_per_core);
            assert!(a > 0.0);
        }
    }

    #[test]
    fn zero_work_zero_efficiency() {
        assert_eq!(core_efficiency(&spec(), 0.0), 0.0);
    }

    #[test]
    fn batching_amortizes_the_fill_cost() {
        let s = spec();
        let g = 0.05;
        // Batch 1 is exactly the unbatched curve.
        assert_eq!(core_efficiency_at_batch(&s, g, 1), core_efficiency(&s, g));
        // Efficiency is strictly monotone in batch (fill paid once).
        let mut last = 0.0;
        for b in [1usize, 2, 4, 8, 16] {
            let e = core_efficiency_at_batch(&s, g, b);
            assert!(e > last, "eta not monotone at batch {b}");
            last = e;
        }
        assert!(last < 1.0);
    }
}
