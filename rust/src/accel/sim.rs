//! The latency model: single operators, fused blocks, and whole schedules.
//!
//! Latency of one compiled operator / fused block at MP = m:
//!
//! ```text
//! t = max(t_compute, t_mem) + t_launch + m * t_sync
//! t_compute = (g_core + fill) / peak_core          [+ per-layer issue cost]
//! t_mem     = traffic / BW
//! ```
//!
//! `g_core` is the critical-path core's op count: channel-partitioned (with
//! granularity padding) for single operators, spatial-band partitioned (with
//! halo redundancy) for fused blocks. `max(compute, mem)` models the
//! double-buffered DMA overlap the CNML runtime performs.

use super::efficiency;
use super::fusion;
use super::memory;
use super::partition;
use super::spec::AcceleratorSpec;
use super::target::Target;
use crate::graph::{Layer, Model};
use crate::optimizer::schedule::Schedule;

/// Per-block outcome inside a [`PerfReport`].
#[derive(Debug, Clone, PartialEq)]
pub struct BlockPerf {
    /// Layer index range `[start, end)` in the model.
    pub start: usize,
    pub end: usize,
    pub mp: usize,
    pub latency_ms: f64,
    /// Useful (non-redundant) op count, GOPs.
    pub gops: f64,
    /// Redundancy-weighted op count actually computed, GOPs.
    pub computed_gops: f64,
    pub fused: bool,
}

/// Outcome of simulating a schedule.
#[derive(Debug, Clone, PartialEq)]
pub struct PerfReport {
    pub model_name: String,
    pub total_ms: f64,
    pub total_gops: f64,
    pub blocks: Vec<BlockPerf>,
}

impl PerfReport {
    /// Frames per second at batch 1 — the paper's Fig. 10 metric.
    pub fn fps(&self) -> f64 {
        1000.0 / self.total_ms
    }

    /// End-to-end achieved GFLOPS (useful ops / time).
    pub fn achieved_gflops(&self) -> f64 {
        self.total_gops / (self.total_ms / 1e3)
    }

    /// Total redundant op count introduced by fusion, GOPs.
    pub fn redundant_gops(&self) -> f64 {
        self.blocks
            .iter()
            .map(|b| b.computed_gops - b.gops)
            .sum()
    }
}

/// The accelerator simulator (see module docs and rust/docs/DESIGN.md §6).
///
/// A simulator models one explicit hardware [`Target`] (rust/docs/DESIGN.md
/// §11) and records which one, so everything derived from it — tuning
/// outcomes, serving plans — can name the hardware it was planned for.
#[derive(Debug, Clone)]
pub struct Simulator {
    pub spec: AcceleratorSpec,
    /// Registry name of the simulated target (`custom:<spec name>#<hash>`
    /// when built from a raw spec — see [`Simulator::from_spec`]).
    target: String,
}

impl Simulator {
    /// Simulate an explicit hardware target (the canonical constructor).
    pub fn new(target: Target) -> Self {
        let (name, spec) = target.into_parts();
        Simulator { spec, target: name }
    }

    /// Simulate a raw spec outside the registry (spec-level experiments).
    /// The spec passes the same [`super::target::validate_spec`] gate as a
    /// [`Target`], so garbage hardware (zero cores, zero granularity) is a
    /// typed error here too, not a panic in the model layers. The recorded
    /// target name is `custom:<spec name>#<field fingerprint>` — the
    /// fingerprint keeps two *different* raw-spec chips from ever carrying
    /// the same label (the serving cluster refuses to co-schedule plans
    /// whose labels differ). Mutating `Simulator::spec` *after*
    /// construction bypasses both guarantees; that pub field stays mutable
    /// for experiments on the understanding that derived plans are then on
    /// the experimenter.
    pub fn from_spec(spec: AcceleratorSpec) -> Result<Self, super::target::TargetError> {
        super::target::validate_spec(&spec)?;
        let target = format!("{}:{}#{:016x}", Target::CUSTOM, spec.name,
                             spec_fingerprint(&spec));
        Ok(Simulator { spec, target })
    }

    /// The MLU100 default target.
    #[deprecated(note = "use Simulator::new(Target::mlu100()) — or --target on the CLI")]
    pub fn mlu100() -> Self {
        Simulator::new(Target::mlu100())
    }

    /// Registry name of the target this simulator models.
    pub fn target(&self) -> &str {
        &self.target
    }
}

/// FNV-1a over the spec's `Debug` rendering: a cheap, deterministic digest
/// of every field's bits, so equal specs share a `custom:` label and any
/// field difference changes it.
fn spec_fingerprint(spec: &AcceleratorSpec) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in format!("{spec:?}").bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

impl Simulator {
    /// Latency (ms) of one *unfused* operator at MP = `mp`
    /// (channel-partitioned, Section IV.A).
    pub fn layer_latency_ms(&self, layer: &Layer, mp: usize) -> f64 {
        let s = &self.spec;
        let gops = layer.op_gops();
        let channels = layer.channels().max(1);
        let g_core = partition::per_core_gops(s, gops, channels, mp);
        let t_compute = efficiency::core_compute_ms(s, g_core);
        let t_mem = memory::transfer_ms(s, memory::unfused_layer_bytes(layer));
        t_compute.max(t_mem) + self.overheads_ms(mp)
    }

    /// Latency (ms) of a fused block of consecutive layers at MP = `mp`
    /// (spatial-band partitioned with halo redundancy, Section IV.B).
    ///
    /// A one-layer block is just the operator compiled alone and takes the
    /// unfused path.
    pub fn block_latency_ms(&self, layers: &[Layer], mp: usize) -> f64 {
        assert!(!layers.is_empty(), "empty fusion block");
        if layers.len() == 1 {
            return self.layer_latency_ms(&layers[0], mp);
        }
        let s = &self.spec;
        let (computed_gops, _) = fusion::block_redundant_gops(layers, mp);
        let g_core = computed_gops / mp as f64;
        let t_compute = efficiency::core_compute_ms(s, g_core)
            + s.fused_layer_us * layers.len() as f64 / 1e3;
        let traffic = memory::fused_block_traffic(s, layers, mp);
        let t_mem = memory::transfer_ms(s, traffic.total());
        // Every spatial-reduction layer inside the block re-tiles the band
        // partition (see fusion::downstream_halos): a full multi-core
        // barrier + data redistribution, charged per participating core.
        let barriers = layers
            .iter()
            .filter(|l| match &l.kind {
                crate::graph::LayerKind::Conv(c) => c.stride > 1,
                crate::graph::LayerKind::Pool { stride, .. } => *stride > 1,
                _ => false,
            })
            .count();
        let t_retile = s.sync_us_per_core * mp as f64 * barriers as f64 / 1e3;
        t_compute.max(t_mem) + t_retile + self.overheads_ms(mp)
    }

    fn overheads_ms(&self, mp: usize) -> f64 {
        (self.spec.launch_overhead_us + self.spec.sync_us_per_core * mp as f64) / 1e3
    }

    /// Evaluate a fused block's latency for *many* MP settings at once.
    ///
    /// Hot path of the brute-force oracle's DP (rust/docs/DESIGN.md §7): the
    /// per-layer quantities that don't depend on MP — downstream halos, op
    /// counts, output geometry, weight bytes — are derived once per candidate
    /// block (via [`crate::cost::ModelFacts`], the single home of that math)
    /// instead of once per (block, MP) pair. Identical results to calling
    /// [`Self::block_latency_ms`] per MP (pinned by a unit test here and by
    /// the property test in `rust/tests/cost_engine.rs`). Callers evaluating
    /// many blocks of the *same* model should go through
    /// [`crate::cost::CostEngine`], which derives the facts once per model
    /// and memoizes each `(block, mp)` outcome.
    pub fn block_latency_ms_multi(&self, layers: &[Layer], mps: &[usize]) -> Vec<f64> {
        assert!(!layers.is_empty());
        let facts = crate::cost::ModelFacts::from_layers(layers);
        mps.iter()
            .map(|&mp| facts.block_latency_ms_sweep(&self.spec, 0, layers.len(), mp))
            .collect()
    }

    /// Latency (ms) of one *unfused* operator serving a batched invocation
    /// of `batch` samples at MP = `mp`. `batch == 1` **is**
    /// [`Self::layer_latency_ms`], bit for bit; larger batches charge
    /// compute and activation movement per sample and the weight fetch plus
    /// launch/sync overheads once per invocation (rust/docs/DESIGN.md §10).
    /// This is the reference path [`crate::cost::ModelFacts::layer_latency_ms_at`]
    /// replays on the fact tables (pinned bit-identical there).
    pub fn layer_latency_ms_batch(&self, layer: &Layer, mp: usize, batch: usize) -> f64 {
        assert!(batch >= 1, "batch must be at least 1");
        if batch == 1 {
            return self.layer_latency_ms(layer, mp);
        }
        let s = &self.spec;
        let channels = layer.channels().max(1);
        let g_core = batch as f64
            * partition::per_core_gops(s, layer.op_gops(), channels, mp);
        let t_compute = efficiency::core_compute_ms(s, g_core);
        let t_mem =
            memory::transfer_ms(s, memory::unfused_layer_bytes_batch(layer, batch));
        t_compute.max(t_mem) + self.overheads_ms(mp)
    }

    /// Latency (ms) of a fused block serving a batched invocation of
    /// `batch` samples at MP = `mp`. `batch == 1` **is**
    /// [`Self::block_latency_ms`], bit for bit. Like
    /// [`Self::block_latency_ms_multi`], the batch math has a single home in
    /// [`crate::cost::ModelFacts`]; callers evaluating many blocks of the
    /// same model should go through [`crate::cost::CostEngine`], whose cache
    /// is keyed by `(start, end, mp, batch)`.
    pub fn block_latency_ms_batch(&self, layers: &[Layer], mp: usize, batch: usize) -> f64 {
        assert!(!layers.is_empty(), "empty fusion block");
        assert!(batch >= 1, "batch must be at least 1");
        if batch == 1 {
            return self.block_latency_ms(layers, mp);
        }
        let facts = crate::cost::ModelFacts::from_layers(layers);
        facts.block_latency_ms_at(&self.spec, 0, layers.len(), mp, batch)
    }

    /// Achieved GFLOPS of one unfused operator at MP = `mp` (useful ops only)
    /// — the y-axis of Figs. 3/4/6.
    pub fn layer_gflops(&self, layer: &Layer, mp: usize) -> f64 {
        layer.op_gops() / (self.layer_latency_ms(layer, mp) / 1e3)
    }

    /// The MP in `1..=num_cores` minimizing a single layer's latency
    /// (ground truth the Eq. 5 model approximates).
    pub fn best_layer_mp(&self, layer: &Layer) -> usize {
        self.spec
            .mp_range()
            .min_by(|&a, &b| {
                self.layer_latency_ms(layer, a)
                    .total_cmp(&self.layer_latency_ms(layer, b))
            })
            .unwrap()
    }

    /// Simulate a whole schedule over a model. Panics if the schedule does
    /// not exactly cover the model's layers (use `Schedule::validate`).
    pub fn run_schedule(&self, model: &Model, schedule: &Schedule) -> PerfReport {
        schedule
            .validate(model.num_layers(), self.spec.num_cores)
            .unwrap_or_else(|e| panic!("invalid schedule for '{}': {e}", model.name));
        let mut blocks = Vec::with_capacity(schedule.blocks.len());
        let mut total_ms = 0.0;
        let mut total_gops = 0.0;
        for b in &schedule.blocks {
            let layers = &model.layers[b.start..b.end];
            let gops: f64 = layers.iter().map(|l| l.op_gops()).sum();
            let (computed, latency) = if layers.len() == 1 {
                (gops, self.layer_latency_ms(&layers[0], b.mp))
            } else {
                let (c, _) = fusion::block_redundant_gops(layers, b.mp);
                (c, self.block_latency_ms(layers, b.mp))
            };
            total_ms += latency;
            total_gops += gops;
            blocks.push(BlockPerf {
                start: b.start,
                end: b.end,
                mp: b.mp,
                latency_ms: latency,
                gops,
                computed_gops: computed,
                fused: layers.len() > 1,
            });
        }
        PerfReport { model_name: model.name.clone(), total_ms, total_gops, blocks }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::layer::ConvSpec;
    use crate::optimizer::schedule::Schedule;
    use crate::zoo;

    fn sim() -> Simulator {
        Simulator::new(Target::mlu100())
    }

    fn conv(c: usize, hw: usize) -> Layer {
        Layer::conv("c", ConvSpec::same(c, c, hw, 3))
    }

    #[test]
    fn deprecated_mlu100_wrapper_is_the_registry_target() {
        #[allow(deprecated)]
        let legacy = Simulator::mlu100();
        assert_eq!(legacy.spec, sim().spec);
        assert_eq!(legacy.target(), "mlu100");
    }

    #[test]
    fn latency_positive_and_finite() {
        let s = sim();
        for mp in [1, 2, 4, 8, 16, 32] {
            let t = s.layer_latency_ms(&conv(64, 56), mp);
            assert!(t.is_finite() && t > 0.0);
        }
    }

    #[test]
    fn big_layers_prefer_more_cores() {
        // Fig. 4(c): large op count -> larger optimal MP.
        let s = sim();
        let small = conv(64, 28); // ~0.06 GOPs
        let big = conv(512, 56);  // ~14.8 GOPs
        assert!(s.best_layer_mp(&big) > s.best_layer_mp(&small));
    }

    #[test]
    fn channel_caps_useful_mp() {
        // Fig. 6(a): few channels -> small optimal MP even at high op count.
        let s = sim();
        let narrow = Layer::conv("n", ConvSpec::same(16, 16, 224, 3));
        let wide = Layer::conv("w", ConvSpec::same(256, 256, 56, 3));
        assert!(s.best_layer_mp(&narrow) < s.best_layer_mp(&wide));
    }

    #[test]
    fn fusing_identical_small_layers_helps() {
        // Fig. 7: fusing low-op-count layers beats layer-wise execution.
        let s = sim();
        let layers: Vec<Layer> = (0..4).map(|_| conv(64, 56)).collect();
        let fused = s.block_latency_ms(&layers, 4);
        let unfused: f64 = layers.iter().map(|l| s.layer_latency_ms(l, 4)).sum();
        assert!(fused < unfused, "fused {fused} vs unfused {unfused}");
    }

    #[test]
    fn oversized_fusion_hurts_big_layers() {
        // Fig. 7(b) Conv1 case: fusing many big layers at high MP loses to a
        // shallower block because of halo redundancy.
        let s = sim();
        let (c1, _) = zoo::synthetic::fig7_convs();
        let big: Vec<Layer> = (0..16).map(|i| Layer::conv(format!("c{i}"), c1)).collect();
        let t16 = s.block_latency_ms(&big, 32);
        let t4: f64 = big
            .chunks(4)
            .map(|ch| s.block_latency_ms(ch, 32))
            .sum();
        assert!(t4 < t16, "4-blocks {t4} vs one 16-block {t16}");
    }

    #[test]
    fn single_layer_block_equals_unfused() {
        let s = sim();
        let l = conv(128, 56);
        assert_eq!(s.block_latency_ms(std::slice::from_ref(&l), 8),
                   s.layer_latency_ms(&l, 8));
    }

    #[test]
    fn run_schedule_sums_blocks() {
        let s = sim();
        let m = zoo::mini_cnn();
        let sched = Schedule::uniform_blocks(m.num_layers(), 4, 2);
        let rep = s.run_schedule(&m, &sched);
        let sum: f64 = rep.blocks.iter().map(|b| b.latency_ms).sum();
        assert!((rep.total_ms - sum).abs() < 1e-12);
        assert!(rep.fps() > 0.0);
        assert!(rep.achieved_gflops() > 0.0);
    }

    #[test]
    #[should_panic(expected = "invalid schedule")]
    fn run_schedule_rejects_gap() {
        let s = sim();
        let m = zoo::mini_cnn();
        let mut sched = Schedule::uniform_blocks(m.num_layers(), 4, 2);
        sched.blocks.pop();
        s.run_schedule(&m, &sched);
    }

    #[test]
    fn multi_mp_matches_scalar_path() {
        // The §Perf fast path must be bit-identical to the reference path.
        let s = sim();
        let mps = s.spec.reduced_mp_set();
        for m in [zoo::resnet18(), zoo::vgg19(), zoo::mini_cnn()] {
            for (start, end) in [(0usize, 3usize), (2, 9), (0, m.num_layers())] {
                let layers = &m.layers[start..end.min(m.num_layers())];
                let fast = s.block_latency_ms_multi(layers, &mps);
                for (&mp, &f) in mps.iter().zip(&fast) {
                    let slow = s.block_latency_ms(layers, mp);
                    assert!((f - slow).abs() < 1e-12,
                            "{} [{start}..{end}] mp={mp}: {f} vs {slow}", m.name);
                }
            }
        }
    }

    #[test]
    fn batch_one_matches_unbatched_bit_for_bit() {
        let s = sim();
        let layers: Vec<Layer> = (0..4).map(|_| conv(64, 56)).collect();
        for mp in [1usize, 4, 32] {
            assert_eq!(s.block_latency_ms_batch(&layers, mp, 1),
                       s.block_latency_ms(&layers, mp));
            assert_eq!(s.layer_latency_ms_batch(&layers[0], mp, 1),
                       s.layer_latency_ms(&layers[0], mp));
        }
    }

    #[test]
    fn batched_block_amortizes_weight_movement() {
        // The tentpole invariant: a batch-b invocation is strictly cheaper
        // than b batch-1 invocations (weights, fill, launch paid once), but
        // never cheaper than one batch-1 invocation.
        let s = sim();
        let layers: Vec<Layer> = (0..4).map(|_| conv(128, 56)).collect();
        for mp in [1usize, 8, 32] {
            let t1 = s.block_latency_ms_batch(&layers, mp, 1);
            for b in [2usize, 4, 8] {
                let tb = s.block_latency_ms_batch(&layers, mp, b);
                assert!(tb > t1, "mp={mp} b={b}");
                assert!(tb < b as f64 * t1, "mp={mp} b={b}: {tb} vs {}",
                        b as f64 * t1);
            }
        }
    }

    #[test]
    fn redundant_gops_reported() {
        let s = sim();
        let m = zoo::synthetic::identical_conv_model(
            "t", ConvSpec::same(64, 64, 56, 3), 8);
        let fused = Schedule::single_block(m.num_layers(), 8);
        let rep = s.run_schedule(&m, &fused);
        assert!(rep.redundant_gops() > 0.0);
        let unfused = Schedule::layerwise(m.num_layers(), 1);
        let rep2 = s.run_schedule(&m, &unfused);
        assert_eq!(rep2.redundant_gops(), 0.0);
    }
}
