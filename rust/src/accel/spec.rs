//! Hardware specification (paper Table I) and simulator calibration.

/// Accelerator hardware description + cost-model constants.
///
/// The Table I entries are the MLU100 datasheet values. The calibration
/// constants below them are *derived*, not free: `fill_gops` is pinned by the
/// paper's measured `OpCount_critical = 10^1.25 GOPs` (the per-core op count
/// where single-core performance saturates, Figs. 3(b)/4(a)/7(c)), and the
/// granularity/overhead terms are fitted so the characterization experiments
/// reproduce the paper's observed optima (see `benches/ablation.rs` for the
/// sensitivity study).
#[derive(Debug, Clone, PartialEq)]
pub struct AcceleratorSpec {
    pub name: String,

    // ---- Table I ----
    /// Number of cores (MP may use 1..=num_cores).
    pub num_cores: usize,
    /// Per-core peak FP16 throughput in GFLOPS (64 TFLOPS / 32 cores).
    pub peak_gflops_per_core: f64,
    /// Off-chip memory bandwidth, GB/s.
    pub mem_bw_gbps: f64,
    /// Device memory, bytes.
    pub mem_bytes: f64,
    /// Core frequency, GHz (informational).
    pub core_freq_ghz: f64,

    // ---- calibration ----
    /// Per-launch per-core pipeline-fill cost expressed in GOPs: a dispatch
    /// achieves eta(g) = g / (g + fill_gops) per core, reaching 90% of peak
    /// at g = 9*fill_gops per core. The paper's `OpCount_critical = 10^1.25`
    /// GOPs is the *chip-wide* saturation point (all 32 cores), i.e.
    /// `fill_gops = 10^1.25 / (9 * num_cores)` ≈ 62 MOPs (~31 µs of fill per
    /// dispatch — a plausible DMA/pipeline ramp for a 1 GHz accelerator).
    pub fill_gops: f64,
    /// Minimum channel-partition granularity (channels per core chunk).
    pub channel_granularity: usize,
    /// Fixed host-side launch overhead per compiled operator, microseconds.
    pub launch_overhead_us: f64,
    /// Multi-core coordination cost per participating core, microseconds
    /// (weight broadcast, barrier, output gather).
    pub sync_us_per_core: f64,
    /// Per-layer instruction-dispatch overhead inside a fused block,
    /// microseconds (fused layers share one launch but still issue).
    pub fused_layer_us: f64,
    /// Per-core on-chip buffer, bytes; fused intermediates beyond this spill.
    pub core_buffer_bytes: f64,
}

impl AcceleratorSpec {
    /// The Cambricon MLU100 (Table I) with the paper-derived calibration.
    /// The values live in the target registry
    /// ([`crate::accel::Target::mlu100`]); this wrapper remains for the
    /// pre-target API.
    #[deprecated(note = "use Target::mlu100().into_spec() (or keep the Target)")]
    pub fn mlu100() -> Self {
        super::target::Target::mlu100().into_spec()
    }

    /// Total chip peak, GFLOPS.
    pub fn peak_gflops(&self) -> f64 {
        self.peak_gflops_per_core * self.num_cores as f64
    }

    /// The paper's `OpCount_critical` (GOPs dispatched chip-wide at which
    /// performance saturates — Figs. 3(b)/4(a); `10^1.25` for the MLU100).
    pub fn opcount_critical(&self) -> f64 {
        9.0 * self.fill_gops * self.num_cores as f64
    }

    /// Per-core critical op count (the Algorithm 1 threshold compares
    /// `sum_Op / avg_mp` — a per-core quantity — against this).
    pub fn opcount_critical_per_core(&self) -> f64 {
        9.0 * self.fill_gops
    }

    /// Valid MP settings (1..=num_cores).
    pub fn mp_range(&self) -> impl Iterator<Item = usize> + '_ {
        1..=self.num_cores
    }

    /// The reduced MP choice set of the brute-force oracle (Section V.3),
    /// derived from the core count: every power of two up to `num_cores`,
    /// the `3·2^k` mid-points from 12 up (the paper's 12 and 24), and the
    /// full chip. For the 32-core MLU100 this is exactly the paper's
    /// `[1, 2, 4, 8, 12, 16, 24, 32]`; a 64-core target extends to 48 and
    /// 64 instead of silently capping at 32, and a non-power-of-two core
    /// count (e.g. 6) still offers the whole chip.
    pub fn reduced_mp_set(&self) -> Vec<usize> {
        let n = self.num_cores;
        let mut set: Vec<usize> = Vec::new();
        let mut p = 1usize;
        while p <= n {
            set.push(p);
            p *= 2;
        }
        let mut mid = 12usize;
        while mid <= n {
            set.push(mid);
            mid *= 2;
        }
        set.push(n);
        set.sort_unstable();
        set.dedup();
        set
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::Target;

    #[test]
    fn table1_values() {
        let s = Target::mlu100().into_spec();
        assert_eq!(s.num_cores, 32);
        assert_eq!(s.peak_gflops(), 64_000.0); // 64 TFLOPS FP16
        assert_eq!(s.mem_bw_gbps, 102.4);
        assert_eq!(s.mem_bytes, 8.0 * (1u64 << 30) as f64);
        // The deprecated wrapper is the registry point, bit for bit.
        #[allow(deprecated)]
        let legacy = AcceleratorSpec::mlu100();
        assert_eq!(legacy, s);
    }

    #[test]
    fn opcount_critical_matches_paper() {
        let s = Target::mlu100().into_spec();
        let crit = s.opcount_critical();
        assert!((crit - 10f64.powf(1.25)).abs() < 1e-9, "{crit}");
        assert!((crit - 17.78).abs() < 0.01);
        assert!((s.opcount_critical_per_core() - crit / 32.0).abs() < 1e-12);
    }

    #[test]
    fn reduced_mp_set_is_paper_list() {
        let s = Target::mlu100().into_spec();
        assert_eq!(s.reduced_mp_set(), vec![1, 2, 4, 8, 12, 16, 24, 32]);
    }

    #[test]
    fn reduced_mp_set_respects_core_count() {
        let mut s = Target::mlu100().into_spec();
        s.num_cores = 8;
        assert_eq!(s.reduced_mp_set(), vec![1, 2, 4, 8]);
    }

    #[test]
    fn reduced_mp_set_derives_from_the_core_count() {
        // A 64-core chip extends past 32 instead of capping there …
        let mut s = Target::mlu100().into_spec();
        s.num_cores = 64;
        assert_eq!(s.reduced_mp_set(), vec![1, 2, 4, 8, 12, 16, 24, 32, 48, 64]);
        // … and a non-power-of-two chip still offers its full core count.
        s.num_cores = 6;
        assert_eq!(s.reduced_mp_set(), vec![1, 2, 4, 6]);
        s.num_cores = 1;
        assert_eq!(s.reduced_mp_set(), vec![1]);
        // Every set is sorted, deduplicated, and caps at num_cores.
        for n in 1..=96usize {
            s.num_cores = n;
            let set = s.reduced_mp_set();
            assert!(set.windows(2).all(|w| w[0] < w[1]), "n={n}: {set:?}");
            assert_eq!(*set.last().unwrap(), n);
        }
    }
}
