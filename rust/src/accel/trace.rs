//! Execution traces: a per-block timeline + utilization breakdown for a
//! simulated run — the observability layer a deployed compiler ships with
//! (what a profiler would show on the real board).

use super::sim::{PerfReport, Simulator};
use crate::graph::Model;
use crate::optimizer::schedule::Schedule;
use crate::util::Table;

/// One timeline event.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub start_ms: f64,
    pub end_ms: f64,
    pub label: String,
    pub mp: usize,
    pub fused: bool,
    /// Useful GOPs retired.
    pub gops: f64,
    /// Redundant (halo) GOPs recomputed.
    pub redundant_gops: f64,
}

/// A full simulated-run trace.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    pub model_name: String,
    pub events: Vec<TraceEvent>,
    pub total_ms: f64,
}

impl Trace {
    /// Build from a simulation report.
    pub fn from_report(model: &Model, report: &PerfReport) -> Trace {
        let mut events = Vec::with_capacity(report.blocks.len());
        let mut clock = 0.0;
        for b in &report.blocks {
            let label = if b.end - b.start == 1 {
                model.layers[b.start].name.clone()
            } else {
                format!("fused[{}..{}] ({}…{})", b.start, b.end,
                        model.layers[b.start].name,
                        model.layers[b.end - 1].name)
            };
            events.push(TraceEvent {
                start_ms: clock,
                end_ms: clock + b.latency_ms,
                label,
                mp: b.mp,
                fused: b.fused,
                gops: b.gops,
                redundant_gops: b.computed_gops - b.gops,
            });
            clock += b.latency_ms;
        }
        Trace { model_name: model.name.clone(), events, total_ms: clock }
    }

    /// Convenience: simulate + trace in one call.
    pub fn capture(sim: &Simulator, model: &Model, schedule: &Schedule) -> Trace {
        Trace::from_report(model, &sim.run_schedule(model, schedule))
    }

    /// Fraction of total computed work that is halo redundancy.
    pub fn redundancy_ratio(&self) -> f64 {
        let useful: f64 = self.events.iter().map(|e| e.gops).sum();
        let red: f64 = self.events.iter().map(|e| e.redundant_gops).sum();
        if useful + red == 0.0 { 0.0 } else { red / (useful + red) }
    }

    /// Mean effective chip utilization: useful ops / (peak * makespan).
    pub fn utilization(&self, sim: &Simulator) -> f64 {
        let useful: f64 = self.events.iter().map(|e| e.gops).sum();
        useful / (sim.spec.peak_gflops() * self.total_ms / 1e3)
    }

    /// Render the timeline as a table.
    pub fn render(&self) -> String {
        let mut t = Table::new(&["t (ms)", "block", "MP", "GOPs", "halo GOPs", "dur (ms)"])
            .label_first()
            .align(1, crate::util::table::Align::Left)
            .with_title(&format!("trace: {} ({:.3} ms total)", self.model_name, self.total_ms));
        for e in &self.events {
            t.row(vec![
                format!("{:.3}", e.start_ms),
                e.label.clone(),
                e.mp.to_string(),
                format!("{:.3}", e.gops),
                format!("{:.3}", e.redundant_gops),
                format!("{:.3}", e.end_ms - e.start_ms),
            ]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer;
    use crate::zoo;

    #[test]
    fn trace_covers_makespan_contiguously() {
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let m = zoo::resnet18();
        let sched = optimizer::dlfusion_schedule(&m, &sim.spec);
        let trace = Trace::capture(&sim, &m, &sched);
        assert_eq!(trace.events.len(), sched.num_blocks());
        let mut clock = 0.0;
        for e in &trace.events {
            assert!((e.start_ms - clock).abs() < 1e-12);
            assert!(e.end_ms > e.start_ms);
            clock = e.end_ms;
        }
        assert!((clock - trace.total_ms).abs() < 1e-12);
    }

    #[test]
    fn redundancy_zero_for_layerwise() {
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let m = zoo::alexnet();
        let sched = optimizer::Schedule::layerwise(m.num_layers(), 1);
        let trace = Trace::capture(&sim, &m, &sched);
        assert_eq!(trace.redundancy_ratio(), 0.0);
    }

    #[test]
    fn fused_trace_reports_redundancy_and_utilization() {
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let m = zoo::vgg19();
        let sched = optimizer::dlfusion_schedule(&m, &sim.spec);
        let trace = Trace::capture(&sim, &m, &sched);
        assert!(trace.redundancy_ratio() > 0.0);
        let u = trace.utilization(&sim);
        assert!(u > 0.0 && u < 1.0, "utilization {u}");
        let rendered = trace.render();
        assert!(rendered.contains("fused["));
        assert!(rendered.contains("trace: vgg19"));
    }

    #[test]
    fn better_schedules_have_higher_utilization() {
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let m = zoo::vgg19();
        let base = Trace::capture(&sim, &m,
                                  &optimizer::Schedule::layerwise(m.num_layers(), 1));
        let opt = Trace::capture(&sim, &m,
                                 &optimizer::dlfusion_schedule(&m, &sim.spec));
        assert!(opt.utilization(&sim) > base.utilization(&sim));
    }
}
