//! # DLFusion
//!
//! A reproduction of *DLFusion: An Auto-Tuning Compiler for Layer Fusion on
//! Deep Neural Network Accelerator* (Liu et al., 2020) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! DLFusion jointly tunes the two execution hyper-parameters the Cambricon
//! MLU100's operator SDK exposes — **model parallelism** (number of cores an
//! operator runs on) and the **layer-fusion scheme** (how consecutive layers
//! are grouped into fused blocks) — using per-layer operation count and
//! channel size as features, instead of brute-forcing an `~10^75`-sized
//! joint space (paper Eq. 4).
//!
//! ## Crate layout (Layer 3: the Rust coordinator)
//!
//! | module | role |
//! |---|---|
//! | [`graph`] | layer-level IR, the branching DAG IR + graph rewrites, `.dlm` v1/v2 model format, op-count math (Eq. 1/2) (rust/docs/DESIGN.md §13) |
//! | [`zoo`] | built-in models: ResNet-18/50, VGG-19, AlexNet, MobileNetV2, synthetics, plus true-DAG ResNet variants |
//! | [`microbench`] | synthesized layer sweeps (the paper's Section II methodology) |
//! | [`accel`] | the accelerator performance-simulator substrate + the hardware-target registry (rust/docs/DESIGN.md §6, §11) |
//! | [`perfmodel`] | roofline, `OpCount_critical`, the `MP(C, Op)` scorer (Eq. 5) |
//! | [`cost`] | memoized, batch-aware cost-evaluation engine shared by every consumer (rust/docs/DESIGN.md §7, §10) |
//! | [`optimizer`] | Algorithm 1 and the seven evaluation strategies (Table III) |
//! | [`search`] | the reduced brute-force oracle (strategy 7), annealing, exhaustive certification |
//! | [`tuner`] | the unified tuning API: one request/outcome surface over every search backend (rust/docs/DESIGN.md §8) |
//! | [`learn`] | learned cost model + active-learning tuner: feature schema, log-space fit, residual-band pruning, cross-target transfer (rust/docs/DESIGN.md §16) |
//! | [`codegen`] | CNML-style C++ code generation (paper Fig. 9) |
//! | [`runtime`] | PJRT client: load AOT HLO-text artifacts, execute |
//! | [`coordinator`] | end-to-end driver: numerics via PJRT + perf via simulator |
//! | [`serving`] | multi-tenant serving simulator, load-aware (MP, batch) allocation, multi-chip fleet routing + plan cache (rust/docs/DESIGN.md §9, §10, §15) |
//! | [`stats`] | descriptive stats, regression, PCA (used for characterization) |
//! | [`obs`] | observability: span tracing, metrics registry, profiling hooks (rust/docs/DESIGN.md §14) |
//! | [`util`] | JSON, RNG, tables, CSV (offline-environment substitutes) |
//! | [`bench_harness`] | criterion-replacement used by `rust/benches/` |
//!
//! ## Quickstart
//!
//! ```no_run
//! use dlfusion::prelude::*;
//!
//! // Every run is *for* an explicit hardware target (rust/docs/DESIGN.md
//! // §11): look one up in the registry (`mlu100`, `mlu270`, `edge4`,
//! // `hbm32`) or build your own with `SpecBuilder` + `Target::custom`.
//! let target = Target::by_name("mlu100").expect("registry target");
//! let sim = Simulator::new(target);
//! let model = zoo::resnet18();
//! // One declarative request; any backend (`Algorithm1`, `OracleDp`,
//! // `Annealer`, `Exhaustive`, `TableStrategy`) runs against it.
//! let request = TuningRequest::new(&sim, &model);
//! let outcome = request.run(&mut Algorithm1).expect("tuning");
//! println!("{}: {} blocks, {:.1} FPS predicted",
//!          model.name, outcome.schedule.num_blocks(), outcome.fps());
//!
//! // `--tuner learned` / `ActiveTuner` fits a surrogate on cost-engine
//! // samples and queries the real engine only where the surrogate is
//! // uncertain, reporting the pruning as `TuningStats::evals_saved`
//! // (rust/docs/DESIGN.md §16).
//! let outcome = request.run(&mut ActiveTuner::new()).expect("tuning");
//! println!("learned: {} evals saved", outcome.stats.evals_saved);
//!
//! // Branching models are first-class: a DAG workload linearizes to a
//! // topological layer order plus the set of fusion-legal cut points, and
//! // every backend honors the constraint (rust/docs/DESIGN.md §13).
//! let dag = zoo::resnet18_dag();
//! let lin = linearize(&dag).expect("valid dag");
//! let request = TuningRequest::new(&sim, &lin.model);
//! let request = match lin.cuts {
//!     Some(cuts) => request.allowed_cuts(cuts),
//!     None => request, // pure chain: the unconstrained path, bit-identical
//! };
//! let outcome = request.run(&mut Algorithm1).expect("tuning");
//! println!("{}: {} blocks", dag.name, outcome.schedule.num_blocks());
//!
//! // Serving is builder-driven (rust/docs/DESIGN.md §9, §15): plan a mix
//! // with `AllocationRequest`, simulate one pool with `SimulationRun`, or
//! // scale out to a heterogeneous fleet with a routing policy and the
//! // fleet-wide tuned-plan cache.
//! let mix = ModelMix::uniform(vec![zoo::resnet18(), zoo::alexnet()]);
//! let fleet = Fleet::parse("mlu100x2,edge4x4").expect("fleet spec");
//! let mut cache = PlanCache::new();
//! let plan = plan_fleet(&fleet, &mix, Some(50.0), 1, true, &mut cache)
//!     .expect("fleet plan");
//! let trace = serving::generate_trace(
//!     &mix, ArrivalProcess::OpenPoisson { rate_rps: 800.0 }, 1000, 7);
//! let result = FleetRun::new(&plan, RouterConfig::new(RoutePolicy::LeastLoaded))
//!     .trace(&trace)
//!     .run()
//!     .expect("fleet run");
//! println!("{}", FleetReport::from_run(&result, &plan, Some(50.0)).render());
//! ```
//!
//! Python (JAX + Pallas) appears only at build time: `make artifacts` lowers
//! the fused-convolution kernel to HLO text which [`runtime`] loads through
//! the PJRT C API. Python is never on the request path.

pub mod util;
pub mod obs;
pub mod stats;
pub mod graph;
pub mod zoo;
pub mod microbench;
pub mod accel;
pub mod perfmodel;
pub mod cost;
pub mod optimizer;
pub mod search;
pub mod tuner;
pub mod learn;
pub mod codegen;
pub mod runtime;
pub mod coordinator;
pub mod serving;
pub mod bench_harness;
pub mod testutil;
pub mod cli;

/// Most-used types, for `use dlfusion::prelude::*`.
pub mod prelude {
    pub use crate::accel::{AcceleratorSpec, PerfReport, Simulator, SpecBuilder,
                           Target, TargetError};
    pub use crate::coordinator::{self, Engine};
    pub use crate::cost::{CostEngine, CostStats};
    pub use crate::graph::dag::{linearize, load_dlm, to_dlm_v2, DagBuilder,
                                DagModel, DagNode, DagOp, Linearization,
                                LoadedModel};
    pub use crate::graph::{DlmError, Layer, LayerKind, Model};
    pub use crate::learn::{self, ActiveTuner, FitConfig, LearnedCostModel,
                           TransferMatrix};
    pub use crate::obs::{Domain, MetricsRegistry, Probe, TraceSession};
    pub use crate::optimizer::{self, Schedule, Strategy};
    pub use crate::perfmodel;
    pub use crate::search::{self, AnnealConfig, BlockRule, SearchStats};
    pub use crate::serving::{self, plan_fleet, AllocationPlan,
                             AllocationRequest, ArrivalProcess, ClusterConfig,
                             DispatchPolicy, Fleet, FleetPlan, FleetReport,
                             FleetRun, ModelMix, PlanCache, RoutePolicy,
                             RouterConfig, SimulationRun, SloReport};
    pub use crate::tuner::{self, backend_by_name, compare, compare_targets,
                           compare_targets_with, compare_threaded, run_sweep,
                           Algorithm1, Annealer, Budget, Exhaustive, OracleDp,
                           SweepJob, SweepOutcome, TableStrategy,
                           TargetComparison, Tuner, TuningContext, TuningError,
                           TuningOutcome, TuningRequest, TuningStats};
    pub use crate::zoo;
}
