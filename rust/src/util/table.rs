//! ASCII table rendering for bench reports and the CLI.
//!
//! Produces the aligned, boxed tables the benches print next to the paper's
//! figure/table numbers (see EXPERIMENTS.md).

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple text table: header row + data rows, auto-sized columns.
#[derive(Debug, Clone)]
pub struct Table {
    title: Option<String>,
    header: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            title: None,
            header: header.iter().map(|s| s.to_string()).collect(),
            aligns: vec![Align::Right; header.len()],
            rows: Vec::new(),
        }
    }

    pub fn with_title(mut self, title: &str) -> Self {
        self.title = Some(title.to_string());
        self
    }

    /// First column left-aligned (the common "label | numbers..." layout).
    pub fn label_first(mut self) -> Self {
        if !self.aligns.is_empty() {
            self.aligns[0] = Align::Left;
        }
        self
    }

    pub fn align(mut self, col: usize, a: Align) -> Self {
        self.aligns[col] = a;
        self
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
    }

    pub fn row_strs(&mut self, cells: &[&str]) {
        self.row(cells.iter().map(|s| s.to_string()).collect());
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String], aligns: &[Align]| {
            let mut s = String::from("|");
            for i in 0..ncols {
                let cell = &cells[i];
                let pad = widths[i] - cell.len();
                match aligns[i] {
                    Align::Left => {
                        s.push(' ');
                        s.push_str(cell);
                        s.push_str(&" ".repeat(pad + 1));
                    }
                    Align::Right => {
                        s.push_str(&" ".repeat(pad + 1));
                        s.push_str(cell);
                        s.push(' ');
                    }
                }
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header, &vec![Align::Left; ncols]));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &self.aligns));
            out.push('\n');
        }
        out.push_str(&sep);
        out
    }
}

impl std::fmt::Display for Table {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["net", "fps"]).label_first();
        t.row_strs(&["resnet18", "123.4"]);
        t.row_strs(&["vgg", "9.1"]);
        let s = t.render();
        assert!(s.contains("| resnet18 | 123.4 |"));
        assert!(s.contains("| vgg      |   9.1 |"));
    }

    #[test]
    fn title_prepended() {
        let t = Table::new(&["a"]).with_title("Fig. 10");
        assert!(t.render().starts_with("Fig. 10\n"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn column_count_preserved() {
        let mut t = Table::new(&["a", "b", "c"]);
        t.row_strs(&["1", "2", "3"]);
        let rendered = t.render();
        let lines: Vec<&str> = rendered.lines().collect();
        // sep, header, sep, row, sep
        assert_eq!(lines.len(), 5);
        assert_eq!(lines[0].matches('+').count(), 4);
    }
}
