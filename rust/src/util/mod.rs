//! Small self-contained utilities.
//!
//! The build environment resolves crates offline from a registry that only
//! carries the `xla` dependency closure, so the conveniences a project would
//! normally pull from crates.io (serde_json, rand, prettytable, csv) are
//! implemented here from scratch. Each submodule is exercised by its own
//! unit tests.

pub mod json;
pub mod parallel;
pub mod rng;
pub mod table;
pub mod csv;
pub mod units;

pub use json::Json;
pub use parallel::ParallelMap;
pub use rng::XorShiftRng;
pub use table::Table;
