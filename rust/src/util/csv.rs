//! Tiny CSV writer for bench side-outputs (`bench_out/*.csv`).
//!
//! Each bench regenerating a paper figure also dumps its raw series as CSV
//! so plots can be rebuilt outside this repo.

use std::fs;
use std::io;
use std::path::Path;

/// In-memory CSV document.
#[derive(Debug, Default, Clone)]
pub struct Csv {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new(header: &[&str]) -> Self {
        Csv {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "CSV row width mismatch");
        self.rows.push(cells);
    }

    pub fn row_display<T: std::fmt::Display>(&mut self, cells: &[T]) {
        self.row(cells.iter().map(|c| c.to_string()).collect());
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        out.push_str(&join_escaped(&self.header));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&join_escaped(r));
            out.push('\n');
        }
        out
    }

    /// Write to `dir/name.csv`, creating the directory if needed.
    pub fn write_to(&self, dir: &str, name: &str) -> io::Result<std::path::PathBuf> {
        fs::create_dir_all(dir)?;
        let path = Path::new(dir).join(format!("{name}.csv"));
        fs::write(&path, self.to_string())?;
        Ok(path)
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }
}

fn join_escaped(cells: &[String]) -> String {
    cells
        .iter()
        .map(|c| {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.clone()
            }
        })
        .collect::<Vec<_>>()
        .join(",")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple_rows() {
        let mut c = Csv::new(&["x", "y"]);
        c.row_display(&[1.0, 2.5]);
        assert_eq!(c.to_string(), "x,y\n1,2.5\n");
    }

    #[test]
    fn escapes_commas_and_quotes() {
        let mut c = Csv::new(&["s"]);
        c.row(vec!["a,b".into()]);
        c.row(vec!["q\"q".into()]);
        assert_eq!(c.to_string(), "s\n\"a,b\"\n\"q\"\"q\"\n");
    }

    #[test]
    fn writes_file() {
        let dir = std::env::temp_dir().join("dlfusion_csv_test");
        let mut c = Csv::new(&["a"]);
        c.row_display(&[7]);
        let p = c.write_to(dir.to_str().unwrap(), "t").unwrap();
        assert_eq!(std::fs::read_to_string(p).unwrap(), "a\n7\n");
    }

    #[test]
    #[should_panic(expected = "width mismatch")]
    fn rejects_ragged() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(vec!["1".into()]);
    }
}
