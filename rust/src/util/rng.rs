//! Deterministic pseudo-random numbers (xorshift128+).
//!
//! Used by the property-test harness, microbenchmark synthesis, and the
//! request-generator in the coordinator. Seeded and reproducible; not
//! cryptographic.

/// xorshift128+ generator (Vigna 2014). Fast, decent statistical quality,
/// and — critically — dependency-free.
#[derive(Debug, Clone)]
pub struct XorShiftRng {
    s0: u64,
    s1: u64,
}

impl XorShiftRng {
    /// Create from a seed; any seed (including 0) is valid.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to spread the seed into two non-zero words.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s0 = next();
        let s1 = next();
        XorShiftRng { s0: s0 | 1, s1 }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.s0;
        let y = self.s1;
        self.s0 = y;
        x ^= x << 23;
        self.s1 = x ^ y ^ (x >> 17) ^ (y >> 26);
        self.s1.wrapping_add(y)
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[lo, hi]` (inclusive). Panics if `lo > hi`.
    pub fn gen_range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "gen_range: lo > hi");
        let span = hi - lo + 1;
        if span == 0 {
            return self.next_u64(); // full range
        }
        // Rejection sampling to avoid modulo bias.
        let zone = u64::MAX - (u64::MAX % span);
        loop {
            let v = self.next_u64();
            if v < zone {
                return lo + v % span;
            }
        }
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn gen_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.gen_range(lo as u64, hi as u64) as usize
    }

    /// Uniform in `[lo, hi)`.
    pub fn gen_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty(), "choose from empty slice");
        &items[self.gen_usize(0, items.len() - 1)]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.gen_usize(0, i);
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = XorShiftRng::new(42);
        let mut b = XorShiftRng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = XorShiftRng::new(1);
        let mut b = XorShiftRng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = XorShiftRng::new(7);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn range_inclusive_hits_bounds() {
        let mut r = XorShiftRng::new(3);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            match r.gen_range(5, 8) {
                5 => lo_seen = true,
                8 => hi_seen = true,
                6 | 7 => {}
                v => panic!("out of range: {v}"),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn mean_roughly_half() {
        let mut r = XorShiftRng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = XorShiftRng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = XorShiftRng::new(17);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn zero_seed_works() {
        let mut r = XorShiftRng::new(0);
        assert_ne!(r.next_u64(), 0);
    }
}
