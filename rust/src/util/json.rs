//! A minimal but complete JSON implementation (RFC 8259 subset).
//!
//! Used for the artifact manifest (`artifacts/manifest.json` written by the
//! python AOT step), the `.dlm` model-description format, and bench CSV/JSON
//! side outputs. Supports parsing and serialization of the full JSON data
//! model; numbers are kept as `f64` (all quantities we exchange fit).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_bool(&self) -> Option<bool> {
        match self { Json::Bool(b) => Some(*b), _ => None }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self { Json::Num(n) => Some(*n), _ => None }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self { Json::Str(s) => Some(s), _ => None }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self { Json::Arr(a) => Some(a), _ => None }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self { Json::Obj(o) => Some(o), _ => None }
    }

    /// `obj["key"]`-style access; returns Null for missing keys/non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index access; Null when out of range.
    pub fn at(&self, idx: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(idx).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ---- construction helpers -------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_usize(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    // ---- serialization ----------------------------------------------------

    /// Compact single-line serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty-printed serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 { out.push(','); }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() { newline_indent(out, indent, depth); }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 { out.push(','); }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() { out.push(' '); }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() { newline_indent(out, indent, depth); }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() { self.pos += 1; }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", lit)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(c.ok_or_else(|| self.err("invalid codepoint"))?);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // Re-decode multi-byte UTF-8 from the source slice.
                    let start = self.pos - 1;
                    let width = utf8_width(b);
                    let end = start + width;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated UTF-8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') { self.pos += 1; }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) { self.pos += 1; }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) { self.pos += 1; }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) { self.pos += 1; }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) { self.pos += 1; }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_width(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").at(1).as_f64(), Some(2.0));
        assert!(v.get("a").at(2).get("b").is_null());
        assert_eq!(v.get("c").as_str(), Some("x"));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A 😀""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" A 😀"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → 世界"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn error_carries_offset() {
        let e = Json::parse("[1, x]").unwrap_err();
        assert_eq!(e.offset, 4);
    }

    #[test]
    fn roundtrip_compact() {
        let src = r#"{"arr":[1,2.5,true,null],"s":"a\"b","z":{}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::obj(vec![
            ("name", Json::Str("resnet".into())),
            ("layers", Json::arr_usize(&[1, 2, 3])),
        ]);
        let v2 = Json::parse(&v.to_pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(8.0).to_string(), "8");
        assert_eq!(Json::Num(8.5).to_string(), "8.5");
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 7, "f": 7.5}"#).unwrap();
        assert_eq!(v.get("n").as_usize(), Some(7));
        assert_eq!(v.get("f").as_usize(), None);
        assert_eq!(v.get("missing").as_usize(), None);
        assert!(v.get("missing").is_null());
    }

    #[test]
    fn real_manifest_shape_parses() {
        // Mirrors the structure aot.py emits.
        let src = r#"{
          "format_version": 1,
          "interchange": "hlo-text",
          "artifacts": [
            {"name": "b1", "file": "b1.hlo.txt", "depth": 1,
             "channels": [8, 8], "batch": 1, "height": 16, "width": 16,
             "input_shapes": [[1,16,16,8],[3,3,8,8],[8]],
             "output_shape": [1,16,16,8], "tile": 16,
             "relu_last": true, "dtype": "f32"}
          ],
          "fused_pairs": {"b1": []},
          "golden": {}
        }"#;
        let m = Json::parse(src).unwrap();
        assert_eq!(m.get("format_version").as_usize(), Some(1));
        let a = m.get("artifacts").at(0);
        assert_eq!(a.get("name").as_str(), Some("b1"));
        assert_eq!(a.get("input_shapes").at(1).as_arr().unwrap().len(), 4);
    }
}
