//! A zero-dependency deterministic parallel map over `std::thread::scope`.
//!
//! The driver behind every `--threads N` surface (rust/docs/DESIGN.md §12):
//! jobs are pulled off a shared atomic cursor by a fixed-size worker pool
//! and results land in their input slot, so the output order — and, for
//! pure jobs, every output bit — is independent of thread scheduling.
//! `threads <= 1` (or a single item) short-circuits to a plain sequential
//! loop with no thread machinery at all, which keeps the sequential path
//! bit-identical to the pre-parallel code.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// A fixed-width worker pool that maps a function over a slice, preserving
/// input order in the output.
#[derive(Debug, Clone, Copy)]
pub struct ParallelMap {
    threads: usize,
}

impl ParallelMap {
    /// A pool of `threads` workers (clamped to at least 1).
    pub fn new(threads: usize) -> ParallelMap {
        ParallelMap { threads: threads.max(1) }
    }

    /// The worker count this pool fans out to.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Apply `f(index, &item)` to every item, returning results in input
    /// order. With one worker (or zero/one items) this is a plain `for`
    /// loop on the calling thread; otherwise scoped workers race over an
    /// atomic cursor — a panic in any job propagates when the scope joins.
    pub fn map<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        if self.threads <= 1 || items.len() <= 1 {
            return items.iter().enumerate().map(|(i, item)| f(i, item)).collect();
        }
        let cursor = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<R>>> =
            (0..items.len()).map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for _ in 0..self.threads.min(items.len()) {
                scope.spawn(|| loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= items.len() {
                        break;
                    }
                    let r = f(i, &items[i]);
                    *slots[i].lock().unwrap() = Some(r);
                });
            }
        });
        slots
            .into_iter()
            .map(|slot| {
                slot.into_inner()
                    .unwrap()
                    .expect("every job slot is filled once the scope joins")
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let items: Vec<usize> = (0..97).collect();
        let seq = ParallelMap::new(1).map(&items, |i, &x| (i, x * x));
        let par = ParallelMap::new(4).map(&items, |i, &x| (i, x * x));
        assert_eq!(seq, par);
        assert_eq!(par[13], (13, 169));
    }

    #[test]
    fn single_item_and_empty_slices() {
        let par = ParallelMap::new(8);
        assert_eq!(par.map(&[] as &[u8], |_, &x| x), Vec::<u8>::new());
        assert_eq!(par.map(&[7u8], |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn zero_threads_clamps_to_one() {
        assert_eq!(ParallelMap::new(0).threads(), 1);
    }
}
