//! Human-readable formatting of the quantities this project trades in:
//! operation counts (GOPs), throughput (GFLOPS / FPS), bytes, and times.

/// Format an operation count given in GOPs (1e9 ops).
pub fn fmt_gops(gops: f64) -> String {
    if gops >= 1000.0 {
        format!("{:.2} TOPs", gops / 1000.0)
    } else if gops >= 1.0 {
        format!("{:.2} GOPs", gops)
    } else if gops >= 1e-3 {
        format!("{:.2} MOPs", gops * 1e3)
    } else {
        format!("{:.0} KOPs", gops * 1e6)
    }
}

/// Format achieved compute throughput given in GFLOPS.
pub fn fmt_gflops(gflops: f64) -> String {
    if gflops >= 1000.0 {
        format!("{:.2} TFLOPS", gflops / 1000.0)
    } else {
        format!("{:.1} GFLOPS", gflops)
    }
}

/// Format a byte count.
pub fn fmt_bytes(bytes: f64) -> String {
    const K: f64 = 1024.0;
    if bytes >= K * K * K {
        format!("{:.2} GiB", bytes / (K * K * K))
    } else if bytes >= K * K {
        format!("{:.2} MiB", bytes / (K * K))
    } else if bytes >= K {
        format!("{:.1} KiB", bytes / K)
    } else {
        format!("{:.0} B", bytes)
    }
}

/// Format a duration given in milliseconds.
pub fn fmt_ms(ms: f64) -> String {
    if ms >= 1000.0 {
        format!("{:.2} s", ms / 1000.0)
    } else if ms >= 1.0 {
        format!("{:.2} ms", ms)
    } else {
        format!("{:.1} µs", ms * 1000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gops_scales() {
        assert_eq!(fmt_gops(1500.0), "1.50 TOPs");
        assert_eq!(fmt_gops(3.38), "3.38 GOPs");
        assert_eq!(fmt_gops(0.169), "169.00 MOPs");
        assert_eq!(fmt_gops(0.000001), "1 KOPs");
    }

    #[test]
    fn gflops_scales() {
        assert_eq!(fmt_gflops(64000.0), "64.00 TFLOPS");
        assert_eq!(fmt_gflops(123.45), "123.5 GFLOPS");
    }

    #[test]
    fn bytes_scales() {
        assert_eq!(fmt_bytes(8.0 * 1024.0 * 1024.0 * 1024.0), "8.00 GiB");
        assert_eq!(fmt_bytes(2048.0), "2.0 KiB");
        assert_eq!(fmt_bytes(12.0), "12 B");
    }

    #[test]
    fn ms_scales() {
        assert_eq!(fmt_ms(2500.0), "2.50 s");
        assert_eq!(fmt_ms(3.25), "3.25 ms");
        assert_eq!(fmt_ms(0.02), "20.0 µs");
    }
}
