//! Fluent network builder: tracks the flowing activation shape and appends
//! conv/bn/relu/pool/add layers with auto-generated names, so the zoo models
//! read like their original architecture tables.

use crate::graph::layer::{ConvSpec, FcSpec, Layer, LayerKind, TensorShape};
use crate::graph::Model;

/// Incremental model builder.
pub struct NetBuilder {
    name: String,
    input: TensorShape,
    cur: TensorShape,
    layers: Vec<Layer>,
    counter: usize,
}

impl NetBuilder {
    pub fn new(name: &str, h: usize, w: usize, c: usize) -> Self {
        let input = TensorShape::new(h, w, c);
        NetBuilder { name: name.to_string(), input, cur: input, layers: Vec::new(), counter: 0 }
    }

    fn next_name(&mut self, prefix: &str) -> String {
        self.counter += 1;
        format!("{prefix}{}", self.counter)
    }

    /// Current activation shape.
    pub fn shape(&self) -> TensorShape {
        self.cur
    }

    /// Raw convolution; updates the flowing shape.
    pub fn conv(&mut self, c_out: usize, k: usize, stride: usize, pad: usize,
                groups: usize) -> &mut Self {
        let spec = ConvSpec {
            c_in: self.cur.c, c_out,
            h_in: self.cur.h, w_in: self.cur.w,
            k, stride, pad, groups,
        };
        let name = self.next_name("conv");
        self.layers.push(Layer::conv(name, spec));
        self.cur = TensorShape::new(spec.h_out(), spec.w_out(), c_out);
        self
    }

    /// 3x3 (or kxk) SAME conv, stride 1.
    pub fn conv_same(&mut self, c_out: usize, k: usize) -> &mut Self {
        self.conv(c_out, k, 1, k / 2, 1)
    }

    pub fn bn(&mut self) -> &mut Self {
        let name = self.next_name("bn");
        self.layers.push(Layer::new(name, LayerKind::BatchNorm { shape: self.cur }));
        self
    }

    pub fn relu(&mut self) -> &mut Self {
        let name = self.next_name("relu");
        self.layers.push(Layer::new(name, LayerKind::ReLU { shape: self.cur }));
        self
    }

    /// conv + BN + ReLU, the ubiquitous triple.
    pub fn conv_bn_relu(&mut self, c_out: usize, k: usize, stride: usize,
                        pad: usize, groups: usize) -> &mut Self {
        self.conv(c_out, k, stride, pad, groups).bn().relu()
    }

    pub fn pool(&mut self, k: usize, stride: usize) -> &mut Self {
        let name = self.next_name("pool");
        self.layers.push(Layer::new(name, LayerKind::Pool { shape: self.cur, k, stride }));
        self.cur = TensorShape::new(self.cur.h / stride, self.cur.w / stride, self.cur.c);
        self
    }

    /// Residual elementwise add at the current shape.
    pub fn add(&mut self) -> &mut Self {
        let name = self.next_name("add");
        self.layers.push(Layer::new(name, LayerKind::Add { shape: self.cur }));
        self
    }

    pub fn fc(&mut self, n: usize) -> &mut Self {
        let k = self.cur.elems();
        let name = self.next_name("fc");
        self.layers.push(Layer::new(name, LayerKind::Fc(FcSpec { k, n })));
        self.cur = TensorShape::new(1, 1, n);
        self
    }

    /// Global average pool to 1x1 spatial.
    pub fn global_pool(&mut self) -> &mut Self {
        let k = self.cur.h;
        self.pool(k, k.max(1))
    }

    /// Finish, validating the chain.
    pub fn build(self) -> Model {
        let m = Model::new(self.name, self.input, self.layers);
        m.validate().unwrap_or_else(|e| panic!("zoo builder produced invalid model: {e}"));
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_tracks_shapes() {
        let mut b = NetBuilder::new("t", 32, 32, 3);
        b.conv_bn_relu(16, 3, 1, 1, 1).pool(2, 2).conv_same(32, 3).relu();
        assert_eq!(b.shape(), TensorShape::new(16, 16, 32));
        let m = b.build();
        assert_eq!(m.stats().num_conv, 2);
        // conv+bn+relu, pool, conv, relu.
        assert_eq!(m.num_layers(), 6);
    }

    #[test]
    fn strided_conv_halves() {
        let mut b = NetBuilder::new("t", 56, 56, 64);
        b.conv(128, 3, 2, 1, 1);
        assert_eq!(b.shape(), TensorShape::new(28, 28, 128));
    }

    #[test]
    fn fc_flattens() {
        let mut b = NetBuilder::new("t", 4, 4, 8);
        b.fc(10);
        let m = b.build();
        assert!(m.validate().is_ok());
        assert_eq!(m.layers[0].output_shape().c, 10);
    }

    #[test]
    fn global_pool_to_1x1() {
        let mut b = NetBuilder::new("t", 7, 7, 32);
        b.global_pool();
        assert_eq!(b.shape(), TensorShape::new(1, 1, 32));
    }

    #[test]
    #[should_panic(expected = "invalid model")]
    fn build_panics_on_empty() {
        NetBuilder::new("t", 4, 4, 4).build();
    }
}
