//! Built-in model zoo: the networks of the paper's evaluation (Table II) and
//! the synthetic models of its characterization experiments.
//!
//! | network | paper Total Op (GOPs) | paper #CONV | builder |
//! |---|---|---|---|
//! | ResNet-18 | 3.38 | 20 | [`resnet18`] |
//! | ResNet-50 | 7.61 | 53 | [`resnet50`] |
//! | VGG-19 | 36.34 | 16 | [`vgg19`] |
//! | AlexNet | 1.22 | 5 | [`alexnet`] |
//! | MobileNetV2 | 10.33 | 52 | [`mobilenet_v2`] |
//!
//! All builders produce fully-specified per-layer shapes (validated), with
//! the BatchNorm / ReLU / Pool / Add auxiliary layers the real networks
//! carry; `rust/tests/paper_tables.rs` checks our Eq. 1 totals against the
//! paper's Table II numbers.
//!
//! The ResNets also exist as genuine branching DAGs ([`resnet18_dag`],
//! [`resnet50_dag`], resolved by [`dag_by_name`]): real residual edges and
//! true two-input joins, with the same Table II op accounting as the linear
//! fakes (pinned in `zoo/resnet.rs` tests).

pub mod builder;
pub mod resnet;
pub mod vgg;
pub mod alexnet;
pub mod mobilenet;
pub mod synthetic;

pub use alexnet::alexnet;
pub use mobilenet::mobilenet_v2;
pub use resnet::{resnet18, resnet18_dag, resnet50, resnet50_dag};
pub use synthetic::{identical_conv_model, mini_cnn, scaled_conv_layer};
pub use vgg::vgg19;

use crate::graph::dag::DagModel;
use crate::graph::Model;

/// All Table II evaluation networks, in the paper's order.
pub fn all_models() -> Vec<Model> {
    vec![resnet18(), resnet50(), vgg19(), alexnet(), mobilenet_v2()]
}

/// Look a zoo model up by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<Model> {
    match name.to_ascii_lowercase().as_str() {
        "resnet18" | "resnet-18" => Some(resnet18()),
        "resnet50" | "resnet-50" => Some(resnet50()),
        "vgg19" | "vgg-19" => Some(vgg19()),
        "alexnet" => Some(alexnet()),
        "mobilenet" | "mobilenetv2" | "mobilenet-v2" => Some(mobilenet_v2()),
        "mini" | "mini_cnn" => Some(mini_cnn()),
        _ => None,
    }
}

/// Resolve a comma-separated list of zoo names (the CLI's `--models` form,
/// e.g. `"resnet18,alexnet"`); whitespace around names is ignored.
pub fn by_names(list: &str) -> Result<Vec<Model>, String> {
    let mut models = Vec::new();
    for name in list.split(',') {
        let name = name.trim();
        models.push(by_name(name).ok_or_else(|| {
            format!("unknown model '{name}' (known: {})", MODEL_NAMES.join(", "))
        })?);
    }
    Ok(models)
}

/// Names accepted by [`by_name`], for CLI help.
pub const MODEL_NAMES: &[&str] =
    &["resnet18", "resnet50", "vgg19", "alexnet", "mobilenet", "mini_cnn"];

/// The genuine branching DAG variants of the zoo ResNets (real residual
/// edges instead of the faked-sequential chains).
pub fn dag_models() -> Vec<DagModel> {
    vec![resnet18_dag(), resnet50_dag()]
}

/// Look a DAG zoo model up by (case-insensitive) name.
pub fn dag_by_name(name: &str) -> Option<DagModel> {
    match name.to_ascii_lowercase().as_str() {
        "resnet18-dag" | "resnet18_dag" => Some(resnet18_dag()),
        "resnet50-dag" | "resnet50_dag" => Some(resnet50_dag()),
        _ => None,
    }
}

/// Names accepted by [`dag_by_name`], for CLI help.
pub const DAG_MODEL_NAMES: &[&str] = &["resnet18-dag", "resnet50-dag"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_models_validate() {
        for m in all_models() {
            m.validate().unwrap_or_else(|e| panic!("{}: {e}", m.name));
        }
    }

    #[test]
    fn conv_counts_match_table2() {
        let want = [("resnet18", 20), ("resnet50", 53), ("vgg19", 16),
                    ("alexnet", 5), ("mobilenet_v2", 52)];
        for (m, (name, count)) in all_models().iter().zip(want) {
            assert_eq!(m.stats().num_conv, count, "{name}");
        }
    }

    #[test]
    fn by_name_resolves_aliases() {
        assert!(by_name("ResNet-18").is_some());
        assert!(by_name("MOBILENETV2").is_some());
        assert!(by_name("nope").is_none());
        for n in MODEL_NAMES {
            assert!(by_name(n).is_some(), "{n}");
        }
    }

    #[test]
    fn dag_by_name_resolves() {
        for n in DAG_MODEL_NAMES {
            assert!(dag_by_name(n).is_some(), "{n}");
        }
        assert!(dag_by_name("RESNET18_DAG").is_some());
        // The dag namespace is disjoint from the linear one.
        assert!(by_name("resnet18-dag").is_none());
        assert!(dag_by_name("resnet18").is_none());
        for d in dag_models() {
            d.validate().unwrap_or_else(|e| panic!("{}: {e}", d.name));
        }
    }

    #[test]
    fn by_names_parses_comma_lists() {
        let ms = by_names("resnet18, alexnet").unwrap();
        assert_eq!(ms.len(), 2);
        assert_eq!(ms[0].name, "resnet18");
        assert_eq!(ms[1].name, "alexnet");
        assert!(by_names("alexnet,nope").unwrap_err().contains("nope"));
        assert!(by_names("").is_err());
    }
}
