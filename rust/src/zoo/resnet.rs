//! ResNet-18 and ResNet-50 (He et al., CVPR 2016), ImageNet configuration.
//!
//! Layer execution order is linearized (the fusion partitioner walks layers
//! in order, like the paper's Algorithm 1): each residual block emits its
//! main-path convs, then the downsample conv when present, then the `Add`.
//! ResNet-18 has 20 convs (16 basic-block + 1 stem + 3 downsample);
//! ResNet-50 has 53 (48 bottleneck + 1 stem + 4 downsample) — the Table II
//! counts.

use super::builder::NetBuilder;
use crate::graph::Model;

/// Skip-path 1x1 projection, linearized after the main path.
///
/// The linear IR cannot fork, so the projection reads the main path's
/// `c_out`-channel tensor instead of the block input's `c_in` channels; a
/// `groups = c_out / c_in` setting makes its Eq. 1 cost (and weight bytes)
/// exactly equal to the real `c_in -> c_out` projection while keeping the
/// chain valid. Spatial downsampling already happened on the main path.
fn downsample_proj(b: &mut NetBuilder, c_in_real: usize) {
    let c_out = b.shape().c;
    assert_eq!(c_out % c_in_real, 0);
    b.conv(c_out, 1, 1, 0, c_out / c_in_real).bn();
}

/// One basic block (two 3x3 convs) with optional strided entry + downsample.
fn basic_block(b: &mut NetBuilder, c_out: usize, stride: usize,
               downsample_from: Option<usize>) {
    b.conv_bn_relu(c_out, 3, stride, 1, 1);
    b.conv(c_out, 3, 1, 1, 1).bn();
    if let Some(c_in_real) = downsample_from {
        downsample_proj(b, c_in_real);
    }
    b.add().relu();
}

/// One bottleneck block (1x1 reduce, 3x3, 1x1 expand); v1 strides the first
/// 1x1 (the variant whose op count matches the paper's Table II row).
fn bottleneck_block(b: &mut NetBuilder, c_mid: usize, c_out: usize,
                    stride: usize, downsample_from: Option<usize>) {
    b.conv_bn_relu(c_mid, 1, stride, 0, 1);
    b.conv_bn_relu(c_mid, 3, 1, 1, 1);
    b.conv(c_out, 1, 1, 0, 1).bn();
    if let Some(c_in_real) = downsample_from {
        downsample_proj(b, c_in_real);
    }
    b.add().relu();
}

/// ResNet-18 for 224x224x3 input.
pub fn resnet18() -> Model {
    let mut b = NetBuilder::new("resnet18", 224, 224, 3);
    b.conv_bn_relu(64, 7, 2, 3, 1); // stem -> 112x112x64
    b.pool(3, 2); // -> 56x56
    // conv2_x: 2 blocks @64.
    basic_block(&mut b, 64, 1, None);
    basic_block(&mut b, 64, 1, None);
    // conv3_x: 2 blocks @128, first strided + downsample (64 -> 128).
    basic_block(&mut b, 128, 2, Some(64));
    basic_block(&mut b, 128, 1, None);
    // conv4_x: 2 blocks @256.
    basic_block(&mut b, 256, 2, Some(128));
    basic_block(&mut b, 256, 1, None);
    // conv5_x: 2 blocks @512.
    basic_block(&mut b, 512, 2, Some(256));
    basic_block(&mut b, 512, 1, None);
    b.global_pool().fc(1000);
    b.build()
}

/// ResNet-50 for 224x224x3 input (v1.5 stride placement).
pub fn resnet50() -> Model {
    let mut b = NetBuilder::new("resnet50", 224, 224, 3);
    b.conv_bn_relu(64, 7, 2, 3, 1);
    b.pool(3, 2);
    // (c_mid, c_out, blocks, first_stride) per stage.
    let stages: [(usize, usize, usize, usize); 4] = [
        (64, 256, 3, 1),
        (128, 512, 4, 2),
        (256, 1024, 6, 2),
        (512, 2048, 3, 2),
    ];
    for (c_mid, c_out, blocks, first_stride) in stages {
        for i in 0..blocks {
            let stride = if i == 0 { first_stride } else { 1 };
            // First block of each stage changes channels -> projection from
            // the stage's real input channel count.
            let ds = if i == 0 {
                Some(if c_out == 256 { 64 } else { c_out / 2 })
            } else {
                None
            };
            bottleneck_block(&mut b, c_mid, c_out, stride, ds);
        }
    }
    b.global_pool().fc(1000);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet18_conv_count_and_ops() {
        let m = resnet18();
        let s = m.stats();
        assert_eq!(s.num_conv, 20);
        // Paper Table II: 3.38 GOPs total, 0.169 avg.
        assert!((s.total_conv_gops - 3.38).abs() / 3.38 < 0.15,
                "total {} vs paper 3.38", s.total_conv_gops);
    }

    #[test]
    fn resnet50_conv_count_and_ops() {
        let m = resnet50();
        let s = m.stats();
        assert_eq!(s.num_conv, 53);
        // Paper Table II: 7.61 GOPs total, 0.144 avg.
        assert!((s.total_conv_gops - 7.61).abs() / 7.61 < 0.15,
                "total {} vs paper 7.61", s.total_conv_gops);
    }

    #[test]
    fn final_shapes() {
        for m in [resnet18(), resnet50()] {
            let last = m.layers.last().unwrap();
            assert_eq!(last.output_shape().c, 1000, "{}", m.name);
        }
    }

    #[test]
    fn stage_spatial_extents() {
        let m = resnet18();
        // First block conv after the stem operates at 56x56.
        let c = m.layers.iter().filter(|l| l.is_compute()).nth(1).unwrap();
        assert_eq!(c.input_shape().h, 56);
    }
}
