//! ResNet-18 and ResNet-50 (He et al., CVPR 2016), ImageNet configuration.
//!
//! Layer execution order is linearized (the fusion partitioner walks layers
//! in order, like the paper's Algorithm 1): each residual block emits its
//! main-path convs, then the downsample conv when present, then the `Add`.
//! ResNet-18 has 20 convs (16 basic-block + 1 stem + 3 downsample);
//! ResNet-50 has 53 (48 bottleneck + 1 stem + 4 downsample) — the Table II
//! counts.

use super::builder::NetBuilder;
use crate::graph::dag::{DagBuilder, DagModel, ValueRef};
use crate::graph::Model;

/// Skip-path 1x1 projection, linearized after the main path.
///
/// The linear IR cannot fork, so the projection reads the main path's
/// `c_out`-channel tensor instead of the block input's `c_in` channels; a
/// `groups = c_out / c_in` setting makes its Eq. 1 cost (and weight bytes)
/// exactly equal to the real `c_in -> c_out` projection while keeping the
/// chain valid. Spatial downsampling already happened on the main path.
fn downsample_proj(b: &mut NetBuilder, c_in_real: usize) {
    let c_out = b.shape().c;
    assert_eq!(c_out % c_in_real, 0);
    b.conv(c_out, 1, 1, 0, c_out / c_in_real).bn();
}

/// One basic block (two 3x3 convs) with optional strided entry + downsample.
fn basic_block(b: &mut NetBuilder, c_out: usize, stride: usize,
               downsample_from: Option<usize>) {
    b.conv_bn_relu(c_out, 3, stride, 1, 1);
    b.conv(c_out, 3, 1, 1, 1).bn();
    if let Some(c_in_real) = downsample_from {
        downsample_proj(b, c_in_real);
    }
    b.add().relu();
}

/// One bottleneck block (1x1 reduce, 3x3, 1x1 expand); v1 strides the first
/// 1x1 (the variant whose op count matches the paper's Table II row).
fn bottleneck_block(b: &mut NetBuilder, c_mid: usize, c_out: usize,
                    stride: usize, downsample_from: Option<usize>) {
    b.conv_bn_relu(c_mid, 1, stride, 0, 1);
    b.conv_bn_relu(c_mid, 3, 1, 1, 1);
    b.conv(c_out, 1, 1, 0, 1).bn();
    if let Some(c_in_real) = downsample_from {
        downsample_proj(b, c_in_real);
    }
    b.add().relu();
}

/// ResNet-18 for 224x224x3 input.
pub fn resnet18() -> Model {
    let mut b = NetBuilder::new("resnet18", 224, 224, 3);
    b.conv_bn_relu(64, 7, 2, 3, 1); // stem -> 112x112x64
    b.pool(3, 2); // -> 56x56
    // conv2_x: 2 blocks @64.
    basic_block(&mut b, 64, 1, None);
    basic_block(&mut b, 64, 1, None);
    // conv3_x: 2 blocks @128, first strided + downsample (64 -> 128).
    basic_block(&mut b, 128, 2, Some(64));
    basic_block(&mut b, 128, 1, None);
    // conv4_x: 2 blocks @256.
    basic_block(&mut b, 256, 2, Some(128));
    basic_block(&mut b, 256, 1, None);
    // conv5_x: 2 blocks @512.
    basic_block(&mut b, 512, 2, Some(256));
    basic_block(&mut b, 512, 1, None);
    b.global_pool().fc(1000);
    b.build()
}

/// ResNet-50 for 224x224x3 input (v1.5 stride placement).
pub fn resnet50() -> Model {
    let mut b = NetBuilder::new("resnet50", 224, 224, 3);
    b.conv_bn_relu(64, 7, 2, 3, 1);
    b.pool(3, 2);
    // (c_mid, c_out, blocks, first_stride) per stage.
    let stages: [(usize, usize, usize, usize); 4] = [
        (64, 256, 3, 1),
        (128, 512, 4, 2),
        (256, 1024, 6, 2),
        (512, 2048, 3, 2),
    ];
    for (c_mid, c_out, blocks, first_stride) in stages {
        for i in 0..blocks {
            let stride = if i == 0 { first_stride } else { 1 };
            // First block of each stage changes channels -> projection from
            // the stage's real input channel count.
            let ds = if i == 0 {
                Some(if c_out == 256 { 64 } else { c_out / 2 })
            } else {
                None
            };
            bottleneck_block(&mut b, c_mid, c_out, stride, ds);
        }
    }
    b.global_pool().fc(1000);
    b.build()
}

// ---- genuine branching DAG variants ------------------------------------
//
// Same networks, but with *real* residual edges: the skip path (identity or
// strided 1x1 projection) reads the block input, and the join is a true
// two-input `Add`. Node insertion order mirrors the linear builders' layer
// order, so the deterministic linearization lays layers out identically;
// the only per-layer difference is that the downsample projection is the
// real `c_in -> c_out` conv instead of the grouped fake — whose Eq. 1 cost
// and weight bytes are equal by construction (see `downsample_proj`).

/// One basic block with a real skip edge.
fn dag_basic_block(
    b: &mut DagBuilder,
    x: &ValueRef,
    c_out: usize,
    stride: usize,
    downsample: bool,
) -> ValueRef {
    let m = b.conv_bn_relu(x, c_out, 3, stride, 1, 1);
    let m = b.conv(&m, c_out, 3, 1, 1, 1);
    let m = b.bn(&m);
    let skip = if downsample {
        let p = b.conv(x, c_out, 1, stride, 0, 1);
        b.bn(&p)
    } else {
        x.clone()
    };
    let j = b.add(&[&m, &skip]);
    b.relu(&j)
}

/// One bottleneck block with a real skip edge.
fn dag_bottleneck_block(
    b: &mut DagBuilder,
    x: &ValueRef,
    c_mid: usize,
    c_out: usize,
    stride: usize,
    downsample: bool,
) -> ValueRef {
    let m = b.conv_bn_relu(x, c_mid, 1, stride, 0, 1);
    let m = b.conv_bn_relu(&m, c_mid, 3, 1, 1, 1);
    let m = b.conv(&m, c_out, 1, 1, 0, 1);
    let m = b.bn(&m);
    let skip = if downsample {
        let p = b.conv(x, c_out, 1, stride, 0, 1);
        b.bn(&p)
    } else {
        x.clone()
    };
    let j = b.add(&[&m, &skip]);
    b.relu(&j)
}

/// ResNet-18 as a genuine branching DAG.
pub fn resnet18_dag() -> DagModel {
    let mut b = DagBuilder::new("resnet18-dag");
    let x = b.input("image", 224, 224, 3);
    let x = b.conv_bn_relu(&x, 64, 7, 2, 3, 1);
    let x = b.pool(&x, 3, 2);
    let x = dag_basic_block(&mut b, &x, 64, 1, false);
    let x = dag_basic_block(&mut b, &x, 64, 1, false);
    let x = dag_basic_block(&mut b, &x, 128, 2, true);
    let x = dag_basic_block(&mut b, &x, 128, 1, false);
    let x = dag_basic_block(&mut b, &x, 256, 2, true);
    let x = dag_basic_block(&mut b, &x, 256, 1, false);
    let x = dag_basic_block(&mut b, &x, 512, 2, true);
    let x = dag_basic_block(&mut b, &x, 512, 1, false);
    let x = b.global_pool(&x);
    let x = b.fc(&x, 1000);
    b.output(&x);
    b.build()
}

/// ResNet-50 as a genuine branching DAG.
pub fn resnet50_dag() -> DagModel {
    let mut b = DagBuilder::new("resnet50-dag");
    let mut x = b.input("image", 224, 224, 3);
    x = b.conv_bn_relu(&x, 64, 7, 2, 3, 1);
    x = b.pool(&x, 3, 2);
    let stages: [(usize, usize, usize, usize); 4] = [
        (64, 256, 3, 1),
        (128, 512, 4, 2),
        (256, 1024, 6, 2),
        (512, 2048, 3, 2),
    ];
    for (c_mid, c_out, blocks, first_stride) in stages {
        for i in 0..blocks {
            let stride = if i == 0 { first_stride } else { 1 };
            x = dag_bottleneck_block(&mut b, &x, c_mid, c_out, stride, i == 0);
        }
    }
    x = b.global_pool(&x);
    x = b.fc(&x, 1000);
    b.output(&x);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dag::linearize;

    #[test]
    fn resnet18_conv_count_and_ops() {
        let m = resnet18();
        let s = m.stats();
        assert_eq!(s.num_conv, 20);
        // Paper Table II: 3.38 GOPs total, 0.169 avg.
        assert!((s.total_conv_gops - 3.38).abs() / 3.38 < 0.15,
                "total {} vs paper 3.38", s.total_conv_gops);
    }

    #[test]
    fn resnet50_conv_count_and_ops() {
        let m = resnet50();
        let s = m.stats();
        assert_eq!(s.num_conv, 53);
        // Paper Table II: 7.61 GOPs total, 0.144 avg.
        assert!((s.total_conv_gops - 7.61).abs() / 7.61 < 0.15,
                "total {} vs paper 7.61", s.total_conv_gops);
    }

    #[test]
    fn final_shapes() {
        for m in [resnet18(), resnet50()] {
            let last = m.layers.last().unwrap();
            assert_eq!(last.output_shape().c, 1000, "{}", m.name);
        }
    }

    #[test]
    fn stage_spatial_extents() {
        let m = resnet18();
        // First block conv after the stem operates at 56x56.
        let c = m.layers.iter().filter(|l| l.is_compute()).nth(1).unwrap();
        assert_eq!(c.input_shape().h, 56);
    }

    #[test]
    fn dag_variants_match_linear_op_accounting() {
        // The grouped-fake downsample was constructed to cost exactly what
        // the real projection costs, so the DAG variants reproduce the
        // Table II op counts of the linear fakes to the bit.
        for (dag, linear) in [(resnet18_dag(), resnet18()), (resnet50_dag(), resnet50())] {
            let lowered = linearize(&dag).unwrap().model;
            let (ds, ls) = (lowered.stats(), linear.stats());
            assert_eq!(ds.num_conv, ls.num_conv, "{}", dag.name);
            assert_eq!(ds.num_layers, ls.num_layers, "{}", dag.name);
            assert_eq!(ds.total_conv_gops, ls.total_conv_gops, "{}", dag.name);
            assert_eq!(lowered.weight_bytes(), linear.weight_bytes(), "{}", dag.name);
        }
    }

    #[test]
    fn dag_variants_really_branch() {
        for dag in [resnet18_dag(), resnet50_dag()] {
            assert!(!dag.is_linear(), "{}", dag.name);
            let lin = linearize(&dag).unwrap();
            let cuts = lin.cuts.expect("branching => constrained cuts");
            let n = lin.model.num_layers();
            // Residual interiors are illegal, so the legal set is a strict
            // subset of all boundaries.
            assert!(cuts.len() < n + 1, "{}", dag.name);
            assert_eq!(cuts.first(), Some(&0));
            assert_eq!(cuts.last(), Some(&n));
        }
    }

    #[test]
    fn resnet18_dag_skip_edges_read_block_input() {
        let dag = resnet18_dag();
        // Every join has two distinct inputs (main path + skip).
        let joins: Vec<_> = dag
            .nodes
            .iter()
            .filter(|nd| matches!(nd.op, crate::graph::dag::DagOp::Add { .. }))
            .collect();
        assert_eq!(joins.len(), 8);
        for j in joins {
            assert_eq!(j.inputs.len(), 2, "{}", j.name);
            assert_ne!(j.inputs[0], j.inputs[1], "{}", j.name);
        }
    }
}
