//! MobileNetV2 (Sandler et al., CVPR 2018): inverted residual bottlenecks
//! with depthwise convolutions. 52 conv layers (1 stem + 2 in the t=1 block
//! + 3 x 16 t=6 blocks + 1 final pointwise), matching Table II's count.
//!
//! Table II lists MobileNet at 10.33 GOPs total — consistent with Eq. 1
//! applied *without* the group reduction (depthwise convs counted at their
//! dense-equivalent cost; the CNML operator SDK of the time had no native
//! depthwise kernel and ran them as dense convolutions). We therefore carry
//! `groups` faithfully in the IR and let `ModelStats` use the group-aware
//! count, while `tests/paper_tables.rs` checks the dense-equivalent total
//! against the paper's 10.33. See EXPERIMENTS.md §Table II.

use super::builder::NetBuilder;
use crate::graph::Model;

/// One inverted-residual bottleneck. `t` = expansion, `c_out` = output
/// channels, `stride` for the depthwise stage.
fn bottleneck(b: &mut NetBuilder, t: usize, c_out: usize, stride: usize) {
    let c_in = b.shape().c;
    let c_mid = c_in * t;
    if t != 1 {
        b.conv_bn_relu(c_mid, 1, 1, 0, 1); // pointwise expand
    }
    b.conv_bn_relu(c_mid, 3, stride, 1, c_mid); // depthwise
    b.conv(c_out, 1, 1, 0, 1).bn(); // pointwise linear (no ReLU)
    if stride == 1 && c_in == c_out {
        b.add();
    }
}

/// MobileNetV2 (width 1.0) for 224x224x3 input.
pub fn mobilenet_v2() -> Model {
    let mut b = NetBuilder::new("mobilenet_v2", 224, 224, 3);
    b.conv_bn_relu(32, 3, 2, 1, 1); // stem -> 112x112x32
    // (t, c, n, s) from the paper's Table 2.
    let cfg: [(usize, usize, usize, usize); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    for (t, c, n, s) in cfg {
        for i in 0..n {
            bottleneck(&mut b, t, c, if i == 0 { s } else { 1 });
        }
    }
    b.conv_bn_relu(1280, 1, 1, 0, 1); // final pointwise
    b.global_pool().fc(1000);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::LayerKind;

    #[test]
    fn conv_count_is_52() {
        assert_eq!(mobilenet_v2().stats().num_conv, 52);
    }

    #[test]
    fn depthwise_layers_are_grouped() {
        let m = mobilenet_v2();
        let dw = m.layers.iter().filter(|l| match &l.kind {
            LayerKind::Conv(c) => c.groups > 1 && c.groups == c.c_in,
            _ => false,
        }).count();
        assert_eq!(dw, 17); // one depthwise per bottleneck
    }

    #[test]
    fn group_aware_total_is_mobilenet_scale() {
        // Real (group-aware) MobileNetV2 is ~0.6 GOPs.
        let s = mobilenet_v2().stats();
        assert!(s.total_conv_gops > 0.4 && s.total_conv_gops < 0.8,
                "got {}", s.total_conv_gops);
    }

    #[test]
    fn dense_equivalent_total_near_paper() {
        // Paper Table II counts 10.33 GOPs (dense-equivalent convention).
        let m = mobilenet_v2();
        let dense: f64 = m.layers.iter().filter_map(|l| match &l.kind {
            LayerKind::Conv(c) => Some(c.op_gops_dense_equiv()),
            _ => None,
        }).sum();
        assert!((dense - 10.33).abs() / 10.33 < 0.25, "dense-equiv {}", dense);
    }

    #[test]
    fn residual_adds_present() {
        let m = mobilenet_v2();
        let adds = m.layers.iter()
            .filter(|l| matches!(l.kind, LayerKind::Add { .. })).count();
        // n-1 adds per stage with n blocks and stride-1 equal-channel repeats:
        // stages with n = 2,3,4,3,3 -> 1+2+3+2+2 = 10.
        assert_eq!(adds, 10);
    }

    #[test]
    fn final_spatial_is_7x7() {
        let m = mobilenet_v2();
        let last_conv = m.layers.iter().rev()
            .find(|l| matches!(l.kind, LayerKind::Conv(_))).unwrap();
        assert_eq!(last_conv.output_shape().h, 7);
        assert_eq!(last_conv.channels(), 1280);
    }
}
