//! AlexNet (Krizhevsky et al., NeurIPS 2012), the original two-tower
//! configuration expressed with grouped convolutions (conv2/4/5 have
//! groups=2). 5 conv layers — the small end of Table II (1.22 GOPs).

use super::builder::NetBuilder;
use crate::graph::Model;

/// AlexNet for 227x227x3 input (the 227 convention makes conv1 emit 55x55).
pub fn alexnet() -> Model {
    let mut b = NetBuilder::new("alexnet", 227, 227, 3);
    b.conv(96, 11, 4, 0, 1).relu();     // conv1 -> 55x55x96
    b.pool(3, 2);                        // -> 27x27
    b.conv(256, 5, 1, 2, 2).relu();     // conv2 (grouped) -> 27x27x256
    b.pool(3, 2);                        // -> 13x13
    b.conv(384, 3, 1, 1, 1).relu();     // conv3
    b.conv(384, 3, 1, 1, 2).relu();     // conv4 (grouped)
    b.conv(256, 3, 1, 1, 2).relu();     // conv5 (grouped)
    b.pool(3, 2);                        // -> 6x6
    b.fc(4096).relu().fc(4096).relu().fc(1000);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::LayerKind;

    #[test]
    fn conv_count_is_5() {
        assert_eq!(alexnet().stats().num_conv, 5);
    }

    #[test]
    fn total_ops_near_paper() {
        // Paper Table II: 1.22 GOPs total, 0.244 avg.
        let s = alexnet().stats();
        assert!((s.total_conv_gops - 1.22).abs() / 1.22 < 0.15,
                "total {}", s.total_conv_gops);
    }

    #[test]
    fn conv1_output_is_55() {
        let m = alexnet();
        let c1 = &m.layers[0];
        assert_eq!(c1.output_shape().h, 55);
        assert_eq!(c1.channels(), 96);
    }

    #[test]
    fn grouped_convs_present() {
        let m = alexnet();
        let grouped = m.layers.iter().filter(|l| match &l.kind {
            LayerKind::Conv(c) => c.groups == 2,
            _ => false,
        }).count();
        assert_eq!(grouped, 3);
    }

    #[test]
    fn flatten_dim_into_fc() {
        let m = alexnet();
        let fc = m.layers.iter()
            .find(|l| matches!(l.kind, LayerKind::Fc(_))).unwrap();
        assert_eq!(fc.input_shape().c, 6 * 6 * 256);
    }
}
