//! Synthetic models used by the paper's characterization experiments:
//! stacks of identical conv layers (Section III.B builds three 16-layer
//! CNNs from ResNet/VGG baseline convs), channel-scaled variants of the
//! VGG-19 base layer (Section II.B.2), and a small real CNN for the
//! end-to-end driver.

use super::builder::NetBuilder;
use crate::graph::layer::{ConvSpec, Layer};
use crate::graph::Model;

/// A CNN of `n` identical SAME conv layers (ReLU between), as used by the
/// Fig. 5(b) / Fig. 7 fusion experiments. `spec` must have
/// `c_in == c_out` so the chain composes.
pub fn identical_conv_model(name: &str, spec: ConvSpec, n: usize) -> Model {
    assert_eq!(spec.c_in, spec.c_out, "identical chain needs c_in == c_out");
    assert_eq!(spec.stride, 1, "identical chain needs stride 1");
    assert!(n >= 1);
    let mut b = NetBuilder::new(name, spec.h_in, spec.w_in, spec.c_in);
    for _ in 0..n {
        b.conv(spec.c_out, spec.k, spec.stride, spec.pad, spec.groups).relu();
    }
    b.build()
}

/// The paper's Section II.B.2 methodology: take the VGG-19 base layer
/// `{64, 64, 224x224, 3x3}` and scale its operation count by expanding the
/// channel dimension by `factor`.
pub fn scaled_conv_layer(factor: usize) -> Layer {
    assert!(factor >= 1);
    let c = 64 * factor;
    Layer::conv(
        format!("vgg_base_x{factor}"),
        ConvSpec::same(c, c, 224, 3),
    )
}

/// The three Fig. 5(b) baseline layers: `{64,64,56x56,3x3}`,
/// `{256,256,56x56,3x3}`, `{512,512,28x28,3x3}`.
pub fn fig5b_models(n_layers: usize) -> Vec<Model> {
    vec![
        identical_conv_model("stack_c64_s56", ConvSpec::same(64, 64, 56, 3), n_layers),
        identical_conv_model("stack_c256_s56", ConvSpec::same(256, 256, 56, 3), n_layers),
        identical_conv_model("stack_c512_s28", ConvSpec::same(512, 512, 28, 3), n_layers),
    ]
}

/// The Fig. 7(b) pair: Conv1 `{128,128,112x112,3x3}`-scale layer with
/// 1.72 GOPs, Conv2 with 0.43 GOPs.
pub fn fig7_convs() -> (ConvSpec, ConvSpec) {
    // 2*h*h*9*c*c = 1.72e9 -> c=128 @ h=76; use {128,128,76x76}: 1.70 GOPs.
    let conv1 = ConvSpec::same(128, 128, 76, 3);
    // 0.43 GOPs -> {128,128,38x38}: 0.426 GOPs.
    let conv2 = ConvSpec::same(128, 128, 38, 3);
    (conv1, conv2)
}

/// A small but real CNN for the end-to-end PJRT driver: three fusible
/// stages whose fused blocks map onto the AOT artifact catalog
/// (16x16 images, 8-channel 3x3 SAME convs).
pub fn mini_cnn() -> Model {
    let mut b = NetBuilder::new("mini_cnn", 16, 16, 8);
    for _ in 0..6 {
        b.conv_same(8, 3).relu();
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::LayerKind;

    #[test]
    fn identical_chain_validates() {
        let m = identical_conv_model("t", ConvSpec::same(64, 64, 56, 3), 16);
        assert!(m.validate().is_ok());
        assert_eq!(m.stats().num_conv, 16);
    }

    #[test]
    #[should_panic(expected = "c_in == c_out")]
    fn rejects_channel_change() {
        identical_conv_model("t", ConvSpec::same(64, 128, 56, 3), 4);
    }

    #[test]
    fn scaled_layer_ops_grow_quadratically() {
        let g1 = scaled_conv_layer(1).op_gops();
        let g2 = scaled_conv_layer(2).op_gops();
        assert!((g2 / g1 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn fig7_op_counts_match_paper() {
        let (c1, c2) = fig7_convs();
        let g1 = ConvSpec::op_gops(&c1);
        let g2 = ConvSpec::op_gops(&c2);
        assert!((g1 - 1.72).abs() < 0.05, "conv1 {g1}");
        assert!((g2 - 0.43).abs() < 0.02, "conv2 {g2}");
    }

    #[test]
    fn fig5b_models_have_right_channels() {
        let ms = fig5b_models(16);
        let cs: Vec<usize> = ms.iter().map(|m| m.layers[0].channels()).collect();
        assert_eq!(cs, vec![64, 256, 512]);
        for m in &ms {
            assert_eq!(m.stats().num_conv, 16);
        }
    }

    #[test]
    fn mini_cnn_is_artifact_compatible() {
        let m = mini_cnn();
        assert!(m.validate().is_ok());
        for l in &m.layers {
            if let LayerKind::Conv(c) = l.kind {
                assert_eq!((c.c_in, c.c_out, c.h_in, c.k), (8, 8, 16, 3));
            }
        }
    }
}
