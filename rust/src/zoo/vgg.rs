//! VGG-19 (Simonyan & Zisserman, ICLR 2015), configuration E: 16 conv
//! layers in five 3x3 stages with max-pools between, then three FC layers.
//! The paper's Table II: 36.34 GOPs over 16 convs (2.27 avg) — the
//! high-op-count-per-layer end of the evaluated spectrum.

use super::builder::NetBuilder;
use crate::graph::Model;

/// VGG-19 for 224x224x3 input.
pub fn vgg19() -> Model {
    let mut b = NetBuilder::new("vgg19", 224, 224, 3);
    // (channels, convs-in-stage); every conv is 3x3/s1/SAME + ReLU.
    let stages: [(usize, usize); 5] =
        [(64, 2), (128, 2), (256, 4), (512, 4), (512, 4)];
    for (c, n) in stages {
        for _ in 0..n {
            b.conv_same(c, 3).relu();
        }
        b.pool(2, 2);
    }
    b.fc(4096).relu().fc(4096).relu().fc(1000);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_count_is_16() {
        assert_eq!(vgg19().stats().num_conv, 16);
    }

    #[test]
    fn total_ops_near_paper() {
        // Paper Table II: 36.34 GOPs, avg 2.27.
        let s = vgg19().stats();
        assert!((s.total_conv_gops - 36.34).abs() / 36.34 < 0.15,
                "total {}", s.total_conv_gops);
        assert!((s.avg_conv_gops - 2.27).abs() / 2.27 < 0.15,
                "avg {}", s.avg_conv_gops);
    }

    #[test]
    fn first_conv_is_paper_microbench_layer() {
        // {64, 64, 224x224, 3x3} — the Section II.B.2 base layer is VGG's
        // conv1_2 (64 -> 64 at 224x224).
        let m = vgg19();
        let second_conv = m
            .layers
            .iter()
            .filter(|l| matches!(l.kind, crate::graph::LayerKind::Conv(_)))
            .nth(1)
            .unwrap();
        assert_eq!(second_conv.input_shape().h, 224);
        assert_eq!(second_conv.channels(), 64);
        assert!((second_conv.op_gops() - 3.7).abs() < 0.05);
    }

    #[test]
    fn fc_sizes() {
        let m = vgg19();
        let fcs: Vec<_> = m.layers.iter()
            .filter(|l| matches!(l.kind, crate::graph::LayerKind::Fc(_)))
            .collect();
        assert_eq!(fcs.len(), 3);
        assert_eq!(fcs[0].input_shape().c, 7 * 7 * 512);
        assert_eq!(fcs[2].output_shape().c, 1000);
    }
}
