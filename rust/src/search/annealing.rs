//! Simulated-annealing search over the *unreduced* joint space — a
//! beyond-paper comparator (the paper's related work points at learned /
//! stochastic schedulers like REGAL as the alternative to heuristics).
//!
//! State: a full [`Schedule`]. Moves: split a random block, merge two
//! adjacent blocks, or bump one block's MP up/down a power of two.
//! Acceptance: Metropolis on simulated latency with geometric cooling.
//! Deterministic under a fixed seed.
//!
//! Used by `benches/ablation.rs` to show where DLFusion's O(n) heuristic
//! sits between the oracle DP and a generic stochastic search given equal
//! and much larger move budgets.

use crate::accel::Simulator;
use crate::graph::Model;
use crate::optimizer::schedule::{Block, Schedule};
use crate::util::XorShiftRng;

/// Annealer configuration.
#[derive(Debug, Clone, Copy)]
pub struct AnnealConfig {
    pub iterations: usize,
    pub seed: u64,
    /// Initial temperature as a fraction of the initial cost.
    pub t0_fraction: f64,
    /// Geometric cooling factor per iteration.
    pub cooling: f64,
}

impl Default for AnnealConfig {
    fn default() -> Self {
        AnnealConfig { iterations: 2000, seed: 0xA11EA1, t0_fraction: 0.1, cooling: 0.997 }
    }
}

/// Run the annealer from the layer-wise MP=1 baseline (or a provided seed
/// schedule). Returns the best schedule found and its latency.
pub fn anneal(sim: &Simulator, model: &Model, cfg: &AnnealConfig,
              init: Option<Schedule>) -> (Schedule, f64) {
    let n = model.num_layers();
    let max_mp = sim.spec.num_cores;
    let mut rng = XorShiftRng::new(cfg.seed);
    let mut cur = init.unwrap_or_else(|| Schedule::layerwise(n, 1));
    debug_assert!(cur.validate(n, max_mp).is_ok());
    let cost = |s: &Schedule| sim.run_schedule(model, s).total_ms;
    let mut cur_cost = cost(&cur);
    let mut best = cur.clone();
    let mut best_cost = cur_cost;
    let mut temp = cur_cost * cfg.t0_fraction;

    for _ in 0..cfg.iterations {
        let cand = propose(&cur, &mut rng, max_mp);
        let cand_cost = cost(&cand);
        let accept = cand_cost < cur_cost
            || rng.next_f64() < (-(cand_cost - cur_cost) / temp.max(1e-12)).exp();
        if accept {
            cur = cand;
            cur_cost = cand_cost;
            if cur_cost < best_cost {
                best = cur.clone();
                best_cost = cur_cost;
            }
        }
        temp *= cfg.cooling;
    }
    (best, best_cost)
}

/// One random neighbourhood move; always yields a valid schedule.
fn propose(s: &Schedule, rng: &mut XorShiftRng, max_mp: usize) -> Schedule {
    let mut blocks = s.blocks.clone();
    match rng.gen_usize(0, 2) {
        // Split a random block at a random interior point (keeps both MPs).
        0 => {
            let bi = rng.gen_usize(0, blocks.len() - 1);
            let b = blocks[bi];
            if b.len() >= 2 {
                let cut = b.start + rng.gen_usize(1, b.len() - 1);
                blocks[bi] = Block { start: b.start, end: cut, mp: b.mp };
                blocks.insert(bi + 1, Block { start: cut, end: b.end, mp: b.mp });
            }
        }
        // Merge a random adjacent pair (MP of the larger half).
        1 => {
            if blocks.len() >= 2 {
                let bi = rng.gen_usize(0, blocks.len() - 2);
                let (a, b) = (blocks[bi], blocks[bi + 1]);
                let mp = if a.len() >= b.len() { a.mp } else { b.mp };
                blocks[bi] = Block { start: a.start, end: b.end, mp };
                blocks.remove(bi + 1);
            }
        }
        // Nudge one block's MP by a power-of-two step.
        _ => {
            let bi = rng.gen_usize(0, blocks.len() - 1);
            let b = &mut blocks[bi];
            if rng.next_f64() < 0.5 {
                b.mp = (b.mp * 2).min(max_mp.next_power_of_two() / 2 * 2).min(max_mp);
            } else {
                b.mp = (b.mp / 2).max(1);
            }
        }
    }
    Schedule::new(blocks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::layer::ConvSpec;
    use crate::optimizer;
    use crate::zoo;

    fn sim() -> Simulator {
        Simulator::mlu100()
    }

    #[test]
    fn proposals_stay_valid() {
        let s = sim();
        let m = zoo::alexnet();
        let mut rng = XorShiftRng::new(1);
        let mut cur = Schedule::layerwise(m.num_layers(), 1);
        for _ in 0..500 {
            cur = propose(&cur, &mut rng, s.spec.num_cores);
            cur.validate(m.num_layers(), s.spec.num_cores).unwrap();
        }
    }

    #[test]
    fn anneal_improves_on_baseline() {
        let s = sim();
        let m = zoo::identical_conv_model("t", ConvSpec::same(64, 64, 56, 3), 12);
        let base = s
            .run_schedule(&m, &Schedule::layerwise(m.num_layers(), 1))
            .total_ms;
        let cfg = AnnealConfig { iterations: 800, ..Default::default() };
        let (sched, cost) = anneal(&s, &m, &cfg, None);
        sched.validate(m.num_layers(), s.spec.num_cores).unwrap();
        assert!(cost < base * 0.6, "anneal {cost} vs baseline {base}");
    }

    #[test]
    fn deterministic_under_seed() {
        let s = sim();
        let m = zoo::alexnet();
        let cfg = AnnealConfig { iterations: 300, ..Default::default() };
        let (a, ca) = anneal(&s, &m, &cfg, None);
        let (b, cb) = anneal(&s, &m, &cfg, None);
        assert_eq!(a, b);
        assert_eq!(ca, cb);
    }

    #[test]
    fn warm_start_from_dlfusion_never_worse() {
        let s = sim();
        let m = zoo::resnet18();
        let dlf = optimizer::dlfusion_schedule(&m, &s.spec);
        let dlf_cost = s.run_schedule(&m, &dlf).total_ms;
        let cfg = AnnealConfig { iterations: 500, ..Default::default() };
        let (_, cost) = anneal(&s, &m, &cfg, Some(dlf));
        assert!(cost <= dlf_cost * 1.0 + 1e-12);
    }
}
