//! Simulated-annealing search over the *unreduced* joint space — a
//! beyond-paper comparator (the paper's related work points at learned /
//! stochastic schedulers like REGAL as the alternative to heuristics).
//!
//! State: a full [`Schedule`]. Moves: split a random block, merge two
//! adjacent blocks, or bump one block's MP up/down a power of two.
//! Acceptance: Metropolis on simulated latency with geometric cooling.
//! Deterministic under a fixed seed.
//!
//! Candidate costs go through [`crate::cost::CostEngine::delta_cost`]: a
//! move touches at most two blocks, so each Metropolis step computes
//! O(changed) raw block latencies instead of re-simulating the whole
//! schedule (rust/docs/DESIGN.md §7.3). The accept/reject trajectory is
//! bit-identical to full re-simulation (pinned by a unit test below).
//!
//! Used by `benches/ablation.rs` to show where DLFusion's O(n) heuristic
//! sits between the oracle DP and a generic stochastic search given equal
//! and much larger move budgets.

use crate::accel::Simulator;
use crate::cost::CostEngine;
use crate::graph::Model;
use crate::optimizer::schedule::{Block, Schedule};
use crate::util::XorShiftRng;

/// Annealer configuration.
#[derive(Debug, Clone, Copy)]
pub struct AnnealConfig {
    pub iterations: usize,
    pub seed: u64,
    /// Initial temperature as a fraction of the initial cost.
    pub t0_fraction: f64,
    /// Geometric cooling factor per iteration.
    pub cooling: f64,
}

impl Default for AnnealConfig {
    fn default() -> Self {
        AnnealConfig { iterations: 2000, seed: 0xA11EA1, t0_fraction: 0.1, cooling: 0.997 }
    }
}

/// Run the annealer from the layer-wise MP=1 baseline (or a provided seed
/// schedule). Returns the best schedule found and its latency.
#[deprecated(note = "build a `CostEngine` and call `anneal_with`, or use \
                     `tuner::Annealer` over a `TuningRequest`")]
pub fn anneal(sim: &Simulator, model: &Model, cfg: &AnnealConfig,
              init: Option<Schedule>) -> (Schedule, f64) {
    let mut engine = CostEngine::new(sim, model);
    anneal_with(&mut engine, cfg, init)
}

/// Anneal through a caller-provided engine (a warm cache carries over both
/// across restarts and from other consumers of the same model).
pub fn anneal_with(engine: &mut CostEngine, cfg: &AnnealConfig,
                   init: Option<Schedule>) -> (Schedule, f64) {
    let (best, best_cost, _) = anneal_budgeted(engine, cfg, init, None, None);
    (best, best_cost)
}

/// The Metropolis walk under optional budgets (rust/docs/DESIGN.md §8):
/// `max_evals` caps engine block queries, `max_wall_us` caps wall-clock
/// time; both are checked at the top of every move, so a truncated walk
/// still returns its best-so-far schedule. With no budgets the trajectory
/// is the exact seed loop ([`anneal_with`] is this function with `None`s).
/// Returns `(best, best_cost, truncated)`.
pub fn anneal_budgeted(engine: &mut CostEngine, cfg: &AnnealConfig,
                       init: Option<Schedule>, max_evals: Option<u64>,
                       max_wall_us: Option<u64>) -> (Schedule, f64, bool) {
    anneal_masked(engine, cfg, init, None, max_evals, max_wall_us)
}

/// The walk restricted to a fusion-legal boundary mask (the DAG
/// linearizer's cut set — rust/docs/DESIGN.md §13): splits only land on
/// legal positions (a split with no legal interior point is a no-op move),
/// merges and MP nudges never create boundaries, and the default initial
/// state is the finest legal partition at MP 1. A provided `init` must
/// already be cut-aligned. `allowed = None` is [`anneal_budgeted`] exactly;
/// an all-`true` mask consumes the identical RNG stream (same spans, same
/// draws), so the trajectory is bit-identical either way.
pub fn anneal_masked(engine: &mut CostEngine, cfg: &AnnealConfig,
                     init: Option<Schedule>, allowed: Option<&[bool]>,
                     max_evals: Option<u64>,
                     max_wall_us: Option<u64>) -> (Schedule, f64, bool) {
    let n = engine.model().num_layers();
    let max_mp = engine.sim().spec.num_cores;
    if let Some(a) = allowed {
        assert_eq!(a.len(), n + 1, "mask covers every boundary");
        assert!(a[0] && a[n], "model ends must be legal cuts");
    }
    let t0 = std::time::Instant::now();
    let queries0 = engine.local_stats().queries();
    let mut rng = XorShiftRng::new(cfg.seed);
    let mut cur = init.unwrap_or_else(|| match allowed {
        None => Schedule::layerwise(n, 1),
        Some(a) => finest_legal_partition(n, a),
    });
    debug_assert!(cur.validate(n, max_mp).is_ok());
    debug_assert!(
        allowed.map_or(true, |a| cur.blocks.iter().all(|b| a[b.start] && a[b.end])),
        "initial schedule must sit on legal cut positions"
    );
    let mut cur_cost = engine.schedule_cost(&cur);
    let mut best = cur.clone();
    let mut best_cost = cur_cost;
    let mut temp = cur_cost * cfg.t0_fraction;
    let mut truncated = false;

    for _ in 0..cfg.iterations {
        if let Some(cap) = max_evals {
            if engine.local_stats().queries() - queries0 >= cap {
                truncated = true;
                break;
            }
        }
        if let Some(cap) = max_wall_us {
            if t0.elapsed().as_micros() as u64 >= cap {
                truncated = true;
                break;
            }
        }
        let (cand, changed) = propose_masked(&cur, &mut rng, max_mp, allowed);
        let cand_cost = engine.delta_cost(&cand, &changed);
        let accept = cand_cost < cur_cost
            || rng.next_f64() < (-(cand_cost - cur_cost) / temp.max(1e-12)).exp();
        if accept {
            cur = cand;
            cur_cost = cand_cost;
            if cur_cost < best_cost {
                best = cur.clone();
                best_cost = cur_cost;
            }
        }
        temp *= cfg.cooling;
    }
    (best, best_cost, truncated)
}

/// The finest partition whose boundaries are all legal, at MP 1 — the
/// masked walk's counterpart of `Schedule::layerwise(n, 1)` (and exactly it
/// when every boundary is legal).
fn finest_legal_partition(n: usize, allowed: &[bool]) -> Schedule {
    let mut blocks = Vec::new();
    let mut start = 0usize;
    for p in 1..=n {
        if allowed[p] {
            blocks.push(Block { start, end: p, mp: 1 });
            start = p;
        }
    }
    Schedule::new(blocks)
}

/// One random neighbourhood move; always yields a valid schedule. Returns
/// the candidate plus the indices (into the *candidate's* block list) of the
/// blocks the move created — every other block is carried over verbatim, so
/// an engine that has costed the parent schedule re-computes only these.
fn propose(s: &Schedule, rng: &mut XorShiftRng, max_mp: usize)
           -> (Schedule, Vec<usize>) {
    propose_masked(s, rng, max_mp, None)
}

/// [`propose`] under an optional boundary mask. Splits draw from the
/// block's *legal* interior positions (under an all-`true` mask that range
/// has the same size as the unmasked draw, so the RNG stream — and
/// therefore the whole trajectory — is bit-identical); a block with no
/// legal interior point yields the unchanged schedule, like the existing
/// len-1 split. Merges and MP nudges only ever remove or keep boundaries,
/// so they need no masking.
fn propose_masked(s: &Schedule, rng: &mut XorShiftRng, max_mp: usize,
                  allowed: Option<&[bool]>) -> (Schedule, Vec<usize>) {
    let mut blocks = s.blocks.clone();
    let mut changed = Vec::with_capacity(2);
    match rng.gen_usize(0, 2) {
        // Split a random block at a random interior point (keeps both MPs).
        0 => {
            let bi = rng.gen_usize(0, blocks.len() - 1);
            let b = blocks[bi];
            if b.len() >= 2 {
                let cut = match allowed {
                    None => b.start + rng.gen_usize(1, b.len() - 1),
                    Some(a) => {
                        let choices: Vec<usize> =
                            (b.start + 1..b.end).filter(|&p| a[p]).collect();
                        if choices.is_empty() {
                            return (Schedule::new(blocks), changed);
                        }
                        choices[rng.gen_usize(0, choices.len() - 1)]
                    }
                };
                blocks[bi] = Block { start: b.start, end: cut, mp: b.mp };
                blocks.insert(bi + 1, Block { start: cut, end: b.end, mp: b.mp });
                changed.extend([bi, bi + 1]);
            }
        }
        // Merge a random adjacent pair (MP of the larger half).
        1 => {
            if blocks.len() >= 2 {
                let bi = rng.gen_usize(0, blocks.len() - 2);
                let (a, b) = (blocks[bi], blocks[bi + 1]);
                let mp = if a.len() >= b.len() { a.mp } else { b.mp };
                blocks[bi] = Block { start: a.start, end: b.end, mp };
                blocks.remove(bi + 1);
                changed.push(bi);
            }
        }
        // Nudge one block's MP by a power-of-two step.
        _ => {
            let bi = rng.gen_usize(0, blocks.len() - 1);
            let b = &mut blocks[bi];
            if rng.next_f64() < 0.5 {
                b.mp = (b.mp * 2).min(max_mp.next_power_of_two() / 2 * 2).min(max_mp);
            } else {
                b.mp = (b.mp / 2).max(1);
            }
            changed.push(bi);
        }
    }
    (Schedule::new(blocks), changed)
}

#[cfg(test)]
#[allow(deprecated)] // the legacy shim stays covered until it is removed
mod tests {
    use super::*;
    use crate::graph::layer::ConvSpec;
    use crate::optimizer;
    use crate::zoo;

    fn sim() -> Simulator {
        Simulator::new(crate::accel::Target::mlu100())
    }

    #[test]
    fn eval_budget_truncates_but_stays_valid() {
        let s = sim();
        let m = zoo::alexnet();
        let mut engine = CostEngine::new(&s, &m);
        let cfg = AnnealConfig::default();
        let cap = m.num_layers() as u64 + 8;
        let (sched, cost, truncated) =
            anneal_budgeted(&mut engine, &cfg, None, Some(cap), None);
        assert!(truncated, "cap {cap} must bind before 2000 moves");
        sched.validate(m.num_layers(), s.spec.num_cores).unwrap();
        assert!(cost.is_finite() && cost > 0.0);
    }

    #[test]
    fn unbudgeted_core_is_the_seed_trajectory() {
        let s = sim();
        let m = zoo::alexnet();
        let cfg = AnnealConfig { iterations: 200, ..Default::default() };
        let mut e1 = CostEngine::new(&s, &m);
        let mut e2 = CostEngine::new(&s, &m);
        let (a, ca) = anneal_with(&mut e1, &cfg, None);
        let (b, cb, truncated) = anneal_budgeted(&mut e2, &cfg, None, None, None);
        assert!(!truncated);
        assert_eq!(a, b);
        assert_eq!(ca, cb);
    }

    #[test]
    fn proposals_stay_valid() {
        let s = sim();
        let m = zoo::alexnet();
        let mut rng = XorShiftRng::new(1);
        let mut cur = Schedule::layerwise(m.num_layers(), 1);
        for _ in 0..500 {
            let (next, changed) = propose(&cur, &mut rng, s.spec.num_cores);
            next.validate(m.num_layers(), s.spec.num_cores).unwrap();
            assert!(changed.iter().all(|&bi| bi < next.blocks.len()));
            cur = next;
        }
    }

    #[test]
    fn all_legal_mask_is_bit_identical_to_unmasked() {
        let s = sim();
        let m = zoo::alexnet();
        let cfg = AnnealConfig { iterations: 300, ..Default::default() };
        let mask = vec![true; m.num_layers() + 1];
        let mut e1 = CostEngine::new(&s, &m);
        let (a, ca, _) = anneal_budgeted(&mut e1, &cfg, None, None, None);
        let mut e2 = CostEngine::new(&s, &m);
        let (b, cb, _) = anneal_masked(&mut e2, &cfg, None, Some(&mask), None, None);
        assert_eq!(a, b);
        assert_eq!(ca, cb);
        assert_eq!(e1.stats(), e2.stats());
    }

    #[test]
    fn masked_walk_stays_on_legal_boundaries() {
        let s = sim();
        let m = zoo::resnet18();
        let n = m.num_layers();
        let mut mask = vec![false; n + 1];
        for p in (0..=n).step_by(5) {
            mask[p] = true;
        }
        mask[n] = true;
        let cfg = AnnealConfig { iterations: 400, ..Default::default() };
        let mut engine = CostEngine::new(&s, &m);
        let (sched, cost, _) =
            anneal_masked(&mut engine, &cfg, None, Some(&mask), None, None);
        sched.validate(n, s.spec.num_cores).unwrap();
        assert!(cost.is_finite() && cost > 0.0);
        for b in &sched.blocks {
            assert!(mask[b.start] && mask[b.end], "illegal boundary: {b:?}");
        }
    }

    #[test]
    fn anneal_improves_on_baseline() {
        let s = sim();
        let m = zoo::identical_conv_model("t", ConvSpec::same(64, 64, 56, 3), 12);
        let base = s
            .run_schedule(&m, &Schedule::layerwise(m.num_layers(), 1))
            .total_ms;
        let cfg = AnnealConfig { iterations: 800, ..Default::default() };
        let (sched, cost) = anneal(&s, &m, &cfg, None);
        sched.validate(m.num_layers(), s.spec.num_cores).unwrap();
        assert!(cost < base * 0.6, "anneal {cost} vs baseline {base}");
    }

    #[test]
    fn deterministic_under_seed() {
        let s = sim();
        let m = zoo::alexnet();
        let cfg = AnnealConfig { iterations: 300, ..Default::default() };
        let (a, ca) = anneal(&s, &m, &cfg, None);
        let (b, cb) = anneal(&s, &m, &cfg, None);
        assert_eq!(a, b);
        assert_eq!(ca, cb);
    }

    #[test]
    fn engine_routed_anneal_matches_full_resimulation() {
        // The seed annealer re-ran `Simulator::run_schedule` on every
        // candidate. Replay that reference loop verbatim and pin the
        // engine-routed trajectory against it, bit for bit.
        let s = sim();
        for m in [zoo::alexnet(), zoo::resnet18()] {
            let cfg = AnnealConfig { iterations: 300, ..Default::default() };
            let max_mp = s.spec.num_cores;
            let mut rng = XorShiftRng::new(cfg.seed);
            let mut cur = Schedule::layerwise(m.num_layers(), 1);
            let cost = |sched: &Schedule| s.run_schedule(&m, sched).total_ms;
            let mut cur_cost = cost(&cur);
            let mut best = cur.clone();
            let mut best_cost = cur_cost;
            let mut temp = cur_cost * cfg.t0_fraction;
            for _ in 0..cfg.iterations {
                let (cand, _) = propose(&cur, &mut rng, max_mp);
                let cand_cost = cost(&cand);
                let accept = cand_cost < cur_cost
                    || rng.next_f64()
                        < (-(cand_cost - cur_cost) / temp.max(1e-12)).exp();
                if accept {
                    cur = cand;
                    cur_cost = cand_cost;
                    if cur_cost < best_cost {
                        best = cur.clone();
                        best_cost = cur_cost;
                    }
                }
                temp *= cfg.cooling;
            }
            let (sched, got_cost) = anneal(&s, &m, &cfg, None);
            assert_eq!(sched, best, "{}", m.name);
            assert_eq!(got_cost, best_cost, "{}", m.name);
        }
    }

    #[test]
    fn anneal_saves_ten_x_block_evaluations() {
        // The acceptance claim: at the default move budget the memoized
        // engine computes >= 10x fewer raw block latencies than the seed's
        // per-move full re-simulation (queries == what the seed computed).
        let s = sim();
        let m = zoo::resnet50();
        let mut engine = CostEngine::new(&s, &m);
        let cfg = AnnealConfig::default();
        let _ = anneal_with(&mut engine, &cfg, None);
        let st = engine.stats();
        assert!(st.queries() >= 10 * st.misses,
                "block-eval reduction only {:.1}x ({st:?})",
                st.block_eval_reduction());
    }

    #[test]
    fn warm_start_from_dlfusion_never_worse() {
        let s = sim();
        let m = zoo::resnet18();
        let dlf = optimizer::dlfusion_schedule(&m, &s.spec);
        let dlf_cost = s.run_schedule(&m, &dlf).total_ms;
        let cfg = AnnealConfig { iterations: 500, ..Default::default() };
        let (_, cost) = anneal(&s, &m, &cfg, Some(dlf));
        assert!(cost <= dlf_cost * 1.0 + 1e-12);
    }
}
