//! The reduced brute-force oracle (strategy 7, Section V.3).
//!
//! Search-space reductions taken from the paper:
//! 1. MP drawn from `{1, 2, 4, 8, 12, 16, 24, 32}` instead of `1..=32`;
//! 2. fusion-block sizes restricted to multiples of four (the final block
//!    may take the remainder so every layer is covered).
//!
//! Within that reduced space the total latency is a sum of independent
//! per-block costs, so the global optimum is a shortest path over cut
//! positions: `dp[j] = min over i of dp[i] + best_mp_cost(i..j)`. The DP
//! visits every (block, MP) candidate exactly once — identical result to
//! explicit enumeration (certified against [`super::exhaustive`] in tests)
//! without the exponential blowup. Block costs are served by
//! [`crate::cost::CostEngine`] (rust/docs/DESIGN.md §7), which derives the
//! per-layer facts once per model instead of once per overlapping candidate
//! range, and memoizes every `(block, mp)` outcome.

use std::collections::HashMap;
use std::time::Instant;

use crate::accel::Simulator;
use crate::cost::CostEngine;
use crate::graph::Model;
use crate::optimizer::schedule::{Block, Schedule};
use crate::util::ParallelMap;

/// Bookkeeping from a search run (for the search-time comparison the paper
/// makes: oracle O(n²) block evaluations vs DLFusion O(n)).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SearchStats {
    /// Number of (block, mp) latency evaluations requested.
    pub evaluations: usize,
    /// Number of candidate blocks considered.
    pub blocks_considered: usize,
    /// Joint (fusion, MP) cross-product candidates certified — the DP never
    /// enumerates the space, so nonzero only for
    /// [`super::exhaustive::exhaustive_schedule_with`].
    pub space_visited: u64,
    /// Evaluations served from the cost engine's cache.
    pub cache_hits: usize,
    /// Evaluations the cost engine actually computed.
    pub cache_misses: usize,
    /// Wall-clock search time, microseconds.
    pub wall_us: u64,
    /// Wall-clock time of the parallel cache-prewarm pool, microseconds —
    /// zero for sequential or budgeted runs, which have no prewarm phase.
    /// The recurrence/enumeration phase is `wall_us - prewarm_us`.
    pub prewarm_us: u64,
}

/// Block-size rule a DP or enumeration admits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockRule {
    /// Paper rule: |block| ≡ 0 (mod 4), remainder allowed only at the end.
    MultipleOfFour,
    /// Any contiguous block.
    Any,
}

impl BlockRule {
    fn allowed(&self, len: usize, ends_at_model_end: bool) -> bool {
        match self {
            BlockRule::Any => len >= 1,
            BlockRule::MultipleOfFour => len >= 1 && (len % 4 == 0 || ends_at_model_end),
        }
    }
}

/// Block admissibility under a size rule, optionally restricted to a
/// fusion-legal boundary mask (the DAG linearizer's legal cut set —
/// rust/docs/DESIGN.md §13). `allowed[p]` answers "may a block boundary sit
/// before layer `p`"; positions 0 and n must be legal. Under a mask the
/// size rule counts fusion-legal *segments* (`cum[j] - cum[i]`) instead of
/// raw layers: the segments are the units the partition can actually vary
/// over, so the multiple-of-four reduction keeps meaning (and stays
/// feasible — a residual block of 7 layers is one segment, not an
/// impossible non-multiple-of-four span). With every boundary legal the
/// segment count *is* the layer count, so the unmasked DP is unchanged bit
/// for bit.
struct CutSpace<'m> {
    rule: BlockRule,
    allowed: Option<&'m [bool]>,
    /// `cum[p]` = number of legal boundaries in `1..=p`; empty when unmasked.
    cum: Vec<usize>,
}

impl<'m> CutSpace<'m> {
    fn new(n: usize, rule: BlockRule, allowed: Option<&'m [bool]>) -> CutSpace<'m> {
        let cum = match allowed {
            None => Vec::new(),
            Some(a) => {
                assert_eq!(a.len(), n + 1, "mask covers every boundary");
                assert!(a[0] && a[n], "model ends must be legal cuts");
                let mut cum = vec![0usize; n + 1];
                for p in 1..=n {
                    cum[p] = cum[p - 1] + usize::from(a[p]);
                }
                cum
            }
        };
        CutSpace { rule, allowed, cum }
    }

    /// Is `[i, j)` an admissible block of an `n`-layer model?
    fn admissible(&self, i: usize, j: usize, n: usize) -> bool {
        match self.allowed {
            None => self.rule.allowed(j - i, j == n),
            Some(a) => a[i] && a[j] && self.rule.allowed(self.cum[j] - self.cum[i], j == n),
        }
    }
}

/// An evaluation budget stopped the DP before it reached the optimum (a
/// partial DP has no usable result, so the caller gets an error, not a
/// schedule — see rust/docs/DESIGN.md §8 budget semantics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DpBudgetExceeded {
    /// Evaluations spent when the budget bound.
    pub evaluations: u64,
    pub budget: u64,
}

/// The power-of-two MP set the full-space DP sweeps.
pub fn full_mp_set(num_cores: usize) -> Vec<usize> {
    (0..=5)
        .map(|p| 1usize << p)
        .filter(|&m| m <= num_cores)
        .collect()
}

/// The paper's reduced oracle. Returns the optimal schedule in the reduced
/// space plus search statistics.
#[deprecated(note = "build a `CostEngine` and call `oracle_schedule_with`, \
                     or use `tuner::OracleDp::reduced()` over a `TuningRequest`")]
pub fn oracle_schedule(sim: &Simulator, model: &Model) -> (Schedule, SearchStats) {
    let mut engine = CostEngine::new(sim, model);
    oracle_schedule_with(&mut engine)
}

/// The reduced oracle through a caller-provided engine (re-running a search
/// over a warm cache computes nothing new).
pub fn oracle_schedule_with(engine: &mut CostEngine) -> (Schedule, SearchStats) {
    let mps = engine.sim().spec.reduced_mp_set();
    oracle_schedule_constrained(engine, &mps, BlockRule::MultipleOfFour)
}

/// Extension: the same DP over *all* block sizes and every power-of-two MP —
/// a strictly larger space than the paper's reduced oracle (used by the
/// ablation bench to quantify what the reduction costs).
#[deprecated(note = "build a `CostEngine` and call `oracle_schedule_full_with`, \
                     or use `tuner::OracleDp::full()` over a `TuningRequest`")]
pub fn oracle_schedule_full(sim: &Simulator, model: &Model) -> (Schedule, SearchStats) {
    let mut engine = CostEngine::new(sim, model);
    oracle_schedule_full_with(&mut engine)
}

/// Full-space DP through a caller-provided engine.
pub fn oracle_schedule_full_with(engine: &mut CostEngine) -> (Schedule, SearchStats) {
    let mps = full_mp_set(engine.sim().spec.num_cores);
    oracle_schedule_constrained(engine, &mps, BlockRule::Any)
}

/// The DP over a caller-chosen MP candidate set and block-size rule (the
/// tuner API's constrained oracle; the paper presets above are wrappers).
///
/// Panics if `mp_set` is empty or the model has no layers — callers on the
/// fallible path should use [`oracle_schedule_budgeted`] behind
/// [`crate::tuner::OracleDp`], which validates the request first.
pub fn oracle_schedule_constrained(engine: &mut CostEngine, mp_set: &[usize],
                                   rule: BlockRule) -> (Schedule, SearchStats) {
    match dp_search(engine, mp_set, rule, None, None, 1) {
        Ok(r) => r,
        Err(_) => unreachable!("unbudgeted DP cannot exhaust a budget"),
    }
}

/// The constrained DP under an optional evaluation budget: checked before
/// every candidate block's MP sweep; exceeding it aborts the search.
pub fn oracle_schedule_budgeted(engine: &mut CostEngine, mp_set: &[usize],
                                rule: BlockRule, max_evals: Option<u64>)
                                -> Result<(Schedule, SearchStats), DpBudgetExceeded> {
    dp_search(engine, mp_set, rule, None, max_evals, 1)
}

/// The budgeted DP with intra-search parallelism: with `threads > 1` and no
/// evaluation budget, the candidate-block MP sweeps — the entirety of the
/// DP's evaluation cost — are precomputed by a worker pool before the
/// (cheap, inherently sequential) recurrence runs over them. The prewarm
/// issues exactly the sweep calls the sequential loop would, once each, so
/// schedules, latencies, and every counter (search stats *and* the engine's
/// merged hit/miss totals) are bit-identical to `threads == 1`
/// (rust/docs/DESIGN.md §12). Budgeted runs stay sequential: the budget's
/// abort point is defined by the sequential visit order.
pub fn oracle_schedule_threaded(engine: &mut CostEngine, mp_set: &[usize],
                                rule: BlockRule, max_evals: Option<u64>,
                                threads: usize)
                                -> Result<(Schedule, SearchStats), DpBudgetExceeded> {
    dp_search(engine, mp_set, rule, None, max_evals, threads)
}

/// The DP restricted to a fusion-legal boundary mask (see [`CutSpace`]):
/// every block's endpoints must be legal positions and the size rule counts
/// legal segments. `allowed = None` is exactly [`oracle_schedule_threaded`];
/// an all-`true` mask admits the same blocks, so schedules, stats, and the
/// engine's counters are bit-identical either way.
pub fn oracle_schedule_masked(engine: &mut CostEngine, mp_set: &[usize],
                              rule: BlockRule, allowed: Option<&[bool]>,
                              max_evals: Option<u64>, threads: usize)
                              -> Result<(Schedule, SearchStats), DpBudgetExceeded> {
    dp_search(engine, mp_set, rule, allowed, max_evals, threads)
}

/// Cut positions the DP can reach from layer 0 under `space` — exactly the
/// `dp[i].is_infinite()` skips of the recurrence, derivable up front
/// because block costs are finite.
fn reachable_cuts(n: usize, space: &CutSpace<'_>) -> Vec<bool> {
    let mut reach = vec![false; n + 1];
    reach[0] = true;
    for j in 1..=n {
        reach[j] = (0..j).any(|i| reach[i] && space.admissible(i, j, n));
    }
    reach
}

/// The admissible candidate blocks a DP over `(n, rule, mask)` evaluates —
/// every `[i, j)` with legal endpoints, a rule-satisfying size, and a start
/// reachable from layer 0 — in the DP's deterministic visit order (`j`
/// outer, `i` inner). This is the candidate space the learned active tuner
/// ([`crate::learn::ActiveTuner`]) prunes; sharing the enumeration keeps
/// its evals-saved accounting honest against the DP reference.
pub(crate) fn admissible_blocks(n: usize, rule: BlockRule,
                                allowed: Option<&[bool]>) -> Vec<(usize, usize)> {
    let space = CutSpace::new(n, rule, allowed);
    let reach = reachable_cuts(n, &space);
    let mut out = Vec::new();
    for j in 1..=n {
        for i in 0..j {
            if reach[i] && space.admissible(i, j, n) {
                out.push((i, j));
            }
        }
    }
    out
}

fn dp_search(engine: &mut CostEngine, mp_set: &[usize], sizes: BlockRule,
             allowed: Option<&[bool]>, max_evals: Option<u64>, threads: usize)
             -> Result<(Schedule, SearchStats), DpBudgetExceeded> {
    let n = engine.model().num_layers();
    assert!(n >= 1);
    assert!(!mp_set.is_empty());
    let space = CutSpace::new(n, sizes, allowed);
    let t0 = Instant::now();
    let engine_stats0 = engine.local_stats();
    let mut stats = SearchStats::default();

    // Intra-search parallelism: precompute every admissible candidate
    // block's MP sweep on a worker pool sharing this engine's cache, then
    // let the recurrence consume the rows instead of re-querying. One sweep
    // call per admissible block either way.
    let mut rows: HashMap<(usize, usize), Vec<f64>> = HashMap::new();
    if threads > 1 && max_evals.is_none() {
        let reach = reachable_cuts(n, &space);
        let mut pairs = Vec::new();
        for j in 1..=n {
            for i in 0..j {
                if reach[i] && space.admissible(i, j, n) {
                    pairs.push((i, j));
                }
            }
        }
        let shared: &CostEngine = engine;
        let costs = ParallelMap::new(threads)
            .map(&pairs, |_, &(i, j)| shared.block_latency_sweep(i, j, mp_set));
        rows = pairs.into_iter().zip(costs).collect();
        stats.prewarm_us = t0.elapsed().as_micros() as u64;
    }

    // best_block[i][j-1]: (cost, mp) of the best single block over [i, j).
    // dp[j]: best cost covering [0, j); parent[j] = (i, mp) of last block.
    let mut dp = vec![f64::INFINITY; n + 1];
    let mut parent: Vec<Option<(usize, usize)>> = vec![None; n + 1];
    dp[0] = 0.0;

    for j in 1..=n {
        for i in 0..j {
            if !space.admissible(i, j, n) {
                continue;
            }
            if dp[i].is_infinite() {
                continue;
            }
            if let Some(cap) = max_evals {
                if stats.evaluations as u64 + mp_set.len() as u64 > cap {
                    return Err(DpBudgetExceeded {
                        evaluations: stats.evaluations as u64,
                        budget: cap,
                    });
                }
            }
            stats.blocks_considered += 1;
            // One shared-precomputation call for the whole MP set —
            // identical numbers to per-MP block_latency_ms_multi (the facts
            // live in the engine, derived once per model). A threaded run
            // already holds the row from the prewarm pool.
            let costs = rows
                .remove(&(i, j))
                .unwrap_or_else(|| engine.block_latency_sweep(i, j, mp_set));
            stats.evaluations += mp_set.len();
            let (best_idx, best) = costs
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.total_cmp(b.1))
                .map(|(k, &c)| (k, c))
                .unwrap();
            let best_mp = mp_set[best_idx];
            let total = dp[i] + best;
            if total < dp[j] {
                dp[j] = total;
                parent[j] = Some((i, best_mp));
            }
        }
    }

    // Reconstruct.
    let mut blocks = Vec::new();
    let mut j = n;
    while j > 0 {
        let (i, mp) = parent[j].expect("dp unreachable state");
        blocks.push(Block { start: i, end: j, mp });
        j = i;
    }
    blocks.reverse();
    let schedule = Schedule::new(blocks);
    debug_assert!(schedule.validate(n, engine.sim().spec.num_cores).is_ok());
    let engine_stats = engine.local_stats();
    stats.cache_hits = (engine_stats.hits - engine_stats0.hits) as usize;
    stats.cache_misses = (engine_stats.misses - engine_stats0.misses) as usize;
    stats.wall_us = t0.elapsed().as_micros() as u64;
    Ok((schedule, stats))
}

#[cfg(test)]
#[allow(deprecated)] // the legacy shims stay covered until they are removed
mod tests {
    use super::*;
    use crate::graph::layer::ConvSpec;
    use crate::optimizer::dlfusion_schedule;
    use crate::zoo;

    fn sim() -> Simulator {
        Simulator::new(crate::accel::Target::mlu100())
    }

    #[test]
    fn constrained_dp_generalizes_the_presets() {
        let s = sim();
        let m = zoo::alexnet();
        let mut e1 = CostEngine::new(&s, &m);
        let mut e2 = CostEngine::new(&s, &m);
        let mps = s.spec.reduced_mp_set();
        let (a, _) = oracle_schedule_with(&mut e1);
        let (b, _) = oracle_schedule_constrained(&mut e2, &mps,
                                                 BlockRule::MultipleOfFour);
        assert_eq!(a, b);
        let mut e3 = CostEngine::new(&s, &m);
        let mut e4 = CostEngine::new(&s, &m);
        let (a, _) = oracle_schedule_full_with(&mut e3);
        let (b, _) = oracle_schedule_constrained(
            &mut e4, &full_mp_set(s.spec.num_cores), BlockRule::Any);
        assert_eq!(a, b);
    }

    #[test]
    fn budget_aborts_the_dp_deterministically() {
        let s = sim();
        let m = zoo::alexnet();
        let mps = s.spec.reduced_mp_set();
        let mut engine = CostEngine::new(&s, &m);
        let err = oracle_schedule_budgeted(&mut engine, &mps,
                                           BlockRule::MultipleOfFour, Some(4))
            .unwrap_err();
        assert_eq!(err.budget, 4);
        assert!(err.evaluations <= 4);
        // An unbudgeted run on the same engine still completes.
        let (sched, st) = oracle_schedule_budgeted(
            &mut engine, &mps, BlockRule::MultipleOfFour, None).unwrap();
        sched.validate(m.num_layers(), s.spec.num_cores).unwrap();
        // A budget exactly equal to the need also completes.
        let mut fresh = CostEngine::new(&s, &m);
        let (sched2, _) = oracle_schedule_budgeted(
            &mut fresh, &mps, BlockRule::MultipleOfFour,
            Some(st.evaluations as u64)).unwrap();
        assert_eq!(sched, sched2);
    }

    #[test]
    fn oracle_covers_and_respects_block_rule() {
        let s = sim();
        let m = zoo::resnet18();
        let (sched, _) = oracle_schedule(&s, &m);
        sched.validate(m.num_layers(), s.spec.num_cores).unwrap();
        for (i, b) in sched.blocks.iter().enumerate() {
            let last = i == sched.blocks.len() - 1;
            assert!(b.len() % 4 == 0 || last,
                    "block {i} len {} violates multiple-of-four", b.len());
            assert!(s.spec.reduced_mp_set().contains(&b.mp));
        }
    }

    #[test]
    fn oracle_beats_or_matches_dlfusion() {
        // Strategy 7 is the optimal point of a superset of DLFusion's
        // decisions *up to the size rule*; on the evaluated networks it must
        // not lose by more than the rule's quantization. We assert the
        // stronger practical property the paper reports: oracle >= DLFusion.
        let s = sim();
        for m in [zoo::resnet18(), zoo::vgg19(), zoo::alexnet()] {
            let (oracle, _) = oracle_schedule(&s, &m);
            let heuristic = dlfusion_schedule(&m, &s.spec);
            let t_oracle = s.run_schedule(&m, &oracle).total_ms;
            let t_heur = s.run_schedule(&m, &heuristic).total_ms;
            assert!(t_oracle <= t_heur * 1.02,
                    "{}: oracle {t_oracle} vs dlfusion {t_heur}", m.name);
        }
    }

    #[test]
    fn engine_routed_dp_matches_seed_dp() {
        // The seed DP called `Simulator::block_latency_ms_multi` per
        // candidate range; replay that reference verbatim and pin the
        // engine-routed result against it, bit for bit.
        let s = sim();
        for m in [zoo::resnet18(), zoo::alexnet()] {
            let mp_set = s.spec.reduced_mp_set();
            let n = m.num_layers();
            let mut dp = vec![f64::INFINITY; n + 1];
            let mut parent: Vec<Option<(usize, usize)>> = vec![None; n + 1];
            dp[0] = 0.0;
            for j in 1..=n {
                for i in 0..j {
                    let len = j - i;
                    if !(len % 4 == 0 || j == n) || dp[i].is_infinite() {
                        continue;
                    }
                    let costs = s.block_latency_ms_multi(&m.layers[i..j], &mp_set);
                    let (k, best) = costs
                        .iter()
                        .enumerate()
                        .min_by(|a, b| a.1.total_cmp(b.1))
                        .map(|(k, &c)| (k, c))
                        .unwrap();
                    if dp[i] + best < dp[j] {
                        dp[j] = dp[i] + best;
                        parent[j] = Some((i, mp_set[k]));
                    }
                }
            }
            let mut blocks = Vec::new();
            let mut j = n;
            while j > 0 {
                let (i, mp) = parent[j].unwrap();
                blocks.push(Block { start: i, end: j, mp });
                j = i;
            }
            blocks.reverse();
            let reference = Schedule::new(blocks);
            let (sched, _) = oracle_schedule(&s, &m);
            assert_eq!(sched, reference, "{}", m.name);
        }
    }

    #[test]
    fn full_dp_at_least_as_good_as_reduced() {
        let s = sim();
        let m = zoo::alexnet();
        let (red, _) = oracle_schedule(&s, &m);
        let (full, _) = oracle_schedule_full(&s, &m);
        let t_red = s.run_schedule(&m, &red).total_ms;
        let t_full = s.run_schedule(&m, &full).total_ms;
        assert!(t_full <= t_red + 1e-12);
    }

    #[test]
    fn search_stats_scale_quadratically() {
        let s = sim();
        let m1 = zoo::identical_conv_model("a", ConvSpec::same(64, 64, 28, 3), 8);
        let m2 = zoo::identical_conv_model("b", ConvSpec::same(64, 64, 28, 3), 16);
        let (_, st1) = oracle_schedule(&s, &m1);
        let (_, st2) = oracle_schedule(&s, &m2);
        assert!(st2.blocks_considered > st1.blocks_considered * 2);
        assert_eq!(st1.evaluations, st1.blocks_considered * 8);
    }

    #[test]
    fn search_stats_carry_cache_and_wall_clock() {
        let s = sim();
        let m = zoo::alexnet();
        let mut engine = CostEngine::new(&s, &m);
        let (_, st) = oracle_schedule_with(&mut engine);
        // A fresh engine: every (block, mp) pair is computed exactly once.
        assert_eq!(st.cache_hits + st.cache_misses, st.evaluations);
        assert_eq!(st.cache_hits, 0);
        // Re-running the same search over the warm engine computes nothing.
        let (_, st2) = oracle_schedule_with(&mut engine);
        assert_eq!(st2.cache_misses, 0);
        assert_eq!(st2.cache_hits, st2.evaluations);
    }

    #[test]
    fn threaded_dp_is_bit_identical_to_sequential() {
        let s = sim();
        for m in [zoo::resnet18(), zoo::alexnet()] {
            let mps = s.spec.reduced_mp_set();
            let mut seq = CostEngine::new(&s, &m);
            let (sched_seq, st_seq) = oracle_schedule_threaded(
                &mut seq, &mps, BlockRule::MultipleOfFour, None, 1).unwrap();
            let mut par = CostEngine::new(&s, &m);
            let (sched_par, st_par) = oracle_schedule_threaded(
                &mut par, &mps, BlockRule::MultipleOfFour, None, 4).unwrap();
            assert_eq!(sched_seq, sched_par, "{}", m.name);
            assert_eq!(st_seq.evaluations, st_par.evaluations);
            assert_eq!(st_seq.blocks_considered, st_par.blocks_considered);
            assert_eq!(st_seq.cache_hits, st_par.cache_hits);
            assert_eq!(st_seq.cache_misses, st_par.cache_misses);
            // The prewarm issues exactly the sequential query stream, so
            // even the engines' merged counters agree.
            assert_eq!(seq.stats(), par.stats(), "{}", m.name);
        }
    }

    #[test]
    fn all_legal_mask_is_bit_identical_to_unmasked() {
        let s = sim();
        for m in [zoo::resnet18(), zoo::alexnet()] {
            let mps = s.spec.reduced_mp_set();
            let mask = vec![true; m.num_layers() + 1];
            let mut e1 = CostEngine::new(&s, &m);
            let (a, sta) = oracle_schedule_threaded(
                &mut e1, &mps, BlockRule::MultipleOfFour, None, 1).unwrap();
            let mut e2 = CostEngine::new(&s, &m);
            let (b, stb) = oracle_schedule_masked(
                &mut e2, &mps, BlockRule::MultipleOfFour, Some(&mask), None, 1)
                .unwrap();
            assert_eq!(a, b, "{}", m.name);
            assert_eq!(sta.evaluations, stb.evaluations, "{}", m.name);
            assert_eq!(sta.blocks_considered, stb.blocks_considered, "{}", m.name);
            assert_eq!(sta.cache_hits, stb.cache_hits, "{}", m.name);
            assert_eq!(sta.cache_misses, stb.cache_misses, "{}", m.name);
            assert_eq!(e1.stats(), e2.stats(), "{}", m.name);
        }
    }

    #[test]
    fn masked_dp_respects_the_mask_and_counts_segments() {
        let s = sim();
        let m = zoo::identical_conv_model("t", ConvSpec::same(64, 64, 28, 3), 16);
        let n = m.num_layers();
        // Legal boundaries every 2 layers: 16 segments of 2 layers each.
        let mut mask = vec![false; n + 1];
        for p in (0..=n).step_by(2) {
            mask[p] = true;
        }
        let mps = s.spec.reduced_mp_set();
        let mut engine = CostEngine::new(&s, &m);
        let (sched, _) = oracle_schedule_masked(
            &mut engine, &mps, BlockRule::MultipleOfFour, Some(&mask), None, 1)
            .unwrap();
        sched.validate(n, s.spec.num_cores).unwrap();
        let segs = |b: &Block| (b.start + 1..=b.end).filter(|&p| mask[p]).count();
        for (i, b) in sched.blocks.iter().enumerate() {
            assert!(mask[b.start] && mask[b.end], "illegal boundary: {b:?}");
            let last = i == sched.blocks.len() - 1;
            assert!(segs(b) % 4 == 0 || last,
                    "block {b:?} spans {} segments", segs(b));
        }
    }

    #[test]
    fn masked_dp_stays_feasible_on_sparse_cut_sets() {
        // Residual-style legality: blocks of 7 and 9 layers between legal
        // boundaries. Raw multiple-of-four would be infeasible everywhere
        // except the single block; segment counting keeps a real search.
        let s = sim();
        let m = zoo::resnet18();
        let n = m.num_layers();
        let legal = [0usize, 2, 7, 12, 19, 26, 33, 40, n];
        let mut mask = vec![false; n + 1];
        for &p in &legal {
            mask[p] = true;
        }
        let mps = s.spec.reduced_mp_set();
        let mut engine = CostEngine::new(&s, &m);
        let (sched, st) = oracle_schedule_masked(
            &mut engine, &mps, BlockRule::MultipleOfFour, Some(&mask), None, 1)
            .unwrap();
        sched.validate(n, s.spec.num_cores).unwrap();
        for b in &sched.blocks {
            assert!(mask[b.start] && mask[b.end], "illegal boundary: {b:?}");
        }
        // The mask admits far fewer candidate blocks than the free DP.
        let mut free = CostEngine::new(&s, &m);
        let (_, st_free) = oracle_schedule_with(&mut free);
        assert!(st.blocks_considered < st_free.blocks_considered);
    }

    #[test]
    fn threaded_masked_dp_is_bit_identical_to_sequential() {
        let s = sim();
        let m = zoo::resnet18();
        let n = m.num_layers();
        let mut mask = vec![false; n + 1];
        for p in (0..=n).step_by(3) {
            mask[p] = true;
        }
        mask[n] = true;
        let mps = s.spec.reduced_mp_set();
        let mut seq = CostEngine::new(&s, &m);
        let (a, sta) = oracle_schedule_masked(
            &mut seq, &mps, BlockRule::MultipleOfFour, Some(&mask), None, 1)
            .unwrap();
        let mut par = CostEngine::new(&s, &m);
        let (b, stb) = oracle_schedule_masked(
            &mut par, &mps, BlockRule::MultipleOfFour, Some(&mask), None, 4)
            .unwrap();
        assert_eq!(a, b);
        assert_eq!(sta.evaluations, stb.evaluations);
        assert_eq!(sta.blocks_considered, stb.blocks_considered);
        assert_eq!(sta.cache_hits, stb.cache_hits);
        assert_eq!(sta.cache_misses, stb.cache_misses);
        assert_eq!(seq.stats(), par.stats());
    }

    #[test]
    fn single_layer_model() {
        let s = sim();
        let m = zoo::identical_conv_model("one", ConvSpec::same(64, 64, 28, 3), 1);
        // n=2 layers (conv+relu). Must still produce a valid schedule.
        let (sched, _) = oracle_schedule(&s, &m);
        sched.validate(m.num_layers(), s.spec.num_cores).unwrap();
    }
}
