//! True exhaustive enumeration over the *full* joint space for tiny models:
//! every contiguous partition (2^(n-1) cut masks) × every MP assignment.
//! Exponential — guarded to n <= 12 — and used solely to certify that the
//! DP oracle is exact and that Eq. 4 counts what we think it counts.

use crate::accel::Simulator;
use crate::graph::Model;
use crate::optimizer::schedule::{Block, Schedule};

/// Enumerate everything; return the best schedule and the number of
/// candidates visited.
pub fn exhaustive_schedule(sim: &Simulator, model: &Model, mp_set: &[usize])
                           -> (Schedule, u64) {
    let n = model.num_layers();
    assert!(n >= 1 && n <= 12, "exhaustive search is exponential (n={n})");
    assert!(!mp_set.is_empty());
    let mut best_cost = f64::INFINITY;
    let mut best: Option<Schedule> = None;
    let mut visited = 0u64;

    // Each mask bit k set = a cut after layer k.
    for mask in 0u32..(1 << (n - 1)) {
        // Build block ranges.
        let mut ranges = Vec::new();
        let mut start = 0usize;
        for k in 0..(n - 1) {
            if mask & (1 << k) != 0 {
                ranges.push((start, k + 1));
                start = k + 1;
            }
        }
        ranges.push((start, n));
        // Cost of each block is independent: pick its best MP directly
        // (equivalent to enumerating the cross product, but we still count
        // the full cross product as "visited" for the space comparison).
        let mut total = 0.0;
        let mut blocks = Vec::with_capacity(ranges.len());
        for &(i, j) in &ranges {
            let mut best_mp = mp_set[0];
            let mut best_c = f64::INFINITY;
            for &mp in mp_set {
                let c = sim.block_latency_ms(&model.layers[i..j], mp);
                if c < best_c {
                    best_c = c;
                    best_mp = mp;
                }
            }
            total += best_c;
            blocks.push(Block { start: i, end: j, mp: best_mp });
        }
        visited += (mp_set.len() as u64).pow(ranges.len() as u32);
        if total < best_cost {
            best_cost = total;
            best = Some(Schedule::new(blocks));
        }
    }
    (best.unwrap(), visited)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::layer::ConvSpec;
    use crate::optimizer::space::enumerate_space;
    use crate::search::brute::oracle_schedule_full;
    use crate::zoo;

    #[test]
    fn dp_matches_exhaustive_on_tiny_models() {
        let sim = Simulator::mlu100();
        let mp_set: Vec<usize> = vec![1, 2, 4, 8, 16, 32];
        for n in [2usize, 3, 5, 8] {
            let m = zoo::identical_conv_model(
                "t", ConvSpec::same(64, 64, 28, 3), n);
            // Strip the relus so n stays tiny and blocks equal convs.
            let m = crate::graph::Model::new(
                "t",
                m.input,
                m.layers.into_iter().filter(|l| l.is_compute()).collect(),
            );
            let (ex, _) = exhaustive_schedule(&sim, &m, &mp_set);
            let (dp, _) = oracle_schedule_full(&sim, &m);
            let t_ex = sim.run_schedule(&m, &ex).total_ms;
            let t_dp = sim.run_schedule(&m, &dp).total_ms;
            assert!((t_ex - t_dp).abs() < 1e-9,
                    "n={n}: exhaustive {t_ex} vs dp {t_dp}");
        }
    }

    #[test]
    fn visited_count_matches_eq4_including_single_block() {
        // Eq. 4 counts partitions with >= 2 blocks; exhaustive also visits
        // the single-block case, so visited = Eq4(n, m) + m.
        let sim = Simulator::mlu100();
        let n = 6;
        let mp_set = vec![1, 2, 4, 8];
        let m = zoo::identical_conv_model("t", ConvSpec::same(32, 32, 14, 3), n);
        let m = crate::graph::Model::new(
            "t",
            m.input,
            m.layers.into_iter().filter(|l| l.is_compute()).collect(),
        );
        let (_, visited) = exhaustive_schedule(&sim, &m, &mp_set);
        let eq4 = enumerate_space(n, mp_set.len());
        assert_eq!(visited as u128, eq4 + mp_set.len() as u128);
    }

    #[test]
    #[should_panic(expected = "exponential")]
    fn guards_large_n() {
        let sim = Simulator::mlu100();
        let m = zoo::resnet18();
        exhaustive_schedule(&sim, &m, &[1]);
    }
}
