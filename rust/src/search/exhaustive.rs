//! True exhaustive enumeration over the *full* joint space for tiny models:
//! every contiguous partition (2^(n-1) cut masks) × every MP assignment.
//! Exponential — refused past [`MAX_EXHAUSTIVE_LAYERS`] layers — and used
//! solely to certify that the DP oracle is exact and that Eq. 4 counts what
//! we think it counts.
//!
//! Candidates are evaluated through the shared [`crate::cost::CostEngine`]
//! (scalar path, bit-identical to the former direct
//! `Simulator::block_latency_ms` calls): overlapping partitions share every
//! `(block, mp)` evaluation instead of re-deriving per-layer facts per
//! candidate, and the run reports [`SearchStats`] like every other backend.

use std::collections::HashMap;
use std::time::Instant;

use crate::accel::Simulator;
use crate::cost::CostEngine;
use crate::graph::Model;
use crate::optimizer::schedule::{Block, Schedule};
use crate::search::brute::SearchStats;
use crate::util::ParallelMap;

/// Hard ceiling on model size: 2^(n-1) cut masks get out of hand fast.
pub const MAX_EXHAUSTIVE_LAYERS: usize = 12;

/// Why an enumeration could not run. Search-level, like
/// [`super::brute::DpBudgetExceeded`]; the [`crate::tuner::Exhaustive`]
/// backend maps these onto `TuningError`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExhaustiveError {
    /// More than [`MAX_EXHAUSTIVE_LAYERS`] layers: exponential blowup.
    ModelTooLarge { layers: usize, max: usize },
    /// No MP candidates to assign.
    EmptyMpSet,
    /// The evaluation budget bound before the enumeration finished (a
    /// partial enumeration certifies nothing).
    BudgetExhausted { spent: u64, budget: u64 },
}

impl std::fmt::Display for ExhaustiveError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExhaustiveError::ModelTooLarge { layers, max } => write!(
                f, "exhaustive search is exponential: {layers} layers (max {max})"),
            ExhaustiveError::EmptyMpSet => write!(f, "MP candidate set is empty"),
            ExhaustiveError::BudgetExhausted { spent, budget } => write!(
                f, "evaluation budget exhausted: {spent} of {budget} spent"),
        }
    }
}

impl std::error::Error for ExhaustiveError {}

/// Enumerate everything; return the best schedule and the number of
/// candidates visited.
#[deprecated(note = "build a `CostEngine` and call `exhaustive_schedule_with`, \
                     or use `tuner::Exhaustive` over a `TuningRequest`")]
pub fn exhaustive_schedule(sim: &Simulator, model: &Model, mp_set: &[usize])
                           -> (Schedule, u64) {
    let n = model.num_layers();
    assert!(n >= 1 && n <= MAX_EXHAUSTIVE_LAYERS,
            "exhaustive search is exponential (n={n})");
    assert!(!mp_set.is_empty());
    let mut engine = CostEngine::new(sim, model);
    let (sched, stats) = exhaustive_schedule_with(&mut engine, mp_set)
        .expect("guards checked above");
    (sched, stats.space_visited)
}

/// Engine-routed exhaustive enumeration: best schedule plus search stats
/// (`space_visited` carries the Eq. 4 cross-product count; `evaluations`
/// the block-latency queries actually requested).
pub fn exhaustive_schedule_with(engine: &mut CostEngine, mp_set: &[usize])
                                -> Result<(Schedule, SearchStats), ExhaustiveError> {
    exhaustive_schedule_budgeted(engine, mp_set, None)
}

/// Exhaustive enumeration under an optional evaluation budget, checked
/// before each block's MP sweep (a partial enumeration certifies nothing,
/// so exceeding the budget is an error — rust/docs/DESIGN.md §8).
pub fn exhaustive_schedule_budgeted(engine: &mut CostEngine, mp_set: &[usize],
                                    max_evals: Option<u64>)
                                    -> Result<(Schedule, SearchStats), ExhaustiveError> {
    enumerate(engine, mp_set, None, max_evals, 1)
}

/// Exhaustive enumeration with intra-search parallelism: with `threads > 1`
/// and no budget, the `n(n+1)/2 × |mp|` distinct block latencies — the
/// entirety of the enumeration's evaluation cost — are precomputed by a
/// worker pool, and the partition loop reads the table instead of the
/// engine. Schedules and every `SearchStats` counter are bit-identical to
/// sequential; the engine's own counters see each distinct key once rather
/// than once per partition (rust/docs/DESIGN.md §12). Budgeted runs stay
/// sequential to preserve the exact abort point.
pub fn exhaustive_schedule_threaded(engine: &mut CostEngine, mp_set: &[usize],
                                    max_evals: Option<u64>, threads: usize)
                                    -> Result<(Schedule, SearchStats), ExhaustiveError> {
    enumerate(engine, mp_set, None, max_evals, threads)
}

/// Exhaustive enumeration restricted to a fusion-legal boundary mask (the
/// DAG linearizer's cut set — rust/docs/DESIGN.md §13): cut masks placing a
/// boundary at an illegal position are skipped before any evaluation, so
/// `space_visited` counts only the legal joint space. `allowed = None` is
/// exactly [`exhaustive_schedule_threaded`]; an all-`true` mask skips
/// nothing, so results and every counter are bit-identical either way.
pub fn exhaustive_schedule_masked(engine: &mut CostEngine, mp_set: &[usize],
                                  allowed: Option<&[bool]>,
                                  max_evals: Option<u64>, threads: usize)
                                  -> Result<(Schedule, SearchStats), ExhaustiveError> {
    enumerate(engine, mp_set, allowed, max_evals, threads)
}

fn enumerate(engine: &mut CostEngine, mp_set: &[usize],
             allowed: Option<&[bool]>, max_evals: Option<u64>, threads: usize)
             -> Result<(Schedule, SearchStats), ExhaustiveError> {
    let n = engine.model().num_layers();
    if n < 1 || n > MAX_EXHAUSTIVE_LAYERS {
        return Err(ExhaustiveError::ModelTooLarge { layers: n, max: MAX_EXHAUSTIVE_LAYERS });
    }
    if mp_set.is_empty() {
        return Err(ExhaustiveError::EmptyMpSet);
    }
    if let Some(a) = allowed {
        assert_eq!(a.len(), n + 1, "mask covers every boundary");
        assert!(a[0] && a[n], "model ends must be legal cuts");
    }
    let t0 = Instant::now();
    let engine_stats0 = engine.local_stats();
    let mut stats = SearchStats::default();
    let mut best_cost = f64::INFINITY;
    let mut best: Option<Schedule> = None;

    // Intra-search parallelism: precompute every distinct block's per-MP
    // latencies once (overlapping partitions re-read the table for free).
    let mut table: Option<HashMap<(usize, usize), Vec<f64>>> = None;
    if threads > 1 && max_evals.is_none() {
        let mut pairs = Vec::new();
        for i in 0..n {
            for j in (i + 1)..=n {
                if allowed.map_or(true, |a| a[i] && a[j]) {
                    pairs.push((i, j));
                }
            }
        }
        let shared: &CostEngine = engine;
        let rows = ParallelMap::new(threads).map(&pairs, |_, &(i, j)| {
            mp_set.iter().map(|&mp| shared.block_latency(i, j, mp)).collect::<Vec<f64>>()
        });
        table = Some(pairs.into_iter().zip(rows).collect());
        stats.prewarm_us = t0.elapsed().as_micros() as u64;
    }

    // Each mask bit k set = a cut after layer k.
    for mask in 0u32..(1 << (n - 1)) {
        // Under a boundary mask, partitions cutting at an illegal position
        // are skipped outright (the `None` path iterates identically).
        if let Some(a) = allowed {
            if (0..(n - 1)).any(|k| mask & (1 << k) != 0 && !a[k + 1]) {
                continue;
            }
        }
        // Build block ranges.
        let mut ranges = Vec::new();
        let mut start = 0usize;
        for k in 0..(n - 1) {
            if mask & (1 << k) != 0 {
                ranges.push((start, k + 1));
                start = k + 1;
            }
        }
        ranges.push((start, n));
        // Cost of each block is independent: pick its best MP directly
        // (equivalent to enumerating the cross product, but we still count
        // the full cross product as "visited" for the space comparison).
        let mut total = 0.0;
        let mut blocks = Vec::with_capacity(ranges.len());
        for &(i, j) in &ranges {
            if let Some(cap) = max_evals {
                if stats.evaluations as u64 + mp_set.len() as u64 > cap {
                    return Err(ExhaustiveError::BudgetExhausted {
                        spent: stats.evaluations as u64,
                        budget: cap,
                    });
                }
            }
            stats.blocks_considered += 1;
            let mut best_mp = mp_set[0];
            let mut best_c = f64::INFINITY;
            for (k, &mp) in mp_set.iter().enumerate() {
                let c = match &table {
                    Some(t) => t[&(i, j)][k],
                    None => engine.block_latency(i, j, mp),
                };
                stats.evaluations += 1;
                if c < best_c {
                    best_c = c;
                    best_mp = mp;
                }
            }
            total += best_c;
            blocks.push(Block { start: i, end: j, mp: best_mp });
        }
        stats.space_visited += (mp_set.len() as u64).pow(ranges.len() as u32);
        if total < best_cost {
            best_cost = total;
            best = Some(Schedule::new(blocks));
        }
    }
    // The n >= 1 guard means mask 0 (the single-block partition) was
    // always visited, so a best schedule exists.
    let schedule = match best {
        Some(s) => s,
        None => unreachable!("n >= 1 guarantees at least one partition"),
    };
    let engine_stats = engine.local_stats();
    stats.cache_misses = (engine_stats.misses - engine_stats0.misses) as usize;
    // Every loop evaluation not computed by the engine was served from a
    // cache — the engine's or the prewarm table's. In a sequential run this
    // equals the engine's hit delta bit for bit; in a threaded run it keeps
    // the per-search stats identical to sequential.
    stats.cache_hits = stats.evaluations - stats.cache_misses;
    stats.wall_us = t0.elapsed().as_micros() as u64;
    Ok((schedule, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::layer::ConvSpec;
    use crate::optimizer::space::enumerate_space;
    use crate::search::brute::oracle_schedule_full_with;
    use crate::zoo;

    fn conv_only(n: usize) -> Model {
        let m = zoo::identical_conv_model("t", ConvSpec::same(64, 64, 28, 3), n);
        // Strip the relus so n stays tiny and blocks equal convs.
        Model::new(
            "t",
            m.input,
            m.layers.into_iter().filter(|l| l.is_compute()).collect(),
        )
    }

    #[test]
    fn dp_matches_exhaustive_on_tiny_models() {
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let mp_set: Vec<usize> = vec![1, 2, 4, 8, 16, 32];
        for n in [2usize, 3, 5, 8] {
            let m = conv_only(n);
            let mut engine = CostEngine::new(&sim, &m);
            let (ex, _) = exhaustive_schedule_with(&mut engine, &mp_set).unwrap();
            let (dp, _) = oracle_schedule_full_with(&mut engine);
            let t_ex = sim.run_schedule(&m, &ex).total_ms;
            let t_dp = sim.run_schedule(&m, &dp).total_ms;
            assert!((t_ex - t_dp).abs() < 1e-9,
                    "n={n}: exhaustive {t_ex} vs dp {t_dp}");
        }
    }

    #[test]
    fn visited_count_matches_eq4_including_single_block() {
        // Eq. 4 counts partitions with >= 2 blocks; exhaustive also visits
        // the single-block case, so visited = Eq4(n, m) + m.
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let n = 6;
        let mp_set = vec![1, 2, 4, 8];
        let m = {
            let m = zoo::identical_conv_model("t", ConvSpec::same(32, 32, 14, 3), n);
            Model::new(
                "t",
                m.input,
                m.layers.into_iter().filter(|l| l.is_compute()).collect(),
            )
        };
        let mut engine = CostEngine::new(&sim, &m);
        let (_, stats) = exhaustive_schedule_with(&mut engine, &mp_set).unwrap();
        let eq4 = enumerate_space(n, mp_set.len());
        assert_eq!(stats.space_visited as u128, eq4 + mp_set.len() as u128);
    }

    #[test]
    fn engine_routed_matches_seed_sim_direct_enumeration() {
        // Replay the seed loop verbatim — `Simulator::block_latency_ms` per
        // (range, mp), no engine — and pin the engine-routed result against
        // it: same schedule, same visit count, bit for bit.
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let mp_set = vec![1usize, 2, 4, 8];
        for n in [3usize, 6] {
            let m = conv_only(n);
            let mut best_cost = f64::INFINITY;
            let mut best: Option<Schedule> = None;
            let mut visited = 0u64;
            for mask in 0u32..(1 << (n - 1)) {
                let mut ranges = Vec::new();
                let mut start = 0usize;
                for k in 0..(n - 1) {
                    if mask & (1 << k) != 0 {
                        ranges.push((start, k + 1));
                        start = k + 1;
                    }
                }
                ranges.push((start, n));
                let mut total = 0.0;
                let mut blocks = Vec::with_capacity(ranges.len());
                for &(i, j) in &ranges {
                    let mut best_mp = mp_set[0];
                    let mut best_c = f64::INFINITY;
                    for &mp in &mp_set {
                        let c = sim.block_latency_ms(&m.layers[i..j], mp);
                        if c < best_c {
                            best_c = c;
                            best_mp = mp;
                        }
                    }
                    total += best_c;
                    blocks.push(Block { start: i, end: j, mp: best_mp });
                }
                visited += (mp_set.len() as u64).pow(ranges.len() as u32);
                if total < best_cost {
                    best_cost = total;
                    best = Some(Schedule::new(blocks));
                }
            }
            let reference = best.unwrap();
            let mut engine = CostEngine::new(&sim, &m);
            let (sched, stats) = exhaustive_schedule_with(&mut engine, &mp_set).unwrap();
            assert_eq!(sched, reference, "n={n}");
            assert_eq!(stats.space_visited, visited, "n={n}");
        }
    }

    #[test]
    #[allow(deprecated)]
    fn legacy_shim_delegates_to_engine_path() {
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let mp_set = vec![1, 2, 4, 8];
        let m = conv_only(4);
        let (legacy, visited) = exhaustive_schedule(&sim, &m, &mp_set);
        let mut engine = CostEngine::new(&sim, &m);
        let (sched, stats) = exhaustive_schedule_with(&mut engine, &mp_set).unwrap();
        assert_eq!(sched, legacy);
        assert_eq!(stats.space_visited, visited);
    }

    #[test]
    fn shared_engine_caches_overlapping_partitions() {
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let m = conv_only(6);
        let mp_set = vec![1, 2, 4, 8];
        let mut engine = CostEngine::new(&sim, &m);
        let (_, stats) = exhaustive_schedule_with(&mut engine, &mp_set).unwrap();
        // Distinct (block, mp) pairs: n(n+1)/2 ranges x |mp|.
        let distinct = 6 * 7 / 2 * mp_set.len();
        assert_eq!(stats.cache_misses, distinct);
        assert!(stats.cache_hits > 0);
        assert_eq!(stats.cache_hits + stats.cache_misses, stats.evaluations);
    }

    #[test]
    fn threaded_enumeration_is_bit_identical_to_sequential() {
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let m = conv_only(7);
        let mp_set = vec![1, 2, 4, 8];
        let mut seq = CostEngine::new(&sim, &m);
        let (sched_seq, st_seq) =
            exhaustive_schedule_threaded(&mut seq, &mp_set, None, 1).unwrap();
        let mut par = CostEngine::new(&sim, &m);
        let (sched_par, st_par) =
            exhaustive_schedule_threaded(&mut par, &mp_set, None, 4).unwrap();
        assert_eq!(sched_seq, sched_par);
        assert_eq!(st_seq.evaluations, st_par.evaluations);
        assert_eq!(st_seq.blocks_considered, st_par.blocks_considered);
        assert_eq!(st_seq.space_visited, st_par.space_visited);
        assert_eq!(st_seq.cache_hits, st_par.cache_hits);
        assert_eq!(st_seq.cache_misses, st_par.cache_misses);
    }

    #[test]
    fn all_legal_mask_is_bit_identical_to_unmasked() {
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let m = conv_only(6);
        let mp_set = vec![1, 2, 4, 8];
        let mask = vec![true; 7];
        let mut e1 = CostEngine::new(&sim, &m);
        let (a, sta) = exhaustive_schedule_with(&mut e1, &mp_set).unwrap();
        let mut e2 = CostEngine::new(&sim, &m);
        let (b, stb) =
            exhaustive_schedule_masked(&mut e2, &mp_set, Some(&mask), None, 1).unwrap();
        assert_eq!(a, b);
        assert_eq!(sta.evaluations, stb.evaluations);
        assert_eq!(sta.blocks_considered, stb.blocks_considered);
        assert_eq!(sta.space_visited, stb.space_visited);
        assert_eq!(sta.cache_hits, stb.cache_hits);
        assert_eq!(sta.cache_misses, stb.cache_misses);
        assert_eq!(e1.stats(), e2.stats());
    }

    #[test]
    fn masked_enumeration_skips_illegal_partitions() {
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let m = conv_only(6);
        let mp_set = vec![1, 2, 4, 8];
        // Only boundaries 0, 3, 6 are legal: 4 legal partitions of the
        // 2^5 = 32 total.
        let mask = vec![true, false, false, true, false, false, true];
        let mut engine = CostEngine::new(&sim, &m);
        let (sched, st) =
            exhaustive_schedule_masked(&mut engine, &mp_set, Some(&mask), None, 1)
                .unwrap();
        sched.validate(6, sim.spec.num_cores).unwrap();
        for b in &sched.blocks {
            assert!(mask[b.start] && mask[b.end], "illegal boundary: {b:?}");
        }
        // Legal partitions: {}, {3} as interior cut sets -> 2 partitions;
        // visited space = 4^1 + 4^2.
        assert_eq!(st.space_visited, 4 + 16);
        // The masked optimum equals brute force over the legal partitions:
        // one block [0,6) or two blocks [0,3)+[3,6), best MP each.
        let free_block = |i: usize, j: usize| {
            mp_set
                .iter()
                .map(|&mp| engine.block_latency(i, j, mp))
                .fold(f64::INFINITY, f64::min)
        };
        let one = free_block(0, 6);
        let two = free_block(0, 3) + free_block(3, 6);
        let best = one.min(two);
        let got: f64 = sched
            .blocks
            .iter()
            .map(|b| engine.block_latency(b.start, b.end, b.mp))
            .sum();
        assert!((got - best).abs() < 1e-12, "got {got} vs best {best}");
    }

    #[test]
    fn threaded_masked_enumeration_matches_sequential() {
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let m = conv_only(7);
        let mp_set = vec![1, 2, 4, 8];
        let mask = vec![true, false, true, true, false, true, false, true];
        let mut seq = CostEngine::new(&sim, &m);
        let (a, sta) =
            exhaustive_schedule_masked(&mut seq, &mp_set, Some(&mask), None, 1).unwrap();
        let mut par = CostEngine::new(&sim, &m);
        let (b, stb) =
            exhaustive_schedule_masked(&mut par, &mp_set, Some(&mask), None, 4).unwrap();
        assert_eq!(a, b);
        assert_eq!(sta.evaluations, stb.evaluations);
        assert_eq!(sta.space_visited, stb.space_visited);
        assert_eq!(sta.cache_hits, stb.cache_hits);
        assert_eq!(sta.cache_misses, stb.cache_misses);
    }

    #[test]
    fn large_model_is_an_error_not_a_panic() {
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let m = zoo::resnet18();
        let mut engine = CostEngine::new(&sim, &m);
        let err = exhaustive_schedule_with(&mut engine, &[1]).unwrap_err();
        assert!(matches!(err, ExhaustiveError::ModelTooLarge { .. }), "{err}");
    }

    #[test]
    fn empty_mp_set_is_an_error_not_a_panic() {
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let m = conv_only(3);
        let mut engine = CostEngine::new(&sim, &m);
        let err = exhaustive_schedule_with(&mut engine, &[]).unwrap_err();
        assert_eq!(err, ExhaustiveError::EmptyMpSet);
    }

    #[test]
    fn budget_aborts_enumeration() {
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let m = conv_only(6);
        let mut engine = CostEngine::new(&sim, &m);
        let err = exhaustive_schedule_budgeted(&mut engine, &[1, 2], Some(5))
            .unwrap_err();
        assert!(matches!(err, ExhaustiveError::BudgetExhausted { budget: 5, .. }),
                "{err}");
    }

    #[test]
    #[should_panic(expected = "exponential")]
    #[allow(deprecated)]
    fn legacy_shim_guards_large_n() {
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let m = zoo::resnet18();
        exhaustive_schedule(&sim, &m, &[1]);
    }
}
