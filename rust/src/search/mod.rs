//! Search strategies over the joint (fusion, MP) space.
//!
//! - [`brute`]: the paper's *reduced* brute-force oracle (strategy 7):
//!   MP restricted to `{1,2,4,8,12,16,24,32}` and block sizes to multiples
//!   of four. Because block latencies are additive, the optimum over the
//!   reduced space is found exactly by shortest-path dynamic programming in
//!   `O(n²/16 · |MP|)` block evaluations — the same optimum an explicit
//!   enumeration would reach, at "acceptable search time".
//! - [`exhaustive`]: true enumeration for tiny models, used by the tests to
//!   certify the DP is exact.
//! - [`annealing`]: simulated annealing over the unreduced space, a
//!   beyond-paper stochastic comparator.
//!
//! All searches evaluate candidates through the shared
//! [`crate::cost::CostEngine`] (rust/docs/DESIGN.md §7); [`SearchStats`]
//! reports the evaluation counts, cache behaviour, and wall-clock time that
//! back the paper's Section V search-time comparison.
//!
//! Every backend here also implements the unified [`crate::tuner::Tuner`]
//! trait (rust/docs/DESIGN.md §8) — prefer a
//! [`crate::tuner::TuningRequest`] over the raw free functions; the
//! engine-less wrappers (`oracle_schedule`, `anneal`, `exhaustive_schedule`)
//! are deprecated shims kept for source compatibility.

pub mod brute;
pub mod exhaustive;
pub mod annealing;

pub use annealing::{anneal_budgeted, anneal_masked, anneal_with, AnnealConfig};
pub use brute::{full_mp_set, oracle_schedule_budgeted, oracle_schedule_constrained,
                oracle_schedule_full_with, oracle_schedule_masked,
                oracle_schedule_with, BlockRule, DpBudgetExceeded, SearchStats};
pub use exhaustive::{exhaustive_schedule_budgeted, exhaustive_schedule_masked,
                     exhaustive_schedule_with, ExhaustiveError,
                     MAX_EXHAUSTIVE_LAYERS};
#[allow(deprecated)]
pub use annealing::anneal;
#[allow(deprecated)]
pub use brute::{oracle_schedule, oracle_schedule_full};
#[allow(deprecated)]
pub use exhaustive::exhaustive_schedule;
