//! The memoized cost-evaluation engine (rust/docs/DESIGN.md §7.2, §12).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::facts::ModelFacts;
use crate::accel::{BlockPerf, PerfReport, Simulator};
use crate::graph::Model;
use crate::obs::{Domain, MetricsRegistry};
use crate::optimizer::schedule::Schedule;

/// Evaluation-throughput counters for a [`CostEngine`].
///
/// Two reductions are tracked, matching the two kinds of waste the seed
/// evaluation paths paid per query:
///
/// - **block level** — `hits`/`misses` on the `(start, end, mp)` cache. The
///   seed paths computed every request from scratch, so `queries()` is the
///   seed-equivalent raw block-latency computation count and `misses` is what
///   the engine actually computed.
/// - **layer level** — `seed_layer_evals` accumulates, per uncacheable-in-seed
///   request, the per-layer fact derivations the seed performed (one full
///   derivation per layer per block evaluation; one per layer per *batched*
///   MP-set call, which shared facts across the set). `layer_facts_built`
///   counts the derivations the engine performed: exactly one per model
///   layer, at construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CostStats {
    /// Block-latency queries served from the cache.
    pub hits: u64,
    /// Block-latency queries computed (fact-table walk + insert).
    pub misses: u64,
    /// Per-layer fact derivations the seed paths would have performed for
    /// the same query stream.
    pub seed_layer_evals: u64,
    /// Per-layer fact derivations actually performed (once per layer).
    pub layer_facts_built: u64,
}

impl CostStats {
    /// Total block-latency requests — what the unmemoized seed paths
    /// computed from scratch.
    pub fn queries(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fraction of queries served from the cache.
    pub fn hit_rate(&self) -> f64 {
        if self.queries() == 0 {
            0.0
        } else {
            self.hits as f64 / self.queries() as f64
        }
    }

    /// Seed-path block computations per engine computation (>= 1.0 means
    /// memoization is paying for itself).
    pub fn block_eval_reduction(&self) -> f64 {
        self.queries() as f64 / (self.misses.max(1)) as f64
    }

    /// Seed-path per-layer fact derivations per engine derivation.
    pub fn layer_eval_reduction(&self) -> f64 {
        self.seed_layer_evals as f64 / (self.layer_facts_built.max(1)) as f64
    }
}

/// Cached outcome of one `(start, end, mp, batch)` scalar-path evaluation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BlockCost {
    pub latency_ms: f64,
    /// Redundancy-weighted op count actually computed, GOPs (summed across
    /// the invocation's batch).
    pub computed_gops: f64,
}

/// How many lock shards the shared cache is split into. Shards are selected
/// by block start index, so a DP row `[i, j)` for fixed `i` stays on one
/// shard while concurrent workers sweeping different starts rarely contend.
const NUM_SHARDS: usize = 16;

/// One lock shard of the shared cache: the two seed-float-ordering maps
/// (see [`CostEngine`] docs) for every key whose `start % NUM_SHARDS`
/// selects this shard.
#[derive(Default)]
struct CacheShard {
    scalar: HashMap<(usize, usize, usize, usize), BlockCost>,
    sweep: HashMap<(usize, usize, usize, usize), f64>,
}

/// One set of evaluation counters, updatable through `&self` (the engine's
/// evaluation methods are shared-access so worker handles can run
/// concurrently). Plain counters, `Relaxed` ordering throughout.
#[derive(Default)]
struct StatCells {
    hits: AtomicU64,
    misses: AtomicU64,
    seed_layer_evals: AtomicU64,
    layer_facts_built: AtomicU64,
}

impl StatCells {
    fn snapshot(&self) -> CostStats {
        CostStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            seed_layer_evals: self.seed_layer_evals.load(Ordering::Relaxed),
            layer_facts_built: self.layer_facts_built.load(Ordering::Relaxed),
        }
    }

    fn reset_queries(&self) {
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
        self.seed_layer_evals.store(0, Ordering::Relaxed);
    }
}

/// State shared by every handle cloned off one engine: the sharded memo
/// cache plus the merged counters and the per-shard instrumentation.
struct SharedState {
    shards: Vec<Mutex<CacheShard>>,
    merged: StatCells,
    /// Lock acquisitions per shard. Deterministic: shard selection is by
    /// block start and every evaluation call locks its shard exactly once,
    /// so the counts depend only on the query stream, not on threading.
    shard_locks: Vec<AtomicU64>,
    /// Lock acquisitions per shard that found the lock already held
    /// (`try_lock` failed and the caller had to block). Machine- and
    /// timing-dependent — a wall-domain quantity, zero in any
    /// single-threaded run.
    shard_contended: Vec<AtomicU64>,
}

/// Memoized `(start, end, mp, batch) -> latency` evaluation over one
/// `(Simulator, Model)` pair.
///
/// Two caches are kept, one per float-operation ordering of the seed code
/// (see [`crate::cost`] module docs): the *scalar* cache mirrors
/// `Simulator::block_latency_ms` / `run_schedule`, the *sweep* cache
/// mirrors `Simulator::block_latency_ms_multi` (the oracle DP's MP-sweep
/// path). They are never mixed, so every consumer sees exactly the bits
/// the seed path produced. At `batch == 1` — the default — every result is
/// bit-identical to the pre-batch engine; see rust/docs/DESIGN.md §10.
///
/// **Concurrency.** The memo cache lives behind `NUM_SHARDS` mutex shards
/// (selected by block start) inside an `Arc`, and the immutable fact tables
/// behind their own `Arc`, so the evaluation methods take `&self` and an
/// engine can be shared across `std::thread::scope` workers — either
/// directly (`&CostEngine` is `Sync`) or through cheap [`Self::worker`]
/// handles that see the same cache. A shard's lock is held across the miss
/// computation, so every distinct key is computed exactly once no matter
/// how many workers race for it: cached values *and* the merged hit/miss
/// totals are identical to a sequential run issuing the same queries
/// (rust/docs/DESIGN.md §12). Each handle additionally keeps handle-local
/// counters ([`Self::local_stats`]) so concurrent searches can meter their
/// own query stream without seeing their neighbours'.
///
/// **Active batch.** The engine carries an *active batch size* (default 1)
/// that the implicit-batch methods ([`Self::block_cost`],
/// [`Self::schedule_cost`], [`Self::block_latency_sweep`], …) evaluate
/// at. Search backends are written against those methods, so setting the
/// active batch ([`Self::set_batch`]) re-targets a whole search — the DP,
/// the annealer's Metropolis walk, the strategy sweeps — at a batch size
/// without touching the search code; the cache key keeps every batch's
/// results separate. The batch is per *handle*: workers fork with the
/// parent's active batch and re-target independently.
pub struct CostEngine<'a> {
    sim: &'a Simulator,
    model: &'a Model,
    facts: Arc<ModelFacts>,
    shared: Arc<SharedState>,
    local: StatCells,
    /// Active batch size for the implicit-batch evaluation methods.
    batch: usize,
}

impl<'a> CostEngine<'a> {
    /// Build an engine: derives the model's fact tables once.
    pub fn new(sim: &'a Simulator, model: &'a Model) -> CostEngine<'a> {
        let facts = Arc::new(ModelFacts::new(model));
        let built = facts.len() as u64;
        let shared = Arc::new(SharedState {
            shards: (0..NUM_SHARDS).map(|_| Mutex::new(CacheShard::default())).collect(),
            merged: StatCells::default(),
            shard_locks: (0..NUM_SHARDS).map(|_| AtomicU64::new(0)).collect(),
            shard_contended: (0..NUM_SHARDS).map(|_| AtomicU64::new(0)).collect(),
        });
        shared.merged.layer_facts_built.store(built, Ordering::Relaxed);
        let local = StatCells::default();
        local.layer_facts_built.store(built, Ordering::Relaxed);
        CostEngine { sim, model, facts, shared, local, batch: 1 }
    }

    /// A second handle onto the same engine: shares the memo cache and the
    /// merged counters (cheap — two `Arc` clones), starts with fresh
    /// handle-local counters and the parent's active batch. Worker threads
    /// take one handle each; anything one worker computes is a cache hit
    /// for every other.
    pub fn worker(&self) -> CostEngine<'a> {
        let local = StatCells::default();
        local
            .layer_facts_built
            .store(self.local.layer_facts_built.load(Ordering::Relaxed), Ordering::Relaxed);
        CostEngine {
            sim: self.sim,
            model: self.model,
            facts: Arc::clone(&self.facts),
            shared: Arc::clone(&self.shared),
            local,
            batch: self.batch,
        }
    }

    /// The active batch size the implicit-batch methods evaluate at.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Re-target the implicit-batch evaluation methods at `batch` samples
    /// per invocation. Cached results are keyed by batch, so switching back
    /// and forth costs nothing beyond the first computation per key.
    pub fn set_batch(&mut self, batch: usize) {
        assert!(batch >= 1, "batch must be at least 1");
        self.batch = batch;
    }

    /// The simulator this engine evaluates against (returned at the
    /// engine's outer lifetime, so holding it does not borrow the engine).
    pub fn sim(&self) -> &'a Simulator {
        self.sim
    }

    /// The model this engine evaluates.
    pub fn model(&self) -> &'a Model {
        self.model
    }

    /// The derived fact tables.
    pub fn facts(&self) -> &ModelFacts {
        &self.facts
    }

    /// Merged counter snapshot: every query through every handle of this
    /// engine. For a lone handle this is exactly the handle's own stream.
    pub fn stats(&self) -> CostStats {
        self.shared.merged.snapshot()
    }

    /// Handle-local counter snapshot: only the queries issued through
    /// *this* handle. Equals [`Self::stats`] until the engine is shared;
    /// the search backends meter their budgets against this so concurrent
    /// neighbours do not inflate their deltas.
    pub fn local_stats(&self) -> CostStats {
        self.local.snapshot()
    }

    /// Zero the query counters, merged and handle-local (the
    /// `layer_facts_built` baseline is kept — the tables are not rebuilt).
    pub fn reset_stats(&mut self) {
        self.shared.merged.reset_queries();
        self.local.reset_queries();
    }

    /// Lock the shard owning block start `start`, metering the acquisition:
    /// every lock bumps the shard's (deterministic) acquisition count, and a
    /// failed `try_lock` — another handle holds the shard right now — bumps
    /// its (wall-domain) contention count before blocking.
    fn lock_shard(&self, start: usize) -> std::sync::MutexGuard<'_, CacheShard> {
        let idx = start % NUM_SHARDS;
        self.shared.shard_locks[idx].fetch_add(1, Ordering::Relaxed);
        match self.shared.shards[idx].try_lock() {
            Ok(guard) => guard,
            Err(_) => {
                self.shared.shard_contended[idx].fetch_add(1, Ordering::Relaxed);
                self.shared.shards[idx].lock().unwrap()
            }
        }
    }

    /// Per-shard lock-contention counts (wall-domain: depends on thread
    /// timing; all zeros in a single-threaded run).
    pub fn shard_contention(&self) -> Vec<u64> {
        self.shared
            .shard_contended
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect()
    }

    /// Export the merged counters plus per-shard cache statistics into the
    /// unified registry (rust/docs/DESIGN.md §14). Deterministic quantities
    /// — query totals, cached-entry counts, per-shard lock acquisitions —
    /// land in [`Domain::Sim`]; lock-contention counts depend on thread
    /// timing and land in [`Domain::Wall`].
    pub fn export_metrics(&self, reg: &mut MetricsRegistry) {
        let st = self.stats();
        reg.inc(Domain::Sim, "cost.cache.hits", st.hits);
        reg.inc(Domain::Sim, "cost.cache.misses", st.misses);
        reg.inc(Domain::Sim, "cost.seed_layer_evals", st.seed_layer_evals);
        reg.inc(Domain::Sim, "cost.layer_facts_built", st.layer_facts_built);
        reg.set_gauge(Domain::Sim, "cost.cache.hit_rate", st.hit_rate());
        let mut entries = 0u64;
        for (i, shard) in self.shared.shards.iter().enumerate() {
            let n = {
                let g = shard.lock().unwrap();
                (g.scalar.len() + g.sweep.len()) as u64
            };
            entries += n;
            reg.set_gauge(Domain::Sim, &format!("cost.shard{i:02}.entries"), n as f64);
            reg.inc(
                Domain::Sim,
                &format!("cost.shard{i:02}.locks"),
                self.shared.shard_locks[i].load(Ordering::Relaxed),
            );
            reg.inc(
                Domain::Wall,
                &format!("cost.shard{i:02}.lock_contended"),
                self.shared.shard_contended[i].load(Ordering::Relaxed),
            );
        }
        reg.inc(Domain::Sim, "cost.cache.entries", entries);
        reg.inc(
            Domain::Wall,
            "cost.lock_contended_total",
            self.shard_contention().iter().sum(),
        );
    }

    fn count_hit(&self) {
        self.shared.merged.hits.fetch_add(1, Ordering::Relaxed);
        self.local.hits.fetch_add(1, Ordering::Relaxed);
    }

    fn count_miss(&self) {
        self.shared.merged.misses.fetch_add(1, Ordering::Relaxed);
        self.local.misses.fetch_add(1, Ordering::Relaxed);
    }

    fn count_seed_layers(&self, n: u64) {
        self.shared.merged.seed_layer_evals.fetch_add(n, Ordering::Relaxed);
        self.local.seed_layer_evals.fetch_add(n, Ordering::Relaxed);
    }

    /// Scalar-path latency + computed-GOPs of block `[start, end)` at `mp`
    /// and an explicit batch size. At `batch == 1` this is bit-identical to
    /// `Simulator::{layer,block}_latency_ms`; larger batches evaluate the
    /// batch-aware model ([`ModelFacts::block_latency_ms_at`]).
    pub fn block_cost_at(&self, start: usize, end: usize, mp: usize,
                         batch: usize) -> BlockCost {
        self.count_seed_layers((end - start) as u64);
        let mut shard = self.lock_shard(start);
        if let Some(&c) = shard.scalar.get(&(start, end, mp, batch)) {
            self.count_hit();
            return c;
        }
        self.count_miss();
        // Computed under the shard lock: the fact-table walk is cheap, and
        // holding the lock guarantees each distinct key is computed exactly
        // once — merged miss counts stay deterministic under parallelism.
        let spec = &self.sim.spec;
        let gops = self.facts.block_gops(start, end);
        let cost = if batch == 1 && end - start == 1 {
            BlockCost {
                latency_ms: self.facts.layer_latency_ms(spec, start, mp),
                computed_gops: gops,
            }
        } else if batch == 1 {
            BlockCost {
                latency_ms: self.facts.block_latency_ms(spec, start, end, mp),
                computed_gops: self.facts.block_computed_gops(start, end, mp),
            }
        } else {
            // Per-sample computed work mirrors the batch-1 accounting: a
            // single-layer block is channel-partitioned (no band-halo
            // redundancy), matching the latency path it is paired with.
            let per_sample = if end - start == 1 {
                gops
            } else {
                self.facts.block_computed_gops(start, end, mp)
            };
            BlockCost {
                latency_ms: self.facts.block_latency_ms_at(spec, start, end, mp, batch),
                computed_gops: batch as f64 * per_sample,
            }
        };
        shard.scalar.insert((start, end, mp, batch), cost);
        cost
    }

    /// Scalar-path latency + computed-GOPs at the **active batch** (1 by
    /// default, so this is the pre-batch `block_cost`, bit for bit).
    pub fn block_cost(&self, start: usize, end: usize, mp: usize) -> BlockCost {
        self.block_cost_at(start, end, mp, self.batch)
    }

    /// Scalar-path latency of block `[start, end)` at `mp` and the active
    /// batch.
    pub fn block_latency(&self, start: usize, end: usize, mp: usize) -> f64 {
        self.block_cost(start, end, mp).latency_ms
    }

    /// MP-sweep-path latencies of block `[start, end)` over an MP set at
    /// the active batch — at batch 1 bit-identical to
    /// `Simulator::block_latency_ms_multi`. Each `(block, mp, batch)`
    /// triple is cached individually (the per-MP values are independent).
    pub fn block_latency_sweep(&self, start: usize, end: usize,
                                 mps: &[usize]) -> Vec<f64> {
        // The seed derived the block's facts once per MP-sweep call.
        self.count_seed_layers((end - start) as u64);
        let spec = &self.sim.spec;
        let batch = self.batch;
        let mut shard = self.lock_shard(start);
        mps.iter()
            .map(|&mp| {
                if let Some(&v) = shard.sweep.get(&(start, end, mp, batch)) {
                    self.count_hit();
                    return v;
                }
                self.count_miss();
                let v = self.facts.block_latency_ms_sweep_at(spec, start, end, mp, batch);
                shard.sweep.insert((start, end, mp, batch), v);
                v
            })
            .collect()
    }

    /// Total latency of a schedule at the active batch — the sequential
    /// per-block sum, at batch 1 bit-equal to
    /// `Simulator::run_schedule(..).total_ms` for any valid schedule
    /// (validation itself is skipped; use [`Self::run_schedule`] when the
    /// schedule is untrusted).
    pub fn schedule_cost(&self, schedule: &Schedule) -> f64 {
        let mut total = 0.0;
        for b in &schedule.blocks {
            total += self.block_latency(b.start, b.end, b.mp);
        }
        total
    }

    /// Total latency of one batched invocation of a schedule at an explicit
    /// batch size, independent of the active batch. The serving allocator
    /// uses this to derive a tuned schedule's batch table.
    pub fn schedule_cost_at(&self, schedule: &Schedule, batch: usize) -> f64 {
        let mut total = 0.0;
        for b in &schedule.blocks {
            total += self.block_cost_at(b.start, b.end, b.mp, batch).latency_ms;
        }
        total
    }

    /// Incremental re-evaluation after a local move that replaced the blocks
    /// at `changed` (indices into `schedule.blocks`); every other block must
    /// already be cached from evaluating the predecessor schedule, so the
    /// move costs O(|changed|) raw block computations. The returned total is
    /// still the full sequential sum — a float sum cannot be updated by
    /// subtraction without changing bits, and bit-equality with
    /// `run_schedule` is part of the engine's contract.
    pub fn delta_cost(&self, schedule: &Schedule, changed: &[usize]) -> f64 {
        debug_assert!(changed.iter().all(|&bi| bi < schedule.blocks.len()));
        let misses_before = self.local_stats().misses;
        let total = self.schedule_cost(schedule);
        debug_assert!(
            self.local_stats().misses - misses_before <= changed.len() as u64,
            "delta_cost: {} misses for {} changed blocks — predecessor \
             schedule was not evaluated through this engine",
            self.local_stats().misses - misses_before,
            changed.len()
        );
        total
    }

    /// Simulate a whole schedule — bit-identical (including the panic on an
    /// invalid schedule) to `Simulator::run_schedule`, served from the
    /// scalar cache. Always a per-inference (batch-1) report, regardless of
    /// the active batch: [`crate::accel::PerfReport`] is the paper's batch-1
    /// Fig. 10 surface.
    pub fn run_schedule(&self, schedule: &Schedule) -> PerfReport {
        schedule
            .validate(self.model.num_layers(), self.sim.spec.num_cores)
            .unwrap_or_else(|e| {
                panic!("invalid schedule for '{}': {e}", self.model.name)
            });
        let mut blocks = Vec::with_capacity(schedule.blocks.len());
        let mut total_ms = 0.0;
        let mut total_gops = 0.0;
        for b in &schedule.blocks {
            let cost = self.block_cost_at(b.start, b.end, b.mp, 1);
            let gops = self.facts.block_gops(b.start, b.end);
            total_ms += cost.latency_ms;
            total_gops += gops;
            blocks.push(BlockPerf {
                start: b.start,
                end: b.end,
                mp: b.mp,
                latency_ms: cost.latency_ms,
                gops,
                computed_gops: cost.computed_gops,
                fused: b.end - b.start > 1,
            });
        }
        PerfReport {
            model_name: self.model.name.clone(),
            total_ms,
            total_gops,
            blocks,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::schedule::{Block, Schedule};
    use crate::zoo;

    fn sim() -> Simulator {
        Simulator::new(crate::accel::Target::mlu100())
    }

    // `&CostEngine` must be shareable across scoped worker threads.
    fn _assert_engine_is_sync(e: &CostEngine<'_>) -> &dyn Sync {
        e
    }

    #[test]
    fn run_schedule_bit_identical_to_simulator() {
        let s = sim();
        for m in [zoo::resnet18(), zoo::alexnet(), zoo::mini_cnn()] {
            let engine = CostEngine::new(&s, &m);
            for sched in [
                Schedule::layerwise(m.num_layers(), 1),
                Schedule::uniform_blocks(m.num_layers(), 4, 8),
                Schedule::single_block(m.num_layers(), 32),
            ] {
                assert_eq!(engine.run_schedule(&sched), s.run_schedule(&m, &sched),
                           "{} {}", m.name, sched.summary());
            }
        }
    }

    #[test]
    fn batched_bit_identical_to_simulator_multi() {
        let s = sim();
        let m = zoo::vgg19();
        let engine = CostEngine::new(&s, &m);
        let mps = s.spec.reduced_mp_set();
        for (start, end) in [(0usize, 1usize), (0, 6), (3, 11)] {
            let fast = engine.block_latency_sweep(start, end, &mps);
            let reference = s.block_latency_ms_multi(&m.layers[start..end], &mps);
            assert_eq!(fast, reference, "[{start}..{end}]");
        }
    }

    #[test]
    fn cache_hits_do_not_recompute() {
        let s = sim();
        let m = zoo::alexnet();
        let engine = CostEngine::new(&s, &m);
        let sched = Schedule::uniform_blocks(m.num_layers(), 3, 4);
        let a = engine.schedule_cost(&sched);
        let st1 = engine.stats();
        assert_eq!(st1.hits, 0);
        assert_eq!(st1.misses as usize, sched.num_blocks());
        let b = engine.schedule_cost(&sched);
        let st2 = engine.stats();
        assert_eq!(a, b);
        assert_eq!(st2.misses, st1.misses, "second walk must be all hits");
        assert_eq!(st2.hits as usize, sched.num_blocks());
    }

    #[test]
    fn delta_cost_only_computes_changed_blocks() {
        let s = sim();
        let m = zoo::resnet18();
        let engine = CostEngine::new(&s, &m);
        let base = Schedule::layerwise(m.num_layers(), 1);
        let base_cost = engine.schedule_cost(&base);
        // Local move: bump block 3's MP.
        let mut moved = base.clone();
        moved.blocks[3] = Block { mp: 2, ..moved.blocks[3] };
        let before = engine.stats().misses;
        let moved_cost = engine.delta_cost(&moved, &[3]);
        assert_eq!(engine.stats().misses - before, 1);
        assert_ne!(moved_cost, base_cost);
        // And the incremental total matches a fresh full evaluation.
        let fresh = CostEngine::new(&s, &m);
        assert_eq!(moved_cost, fresh.schedule_cost(&moved));
    }

    #[test]
    fn stats_reductions_and_reset() {
        let s = sim();
        let m = zoo::mini_cnn();
        let mut engine = CostEngine::new(&s, &m);
        let sched = Schedule::layerwise(m.num_layers(), 2);
        for _ in 0..20 {
            engine.schedule_cost(&sched);
        }
        let st = engine.stats();
        assert_eq!(st.layer_facts_built as usize, m.num_layers());
        assert!(st.block_eval_reduction() >= 10.0, "{st:?}");
        assert!(st.layer_eval_reduction() >= 10.0, "{st:?}");
        assert!(st.hit_rate() > 0.9);
        engine.reset_stats();
        let st = engine.stats();
        assert_eq!(st.queries(), 0);
        assert_eq!(st.layer_facts_built as usize, m.num_layers());
    }

    #[test]
    fn active_batch_defaults_to_one_and_is_bit_identical() {
        let s = sim();
        let m = zoo::alexnet();
        let mut engine = CostEngine::new(&s, &m);
        assert_eq!(engine.batch(), 1);
        let sched = Schedule::uniform_blocks(m.num_layers(), 4, 8);
        let base = engine.schedule_cost(&sched);
        assert_eq!(base, s.run_schedule(&m, &sched).total_ms);
        // Explicit batch 1 hits the same cache entries.
        assert_eq!(engine.schedule_cost_at(&sched, 1), base);
        // set_batch(1) changes nothing.
        engine.set_batch(1);
        assert_eq!(engine.schedule_cost(&sched), base);
    }

    #[test]
    fn batch_keys_do_not_collide_across_batches() {
        let s = sim();
        let m = zoo::alexnet();
        let mut engine = CostEngine::new(&s, &m);
        let sched = Schedule::uniform_blocks(m.num_layers(), 4, 8);
        let b1 = engine.schedule_cost(&sched);
        engine.set_batch(4);
        let b4 = engine.schedule_cost(&sched);
        assert!(b4 > b1 && b4 < 4.0 * b1, "{b4} vs {b1}");
        // Returning to batch 1 serves the original bits from cache.
        engine.set_batch(1);
        let misses = engine.stats().misses;
        assert_eq!(engine.schedule_cost(&sched), b1);
        assert_eq!(engine.stats().misses, misses, "batch-1 walk must be all hits");
        // And the explicit-batch accessor agrees with the active-batch one.
        assert_eq!(engine.schedule_cost_at(&sched, 4), b4);
    }

    #[test]
    fn batched_block_cost_matches_simulator_batch_path() {
        let s = sim();
        let m = zoo::vgg19();
        let mut engine = CostEngine::new(&s, &m);
        for (start, end, mp, b) in [(0usize, 6usize, 8usize, 4usize), (3, 11, 4, 8)] {
            let got = engine.block_cost_at(start, end, mp, b);
            let want = s.block_latency_ms_batch(&m.layers[start..end], mp, b);
            assert_eq!(got.latency_ms, want, "[{start}..{end}] mp={mp} b={b}");
            assert!(got.computed_gops > 0.0);
        }
        // The MP-sweep path agrees with the scalar path at batch > 1 (one
        // shared implementation; only batch 1 keeps two float orderings).
        engine.set_batch(4);
        let sweep = engine.block_latency_sweep(0, 6, &[8]);
        assert_eq!(sweep[0], engine.block_cost_at(0, 6, 8, 4).latency_ms);
    }

    #[test]
    #[should_panic(expected = "batch must be at least 1")]
    fn zero_batch_is_rejected() {
        let s = sim();
        let m = zoo::mini_cnn();
        let mut engine = CostEngine::new(&s, &m);
        engine.set_batch(0);
    }

    #[test]
    #[should_panic(expected = "invalid schedule")]
    fn run_schedule_rejects_gap_like_simulator() {
        let s = sim();
        let m = zoo::mini_cnn();
        let engine = CostEngine::new(&s, &m);
        let mut sched = Schedule::uniform_blocks(m.num_layers(), 4, 2);
        sched.blocks.pop();
        engine.run_schedule(&sched);
    }

    #[test]
    fn worker_handles_share_cache_and_merge_stats() {
        let s = sim();
        let m = zoo::alexnet();
        let engine = CostEngine::new(&s, &m);
        let sched = Schedule::uniform_blocks(m.num_layers(), 3, 4);
        let a = engine.schedule_cost(&sched);
        let w = engine.worker();
        // Everything the parent computed is a hit for the worker...
        let b = w.schedule_cost(&sched);
        assert_eq!(a, b);
        let lw = w.local_stats();
        assert_eq!(lw.misses, 0, "worker walk must be all hits");
        assert_eq!(lw.hits as usize, sched.num_blocks());
        // ...and the merged view sees both handles' query streams.
        let merged = engine.stats();
        assert_eq!(merged.misses as usize, sched.num_blocks());
        assert_eq!(merged.hits as usize, sched.num_blocks());
        assert_eq!(engine.local_stats().hits, 0);
        assert_eq!(w.stats(), merged, "merged view is shared across handles");
    }

    #[test]
    fn concurrent_workers_match_sequential_bits_and_counts() {
        let s = sim();
        let m = zoo::resnet18();
        let mps = s.spec.reduced_mp_set();
        let n = m.num_layers();
        // Sequential reference: sweep every block on a fresh engine.
        let reference = CostEngine::new(&s, &m);
        let mut want = Vec::new();
        for i in 0..n {
            for j in (i + 1)..=n {
                want.push(reference.block_latency_sweep(i, j, &mps));
            }
        }
        // Four scoped workers racing over the same blocks, shared cache.
        let engine = CostEngine::new(&s, &m);
        std::thread::scope(|scope| {
            for t in 0..4 {
                let w = engine.worker();
                scope.spawn(move || {
                    for i in 0..n {
                        if i % 4 != t {
                            continue;
                        }
                        for j in (i + 1)..=n {
                            w.block_latency_sweep(i, j, &mps);
                        }
                    }
                });
            }
        });
        let mut got = Vec::new();
        for i in 0..n {
            for j in (i + 1)..=n {
                got.push(engine.block_latency_sweep(i, j, &mps));
            }
        }
        assert_eq!(got, want, "shared-cache values must match sequential bits");
        // Each distinct key was computed exactly once (the shard lock is
        // held across the miss computation), so merged misses are
        // deterministic and equal to the sequential engine's.
        assert_eq!(engine.stats().misses, reference.stats().misses);
        // Per-shard lock acquisitions are query-stream-determined too: both
        // engines saw the same calls, in any order.
        assert_eq!(
            engine.shared.shard_locks.iter().map(|c| c.load(Ordering::Relaxed)).collect::<Vec<_>>(),
            reference.shared.shard_locks.iter().map(|c| c.load(Ordering::Relaxed)).collect::<Vec<_>>(),
        );
    }

    #[test]
    fn export_metrics_separates_sim_and_wall_domains() {
        let s = sim();
        let m = zoo::alexnet();
        let engine = CostEngine::new(&s, &m);
        let sched = Schedule::uniform_blocks(m.num_layers(), 3, 4);
        engine.schedule_cost(&sched);
        engine.schedule_cost(&sched);
        let mut reg = MetricsRegistry::new();
        engine.export_metrics(&mut reg);
        let st = engine.stats();
        assert_eq!(reg.counter("cost.cache.hits"), Some(st.hits));
        assert_eq!(reg.counter("cost.cache.misses"), Some(st.misses));
        assert_eq!(reg.counter("cost.cache.entries"), Some(st.misses),
                   "every miss inserts exactly one entry");
        assert_eq!(reg.gauge("cost.cache.hit_rate"), Some(st.hit_rate()));
        // Single-threaded: lock acquisitions happened, contention did not.
        assert_eq!(reg.counter("cost.lock_contended_total"), Some(0));
        assert!(engine.shard_contention().iter().all(|&c| c == 0));
        let locks: u64 = (0..NUM_SHARDS)
            .map(|i| reg.counter(&format!("cost.shard{i:02}.locks")).unwrap())
            .sum();
        assert_eq!(locks, st.queries(), "scalar path: one lock per query");
        // Domain split: shard entry/lock metrics are sim, contention wall.
        let snap = reg.snapshot();
        let sim_section = snap.get("deterministic").unwrap();
        let wall_section = snap.get("wall").unwrap();
        assert!(sim_section.get("cost.shard00.locks").is_some());
        assert!(sim_section.get("cost.shard00.lock_contended").is_none());
        assert!(wall_section.get("cost.shard00.lock_contended").is_some());
        assert!(wall_section.get("cost.shard00.locks").is_none());
    }
}
