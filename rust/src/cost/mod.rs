//! The shared cost-evaluation engine (rust/docs/DESIGN.md §7).
//!
//! Every consumer of simulated latency — the Table III strategy sweeps, the
//! brute-force oracle's DP, the annealer's Metropolis loop, the coordinator's
//! predicted-vs-measured reporting, and the paper-figure benches — used to
//! re-derive block costs from raw [`crate::graph::Layer`] structs on every
//! query. This module centralizes that work:
//!
//! - [`ModelFacts`]: the MP-independent per-layer quantities the latency
//!   model consumes (op counts, output geometry, weight/row/boundary bytes,
//!   halo radii, re-tile flags), derived **once per model** into tables
//!   indexable by layer range, plus a prefix-sum table for re-tile barrier
//!   counts. This is the single home of the math that was previously
//!   hand-inlined twice (in `Simulator::block_latency_ms` via the
//!   `fusion`/`memory` modules and again inside `block_latency_ms_multi`).
//! - [`CostEngine`]: a memoized `(start, end, mp, batch) → latency` cache
//!   over a `(Simulator, Model)` pair with hit/miss statistics,
//!   whole-schedule evaluation, incremental (`delta_cost`) evaluation for
//!   local-move searches, and an *active batch size* that re-targets every
//!   implicit-batch query (so a search written against the engine
//!   co-optimizes at any batch — rust/docs/DESIGN.md §10).
//!
//! **Exactness contract:** at batch 1 — the default — every number produced
//! here is bit-identical to the corresponding `Simulator` method
//! (`layer_latency_ms`, `block_latency_ms`, `run_schedule`). The float
//! operations are kept in the exact order of the reference paths — which is
//! also why aggregate float sums iterate over the fact tables instead of
//! using prefix-sum differences (float prefix differences are not bit-equal
//! to sequential sums; integer prefixes like the barrier counts are). The
//! equality is pinned by property tests in `rust/tests/cost_engine.rs`.
//! Batches above 1 evaluate the batch-aware model
//! ([`ModelFacts::block_latency_ms_at`]): weights move once per invocation,
//! compute and activation movement are charged per sample.

pub mod engine;
pub mod facts;

pub use engine::{BlockCost, CostEngine, CostStats};
pub use facts::{LayerFacts, ModelFacts};
