//! MP-independent per-layer fact tables (rust/docs/DESIGN.md §7.1).
//!
//! Everything the latency model needs from a [`Layer`] that does not depend
//! on the MP setting or on which block the layer lands in: op counts, output
//! geometry, boundary/weight bytes, halo radii, re-tile flags. Deriving these
//! quantities is the expensive, branch-heavy part of a block evaluation (shape
//! matches, Eq. 1/2 arithmetic); the tables below derive each layer **once
//! per model** and make every later query a table walk.
//!
//! The only block-dependent quantity, the downstream halo of layer `i` in a
//! block ending at `end` (see [`crate::accel::fusion::downstream_halos`]), is
//! recovered in O(1) from two auxiliary tables: an integer prefix sum of halo
//! radii and a next-re-tile index. Integer prefixes are exact, so the
//! recovered halos are identical to the backward walk's — this is load-bearing
//! for the bit-exactness contract in [`crate::cost`].

use crate::accel::spec::AcceleratorSpec;
use crate::accel::{efficiency, memory, partition};
use crate::graph::layer::BYTES_PER_ELEM;
use crate::graph::{Layer, LayerKind, Model};

/// The MP-independent facts of one layer (all derived in
/// [`ModelFacts::from_layers`]; field-by-field provenance in the docs there).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerFacts {
    /// Eq. 1/2 operation count, GOPs.
    pub gops: f64,
    /// Output-channel count, clamped to >= 1 (the partitioning axis).
    pub channels: usize,
    /// Output rows `h`, clamped to >= 1, as f64 (band-partition denominator).
    pub rows: f64,
    /// Output width as f64.
    pub out_w: f64,
    /// Output channels as f64.
    pub out_c: f64,
    /// Input activation bytes.
    pub in_bytes: f64,
    /// Output activation bytes.
    pub out_bytes: f64,
    /// Parameter bytes.
    pub weight_bytes: f64,
    /// Off-chip bytes of the layer run unfused (input + output + weights).
    pub unfused_bytes: f64,
    /// Receptive-field radius added to a fusion block's halo.
    pub halo_radius: usize,
    /// Spatial-reduction layer (stride > 1 conv/pool): re-tiles the band
    /// partition, resetting the halo pyramid and costing a barrier.
    pub retile: bool,
}

/// Per-model fact tables + prefix structures for O(1) range queries.
#[derive(Debug, Clone)]
pub struct ModelFacts {
    facts: Vec<LayerFacts>,
    /// `radius_prefix[i]` = sum of `halo_radius` over layers `0..i`.
    radius_prefix: Vec<usize>,
    /// `retile_prefix[i]` = number of re-tile layers among `0..i`.
    retile_prefix: Vec<usize>,
    /// `next_retile[i]` = smallest `j >= i` with `facts[j].retile`, else `n`.
    next_retile: Vec<usize>,
}

impl ModelFacts {
    /// Derive the fact tables for a slice of layers (one pass, O(n)).
    pub fn from_layers(layers: &[Layer]) -> ModelFacts {
        let n = layers.len();
        let facts: Vec<LayerFacts> = layers
            .iter()
            .map(|l| {
                let out = l.output_shape();
                let in_bytes = l.input_shape().bytes();
                let out_bytes = out.bytes();
                let weight_bytes = l.weight_bytes();
                LayerFacts {
                    gops: l.op_gops(),
                    channels: l.channels().max(1),
                    rows: out.h.max(1) as f64,
                    out_w: out.w as f64,
                    out_c: out.c as f64,
                    in_bytes,
                    out_bytes,
                    weight_bytes,
                    unfused_bytes: in_bytes + out_bytes + weight_bytes,
                    halo_radius: l.halo_radius(),
                    retile: match &l.kind {
                        LayerKind::Conv(c) => c.stride > 1,
                        LayerKind::Pool { stride, .. } => *stride > 1,
                        _ => false,
                    },
                }
            })
            .collect();
        let mut radius_prefix = vec![0usize; n + 1];
        let mut retile_prefix = vec![0usize; n + 1];
        for (i, f) in facts.iter().enumerate() {
            radius_prefix[i + 1] = radius_prefix[i] + f.halo_radius;
            retile_prefix[i + 1] = retile_prefix[i] + usize::from(f.retile);
        }
        let mut next_retile = vec![n; n + 1];
        for i in (0..n).rev() {
            next_retile[i] = if facts[i].retile { i } else { next_retile[i + 1] };
        }
        ModelFacts { facts, radius_prefix, retile_prefix, next_retile }
    }

    /// Derive the fact tables for a whole model.
    pub fn new(model: &Model) -> ModelFacts {
        ModelFacts::from_layers(&model.layers)
    }

    /// Number of layers covered.
    pub fn len(&self) -> usize {
        self.facts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.facts.is_empty()
    }

    /// Facts of one layer.
    pub fn layer(&self, i: usize) -> &LayerFacts {
        &self.facts[i]
    }

    /// Downstream halo (output rows) of layer `i` inside a block ending at
    /// `end` — identical to `fusion::downstream_halos(&layers[start..end])[i -
    /// start]` for any `start <= i`. The halo accumulates the radii of layers
    /// `i+1..` up to and including the first re-tile layer, where the pyramid
    /// resets.
    pub fn halo(&self, i: usize, end: usize) -> usize {
        debug_assert!(i < end && end <= self.len());
        let j0 = self.next_retile[i + 1];
        let upper = if j0 < end { j0 } else { end - 1 };
        self.radius_prefix[upper + 1] - self.radius_prefix[i + 1]
    }

    /// Number of re-tile barrier layers in `[start, end)`.
    pub fn barriers(&self, start: usize, end: usize) -> usize {
        self.retile_prefix[end] - self.retile_prefix[start]
    }

    /// Useful op count (GOPs) of `[start, end)` — sequential sum, matching
    /// `layers.iter().map(Layer::op_gops).sum()` bit for bit.
    pub fn block_gops(&self, start: usize, end: usize) -> f64 {
        self.facts[start..end].iter().map(|f| f.gops).sum()
    }

    /// Redundancy-weighted op count of block `[start, end)` at MP = `mp` —
    /// bit-identical to [`crate::accel::fusion::block_redundant_gops`].
    pub fn block_computed_gops(&self, start: usize, end: usize, mp: usize) -> f64 {
        let mut total = 0.0;
        for i in start..end {
            let f = &self.facts[i];
            total += f.gops * self.redundancy(i, end, mp);
        }
        total
    }

    /// `fusion::layer_redundancy` on the fact tables (same float ops, same
    /// order).
    fn redundancy(&self, i: usize, end: usize, mp: usize) -> f64 {
        if mp == 1 {
            return 1.0;
        }
        let f = &self.facts[i];
        let halo = self.halo(i, end) as f64;
        let band = (f.rows / mp as f64).ceil();
        let per_core = (band + 2.0 * halo).min(f.rows);
        (per_core * mp as f64) / f.rows
    }

    fn overheads_ms(&self, s: &AcceleratorSpec, mp: usize) -> f64 {
        (s.launch_overhead_us + s.sync_us_per_core * mp as f64) / 1e3
    }

    /// Per-sample spilled bytes of block `[start, end)` at MP = `mp` — the
    /// scalar-path replay of `memory::fused_block_traffic`'s working-set
    /// walk, shared by the batch-1 and batch-aware scalar paths (samples
    /// stream through the block one at a time, so which boundaries spill
    /// is batch-independent). The MP-sweep path keeps its own interleaved
    /// loop: its float-operation order is part of the §7 bit-exactness
    /// contract.
    fn spill_bytes(&self, s: &AcceleratorSpec, start: usize, end: usize,
                   mp: usize) -> f64 {
        let mut spill = 0.0;
        for l in start..end - 1 {
            let f = &self.facts[l];
            let band_rows = (f.rows / mp as f64).ceil() + 2.0 * self.halo(l, end) as f64;
            let band_rows = band_rows.min(f.rows);
            let band_bytes = band_rows * f.out_w * f.out_c * BYTES_PER_ELEM;
            let next_weights = self.facts[l + 1].weight_bytes / mp as f64;
            let working = 2.0 * band_bytes + next_weights;
            if working > s.core_buffer_bytes {
                spill += 2.0 * f.out_bytes;
            }
        }
        spill
    }

    /// Latency of layer `i` run unfused at MP = `mp` — bit-identical to
    /// [`crate::accel::Simulator::layer_latency_ms`].
    pub fn layer_latency_ms(&self, s: &AcceleratorSpec, i: usize, mp: usize) -> f64 {
        let f = &self.facts[i];
        let g_core = partition::per_core_gops(s, f.gops, f.channels, mp);
        let t_compute = efficiency::core_compute_ms(s, g_core);
        let t_mem = memory::transfer_ms(s, f.unfused_bytes);
        t_compute.max(t_mem) + self.overheads_ms(s, mp)
    }

    /// Latency of fused block `[start, end)` at MP = `mp` — bit-identical to
    /// [`crate::accel::Simulator::block_latency_ms`] (the reference scalar
    /// path; every float operation is replayed in the same order).
    pub fn block_latency_ms(&self, s: &AcceleratorSpec, start: usize, end: usize,
                            mp: usize) -> f64 {
        assert!(start < end && end <= self.len(), "empty or out-of-range block");
        if end - start == 1 {
            return self.layer_latency_ms(s, start, mp);
        }
        let computed = self.block_computed_gops(start, end, mp);
        let g_core = computed / mp as f64;
        let t_compute = efficiency::core_compute_ms(s, g_core)
            + s.fused_layer_us * (end - start) as f64 / 1e3;
        // memory::fused_block_traffic replayed on the tables.
        let boundary = self.facts[start].in_bytes + self.facts[end - 1].out_bytes;
        let weight: f64 = self.facts[start..end].iter().map(|f| f.weight_bytes).sum();
        let spill = self.spill_bytes(s, start, end, mp);
        let t_mem = memory::transfer_ms(s, boundary + weight + spill);
        let barriers = self.barriers(start, end) as f64;
        let t_retile = s.sync_us_per_core * mp as f64 * barriers / 1e3;
        t_compute.max(t_mem) + t_retile + self.overheads_ms(s, mp)
    }

    /// Latency of layer `i` run unfused at MP = `mp` serving a batched
    /// invocation of `batch` samples. `batch == 1` **is**
    /// [`Self::layer_latency_ms`], bit for bit; larger batches charge
    /// compute and activation movement per sample while the weight fetch,
    /// pipeline fill, and launch/sync overheads are paid once per
    /// invocation (rust/docs/DESIGN.md §10).
    pub fn layer_latency_ms_at(&self, s: &AcceleratorSpec, i: usize, mp: usize,
                               batch: usize) -> f64 {
        assert!(batch >= 1, "batch must be at least 1");
        if batch == 1 {
            return self.layer_latency_ms(s, i, mp);
        }
        let bf = batch as f64;
        let f = &self.facts[i];
        let g_core = bf * partition::per_core_gops(s, f.gops, f.channels, mp);
        let t_compute = efficiency::core_compute_ms(s, g_core);
        let t_mem = memory::transfer_ms(
            s, bf * (f.in_bytes + f.out_bytes) + f.weight_bytes);
        t_compute.max(t_mem) + self.overheads_ms(s, mp)
    }

    /// Latency of fused block `[start, end)` at MP = `mp` serving a batched
    /// invocation of `batch` samples. `batch == 1` **is**
    /// [`Self::block_latency_ms`], bit for bit. For larger batches the
    /// block charges, per the batch-aware model (rust/docs/DESIGN.md §10):
    ///
    /// - compute (with the per-sample halo redundancy of the batch-1 model)
    ///   `batch` times, against a single pipeline fill per invocation;
    /// - boundary activations and spilled intermediates `batch` times —
    ///   samples stream through the block one at a time, so the per-core
    ///   working set (and therefore which boundaries spill) is the batch-1
    ///   computation — while **weights move once per invocation**;
    /// - re-tile barriers per sample (the band repartition redistributes
    ///   every sample's feature maps) and launch/sync overheads once.
    pub fn block_latency_ms_at(&self, s: &AcceleratorSpec, start: usize,
                               end: usize, mp: usize, batch: usize) -> f64 {
        assert!(batch >= 1, "batch must be at least 1");
        if batch == 1 {
            return self.block_latency_ms(s, start, end, mp);
        }
        assert!(start < end && end <= self.len(), "empty or out-of-range block");
        if end - start == 1 {
            return self.layer_latency_ms_at(s, start, mp, batch);
        }
        let bf = batch as f64;
        let computed = self.block_computed_gops(start, end, mp);
        let g_core = bf * computed / mp as f64;
        let t_compute = efficiency::core_compute_ms(s, g_core)
            + s.fused_layer_us * (end - start) as f64 / 1e3;
        // Same traffic decomposition as memory::fused_block_traffic_batch:
        // boundary and spill per sample, weights once.
        let boundary = self.facts[start].in_bytes + self.facts[end - 1].out_bytes;
        let weight: f64 = self.facts[start..end].iter().map(|f| f.weight_bytes).sum();
        let spill = self.spill_bytes(s, start, end, mp);
        let t_mem = memory::transfer_ms(s, bf * boundary + weight + bf * spill);
        let barriers = self.barriers(start, end) as f64;
        let t_retile = s.sync_us_per_core * mp as f64 * barriers * bf / 1e3;
        t_compute.max(t_mem) + t_retile + self.overheads_ms(s, mp)
    }

    /// One MP of the MP-sweep evaluation — bit-identical to the
    /// corresponding element of
    /// [`crate::accel::Simulator::block_latency_ms_multi`] (whose body now
    /// delegates here). The sweep path multiplies the spill working-set
    /// terms in a different association order than the scalar path, so the
    /// two agree only to ~1e-12, exactly as in the seed code; both orders
    /// are preserved so each consumer stays bit-stable.
    pub fn block_latency_ms_sweep(&self, s: &AcceleratorSpec, start: usize,
                                  end: usize, mp: usize) -> f64 {
        assert!(start < end && end <= self.len(), "empty or out-of-range block");
        if end - start == 1 {
            return self.layer_latency_ms(s, start, mp);
        }
        let mpf = mp as f64;
        let mut computed = 0.0;
        let mut spill = 0.0;
        for i in start..end {
            let f = &self.facts[i];
            let halo = self.halo(i, end) as f64;
            let rho = if mp == 1 {
                1.0
            } else {
                let band = (f.rows / mpf).ceil();
                let per_core = (band + 2.0 * halo).min(f.rows);
                per_core * mpf / f.rows
            };
            computed += f.gops * rho;
            if i + 1 < end {
                let band_rows = ((f.rows / mpf).ceil() + 2.0 * halo).min(f.rows);
                let out_row_bytes = f.out_w * f.out_c * BYTES_PER_ELEM;
                let working = 2.0 * band_rows * out_row_bytes
                    + self.facts[i + 1].weight_bytes / mpf;
                if working > s.core_buffer_bytes {
                    spill += 2.0 * f.out_bytes;
                }
            }
        }
        let t_issue = s.fused_layer_us * (end - start) as f64 / 1e3;
        let t_compute = efficiency::core_compute_ms(s, computed / mpf) + t_issue;
        let boundary = self.facts[start].in_bytes + self.facts[end - 1].out_bytes;
        let weight_bytes: f64 = self.facts[start..end].iter().map(|f| f.weight_bytes).sum();
        let t_mem = memory::transfer_ms(s, boundary + weight_bytes + spill);
        let barriers = self.barriers(start, end) as f64;
        let t_retile = s.sync_us_per_core * mpf * barriers / 1e3;
        t_compute.max(t_mem) + t_retile + self.overheads_ms(s, mp)
    }

    /// The MP-sweep evaluation path at a batch size. `batch == 1` **is**
    /// [`Self::block_latency_ms_sweep`], bit for bit — the seed's
    /// distinct float-operation ordering exists only there. Larger batches
    /// have no seed reference, so both evaluation paths share one
    /// implementation ([`Self::block_latency_ms_at`]) and the DP's sweep
    /// agrees with the scalar path exactly.
    pub fn block_latency_ms_sweep_at(&self, s: &AcceleratorSpec, start: usize,
                                     end: usize, mp: usize, batch: usize) -> f64 {
        assert!(batch >= 1, "batch must be at least 1");
        if batch == 1 {
            self.block_latency_ms_sweep(s, start, end, mp)
        } else {
            self.block_latency_ms_at(s, start, end, mp, batch)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::{fusion, Simulator};
    use crate::graph::layer::{ConvSpec, TensorShape};
    use crate::zoo;

    fn sim() -> Simulator {
        Simulator::new(crate::accel::Target::mlu100())
    }

    #[test]
    fn halos_match_backward_walk_on_all_ranges() {
        for m in [zoo::resnet18(), zoo::alexnet(), zoo::mobilenet_v2()] {
            let facts = ModelFacts::new(&m);
            let n = m.num_layers();
            for start in (0..n).step_by(3) {
                for end in [start + 1, (start + 5).min(n), n] {
                    if end <= start {
                        continue;
                    }
                    let walk = fusion::downstream_halos(&m.layers[start..end]);
                    for i in start..end {
                        assert_eq!(facts.halo(i, end), walk[i - start],
                                   "{} [{start}..{end}] layer {i}", m.name);
                    }
                }
            }
        }
    }

    #[test]
    fn barriers_match_filter_count() {
        let m = zoo::resnet50();
        let facts = ModelFacts::new(&m);
        let count = |s: usize, e: usize| {
            m.layers[s..e]
                .iter()
                .filter(|l| match &l.kind {
                    crate::graph::LayerKind::Conv(c) => c.stride > 1,
                    crate::graph::LayerKind::Pool { stride, .. } => *stride > 1,
                    _ => false,
                })
                .count()
        };
        let n = m.num_layers();
        for (s, e) in [(0, n), (0, 5), (3, 17), (n - 4, n)] {
            assert_eq!(facts.barriers(s, e), count(s, e));
        }
    }

    #[test]
    fn scalar_block_latency_bit_identical() {
        let s = sim();
        for m in [zoo::resnet18(), zoo::vgg19(), zoo::mini_cnn()] {
            let facts = ModelFacts::new(&m);
            let n = m.num_layers();
            for (start, end) in [(0usize, 1usize), (0, 3), (2, 9), (0, n)] {
                let end = end.min(n);
                if start >= end {
                    continue;
                }
                for mp in [1usize, 2, 7, 12, 32] {
                    let reference = s.block_latency_ms(&m.layers[start..end], mp);
                    let fast = facts.block_latency_ms(&s.spec, start, end, mp);
                    assert_eq!(fast, reference,
                               "{} [{start}..{end}] mp={mp}", m.name);
                }
            }
        }
    }

    #[test]
    fn layer_latency_bit_identical() {
        let s = sim();
        let m = zoo::alexnet();
        let facts = ModelFacts::new(&m);
        for i in 0..m.num_layers() {
            for mp in [1usize, 3, 8, 32] {
                assert_eq!(facts.layer_latency_ms(&s.spec, i, mp),
                           s.layer_latency_ms(&m.layers[i], mp));
            }
        }
    }

    #[test]
    fn computed_gops_bit_identical() {
        let m = zoo::resnet18();
        let facts = ModelFacts::new(&m);
        for (start, end) in [(0usize, 4usize), (2, 10), (0, m.num_layers())] {
            for mp in [1usize, 4, 32] {
                let (reference, _) =
                    fusion::block_redundant_gops(&m.layers[start..end], mp);
                assert_eq!(facts.block_computed_gops(start, end, mp), reference);
            }
        }
    }

    #[test]
    fn batch_one_is_the_scalar_path_bit_for_bit() {
        let s = sim();
        for m in [zoo::resnet18(), zoo::vgg19()] {
            let facts = ModelFacts::new(&m);
            let n = m.num_layers();
            for (start, end) in [(0usize, 1usize), (0, 4), (2, 9), (0, n)] {
                let end = end.min(n);
                for mp in [1usize, 4, 32] {
                    assert_eq!(
                        facts.block_latency_ms_at(&s.spec, start, end, mp, 1),
                        facts.block_latency_ms(&s.spec, start, end, mp),
                        "{} [{start}..{end}] mp={mp}", m.name);
                    assert_eq!(
                        facts.block_latency_ms_sweep_at(&s.spec, start, end, mp, 1),
                        facts.block_latency_ms_sweep(&s.spec, start, end, mp),
                        "{} [{start}..{end}] mp={mp}", m.name);
                }
            }
            for i in [0usize, n / 2] {
                assert_eq!(facts.layer_latency_ms_at(&s.spec, i, 8, 1),
                           facts.layer_latency_ms(&s.spec, i, 8));
                // At batch > 1 the fact-table walk replays the Simulator's
                // reference path (which charges via unfused_layer_bytes_batch)
                // bit for bit.
                for b in [2usize, 8] {
                    assert_eq!(facts.layer_latency_ms_at(&s.spec, i, 8, b),
                               s.layer_latency_ms_batch(&m.layers[i], 8, b),
                               "{} layer {i} batch {b}", m.name);
                }
            }
        }
    }

    #[test]
    fn batching_amortizes_strictly_sublinearly() {
        // t(b) < b * t(1): fill, weights, and launch/sync amortize; and the
        // per-sample latency t(b)/b strictly decreases in b for weighted
        // blocks.
        let s = sim();
        let m = zoo::vgg19();
        let facts = ModelFacts::new(&m);
        let n = m.num_layers();
        for (start, end) in [(0usize, 1usize), (0, 6), (3, 11), (0, n)] {
            for mp in [1usize, 8, 32] {
                let t1 = facts.block_latency_ms_at(&s.spec, start, end, mp, 1);
                let mut last_per_sample = f64::INFINITY;
                for b in [1usize, 2, 4, 8, 16] {
                    let tb = facts.block_latency_ms_at(&s.spec, start, end, mp, b);
                    assert!(tb >= t1, "[{start}..{end}] mp={mp} b={b}");
                    assert!(tb < b as f64 * t1 + 1e-15,
                            "[{start}..{end}] mp={mp} b={b}: {tb} vs {}",
                            b as f64 * t1);
                    let per_sample = tb / b as f64;
                    assert!(per_sample < last_per_sample + 1e-15,
                            "[{start}..{end}] mp={mp} b={b}: per-sample not \
                             decreasing ({per_sample} vs {last_per_sample})");
                    last_per_sample = per_sample;
                }
            }
        }
    }

    #[test]
    fn batched_traffic_matches_memory_decomposition() {
        // The facts walk charges exactly what fused_block_traffic_batch
        // decomposes: boundary and spill per sample, weights once.
        let s = sim();
        let m = zoo::vgg19();
        let facts = ModelFacts::new(&m);
        for (start, end, mp, b) in [(0usize, 6usize, 4usize, 8usize), (3, 11, 8, 4)] {
            let traffic = crate::accel::memory::fused_block_traffic_batch(
                &s.spec, &m.layers[start..end], mp, b);
            let t_mem = crate::accel::memory::transfer_ms(&s.spec, traffic.total());
            // Reconstruct the memory term the scalar batch walk computed.
            let computed = facts.block_computed_gops(start, end, mp);
            let g_core = b as f64 * computed / mp as f64;
            let t_compute = crate::accel::efficiency::core_compute_ms(&s.spec, g_core)
                + s.spec.fused_layer_us * (end - start) as f64 / 1e3;
            let barriers = facts.barriers(start, end) as f64;
            let t_retile =
                s.spec.sync_us_per_core * mp as f64 * barriers * b as f64 / 1e3;
            let overheads = (s.spec.launch_overhead_us
                + s.spec.sync_us_per_core * mp as f64) / 1e3;
            let reference = t_compute.max(t_mem) + t_retile + overheads;
            let got = facts.block_latency_ms_at(&s.spec, start, end, mp, b);
            assert!((got - reference).abs() < 1e-12,
                    "[{start}..{end}] mp={mp} b={b}: {got} vs {reference}");
        }
    }

    #[test]
    fn retile_flags_and_radii() {
        let layers = vec![
            Layer::conv("c0", ConvSpec::same(8, 8, 56, 3)),
            Layer::conv("s2", ConvSpec {
                c_in: 8, c_out: 8, h_in: 56, w_in: 56, k: 3, stride: 2,
                pad: 1, groups: 1,
            }),
            Layer::new("p", LayerKind::Pool {
                shape: TensorShape::new(28, 28, 8), k: 2, stride: 2,
            }),
            Layer::new("r", LayerKind::ReLU { shape: TensorShape::new(14, 14, 8) }),
        ];
        let facts = ModelFacts::from_layers(&layers);
        assert!(!facts.layer(0).retile);
        assert!(facts.layer(1).retile);
        assert!(facts.layer(2).retile);
        assert!(!facts.layer(3).retile);
        assert_eq!(facts.barriers(0, 4), 2);
        assert_eq!(facts.layer(0).halo_radius, 1);
        assert_eq!(facts.layer(3).halo_radius, 0);
    }
}
