//! The `artifacts/manifest.json` schema, written by `python/compile/aot.py`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// One AOT artifact: a fused block (or single conv stage) lowered to HLO
/// text. Mirrors `python/compile/model.py::BlockSpec`.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub depth: usize,
    pub batch: usize,
    pub height: usize,
    pub width: usize,
    /// C_0 (input) followed by each stage's output channels.
    pub channels: Vec<usize>,
    pub relu_last: bool,
    pub dtype: String,
    /// Parameter shapes in calling order: x, then (w, b) per stage.
    pub input_shapes: Vec<Vec<usize>>,
    pub output_shape: Vec<usize>,
}

/// Golden-vector entry (deterministic inputs + expected output on disk).
#[derive(Debug, Clone, PartialEq)]
pub struct GoldenSpec {
    pub dir: String,
    pub num_inputs: usize,
    pub sha256: String,
}

/// The parsed manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
    /// fused artifact name -> its unfused per-stage artifact names.
    pub fused_pairs: BTreeMap<String, Vec<String>>,
    pub golden: BTreeMap<String, GoldenSpec>,
}

impl Manifest {
    /// Load `manifest.json` from an artifact directory.
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        Manifest::parse(&text, dir)
    }

    /// Parse manifest JSON text.
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest, String> {
        let v = Json::parse(text).map_err(|e| e.to_string())?;
        let version = v.get("format_version").as_usize().ok_or("missing format_version")?;
        if version != 1 {
            return Err(format!("unsupported manifest version {version}"));
        }
        if v.get("interchange").as_str() != Some("hlo-text") {
            return Err("manifest interchange must be 'hlo-text'".into());
        }
        let mut artifacts = Vec::new();
        for (i, a) in v.get("artifacts").as_arr().ok_or("missing artifacts")?.iter().enumerate() {
            artifacts.push(parse_artifact(a).map_err(|e| format!("artifact {i}: {e}"))?);
        }
        let mut fused_pairs = BTreeMap::new();
        if let Some(obj) = v.get("fused_pairs").as_obj() {
            for (k, stages) in obj {
                let names = stages
                    .as_arr()
                    .ok_or("fused_pairs entry not an array")?
                    .iter()
                    .map(|s| s.as_str().map(String::from).ok_or("stage name not a string"))
                    .collect::<Result<Vec<_>, _>>()?;
                fused_pairs.insert(k.clone(), names);
            }
        }
        let mut golden = BTreeMap::new();
        if let Some(obj) = v.get("golden").as_obj() {
            for (k, g) in obj {
                golden.insert(
                    k.clone(),
                    GoldenSpec {
                        dir: g.get("dir").as_str().ok_or("golden missing dir")?.to_string(),
                        num_inputs: g
                            .get("num_inputs")
                            .as_usize()
                            .ok_or("golden missing num_inputs")?,
                        sha256: g.get("sha256").as_str().unwrap_or("").to_string(),
                    },
                );
            }
        }
        let m = Manifest { dir: dir.to_path_buf(), artifacts, fused_pairs, golden };
        m.validate()?;
        Ok(m)
    }

    fn validate(&self) -> Result<(), String> {
        let names: std::collections::BTreeSet<&str> =
            self.artifacts.iter().map(|a| a.name.as_str()).collect();
        if names.len() != self.artifacts.len() {
            return Err("duplicate artifact names".into());
        }
        for (fused, stages) in &self.fused_pairs {
            if !names.contains(fused.as_str()) {
                return Err(format!("fused_pairs references unknown '{fused}'"));
            }
            for s in stages {
                if !names.contains(s.as_str()) {
                    return Err(format!("fused_pairs references unknown stage '{s}'"));
                }
            }
        }
        for a in &self.artifacts {
            if a.input_shapes.len() != 1 + 2 * a.depth {
                return Err(format!(
                    "{}: {} input shapes for depth {}",
                    a.name, a.input_shapes.len(), a.depth
                ));
            }
            if a.channels.len() != a.depth + 1 {
                return Err(format!("{}: channels/depth mismatch", a.name));
            }
        }
        Ok(())
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Path of an artifact's HLO text file.
    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }

    /// Fused artifacts that have per-stage counterparts (depth > 1).
    pub fn fused_with_stages(&self) -> Vec<(&ArtifactSpec, Vec<&ArtifactSpec>)> {
        self.fused_pairs
            .iter()
            .filter(|(_, stages)| !stages.is_empty())
            .filter_map(|(name, stages)| {
                let fused = self.get(name)?;
                let st: Option<Vec<&ArtifactSpec>> =
                    stages.iter().map(|s| self.get(s)).collect();
                Some((fused, st?))
            })
            .collect()
    }
}

fn parse_artifact(a: &Json) -> Result<ArtifactSpec, String> {
    let shapes = |key: &str| -> Result<Vec<Vec<usize>>, String> {
        a.get(key)
            .as_arr()
            .ok_or_else(|| format!("missing {key}"))?
            .iter()
            .map(|s| {
                s.as_arr()
                    .ok_or("shape not an array".to_string())?
                    .iter()
                    .map(|d| d.as_usize().ok_or("bad dim".to_string()))
                    .collect()
            })
            .collect()
    };
    Ok(ArtifactSpec {
        name: a.get("name").as_str().ok_or("missing name")?.to_string(),
        file: a.get("file").as_str().ok_or("missing file")?.to_string(),
        depth: a.get("depth").as_usize().ok_or("missing depth")?,
        batch: a.get("batch").as_usize().ok_or("missing batch")?,
        height: a.get("height").as_usize().ok_or("missing height")?,
        width: a.get("width").as_usize().ok_or("missing width")?,
        channels: a
            .get("channels")
            .as_arr()
            .ok_or("missing channels")?
            .iter()
            .map(|c| c.as_usize().ok_or("bad channel".to_string()))
            .collect::<Result<_, _>>()?,
        relu_last: a.get("relu_last").as_bool().unwrap_or(true),
        dtype: a.get("dtype").as_str().unwrap_or("f32").to_string(),
        input_shapes: shapes("input_shapes")?,
        output_shape: a
            .get("output_shape")
            .as_arr()
            .ok_or("missing output_shape")?
            .iter()
            .map(|d| d.as_usize().ok_or("bad dim".to_string()))
            .collect::<Result<_, _>>()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> String {
        r#"{
          "format_version": 1,
          "interchange": "hlo-text",
          "artifacts": [
            {"name": "b2", "file": "b2.hlo.txt", "depth": 2, "batch": 1,
             "height": 16, "width": 16, "channels": [8, 8, 8],
             "relu_last": true, "dtype": "f32",
             "input_shapes": [[1,16,16,8],[3,3,8,8],[8],[3,3,8,8],[8]],
             "output_shape": [1,16,16,8]},
            {"name": "b2__stage0", "file": "s0.hlo.txt", "depth": 1, "batch": 1,
             "height": 16, "width": 16, "channels": [8, 8],
             "relu_last": true, "dtype": "f32",
             "input_shapes": [[1,16,16,8],[3,3,8,8],[8]],
             "output_shape": [1,16,16,8]},
            {"name": "b2__stage1", "file": "s1.hlo.txt", "depth": 1, "batch": 1,
             "height": 16, "width": 16, "channels": [8, 8],
             "relu_last": true, "dtype": "f32",
             "input_shapes": [[1,16,16,8],[3,3,8,8],[8]],
             "output_shape": [1,16,16,8]}
          ],
          "fused_pairs": {"b2": ["b2__stage0", "b2__stage1"]},
          "golden": {"b2": {"dir": "golden/b2", "num_inputs": 5,
                            "sha256": "abc"}}
        }"#
        .to_string()
    }

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(&sample(), Path::new("/tmp/a")).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        let b2 = m.get("b2").unwrap();
        assert_eq!(b2.depth, 2);
        assert_eq!(b2.input_shapes.len(), 5);
        assert_eq!(m.fused_pairs["b2"].len(), 2);
        assert_eq!(m.golden["b2"].num_inputs, 5);
        assert_eq!(m.hlo_path(b2), PathBuf::from("/tmp/a/b2.hlo.txt"));
    }

    #[test]
    fn fused_with_stages_resolves() {
        let m = Manifest::parse(&sample(), Path::new("/tmp/a")).unwrap();
        let pairs = m.fused_with_stages();
        assert_eq!(pairs.len(), 1);
        assert_eq!(pairs[0].1.len(), 2);
    }

    #[test]
    fn rejects_bad_version() {
        let bad = sample().replace("\"format_version\": 1", "\"format_version\": 9");
        assert!(Manifest::parse(&bad, Path::new("/tmp")).unwrap_err().contains("version"));
    }

    #[test]
    fn rejects_unknown_stage_reference() {
        let bad = sample().replace("b2__stage1\"]", "nonexistent\"]");
        assert!(Manifest::parse(&bad, Path::new("/tmp")).is_err());
    }

    #[test]
    fn rejects_shape_arity_mismatch() {
        let bad = sample().replace(
            "\"input_shapes\": [[1,16,16,8],[3,3,8,8],[8],[3,3,8,8],[8]]",
            "\"input_shapes\": [[1,16,16,8],[3,3,8,8],[8]]",
        );
        assert!(Manifest::parse(&bad, Path::new("/tmp")).unwrap_err().contains("input shapes"));
    }

    #[test]
    fn loads_real_manifest_when_present() {
        let dir = crate::runtime::artifact_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(!m.artifacts.is_empty());
        assert!(!m.fused_with_stages().is_empty());
        for a in &m.artifacts {
            assert!(m.hlo_path(a).exists(), "{} missing", a.file);
        }
    }
}
