//! Shaped host-side f32 tensors and flat-file I/O.
//!
//! The runtime exchanges plain row-major f32 buffers with PJRT (`xla::
//! Literal`) and with the python-written golden vectors (`*.f32` files,
//! little-endian).

use std::io::Read;
use std::path::Path;

use crate::util::XorShiftRng;

/// A dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape/data mismatch"
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    /// Deterministic pseudo-random tensor (for equivalence tests and the
    /// request generator).
    pub fn random(shape: Vec<usize>, rng: &mut XorShiftRng, scale: f32) -> Self {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| (rng.gen_normal() as f32) * scale).collect();
        Tensor { shape, data }
    }

    pub fn num_elems(&self) -> usize {
        self.data.len()
    }

    /// Read a little-endian flat f32 file with a known shape (the format
    /// `aot.py` writes under `artifacts/golden/`).
    pub fn from_f32_file(path: &Path, shape: Vec<usize>) -> std::io::Result<Tensor> {
        let mut bytes = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut bytes)?;
        let want: usize = shape.iter().product::<usize>() * 4;
        if bytes.len() != want {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("{}: {} bytes, expected {want}", path.display(), bytes.len()),
            ));
        }
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Tensor { shape, data })
    }

    /// Max absolute elementwise difference.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape, "shape mismatch");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    /// allclose with combined absolute/relative tolerance.
    pub fn allclose(&self, other: &Tensor, rtol: f32, atol: f32) -> bool {
        if self.shape != other.shape {
            return false;
        }
        self.data
            .iter()
            .zip(&other.data)
            .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }

    /// Convert to an `xla::Literal` with this tensor's shape.
    pub fn to_literal(&self) -> Result<xla::Literal, xla::Error> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(&self.data).reshape(&dims)
    }

    /// Convert from an `xla::Literal` (f32) with a known shape.
    pub fn from_literal(lit: &xla::Literal, shape: Vec<usize>) -> Result<Tensor, xla::Error> {
        let data = lit.to_vec::<f32>()?;
        Ok(Tensor::new(shape, data))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_checks_shape() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.num_elems(), 6);
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn allclose_tolerances() {
        let a = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::new(vec![3], vec![1.0, 2.0 + 1e-6, 3.0]);
        assert!(a.allclose(&b, 1e-5, 1e-5));
        let c = Tensor::new(vec![3], vec![1.0, 2.5, 3.0]);
        assert!(!a.allclose(&c, 1e-5, 1e-5));
        assert!((a.max_abs_diff(&c) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn shape_mismatch_not_close() {
        let a = Tensor::zeros(vec![2, 2]);
        let b = Tensor::zeros(vec![4]);
        assert!(!a.allclose(&b, 1.0, 1.0));
    }

    #[test]
    fn f32_file_roundtrip() {
        let dir = std::env::temp_dir().join("dlfusion_tensor_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.f32");
        let values = [1.5f32, -2.25, 3.125];
        let bytes: Vec<u8> = values.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        let t = Tensor::from_f32_file(&path, vec![3]).unwrap();
        assert_eq!(t.data, values);
        // Wrong shape -> error.
        assert!(Tensor::from_f32_file(&path, vec![4]).is_err());
    }

    #[test]
    fn random_is_deterministic() {
        let mut r1 = XorShiftRng::new(3);
        let mut r2 = XorShiftRng::new(3);
        assert_eq!(
            Tensor::random(vec![4, 4], &mut r1, 1.0),
            Tensor::random(vec![4, 4], &mut r2, 1.0)
        );
    }
}
