//! PJRT client wrapper: compile-once, execute-many.
//!
//! Follows /opt/xla-example/load_hlo.rs: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Executables are cached by artifact name so
//! the request loop never recompiles (the paper's "compiled inference
//! session" model).

use std::collections::HashMap;
use std::path::Path;

use super::manifest::{ArtifactSpec, Manifest};
use super::tensor::Tensor;

/// Runtime error domain.
#[derive(Debug)]
pub enum RuntimeError {
    Io(String),
    Xla(String),
    Shape(String),
    UnknownArtifact(String),
    /// An execution plan is internally inconsistent with its model or
    /// manifest (empty step list, conv indices without weights, ...).
    InvalidPlan(String),
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::Io(m) => write!(f, "I/O error: {m}"),
            RuntimeError::Xla(m) => write!(f, "XLA error: {m}"),
            RuntimeError::Shape(m) => write!(f, "shape error: {m}"),
            RuntimeError::UnknownArtifact(m) => write!(f, "unknown artifact: {m}"),
            RuntimeError::InvalidPlan(m) => write!(f, "invalid plan: {m}"),
        }
    }
}

impl std::error::Error for RuntimeError {}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

/// The PJRT-backed execution runtime.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the manifest from `dir`.
    pub fn new(dir: &Path) -> Result<Runtime, RuntimeError> {
        let manifest = Manifest::load(dir).map_err(RuntimeError::Io)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, manifest, executables: HashMap::new() })
    }

    /// Open the default artifact directory (see [`super::artifact_dir`]).
    pub fn open_default() -> Result<Runtime, RuntimeError> {
        Runtime::new(&super::artifact_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.executables.len()
    }

    /// Compile (or fetch from cache) the executable for an artifact.
    pub fn prepare(&mut self, name: &str) -> Result<(), RuntimeError> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let spec = self
            .manifest
            .get(name)
            .ok_or_else(|| RuntimeError::UnknownArtifact(name.to_string()))?
            .clone();
        let path = self.manifest.hlo_path(&spec);
        let path_str = path
            .to_str()
            .ok_or_else(|| RuntimeError::Io(format!("non-UTF8 path {path:?}")))?;
        // HLO *text* interchange — see gen_hlo.py / DESIGN.md: serialized
        // protos from jax >= 0.5 carry 64-bit ids this XLA rejects.
        let proto = xla::HloModuleProto::from_text_file(path_str)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact with the given inputs (shapes checked against the
    /// manifest). Returns the single output tensor.
    pub fn execute(&mut self, name: &str, inputs: &[Tensor]) -> Result<Tensor, RuntimeError> {
        self.prepare(name)?;
        let spec = self.manifest.get(name).unwrap().clone();
        check_shapes(&spec, inputs)?;
        let exe = self.executables.get(name).unwrap();
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_, _>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // Lowered with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        Ok(Tensor::from_literal(&out, spec.output_shape.clone())?)
    }

    /// Execute a fused artifact's unfused stage chain: feed `x` through each
    /// per-stage executable, threading the activation. `params` are the
    /// fused artifact's (w, b) pairs in order.
    pub fn execute_stagewise(&mut self, fused_name: &str, inputs: &[Tensor])
                             -> Result<Tensor, RuntimeError> {
        let stages: Vec<String> = self
            .manifest
            .fused_pairs
            .get(fused_name)
            .ok_or_else(|| RuntimeError::UnknownArtifact(fused_name.to_string()))?
            .clone();
        if stages.is_empty() {
            return Err(RuntimeError::UnknownArtifact(format!(
                "{fused_name} has no per-stage artifacts"
            )));
        }
        let mut cur = inputs[0].clone();
        for (i, stage) in stages.iter().enumerate() {
            let stage_inputs =
                vec![cur, inputs[1 + 2 * i].clone(), inputs[2 + 2 * i].clone()];
            cur = self.execute(stage, &stage_inputs)?;
        }
        Ok(cur)
    }

    /// Deterministic random inputs for an artifact (for equivalence checks).
    pub fn random_inputs(&self, name: &str, seed: u64) -> Result<Vec<Tensor>, RuntimeError> {
        let spec = self
            .manifest
            .get(name)
            .ok_or_else(|| RuntimeError::UnknownArtifact(name.to_string()))?;
        let mut rng = crate::util::XorShiftRng::new(seed);
        Ok(spec
            .input_shapes
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let scale = if i == 0 { 1.0 } else { 0.3 };
                Tensor::random(s.clone(), &mut rng, scale)
            })
            .collect())
    }
}

fn check_shapes(spec: &ArtifactSpec, inputs: &[Tensor]) -> Result<(), RuntimeError> {
    if inputs.len() != spec.input_shapes.len() {
        return Err(RuntimeError::Shape(format!(
            "{}: {} inputs given, {} expected",
            spec.name,
            inputs.len(),
            spec.input_shapes.len()
        )));
    }
    for (i, (t, want)) in inputs.iter().zip(&spec.input_shapes).enumerate() {
        if &t.shape != want {
            return Err(RuntimeError::Shape(format!(
                "{}: input {i} has shape {:?}, expected {:?}",
                spec.name, t.shape, want
            )));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    // PJRT-touching tests live in rust/tests/runtime_numerics.rs (they need
    // built artifacts); here we only cover pure helpers.
    use super::*;

    fn spec() -> ArtifactSpec {
        ArtifactSpec {
            name: "t".into(),
            file: "t.hlo.txt".into(),
            depth: 1,
            batch: 1,
            height: 4,
            width: 4,
            channels: vec![2, 2],
            relu_last: true,
            dtype: "f32".into(),
            input_shapes: vec![vec![1, 4, 4, 2], vec![3, 3, 2, 2], vec![2]],
            output_shape: vec![1, 4, 4, 2],
        }
    }

    #[test]
    fn shape_check_passes_on_match() {
        let s = spec();
        let inputs: Vec<Tensor> = s
            .input_shapes
            .iter()
            .map(|sh| Tensor::zeros(sh.clone()))
            .collect();
        assert!(check_shapes(&s, &inputs).is_ok());
    }

    #[test]
    fn shape_check_rejects_arity() {
        let s = spec();
        let inputs = vec![Tensor::zeros(vec![1, 4, 4, 2])];
        assert!(matches!(check_shapes(&s, &inputs), Err(RuntimeError::Shape(_))));
    }

    #[test]
    fn shape_check_rejects_wrong_dims() {
        let s = spec();
        let mut inputs: Vec<Tensor> = s
            .input_shapes
            .iter()
            .map(|sh| Tensor::zeros(sh.clone()))
            .collect();
        inputs[1] = Tensor::zeros(vec![3, 3, 2, 4]);
        let err = check_shapes(&s, &inputs).unwrap_err();
        assert!(err.to_string().contains("input 1"));
    }

    #[test]
    fn error_display() {
        let e = RuntimeError::UnknownArtifact("zz".into());
        assert!(e.to_string().contains("zz"));
    }
}
