//! The PJRT runtime: loads AOT-compiled HLO-text artifacts and executes
//! them on the request path.
//!
//! Architecture (see /opt/xla-example and DESIGN.md §3): Python/JAX lowers
//! the Pallas fused-conv blocks ONCE at build time (`make artifacts`) to HLO
//! *text*; this module loads the text through `xla::HloModuleProto::
//! from_text_file`, compiles with the PJRT CPU client, and executes with
//! concrete tensors. Python is never involved at run time.
//!
//! - [`manifest`]: the `artifacts/manifest.json` schema (names, shapes,
//!   fused-block ↔ per-stage pairings, golden vectors);
//! - [`tensor`]: shaped host-side f32 buffers + flat-file I/O;
//! - [`client`]: the PJRT client wrapper with an executable cache.

pub mod manifest;
pub mod tensor;
pub mod client;

pub use client::{Runtime, RuntimeError};
pub use manifest::{ArtifactSpec, Manifest};
pub use tensor::Tensor;

/// Default artifact directory relative to the repo root.
pub const ARTIFACT_DIR: &str = "artifacts";

/// Locate the artifact directory: `$DLFUSION_ARTIFACTS`, else `artifacts/`
/// relative to the current dir, else relative to the crate root (so tests
/// and examples work from any cwd).
pub fn artifact_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("DLFUSION_ARTIFACTS") {
        return p.into();
    }
    let cwd = std::path::Path::new(ARTIFACT_DIR);
    if cwd.join("manifest.json").exists() {
        return cwd.to_path_buf();
    }
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(ARTIFACT_DIR)
}
