//! Fitting `OpCount_critical` from a single-core sweep.
//!
//! The paper reads `OpCount_critical = 10^1.25 GOPs` off Fig. 3(b)/4(a): the
//! per-core op count beyond which achieved performance stops improving. This
//! module recovers that constant from measurements alone (simulated or
//! real), which is how a user would recalibrate DLFusion for a different
//! accelerator — the paper's "microbenchmark methodology can also be applied
//! to reveal hardware characteristics" claim, made executable.

use crate::accel::Simulator;

/// A (op-count GOPs, achieved GFLOPS) measurement pair.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SweepPoint {
    pub gops: f64,
    pub gflops: f64,
}

/// Run a single-core op-count sweep on the simulator, isolating the
/// efficiency curve (memory-rich layers are skipped so compute dominates).
pub fn single_core_sweep(sim: &Simulator, points: usize) -> Vec<SweepPoint> {
    assert!(points >= 8);
    let mut out = Vec::with_capacity(points);
    // Log-spaced op counts from 10^-2 to 10^2.5 GOPs, realised as synthetic
    // square convs with matched op count (channel fixed wide so channel
    // effects don't contaminate the fit).
    for i in 0..points {
        let exp = -2.0 + 4.5 * i as f64 / (points - 1) as f64;
        let target_gops = 10f64.powf(exp);
        // 2*h^2*9*256*256 / 1e9 = target -> h = sqrt(target*1e9 / (18*65536)).
        let h = ((target_gops * 1e9) / (18.0 * 256.0 * 256.0)).sqrt().ceil() as usize;
        let h = h.max(1);
        let layer = crate::graph::Layer::conv(
            format!("sweep{i}"),
            crate::graph::layer::ConvSpec::same(256, 256, h, 3),
        );
        out.push(SweepPoint {
            gops: layer.op_gops(),
            gflops: sim.layer_gflops(&layer, 1),
        });
    }
    out
}

/// Estimate `OpCount_critical`: the smallest op count whose achieved
/// performance reaches `threshold` (default 0.9) of the sweep's plateau.
pub fn fit_opcount_critical(sweep: &[SweepPoint], threshold: f64) -> f64 {
    assert!(sweep.len() >= 2);
    assert!(threshold > 0.0 && threshold < 1.0);
    let plateau = sweep
        .iter()
        .map(|p| p.gflops)
        .fold(f64::NEG_INFINITY, f64::max);
    let mut pts: Vec<&SweepPoint> = sweep.iter().collect();
    pts.sort_by(|a, b| a.gops.total_cmp(&b.gops));
    for w in pts.windows(2) {
        let (a, b) = (w[0], w[1]);
        if b.gflops >= threshold * plateau && a.gflops < threshold * plateau {
            // Log-linear interpolation between the bracketing points.
            let t = (threshold * plateau - a.gflops) / (b.gflops - a.gflops);
            return 10f64.powf(a.gops.log10() + t * (b.gops.log10() - a.gops.log10()));
        }
    }
    pts.last().unwrap().gops
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_is_monotone_in_gflops() {
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let sweep = single_core_sweep(&sim, 24);
        for w in sweep.windows(2) {
            assert!(w[1].gflops >= w[0].gflops * 0.98,
                    "non-monotone at {} GOPs", w[1].gops);
        }
    }

    #[test]
    fn recovers_paper_critical_value() {
        // The simulator was calibrated with a per-core critical op count of
        // 10^1.25 / 32; a single-core sweep must recover it from
        // measurements alone. (Scaled by the core count this is the paper's
        // chip-wide OpCount_critical.) Launch/sync overheads shift the
        // measured 90% point slightly right of the pure-eta value, hence
        // the log-space tolerance.
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let sweep = single_core_sweep(&sim, 64);
        let crit = fit_opcount_critical(&sweep, 0.9);
        let want = sim.spec.opcount_critical_per_core();
        assert!((crit.log10() - want.log10()).abs() < 0.35,
                "fit {crit} vs calibrated {want}");
        let chip = crit * sim.spec.num_cores as f64;
        assert!((chip.log10() - 1.25).abs() < 0.35, "chip-wide {chip}");
    }

    #[test]
    fn threshold_moves_estimate() {
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let sweep = single_core_sweep(&sim, 48);
        let lo = fit_opcount_critical(&sweep, 0.5);
        let hi = fit_opcount_critical(&sweep, 0.9);
        assert!(lo < hi);
    }
}
