//! The Eq. 5 MP selector.
//!
//! `MP(C, OpCount) ∝ α · log2(C) + β · log2(OpCount)` with the paper's
//! empirical MLU100 weights α = 0.316, β = 0.659 ("according to the weight
//! result of PCA"). We realise the proportionality as
//!
//! `MP = 2^round(α·log2(C) + β·log2(G) + bias)`
//!
//! clamped to `[1, num_cores]` and to the largest power of two not exceeding
//! the useful channel-partition count (beyond `ceil(C/granularity)` cores
//! can only hold pad lanes — Section IV.A's "minimal partition size").
//! `bias` is the fitted proportionality constant; [`MpModel::fit`] re-derives
//! all three constants from a simulator sweep, which is what
//! `examples/characterize.rs` demonstrates.

use crate::accel::{AcceleratorSpec, Simulator};
use crate::graph::Layer;
use crate::stats::regression::multi_linear_fit;

/// Eq. 5 parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MpModel {
    pub alpha: f64,
    pub beta: f64,
    pub bias: f64,
}

impl Default for MpModel {
    fn default() -> Self {
        // Paper Section IV.A: α = 0.316, β = 0.659 for the MLU100. The bias
        // is our proportionality constant, calibrated on the simulator
        // (`examples/characterize.rs` re-fits all three).
        MpModel { alpha: 0.316, beta: 0.659, bias: 3.0 }
    }
}

impl MpModel {
    /// The default weights re-anchored to a hardware target: α and β are
    /// the paper's PCA weights (properties of conv workloads, not of the
    /// chip), while the proportionality constant shifts by
    /// `log2(num_cores / 32)` so the layer that lands mid-range on the
    /// 32-core MLU100 lands mid-range on any core count. For a 32-core
    /// target this is bit-identical to [`MpModel::default`]
    /// (`log2(1) == 0`), which keeps every pinned MLU100 result unchanged.
    pub fn for_spec(spec: &AcceleratorSpec) -> MpModel {
        let d = MpModel::default();
        MpModel { bias: d.bias + (spec.num_cores as f64 / 32.0).log2(), ..d }
    }

    /// Select the MP for a layer with `channels` output channels and `gops`
    /// operation count.
    pub fn select(&self, spec: &AcceleratorSpec, channels: usize, gops: f64) -> usize {
        let c = channels.max(1) as f64;
        let g = gops.max(1e-6);
        let score = self.alpha * c.log2() + self.beta * g.log2() + self.bias;
        let mp = 2f64.powf(score.round()).max(1.0);
        let mp = (mp as usize).min(spec.num_cores);
        // Cap at the useful channel-partition count, rounded up to a power
        // of two (a partial extra chunk still helps).
        let useful = channels.div_ceil(spec.channel_granularity).max(1);
        let cap = useful.next_power_of_two().min(spec.num_cores);
        round_pow2(mp.min(cap))
    }

    /// Select for a [`Layer`].
    pub fn select_layer(&self, spec: &AcceleratorSpec, layer: &Layer) -> usize {
        self.select(spec, layer.channels(), layer.op_gops())
    }

    /// Re-derive (α, β, bias) by regressing `log2(best MP)` on
    /// `(log2 C, log2 G)` over a layer sweep, using the simulator's true
    /// optimum as ground truth — the characterization route the paper took
    /// on hardware.
    pub fn fit(sim: &Simulator, layers: &[Layer]) -> MpModel {
        assert!(layers.len() >= 3, "need a sweep to fit");
        let mut xs = Vec::with_capacity(layers.len());
        let mut ys = Vec::with_capacity(layers.len());
        for l in layers {
            let best = sim.best_layer_mp(l);
            xs.push(vec![
                (l.channels().max(1) as f64).log2(),
                l.op_gops().max(1e-6).log2(),
            ]);
            ys.push((best as f64).log2());
        }
        let (w, b) = multi_linear_fit(&xs, &ys);
        MpModel { alpha: w[0], beta: w[1], bias: b }
    }
}

/// Largest power of two `<= x` (x >= 1).
fn round_pow2(x: usize) -> usize {
    assert!(x >= 1);
    let mut p = 1usize;
    while p * 2 <= x {
        p *= 2;
    }
    p
}

/// Convenience: Eq. 5 with the target-derived default weights
/// (bit-identical to [`MpModel::default`] on 32-core targets).
pub fn select_mp(spec: &AcceleratorSpec, layer: &Layer) -> usize {
    MpModel::for_spec(spec).select_layer(spec, layer)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::layer::ConvSpec;

    fn spec() -> AcceleratorSpec {
        crate::accel::Target::mlu100().into_spec()
    }

    #[test]
    fn returns_power_of_two_in_range() {
        let s = spec();
        let m = MpModel::default();
        for c in [1usize, 3, 16, 64, 150, 512, 2048] {
            for g in [1e-4, 0.05, 0.4, 3.7, 20.0] {
                let mp = m.select(&s, c, g);
                assert!(mp.is_power_of_two());
                assert!(mp >= 1 && mp <= s.num_cores);
            }
        }
    }

    #[test]
    fn monotone_in_opcount() {
        // Fig. 6(b): same channels, more ops -> no smaller MP.
        let s = spec();
        let m = MpModel::default();
        let mut last = 0;
        for g in [0.01, 0.1, 0.5, 2.0, 8.0, 32.0] {
            let mp = m.select(&s, 512, g);
            assert!(mp >= last, "g={g}");
            last = mp;
        }
    }

    #[test]
    fn channel_cap_applies() {
        // Fig. 6(a): narrow layers cap at ceil(C / granularity) partitions
        // regardless of op count.
        let s = spec();
        let m = MpModel::default();
        assert_eq!(m.select(&s, 4, 50.0), 1);
        assert!(m.select(&s, 16, 50.0) <= 4);
        assert!(m.select(&s, 64, 50.0) <= 16);
        assert!(m.select(&s, 512, 50.0) > m.select(&s, 16, 50.0));
    }

    #[test]
    fn paper_weights_are_default() {
        let m = MpModel::default();
        assert!((m.alpha - 0.316).abs() < 1e-12);
        assert!((m.beta - 0.659).abs() < 1e-12);
    }

    #[test]
    fn for_spec_is_bit_identical_on_32_cores_and_scales_elsewhere() {
        let s = spec();
        assert_eq!(MpModel::for_spec(&s), MpModel::default());
        // Twice the cores shifts the proportionality constant by one
        // power-of-two step; a quarter shifts it down two.
        let mut big = s.clone();
        big.num_cores = 64;
        assert!((MpModel::for_spec(&big).bias - 4.0).abs() < 1e-12);
        let mut small = s.clone();
        small.num_cores = 8;
        assert!((MpModel::for_spec(&small).bias - 1.0).abs() < 1e-12);
        // A mid-size layer therefore gets a larger MP on the bigger chip.
        let l = Layer::conv("c", ConvSpec::same(256, 256, 56, 3));
        assert!(MpModel::for_spec(&big).select_layer(&big, &l)
                >= MpModel::for_spec(&s).select_layer(&s, &l));
    }

    #[test]
    fn vgg_like_layer_gets_big_mp_resnet_tail_small() {
        let s = spec();
        let m = MpModel::default();
        let vgg_late = Layer::conv("v", ConvSpec::same(512, 512, 28, 3));
        let tiny = Layer::conv("t", ConvSpec::same(64, 64, 14, 3));
        assert!(m.select_layer(&s, &vgg_late) >= 8);
        assert!(m.select_layer(&s, &tiny) <= 4);
    }

    #[test]
    fn fit_recovers_positive_weights() {
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let mut layers = Vec::new();
        for c in [32usize, 64, 128, 256, 512] {
            for hw in [14usize, 28, 56, 112] {
                layers.push(Layer::conv(format!("c{c}_{hw}"),
                                        ConvSpec::same(c, c, hw, 3)));
            }
        }
        let m = MpModel::fit(&sim, &layers);
        // Both features should matter, with positive influence.
        assert!(m.beta > 0.0, "beta {}", m.beta);
        assert!(m.alpha + m.beta > 0.3, "alpha {} beta {}", m.alpha, m.beta);
    }

    #[test]
    fn fitted_model_tracks_simulator_optimum() {
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let mut layers = Vec::new();
        for c in [32usize, 64, 128, 256, 512] {
            for hw in [14usize, 28, 56, 112] {
                layers.push(Layer::conv(format!("c{c}_{hw}"),
                                        ConvSpec::same(c, c, hw, 3)));
            }
        }
        let m = MpModel::fit(&sim, &layers);
        let mut within2x = 0;
        for l in &layers {
            let pred = m.select_layer(&sim.spec, l) as f64;
            let best = sim.best_layer_mp(l) as f64;
            if pred / best <= 2.0 && best / pred <= 2.0 {
                within2x += 1;
            }
        }
        // The heuristic should land within one power-of-two step of the
        // true optimum for the large majority of the sweep.
        assert!(within2x * 10 >= layers.len() * 6,
                "only {within2x}/{} within 2x", layers.len());
    }

    #[test]
    fn round_pow2_basics() {
        assert_eq!(round_pow2(1), 1);
        assert_eq!(round_pow2(2), 2);
        assert_eq!(round_pow2(3), 2);
        assert_eq!(round_pow2(31), 16);
        assert_eq!(round_pow2(32), 32);
    }
}
