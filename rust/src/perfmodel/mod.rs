//! The analytical performance model derived from characterization.
//!
//! Section II of the paper builds, in order: a roofline model that *fails*
//! to predict measured performance (Fig. 3), a PCA over layer features that
//! identifies operation count and channel size as the dominant factors, a
//! fitted `OpCount_critical` where per-core performance saturates, and the
//! Eq. 5 MP selector used by Algorithm 1. Each step is a submodule here:
//!
//! - [`roofline`]: Eq. 3 intensity + the classical roofline bound;
//! - [`features`]: layer feature extraction + the PCA characterization;
//! - [`critical`]: fitting `OpCount_critical` from a single-core sweep;
//! - [`mp_select`]: the Eq. 5 `MP(C, OpCount)` selector (α = 0.316,
//!   β = 0.659) with a regression fitter to re-derive the weights.

pub mod roofline;
pub mod features;
pub mod critical;
pub mod mp_select;

pub use mp_select::{MpModel, select_mp};
pub use roofline::roofline_gflops;
