//! The roofline model (Eq. 3 + Williams et al.) the paper starts from —
//! and shows to be insufficient for the MLU100 (Fig. 3).

use crate::accel::AcceleratorSpec;
use crate::graph::Layer;

/// Eq. 3: operation intensity = ops / total tensor bytes.
pub fn intensity(layer: &Layer) -> f64 {
    layer.intensity()
}

/// Roofline-attainable GFLOPS at a given intensity for the whole chip:
/// `min(peak, intensity * BW)`.
pub fn roofline_gflops(spec: &AcceleratorSpec, intensity_ops_per_byte: f64) -> f64 {
    (intensity_ops_per_byte * spec.mem_bw_gbps).min(spec.peak_gflops())
}

/// Roofline for a single core (1/num_cores of bandwidth and compute).
pub fn roofline_gflops_single_core(spec: &AcceleratorSpec, intensity_ops_per_byte: f64) -> f64 {
    (intensity_ops_per_byte * spec.mem_bw_gbps).min(spec.peak_gflops_per_core)
}

/// The ridge point (ops/byte) where the chip turns compute-bound.
pub fn ridge_intensity(spec: &AcceleratorSpec) -> f64 {
    spec.peak_gflops() / spec.mem_bw_gbps
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::Simulator;
    use crate::graph::layer::ConvSpec;

    #[test]
    fn memory_bound_region_linear() {
        let s = crate::accel::Target::mlu100().into_spec();
        assert!((roofline_gflops(&s, 10.0) - 1024.0).abs() < 1e-9);
        assert!((roofline_gflops(&s, 100.0) - 10240.0).abs() < 1e-9);
    }

    #[test]
    fn compute_bound_region_flat() {
        let s = crate::accel::Target::mlu100().into_spec();
        assert_eq!(roofline_gflops(&s, 1e6), s.peak_gflops());
    }

    #[test]
    fn ridge_point() {
        let s = crate::accel::Target::mlu100().into_spec();
        // 64000 / 102.4 = 625 ops/byte.
        assert!((ridge_intensity(&s) - 625.0).abs() < 1e-9);
    }

    #[test]
    fn measured_gap_exists() {
        // The Fig. 3 observation: actual performance sits well below the
        // roofline for real layers.
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let layer = crate::graph::Layer::conv("c", ConvSpec::same(64, 64, 56, 3));
        let measured = sim.layer_gflops(&layer, 32);
        let bound = roofline_gflops(&sim.spec, intensity(&layer));
        assert!(measured < 0.5 * bound,
                "measured {measured} should gap below roofline {bound}");
    }
}
