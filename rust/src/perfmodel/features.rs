//! Layer feature extraction and the PCA characterization.
//!
//! Section II.B: "we applied PCA method to extract the parameters that are
//! most likely to influence the performance ... we found that operation
//! count has the most significant influence on the performance, and channel
//! the second." This module reproduces the analysis: featurize layers as
//! `(log op count, log channels, log kernel, log feature size)` paired with
//! achieved performance, run [`crate::stats::Pca`], and report each
//! feature's association with the performance axis.

use crate::accel::Simulator;
use crate::graph::{Layer, LayerKind};
use crate::stats::Pca;

/// Names of the feature columns, in order.
pub const FEATURE_NAMES: [&str; 4] = ["op_count", "channels", "kernel", "feature_size"];

/// Feature vector for one conv layer: log2-scaled op count, output channels,
/// kernel size, and output feature-map edge.
pub fn layer_features(layer: &Layer) -> Option<[f64; 4]> {
    match &layer.kind {
        LayerKind::Conv(c) => Some([
            layer.op_gops().max(1e-9).log2(),
            (c.c_out as f64).log2(),
            (c.k as f64).log2(),
            (c.h_out().max(1) as f64).log2(),
        ]),
        _ => None,
    }
}

/// Result of the PCA characterization over a layer population.
#[derive(Debug, Clone)]
pub struct Characterization {
    /// PCA over `[features..., achieved log-GFLOPS]` (5 columns).
    pub pca: Pca,
    /// |correlation| of each feature with achieved performance, aligned with
    /// [`FEATURE_NAMES`] — the ranking the paper reads off its PCA.
    pub perf_association: [f64; 4],
}

/// Run the characterization: measure every conv layer at MP = `mp` on the
/// simulator, fit PCA, and rank features by their association with
/// performance.
pub fn characterize(sim: &Simulator, layers: &[Layer], mp: usize) -> Characterization {
    let mut rows = Vec::new();
    let mut feats = Vec::new();
    let mut perfs = Vec::new();
    for l in layers {
        if let Some(f) = layer_features(l) {
            let gflops = sim.layer_gflops(l, mp).max(1e-9).log2();
            let mut row = f.to_vec();
            row.push(gflops);
            rows.push(row);
            feats.push(f);
            perfs.push(gflops);
        }
    }
    assert!(rows.len() >= 3, "need at least 3 conv layers to characterize");
    let pca = Pca::fit(&rows);
    let mut assoc = [0.0f64; 4];
    for j in 0..4 {
        assoc[j] = correlation(&feats.iter().map(|f| f[j]).collect::<Vec<_>>(), &perfs).abs();
    }
    Characterization { pca, perf_association: assoc }
}

fn correlation(xs: &[f64], ys: &[f64]) -> f64 {
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx).powi(2)).sum();
    let syy: f64 = ys.iter().map(|y| (y - my).powi(2)).sum();
    if sxx <= 1e-12 || syy <= 1e-12 {
        return 0.0;
    }
    sxy / (sxx * syy).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::microbench;

    #[test]
    fn features_only_for_convs() {
        use crate::graph::layer::{ConvSpec, TensorShape};
        let conv = Layer::conv("c", ConvSpec::same(64, 64, 56, 3));
        assert!(layer_features(&conv).is_some());
        let relu = Layer::new("r", LayerKind::ReLU { shape: TensorShape::new(8, 8, 8) });
        assert!(layer_features(&relu).is_none());
    }

    #[test]
    fn opcount_is_dominant_factor() {
        // The paper's key PCA finding, reproduced on the simulator: op count
        // associates with performance more strongly than kernel size or
        // feature size, and channel is material.
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let layers = microbench::conv_sweep();
        let ch = characterize(&sim, &layers, 1);
        let [op, chan, kernel, fsize] = ch.perf_association;
        assert!(op > chan, "op {op} should dominate channel {chan}");
        assert!(op > kernel && op > fsize, "op {op} kernel {kernel} fsize {fsize}");
    }

    #[test]
    fn pca_explains_most_variance_in_two_components() {
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let layers = microbench::conv_sweep();
        let ch = characterize(&sim, &layers, 1);
        let ratio = ch.pca.explained_ratio();
        assert!(ratio[0] + ratio[1] > 0.6, "PC1+PC2 = {}", ratio[0] + ratio[1]);
    }
}
