//! The `.dlm` model-description format.
//!
//! Our framework-independent substitute for ONNX (DESIGN.md §2): a JSON
//! document listing the input shape and the layer sequence. The paper's
//! tool-chain consumed ONNX through TVM.Relay and only retained per-layer
//! specifications; `.dlm` carries exactly those specifications, so the
//! optimizer sees the same information.
//!
//! Example:
//! ```json
//! {
//!   "name": "tiny",
//!   "input": [8, 8, 3],
//!   "layers": [
//!     {"name": "c1", "op": "conv", "c_in": 3, "c_out": 8,
//!      "h_in": 8, "w_in": 8, "k": 3, "stride": 1, "pad": 1, "groups": 1},
//!     {"name": "r1", "op": "relu", "shape": [8, 8, 8]}
//!   ]
//! }
//! ```

use super::layer::{ConvSpec, FcSpec, Layer, LayerKind, TensorShape};
use super::model::Model;
use crate::util::json::Json;

/// Serialize a model to `.dlm` JSON text (pretty-printed).
pub fn to_dlm(model: &Model) -> String {
    let layers: Vec<Json> = model.layers.iter().map(layer_to_json).collect();
    Json::obj(vec![
        ("name", Json::Str(model.name.clone())),
        ("input", shape_to_json(model.input)),
        ("layers", Json::Arr(layers)),
    ])
    .to_pretty()
}

/// Parse `.dlm` JSON text into a [`Model`] (validated).
pub fn from_dlm(text: &str) -> Result<Model, String> {
    let v = Json::parse(text).map_err(|e| e.to_string())?;
    let name = v
        .get("name")
        .as_str()
        .ok_or("missing model 'name'")?
        .to_string();
    let input = shape_from_json(v.get("input")).ok_or("bad 'input' shape")?;
    let layers_json = v.get("layers").as_arr().ok_or("missing 'layers' array")?;
    let mut layers = Vec::with_capacity(layers_json.len());
    for (i, lj) in layers_json.iter().enumerate() {
        layers.push(layer_from_json(lj).map_err(|e| format!("layer {i}: {e}"))?);
    }
    let model = Model::new(name, input, layers);
    model.validate()?;
    Ok(model)
}

fn shape_to_json(s: TensorShape) -> Json {
    Json::arr_usize(&[s.h, s.w, s.c])
}

fn shape_from_json(v: &Json) -> Option<TensorShape> {
    let a = v.as_arr()?;
    if a.len() != 3 {
        return None;
    }
    Some(TensorShape::new(
        a[0].as_usize()?,
        a[1].as_usize()?,
        a[2].as_usize()?,
    ))
}

fn layer_to_json(l: &Layer) -> Json {
    let mut pairs: Vec<(&str, Json)> = vec![("name", Json::Str(l.name.clone()))];
    match &l.kind {
        LayerKind::Conv(c) => {
            pairs.push(("op", Json::Str("conv".into())));
            pairs.push(("c_in", Json::Num(c.c_in as f64)));
            pairs.push(("c_out", Json::Num(c.c_out as f64)));
            pairs.push(("h_in", Json::Num(c.h_in as f64)));
            pairs.push(("w_in", Json::Num(c.w_in as f64)));
            pairs.push(("k", Json::Num(c.k as f64)));
            pairs.push(("stride", Json::Num(c.stride as f64)));
            pairs.push(("pad", Json::Num(c.pad as f64)));
            pairs.push(("groups", Json::Num(c.groups as f64)));
        }
        LayerKind::Fc(f) => {
            pairs.push(("op", Json::Str("fc".into())));
            pairs.push(("k", Json::Num(f.k as f64)));
            pairs.push(("n", Json::Num(f.n as f64)));
        }
        LayerKind::ReLU { shape } => {
            pairs.push(("op", Json::Str("relu".into())));
            pairs.push(("shape", shape_to_json(*shape)));
        }
        LayerKind::BatchNorm { shape } => {
            pairs.push(("op", Json::Str("batchnorm".into())));
            pairs.push(("shape", shape_to_json(*shape)));
        }
        LayerKind::Pool { shape, k, stride } => {
            pairs.push(("op", Json::Str("pool".into())));
            pairs.push(("shape", shape_to_json(*shape)));
            pairs.push(("k", Json::Num(*k as f64)));
            pairs.push(("stride", Json::Num(*stride as f64)));
        }
        LayerKind::Add { shape } => {
            pairs.push(("op", Json::Str("add".into())));
            pairs.push(("shape", shape_to_json(*shape)));
        }
    }
    Json::obj(pairs)
}

fn layer_from_json(v: &Json) -> Result<Layer, String> {
    let name = v.get("name").as_str().ok_or("missing 'name'")?.to_string();
    let op = v.get("op").as_str().ok_or("missing 'op'")?;
    let usize_field = |key: &str| -> Result<usize, String> {
        v.get(key)
            .as_usize()
            .ok_or_else(|| format!("missing/invalid '{key}'"))
    };
    let kind = match op {
        "conv" => LayerKind::Conv(ConvSpec {
            c_in: usize_field("c_in")?,
            c_out: usize_field("c_out")?,
            h_in: usize_field("h_in")?,
            w_in: usize_field("w_in")?,
            k: usize_field("k")?,
            stride: usize_field("stride")?,
            pad: usize_field("pad")?,
            groups: if v.get("groups").is_null() { 1 } else { usize_field("groups")? },
        }),
        "fc" => LayerKind::Fc(FcSpec { k: usize_field("k")?, n: usize_field("n")? }),
        "relu" => LayerKind::ReLU {
            shape: shape_from_json(v.get("shape")).ok_or("bad 'shape'")?,
        },
        "batchnorm" => LayerKind::BatchNorm {
            shape: shape_from_json(v.get("shape")).ok_or("bad 'shape'")?,
        },
        "pool" => LayerKind::Pool {
            shape: shape_from_json(v.get("shape")).ok_or("bad 'shape'")?,
            k: usize_field("k")?,
            stride: usize_field("stride")?,
        },
        "add" => LayerKind::Add {
            shape: shape_from_json(v.get("shape")).ok_or("bad 'shape'")?,
        },
        other => return Err(format!("unknown op '{other}'")),
    };
    Ok(Layer::new(name, kind))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo;

    #[test]
    fn roundtrip_tiny() {
        let m = Model::new(
            "t",
            TensorShape::new(8, 8, 3),
            vec![
                Layer::conv("c1", ConvSpec::same(3, 8, 8, 3)),
                Layer::new("r", LayerKind::ReLU { shape: TensorShape::new(8, 8, 8) }),
                Layer::new("p", LayerKind::Pool {
                    shape: TensorShape::new(8, 8, 8), k: 2, stride: 2 }),
                Layer::new("fc", LayerKind::Fc(FcSpec { k: 128, n: 10 })),
            ],
        );
        let text = to_dlm(&m);
        let back = from_dlm(&text).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn roundtrip_every_zoo_model() {
        for m in zoo::all_models() {
            let text = to_dlm(&m);
            let back = from_dlm(&text).expect(&m.name);
            assert_eq!(m, back, "roundtrip {}", m.name);
        }
    }

    #[test]
    fn groups_default_to_one() {
        let text = r#"{"name":"g","input":[4,4,2],"layers":[
            {"name":"c","op":"conv","c_in":2,"c_out":2,"h_in":4,"w_in":4,
             "k":3,"stride":1,"pad":1}]}"#;
        let m = from_dlm(text).unwrap();
        match &m.layers[0].kind {
            LayerKind::Conv(c) => assert_eq!(c.groups, 1),
            _ => panic!(),
        }
    }

    #[test]
    fn rejects_unknown_op() {
        let text = r#"{"name":"g","input":[4,4,2],"layers":[
            {"name":"x","op":"softmax9000"}]}"#;
        assert!(from_dlm(text).unwrap_err().contains("unknown op"));
    }

    #[test]
    fn rejects_invalid_chain() {
        let text = r#"{"name":"g","input":[4,4,2],"layers":[
            {"name":"c","op":"conv","c_in":5,"c_out":2,"h_in":4,"w_in":4,
             "k":3,"stride":1,"pad":1,"groups":1}]}"#;
        assert!(from_dlm(text).unwrap_err().contains("expects input"));
    }

    #[test]
    fn rejects_bad_json() {
        assert!(from_dlm("{not json").is_err());
    }
}
