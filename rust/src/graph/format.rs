//! The `.dlm` model-description format.
//!
//! Our framework-independent substitute for ONNX (DESIGN.md §2): a JSON
//! document listing the input shape and the layer sequence. The paper's
//! tool-chain consumed ONNX through TVM.Relay and only retained per-layer
//! specifications; `.dlm` carries exactly those specifications, so the
//! optimizer sees the same information.
//!
//! Example:
//! ```json
//! {
//!   "name": "tiny",
//!   "input": [8, 8, 3],
//!   "layers": [
//!     {"name": "c1", "op": "conv", "c_in": 3, "c_out": 8,
//!      "h_in": 8, "w_in": 8, "k": 3, "stride": 1, "pad": 1, "groups": 1},
//!     {"name": "r1", "op": "relu", "shape": [8, 8, 8]}
//!   ]
//! }
//! ```

use std::collections::BTreeSet;
use std::fmt;

use super::dag::DagError;
use super::layer::{ConvSpec, FcSpec, Layer, LayerKind, TensorShape};
use super::model::Model;
use crate::util::json::{Json, JsonError};

/// Structured `.dlm` parse/validation error (both format versions).
#[derive(Debug, Clone, PartialEq)]
pub enum DlmError {
    /// The document text is not JSON.
    Json(JsonError),
    /// Top-level structure problems: missing fields, bad shapes, a v2
    /// document handed to the v1-only entry point.
    Document(String),
    /// A layer entry failed to parse.
    Layer { index: usize, message: String },
    /// Two layers share a name.
    DuplicateLayerName(String),
    /// A layer consumes a value no input or layer produces (v2).
    DanglingReference { layer: String, value: String },
    /// The `version` field is neither 1 nor 2.
    UnsupportedVersion(usize),
    /// Parsed fine but semantically invalid (shape chain / dag rules).
    Validation(String),
}

impl fmt::Display for DlmError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DlmError::Json(e) => write!(f, "{e}"),
            DlmError::Document(m) => write!(f, "{m}"),
            DlmError::Layer { index, message } => write!(f, "layer {index}: {message}"),
            DlmError::DuplicateLayerName(n) => write!(f, "duplicate layer name '{n}'"),
            DlmError::DanglingReference { layer, value } => {
                write!(f, "layer '{layer}': dangling reference to unknown value '{value}'")
            }
            DlmError::UnsupportedVersion(v) => {
                write!(f, "unsupported .dlm version {v} (known versions: 1, 2)")
            }
            DlmError::Validation(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for DlmError {}

impl From<DagError> for DlmError {
    fn from(e: DagError) -> Self {
        match e {
            DagError::DuplicateName(n) => DlmError::DuplicateLayerName(n),
            DagError::DanglingReference { node, value } => {
                DlmError::DanglingReference { layer: node, value }
            }
            other => DlmError::Validation(other.to_string()),
        }
    }
}

/// Serialize a model to `.dlm` JSON text (pretty-printed, v1).
pub fn to_dlm(model: &Model) -> String {
    let layers: Vec<Json> = model.layers.iter().map(layer_to_json).collect();
    Json::obj(vec![
        ("name", Json::Str(model.name.clone())),
        ("input", shape_to_json(model.input)),
        ("layers", Json::Arr(layers)),
    ])
    .to_pretty()
}

/// Parse `.dlm` JSON text into a [`Model`] (validated), with the historical
/// stringly-typed error. See [`from_dlm_checked`] for the structured form
/// and [`crate::graph::dag::load_dlm`] for the version dispatcher that also
/// accepts v2 (DAG) documents.
pub fn from_dlm(text: &str) -> Result<Model, String> {
    from_dlm_checked(text).map_err(|e| e.to_string())
}

/// Parse a v1 `.dlm` document with structured errors.
pub fn from_dlm_checked(text: &str) -> Result<Model, DlmError> {
    let v = Json::parse(text).map_err(DlmError::Json)?;
    model_from_json(&v)
}

/// v1 (linear chain) parse of an already-parsed document.
pub(crate) fn model_from_json(v: &Json) -> Result<Model, DlmError> {
    match document_version(v)? {
        1 => {}
        2 => {
            return Err(DlmError::Document(
                "version 2 documents describe a dag; load them with graph::dag::load_dlm"
                    .into(),
            ));
        }
        other => return Err(DlmError::UnsupportedVersion(other)),
    }
    let name = v
        .get("name")
        .as_str()
        .ok_or_else(|| DlmError::Document("missing model 'name'".into()))?
        .to_string();
    let input = shape_from_json(v.get("input"))
        .ok_or_else(|| DlmError::Document("bad 'input' shape".into()))?;
    let layers_json = v
        .get("layers")
        .as_arr()
        .ok_or_else(|| DlmError::Document("missing 'layers' array".into()))?;
    let mut layers = Vec::with_capacity(layers_json.len());
    let mut seen: BTreeSet<String> = BTreeSet::new();
    for (i, lj) in layers_json.iter().enumerate() {
        if !lj.get("inputs").is_null() {
            return Err(DlmError::Layer {
                index: i,
                message: "per-layer 'inputs' require .dlm version 2".into(),
            });
        }
        let layer =
            layer_from_json(lj).map_err(|message| DlmError::Layer { index: i, message })?;
        if !seen.insert(layer.name.clone()) {
            return Err(DlmError::DuplicateLayerName(layer.name.clone()));
        }
        layers.push(layer);
    }
    let model = Model::new(name, input, layers);
    model.validate().map_err(DlmError::Validation)?;
    Ok(model)
}

/// The declared format version: absent means 1 (every pre-v2 document).
pub(crate) fn document_version(v: &Json) -> Result<usize, DlmError> {
    let ver = v.get("version");
    if ver.is_null() {
        return Ok(1);
    }
    ver.as_usize()
        .ok_or_else(|| DlmError::Document("bad 'version' field".into()))
}

pub(crate) fn shape_to_json(s: TensorShape) -> Json {
    Json::arr_usize(&[s.h, s.w, s.c])
}

pub(crate) fn shape_from_json(v: &Json) -> Option<TensorShape> {
    let a = v.as_arr()?;
    if a.len() != 3 {
        return None;
    }
    Some(TensorShape::new(
        a[0].as_usize()?,
        a[1].as_usize()?,
        a[2].as_usize()?,
    ))
}

pub(crate) fn layer_to_json(l: &Layer) -> Json {
    let mut pairs: Vec<(&str, Json)> = vec![("name", Json::Str(l.name.clone()))];
    match &l.kind {
        LayerKind::Conv(c) => {
            pairs.push(("op", Json::Str("conv".into())));
            pairs.push(("c_in", Json::Num(c.c_in as f64)));
            pairs.push(("c_out", Json::Num(c.c_out as f64)));
            pairs.push(("h_in", Json::Num(c.h_in as f64)));
            pairs.push(("w_in", Json::Num(c.w_in as f64)));
            pairs.push(("k", Json::Num(c.k as f64)));
            pairs.push(("stride", Json::Num(c.stride as f64)));
            pairs.push(("pad", Json::Num(c.pad as f64)));
            pairs.push(("groups", Json::Num(c.groups as f64)));
        }
        LayerKind::Fc(f) => {
            pairs.push(("op", Json::Str("fc".into())));
            pairs.push(("k", Json::Num(f.k as f64)));
            pairs.push(("n", Json::Num(f.n as f64)));
        }
        LayerKind::ReLU { shape } => {
            pairs.push(("op", Json::Str("relu".into())));
            pairs.push(("shape", shape_to_json(*shape)));
        }
        LayerKind::BatchNorm { shape } => {
            pairs.push(("op", Json::Str("batchnorm".into())));
            pairs.push(("shape", shape_to_json(*shape)));
        }
        LayerKind::Pool { shape, k, stride } => {
            pairs.push(("op", Json::Str("pool".into())));
            pairs.push(("shape", shape_to_json(*shape)));
            pairs.push(("k", Json::Num(*k as f64)));
            pairs.push(("stride", Json::Num(*stride as f64)));
        }
        LayerKind::Add { shape } => {
            pairs.push(("op", Json::Str("add".into())));
            pairs.push(("shape", shape_to_json(*shape)));
        }
        LayerKind::Concat { shape } => {
            pairs.push(("op", Json::Str("concat".into())));
            pairs.push(("shape", shape_to_json(*shape)));
        }
    }
    Json::obj(pairs)
}

pub(crate) fn layer_from_json(v: &Json) -> Result<Layer, String> {
    let name = v.get("name").as_str().ok_or("missing 'name'")?.to_string();
    let op = v.get("op").as_str().ok_or("missing 'op'")?;
    let usize_field = |key: &str| -> Result<usize, String> {
        v.get(key)
            .as_usize()
            .ok_or_else(|| format!("missing/invalid '{key}'"))
    };
    let kind = match op {
        "conv" => LayerKind::Conv(ConvSpec {
            c_in: usize_field("c_in")?,
            c_out: usize_field("c_out")?,
            h_in: usize_field("h_in")?,
            w_in: usize_field("w_in")?,
            k: usize_field("k")?,
            stride: usize_field("stride")?,
            pad: usize_field("pad")?,
            groups: if v.get("groups").is_null() { 1 } else { usize_field("groups")? },
        }),
        "fc" => LayerKind::Fc(FcSpec { k: usize_field("k")?, n: usize_field("n")? }),
        "relu" => LayerKind::ReLU {
            shape: shape_from_json(v.get("shape")).ok_or("bad 'shape'")?,
        },
        "batchnorm" => LayerKind::BatchNorm {
            shape: shape_from_json(v.get("shape")).ok_or("bad 'shape'")?,
        },
        "pool" => LayerKind::Pool {
            shape: shape_from_json(v.get("shape")).ok_or("bad 'shape'")?,
            k: usize_field("k")?,
            stride: usize_field("stride")?,
        },
        "add" => LayerKind::Add {
            shape: shape_from_json(v.get("shape")).ok_or("bad 'shape'")?,
        },
        "concat" => LayerKind::Concat {
            shape: shape_from_json(v.get("shape")).ok_or("bad 'shape'")?,
        },
        other => return Err(format!("unknown op '{other}'")),
    };
    Ok(Layer::new(name, kind))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo;

    #[test]
    fn roundtrip_tiny() {
        let m = Model::new(
            "t",
            TensorShape::new(8, 8, 3),
            vec![
                Layer::conv("c1", ConvSpec::same(3, 8, 8, 3)),
                Layer::new("r", LayerKind::ReLU { shape: TensorShape::new(8, 8, 8) }),
                Layer::new("p", LayerKind::Pool {
                    shape: TensorShape::new(8, 8, 8), k: 2, stride: 2 }),
                Layer::new("fc", LayerKind::Fc(FcSpec { k: 128, n: 10 })),
            ],
        );
        let text = to_dlm(&m);
        let back = from_dlm(&text).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn roundtrip_every_zoo_model() {
        for m in zoo::all_models() {
            let text = to_dlm(&m);
            let back = from_dlm(&text).expect(&m.name);
            assert_eq!(m, back, "roundtrip {}", m.name);
        }
    }

    #[test]
    fn groups_default_to_one() {
        let text = r#"{"name":"g","input":[4,4,2],"layers":[
            {"name":"c","op":"conv","c_in":2,"c_out":2,"h_in":4,"w_in":4,
             "k":3,"stride":1,"pad":1}]}"#;
        let m = from_dlm(text).unwrap();
        match &m.layers[0].kind {
            LayerKind::Conv(c) => assert_eq!(c.groups, 1),
            _ => panic!(),
        }
    }

    #[test]
    fn rejects_unknown_op() {
        let text = r#"{"name":"g","input":[4,4,2],"layers":[
            {"name":"x","op":"softmax9000"}]}"#;
        assert!(from_dlm(text).unwrap_err().contains("unknown op"));
    }

    #[test]
    fn rejects_invalid_chain() {
        let text = r#"{"name":"g","input":[4,4,2],"layers":[
            {"name":"c","op":"conv","c_in":5,"c_out":2,"h_in":4,"w_in":4,
             "k":3,"stride":1,"pad":1,"groups":1}]}"#;
        assert!(from_dlm(text).unwrap_err().contains("expects input"));
    }

    #[test]
    fn rejects_bad_json() {
        assert!(from_dlm("{not json").is_err());
        assert!(matches!(from_dlm_checked("{not json"), Err(DlmError::Json(_))));
    }

    #[test]
    fn rejects_duplicate_layer_names() {
        let text = r#"{"name":"g","input":[4,4,2],"layers":[
            {"name":"r","op":"relu","shape":[4,4,2]},
            {"name":"r","op":"relu","shape":[4,4,2]}]}"#;
        let err = from_dlm_checked(text).unwrap_err();
        assert_eq!(err, DlmError::DuplicateLayerName("r".into()));
        assert!(err.to_string().contains("duplicate layer name 'r'"));
    }

    #[test]
    fn rejects_v2_feature_in_v1_document() {
        // No "version" field makes this a v1 document; per-layer inputs are
        // a v2 feature and must be called out, not silently ignored.
        let text = r#"{"name":"g","input":[4,4,2],"layers":[
            {"name":"r","op":"relu","shape":[4,4,2],"inputs":["x"]}]}"#;
        let err = from_dlm_checked(text).unwrap_err();
        assert!(matches!(err, DlmError::Layer { index: 0, .. }));
        assert!(err.to_string().contains("version 2"), "{err}");
    }

    #[test]
    fn v1_entry_rejects_v2_documents_with_pointer() {
        let text = r#"{"name":"g","version":2,"inputs":[],"outputs":[],"layers":[]}"#;
        let err = from_dlm(text).unwrap_err();
        assert!(err.contains("load_dlm"), "{err}");
    }

    #[test]
    fn rejects_unsupported_version() {
        let text = r#"{"name":"g","version":3,"input":[4,4,2],"layers":[]}"#;
        assert_eq!(from_dlm_checked(text).unwrap_err(), DlmError::UnsupportedVersion(3));
    }
}
