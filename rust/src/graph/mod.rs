//! Layer-level intermediate representation of DNN models.
//!
//! The paper's optimizer consumes ONNX files through TVM's Relay parser and
//! only ever looks at per-layer *specifications*: layer type, channel sizes,
//! spatial extents, kernel size — from which it derives the two features that
//! drive the tuning decisions, operation count (Eq. 1/2) and channel size.
//! This module carries exactly those facts:
//!
//! - [`layer`]: the layer kinds and the Eq. 1/2 operation-count math;
//! - [`model`]: a model as an ordered layer sequence with validation and the
//!   Table II statistics;
//! - [`format`]: the `.dlm` JSON model-description format (our ONNX
//!   substitute — see DESIGN.md §2) with parser and serializer;
//! - [`dag`]: the true DAG IR (named value edges, multi-input `Add`/
//!   `Concat`, subgraph fusion legality, declarative rewrites, `.dlm` v2) —
//!   DESIGN.md §13. Linear chains remain first-class: a pure-chain DAG
//!   lowers back onto [`Model`] bit-identically.

pub mod dag;
pub mod layer;
pub mod model;
pub mod format;

pub use format::DlmError;
pub use layer::{ConvSpec, FcSpec, Layer, LayerKind, TensorShape};
pub use model::{Model, ModelStats};
