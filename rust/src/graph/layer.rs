//! Layer kinds and the paper's operation-count math.
//!
//! Eq. 1: `GOPS_Conv = 2 · H_out · W_out · H_K · W_K · C_in · C_out`
//! Eq. 2: `GOPS_FC   = 2 · M · K · N`
//!
//! For grouped convolutions `C_in` is the *per-group* input channel count
//! (the factor the multiply-accumulates actually see). Batch is 1 throughout,
//! matching the paper's latency-oriented inference setting.

/// Bytes per element; the MLU100 runs FP16 on its compute path (Table I).
pub const BYTES_PER_ELEM: f64 = 2.0;

/// A (height, width, channels) activation shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TensorShape {
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

impl TensorShape {
    pub fn new(h: usize, w: usize, c: usize) -> Self {
        TensorShape { h, w, c }
    }

    pub fn elems(&self) -> usize {
        self.h * self.w * self.c
    }

    pub fn bytes(&self) -> f64 {
        self.elems() as f64 * BYTES_PER_ELEM
    }
}

/// Convolution layer specification.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvSpec {
    pub c_in: usize,
    pub c_out: usize,
    /// Input spatial extent.
    pub h_in: usize,
    pub w_in: usize,
    /// Square kernel edge.
    pub k: usize,
    pub stride: usize,
    /// Symmetric zero padding.
    pub pad: usize,
    /// Convolution groups (1 = dense, `c_in` = depthwise).
    pub groups: usize,
}

impl ConvSpec {
    /// Dense (groups=1) conv in the paper's `{C_in, C_out, HxW, KxK}`
    /// notation, stride 1, SAME padding.
    pub fn same(c_in: usize, c_out: usize, hw: usize, k: usize) -> Self {
        ConvSpec { c_in, c_out, h_in: hw, w_in: hw, k, stride: 1, pad: k / 2, groups: 1 }
    }

    pub fn h_out(&self) -> usize {
        (self.h_in + 2 * self.pad - self.k) / self.stride + 1
    }

    pub fn w_out(&self) -> usize {
        (self.w_in + 2 * self.pad - self.k) / self.stride + 1
    }

    /// Eq. 1 operation count in GOPs (2 ops per MAC), group-aware.
    pub fn op_gops(&self) -> f64 {
        let per_group_cin = (self.c_in / self.groups).max(1);
        2.0 * self.h_out() as f64
            * self.w_out() as f64
            * (self.k * self.k) as f64
            * per_group_cin as f64
            * self.c_out as f64
            / 1e9
    }

    /// Eq. 1 *ignoring* groups — the convention under which the paper's
    /// Table II MobileNet row was computed (see EXPERIMENTS.md discussion).
    pub fn op_gops_dense_equiv(&self) -> f64 {
        2.0 * self.h_out() as f64
            * self.w_out() as f64
            * (self.k * self.k) as f64
            * self.c_in as f64
            * self.c_out as f64
            / 1e9
    }

    pub fn weight_bytes(&self) -> f64 {
        let per_group_cin = (self.c_in / self.groups).max(1);
        (self.k * self.k * per_group_cin * self.c_out) as f64 * BYTES_PER_ELEM
    }
}

/// Fully-connected layer specification (`y[M,N] = x[M,K] · W[K,N]`, M = 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FcSpec {
    pub k: usize,
    pub n: usize,
}

impl FcSpec {
    /// Eq. 2 operation count in GOPs with M = 1.
    pub fn op_gops(&self) -> f64 {
        2.0 * (self.k * self.n) as f64 / 1e9
    }

    pub fn weight_bytes(&self) -> f64 {
        (self.k * self.n) as f64 * BYTES_PER_ELEM
    }
}

/// The layer types the CNML operator SDK supports that we model
/// (conv, FC, ReLU, BatchNorm, pooling, elementwise add, channel concat —
/// the building blocks of every evaluated network).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LayerKind {
    Conv(ConvSpec),
    Fc(FcSpec),
    /// In-place activation over `shape`.
    ReLU { shape: TensorShape },
    /// Batch normalization over `shape` (scale+shift at inference).
    BatchNorm { shape: TensorShape },
    /// Max/avg pooling.
    Pool { shape: TensorShape, k: usize, stride: usize },
    /// Elementwise residual add over `shape`.
    Add { shape: TensorShape },
    /// Channel-axis concatenation producing `shape` (the summed-channel
    /// output). Pure data movement: under Eq. 1's MAC accounting it
    /// performs zero arithmetic, unlike the one-op-per-element `Add` it
    /// was previously costed as.
    Concat { shape: TensorShape },
}

/// One layer in the model's execution order.
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
}

impl Layer {
    pub fn new(name: impl Into<String>, kind: LayerKind) -> Self {
        Layer { name: name.into(), kind }
    }

    pub fn conv(name: impl Into<String>, spec: ConvSpec) -> Self {
        Layer::new(name, LayerKind::Conv(spec))
    }

    /// Is this a layer Algorithm 1 assigns an MP to (line 6: Conv / FC)?
    pub fn is_compute(&self) -> bool {
        matches!(self.kind, LayerKind::Conv(_) | LayerKind::Fc(_))
    }

    /// Operation count in GOPs (Eq. 1 / Eq. 2; auxiliary layers are counted
    /// at their elementwise cost, which is negligible next to conv/FC and
    /// matches the paper's conv-centric accounting).
    pub fn op_gops(&self) -> f64 {
        match &self.kind {
            LayerKind::Conv(c) => c.op_gops(),
            LayerKind::Fc(f) => f.op_gops(),
            LayerKind::ReLU { shape } => shape.elems() as f64 / 1e9,
            LayerKind::BatchNorm { shape } => 2.0 * shape.elems() as f64 / 1e9,
            LayerKind::Pool { shape, k, .. } => {
                (shape.elems() * k * k) as f64 / 1e9
            }
            LayerKind::Add { shape } => shape.elems() as f64 / 1e9,
            // Concat moves bytes but multiplies nothing: Eq. 1 with zero
            // MACs. Its traffic still shows up in `tensor_bytes`.
            LayerKind::Concat { .. } => 0.0,
        }
    }

    /// Output-channel dimension — the tensor axis the MLU100 partitions
    /// across cores, and the "channel" feature of Eq. 5.
    pub fn channels(&self) -> usize {
        match &self.kind {
            LayerKind::Conv(c) => c.c_out,
            LayerKind::Fc(f) => f.n,
            LayerKind::ReLU { shape }
            | LayerKind::BatchNorm { shape }
            | LayerKind::Add { shape }
            | LayerKind::Concat { shape } => shape.c,
            LayerKind::Pool { shape, .. } => shape.c,
        }
    }

    /// Input activation shape.
    pub fn input_shape(&self) -> TensorShape {
        match &self.kind {
            LayerKind::Conv(c) => TensorShape::new(c.h_in, c.w_in, c.c_in),
            LayerKind::Fc(f) => TensorShape::new(1, 1, f.k),
            LayerKind::ReLU { shape }
            | LayerKind::BatchNorm { shape }
            | LayerKind::Add { shape }
            | LayerKind::Concat { shape } => *shape,
            LayerKind::Pool { shape, .. } => *shape,
        }
    }

    /// Output activation shape.
    pub fn output_shape(&self) -> TensorShape {
        match &self.kind {
            LayerKind::Conv(c) => TensorShape::new(c.h_out(), c.w_out(), c.c_out),
            LayerKind::Fc(f) => TensorShape::new(1, 1, f.n),
            LayerKind::ReLU { shape }
            | LayerKind::BatchNorm { shape }
            | LayerKind::Add { shape }
            | LayerKind::Concat { shape } => *shape,
            LayerKind::Pool { shape, stride, .. } => {
                let s = (*stride).max(1);
                TensorShape::new(shape.h / s, shape.w / s, shape.c)
            }
        }
    }

    /// Parameter bytes resident off-chip.
    pub fn weight_bytes(&self) -> f64 {
        match &self.kind {
            LayerKind::Conv(c) => c.weight_bytes(),
            LayerKind::Fc(f) => f.weight_bytes(),
            LayerKind::BatchNorm { shape } => 2.0 * shape.c as f64 * BYTES_PER_ELEM,
            _ => 0.0,
        }
    }

    /// Spatial receptive-field radius this layer adds to a fusion block's
    /// halo (Fig. 7(a)): (k-1)/2 per conv/pool stage, 0 for pointwise ops.
    pub fn halo_radius(&self) -> usize {
        match &self.kind {
            LayerKind::Conv(c) => (c.k.saturating_sub(1)) / 2,
            LayerKind::Pool { k, .. } => (k.saturating_sub(1)) / 2,
            _ => 0,
        }
    }

    /// Total tensor traffic (input + output + weights) in bytes — the
    /// denominator of the paper's Eq. 3 operation intensity.
    pub fn tensor_bytes(&self) -> f64 {
        self.input_shape().bytes() + self.output_shape().bytes() + self.weight_bytes()
    }

    /// Eq. 3: operation intensity in ops/byte.
    pub fn intensity(&self) -> f64 {
        self.op_gops() * 1e9 / self.tensor_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's running example: VGG-19 conv {64, 64, 224x224, 3x3}.
    fn vgg_conv() -> ConvSpec {
        ConvSpec::same(64, 64, 224, 3)
    }

    #[test]
    fn eq1_vgg_example() {
        // 2 * 224 * 224 * 3 * 3 * 64 * 64 = 3.7 GOPs
        let g = vgg_conv().op_gops();
        assert!((g - 3.699).abs() < 0.01, "got {g}");
    }

    #[test]
    fn eq1_fig7_conv_examples() {
        // Fig. 7(b): Conv2 has 0.43 GOPs; {64,64,56x56,3x3} has ~0.231.
        let c = ConvSpec::same(64, 64, 56, 3);
        assert!((c.op_gops() - 0.231).abs() < 0.01);
        let c2 = ConvSpec::same(128, 128, 28, 3);
        assert!((c2.op_gops() - 0.231).abs() < 0.01);
    }

    #[test]
    fn eq2_fc() {
        let f = FcSpec { k: 4096, n: 1000 };
        assert!((f.op_gops() - 2.0 * 4096.0 * 1000.0 / 1e9).abs() < 1e-12);
    }

    #[test]
    fn stride_and_pad_output_shape() {
        let c = ConvSpec { c_in: 3, c_out: 96, h_in: 227, w_in: 227, k: 11,
                           stride: 4, pad: 0, groups: 1 };
        assert_eq!(c.h_out(), 55);
        let c2 = ConvSpec { c_in: 64, c_out: 64, h_in: 56, w_in: 56, k: 3,
                            stride: 2, pad: 1, groups: 1 };
        assert_eq!(c2.h_out(), 28);
    }

    #[test]
    fn grouped_conv_reduces_ops() {
        let dense = ConvSpec::same(64, 64, 28, 3);
        let dw = ConvSpec { groups: 64, ..dense };
        assert!((dw.op_gops() - dense.op_gops() / 64.0).abs() < 1e-12);
        assert!((dw.op_gops_dense_equiv() - dense.op_gops()).abs() < 1e-12);
    }

    #[test]
    fn halo_radius_by_kind() {
        assert_eq!(Layer::conv("c", vgg_conv()).halo_radius(), 1);
        let five = ConvSpec::same(8, 8, 28, 5);
        assert_eq!(Layer::conv("c5", five).halo_radius(), 2);
        let relu = Layer::new("r", LayerKind::ReLU { shape: TensorShape::new(28, 28, 8) });
        assert_eq!(relu.halo_radius(), 0);
    }

    #[test]
    fn intensity_positive_and_sane() {
        let l = Layer::conv("c", vgg_conv());
        // ~3.7e9 ops over ~13 MB -> hundreds of ops/byte.
        let i = l.intensity();
        assert!(i > 100.0 && i < 1000.0, "intensity {i}");
    }

    #[test]
    fn compute_layer_classification() {
        assert!(Layer::conv("c", vgg_conv()).is_compute());
        assert!(Layer::new("f", LayerKind::Fc(FcSpec { k: 10, n: 10 })).is_compute());
        let shape = TensorShape::new(4, 4, 4);
        assert!(!Layer::new("r", LayerKind::ReLU { shape }).is_compute());
        assert!(!Layer::new("a", LayerKind::Add { shape }).is_compute());
    }

    #[test]
    fn concat_is_free_data_movement() {
        let shape = TensorShape::new(8, 8, 32);
        let cat = Layer::new("cat", LayerKind::Concat { shape });
        assert_eq!(cat.op_gops(), 0.0, "Eq. 1 with zero MACs");
        assert!(!cat.is_compute());
        assert_eq!(cat.channels(), 32);
        assert_eq!(cat.input_shape(), shape);
        assert_eq!(cat.output_shape(), shape);
        assert_eq!(cat.weight_bytes(), 0.0);
        assert_eq!(cat.halo_radius(), 0);
        // Traffic is still accounted: input + output activations.
        assert_eq!(cat.tensor_bytes(), 2.0 * shape.bytes());
        // And strictly cheaper than the Add it used to be costed as.
        let add = Layer::new("add", LayerKind::Add { shape });
        assert!(add.op_gops() > cat.op_gops());
    }

    #[test]
    fn weight_bytes_fp16() {
        let c = ConvSpec::same(64, 64, 56, 3);
        assert_eq!(c.weight_bytes(), (3 * 3 * 64 * 64) as f64 * 2.0);
    }

    #[test]
    fn pool_output_shape() {
        let p = Layer::new("p", LayerKind::Pool {
            shape: TensorShape::new(56, 56, 64), k: 2, stride: 2 });
        assert_eq!(p.output_shape(), TensorShape::new(28, 28, 64));
    }
}
