//! A model is an ordered sequence of layers (the execution order TVM's Relay
//! parser hands the paper's optimizer), plus the Table II statistics.

use super::layer::{Layer, LayerKind, TensorShape};

/// A DNN model in execution order.
///
/// Like the paper (whose Algorithm 1 walks `0..num_of_layer` linearly), the
/// IR is a *linear* sequence: residual topologies are represented by their
/// layer execution order with explicit `Add` layers, which is the shape the
/// fusion partitioner consumes.
#[derive(Debug, Clone, PartialEq)]
pub struct Model {
    pub name: String,
    /// Network input activation.
    pub input: TensorShape,
    pub layers: Vec<Layer>,
}

/// The Table II row for a model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelStats {
    /// Total op count over conv layers, GOPs.
    pub total_conv_gops: f64,
    /// Average per-conv op count, GOPs.
    pub avg_conv_gops: f64,
    pub num_conv: usize,
    /// Total over *all* layers (incl. FC and auxiliaries), GOPs.
    pub total_gops: f64,
    pub num_layers: usize,
}

impl Model {
    pub fn new(name: impl Into<String>, input: TensorShape, layers: Vec<Layer>) -> Self {
        Model { name: name.into(), input, layers }
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Indices of compute (Conv/FC) layers.
    pub fn compute_indices(&self) -> Vec<usize> {
        self.layers
            .iter()
            .enumerate()
            .filter(|(_, l)| l.is_compute())
            .map(|(i, _)| i)
            .collect()
    }

    /// The Table II statistics (conv layers only, like the paper's
    /// "Total Op / Avg. Op / No. of CONV" columns).
    pub fn stats(&self) -> ModelStats {
        let convs: Vec<&Layer> = self
            .layers
            .iter()
            .filter(|l| matches!(l.kind, LayerKind::Conv(_)))
            .collect();
        let total_conv: f64 = convs.iter().map(|l| l.op_gops()).sum();
        let num_conv = convs.len();
        ModelStats {
            total_conv_gops: total_conv,
            avg_conv_gops: if num_conv == 0 { 0.0 } else { total_conv / num_conv as f64 },
            num_conv,
            total_gops: self.layers.iter().map(|l| l.op_gops()).sum(),
            num_layers: self.layers.len(),
        }
    }

    /// Check structural sanity: non-empty, shapes chain (each layer's input
    /// matches the previous layer's output, with `Add` layers allowed to
    /// merge an earlier skip tensor of identical shape).
    pub fn validate(&self) -> Result<(), String> {
        if self.layers.is_empty() {
            return Err(format!("model '{}' has no layers", self.name));
        }
        let mut cur = self.input;
        for (i, layer) in self.layers.iter().enumerate() {
            let expect = layer.input_shape();
            // FC layers flatten whatever precedes them.
            let flatten_ok = matches!(layer.kind, LayerKind::Fc(f) if f.k == cur.elems());
            if expect != cur && !flatten_ok {
                return Err(format!(
                    "model '{}' layer {} ('{}'): expects input {}x{}x{}, got {}x{}x{}",
                    self.name, i, layer.name,
                    expect.h, expect.w, expect.c, cur.h, cur.w, cur.c
                ));
            }
            cur = layer.output_shape();
        }
        Ok(())
    }

    /// Summed weight bytes (model footprint in device memory).
    pub fn weight_bytes(&self) -> f64 {
        self.layers.iter().map(|l| l.weight_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::layer::{ConvSpec, FcSpec};

    fn tiny_model() -> Model {
        let c1 = ConvSpec::same(3, 8, 8, 3);
        let c2 = ConvSpec::same(8, 8, 8, 3);
        Model::new(
            "tiny",
            TensorShape::new(8, 8, 3),
            vec![
                Layer::conv("c1", c1),
                Layer::new("r1", LayerKind::ReLU { shape: TensorShape::new(8, 8, 8) }),
                Layer::conv("c2", c2),
            ],
        )
    }

    #[test]
    fn validate_ok() {
        assert!(tiny_model().validate().is_ok());
    }

    #[test]
    fn validate_catches_channel_break() {
        let mut m = tiny_model();
        m.layers[2] = Layer::conv("bad", ConvSpec::same(16, 8, 8, 3));
        let err = m.validate().unwrap_err();
        assert!(err.contains("expects input"), "{err}");
    }

    #[test]
    fn validate_rejects_empty() {
        let m = Model::new("e", TensorShape::new(1, 1, 1), vec![]);
        assert!(m.validate().is_err());
    }

    #[test]
    fn fc_flatten_accepted() {
        let m = Model::new(
            "f",
            TensorShape::new(2, 2, 3),
            vec![Layer::new("fc", LayerKind::Fc(FcSpec { k: 12, n: 5 }))],
        );
        assert!(m.validate().is_ok());
    }

    #[test]
    fn stats_count_convs_only() {
        let s = tiny_model().stats();
        assert_eq!(s.num_conv, 2);
        assert_eq!(s.num_layers, 3);
        assert!(s.total_conv_gops > 0.0);
        assert!((s.avg_conv_gops - s.total_conv_gops / 2.0).abs() < 1e-15);
        assert!(s.total_gops >= s.total_conv_gops);
    }

    #[test]
    fn compute_indices() {
        assert_eq!(tiny_model().compute_indices(), vec![0, 2]);
    }

    #[test]
    fn weight_bytes_sums() {
        let m = tiny_model();
        let want: f64 = (3 * 3 * 3 * 8 + 3 * 3 * 8 * 8) as f64 * 2.0;
        assert_eq!(m.weight_bytes(), want);
    }
}
