//! The DAG IR: nodes, named value edges, and multi-input joins.
//!
//! The linear [`Model`](crate::graph::Model) mirrors the paper's Algorithm 1,
//! which walks `0..num_of_layer`: residual topologies are *faked* as
//! sequential layers. `DagModel` is the real thing — every node consumes
//! named values (graph inputs or other nodes' outputs) and produces one
//! value named after itself, so ResNet skip connections and Inception-style
//! concats are expressible directly.
//!
//! A `DagModel` is always kept valid: names are unique, references resolve,
//! the graph is acyclic, and shapes agree at every join. Construction goes
//! through [`DagModel::new`] (or the
//! [`DagBuilder`](crate::graph::dag::DagBuilder)), which runs
//! [`DagModel::validate`].

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;

use crate::graph::{Layer, LayerKind, Model, TensorShape};

/// A named graph input: a value the model consumes from outside.
#[derive(Debug, Clone, PartialEq)]
pub struct GraphInput {
    pub name: String,
    pub shape: TensorShape,
}

/// Operation carried by a DAG node.
///
/// Unary layer ops reuse [`LayerKind`] unchanged; the joins (`Add`,
/// `Concat`) are native DAG ops because the linear IR cannot express their
/// arity. `LayerKind::Add` and `LayerKind::Concat` are *not* allowed inside
/// `DagOp::Layer` — the DAG canonical forms are always [`DagOp::Add`] /
/// [`DagOp::Concat`], which keeps "is this a join?" a structural question.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DagOp {
    /// A unary op from the linear IR: conv, FC, ReLU, batch-norm, pool.
    Layer(LayerKind),
    /// Elementwise sum of all inputs; every input must have shape `shape`.
    Add { shape: TensorShape },
    /// Channel concatenation: inputs share `shape`'s spatial dims and their
    /// channels sum to `shape.c`. Lowered to `LayerKind::Concat { shape }`
    /// for costing (pure data movement: zero MACs under Eq. 1, zero
    /// weights, zero halo) — see `lower.rs`.
    Concat { shape: TensorShape },
}

impl DagOp {
    /// Shape of the value this op produces.
    pub fn output_shape(&self) -> TensorShape {
        match self {
            DagOp::Layer(kind) => Layer::new("", *kind).output_shape(),
            DagOp::Add { shape } | DagOp::Concat { shape } => *shape,
        }
    }

    /// Short op mnemonic for tables and summaries.
    pub fn mnemonic(&self) -> &'static str {
        match self {
            DagOp::Layer(LayerKind::Conv(_)) => "conv",
            DagOp::Layer(LayerKind::Fc(_)) => "fc",
            DagOp::Layer(LayerKind::ReLU { .. }) => "relu",
            DagOp::Layer(LayerKind::BatchNorm { .. }) => "batchnorm",
            DagOp::Layer(LayerKind::Pool { .. }) => "pool",
            DagOp::Layer(LayerKind::Add { .. }) | DagOp::Add { .. } => "add",
            DagOp::Layer(LayerKind::Concat { .. }) | DagOp::Concat { .. } => "concat",
        }
    }
}

/// One node: a named op consuming named values. The node's output value is
/// named after the node itself.
#[derive(Debug, Clone, PartialEq)]
pub struct DagNode {
    pub name: String,
    pub op: DagOp,
    /// Value names consumed, in order: graph input names or other nodes'
    /// names. Unary ops take exactly one; joins take one or more.
    pub inputs: Vec<String>,
}

/// Structured validation error for [`DagModel`].
#[derive(Debug, Clone, PartialEq)]
pub enum DagError {
    /// The graph has no nodes.
    Empty,
    /// The graph declares no inputs / no outputs.
    NoGraphInputs,
    NoGraphOutputs,
    /// Two values (graph inputs or nodes) share a name.
    DuplicateName(String),
    /// A node consumes a value no input or node produces.
    DanglingReference { node: String, value: String },
    /// A declared graph output names an unknown value.
    UnknownOutput(String),
    /// The graph has a cycle through this node.
    Cycle(String),
    /// Wrong input count for the op (or a join expressed as a unary layer).
    BadArity { node: String, message: String },
    /// Shapes disagree at this node.
    ShapeMismatch { node: String, message: String },
}

impl fmt::Display for DagError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DagError::Empty => write!(f, "dag has no nodes"),
            DagError::NoGraphInputs => write!(f, "dag declares no graph inputs"),
            DagError::NoGraphOutputs => write!(f, "dag declares no graph outputs"),
            DagError::DuplicateName(n) => write!(f, "duplicate layer name '{n}'"),
            DagError::DanglingReference { node, value } => {
                write!(f, "layer '{node}': dangling reference to unknown value '{value}'")
            }
            DagError::UnknownOutput(n) => {
                write!(f, "graph output '{n}' names no input or layer")
            }
            DagError::Cycle(n) => write!(f, "cycle through layer '{n}'"),
            DagError::BadArity { node, message } => write!(f, "layer '{node}': {message}"),
            DagError::ShapeMismatch { node, message } => {
                write!(f, "layer '{node}': expects input {message}")
            }
        }
    }
}

impl std::error::Error for DagError {}

/// A directed acyclic graph of named ops. See the module docs.
#[derive(Debug, Clone, PartialEq)]
pub struct DagModel {
    pub name: String,
    pub inputs: Vec<GraphInput>,
    /// Value names the graph exposes; they stay live to the end of any
    /// linearization.
    pub outputs: Vec<String>,
    /// Nodes in insertion order. Insertion order need not be topological —
    /// [`DagModel::topo_order`] computes a deterministic schedule.
    pub nodes: Vec<DagNode>,
}

impl DagModel {
    /// Build and validate.
    pub fn new(
        name: impl Into<String>,
        inputs: Vec<GraphInput>,
        outputs: Vec<String>,
        nodes: Vec<DagNode>,
    ) -> Result<DagModel, DagError> {
        let m = DagModel { name: name.into(), inputs, outputs, nodes };
        m.validate()?;
        Ok(m)
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Full structural + shape validation. Every constructor routes through
    /// this; rewrites re-run it after applying a patch.
    pub fn validate(&self) -> Result<(), DagError> {
        if self.nodes.is_empty() {
            return Err(DagError::Empty);
        }
        if self.inputs.is_empty() {
            return Err(DagError::NoGraphInputs);
        }
        if self.outputs.is_empty() {
            return Err(DagError::NoGraphOutputs);
        }
        // Unique names across the whole value namespace (inputs + nodes).
        let mut names: BTreeSet<&str> = BTreeSet::new();
        for name in self
            .inputs
            .iter()
            .map(|i| i.name.as_str())
            .chain(self.nodes.iter().map(|n| n.name.as_str()))
        {
            if !names.insert(name) {
                return Err(DagError::DuplicateName(name.to_string()));
            }
        }
        // References resolve.
        for node in &self.nodes {
            if node.inputs.is_empty() {
                return Err(DagError::BadArity {
                    node: node.name.clone(),
                    message: "consumes no inputs".into(),
                });
            }
            for v in &node.inputs {
                if !names.contains(v.as_str()) {
                    return Err(DagError::DanglingReference {
                        node: node.name.clone(),
                        value: v.clone(),
                    });
                }
            }
        }
        for out in &self.outputs {
            if !names.contains(out.as_str()) {
                return Err(DagError::UnknownOutput(out.clone()));
            }
        }
        // Acyclicity (topo_order errs on cycles) + shape agreement.
        let order = self.topo_order()?;
        let mut shapes: BTreeMap<&str, TensorShape> =
            self.inputs.iter().map(|i| (i.name.as_str(), i.shape)).collect();
        for &ni in &order {
            let node = &self.nodes[ni];
            let got: Vec<TensorShape> =
                node.inputs.iter().map(|v| shapes[v.as_str()]).collect();
            check_node_shapes(node, &got)?;
            shapes.insert(node.name.as_str(), node.op.output_shape());
        }
        Ok(())
    }

    /// Deterministic topological order of node indices: Kahn's algorithm,
    /// always dispatching the ready node with the smallest insertion index.
    /// When insertion order is already topological (builder output, chain
    /// imports) this returns `0..n` exactly.
    pub fn topo_order(&self) -> Result<Vec<usize>, DagError> {
        let n = self.nodes.len();
        let producer: BTreeMap<&str, usize> = self
            .nodes
            .iter()
            .enumerate()
            .map(|(i, node)| (node.name.as_str(), i))
            .collect();
        // Pending dependency count per node; graph inputs are always ready.
        let mut pending: Vec<usize> = vec![0; n];
        for (i, node) in self.nodes.iter().enumerate() {
            pending[i] = node
                .inputs
                .iter()
                .filter(|v| producer.contains_key(v.as_str()))
                .count();
        }
        let mut done = vec![false; n];
        let mut order = Vec::with_capacity(n);
        for _ in 0..n {
            // O(n^2) min-scan: models are tens of nodes; determinism beats
            // asymptotics here.
            let Some(next) = (0..n).find(|&i| !done[i] && pending[i] == 0) else {
                let stuck = (0..n).find(|&i| !done[i]).unwrap();
                return Err(DagError::Cycle(self.nodes[stuck].name.clone()));
            };
            done[next] = true;
            order.push(next);
            let name = self.nodes[next].name.as_str();
            for (i, node) in self.nodes.iter().enumerate() {
                if !done[i] {
                    pending[i] -= node.inputs.iter().filter(|v| v == &name).count();
                }
            }
        }
        Ok(order)
    }

    /// Shape of every value (graph inputs + node outputs), for display and
    /// rewrite passes. Assumes a valid graph.
    pub fn value_shapes(&self) -> BTreeMap<String, TensorShape> {
        let mut shapes: BTreeMap<String, TensorShape> =
            self.inputs.iter().map(|i| (i.name.clone(), i.shape)).collect();
        for node in &self.nodes {
            shapes.insert(node.name.clone(), node.op.output_shape());
        }
        shapes
    }

    /// Number of consumers of a value (node fan-in references plus graph
    /// outputs naming it).
    pub fn consumer_count(&self, value: &str) -> usize {
        let from_nodes: usize = self
            .nodes
            .iter()
            .map(|n| n.inputs.iter().filter(|v| v.as_str() == value).count())
            .sum();
        from_nodes + self.outputs.iter().filter(|o| o.as_str() == value).count()
    }

    /// True when the graph is a single-input single-output chain: every
    /// topological boundary is crossed by exactly one live value. Such a
    /// graph lowers to the legacy range-based path bit-identically.
    pub fn is_linear(&self) -> bool {
        matches!(super::lower::legal_cuts(self), Ok(None))
    }

    /// Import a legacy linear [`Model`] as a chain DAG. Lowering the result
    /// reproduces `m` layer-for-layer (pinned in `tests/dag_parity.rs`).
    pub fn from_model(m: &Model) -> DagModel {
        let taken: Vec<&str> = m.layers.iter().map(|l| l.name.as_str()).collect();
        let mut input_name = String::from("input");
        let mut salt = 0usize;
        while taken.contains(&input_name.as_str()) {
            input_name = format!("input{salt}");
            salt += 1;
        }
        let mut nodes = Vec::with_capacity(m.layers.len());
        let mut prev = input_name.clone();
        for layer in &m.layers {
            let op = match layer.kind {
                LayerKind::Add { shape } => DagOp::Add { shape },
                LayerKind::Concat { shape } => DagOp::Concat { shape },
                other => DagOp::Layer(other),
            };
            nodes.push(DagNode { name: layer.name.clone(), op, inputs: vec![prev] });
            prev = layer.name.clone();
        }
        DagModel {
            name: m.name.clone(),
            inputs: vec![GraphInput { name: input_name, shape: m.input }],
            outputs: vec![prev],
            nodes,
        }
    }
}

/// Per-node arity + shape rules (the DAG analogue of `Model::validate`'s
/// flowing-shape check, including the FC flatten exception).
fn check_node_shapes(node: &DagNode, got: &[TensorShape]) -> Result<(), DagError> {
    let fmt_shape = |s: TensorShape| format!("{}x{}x{}", s.h, s.w, s.c);
    match node.op {
        DagOp::Layer(LayerKind::Add { .. }) => Err(DagError::BadArity {
            node: node.name.clone(),
            message: "elementwise add must use the dag 'add' op, not a unary layer".into(),
        }),
        DagOp::Layer(LayerKind::Concat { .. }) => Err(DagError::BadArity {
            node: node.name.clone(),
            message: "concat must use the dag 'concat' op, not a unary layer".into(),
        }),
        DagOp::Layer(kind) => {
            if got.len() != 1 {
                return Err(DagError::BadArity {
                    node: node.name.clone(),
                    message: format!("unary op takes 1 input, got {}", got.len()),
                });
            }
            let expect = Layer::new("", kind).input_shape();
            let flatten_ok = matches!(kind, LayerKind::Fc(f) if f.k == got[0].elems());
            if expect != got[0] && !flatten_ok {
                return Err(DagError::ShapeMismatch {
                    node: node.name.clone(),
                    message: format!("{}, got {}", fmt_shape(expect), fmt_shape(got[0])),
                });
            }
            Ok(())
        }
        DagOp::Add { shape } => {
            for s in got {
                if *s != shape {
                    return Err(DagError::ShapeMismatch {
                        node: node.name.clone(),
                        message: format!("{}, got {}", fmt_shape(shape), fmt_shape(*s)),
                    });
                }
            }
            Ok(())
        }
        DagOp::Concat { shape } => {
            let mut c_sum = 0usize;
            for s in got {
                if s.h != shape.h || s.w != shape.w {
                    return Err(DagError::ShapeMismatch {
                        node: node.name.clone(),
                        message: format!(
                            "spatial {}x{}, got {}x{}",
                            shape.h, shape.w, s.h, s.w
                        ),
                    });
                }
                c_sum += s.c;
            }
            if c_sum != shape.c {
                return Err(DagError::ShapeMismatch {
                    node: node.name.clone(),
                    message: format!("{} total channels, got {}", shape.c, c_sum),
                });
            }
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ConvSpec;
    use crate::zoo;

    fn diamond() -> DagModel {
        // input -> c1 -> {c2a, c2b} -> add -> relu
        let s = TensorShape::new(8, 8, 8);
        DagModel::new(
            "diamond",
            vec![GraphInput { name: "x".into(), shape: TensorShape::new(8, 8, 3) }],
            vec!["r".into()],
            vec![
                DagNode {
                    name: "c1".into(),
                    op: DagOp::Layer(LayerKind::Conv(ConvSpec::same(3, 8, 8, 3))),
                    inputs: vec!["x".into()],
                },
                DagNode {
                    name: "c2a".into(),
                    op: DagOp::Layer(LayerKind::Conv(ConvSpec::same(8, 8, 8, 3))),
                    inputs: vec!["c1".into()],
                },
                DagNode {
                    name: "c2b".into(),
                    op: DagOp::Layer(LayerKind::Conv(ConvSpec::same(8, 8, 8, 3))),
                    inputs: vec!["c1".into()],
                },
                DagNode {
                    name: "j".into(),
                    op: DagOp::Add { shape: s },
                    inputs: vec!["c2a".into(), "c2b".into()],
                },
                DagNode {
                    name: "r".into(),
                    op: DagOp::Layer(LayerKind::ReLU { shape: s }),
                    inputs: vec!["j".into()],
                },
            ],
        )
        .unwrap()
    }

    #[test]
    fn diamond_validates_and_orders() {
        let d = diamond();
        assert_eq!(d.topo_order().unwrap(), vec![0, 1, 2, 3, 4]);
        assert!(!d.is_linear());
    }

    #[test]
    fn duplicate_name_rejected() {
        let mut d = diamond();
        d.nodes[2].name = "c2a".into();
        assert!(matches!(d.validate(), Err(DagError::DuplicateName(n)) if n == "c2a"));
    }

    #[test]
    fn dangling_reference_rejected() {
        let mut d = diamond();
        d.nodes[4].inputs = vec!["ghost".into()];
        assert!(matches!(
            d.validate(),
            Err(DagError::DanglingReference { value, .. }) if value == "ghost"
        ));
    }

    #[test]
    fn cycle_rejected() {
        let mut d = diamond();
        d.nodes[1].inputs = vec!["r".into()];
        assert!(matches!(d.validate(), Err(DagError::Cycle(_))));
    }

    #[test]
    fn join_shape_mismatch_rejected() {
        let mut d = diamond();
        d.nodes[3].op = DagOp::Add { shape: TensorShape::new(4, 4, 8) };
        assert!(matches!(d.validate(), Err(DagError::ShapeMismatch { .. })));
    }

    #[test]
    fn unary_layer_add_rejected() {
        let mut d = diamond();
        d.nodes[4].op = DagOp::Layer(LayerKind::Add { shape: TensorShape::new(8, 8, 8) });
        assert!(matches!(d.validate(), Err(DagError::BadArity { .. })));
    }

    #[test]
    fn unknown_output_rejected() {
        let mut d = diamond();
        d.outputs = vec!["nope".into()];
        assert!(matches!(d.validate(), Err(DagError::UnknownOutput(_))));
    }

    #[test]
    fn chain_import_is_linear() {
        for m in zoo::all_models() {
            let d = DagModel::from_model(&m);
            d.validate().unwrap_or_else(|e| panic!("{}: {e}", m.name));
            assert!(d.is_linear(), "{} should import as a linear chain", m.name);
            assert_eq!(d.topo_order().unwrap(), (0..m.num_layers()).collect::<Vec<_>>());
        }
    }

    #[test]
    fn topo_order_handles_out_of_order_insertion() {
        let mut d = diamond();
        d.nodes.swap(1, 3); // join now inserted before its producers
        d.validate().unwrap();
        assert_eq!(d.topo_order().unwrap(), vec![0, 2, 3, 1, 4]);
    }
}
