//! Fluent construction of [`DagModel`]s with explicit value handles.
//!
//! The linear zoo uses `NetBuilder`, whose implicit "current tensor" cannot
//! express branches. `DagBuilder` returns a [`ValueRef`] from every op;
//! branching is just using the same handle twice:
//!
//! ```
//! use dlfusion::graph::dag::DagBuilder;
//!
//! let mut b = DagBuilder::new("residual");
//! let x = b.input("image", 56, 56, 64);
//! let y = b.conv_bn_relu(&x, 64, 3, 1, 1, 1);
//! let y = b.conv(&y, 64, 3, 1, 1, 1);
//! let y = b.bn(&y);
//! let j = b.add(&[&x, &y]);
//! let j = b.relu(&j);
//! b.output(&j);
//! let dag = b.build();
//! assert!(!dag.is_linear());
//! ```

use super::model::{DagModel, DagNode, DagOp, GraphInput};
use crate::graph::{ConvSpec, FcSpec, LayerKind, TensorShape};

/// Handle to a value in the graph under construction: its name plus the
/// shape it will have, so downstream ops can size themselves.
#[derive(Debug, Clone)]
pub struct ValueRef {
    name: String,
    shape: TensorShape,
}

impl ValueRef {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn shape(&self) -> TensorShape {
        self.shape
    }
}

/// Builder for [`DagModel`]. Ops are named `conv1`, `bn2`, ... from a
/// shared counter, the same scheme as the linear zoo builder.
#[derive(Debug)]
pub struct DagBuilder {
    name: String,
    inputs: Vec<GraphInput>,
    outputs: Vec<String>,
    nodes: Vec<DagNode>,
    counter: usize,
}

impl DagBuilder {
    pub fn new(name: impl Into<String>) -> Self {
        DagBuilder {
            name: name.into(),
            inputs: Vec::new(),
            outputs: Vec::new(),
            nodes: Vec::new(),
            counter: 0,
        }
    }

    fn next_name(&mut self, prefix: &str) -> String {
        self.counter += 1;
        format!("{prefix}{}", self.counter)
    }

    fn push(&mut self, prefix: &str, op: DagOp, inputs: Vec<&ValueRef>) -> ValueRef {
        let name = self.next_name(prefix);
        let shape = op.output_shape();
        self.nodes.push(DagNode {
            name: name.clone(),
            op,
            inputs: inputs.iter().map(|v| v.name.clone()).collect(),
        });
        ValueRef { name, shape }
    }

    /// Declare a named graph input.
    pub fn input(&mut self, name: impl Into<String>, h: usize, w: usize, c: usize) -> ValueRef {
        let name = name.into();
        let shape = TensorShape::new(h, w, c);
        self.inputs.push(GraphInput { name: name.clone(), shape });
        ValueRef { name, shape }
    }

    pub fn conv(
        &mut self,
        from: &ValueRef,
        c_out: usize,
        k: usize,
        stride: usize,
        pad: usize,
        groups: usize,
    ) -> ValueRef {
        let s = from.shape;
        let spec = ConvSpec {
            c_in: s.c,
            c_out,
            h_in: s.h,
            w_in: s.w,
            k,
            stride,
            pad,
            groups,
        };
        self.push("conv", DagOp::Layer(LayerKind::Conv(spec)), vec![from])
    }

    pub fn bn(&mut self, from: &ValueRef) -> ValueRef {
        self.push("bn", DagOp::Layer(LayerKind::BatchNorm { shape: from.shape }), vec![from])
    }

    pub fn relu(&mut self, from: &ValueRef) -> ValueRef {
        self.push("relu", DagOp::Layer(LayerKind::ReLU { shape: from.shape }), vec![from])
    }

    pub fn conv_bn_relu(
        &mut self,
        from: &ValueRef,
        c_out: usize,
        k: usize,
        stride: usize,
        pad: usize,
        groups: usize,
    ) -> ValueRef {
        let c = self.conv(from, c_out, k, stride, pad, groups);
        let b = self.bn(&c);
        self.relu(&b)
    }

    pub fn pool(&mut self, from: &ValueRef, k: usize, stride: usize) -> ValueRef {
        let op = DagOp::Layer(LayerKind::Pool { shape: from.shape, k, stride });
        self.push("pool", op, vec![from])
    }

    /// Global average pool: kernel = the full spatial extent.
    pub fn global_pool(&mut self, from: &ValueRef) -> ValueRef {
        let k = from.shape.h;
        self.pool(from, k, k.max(1))
    }

    pub fn fc(&mut self, from: &ValueRef, n: usize) -> ValueRef {
        let spec = FcSpec { k: from.shape.elems(), n };
        self.push("fc", DagOp::Layer(LayerKind::Fc(spec)), vec![from])
    }

    /// Elementwise sum join. All inputs must share a shape.
    pub fn add(&mut self, from: &[&ValueRef]) -> ValueRef {
        let shape = from[0].shape;
        self.push("add", DagOp::Add { shape }, from.to_vec())
    }

    /// Channel-concatenation join. Inputs share spatial dims; channels sum.
    pub fn concat(&mut self, from: &[&ValueRef]) -> ValueRef {
        let first = from[0].shape;
        let c: usize = from.iter().map(|v| v.shape.c).sum();
        let shape = TensorShape::new(first.h, first.w, c);
        self.push("concat", DagOp::Concat { shape }, from.to_vec())
    }

    /// Mark a value as a graph output.
    pub fn output(&mut self, v: &ValueRef) {
        self.outputs.push(v.name.clone());
    }

    /// Validate and finish. Panics on an invalid graph — builder misuse is
    /// a programming error, matching the linear zoo builder's contract.
    pub fn build(self) -> DagModel {
        DagModel::new(self.name, self.inputs, self.outputs, self.nodes)
            .unwrap_or_else(|e| panic!("dag builder produced invalid model: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_names_follow_shared_counter() {
        let mut b = DagBuilder::new("t");
        let x = b.input("x", 8, 8, 3);
        let c = b.conv(&x, 8, 3, 1, 1, 1);
        let r = b.relu(&c);
        b.output(&r);
        let d = b.build();
        assert_eq!(d.nodes[0].name, "conv1");
        assert_eq!(d.nodes[1].name, "relu2");
        assert!(d.is_linear());
    }

    #[test]
    fn branch_and_join() {
        let mut b = DagBuilder::new("t");
        let x = b.input("x", 8, 8, 8);
        let a = b.conv(&x, 8, 3, 1, 1, 1);
        let j = b.add(&[&x, &a]);
        let cat = b.concat(&[&j, &a]);
        b.output(&cat);
        let d = b.build();
        assert_eq!(cat.shape(), TensorShape::new(8, 8, 16));
        assert!(!d.is_linear());
        assert_eq!(d.consumer_count("conv1"), 2);
    }

    #[test]
    #[should_panic(expected = "invalid model")]
    fn build_panics_on_shape_break() {
        let mut b = DagBuilder::new("t");
        let x = b.input("x", 8, 8, 3);
        let a = b.conv(&x, 8, 3, 1, 1, 1);
        let y = b.input("y", 4, 4, 8);
        let j = b.add(&[&a, &y]);
        b.output(&j);
        b.build();
    }
}
