//! Lowering the DAG onto the range-based scheduling stack.
//!
//! The cost engine, Algorithm 1, the oracle DP, the annealer, and the
//! exhaustive search all partition a *linear* layer sequence into `(start,
//! end)` blocks. The DAG joins them through two artifacts:
//!
//! 1. a **linearization** — the nodes in deterministic topological order,
//!    lowered to legacy [`Layer`]s (an `Add` join becomes `LayerKind::Add`
//!    at the join's output shape — identical elementwise GOPs, zero
//!    weights, zero halo, the same approximation the faked-sequential zoo
//!    chains always made — and a `Concat` join becomes
//!    `LayerKind::Concat`, costed as pure data movement: zero MACs under
//!    Eq. 1); and
//! 2. the **fusion-legal cut set** — a boundary in that order is a legal
//!    block edge iff exactly **one** live value crosses it. A fusion block
//!    hands exactly one tensor to its successor (the Fig. 7 pipeline), so a
//!    residual skip that is still live mid-block makes every interior
//!    boundary of that block illegal.
//!
//! A pure chain has exactly one live value at every boundary, so its cut
//! set is `None` ("everything legal") and the tuner stack runs its
//! untouched, bit-identical legacy path — the parity discipline pinned in
//! `tests/dag_parity.rs`.
//!
//! Note the lowered `Model` of a *branching* DAG is not a flowing-shape
//! chain (a skip consumer reads an earlier value), so `Model::validate`
//! would reject it. That is fine: the cost stack only reads per-layer
//! shapes from `layers[i..j]` slices and never re-validates.

use std::collections::BTreeMap;

use super::model::{DagError, DagModel, DagOp};
use crate::graph::{Layer, LayerKind, Model};

/// A DAG lowered for the range-based tuner stack.
#[derive(Debug, Clone, PartialEq)]
pub struct Linearization {
    /// Nodes in topological order as legacy layers.
    pub model: Model,
    /// Ascending fusion-legal cut positions in `0..=n` (0 and `n` always
    /// present), or `None` when every boundary is legal — i.e. the DAG is a
    /// pure chain and the unconstrained legacy path applies.
    pub cuts: Option<Vec<usize>>,
}

/// Lower a validated DAG: topological order + legal cut set.
pub fn linearize(d: &DagModel) -> Result<Linearization, DagError> {
    let order = d.topo_order()?;
    let layers: Vec<Layer> = order
        .iter()
        .map(|&ni| {
            let node = &d.nodes[ni];
            let kind = match node.op {
                DagOp::Layer(kind) => kind,
                DagOp::Add { shape } => LayerKind::Add { shape },
                DagOp::Concat { shape } => LayerKind::Concat { shape },
            };
            Layer::new(node.name.clone(), kind)
        })
        .collect();
    let model = Model::new(d.name.clone(), d.inputs[0].shape, layers);
    Ok(Linearization { model, cuts: legal_cuts(d)? })
}

/// The fusion-legal cut positions of `d`'s deterministic linearization, or
/// `None` when every boundary is legal (see the module docs).
pub fn legal_cuts(d: &DagModel) -> Result<Option<Vec<usize>>, DagError> {
    let order = d.topo_order()?;
    let n = order.len();
    // Topological position of each node, by name.
    let pos: BTreeMap<&str, usize> = order
        .iter()
        .enumerate()
        .map(|(p, &ni)| (d.nodes[ni].name.as_str(), p))
        .collect();
    // For every value: position after which it exists (graph inputs exist
    // from the start) and last position that needs it (graph outputs stay
    // live to the end).
    let mut produced_before: BTreeMap<&str, usize> =
        d.inputs.iter().map(|i| (i.name.as_str(), 0)).collect();
    let mut live_until: BTreeMap<&str, usize> = BTreeMap::new();
    for (&name, &p) in &pos {
        produced_before.insert(name, p + 1);
    }
    for &ni in &order {
        let node = &d.nodes[ni];
        let p = pos[node.name.as_str()];
        for v in &node.inputs {
            let e = live_until.entry(v.as_str()).or_insert(p);
            *e = (*e).max(p);
        }
    }
    for out in &d.outputs {
        live_until.insert(out.as_str(), n);
    }
    // A boundary p is legal iff exactly one value crosses it: produced at a
    // position < p, still needed at a position >= p.
    let crossing = |p: usize| {
        live_until
            .iter()
            .filter(|(v, &until)| produced_before[*v] <= p && until >= p)
            .count()
    };
    let legal: Vec<usize> = (1..n).filter(|&p| crossing(p) == 1).collect();
    if legal.len() == n.saturating_sub(1) {
        return Ok(None);
    }
    let mut cuts = Vec::with_capacity(legal.len() + 2);
    cuts.push(0);
    cuts.extend(legal);
    cuts.push(n);
    Ok(Some(cuts))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dag::{DagNode, GraphInput};
    use crate::graph::{ConvSpec, TensorShape};
    use crate::zoo;

    #[test]
    fn chain_lowering_reproduces_model_with_no_cut_constraint() {
        for m in zoo::all_models() {
            let lin = linearize(&DagModel::from_model(&m)).unwrap();
            assert_eq!(lin.model, m, "{} chain roundtrip", m.name);
            assert_eq!(lin.cuts, None, "{} should have no cut constraint", m.name);
        }
    }

    #[test]
    fn residual_block_interior_cuts_are_illegal() {
        // x -> c1 -> c2 -> j(add c1, c2) -> r. The skip from c1 keeps two
        // values live across the c2|j boundary.
        let s = TensorShape::new(8, 8, 8);
        let d = DagModel::new(
            "res",
            vec![GraphInput { name: "x".into(), shape: TensorShape::new(8, 8, 3) }],
            vec!["r".into()],
            vec![
                DagNode {
                    name: "c1".into(),
                    op: DagOp::Layer(LayerKind::Conv(ConvSpec::same(3, 8, 8, 3))),
                    inputs: vec!["x".into()],
                },
                DagNode {
                    name: "c2".into(),
                    op: DagOp::Layer(LayerKind::Conv(ConvSpec::same(8, 8, 8, 3))),
                    inputs: vec!["c1".into()],
                },
                DagNode {
                    name: "j".into(),
                    op: DagOp::Add { shape: s },
                    inputs: vec!["c1".into(), "c2".into()],
                },
                DagNode {
                    name: "r".into(),
                    op: DagOp::Layer(LayerKind::ReLU { shape: s }),
                    inputs: vec!["j".into()],
                },
            ],
        )
        .unwrap();
        let lin = linearize(&d).unwrap();
        // c1|c2 is legal (one value crosses even though it has two
        // consumers); c2|j is not (skip + main path both live).
        assert_eq!(lin.cuts, Some(vec![0, 1, 3, 4]));
        assert_eq!(lin.model.num_layers(), 4);
        // The join lowers to an Add layer at the join's output shape.
        assert_eq!(lin.model.layers[2].kind, LayerKind::Add { shape: s });
    }

    #[test]
    fn concat_lowers_to_concat_at_output_shape() {
        let d = DagModel::new(
            "cat",
            vec![GraphInput { name: "x".into(), shape: TensorShape::new(8, 8, 4) }],
            vec!["cat".into()],
            vec![
                DagNode {
                    name: "a".into(),
                    op: DagOp::Layer(LayerKind::Conv(ConvSpec::same(4, 8, 8, 3))),
                    inputs: vec!["x".into()],
                },
                DagNode {
                    name: "b".into(),
                    op: DagOp::Layer(LayerKind::Conv(ConvSpec::same(4, 8, 8, 3))),
                    inputs: vec!["x".into()],
                },
                DagNode {
                    name: "cat".into(),
                    op: DagOp::Concat { shape: TensorShape::new(8, 8, 16) },
                    inputs: vec!["a".into(), "b".into()],
                },
            ],
        )
        .unwrap();
        let lin = linearize(&d).unwrap();
        assert_eq!(
            lin.model.layers[2].kind,
            LayerKind::Concat { shape: TensorShape::new(8, 8, 16) }
        );
        // Concat is pure data movement: the lowered layer costs zero GOPs.
        assert_eq!(lin.model.layers[2].op_gops(), 0.0);
        // Both interior boundaries carry two live values (x + a, then a + b).
        assert_eq!(lin.cuts, Some(vec![0, 3]));
    }
}
