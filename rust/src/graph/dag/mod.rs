//! True DAG graph IR: nodes, named value edges, subgraph fusion legality,
//! declarative rewrites, and the `.dlm` v2 interchange format.
//!
//! Layout (DESIGN.md §13):
//! - [`model`] — [`DagModel`]/[`DagNode`]/[`DagOp`]: the validated IR.
//! - [`builder`] — [`DagBuilder`]: fluent construction with value handles.
//! - [`lower`] — [`linearize`]: topological order + fusion-legal cut set,
//!   the bridge onto the range-based `CostEngine`/`Tuner` stack.
//! - [`rewrite`] — [`DagPatch`] and the built-in legalization passes.
//! - [`format`] — `.dlm` v2 parse/serialize and the [`load_dlm`] version
//!   dispatcher.

pub mod builder;
pub mod format;
pub mod lower;
pub mod model;
pub mod rewrite;

pub use builder::{DagBuilder, ValueRef};
pub use format::{load_dlm, to_dlm_v2, LoadedModel};
pub use lower::{legal_cuts, linearize, Linearization};
pub use model::{DagError, DagModel, DagNode, DagOp, GraphInput};
pub use rewrite::{
    canonicalize_residual_joins, eliminate_dead_nodes, fold_inert_ops, legalize, DagPatch,
};
