//! `.dlm` version 2: the DAG interchange grammar, plus the version
//! dispatcher [`load_dlm`] that accepts both format versions.
//!
//! v2 extends v1 with named dataflow: a `version: 2` marker, named graph
//! `inputs`/`outputs`, and a per-layer `inputs` list of value names (a
//! layer's output value is named after the layer). The per-layer op fields
//! are exactly the v1 grammar, plus the v2-only multi-input ops `add` and
//! `concat`. v1 documents (no `version` field) parse unchanged through the
//! original linear path.
//!
//! Example:
//! ```json
//! {
//!   "name": "residual",
//!   "version": 2,
//!   "inputs": [{"name": "image", "shape": [56, 56, 64]}],
//!   "outputs": ["relu2"],
//!   "layers": [
//!     {"name": "conv1", "op": "conv", "inputs": ["image"], "c_in": 64,
//!      "c_out": 64, "h_in": 56, "w_in": 56, "k": 3, "stride": 1,
//!      "pad": 1, "groups": 1},
//!     {"name": "add1", "op": "add", "inputs": ["image", "conv1"],
//!      "shape": [56, 56, 64]},
//!     {"name": "relu2", "op": "relu", "inputs": ["add1"],
//!      "shape": [56, 56, 64]}
//!   ]
//! }
//! ```

use super::model::{DagModel, DagNode, DagOp, GraphInput};
use crate::graph::format::{
    self, layer_from_json, layer_to_json, shape_from_json, shape_to_json, DlmError,
};
use crate::graph::{Layer, Model};
use crate::util::json::Json;

/// A parsed `.dlm` document of either version.
#[derive(Debug, Clone, PartialEq)]
pub enum LoadedModel {
    /// v1: a linear layer chain.
    Linear(Model),
    /// v2: a dag (which may still happen to be a pure chain).
    Dag(DagModel),
}

impl LoadedModel {
    pub fn name(&self) -> &str {
        match self {
            LoadedModel::Linear(m) => &m.name,
            LoadedModel::Dag(d) => &d.name,
        }
    }
}

/// Parse `.dlm` text of either version, dispatching on the `version` field
/// (absent means 1).
pub fn load_dlm(text: &str) -> Result<LoadedModel, DlmError> {
    let v = Json::parse(text).map_err(DlmError::Json)?;
    match format::document_version(&v)? {
        1 => format::model_from_json(&v).map(LoadedModel::Linear),
        2 => dag_from_json(&v).map(LoadedModel::Dag),
        other => Err(DlmError::UnsupportedVersion(other)),
    }
}

/// Serialize a DAG to `.dlm` v2 JSON text (pretty-printed).
pub fn to_dlm_v2(d: &DagModel) -> String {
    let inputs: Vec<Json> = d
        .inputs
        .iter()
        .map(|i| {
            Json::obj(vec![
                ("name", Json::Str(i.name.clone())),
                ("shape", shape_to_json(i.shape)),
            ])
        })
        .collect();
    let outputs: Vec<Json> = d.outputs.iter().map(|o| Json::Str(o.clone())).collect();
    let layers: Vec<Json> = d.nodes.iter().map(node_to_json).collect();
    Json::obj(vec![
        ("name", Json::Str(d.name.clone())),
        ("version", Json::Num(2.0)),
        ("inputs", Json::Arr(inputs)),
        ("outputs", Json::Arr(outputs)),
        ("layers", Json::Arr(layers)),
    ])
    .to_pretty()
}

fn node_to_json(n: &DagNode) -> Json {
    let mut obj = match n.op {
        // Unary ops reuse the v1 field grammar verbatim.
        DagOp::Layer(kind) => layer_to_json(&Layer::new(n.name.clone(), kind)),
        DagOp::Add { shape } => Json::obj(vec![
            ("name", Json::Str(n.name.clone())),
            ("op", Json::Str("add".into())),
            ("shape", shape_to_json(shape)),
        ]),
        DagOp::Concat { shape } => Json::obj(vec![
            ("name", Json::Str(n.name.clone())),
            ("op", Json::Str("concat".into())),
            ("shape", shape_to_json(shape)),
        ]),
    };
    if let Json::Obj(map) = &mut obj {
        let vals = n.inputs.iter().map(|v| Json::Str(v.clone())).collect();
        map.insert("inputs".into(), Json::Arr(vals));
    }
    obj
}

fn dag_from_json(v: &Json) -> Result<DagModel, DlmError> {
    let name = v
        .get("name")
        .as_str()
        .ok_or_else(|| DlmError::Document("missing model 'name'".into()))?
        .to_string();
    let inputs_json = v
        .get("inputs")
        .as_arr()
        .ok_or_else(|| DlmError::Document("missing 'inputs' array".into()))?;
    let mut inputs = Vec::with_capacity(inputs_json.len());
    for (i, ij) in inputs_json.iter().enumerate() {
        let iname = ij
            .get("name")
            .as_str()
            .ok_or_else(|| DlmError::Document(format!("input {i}: missing 'name'")))?
            .to_string();
        let shape = shape_from_json(ij.get("shape"))
            .ok_or_else(|| DlmError::Document(format!("input {i}: bad 'shape'")))?;
        inputs.push(GraphInput { name: iname, shape });
    }
    let outputs_json = v
        .get("outputs")
        .as_arr()
        .ok_or_else(|| DlmError::Document("missing 'outputs' array".into()))?;
    let mut outputs = Vec::with_capacity(outputs_json.len());
    for (i, oj) in outputs_json.iter().enumerate() {
        let o = oj
            .as_str()
            .ok_or_else(|| DlmError::Document(format!("output {i}: not a value name")))?;
        outputs.push(o.to_string());
    }
    let layers_json = v
        .get("layers")
        .as_arr()
        .ok_or_else(|| DlmError::Document("missing 'layers' array".into()))?;
    let mut nodes = Vec::with_capacity(layers_json.len());
    for (i, lj) in layers_json.iter().enumerate() {
        let node =
            node_from_json(lj).map_err(|message| DlmError::Layer { index: i, message })?;
        nodes.push(node);
    }
    // DagModel::new validates: unique names, dangling references, cycles,
    // shape agreement — all surfaced as structured DlmErrors.
    DagModel::new(name, inputs, outputs, nodes).map_err(DlmError::from)
}

fn node_from_json(v: &Json) -> Result<DagNode, String> {
    let name = v.get("name").as_str().ok_or("missing 'name'")?.to_string();
    let inputs_json = v.get("inputs").as_arr().ok_or("missing 'inputs' array")?;
    let mut inputs = Vec::with_capacity(inputs_json.len());
    for x in inputs_json {
        inputs.push(x.as_str().ok_or("bad value name in 'inputs'")?.to_string());
    }
    let op_tag = v.get("op").as_str().ok_or("missing 'op'")?;
    let op = match op_tag {
        "add" => DagOp::Add {
            shape: shape_from_json(v.get("shape")).ok_or("bad 'shape'")?,
        },
        "concat" => DagOp::Concat {
            shape: shape_from_json(v.get("shape")).ok_or("bad 'shape'")?,
        },
        _ => DagOp::Layer(layer_from_json(v)?.kind),
    };
    Ok(DagNode { name, op, inputs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::format::{from_dlm, to_dlm};
    use crate::zoo;

    #[test]
    fn v1_documents_dispatch_to_the_linear_path() {
        for m in zoo::all_models() {
            let text = to_dlm(&m);
            match load_dlm(&text).expect(&m.name) {
                LoadedModel::Linear(back) => assert_eq!(back, m),
                LoadedModel::Dag(_) => panic!("{} is a v1 document", m.name),
            }
            // And the v1-only entry agrees.
            assert_eq!(from_dlm(&text).unwrap(), m);
        }
    }

    #[test]
    fn v2_roundtrip_is_identity() {
        for d in [zoo::resnet18_dag(), zoo::resnet50_dag()] {
            let text = to_dlm_v2(&d);
            match load_dlm(&text).expect(&d.name) {
                LoadedModel::Dag(back) => assert_eq!(back, d, "roundtrip {}", d.name),
                LoadedModel::Linear(_) => panic!("{} is a v2 document", d.name),
            }
        }
    }

    #[test]
    fn v2_rejects_duplicate_layer_names() {
        let text = r#"{"name":"g","version":2,
            "inputs":[{"name":"x","shape":[4,4,2]}],
            "outputs":["r"],
            "layers":[
              {"name":"r","op":"relu","inputs":["x"],"shape":[4,4,2]},
              {"name":"r","op":"relu","inputs":["x"],"shape":[4,4,2]}]}"#;
        assert_eq!(load_dlm(text).unwrap_err(), DlmError::DuplicateLayerName("r".into()));
    }

    #[test]
    fn v2_rejects_dangling_references() {
        let text = r#"{"name":"g","version":2,
            "inputs":[{"name":"x","shape":[4,4,2]}],
            "outputs":["r"],
            "layers":[
              {"name":"r","op":"relu","inputs":["ghost"],"shape":[4,4,2]}]}"#;
        let err = load_dlm(text).unwrap_err();
        assert_eq!(
            err,
            DlmError::DanglingReference { layer: "r".into(), value: "ghost".into() }
        );
        assert!(err.to_string().contains("dangling reference"), "{err}");
    }

    #[test]
    fn v2_rejects_unknown_op_via_v1_grammar() {
        let text = r#"{"name":"g","version":2,
            "inputs":[{"name":"x","shape":[4,4,2]}],
            "outputs":["y"],
            "layers":[
              {"name":"y","op":"softmax9000","inputs":["x"]}]}"#;
        let err = load_dlm(text).unwrap_err().to_string();
        assert!(err.contains("unknown op"), "{err}");
    }

    #[test]
    fn v2_node_without_inputs_is_rejected() {
        let text = r#"{"name":"g","version":2,
            "inputs":[{"name":"x","shape":[4,4,2]}],
            "outputs":["y"],
            "layers":[{"name":"y","op":"relu","shape":[4,4,2]}]}"#;
        let err = load_dlm(text).unwrap_err();
        assert!(matches!(err, DlmError::Layer { index: 0, .. }), "{err}");
    }

    #[test]
    fn unsupported_version_is_rejected() {
        let text = r#"{"name":"g","version":7,"layers":[]}"#;
        assert_eq!(load_dlm(text).unwrap_err(), DlmError::UnsupportedVersion(7));
    }
}
