//! Declarative graph rewrites: match, build a [`DagPatch`], apply.
//!
//! Rewrites are two-phase in the `ModelPatch` spirit: a *matcher* walks an
//! immutable [`DagModel`] and records edits into a patch; [`DagPatch::apply`]
//! then produces a new, re-validated model. Nothing mutates in place, a
//! patch is inspectable before it runs, and an empty patch means "nothing
//! matched" — which is how the fixpoint driver [`legalize`] terminates.
//!
//! Rewrites are always explicit passes. Import (`.dlm`) and chain
//! conversion never run them implicitly: a legacy chain must lower back
//! bit-identically, and e.g. [`canonicalize_residual_joins`] would fold the
//! single-input `Add` layers such a chain contains.

use super::model::{DagModel, DagNode, DagOp};
use crate::graph::LayerKind;

/// One edit recorded by a matcher.
#[derive(Debug, Clone, PartialEq)]
enum DagEdit {
    /// Remove `node`, rewiring every consumer of its value (and any graph
    /// output naming it) to the value `to`.
    Bypass { node: String, to: String },
    /// Delete `node`; it must have no consumers left when applied.
    Delete { node: String },
    /// Replace `node`'s op and inputs in place.
    Retype { node: String, op: DagOp, inputs: Vec<String> },
}

/// An ordered batch of edits against a [`DagModel`].
#[derive(Debug, Clone, PartialEq)]
pub struct DagPatch {
    description: String,
    edits: Vec<DagEdit>,
}

impl DagPatch {
    pub fn new(description: impl Into<String>) -> Self {
        DagPatch { description: description.into(), edits: Vec::new() }
    }

    pub fn description(&self) -> &str {
        &self.description
    }

    pub fn is_empty(&self) -> bool {
        self.edits.is_empty()
    }

    pub fn len(&self) -> usize {
        self.edits.len()
    }

    /// Record: remove `node` and route its consumers to `to`.
    pub fn bypass(&mut self, node: impl Into<String>, to: impl Into<String>) -> &mut Self {
        self.edits.push(DagEdit::Bypass { node: node.into(), to: to.into() });
        self
    }

    /// Record: delete the consumer-less `node`.
    pub fn delete(&mut self, node: impl Into<String>) -> &mut Self {
        self.edits.push(DagEdit::Delete { node: node.into() });
        self
    }

    /// Record: replace `node`'s op and inputs.
    pub fn retype(
        &mut self,
        node: impl Into<String>,
        op: DagOp,
        inputs: Vec<String>,
    ) -> &mut Self {
        self.edits.push(DagEdit::Retype { node: node.into(), op, inputs });
        self
    }

    /// Apply the edits in order and re-validate. The input model is
    /// untouched; errors leave no partial state behind.
    pub fn apply(&self, m: &DagModel) -> Result<DagModel, String> {
        let mut out = m.clone();
        for edit in &self.edits {
            match edit {
                DagEdit::Bypass { node, to } => {
                    let idx = find_node(&out, node)
                        .ok_or_else(|| format!("patch bypasses unknown node '{node}'"))?;
                    let known = out.inputs.iter().any(|i| &i.name == to)
                        || out.nodes.iter().any(|n| &n.name == to);
                    if !known {
                        return Err(format!(
                            "patch bypasses '{node}' to unknown value '{to}'"
                        ));
                    }
                    out.nodes.remove(idx);
                    for n in &mut out.nodes {
                        for v in &mut n.inputs {
                            if v == node {
                                *v = to.clone();
                            }
                        }
                    }
                    for o in &mut out.outputs {
                        if o == node {
                            *o = to.clone();
                        }
                    }
                }
                DagEdit::Delete { node } => {
                    let idx = find_node(&out, node)
                        .ok_or_else(|| format!("patch deletes unknown node '{node}'"))?;
                    if out.consumer_count(node) != 0 {
                        return Err(format!(
                            "patch deletes '{node}', which still has consumers"
                        ));
                    }
                    out.nodes.remove(idx);
                }
                DagEdit::Retype { node, op, inputs } => {
                    let idx = find_node(&out, node)
                        .ok_or_else(|| format!("patch retypes unknown node '{node}'"))?;
                    out.nodes[idx].op = *op;
                    out.nodes[idx].inputs = inputs.clone();
                }
            }
        }
        out.validate().map_err(|e| format!("patch '{}': {e}", self.description))?;
        Ok(out)
    }
}

fn find_node(m: &DagModel, name: &str) -> Option<usize> {
    m.nodes.iter().position(|n| n.name == name)
}

/// Match ops that compute the identity: `Pool` with `k == 1, stride == 1`
/// (a 1x1 window moves nothing) and single-input `Concat`.
pub fn fold_inert_ops(m: &DagModel) -> DagPatch {
    let mut p = DagPatch::new("fold inert ops");
    for node in &m.nodes {
        let inert = match node.op {
            DagOp::Layer(LayerKind::Pool { k: 1, stride: 1, .. }) => true,
            DagOp::Concat { .. } => node.inputs.len() == 1,
            _ => false,
        };
        if inert {
            p.bypass(node.name.clone(), node.inputs[0].clone());
        }
    }
    p
}

/// Match degenerate residual joins: an `Add` with a single input sums one
/// tensor, i.e. the identity. Chain imports of legacy models contain one
/// per faked residual — this pass is how such a chain is *explicitly*
/// promoted to canonical DAG form.
pub fn canonicalize_residual_joins(m: &DagModel) -> DagPatch {
    let mut p = DagPatch::new("canonicalize residual joins");
    for node in &m.nodes {
        if matches!(node.op, DagOp::Add { .. }) && node.inputs.len() == 1 {
            p.bypass(node.name.clone(), node.inputs[0].clone());
        }
    }
    p
}

/// Match nodes whose value nobody consumes and no graph output names.
pub fn eliminate_dead_nodes(m: &DagModel) -> DagPatch {
    let mut p = DagPatch::new("eliminate dead nodes");
    for node in &m.nodes {
        if m.consumer_count(&node.name) == 0 {
            p.delete(node.name.clone());
        }
    }
    p
}

/// Run the built-in legalization passes to fixpoint. Returns the legalized
/// model plus a log line per applied (non-empty) patch.
pub fn legalize(m: &DagModel) -> Result<(DagModel, Vec<String>), String> {
    let passes: &[fn(&DagModel) -> DagPatch] =
        &[fold_inert_ops, canonicalize_residual_joins, eliminate_dead_nodes];
    let mut cur = m.clone();
    let mut log = Vec::new();
    for _round in 0..64 {
        let mut changed = false;
        for pass in passes {
            let patch = pass(&cur);
            if !patch.is_empty() {
                log.push(format!("{} ({} edits)", patch.description(), patch.len()));
                cur = patch.apply(&cur)?;
                changed = true;
            }
        }
        if !changed {
            return Ok((cur, log));
        }
    }
    Err("legalize did not converge in 64 rounds".into())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dag::DagBuilder;
    use crate::graph::dag::DagModel;
    use crate::graph::TensorShape;
    use crate::zoo;

    #[test]
    fn folds_inert_pool() {
        let mut b = DagBuilder::new("t");
        let x = b.input("x", 8, 8, 3);
        let c = b.conv(&x, 8, 3, 1, 1, 1);
        let p = b.pool(&c, 1, 1);
        let r = b.relu(&p);
        b.output(&r);
        let d = b.build();
        let patch = fold_inert_ops(&d);
        assert_eq!(patch.len(), 1);
        let out = patch.apply(&d).unwrap();
        assert_eq!(out.num_nodes(), 2);
        // The relu now reads the conv directly.
        assert_eq!(out.nodes[1].inputs, vec!["conv1".to_string()]);
    }

    #[test]
    fn canonicalizes_imported_chain_joins() {
        // Legacy resnet18 fakes residuals as single-input Add layers; the
        // pass removes every one of them, explicitly.
        let m = zoo::resnet18();
        let adds = m
            .layers
            .iter()
            .filter(|l| matches!(l.kind, crate::graph::LayerKind::Add { .. }))
            .count();
        assert!(adds > 0);
        let d = DagModel::from_model(&m);
        let (out, log) = legalize(&d).unwrap();
        assert_eq!(out.num_nodes(), m.num_layers() - adds);
        assert!(!log.is_empty());
        out.validate().unwrap();
    }

    #[test]
    fn real_joins_survive_legalization() {
        let mut b = DagBuilder::new("t");
        let x = b.input("x", 8, 8, 8);
        let c = b.conv(&x, 8, 3, 1, 1, 1);
        let j = b.add(&[&x, &c]);
        b.output(&j);
        let d = b.build();
        let (out, log) = legalize(&d).unwrap();
        assert_eq!(out, d);
        assert!(log.is_empty());
    }

    #[test]
    fn deletes_dead_branch() {
        let mut b = DagBuilder::new("t");
        let x = b.input("x", 8, 8, 3);
        let live = b.conv(&x, 8, 3, 1, 1, 1);
        let _dead = b.conv(&x, 16, 3, 1, 1, 1);
        b.output(&live);
        let d = b.build();
        let (out, _log) = legalize(&d).unwrap();
        assert_eq!(out.num_nodes(), 1);
    }

    #[test]
    fn patch_rejects_unknown_node() {
        let mut b = DagBuilder::new("t");
        let x = b.input("x", 8, 8, 3);
        let c = b.conv(&x, 8, 3, 1, 1, 1);
        b.output(&c);
        let d = b.build();
        let mut p = DagPatch::new("bad");
        p.bypass("ghost", "x");
        assert!(p.apply(&d).unwrap_err().contains("unknown node"));
    }

    #[test]
    fn patch_result_is_revalidated() {
        let mut b = DagBuilder::new("t");
        let x = b.input("x", 8, 8, 3);
        let c = b.conv(&x, 8, 3, 1, 1, 1);
        let r = b.relu(&c);
        b.output(&r);
        let d = b.build();
        let mut p = DagPatch::new("break shapes");
        p.retype(
            "relu2",
            DagOp::Add { shape: TensorShape::new(1, 1, 1) },
            vec!["conv1".into()],
        );
        assert!(p.apply(&d).is_err());
    }
}
