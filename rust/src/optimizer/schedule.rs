//! Execution schedules: contiguous fusion blocks with per-block MP.
//!
//! Algorithm 1's outputs are `fusion_partition_index[]` (where blocks end)
//! and `mp_of_fusionblock[]`; a [`Schedule`] carries both as explicit
//! `[start, end)` blocks. Every strategy and the brute-force oracle produce
//! this same type, so the simulator, code generator, and PJRT coordinator
//! are strategy-agnostic.

/// One fused block: layers `[start, end)` compiled together, run at `mp`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Block {
    pub start: usize,
    pub end: usize,
    pub mp: usize,
}

impl Block {
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.end <= self.start
    }
}

/// A complete schedule for a model: blocks must tile `0..num_layers`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Schedule {
    pub blocks: Vec<Block>,
}

impl Schedule {
    pub fn new(blocks: Vec<Block>) -> Self {
        Schedule { blocks }
    }

    /// Strategy-1 shape: every layer its own block at a fixed MP.
    pub fn layerwise(num_layers: usize, mp: usize) -> Self {
        Schedule {
            blocks: (0..num_layers)
                .map(|i| Block { start: i, end: i + 1, mp })
                .collect(),
        }
    }

    /// Strategy-4 shape: all layers fused into one block.
    pub fn single_block(num_layers: usize, mp: usize) -> Self {
        Schedule { blocks: vec![Block { start: 0, end: num_layers, mp }] }
    }

    /// Equal-size blocks of `block_size` (last block takes the remainder).
    pub fn uniform_blocks(num_layers: usize, block_size: usize, mp: usize) -> Self {
        assert!(block_size >= 1);
        let mut blocks = Vec::new();
        let mut start = 0;
        while start < num_layers {
            let end = (start + block_size).min(num_layers);
            blocks.push(Block { start, end, mp });
            start = end;
        }
        Schedule { blocks }
    }

    /// Check the blocks exactly tile `0..num_layers` with valid MPs.
    pub fn validate(&self, num_layers: usize, max_mp: usize) -> Result<(), String> {
        if self.blocks.is_empty() {
            return Err("schedule has no blocks".into());
        }
        let mut expected = 0usize;
        for (i, b) in self.blocks.iter().enumerate() {
            if b.is_empty() {
                return Err(format!("block {i} is empty ({}..{})", b.start, b.end));
            }
            if b.start != expected {
                return Err(format!(
                    "block {i} starts at {} but previous ended at {expected}",
                    b.start
                ));
            }
            if b.mp < 1 || b.mp > max_mp {
                return Err(format!("block {i} MP {} outside 1..={max_mp}", b.mp));
            }
            expected = b.end;
        }
        if expected != num_layers {
            return Err(format!(
                "schedule covers {expected} layers but the model has {num_layers}"
            ));
        }
        Ok(())
    }

    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Largest block length.
    pub fn max_block_len(&self) -> usize {
        self.blocks.iter().map(|b| b.len()).max().unwrap_or(0)
    }

    /// The paper's output form: indices where blocks end, plus block MPs.
    pub fn partition_indices(&self) -> (Vec<usize>, Vec<usize>) {
        (
            self.blocks.iter().map(|b| b.end).collect(),
            self.blocks.iter().map(|b| b.mp).collect(),
        )
    }

    /// Human-readable one-liner, e.g. `[0..8@4 | 8..20@8]`.
    pub fn summary(&self) -> String {
        let parts: Vec<String> = self
            .blocks
            .iter()
            .map(|b| format!("{}..{}@{}", b.start, b.end, b.mp))
            .collect();
        format!("[{}]", parts.join(" | "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layerwise_tiles() {
        let s = Schedule::layerwise(5, 1);
        assert_eq!(s.num_blocks(), 5);
        assert!(s.validate(5, 32).is_ok());
    }

    #[test]
    fn single_block_tiles() {
        let s = Schedule::single_block(7, 32);
        assert_eq!(s.num_blocks(), 1);
        assert!(s.validate(7, 32).is_ok());
    }

    #[test]
    fn uniform_blocks_remainder() {
        let s = Schedule::uniform_blocks(10, 4, 2);
        assert_eq!(s.blocks.len(), 3);
        assert_eq!(s.blocks[2].len(), 2);
        assert!(s.validate(10, 32).is_ok());
    }

    #[test]
    fn validate_catches_gap() {
        let s = Schedule::new(vec![
            Block { start: 0, end: 2, mp: 1 },
            Block { start: 3, end: 5, mp: 1 },
        ]);
        assert!(s.validate(5, 32).unwrap_err().contains("starts at 3"));
    }

    #[test]
    fn validate_catches_overlap() {
        let s = Schedule::new(vec![
            Block { start: 0, end: 3, mp: 1 },
            Block { start: 2, end: 5, mp: 1 },
        ]);
        assert!(s.validate(5, 32).is_err());
    }

    #[test]
    fn validate_catches_bad_mp() {
        let s = Schedule::new(vec![Block { start: 0, end: 2, mp: 64 }]);
        assert!(s.validate(2, 32).unwrap_err().contains("MP"));
        let s0 = Schedule::new(vec![Block { start: 0, end: 2, mp: 0 }]);
        assert!(s0.validate(2, 32).is_err());
    }

    #[test]
    fn validate_catches_short_cover() {
        let s = Schedule::new(vec![Block { start: 0, end: 2, mp: 1 }]);
        assert!(s.validate(5, 32).unwrap_err().contains("covers 2"));
    }

    #[test]
    fn partition_indices_match_paper_form() {
        let s = Schedule::new(vec![
            Block { start: 0, end: 3, mp: 4 },
            Block { start: 3, end: 5, mp: 8 },
        ]);
        let (idx, mps) = s.partition_indices();
        assert_eq!(idx, vec![3, 5]);
        assert_eq!(mps, vec![4, 8]);
    }

    #[test]
    fn summary_readable() {
        let s = Schedule::uniform_blocks(4, 2, 8);
        assert_eq!(s.summary(), "[0..2@8 | 2..4@8]");
    }
}
