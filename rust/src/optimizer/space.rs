//! Eq. 4: the size of the joint (fusion scheme × MP) search space.
//!
//! ```text
//! Space(n) = Σ_{i=1}^{n-1}  32^{i+1} · Π_{x=1}^{i}(n-x) / i!
//!          = Σ_{i=1}^{n-1}  32^{i+1} · C(n-1, i)
//! ```
//!
//! `i` counts internal partition points (i+1 blocks, each with one of 32 MP
//! settings); choosing `i` cut positions among the `n-1` gaps gives the
//! binomial. The paper quotes `8.17 × 10^75` possibilities at n = 50 —
//! far beyond brute force, which is the motivation for Algorithm 1.
//!
//! Values overflow u128 around n ≈ 23, so we compute in log10 space and
//! return a `(mantissa, exponent)` pair; an exact u128 path covers small n
//! and an enumerative cross-check lives in the tests.

/// A number expressed as `mantissa × 10^exp10` with `1 <= mantissa < 10`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BigMagnitude {
    pub mantissa: f64,
    pub exp10: i32,
}

impl BigMagnitude {
    fn from_log10(log10: f64) -> Self {
        let exp10 = log10.floor() as i32;
        BigMagnitude { mantissa: 10f64.powf(log10 - exp10 as f64), exp10 }
    }

    pub fn log10(&self) -> f64 {
        self.mantissa.log10() + self.exp10 as f64
    }

    pub fn to_f64(&self) -> f64 {
        self.mantissa * 10f64.powi(self.exp10)
    }
}

impl std::fmt::Display for BigMagnitude {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:.2}e{}", self.mantissa, self.exp10)
    }
}

/// Eq. 4 evaluated in log space (stable for any n, `mp_choices` = 32 in the
/// paper).
pub fn search_space(n: usize, mp_choices: usize) -> BigMagnitude {
    assert!(n >= 2, "need at least two layers");
    assert!(mp_choices >= 1);
    let log_m = (mp_choices as f64).log10();
    // log-sum-exp over i of (i+1)*log m + log C(n-1, i).
    let mut terms = Vec::with_capacity(n - 1);
    let mut log_binom = 0.0f64; // log10 C(n-1, 0)
    for i in 1..=(n - 1) {
        // C(n-1, i) = C(n-1, i-1) * (n-i) / i.
        log_binom += ((n - i) as f64).log10() - (i as f64).log10();
        terms.push((i as f64 + 1.0) * log_m + log_binom);
    }
    let max = terms.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let sum: f64 = terms.iter().map(|t| 10f64.powf(t - max)).sum();
    BigMagnitude::from_log10(max + sum.log10())
}

/// Exact value for small n (u128; panics on overflow) — used to validate the
/// log-space path and by the enumerative tests.
pub fn search_space_exact(n: usize, mp_choices: usize) -> u128 {
    assert!(n >= 2);
    let m = mp_choices as u128;
    let mut total: u128 = 0;
    let mut binom: u128 = 1; // C(n-1, 0)
    for i in 1..=(n - 1) {
        binom = binom * (n - i) as u128 / i as u128;
        let term = m
            .checked_pow(i as u32 + 1)
            .and_then(|p| p.checked_mul(binom))
            .expect("search_space_exact overflow; use search_space()");
        total = total.checked_add(term).expect("overflow");
    }
    total
}

/// Brute enumeration for *very* small n: every composition of `0..n` into
/// contiguous non-empty blocks (>= 2 blocks, matching Eq. 4's i >= 1), each
/// assigned one of `mp_choices` MPs.
pub fn enumerate_space(n: usize, mp_choices: usize) -> u128 {
    assert!(n >= 2 && n <= 16, "enumeration is exponential");
    let mut total: u128 = 0;
    // Each of the 2^(n-1) cut masks with >= 1 cut.
    for mask in 1u32..(1 << (n - 1)) {
        let blocks = mask.count_ones() as u32 + 1;
        total += (mp_choices as u128).pow(blocks);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_value_at_n50() {
        // Paper: "When n equals 50, there are 8.17 x 10^75 possible
        // combinations." Our closed form gives 32·(33^49 - 1) ≈ 2.5e76 —
        // same astronomic order; assert the magnitude band (the exact
        // mantissa depends on how the paper's authors rounded Eq. 4).
        let s = search_space(50, 32);
        assert!(s.exp10 >= 75 && s.exp10 <= 76, "{s}");
    }

    #[test]
    fn log_space_matches_exact_small_n() {
        for n in 2..=20 {
            let exact = search_space_exact(n, 32) as f64;
            let approx = search_space(n, 32).to_f64();
            assert!((approx / exact - 1.0).abs() < 1e-9, "n={n}");
        }
    }

    #[test]
    fn exact_matches_enumeration() {
        for n in 2..=10 {
            for m in [2usize, 8, 32] {
                assert_eq!(
                    search_space_exact(n, m),
                    enumerate_space(n, m),
                    "n={n} m={m}"
                );
            }
        }
    }

    #[test]
    fn grows_monotonically() {
        let mut last = 0.0;
        for n in 2..100 {
            let l = search_space(n, 32).log10();
            assert!(l > last);
            last = l;
        }
    }

    #[test]
    fn n2_hand_value() {
        // n=2: only i=1 -> 32^2 * C(1,1) = 1024.
        assert_eq!(search_space_exact(2, 32), 1024);
        assert_eq!(enumerate_space(2, 32), 1024);
    }

    #[test]
    fn display_format() {
        let s = search_space(50, 32);
        let text = format!("{s}");
        assert!(text.contains('e'), "{text}");
    }
}
