//! Algorithm 1: joint fusion-scheme and MP selection (the paper's core).
//!
//! Pseudo-code (paper, Section IV.C):
//!
//! ```text
//! for i in 0..num_of_layer:
//!     read layer spec
//!     if layer is Conv/FC:
//!         current_mp <- selection based on channel (major) and op count (minor)   [Eq. 5]
//!         sum_Op     <- sum_Op + op count of layer i
//!         avg_mp_acc <- avg_mp_acc + current_mp ; block_size += 1
//!     avg_mp <- avg_mp_acc / block_size
//!     if sum_Op / avg_mp >= OpCount_critical:
//!         close block at i; block MP <- 2^floor(log2(avg_mp))
//!         reset accumulators
//! ```
//!
//! The walk is O(n); fusion stops exactly when the per-core op count of the
//! accumulating block reaches the critical value — "just enough computation
//! to fully utilize the hardware while avoiding excessive redundant
//! computation".

use super::schedule::{Block, Schedule};
use crate::accel::AcceleratorSpec;
use crate::graph::Model;
use crate::perfmodel::mp_select::MpModel;

/// Tunable inputs of Algorithm 1.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AlgorithmParams {
    /// `OpCount_critical` in GOPs (paper: `10^1.25` for the MLU100).
    pub opcount_critical: f64,
    /// The Eq. 5 MP selector.
    pub mp_model: MpModel,
}

impl AlgorithmParams {
    /// Paper defaults for a given accelerator. The threshold compares
    /// `sum_Op / avg_mp` (a per-core quantity, line 12) against the per-core
    /// critical op count. `sum_Op` counts *useful* ops while the cores
    /// additionally compute the halo-redundant rows (~2–4x inside typical
    /// blocks), so the default threshold is 4x the per-core saturation
    /// point — the block's computed work lands at saturation. Both inputs
    /// are target-derived: the threshold from the spec's per-core
    /// `OpCount_critical`, and the Eq. 5 weights re-anchored to its core
    /// count ([`MpModel::for_spec`] — bit-identical to the MLU100 defaults
    /// on 32-core targets). The ablation bench sweeps this constant.
    pub fn for_spec(spec: &AcceleratorSpec) -> Self {
        AlgorithmParams {
            opcount_critical: 4.0 * spec.opcount_critical_per_core(),
            mp_model: MpModel::for_spec(spec),
        }
    }
}

/// Run Algorithm 1 and return the schedule.
pub fn dlfusion_schedule_with(model: &Model, spec: &AcceleratorSpec,
                              params: &AlgorithmParams) -> Schedule {
    let n = model.num_layers();
    assert!(n > 0, "empty model");
    let mut blocks: Vec<Block> = Vec::new();
    let mut block_start = 0usize;
    let mut sum_op = 0.0f64;
    let mut mp_acc = 0.0f64;
    let mut block_size = 0usize; // compute layers in the current block

    for i in 0..n {
        let layer = &model.layers[i];
        if layer.is_compute() {
            let current_mp = params.mp_model.select_layer(spec, layer);
            sum_op += layer.op_gops();
            mp_acc += current_mp as f64;
            block_size += 1;
        }
        if block_size == 0 {
            continue; // no compute layer accumulated yet — keep extending
        }
        let avg_mp = mp_acc / block_size as f64;
        if sum_op / avg_mp >= params.opcount_critical {
            blocks.push(Block {
                start: block_start,
                end: i + 1,
                mp: floor_pow2(avg_mp, spec.num_cores),
            });
            block_start = i + 1;
            sum_op = 0.0;
            mp_acc = 0.0;
            block_size = 0;
        }
    }
    // Trailing block: whatever remains after the last closed block.
    if block_start < n {
        let mp = if block_size > 0 {
            floor_pow2(mp_acc / block_size as f64, spec.num_cores)
        } else {
            1
        };
        blocks.push(Block { start: block_start, end: n, mp });
    }
    let schedule = Schedule::new(blocks);
    debug_assert!(schedule.validate(n, spec.num_cores).is_ok());
    schedule
}

/// Algorithm 1 with the paper's default parameters.
pub fn dlfusion_schedule(model: &Model, spec: &AcceleratorSpec) -> Schedule {
    dlfusion_schedule_with(model, spec, &AlgorithmParams::for_spec(spec))
}

/// Algorithm 1 restricted to a set of legal block boundaries. `allowed`
/// has length `n + 1`; `allowed[p]` answers "may a block end before layer
/// `p`" (positions 0 and `n` must be legal). The walk is the same greedy
/// accumulation, but a block only closes at a boundary that is both past
/// the op-count threshold *and* legal — at an illegal boundary the block
/// keeps extending and the threshold is re-checked one layer later. This
/// is how DAG workloads run the heuristic: the linearizer's fusion-legal
/// cut set keeps every block from straddling a branching region. With an
/// all-`true` mask the walk is statement-for-statement
/// [`dlfusion_schedule_with`] — bit-identical schedules.
pub fn dlfusion_schedule_masked(model: &Model, spec: &AcceleratorSpec,
                                params: &AlgorithmParams,
                                allowed: &[bool]) -> Schedule {
    let n = model.num_layers();
    assert!(n > 0, "empty model");
    assert_eq!(allowed.len(), n + 1, "mask covers every boundary");
    assert!(allowed[0] && allowed[n], "model ends must be legal cuts");
    let mut blocks: Vec<Block> = Vec::new();
    let mut block_start = 0usize;
    let mut sum_op = 0.0f64;
    let mut mp_acc = 0.0f64;
    let mut block_size = 0usize;

    for i in 0..n {
        let layer = &model.layers[i];
        if layer.is_compute() {
            let current_mp = params.mp_model.select_layer(spec, layer);
            sum_op += layer.op_gops();
            mp_acc += current_mp as f64;
            block_size += 1;
        }
        if block_size == 0 {
            continue;
        }
        let avg_mp = mp_acc / block_size as f64;
        if sum_op / avg_mp >= params.opcount_critical && allowed[i + 1] {
            blocks.push(Block {
                start: block_start,
                end: i + 1,
                mp: floor_pow2(avg_mp, spec.num_cores),
            });
            block_start = i + 1;
            sum_op = 0.0;
            mp_acc = 0.0;
            block_size = 0;
        }
    }
    if block_start < n {
        let mp = if block_size > 0 {
            floor_pow2(mp_acc / block_size as f64, spec.num_cores)
        } else {
            1
        };
        blocks.push(Block { start: block_start, end: n, mp });
    }
    let schedule = Schedule::new(blocks);
    debug_assert!(schedule.validate(n, spec.num_cores).is_ok());
    schedule
}

/// Line 14: `2^floor(log2(avg_mp))`, clamped to `[1, max]`.
fn floor_pow2(avg_mp: f64, max: usize) -> usize {
    if avg_mp < 1.0 {
        return 1;
    }
    let p = 1usize << (avg_mp.log2().floor() as u32);
    p.clamp(1, max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::AcceleratorSpec;
    use crate::graph::layer::ConvSpec;
    use crate::zoo;

    fn spec() -> AcceleratorSpec {
        crate::accel::Target::mlu100().into_spec()
    }

    #[test]
    fn schedules_every_zoo_model() {
        let s = spec();
        for m in zoo::all_models() {
            let sched = dlfusion_schedule(&m, &s);
            sched.validate(m.num_layers(), s.num_cores).expect(&m.name);
            for b in &sched.blocks {
                assert!(b.mp.is_power_of_two(), "{}: {}", m.name, sched.summary());
            }
        }
    }

    #[test]
    fn blocks_close_at_critical_opcount() {
        // A chain of 3.7-GOPs convs with a tiny critical value must split
        // into many blocks; with a huge critical value, one block.
        let s = spec();
        let m = zoo::identical_conv_model("t", ConvSpec::same(256, 256, 56, 3), 16);
        let tight = AlgorithmParams {
            opcount_critical: 0.2,
            mp_model: MpModel::default(),
        };
        let sched = dlfusion_schedule_with(&m, &s, &tight);
        assert!(sched.num_blocks() >= 8, "{}", sched.summary());

        let loose = AlgorithmParams {
            opcount_critical: 1e9,
            mp_model: MpModel::default(),
        };
        let sched1 = dlfusion_schedule_with(&m, &s, &loose);
        assert_eq!(sched1.num_blocks(), 1);
    }

    #[test]
    fn per_core_opcount_near_threshold() {
        // Every closed (non-trailing) block must have just crossed the
        // threshold: sum/avg_mp >= critical, and was below it one layer
        // earlier.
        let s = spec();
        let m = zoo::identical_conv_model("t", ConvSpec::same(256, 256, 56, 3), 32);
        let params = AlgorithmParams {
            opcount_critical: 1.0,
            mp_model: MpModel::default(),
        };
        let sched = dlfusion_schedule_with(&m, &s, &params);
        assert!(sched.num_blocks() >= 2);
        for b in &sched.blocks[..sched.num_blocks() - 1] {
            let layers = &m.layers[b.start..b.end];
            let compute: Vec<_> = layers.iter().filter(|l| l.is_compute()).collect();
            let sum: f64 = compute.iter().map(|l| l.op_gops()).sum();
            let avg_mp: f64 = compute
                .iter()
                .map(|l| params.mp_model.select_layer(&s, l) as f64)
                .sum::<f64>()
                / compute.len() as f64;
            assert!(sum / avg_mp >= params.opcount_critical,
                    "block {:?} below threshold", b);
            // Removing the last compute layer drops it below the threshold.
            let sum_minus: f64 = sum - compute.last().unwrap().op_gops();
            let avg_minus = if compute.len() > 1 {
                compute[..compute.len() - 1]
                    .iter()
                    .map(|l| params.mp_model.select_layer(&s, l) as f64)
                    .sum::<f64>()
                    / (compute.len() - 1) as f64
            } else {
                1.0
            };
            if compute.len() > 1 {
                assert!(sum_minus / avg_minus < params.opcount_critical,
                        "block {:?} closed late", b);
            }
        }
    }

    #[test]
    fn trailing_non_compute_layers_covered() {
        use crate::graph::layer::{Layer, LayerKind, TensorShape};
        let s = spec();
        let mut m = zoo::identical_conv_model("t", ConvSpec::same(64, 64, 28, 3), 2);
        let shape = TensorShape::new(28, 28, 64);
        m.layers.push(Layer::new("extra_relu", LayerKind::ReLU { shape }));
        m.layers.push(Layer::new("extra_add", LayerKind::Add { shape }));
        let sched = dlfusion_schedule(&m, &s);
        sched.validate(m.num_layers(), s.num_cores).unwrap();
    }

    #[test]
    fn block_mp_is_floor_pow2_of_avg() {
        assert_eq!(floor_pow2(1.0, 32), 1);
        assert_eq!(floor_pow2(3.9, 32), 2);
        assert_eq!(floor_pow2(4.0, 32), 4);
        assert_eq!(floor_pow2(11.3, 32), 8);
        assert_eq!(floor_pow2(31.9, 32), 16);
        assert_eq!(floor_pow2(70.0, 32), 32);
        assert_eq!(floor_pow2(0.2, 32), 1);
    }

    #[test]
    fn deterministic() {
        let s = spec();
        let m = zoo::resnet18();
        assert_eq!(dlfusion_schedule(&m, &s), dlfusion_schedule(&m, &s));
    }

    #[test]
    fn all_legal_mask_is_bit_identical_to_unmasked() {
        let s = spec();
        for m in zoo::all_models() {
            let params = AlgorithmParams::for_spec(&s);
            let mask = vec![true; m.num_layers() + 1];
            assert_eq!(
                dlfusion_schedule_masked(&m, &s, &params, &mask),
                dlfusion_schedule_with(&m, &s, &params),
                "{}",
                m.name
            );
        }
    }

    #[test]
    fn masked_walk_only_cuts_at_legal_boundaries() {
        let s = spec();
        let m = zoo::identical_conv_model("t", ConvSpec::same(256, 256, 56, 3), 16);
        let n = m.num_layers();
        // Only every fourth boundary (plus the ends) is legal; a tight
        // threshold would otherwise cut almost everywhere.
        let mut mask = vec![false; n + 1];
        for p in (0..=n).step_by(4) {
            mask[p] = true;
        }
        mask[0] = true;
        mask[n] = true;
        let params = AlgorithmParams {
            opcount_critical: 0.2,
            mp_model: MpModel::default(),
        };
        let sched = dlfusion_schedule_masked(&m, &s, &params, &mask);
        sched.validate(n, s.num_cores).unwrap();
        assert!(sched.num_blocks() >= 2, "{}", sched.summary());
        for b in &sched.blocks {
            assert!(mask[b.start] && mask[b.end], "illegal boundary: {b:?}");
        }
    }

    #[test]
    fn linear_time_behaviour() {
        // Not a perf test per se: just confirm a 2000-layer model schedules
        // instantly (O(n) walk, no quadratic blowup).
        let s = spec();
        let m = zoo::identical_conv_model("big", ConvSpec::same(64, 64, 28, 3), 2000);
        let t0 = std::time::Instant::now();
        let sched = dlfusion_schedule(&m, &s);
        assert!(t0.elapsed().as_millis() < 500);
        sched.validate(m.num_layers(), s.num_cores).unwrap();
    }
}
