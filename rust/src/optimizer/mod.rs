//! The DLFusion optimizer: Algorithm 1 and the Table III strategies.
//!
//! - [`schedule`]: the output representation — a partition of the model's
//!   layers into contiguous fused blocks, each with an MP setting (the
//!   paper's `fusion_partition_index[]` + `mp_of_fusionblock[]`);
//! - [`algorithm`]: Algorithm 1 — joint fusion-scheme + MP selection in
//!   O(n);
//! - [`strategies`]: the seven evaluation strategies of Table III / Fig. 10;
//! - [`space`]: Eq. 4 — the size of the joint search space that makes
//!   brute force infeasible.

pub mod schedule;
pub mod algorithm;
pub mod strategies;
pub mod space;

pub use algorithm::{dlfusion_schedule, dlfusion_schedule_masked, AlgorithmParams};
pub use schedule::{Block, Schedule};
pub use strategies::{run_strategy_with, strategy_schedule_with, Strategy};
#[allow(deprecated)]
pub use strategies::{run_strategy, strategy_schedule};
