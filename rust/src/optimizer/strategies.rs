//! The seven optimization strategies of Table III / Fig. 10.
//!
//! | # | name | fusion | MP |
//! |---|---|---|---|
//! | 1 | Non-Optimization | none | 1 everywhere |
//! | 2 | Fixed MP | none | one value for all layers (best of a sweep) |
//! | 3 | Dynamic MP | none | per-layer Eq. 5 |
//! | 4 | All Fusion & Max MP | single block | 32 |
//! | 5 | Fusion & Fixed MP | Algorithm 1 blocks | one value for all blocks (best of a sweep) |
//! | 6 | DLFusion | Algorithm 1 blocks | per-block Algorithm 1 MP |
//! | 7 | Brute-force Search | reduced oracle | reduced oracle |

use super::algorithm::{dlfusion_schedule_with, AlgorithmParams};
use super::schedule::{Block, Schedule};
use crate::accel::Simulator;
use crate::cost::CostEngine;
use crate::graph::Model;
use crate::search::brute::oracle_schedule_with;

/// Table III strategy index.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    NonOptimization,
    FixedMp,
    DynamicMp,
    AllFusionMaxMp,
    FusionFixedMp,
    DlFusion,
    BruteForce,
}

impl Strategy {
    /// All seven, in Table III order.
    pub const ALL: [Strategy; 7] = [
        Strategy::NonOptimization,
        Strategy::FixedMp,
        Strategy::DynamicMp,
        Strategy::AllFusionMaxMp,
        Strategy::FusionFixedMp,
        Strategy::DlFusion,
        Strategy::BruteForce,
    ];

    /// 1-based Table III index.
    pub fn index(&self) -> usize {
        Strategy::ALL.iter().position(|s| s == self).unwrap() + 1
    }

    /// Table III strategy name.
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::NonOptimization => "Non-Optimization",
            Strategy::FixedMp => "Fixed MP",
            Strategy::DynamicMp => "Dynamic MP",
            Strategy::AllFusionMaxMp => "All Fusion & Max. MP",
            Strategy::FusionFixedMp => "Fusion & Fixed MP",
            Strategy::DlFusion => "DLFusion",
            Strategy::BruteForce => "Brute-force Search",
        }
    }

    pub fn from_index(i: usize) -> Option<Strategy> {
        Strategy::ALL.get(i.checked_sub(1)?).copied()
    }
}

impl std::fmt::Display for Strategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Build the schedule a strategy produces for `model` (simulator needed for
/// the sweep-based strategies 2/5 and the oracle). Constructs a throwaway
/// [`CostEngine`]; callers evaluating several strategies on one model should
/// use [`strategy_schedule_with`] over a shared engine instead.
#[deprecated(note = "build a `CostEngine` and call `strategy_schedule_with`, \
                     or use `tuner::TableStrategy` over a `TuningRequest`")]
pub fn strategy_schedule(sim: &Simulator, model: &Model, strategy: Strategy,
                         params: &AlgorithmParams) -> Schedule {
    let mut engine = CostEngine::new(sim, model);
    strategy_schedule_with(&mut engine, strategy, params)
}

/// Build a strategy's schedule, evaluating every candidate through the
/// given engine (the sweeps of strategies 2/5 and the oracle DP share its
/// memoized `(block, mp)` cache).
pub fn strategy_schedule_with(engine: &mut CostEngine, strategy: Strategy,
                              params: &AlgorithmParams) -> Schedule {
    let model = engine.model();
    let spec = &engine.sim().spec;
    let n = model.num_layers();
    match strategy {
        Strategy::NonOptimization => Schedule::layerwise(n, 1),
        Strategy::FixedMp => {
            // Sweep a single shared MP across the layer-wise schedule and
            // keep the best — the Fig. 5(a) procedure.
            best_over(engine, spec.reduced_mp_set(), |mp| Schedule::layerwise(n, mp))
        }
        Strategy::DynamicMp => Schedule::new(
            model
                .layers
                .iter()
                .enumerate()
                .map(|(i, l)| Block {
                    start: i,
                    end: i + 1,
                    mp: if l.is_compute() {
                        params.mp_model.select_layer(spec, l)
                    } else {
                        1
                    },
                })
                .collect(),
        ),
        Strategy::AllFusionMaxMp => Schedule::single_block(n, spec.num_cores),
        Strategy::FusionFixedMp => {
            let base = dlfusion_schedule_with(model, spec, params);
            best_over(engine, spec.reduced_mp_set(), |mp| {
                Schedule::new(
                    base.blocks
                        .iter()
                        .map(|b| Block { mp, ..*b })
                        .collect(),
                )
            })
        }
        Strategy::DlFusion => dlfusion_schedule_with(model, spec, params),
        Strategy::BruteForce => oracle_schedule_with(engine).0,
    }
}

/// Keep the sweep's seed shape — a lazy `min_by` over the candidates — but
/// serve every evaluation from the engine's cache: the comparator's repeated
/// looks at the running minimum cost nothing after the first.
fn best_over(engine: &mut CostEngine, mps: Vec<usize>,
             make: impl Fn(usize) -> Schedule) -> Schedule {
    mps.into_iter()
        .map(make)
        .min_by(|a, b| {
            let cost_a = engine.schedule_cost(a);
            let cost_b = engine.schedule_cost(b);
            cost_a.total_cmp(&cost_b)
        })
        .expect("non-empty MP set")
}

/// Convenience: schedule + simulated report for one strategy.
#[deprecated(note = "build a `CostEngine` and call `run_strategy_with`, or \
                     use `tuner::TableStrategy` over a `TuningRequest`")]
pub fn run_strategy(sim: &Simulator, model: &Model, strategy: Strategy)
                    -> (Schedule, crate::accel::PerfReport) {
    let mut engine = CostEngine::new(sim, model);
    run_strategy_with(&mut engine, strategy)
}

/// Schedule + report for one strategy over a shared engine.
pub fn run_strategy_with(engine: &mut CostEngine, strategy: Strategy)
                         -> (Schedule, crate::accel::PerfReport) {
    let params = AlgorithmParams::for_spec(&engine.sim().spec);
    let sched = strategy_schedule_with(engine, strategy, &params);
    let report = engine.run_schedule(&sched);
    (sched, report)
}

#[cfg(test)]
#[allow(deprecated)] // the legacy shims stay covered until they are removed
mod tests {
    use super::*;
    use crate::zoo;

    fn sim() -> Simulator {
        Simulator::new(crate::accel::Target::mlu100())
    }

    #[test]
    fn indices_and_names_match_table3() {
        assert_eq!(Strategy::NonOptimization.index(), 1);
        assert_eq!(Strategy::DlFusion.index(), 6);
        assert_eq!(Strategy::BruteForce.index(), 7);
        assert_eq!(Strategy::from_index(4), Some(Strategy::AllFusionMaxMp));
        assert_eq!(Strategy::from_index(0), None);
        assert_eq!(Strategy::from_index(8), None);
        assert_eq!(Strategy::DlFusion.name(), "DLFusion");
    }

    #[test]
    fn all_strategies_produce_valid_schedules() {
        let s = sim();
        let m = zoo::alexnet();
        for st in Strategy::ALL {
            let (sched, rep) = run_strategy(&s, &m, st);
            sched.validate(m.num_layers(), s.spec.num_cores)
                .unwrap_or_else(|e| panic!("{st}: {e}"));
            assert!(rep.total_ms > 0.0);
        }
    }

    #[test]
    fn baseline_is_everything_mp1_unfused() {
        let s = sim();
        let m = zoo::alexnet();
        let (sched, _) = run_strategy(&s, &m, Strategy::NonOptimization);
        assert_eq!(sched.num_blocks(), m.num_layers());
        assert!(sched.blocks.iter().all(|b| b.mp == 1));
    }

    #[test]
    fn strategy4_is_one_block_mp32() {
        let s = sim();
        let m = zoo::alexnet();
        let (sched, _) = run_strategy(&s, &m, Strategy::AllFusionMaxMp);
        assert_eq!(sched.num_blocks(), 1);
        assert_eq!(sched.blocks[0].mp, 32);
    }

    #[test]
    fn fixed_mp_beats_baseline() {
        let s = sim();
        let m = zoo::vgg19();
        let (_, base) = run_strategy(&s, &m, Strategy::NonOptimization);
        let (_, fixed) = run_strategy(&s, &m, Strategy::FixedMp);
        assert!(fixed.fps() >= base.fps());
    }

    #[test]
    fn dlfusion_beats_strategies_1_to_4() {
        // The Fig. 10 ordering: strategy 6 strictly dominates the naive
        // strategies (no fusion, or fuse-all at max MP).
        let s = sim();
        for m in zoo::all_models() {
            let (_, dlf) = run_strategy(&s, &m, Strategy::DlFusion);
            for st in [Strategy::NonOptimization, Strategy::FixedMp,
                       Strategy::DynamicMp, Strategy::AllFusionMaxMp] {
                let (_, other) = run_strategy(&s, &m, st);
                assert!(dlf.fps() >= other.fps(),
                        "{}: DLFusion {:.1} FPS < {} {:.1} FPS",
                        m.name, dlf.fps(), st, other.fps());
            }
        }
    }

    #[test]
    fn dlfusion_close_to_swept_mp_variant() {
        // Strategy 5 shares DLFusion's partition but *sweeps* a uniform MP
        // (an oracle DLFusion doesn't get); Algorithm 1's Eq.5-derived
        // per-block MP must stay within 25% of it. (AlexNet is the worst
        // case: Eq. 5 overshoots MP for its small-spatial mid layers — see
        // EXPERIMENTS.md §Fig.10 deviations.)
        let s = sim();
        for m in zoo::all_models() {
            let (_, dlf) = run_strategy(&s, &m, Strategy::DlFusion);
            let (_, s5) = run_strategy(&s, &m, Strategy::FusionFixedMp);
            assert!(dlf.fps() >= s5.fps() * 0.75,
                    "{}: DLFusion {:.1} vs swept {:.1}", m.name, dlf.fps(), s5.fps());
        }
    }

    #[test]
    fn dlfusion_speedup_in_paper_band() {
        // Fig. 10: 3.6x–7.9x over the non-optimized baseline on the paper's
        // testbed. Our simulator substrate reproduces the band within a
        // tolerance (see EXPERIMENTS.md for the per-network comparison);
        // AlexNet sits below because its FC weight streaming bounds the
        // achievable gain in our memory model.
        let s = sim();
        for m in zoo::all_models() {
            let (_, base) = run_strategy(&s, &m, Strategy::NonOptimization);
            let (_, dlf) = run_strategy(&s, &m, Strategy::DlFusion);
            let speedup = dlf.fps() / base.fps();
            assert!(speedup > 1.5 && speedup < 10.0,
                    "{}: speedup {speedup:.2} outside band", m.name);
        }
    }

    #[test]
    fn engine_routed_sweeps_match_seed_sweeps() {
        // The seed `best_over` re-ran `Simulator::run_schedule` inside the
        // `min_by` comparator; replay that reference verbatim and pin the
        // engine-routed strategies 2 and 5 against it.
        let s = sim();
        for m in [zoo::resnet50(), zoo::alexnet()] {
            let params = AlgorithmParams::for_spec(&s.spec);
            let n = m.num_layers();
            let seed_best = |cands: Vec<Schedule>| {
                cands
                    .into_iter()
                    .min_by(|a, b| {
                        s.run_schedule(&m, a)
                            .total_ms
                            .total_cmp(&s.run_schedule(&m, b).total_ms)
                    })
                    .unwrap()
            };
            let ref2 = seed_best(
                s.spec.reduced_mp_set().into_iter()
                    .map(|mp| Schedule::layerwise(n, mp))
                    .collect(),
            );
            assert_eq!(strategy_schedule(&s, &m, Strategy::FixedMp, &params),
                       ref2, "{} strategy 2", m.name);
            let base = dlfusion_schedule_with(&m, &s.spec, &params);
            let ref5 = seed_best(
                s.spec.reduced_mp_set().into_iter()
                    .map(|mp| Schedule::new(
                        base.blocks.iter().map(|b| Block { mp, ..*b }).collect(),
                    ))
                    .collect(),
            );
            assert_eq!(strategy_schedule(&s, &m, Strategy::FusionFixedMp, &params),
                       ref5, "{} strategy 5", m.name);
        }
    }

    #[test]
    fn engine_reports_match_simulator_reports() {
        let s = sim();
        let m = zoo::resnet18();
        for st in Strategy::ALL {
            let (sched, rep) = run_strategy(&s, &m, st);
            assert_eq!(rep, s.run_schedule(&m, &sched), "{st}");
        }
    }

    #[test]
    fn sweeps_save_ten_x_layer_fact_derivations() {
        // The acceptance claim for the MP sweeps: the seed derived every
        // layer's facts on every schedule evaluation (15 full-model walks
        // across the sweep); the engine derives them once per model.
        let s = sim();
        let m = zoo::resnet50();
        let params = AlgorithmParams::for_spec(&s.spec);
        for st in [Strategy::FixedMp, Strategy::FusionFixedMp] {
            let mut engine = CostEngine::new(&s, &m);
            let sched = strategy_schedule_with(&mut engine, st, &params);
            let _ = engine.run_schedule(&sched);
            let stats = engine.stats();
            assert!(stats.seed_layer_evals >= 10 * stats.layer_facts_built,
                    "{st}: layer-eval reduction only {:.1}x ({stats:?})",
                    stats.layer_eval_reduction());
        }
    }

    #[test]
    fn fusion_fixed_mp_shares_partition_with_dlfusion() {
        let s = sim();
        let m = zoo::resnet50();
        let params = AlgorithmParams::for_spec(&s.spec);
        let s5 = strategy_schedule(&s, &m, Strategy::FusionFixedMp, &params);
        let s6 = strategy_schedule(&s, &m, Strategy::DlFusion, &params);
        let (idx5, _) = s5.partition_indices();
        let (idx6, _) = s6.partition_indices();
        assert_eq!(idx5, idx6);
        let (_, mps5) = s5.partition_indices();
        assert!(mps5.windows(2).all(|w| w[0] == w[1]), "strategy 5 MPs uniform");
    }
}
