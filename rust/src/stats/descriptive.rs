//! Descriptive statistics: mean, stddev, median, percentiles.

/// Summary statistics of a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    /// Sample standard deviation (n-1 denominator; 0 for n < 2).
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
}

impl Summary {
    /// Compute from a sample. Panics on an empty slice.
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 50.0),
        }
    }

    /// Coefficient of variation (std / mean); 0 when mean is 0.
    pub fn cv(&self) -> f64 {
        if self.mean == 0.0 { 0.0 } else { self.std / self.mean.abs() }
    }
}

/// p-th percentile (0..=100) by linear interpolation on a *sorted* slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// p-th percentile of an unsorted sample.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&sorted, p)
}

/// Geometric mean (all inputs must be positive).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let log_sum: f64 = xs
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean needs positive values, got {x}");
            x.ln()
        })
        .sum();
    (log_sum / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.std - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.median - 2.5).abs() < 1e-12);
    }

    #[test]
    fn single_element() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.median, 7.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
        assert!((percentile(&xs, 25.0) - 17.5).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_powers() {
        assert!((geomean(&[1.0, 4.0, 16.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn cv_zero_mean_guard() {
        let s = Summary::of(&[-1.0, 1.0]);
        assert_eq!(s.cv(), 0.0);
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn empty_panics() {
        Summary::of(&[]);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn geomean_rejects_nonpositive() {
        geomean(&[1.0, 0.0]);
    }
}
