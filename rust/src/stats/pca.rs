//! Principal component analysis, from scratch.
//!
//! The paper applies PCA to (standardized) layer features — operation count,
//! channel size, kernel size, feature-map size — against achieved
//! performance, finding op count and channel carry the weight; the Eq. 5
//! coefficients α = 0.316 and β = 0.659 come from "the weight result of
//! PCA". `examples/characterize.rs` repeats that derivation on simulator
//! sweeps using this implementation.
//!
//! Implementation: standardize features, form the covariance matrix, and
//! diagonalize with the cyclic Jacobi eigenvalue algorithm (symmetric
//! matrices, unconditionally convergent — no external linear algebra needed).

/// PCA decomposition result.
#[derive(Debug, Clone)]
pub struct Pca {
    /// Eigenvalues (explained variance), descending.
    pub eigenvalues: Vec<f64>,
    /// Row i = i-th principal axis (unit length), matching `eigenvalues[i]`.
    pub components: Vec<Vec<f64>>,
    /// Per-feature means used for standardization.
    pub means: Vec<f64>,
    /// Per-feature standard deviations used for standardization.
    pub stds: Vec<f64>,
}

impl Pca {
    /// Fit on a samples × features matrix. Features with zero variance get
    /// std 1 (they simply contribute nothing).
    pub fn fit(data: &[Vec<f64>]) -> Pca {
        assert!(data.len() >= 2, "PCA needs at least 2 samples");
        let d = data[0].len();
        assert!(d >= 1);
        for row in data {
            assert_eq!(row.len(), d, "ragged data");
        }
        let n = data.len() as f64;
        let means: Vec<f64> = (0..d)
            .map(|j| data.iter().map(|r| r[j]).sum::<f64>() / n)
            .collect();
        let stds: Vec<f64> = (0..d)
            .map(|j| {
                let v = data.iter().map(|r| (r[j] - means[j]).powi(2)).sum::<f64>()
                    / (n - 1.0);
                let s = v.sqrt();
                if s > 1e-12 { s } else { 1.0 }
            })
            .collect();
        // Covariance of standardized data (== correlation matrix).
        let mut cov = vec![vec![0.0f64; d]; d];
        for row in data {
            let z: Vec<f64> = (0..d).map(|j| (row[j] - means[j]) / stds[j]).collect();
            for i in 0..d {
                for j in 0..d {
                    cov[i][j] += z[i] * z[j];
                }
            }
        }
        for r in cov.iter_mut() {
            for v in r.iter_mut() {
                *v /= n - 1.0;
            }
        }
        let (mut eigenvalues, mut components) = jacobi_eigen(&cov);
        // Sort descending by eigenvalue.
        let mut idx: Vec<usize> = (0..d).collect();
        idx.sort_by(|&a, &b| eigenvalues[b].partial_cmp(&eigenvalues[a]).unwrap());
        eigenvalues = idx.iter().map(|&i| eigenvalues[i]).collect();
        components = idx.iter().map(|&i| components[i].clone()).collect();
        // Sign convention: largest-magnitude entry positive (deterministic).
        for c in components.iter_mut() {
            let lead = c
                .iter()
                .cloned()
                .max_by(|a, b| a.abs().partial_cmp(&b.abs()).unwrap())
                .unwrap();
            if lead < 0.0 {
                for v in c.iter_mut() {
                    *v = -*v;
                }
            }
        }
        Pca { eigenvalues, components, means, stds }
    }

    /// Fraction of variance explained by each component.
    pub fn explained_ratio(&self) -> Vec<f64> {
        let total: f64 = self.eigenvalues.iter().sum();
        self.eigenvalues.iter().map(|&e| e / total.max(1e-300)).collect()
    }

    /// Project one sample onto the principal axes.
    pub fn transform(&self, row: &[f64]) -> Vec<f64> {
        let z: Vec<f64> = row
            .iter()
            .zip(self.means.iter().zip(&self.stds))
            .map(|(x, (m, s))| (x - m) / s)
            .collect();
        self.components
            .iter()
            .map(|c| c.iter().zip(&z).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// |loading| of each input feature on the first principal component,
    /// normalized to sum 1 — the paper's "weight result of PCA" used for the
    /// Eq. 5 α/β.
    pub fn pc1_weights(&self) -> Vec<f64> {
        let abs: Vec<f64> = self.components[0].iter().map(|v| v.abs()).collect();
        let sum: f64 = abs.iter().sum();
        abs.iter().map(|v| v / sum.max(1e-300)).collect()
    }
}

/// Cyclic Jacobi eigendecomposition of a symmetric matrix.
/// Returns (eigenvalues, eigenvectors-as-rows), unsorted.
fn jacobi_eigen(m: &[Vec<f64>]) -> (Vec<f64>, Vec<Vec<f64>>) {
    let n = m.len();
    let mut a: Vec<Vec<f64>> = m.to_vec();
    // v starts as identity; columns accumulate the rotations.
    let mut v = vec![vec![0.0f64; n]; n];
    for (i, r) in v.iter_mut().enumerate() {
        r[i] = 1.0;
    }
    for _sweep in 0..100 {
        let off: f64 = (0..n)
            .flat_map(|i| ((i + 1)..n).map(move |j| (i, j)))
            .map(|(i, j)| a[i][j] * a[i][j])
            .sum();
        if off < 1e-22 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                if a[p][q].abs() < 1e-30 {
                    continue;
                }
                let theta = (a[q][q] - a[p][p]) / (2.0 * a[p][q]);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                for k in 0..n {
                    let akp = a[k][p];
                    let akq = a[k][q];
                    a[k][p] = c * akp - s * akq;
                    a[k][q] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a[p][k];
                    let aqk = a[q][k];
                    a[p][k] = c * apk - s * aqk;
                    a[q][k] = s * apk + c * aqk;
                }
                for k in 0..n {
                    let vkp = v[k][p];
                    let vkq = v[k][q];
                    v[k][p] = c * vkp - s * vkq;
                    v[k][q] = s * vkp + c * vkq;
                }
            }
        }
    }
    let eigenvalues: Vec<f64> = (0..n).map(|i| a[i][i]).collect();
    // Transpose: eigenvector for eigenvalue i is column i of v.
    let vectors: Vec<Vec<f64>> = (0..n)
        .map(|i| (0..n).map(|k| v[k][i]).collect())
        .collect();
    (eigenvalues, vectors)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShiftRng;

    #[test]
    fn identity_covariance_unit_eigenvalues() {
        // Independent standardized features -> eigenvalues near 1 each.
        let mut rng = XorShiftRng::new(5);
        let data: Vec<Vec<f64>> = (0..4000)
            .map(|_| vec![rng.gen_normal(), rng.gen_normal(), rng.gen_normal()])
            .collect();
        let p = Pca::fit(&data);
        for &e in &p.eigenvalues {
            assert!((e - 1.0).abs() < 0.15, "eigenvalue {e}");
        }
    }

    #[test]
    fn dominant_direction_recovered() {
        // x1 = 2*x0 + tiny noise -> PC1 along (1,2)/sqrt(5) in raw space,
        // (1,1)/sqrt(2) after standardization.
        let mut rng = XorShiftRng::new(6);
        let data: Vec<Vec<f64>> = (0..2000)
            .map(|_| {
                let t = rng.gen_normal();
                vec![t, 2.0 * t + 0.01 * rng.gen_normal()]
            })
            .collect();
        let p = Pca::fit(&data);
        let ratio = p.explained_ratio();
        assert!(ratio[0] > 0.99, "PC1 ratio {}", ratio[0]);
        let c = &p.components[0];
        assert!((c[0].abs() - (0.5f64).sqrt()).abs() < 0.02);
        assert!((c[1].abs() - (0.5f64).sqrt()).abs() < 0.02);
    }

    #[test]
    fn components_orthonormal() {
        let mut rng = XorShiftRng::new(7);
        let data: Vec<Vec<f64>> = (0..500)
            .map(|_| {
                let a = rng.gen_normal();
                let b = rng.gen_normal();
                vec![a, b, a + 0.5 * b, rng.gen_normal()]
            })
            .collect();
        let p = Pca::fit(&data);
        let d = p.components.len();
        for i in 0..d {
            for j in 0..d {
                let dot: f64 = p.components[i]
                    .iter()
                    .zip(&p.components[j])
                    .map(|(a, b)| a * b)
                    .sum();
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-8, "({i},{j}) dot={dot}");
            }
        }
    }

    #[test]
    fn eigenvalues_sum_to_trace() {
        // Correlation matrix has trace d.
        let mut rng = XorShiftRng::new(8);
        let data: Vec<Vec<f64>> = (0..300)
            .map(|_| vec![rng.gen_normal(), 3.0 * rng.gen_normal() + 1.0])
            .collect();
        let p = Pca::fit(&data);
        let sum: f64 = p.eigenvalues.iter().sum();
        assert!((sum - 2.0).abs() < 1e-8, "sum={sum}");
    }

    #[test]
    fn pc1_weights_normalized() {
        let mut rng = XorShiftRng::new(9);
        let data: Vec<Vec<f64>> = (0..200)
            .map(|_| vec![rng.gen_normal(), rng.gen_normal()])
            .collect();
        let w = Pca::fit(&data).pc1_weights();
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn transform_decorrelates() {
        let mut rng = XorShiftRng::new(10);
        let data: Vec<Vec<f64>> = (0..3000)
            .map(|_| {
                let t = rng.gen_normal();
                vec![t + 0.3 * rng.gen_normal(), t]
            })
            .collect();
        let p = Pca::fit(&data);
        let proj: Vec<Vec<f64>> = data.iter().map(|r| p.transform(r)).collect();
        let n = proj.len() as f64;
        let m0 = proj.iter().map(|r| r[0]).sum::<f64>() / n;
        let m1 = proj.iter().map(|r| r[1]).sum::<f64>() / n;
        let cov01 = proj.iter().map(|r| (r[0] - m0) * (r[1] - m1)).sum::<f64>() / n;
        assert!(cov01.abs() < 0.02, "cov={cov01}");
    }

    #[test]
    #[should_panic(expected = "at least 2")]
    fn needs_two_samples() {
        Pca::fit(&[vec![1.0, 2.0]]);
    }
}
