//! Statistics toolkit for the characterization methodology.
//!
//! The paper derives its performance model by (1) sweeping microbenchmarks,
//! (2) running **PCA** over layer features to find that operation count and
//! channel size dominate achieved performance (Section II.B), and (3)
//! empirically fitting the Eq. 5 weights (α = 0.316, β = 0.659) from the PCA
//! weights. This module provides exactly those tools: descriptive stats for
//! the error bars of Fig. 4(a), least-squares fits for `OpCount_critical`,
//! and a dependency-free PCA (covariance + Jacobi eigensolver).

pub mod descriptive;
pub mod regression;
pub mod pca;

pub use descriptive::Summary;
pub use pca::Pca;
pub use regression::{linear_fit, multi_linear_fit};
