//! Least-squares fits used when re-deriving the paper's empirical constants
//! (`OpCount_critical`, the Eq. 5 α/β weights) from microbenchmark sweeps.

/// Result of a simple linear fit `y = slope * x + intercept`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinearFit {
    pub slope: f64,
    pub intercept: f64,
    /// Coefficient of determination.
    pub r2: f64,
}

/// Ordinary least squares on paired samples. Panics on < 2 points or
/// degenerate x.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> LinearFit {
    assert_eq!(xs.len(), ys.len(), "paired samples");
    assert!(xs.len() >= 2, "need at least two points");
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxx: f64 = xs.iter().map(|x| (x - mx).powi(2)).sum();
    assert!(sxx > 0.0, "degenerate x (all equal)");
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| (y - (slope * x + intercept)).powi(2))
        .sum();
    let ss_tot: f64 = ys.iter().map(|y| (y - my).powi(2)).sum();
    let r2 = if ss_tot == 0.0 { 1.0 } else { 1.0 - ss_res / ss_tot };
    LinearFit { slope, intercept, r2 }
}

/// Multiple linear regression `y = X·w + b` via normal equations with
/// Gaussian elimination. Columns of `xs` are features; returns (weights, b).
///
/// Singular or collinear Gram matrices (zero-variance feature columns, a
/// duplicated feature, fewer samples than features) are handled by ridge
/// regularization instead of a panic: a multiple of the identity, scaled by
/// the Gram trace and escalated tenfold until the elimination succeeds, is
/// added to the diagonal. The fallback is deterministic and always returns
/// finite coefficients — a zero-variance column simply gets (near-)zero
/// weight and its constant contribution folds into the intercept.
pub fn multi_linear_fit(xs: &[Vec<f64>], ys: &[f64]) -> (Vec<f64>, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(!xs.is_empty());
    let d = xs[0].len();
    // Augment with a constant-1 feature for the intercept.
    let k = d + 1;
    let mut ata = vec![vec![0.0f64; k]; k];
    let mut atb = vec![0.0f64; k];
    for (row, &y) in xs.iter().zip(ys) {
        assert_eq!(row.len(), d, "ragged feature matrix");
        let mut aug = row.clone();
        aug.push(1.0);
        for i in 0..k {
            atb[i] += aug[i] * y;
            for j in 0..k {
                ata[i][j] += aug[i] * aug[j];
            }
        }
    }
    // Scale-aware ridge ladder: exact solve first, then λ escalating
    // tenfold from trace/k · 1e-10. The intercept column keeps the trace
    // ≥ n, so the final rung (λ = trace/k · 0.1) dominates any residual
    // rank deficiency and the loop always terminates with finite
    // coefficients.
    let trace: f64 = (0..k).map(|i| ata[i][i]).sum();
    let base = (trace / k as f64).max(f64::MIN_POSITIVE);
    for attempt in 0..=10 {
        let lambda = if attempt == 0 { 0.0 } else { base * 1e-10 * 10f64.powi(attempt - 1) };
        let mut a = ata.clone();
        let mut b = atb.clone();
        for (i, row) in a.iter_mut().enumerate() {
            row[i] += lambda;
        }
        if let Some(w) = solve(&mut a, &mut b) {
            if w.iter().all(|v| v.is_finite()) {
                let bias = w[d];
                return (w[..d].to_vec(), bias);
            }
        }
    }
    unreachable!("ridge ladder ends at a strictly diagonally dominated system")
}

/// Solve `A x = b` in place by Gaussian elimination with partial pivoting.
/// Returns `None` when a pivot is too small to divide by (singular system).
fn solve(a: &mut [Vec<f64>], b: &mut [f64]) -> Option<Vec<f64>> {
    let n = b.len();
    for col in 0..n {
        // Pivot.
        let piv = (col..n)
            .max_by(|&i, &j| a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap())
            .unwrap();
        a.swap(col, piv);
        b.swap(col, piv);
        let diag = a[col][col];
        if diag.abs() <= 1e-12 {
            return None;
        }
        for row in (col + 1)..n {
            let f = a[row][col] / diag;
            for c in col..n {
                a[row][c] -= f * a[col][c];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = vec![0.0; n];
    for row in (0..n).rev() {
        let mut acc = b[row];
        for c in (row + 1)..n {
            acc -= a[row][c] * x[c];
        }
        x[row] = acc / a[row][row];
    }
    Some(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let f = linear_fit(&xs, &ys);
        assert!((f.slope - 2.0).abs() < 1e-12);
        assert!((f.intercept - 1.0).abs() < 1e-12);
        assert!((f.r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn noisy_line_r2_below_one() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|x| 3.0 * x + if *x as u64 % 2 == 0 { 1.0 } else { -1.0 })
            .collect();
        let f = linear_fit(&xs, &ys);
        assert!((f.slope - 3.0).abs() < 0.01);
        assert!(f.r2 > 0.99 && f.r2 < 1.0);
    }

    #[test]
    fn multi_fit_recovers_plane() {
        // y = 2 x0 - 0.5 x1 + 4
        let xs: Vec<Vec<f64>> = (0..20)
            .map(|i| vec![(i % 5) as f64, (i / 5) as f64])
            .collect();
        let ys: Vec<f64> = xs.iter().map(|r| 2.0 * r[0] - 0.5 * r[1] + 4.0).collect();
        let (w, b) = multi_linear_fit(&xs, &ys);
        assert!((w[0] - 2.0).abs() < 1e-9);
        assert!((w[1] + 0.5).abs() < 1e-9);
        assert!((b - 4.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "degenerate")]
    fn degenerate_x_panics() {
        linear_fit(&[1.0, 1.0], &[0.0, 1.0]);
    }

    #[test]
    fn zero_variance_column_falls_back_to_ridge() {
        // Column 1 is constant — perfectly collinear with the intercept.
        // The fit must stay finite and still recover the informative slope.
        let xs: Vec<Vec<f64>> = (0..12).map(|i| vec![i as f64, 7.0]).collect();
        let ys: Vec<f64> = xs.iter().map(|r| 3.0 * r[0] + 1.0).collect();
        let (w, b) = multi_linear_fit(&xs, &ys);
        assert!(w.iter().all(|v| v.is_finite()) && b.is_finite());
        assert!((w[0] - 3.0).abs() < 1e-3, "slope {}", w[0]);
        // Predictions are what the ridge split of the constant term must
        // preserve, not the individual (w[1], b) coefficients.
        for (row, &y) in xs.iter().zip(&ys) {
            let pred = w[0] * row[0] + w[1] * row[1] + b;
            assert!((pred - y).abs() < 1e-3, "pred {pred} vs {y}");
        }
    }

    #[test]
    fn duplicated_column_falls_back_to_ridge() {
        let xs: Vec<Vec<f64>> = (0..10).map(|i| vec![i as f64, i as f64]).collect();
        let ys: Vec<f64> = xs.iter().map(|r| 2.0 * r[0] + 5.0).collect();
        let (w, b) = multi_linear_fit(&xs, &ys);
        assert!(w.iter().all(|v| v.is_finite()) && b.is_finite());
        // The duplicated pair shares the true slope in some split; their sum
        // must carry it.
        assert!((w[0] + w[1] - 2.0).abs() < 1e-3, "w = {w:?}");
        assert!((b - 5.0).abs() < 1e-2, "b = {b}");
    }

    #[test]
    fn underdetermined_system_stays_finite() {
        // Two samples, three features: the Gram matrix is rank-deficient.
        let xs = vec![vec![1.0, 2.0, 3.0], vec![2.0, 1.0, 0.5]];
        let ys = vec![10.0, 20.0];
        let (w, b) = multi_linear_fit(&xs, &ys);
        assert!(w.iter().all(|v| v.is_finite()) && b.is_finite());
    }

    #[test]
    fn degenerate_fit_is_deterministic() {
        let xs: Vec<Vec<f64>> = (0..8).map(|i| vec![i as f64, 4.0]).collect();
        let ys: Vec<f64> = xs.iter().map(|r| r[0] * 0.5 - 2.0).collect();
        let (w1, b1) = multi_linear_fit(&xs, &ys);
        let (w2, b2) = multi_linear_fit(&xs, &ys);
        assert_eq!(b1.to_bits(), b2.to_bits());
        for (a, b) in w1.iter().zip(&w2) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
