//! `dlfusion` CLI entrypoint (Layer-3 leader binary).

use dlfusion::cli::{args::Args, commands};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            eprint!("{}", commands::HELP);
            std::process::exit(2);
        }
    };
    std::process::exit(commands::run(&args));
}
