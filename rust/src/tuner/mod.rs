//! The unified tuning API: one request/outcome surface over every search
//! backend (rust/docs/DESIGN.md §8).
//!
//! The paper's contribution is a *joint* auto-tuning framework over the
//! (fusion scheme, MP) space, but the crate historically exposed it as five
//! differently-shaped entry points — Algorithm 1, the Table III strategies,
//! the oracle DP, the annealer, and the exhaustive certifier — each with its
//! own signature and stats reporting. This module folds them behind one
//! abstraction:
//!
//! - [`TuningRequest`]: a builder describing *what* to tune — the
//!   `(Simulator, Model)` pair, search-space constraints (MP candidate set,
//!   block-size granularity), the annealing configuration, and
//!   evaluation/wall-clock budgets;
//! - [`TuningContext`]: the per-request execution state, owning one
//!   [`crate::cost::CostEngine`] so every backend run against the same
//!   request shares the memoized `(block, mp)` cache;
//! - [`Tuner`]: the trait every search backend implements
//!   (`tune(&mut TuningContext) -> Result<TuningOutcome, TuningError>`);
//! - [`TuningOutcome`]: the uniform result — schedule, predicted latency,
//!   and [`TuningStats`] folding the old `SearchStats`, the engine's cache
//!   counters, and wall-clock time into one struct;
//! - [`compare`]: run several boxed tuners over one shared context and
//!   render the Fig. 10-style side-by-side report.
//!
//! The five backends are [`Algorithm1`], [`TableStrategy`], [`OracleDp`],
//! [`Annealer`], and [`Exhaustive`]. Each is pinned bit-identical to the
//! legacy free function it wraps (`rust/tests/tuner_parity.rs`); the legacy
//! functions remain as `#[deprecated]` shims. A sixth, model-guided backend
//! — [`crate::learn::ActiveTuner`], registered as `learned` — lives in the
//! `learn` subsystem (rust/docs/DESIGN.md §16).
//!
//! ```no_run
//! use dlfusion::prelude::*;
//!
//! let sim = Simulator::new(Target::mlu100());
//! let model = zoo::resnet18();
//! let request = TuningRequest::new(&sim, &model);
//! let outcome = request.run(&mut Algorithm1).expect("tuning");
//! println!("{}: {} predicted FPS", model.name, outcome.fps());
//! ```

pub mod outcome;
pub mod request;
pub mod backends;
pub mod compare;
pub mod parallel;

pub use backends::{backend_by_name, Algorithm1, Annealer, Exhaustive, OracleDp,
                   TableStrategy};
pub(crate) use backends::tune_over_batches;
pub use compare::{compare, compare_targets, compare_targets_with,
                  compare_threaded, Comparison, TargetComparison,
                  TargetOutcome};
pub use outcome::{TuningError, TuningOutcome, TuningStats};
pub use parallel::{run_sweep, SweepJob, SweepOutcome};
pub use request::{Budget, TuningContext, TuningRequest};

/// A search backend over the joint (fusion scheme, MP) space.
///
/// Contract (rust/docs/DESIGN.md §8, batch semantics §10):
/// - the backend evaluates candidates **only** through the context's
///   [`crate::cost::CostEngine`], so multi-tuner comparisons on one context
///   reuse each other's block evaluations;
/// - the backend co-optimizes over the request's batch candidates and the
///   returned [`TuningOutcome::predicted_ms`] is the scalar-path cost of
///   one invocation of the schedule at [`TuningOutcome::batch`] — for the
///   default batch set `[1]`, bit-identical to
///   `Simulator::run_schedule(..).total_ms` for the returned schedule;
/// - budget semantics: backends that can stop early and still hold a valid
///   best-so-far result (the annealer) truncate and set
///   [`TuningStats::truncated`]; backends whose partial state is not a
///   usable result (the DP oracle, the exhaustive certifier) return
///   [`TuningError::BudgetExhausted`] instead.
///
/// `Send` is a supertrait so boxed backends can move into worker threads
/// (the parallel comparison and sweep drivers, rust/docs/DESIGN.md §12);
/// every backend is plain data, so this costs implementors nothing.
pub trait Tuner: Send {
    /// Short backend name, used in reports and comparison tables.
    fn name(&self) -> String;

    /// Run the search through the shared context and return the uniform
    /// outcome.
    fn tune(&mut self, cx: &mut TuningContext<'_>) -> Result<TuningOutcome, TuningError>;
}
