//! The uniform result surface of a tuning run: outcome, stats, errors.

use crate::obs::{Domain, MetricsRegistry};
use crate::optimizer::schedule::Schedule;
use crate::search::brute::SearchStats;

/// Unified run statistics — the old per-backend bookkeeping
/// ([`SearchStats`], the cost engine's cache counters, ad-hoc wall-clock
/// timers) folded into one struct every [`super::Tuner`] reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TuningStats {
    /// Block-latency evaluations the backend requested from the engine.
    pub evaluations: u64,
    /// Candidate blocks examined (distinct `(start, end)` visits for the
    /// DP/exhaustive backends; equals `evaluations` for the engine-delta
    /// backends, where every query is one candidate block).
    pub blocks_considered: u64,
    /// Joint (fusion, MP) cross-product candidates certified — nonzero only
    /// for the exhaustive backend (the Eq. 4 space comparison).
    pub space_visited: u64,
    /// Evaluations served from the shared engine's memoized cache.
    pub cache_hits: u64,
    /// Evaluations the engine actually computed.
    pub cache_misses: u64,
    /// Wall-clock time of the whole `tune()` call, microseconds.
    pub wall_us: u64,
    /// Wall-clock time of the schedule-producing search phase (the DP
    /// recurrence, the annealing walk, the heuristic partition),
    /// microseconds. The remainder of `wall_us` is final-schedule pricing
    /// and per-batch bookkeeping.
    pub search_us: u64,
    /// Wall-clock time of the parallel cache-prewarm phase inside
    /// `search_us` — zero for sequential runs and for backends without a
    /// prewarm pool. The DP's own recurrence is `search_us - prewarm_us`.
    pub prewarm_us: u64,
    /// Real engine evaluations the backend *avoided* relative to sweeping
    /// its full candidate space (`|admissible blocks| × |MP set|` per
    /// batch). Nonzero only for model-guided backends — the learned active
    /// tuner ([`crate::learn::ActiveTuner`]) reports here how much of the
    /// reduced-DP reference sweep its surrogate pruned.
    pub evals_saved: u64,
    /// The run stopped early on a budget and returned its best-so-far
    /// result (only backends that can: see the [`super::Tuner`] contract).
    pub truncated: bool,
}

impl TuningStats {
    /// Fraction of evaluations served from the cache.
    pub fn hit_rate(&self) -> f64 {
        if self.evaluations == 0 {
            0.0
        } else {
            self.cache_hits as f64 / self.evaluations as f64
        }
    }

    /// Fold a legacy [`SearchStats`] (the oracle DP / exhaustive bookkeeping
    /// shape) into the unified form.
    pub fn from_search(st: &SearchStats) -> TuningStats {
        TuningStats {
            evaluations: st.evaluations as u64,
            blocks_considered: st.blocks_considered as u64,
            space_visited: st.space_visited,
            cache_hits: st.cache_hits as u64,
            cache_misses: st.cache_misses as u64,
            wall_us: st.wall_us,
            // The search function's internal wall time is the search phase;
            // the backend overwrites `wall_us` with its whole-call time.
            search_us: st.wall_us,
            prewarm_us: st.prewarm_us,
            evals_saved: 0,
            truncated: false,
        }
    }
}

/// What a [`super::Tuner`] returns: the schedule it chose, the batch size
/// it chose it for, its predicted latency, and the unified run statistics.
#[derive(Debug, Clone, PartialEq)]
pub struct TuningOutcome {
    /// Name of the backend that produced this outcome.
    pub tuner: String,
    /// The chosen schedule.
    pub schedule: Schedule,
    /// The batch size the schedule was tuned for — the winning candidate of
    /// the request's batch set (always 1 for the default `[1]` request,
    /// where every result is bit-identical to the pre-batch tuners).
    pub batch: usize,
    /// Predicted latency of one invocation of `schedule` at `batch`, ms —
    /// at batch 1 bit-identical to `Simulator::run_schedule(..).total_ms`.
    pub predicted_ms: f64,
    pub stats: TuningStats,
}

impl TuningOutcome {
    /// Predicted frames (samples) per second: a batch-`b` invocation
    /// retires `b` samples. At batch 1 this is the paper's Fig. 10 metric.
    pub fn fps(&self) -> f64 {
        self.batch as f64 * 1000.0 / self.predicted_ms
    }

    /// Predicted per-sample latency, ms — the joint `(mp, batch)` search's
    /// objective (equals `predicted_ms` at batch 1).
    pub fn per_sample_ms(&self) -> f64 {
        self.predicted_ms / self.batch as f64
    }

    /// Export the outcome into the unified registry (rust/docs/DESIGN.md
    /// §14). Search-space quantities — evaluation counts, cache counters,
    /// the predicted latency — are reproducible for a fixed request and
    /// land in [`Domain::Sim`]; every timer (whole call, search phase,
    /// prewarm phase) is machine-dependent and lands in [`Domain::Wall`].
    pub fn export_metrics(&self, reg: &mut MetricsRegistry) {
        reg.inc(Domain::Sim, "tuner.evaluations", self.stats.evaluations);
        reg.inc(Domain::Sim, "tuner.blocks_considered", self.stats.blocks_considered);
        reg.inc(Domain::Sim, "tuner.space_visited", self.stats.space_visited);
        reg.inc(Domain::Sim, "tuner.cache_hits", self.stats.cache_hits);
        reg.inc(Domain::Sim, "tuner.cache_misses", self.stats.cache_misses);
        reg.set_gauge(Domain::Sim, "tuner.cache_hit_rate", self.stats.hit_rate());
        reg.set_gauge(Domain::Sim, "tuner.predicted_ms", self.predicted_ms);
        reg.set_gauge(Domain::Sim, "tuner.batch", self.batch as f64);
        reg.set_gauge(Domain::Sim, "tuner.schedule_blocks",
                      self.schedule.num_blocks() as f64);
        reg.inc(Domain::Sim, "tuner.evals_saved", self.stats.evals_saved);
        reg.inc(Domain::Sim, "tuner.truncated", u64::from(self.stats.truncated));
        reg.inc(Domain::Wall, "tuner.wall_us", self.stats.wall_us);
        reg.inc(Domain::Wall, "tuner.search_us", self.stats.search_us);
        reg.inc(Domain::Wall, "tuner.prewarm_us", self.stats.prewarm_us);
    }
}

/// Why a tuning run could not produce an outcome.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TuningError {
    /// The request's MP candidate set is empty.
    EmptyMpSet,
    /// An MP candidate is zero or exceeds the accelerator's core count.
    InvalidMp { mp: usize, num_cores: usize },
    /// The request's batch candidate set is empty.
    EmptyBatchSet,
    /// A batch candidate is zero (a batched invocation carries >= 1 sample).
    InvalidBatch { batch: usize },
    /// The exhaustive backend refuses exponential blowup past `max` layers.
    ModelTooLarge { layers: usize, max: usize },
    /// An evaluation budget ran out before the backend could complete (only
    /// backends without a usable partial result report this; the annealer
    /// truncates instead).
    BudgetExhausted { spent: u64, budget: u64 },
    /// The request is malformed in some other way.
    InvalidRequest(String),
}

impl std::fmt::Display for TuningError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TuningError::EmptyMpSet => write!(f, "MP candidate set is empty"),
            TuningError::InvalidMp { mp, num_cores } => {
                write!(f, "MP candidate {mp} outside 1..={num_cores}")
            }
            TuningError::EmptyBatchSet => write!(f, "batch candidate set is empty"),
            TuningError::InvalidBatch { batch } => {
                write!(f, "batch candidate {batch} must be at least 1")
            }
            TuningError::ModelTooLarge { layers, max } => write!(
                f,
                "exhaustive search is exponential: model has {layers} layers (max {max})"
            ),
            TuningError::BudgetExhausted { spent, budget } => write!(
                f,
                "evaluation budget exhausted: {spent} of {budget} evaluations \
                 spent before the search could complete"
            ),
            TuningError::InvalidRequest(s) => write!(f, "invalid tuning request: {s}"),
        }
    }
}

impl std::error::Error for TuningError {}
