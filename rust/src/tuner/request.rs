//! Building a tuning run: the declarative request and the shared context.

use crate::accel::Simulator;
use crate::cost::{CostEngine, CostStats};
use crate::graph::Model;
use crate::optimizer::algorithm::AlgorithmParams;
use crate::search::annealing::AnnealConfig;
use crate::search::brute::BlockRule;

use super::compare::{compare_threaded, Comparison};
use super::outcome::{TuningError, TuningOutcome};
use super::Tuner;

/// Evaluation / wall-clock budgets for a tuning run.
///
/// Semantics (rust/docs/DESIGN.md §8): `max_evaluations` caps the number of
/// block-latency evaluations a backend may request from the shared engine
/// (cache hits count — the budget bounds *search effort*, not compute). The
/// annealer also honours `max_wall_us`, checked once per Metropolis move.
/// Backends that cannot yield a valid partial result (DP, exhaustive —
/// including Table III strategy 7, which *is* the reduced DP) return
/// [`TuningError::BudgetExhausted`]; the annealer truncates and reports
/// [`super::TuningStats::truncated`]. `Algorithm1` and strategies 1–6 are
/// effectively free (O(n) walks plus a bounded sweep) and ignore budgets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Budget {
    pub max_evaluations: Option<u64>,
    pub max_wall_us: Option<u64>,
}

impl Budget {
    pub fn unlimited() -> Budget {
        Budget::default()
    }

    pub fn is_unlimited(&self) -> bool {
        self.max_evaluations.is_none() && self.max_wall_us.is_none()
    }
}

/// Declarative description of one tuning run over a `(Simulator, Model)`
/// pair: search-space constraints, annealing configuration, Algorithm 1
/// parameters, and budgets. Build with the fluent methods, then either
/// [`TuningRequest::run`] one backend, [`TuningRequest::compare`] several,
/// or take a [`TuningRequest::context`] and drive tuners by hand (every
/// backend run against one context shares its memoized cost cache).
#[derive(Debug, Clone)]
pub struct TuningRequest<'a> {
    sim: &'a Simulator,
    model: &'a Model,
    mp_candidates: Option<Vec<usize>>,
    batch_candidates: Option<Vec<usize>>,
    granularity: BlockRule,
    anneal: AnnealConfig,
    params: Option<AlgorithmParams>,
    budget: Budget,
    threads: usize,
    allowed_cuts: Option<Vec<usize>>,
}

impl<'a> TuningRequest<'a> {
    /// A request with the paper defaults: the spec's reduced MP set, batch
    /// candidates `[1]`, multiple-of-four block granularity, default
    /// annealing config, `AlgorithmParams::for_spec`, no budgets, and one
    /// worker thread.
    pub fn new(sim: &'a Simulator, model: &'a Model) -> TuningRequest<'a> {
        TuningRequest {
            sim,
            model,
            mp_candidates: None,
            batch_candidates: None,
            granularity: BlockRule::MultipleOfFour,
            anneal: AnnealConfig::default(),
            params: None,
            budget: Budget::default(),
            threads: 1,
            allowed_cuts: None,
        }
    }

    /// Constrain the MP candidate set (used by the constrained oracle DP
    /// and the exhaustive backend). Defaults to `spec.reduced_mp_set()`.
    pub fn mp_candidates(mut self, mps: Vec<usize>) -> Self {
        self.mp_candidates = Some(mps);
        self
    }

    /// The batch sizes every backend co-optimizes over: the search runs
    /// once per candidate (each run batch-aware through the shared engine's
    /// active batch) and the outcome with the lowest predicted *per-sample*
    /// latency wins, ties preferring the earlier candidate. Defaults to
    /// `[1]`, where every backend is bit-identical to its pre-batch self
    /// (rust/docs/DESIGN.md §10).
    pub fn batch_candidates(mut self, batches: Vec<usize>) -> Self {
        self.batch_candidates = Some(batches);
        self
    }

    /// Block-size granularity for the constrained oracle DP. Defaults to
    /// the paper's multiple-of-four rule.
    pub fn granularity(mut self, rule: BlockRule) -> Self {
        self.granularity = rule;
        self
    }

    /// Restrict fusion boundaries to the given cut positions (a position
    /// `p` means "between layer `p-1` and layer `p`"; 0 and `n` are always
    /// implied). This is how DAG workloads tune: the linearizer's
    /// fusion-legal cut set ([`crate::graph::dag::Linearization::cuts`])
    /// becomes the searchable boundary set, so no block ever straddles a
    /// branching region. `None` (the default) leaves every boundary legal —
    /// all backends are bit-identical to their unconstrained selves
    /// (rust/docs/DESIGN.md §13).
    pub fn allowed_cuts(mut self, cuts: Vec<usize>) -> Self {
        self.allowed_cuts = Some(cuts);
        self
    }

    /// Configuration for the [`super::Annealer`] backend.
    pub fn anneal_config(mut self, cfg: AnnealConfig) -> Self {
        self.anneal = cfg;
        self
    }

    /// Override Algorithm 1's parameters (threshold, Eq. 5 weights).
    pub fn params(mut self, params: AlgorithmParams) -> Self {
        self.params = Some(params);
        self
    }

    /// Cap block-latency evaluations (see [`Budget`]).
    pub fn max_evaluations(mut self, n: u64) -> Self {
        self.budget.max_evaluations = Some(n);
        self
    }

    /// Cap wall-clock time, microseconds (see [`Budget`]).
    pub fn max_wall_us(mut self, us: u64) -> Self {
        self.budget.max_wall_us = Some(us);
        self
    }

    /// Fan the run across `threads` workers (clamped to at least 1; the
    /// default 1 is the plain sequential path with no thread machinery).
    /// [`TuningRequest::run`] gives the DP/exhaustive backends intra-search
    /// parallelism; [`TuningRequest::compare`] additionally fans the
    /// backends themselves across workers sharing one concurrent cache.
    /// Results are bit-identical to sequential either way
    /// (rust/docs/DESIGN.md §12). Budgeted searches ignore the knob — the
    /// budget's abort point is defined by the sequential visit order.
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    pub fn sim(&self) -> &'a Simulator {
        self.sim
    }

    /// Registry name of the hardware target this request tunes for (the
    /// simulator's — every outcome of the request is *for* that hardware).
    pub fn target(&self) -> &'a str {
        self.sim.target()
    }

    pub fn model(&self) -> &'a Model {
        self.model
    }

    /// Materialize the execution state: one fresh [`CostEngine`] plus the
    /// resolved constraints. Cheap relative to any search; reuse one context
    /// across backends to share the cache.
    pub fn context(&self) -> TuningContext<'a> {
        TuningContext {
            engine: CostEngine::new(self.sim, self.model),
            mp_candidates: self
                .mp_candidates
                .clone()
                .unwrap_or_else(|| self.sim.spec.reduced_mp_set()),
            batch_candidates: self
                .batch_candidates
                .clone()
                .unwrap_or_else(|| vec![1]),
            granularity: self.granularity,
            anneal: self.anneal,
            params: self
                .params
                .unwrap_or_else(|| AlgorithmParams::for_spec(&self.sim.spec)),
            budget: self.budget,
            threads: self.threads,
            allowed_cuts: self.allowed_cuts.clone(),
        }
    }

    /// Run one backend over a fresh context.
    pub fn run(&self, tuner: &mut dyn Tuner) -> Result<TuningOutcome, TuningError> {
        tuner.tune(&mut self.context())
    }

    /// Run several backends over one shared context (see
    /// [`super::compare`]); with [`TuningRequest::threads`] > 1 the
    /// backends are fanned across workers sharing the context's concurrent
    /// cache, bit-identical to the sequential run.
    pub fn compare(&self, tuners: &mut [Box<dyn Tuner>]) -> Result<Comparison, TuningError> {
        compare_threaded(&mut self.context(), tuners, self.threads)
    }

    /// Re-point this request's constraints at another `(sim, model)` pair.
    /// The cross-target comparison ([`super::compare_targets`]) uses this to
    /// apply one set of knobs to every hardware point; an unset MP candidate
    /// set stays unset, so each target derives its own reduced MP set.
    pub fn for_sim<'b>(&self, sim: &'b Simulator, model: &'b Model) -> TuningRequest<'b> {
        TuningRequest {
            sim,
            model,
            mp_candidates: self.mp_candidates.clone(),
            batch_candidates: self.batch_candidates.clone(),
            granularity: self.granularity,
            anneal: self.anneal,
            params: self.params,
            budget: self.budget,
            threads: self.threads,
            allowed_cuts: self.allowed_cuts.clone(),
        }
    }
}

/// Per-request execution state shared by every backend run against it: the
/// memoized cost engine plus the request's resolved constraints.
pub struct TuningContext<'a> {
    pub(crate) engine: CostEngine<'a>,
    pub(crate) mp_candidates: Vec<usize>,
    pub(crate) batch_candidates: Vec<usize>,
    pub(crate) granularity: BlockRule,
    pub(crate) anneal: AnnealConfig,
    pub(crate) params: AlgorithmParams,
    pub(crate) budget: Budget,
    pub(crate) threads: usize,
    pub(crate) allowed_cuts: Option<Vec<usize>>,
}

impl<'a> TuningContext<'a> {
    /// The shared engine — evaluation methods take `&self`, so this is all
    /// a read-only consumer (plan annotation, cache prewarming) needs.
    pub fn engine(&self) -> &CostEngine<'a> {
        &self.engine
    }

    /// The shared engine, mutably (to re-target its active batch or reset
    /// its counters; plain evaluation only needs [`TuningContext::engine`]).
    pub fn engine_mut(&mut self) -> &mut CostEngine<'a> {
        &mut self.engine
    }

    /// A second context onto the same request state for a concurrent
    /// worker: same resolved constraints, an engine handle sharing the
    /// cache ([`CostEngine::worker`]), `threads` pinned to 1 (the fork *is*
    /// the unit of parallelism).
    pub fn fork(&self) -> TuningContext<'a> {
        TuningContext {
            engine: self.engine.worker(),
            mp_candidates: self.mp_candidates.clone(),
            batch_candidates: self.batch_candidates.clone(),
            granularity: self.granularity,
            anneal: self.anneal,
            params: self.params,
            budget: self.budget,
            threads: 1,
            allowed_cuts: self.allowed_cuts.clone(),
        }
    }

    /// Worker threads the request asked for (1 = sequential).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Re-constrain the MP candidate set without rebuilding the context.
    /// The serving allocator sweeps MP caps this way: every sweep step
    /// shares the engine's memoized `(block, mp)` cache, so capping the set
    /// costs only the candidates the cache has not seen yet.
    pub fn set_mp_candidates(&mut self, mps: Vec<usize>) {
        self.mp_candidates = mps;
    }

    /// Re-constrain the batch candidate set without rebuilding the context
    /// (the engine's cache is keyed by batch, so nothing is invalidated).
    pub fn set_batch_candidates(&mut self, batches: Vec<usize>) {
        self.batch_candidates = batches;
    }

    /// Engine counter snapshot (accumulated across every backend run
    /// against this context).
    pub fn engine_stats(&self) -> CostStats {
        self.engine.stats()
    }

    pub fn sim(&self) -> &'a Simulator {
        self.engine.sim()
    }

    /// Registry name of the hardware target this context tunes for.
    pub fn target(&self) -> &'a str {
        self.engine.sim().target()
    }

    pub fn model(&self) -> &'a Model {
        self.engine.model()
    }

    pub fn mp_candidates(&self) -> &[usize] {
        &self.mp_candidates
    }

    pub fn batch_candidates(&self) -> &[usize] {
        &self.batch_candidates
    }

    pub fn granularity(&self) -> BlockRule {
        self.granularity
    }

    pub fn anneal_config(&self) -> AnnealConfig {
        self.anneal
    }

    pub fn params(&self) -> AlgorithmParams {
        self.params
    }

    pub fn budget(&self) -> Budget {
        self.budget
    }

    /// The request's cut-position constraint (see
    /// [`TuningRequest::allowed_cuts`]); `None` means every boundary is
    /// legal.
    pub fn allowed_cuts(&self) -> Option<&[usize]> {
        self.allowed_cuts.as_deref()
    }

    /// The cut constraint as a per-boundary legality mask of length `n + 1`
    /// (index `p` = "may a block boundary sit before layer `p`"), validated
    /// against the model. `Ok(None)` when the request is unconstrained —
    /// the backends' fast path, bit-identical to the pre-DAG code. The
    /// model's two ends are always legal whether listed or not.
    pub(crate) fn checked_cut_mask(&self) -> Result<Option<Vec<bool>>, TuningError> {
        let cuts = match &self.allowed_cuts {
            None => return Ok(None),
            Some(c) => c,
        };
        let n = self.engine.model().num_layers();
        let mut mask = vec![false; n + 1];
        for &p in cuts {
            if p > n {
                return Err(TuningError::InvalidRequest(format!(
                    "allowed cut position {p} beyond the model's {n} layers"
                )));
            }
            mask[p] = true;
        }
        mask[0] = true;
        mask[n] = true;
        Ok(Some(mask))
    }

    /// The MP candidate set, validated against the accelerator.
    pub(crate) fn checked_mps(&self) -> Result<Vec<usize>, TuningError> {
        if self.mp_candidates.is_empty() {
            return Err(TuningError::EmptyMpSet);
        }
        let num_cores = self.engine.sim().spec.num_cores;
        for &mp in &self.mp_candidates {
            if mp == 0 || mp > num_cores {
                return Err(TuningError::InvalidMp { mp, num_cores });
            }
        }
        Ok(self.mp_candidates.clone())
    }

    /// The batch candidate set, validated (non-empty, every batch >= 1).
    pub(crate) fn checked_batches(&self) -> Result<Vec<usize>, TuningError> {
        if self.batch_candidates.is_empty() {
            return Err(TuningError::EmptyBatchSet);
        }
        for &batch in &self.batch_candidates {
            if batch == 0 {
                return Err(TuningError::InvalidBatch { batch });
            }
        }
        Ok(self.batch_candidates.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::Target;
    use crate::zoo;

    #[test]
    fn unconstrained_request_has_no_cut_mask() {
        let sim = Simulator::new(Target::mlu100());
        let m = zoo::alexnet();
        let cx = TuningRequest::new(&sim, &m).context();
        assert_eq!(cx.checked_cut_mask().unwrap(), None);
        assert_eq!(cx.allowed_cuts(), None);
    }

    #[test]
    fn cut_mask_marks_positions_and_forces_the_ends() {
        let sim = Simulator::new(Target::mlu100());
        let m = zoo::alexnet();
        let n = m.num_layers();
        let cx = TuningRequest::new(&sim, &m).allowed_cuts(vec![3, 5]).context();
        let mask = cx.checked_cut_mask().unwrap().unwrap();
        assert_eq!(mask.len(), n + 1);
        assert!(mask[0] && mask[n], "ends are always legal");
        assert!(mask[3] && mask[5]);
        assert!(!mask[1] && !mask[2] && !mask[4]);
    }

    #[test]
    fn out_of_range_cut_position_is_rejected() {
        let sim = Simulator::new(Target::mlu100());
        let m = zoo::alexnet();
        let n = m.num_layers();
        let cx = TuningRequest::new(&sim, &m).allowed_cuts(vec![n + 1]).context();
        assert!(matches!(cx.checked_cut_mask(),
                         Err(TuningError::InvalidRequest(_))));
    }

    #[test]
    fn cut_constraint_survives_fork_and_for_sim() {
        let sim = Simulator::new(Target::mlu100());
        let m = zoo::alexnet();
        let req = TuningRequest::new(&sim, &m).allowed_cuts(vec![4]);
        assert_eq!(req.context().fork().allowed_cuts(), Some(&[4usize][..]));
        let sim2 = Simulator::new(Target::mlu100());
        let re = req.for_sim(&sim2, &m);
        assert_eq!(re.context().allowed_cuts(), Some(&[4usize][..]));
    }
}
