//! The parallel sweep driver: fan independent `(model, target, backend,
//! batches)` tuning jobs across a worker pool (rust/docs/DESIGN.md §12).
//!
//! Jobs share nothing — each worker builds its own simulator, cost engine,
//! and backend — so the result of every job is bit-identical to running it
//! alone, regardless of thread count or completion order. This is the
//! coarse-grained layer of the concurrency model (the CLI's `tune`,
//! `perf-smoke`, and the zoo parity suite drive it); the fine-grained layer
//! is the shared-cache fork in [`super::compare_threaded`] and the
//! intra-search prewarm inside the DP/exhaustive backends.

use crate::accel::{Simulator, Target};
use crate::graph::Model;
use crate::util::ParallelMap;

use super::backends::backend_by_name;
use super::outcome::{TuningError, TuningOutcome};
use super::request::TuningRequest;

/// One independent unit of a tuning sweep: tune `model` on `target` with
/// the backend named as in the CLI (`super::backend_by_name`), co-optimized
/// over `batches` (empty means the default `[1]`).
#[derive(Debug, Clone)]
pub struct SweepJob<'a> {
    pub model: &'a Model,
    pub target: Target,
    pub backend: String,
    pub batches: Vec<usize>,
}

impl<'a> SweepJob<'a> {
    pub fn new(model: &'a Model, target: Target, backend: &str) -> SweepJob<'a> {
        SweepJob { model, target, backend: backend.to_string(), batches: Vec::new() }
    }

    pub fn batches(mut self, batches: Vec<usize>) -> Self {
        self.batches = batches;
        self
    }
}

/// One finished sweep job: the job description paired with its result.
#[derive(Debug)]
pub struct SweepOutcome<'a> {
    pub job: SweepJob<'a>,
    pub result: Result<TuningOutcome, TuningError>,
}

/// Run every job across `threads` workers (1 = plain sequential loop) and
/// return the outcomes in job order. A failing job — unknown backend name,
/// invalid MP/batch for its target — yields an `Err` row without touching
/// its neighbours.
pub fn run_sweep<'a>(jobs: &[SweepJob<'a>], threads: usize) -> Vec<SweepOutcome<'a>> {
    let results = ParallelMap::new(threads).map(jobs, |_, job| {
        let sim = Simulator::new(job.target.clone());
        let mut request = TuningRequest::new(&sim, job.model);
        if !job.batches.is_empty() {
            request = request.batch_candidates(job.batches.clone());
        }
        let mut tuner = backend_by_name(&job.backend).map_err(TuningError::InvalidRequest)?;
        tuner.tune(&mut request.context())
    });
    jobs.iter()
        .cloned()
        .zip(results)
        .map(|(job, result)| SweepOutcome { job, result })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo;

    #[test]
    fn sweep_outcomes_are_thread_count_invariant() {
        let models = [zoo::by_name("alexnet").unwrap(), zoo::by_name("resnet18").unwrap()];
        let jobs: Vec<SweepJob<'_>> = models
            .iter()
            .flat_map(|m| {
                [Target::mlu100(), Target::edge4()].into_iter().flat_map(move |t| {
                    ["algorithm1", "oracle"]
                        .into_iter()
                        .map(move |b| SweepJob::new(m, t.clone(), b))
                })
            })
            .collect();
        let seq = run_sweep(&jobs, 1);
        let par = run_sweep(&jobs, 4);
        assert_eq!(seq.len(), par.len());
        for (s, p) in seq.iter().zip(&par) {
            let (s, p) = (s.result.as_ref().unwrap(), p.result.as_ref().unwrap());
            assert_eq!(s.schedule, p.schedule);
            assert_eq!(s.predicted_ms.to_bits(), p.predicted_ms.to_bits());
            assert_eq!(s.batch, p.batch);
            assert_eq!(s.stats.evaluations, p.stats.evaluations);
            assert_eq!(s.stats.cache_misses, p.stats.cache_misses);
        }
    }

    #[test]
    fn unknown_backend_fails_only_its_job() {
        let model = zoo::by_name("alexnet").unwrap();
        let jobs = vec![
            SweepJob::new(&model, Target::mlu100(), "no-such-backend"),
            SweepJob::new(&model, Target::mlu100(), "algorithm1"),
        ];
        let out = run_sweep(&jobs, 2);
        assert!(out[0].result.is_err());
        assert!(out[1].result.is_ok());
    }
}
