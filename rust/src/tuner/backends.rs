//! The five search backends behind the [`Tuner`] trait.
//!
//! Each wraps the corresponding engine-level search function and is pinned
//! bit-identical to it (`rust/tests/tuner_parity.rs`): same schedule, same
//! predicted latency, for the same request defaults. Every backend
//! co-optimizes over the request's batch candidates through one shared
//! loop (`tune_over_batches`): the search body runs once per batch with
//! the engine's active batch set, and the per-sample-fastest outcome wins
//! (rust/docs/DESIGN.md §10). The default `[1]` set keeps the pre-batch
//! behaviour exactly.

use std::time::Instant;

use crate::cost::CostStats;
use crate::optimizer::algorithm::{dlfusion_schedule_masked, dlfusion_schedule_with};
use crate::optimizer::schedule::Schedule;
use crate::optimizer::strategies::{strategy_schedule_with, Strategy};
use crate::search::annealing;
use crate::search::brute::{self, BlockRule};
use crate::search::exhaustive::{self, ExhaustiveError};

use super::outcome::{TuningError, TuningOutcome, TuningStats};
use super::request::TuningContext;
use super::Tuner;

/// Unified stats for backends whose bookkeeping is the engine-counter delta
/// (every query is one candidate-block evaluation). `search_us` is the
/// schedule-producing phase's share of `wall_us` (rust/docs/DESIGN.md §14);
/// these backends have no prewarm pool, so `prewarm_us` stays zero.
fn delta_stats(before: CostStats, after: CostStats, wall_us: u64, search_us: u64,
               truncated: bool) -> TuningStats {
    let hits = after.hits - before.hits;
    let misses = after.misses - before.misses;
    TuningStats {
        evaluations: hits + misses,
        blocks_considered: hits + misses,
        space_visited: 0,
        cache_hits: hits,
        cache_misses: misses,
        wall_us,
        search_us,
        prewarm_us: 0,
        evals_saved: 0,
        truncated,
    }
}

/// Run a backend's single-batch search at every batch candidate of the
/// request and keep the outcome with the lowest predicted *per-sample*
/// latency (ties prefer the earlier candidate). Each run sets the shared
/// engine's active batch, so the body's block evaluations — the DP's
/// sweeps, the annealer's moves, the strategy sweeps — are batch-aware
/// without any change to the search code; the engine's cache keys keep the
/// batches separate. The returned [`TuningStats`] aggregate the whole
/// joint search (every candidate's evaluations, cache counters, and wall
/// time — not just the winner's), so tune/compare reports state the true
/// search cost. With the default `[1]` candidate set this is exactly one
/// batch-1 run, bit-identical to the pre-batch backends. Budgets bound
/// each candidate's search independently; the first failing candidate
/// aborts the whole run.
pub(crate) fn tune_over_batches<F>(cx: &mut TuningContext<'_>,
                                   mut body: F) -> Result<TuningOutcome, TuningError>
where
    F: FnMut(&mut TuningContext<'_>) -> Result<TuningOutcome, TuningError>,
{
    let batches = cx.checked_batches()?;
    let mut best: Option<TuningOutcome> = None;
    let mut total = TuningStats::default();
    for &batch in &batches {
        cx.engine_mut().set_batch(batch);
        let result = body(cx);
        // Leave the context at the default batch whether or not the body
        // succeeded, so later consumers of the shared engine start clean.
        cx.engine_mut().set_batch(1);
        let out = result?;
        debug_assert_eq!(out.batch, batch, "backend must report its batch");
        total.evaluations += out.stats.evaluations;
        total.blocks_considered += out.stats.blocks_considered;
        total.space_visited += out.stats.space_visited;
        total.cache_hits += out.stats.cache_hits;
        total.cache_misses += out.stats.cache_misses;
        total.wall_us += out.stats.wall_us;
        total.search_us += out.stats.search_us;
        total.prewarm_us += out.stats.prewarm_us;
        total.evals_saved += out.stats.evals_saved;
        total.truncated |= out.stats.truncated;
        let better = match &best {
            None => true,
            Some(b) => out.per_sample_ms() < b.per_sample_ms(),
        };
        if better {
            best = Some(out);
        }
    }
    let mut best = best.expect("checked_batches is non-empty");
    best.stats = total;
    Ok(best)
}

/// The paper's Algorithm 1: the O(n) joint fusion + MP heuristic. Uses the
/// context's [`crate::optimizer::AlgorithmParams`]; its only engine queries
/// are the final schedule costing, so budgets never bind. The heuristic's
/// partition is batch-independent; over a multi-batch request the batch
/// loop prices the same schedule per candidate and serves the per-sample
/// winner.
#[derive(Debug, Clone, Copy, Default)]
pub struct Algorithm1;

impl Algorithm1 {
    fn tune_at_batch(&mut self, cx: &mut TuningContext<'_>)
                     -> Result<TuningOutcome, TuningError> {
        let t0 = Instant::now();
        let before = cx.engine.local_stats();
        let batch = cx.engine.batch();
        let params = cx.params;
        let spec = &cx.engine.sim().spec;
        let schedule = match cx.checked_cut_mask()? {
            Some(mask) => dlfusion_schedule_masked(cx.engine.model(), spec, &params, &mask),
            None => dlfusion_schedule_with(cx.engine.model(), spec, &params),
        };
        let search_us = t0.elapsed().as_micros() as u64;
        let predicted_ms = cx.engine.schedule_cost(&schedule);
        let stats = delta_stats(before, cx.engine.local_stats(),
                                t0.elapsed().as_micros() as u64, search_us, false);
        Ok(TuningOutcome { tuner: self.name(), schedule, batch, predicted_ms, stats })
    }
}

impl Tuner for Algorithm1 {
    fn name(&self) -> String {
        "algorithm1".into()
    }

    fn tune(&mut self, cx: &mut TuningContext<'_>) -> Result<TuningOutcome, TuningError> {
        tune_over_batches(cx, |cx| self.tune_at_batch(cx))
    }
}

/// One of the seven Table III evaluation strategies (strategy 6 is
/// [`Algorithm1`] itself; strategy 7 runs the reduced oracle DP). The
/// strategies pin the paper's definitions — sweep-based strategies use the
/// spec's reduced MP set regardless of the request's candidate constraint.
/// Strategy 7 is the one Table III entry where an evaluation budget can
/// bind (it *is* the O(n²·|MP|) DP) and errors like [`OracleDp`] does;
/// the others' bounded sweeps ignore budgets.
#[derive(Debug, Clone, Copy)]
pub struct TableStrategy(pub Strategy);

impl TableStrategy {
    fn tune_at_batch(&mut self, cx: &mut TuningContext<'_>)
                     -> Result<TuningOutcome, TuningError> {
        // The Table III strategies pin the paper's linear-chain definitions;
        // a cut-constrained (DAG) workload has no Table III row.
        if cx.allowed_cuts.is_some() {
            return Err(TuningError::InvalidRequest(
                "Table III strategies are defined over linear chains; \
                 cut-constrained (DAG) workloads need algorithm1, the \
                 oracle DP, annealing, or exhaustive"
                    .into(),
            ));
        }
        let t0 = Instant::now();
        let before = cx.engine.local_stats();
        let batch = cx.engine.batch();
        let params = cx.params;
        let mut prewarm_us = 0;
        let schedule = if self.0 == Strategy::BruteForce {
            // Same search `strategy_schedule_with` delegates to
            // (`oracle_schedule_with`: reduced MP set, blocks % 4), but
            // budget-checked like every other DP run.
            let mps = cx.engine.sim().spec.reduced_mp_set();
            let (schedule, st) =
                brute::oracle_schedule_threaded(&mut cx.engine, &mps,
                                                BlockRule::MultipleOfFour,
                                                cx.budget.max_evaluations, cx.threads)
                    .map_err(|e| TuningError::BudgetExhausted {
                        spent: e.evaluations,
                        budget: e.budget,
                    })?;
            prewarm_us = st.prewarm_us;
            schedule
        } else {
            strategy_schedule_with(&mut cx.engine, self.0, &params)
        };
        let search_us = t0.elapsed().as_micros() as u64;
        let predicted_ms = cx.engine.schedule_cost(&schedule);
        let mut stats = delta_stats(before, cx.engine.local_stats(),
                                    t0.elapsed().as_micros() as u64, search_us, false);
        stats.prewarm_us = prewarm_us;
        Ok(TuningOutcome { tuner: self.name(), schedule, batch, predicted_ms, stats })
    }
}

impl Tuner for TableStrategy {
    fn name(&self) -> String {
        format!("strategy{} ({})", self.0.index(), self.0.name())
    }

    fn tune(&mut self, cx: &mut TuningContext<'_>) -> Result<TuningOutcome, TuningError> {
        tune_over_batches(cx, |cx| self.tune_at_batch(cx))
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum OracleSpace {
    Reduced,
    Full,
    Constrained,
}

/// The exact shortest-path DP over cut positions (strategy 7's engine).
///
/// Three presets: [`OracleDp::reduced`] is the paper's reduced space
/// (reduced MP set, blocks % 4), [`OracleDp::full`] sweeps every block size
/// and power-of-two MP, and [`OracleDp::constrained`] honours the request's
/// MP candidates and block granularity.
#[derive(Debug, Clone, Copy)]
pub struct OracleDp {
    space: OracleSpace,
}

impl OracleDp {
    /// Paper preset (strategy 7): reduced MP set, multiple-of-four blocks.
    pub fn reduced() -> OracleDp {
        OracleDp { space: OracleSpace::Reduced }
    }

    /// Full-space preset: any block size, every power-of-two MP.
    pub fn full() -> OracleDp {
        OracleDp { space: OracleSpace::Full }
    }

    /// Honour the request's MP candidate set and block granularity.
    pub fn constrained() -> OracleDp {
        OracleDp { space: OracleSpace::Constrained }
    }
}

impl OracleDp {
    fn tune_at_batch(&mut self, cx: &mut TuningContext<'_>)
                     -> Result<TuningOutcome, TuningError> {
        let t0 = Instant::now();
        let batch = cx.engine.batch();
        let spec = &cx.engine.sim().spec;
        let (mps, rule) = match self.space {
            OracleSpace::Reduced => (spec.reduced_mp_set(), BlockRule::MultipleOfFour),
            OracleSpace::Full => (brute::full_mp_set(spec.num_cores), BlockRule::Any),
            OracleSpace::Constrained => (cx.checked_mps()?, cx.granularity),
        };
        if mps.is_empty() {
            return Err(TuningError::EmptyMpSet);
        }
        let mask = cx.checked_cut_mask()?;
        let (schedule, st) =
            brute::oracle_schedule_masked(&mut cx.engine, &mps, rule, mask.as_deref(),
                                          cx.budget.max_evaluations, cx.threads)
                .map_err(|e| TuningError::BudgetExhausted {
                    spent: e.evaluations,
                    budget: e.budget,
                })?;
        let predicted_ms = cx.engine.schedule_cost(&schedule);
        let mut stats = TuningStats::from_search(&st);
        stats.wall_us = t0.elapsed().as_micros() as u64;
        Ok(TuningOutcome { tuner: self.name(), schedule, batch, predicted_ms, stats })
    }
}

impl Tuner for OracleDp {
    fn name(&self) -> String {
        match self.space {
            OracleSpace::Reduced => "oracle-dp (reduced)".into(),
            OracleSpace::Full => "oracle-dp (full)".into(),
            OracleSpace::Constrained => "oracle-dp (constrained)".into(),
        }
    }

    fn tune(&mut self, cx: &mut TuningContext<'_>) -> Result<TuningOutcome, TuningError> {
        tune_over_batches(cx, |cx| self.tune_at_batch(cx))
    }
}

/// Simulated annealing over the unreduced joint space. Configuration comes
/// from the request ([`crate::search::AnnealConfig`]); the optional seed
/// schedule warm-starts the walk. The only backend that honours budgets by
/// truncation: it stops mid-walk and returns its best-so-far schedule.
#[derive(Debug, Clone, Default)]
pub struct Annealer {
    /// Start from this schedule instead of the layer-wise MP=1 baseline.
    pub init: Option<Schedule>,
}

impl Annealer {
    /// Anneal from the layer-wise MP=1 baseline.
    pub fn new() -> Annealer {
        Annealer { init: None }
    }

    /// Warm-start from a seed schedule (e.g. an [`Algorithm1`] outcome).
    pub fn from_schedule(init: Schedule) -> Annealer {
        Annealer { init: Some(init) }
    }
}

impl Annealer {
    fn tune_at_batch(&mut self, cx: &mut TuningContext<'_>)
                     -> Result<TuningOutcome, TuningError> {
        let t0 = Instant::now();
        let before = cx.engine.local_stats();
        let batch = cx.engine.batch();
        let cfg = cx.anneal;
        let mask = cx.checked_cut_mask()?;
        let (schedule, best_cost, truncated) = annealing::anneal_masked(
            &mut cx.engine,
            &cfg,
            self.init.clone(),
            mask.as_deref(),
            cx.budget.max_evaluations,
            cx.budget.max_wall_us,
        );
        let search_us = t0.elapsed().as_micros() as u64;
        let stats = delta_stats(before, cx.engine.local_stats(),
                                t0.elapsed().as_micros() as u64, search_us, truncated);
        Ok(TuningOutcome {
            tuner: self.name(),
            schedule,
            batch,
            // The trajectory's best cost is the scalar-path schedule cost of
            // `schedule` (same cache entries), so the predicted-latency
            // contract holds without re-walking the schedule.
            predicted_ms: best_cost,
            stats,
        })
    }
}

impl Tuner for Annealer {
    fn name(&self) -> String {
        "annealing".into()
    }

    fn tune(&mut self, cx: &mut TuningContext<'_>) -> Result<TuningOutcome, TuningError> {
        tune_over_batches(cx, |cx| self.tune_at_batch(cx))
    }
}

/// True exhaustive enumeration over every contiguous partition × the
/// request's MP candidates. Exponential: refuses models past
/// [`crate::search::exhaustive::MAX_EXHAUSTIVE_LAYERS`] layers with
/// [`TuningError::ModelTooLarge`].
#[derive(Debug, Clone, Copy, Default)]
pub struct Exhaustive;

impl Exhaustive {
    fn tune_at_batch(&mut self, cx: &mut TuningContext<'_>)
                     -> Result<TuningOutcome, TuningError> {
        let t0 = Instant::now();
        let batch = cx.engine.batch();
        let mps = cx.checked_mps()?;
        let mask = cx.checked_cut_mask()?;
        let (schedule, st) = exhaustive::exhaustive_schedule_masked(
            &mut cx.engine, &mps, mask.as_deref(),
            cx.budget.max_evaluations, cx.threads)
            .map_err(|e| match e {
                ExhaustiveError::ModelTooLarge { layers, max } => {
                    TuningError::ModelTooLarge { layers, max }
                }
                ExhaustiveError::EmptyMpSet => TuningError::EmptyMpSet,
                ExhaustiveError::BudgetExhausted { spent, budget } => {
                    TuningError::BudgetExhausted { spent, budget }
                }
            })?;
        let predicted_ms = cx.engine.schedule_cost(&schedule);
        let mut stats = TuningStats::from_search(&st);
        stats.wall_us = t0.elapsed().as_micros() as u64;
        Ok(TuningOutcome { tuner: self.name(), schedule, batch, predicted_ms, stats })
    }
}

impl Tuner for Exhaustive {
    fn name(&self) -> String {
        "exhaustive".into()
    }

    fn tune(&mut self, cx: &mut TuningContext<'_>) -> Result<TuningOutcome, TuningError> {
        tune_over_batches(cx, |cx| self.tune_at_batch(cx))
    }
}

/// Construct a backend from its CLI name — the one registry behind
/// `dlfusion tune --tuner ...` and the tuner-factory paths (the threaded
/// cross-target comparison builds one backend per worker from the name).
/// Known names: `algorithm1`/`dlfusion`, `strategy1..7`, `oracle`/
/// `oracle-dp`, `oracle-full`, `oracle-constrained`, `anneal`/`annealing`,
/// `exhaustive`, `learned`/`active` (the model-guided
/// [`crate::learn::ActiveTuner`]).
pub fn backend_by_name(name: &str) -> Result<Box<dyn Tuner>, String> {
    match name {
        "algorithm1" | "dlfusion" => Ok(Box::new(Algorithm1)),
        "oracle" | "oracle-dp" => Ok(Box::new(OracleDp::reduced())),
        "oracle-full" => Ok(Box::new(OracleDp::full())),
        "oracle-constrained" => Ok(Box::new(OracleDp::constrained())),
        "anneal" | "annealing" => Ok(Box::new(Annealer::new())),
        "exhaustive" => Ok(Box::new(Exhaustive)),
        "learned" | "active" => Ok(Box::new(crate::learn::ActiveTuner::new())),
        s if s.starts_with("strategy") => {
            let idx: usize = s["strategy".len()..]
                .parse()
                .map_err(|_| format!("bad strategy index in '{s}'"))?;
            let st = Strategy::from_index(idx)
                .ok_or_else(|| format!("strategy must be 1..=7, got {idx}"))?;
            Ok(Box::new(TableStrategy(st)))
        }
        other => Err(format!(
            "unknown tuner '{other}' (known: algorithm1, strategy1..7, \
             oracle, oracle-full, oracle-constrained, anneal, exhaustive, \
             learned)"
        )),
    }
}
