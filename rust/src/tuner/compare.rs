//! Side-by-side tuner comparison over one shared context — the Fig. 10 /
//! Section V "strategies and search costs" report as a first-class API.

use crate::cost::CostStats;
use crate::util::units::fmt_ms;
use crate::util::Table;

use super::outcome::{TuningError, TuningOutcome};
use super::request::TuningContext;
use super::Tuner;

/// Outcomes of several tuners run sequentially over one shared context
/// (later tuners see earlier tuners' block evaluations as cache hits).
#[derive(Debug, Clone)]
pub struct Comparison {
    /// One outcome per tuner, in run order.
    pub outcomes: Vec<TuningOutcome>,
    /// Engine counters accumulated across the whole comparison.
    pub engine_stats: CostStats,
}

/// Run every tuner over the shared context, in order. The first backend
/// error aborts the comparison.
pub fn compare(cx: &mut TuningContext<'_>, tuners: &mut [Box<dyn Tuner>])
               -> Result<Comparison, TuningError> {
    let mut outcomes = Vec::with_capacity(tuners.len());
    for t in tuners.iter_mut() {
        outcomes.push(t.tune(cx)?);
    }
    Ok(Comparison { outcomes, engine_stats: cx.engine.stats() })
}

impl Comparison {
    /// The outcome with the lowest predicted *per-sample* latency (the
    /// joint `(mp, batch)` objective; identical to lowest invocation
    /// latency when every outcome is batch 1).
    pub fn best(&self) -> Option<&TuningOutcome> {
        self.outcomes
            .iter()
            .min_by(|a, b| a.per_sample_ms().total_cmp(&b.per_sample_ms()))
    }

    /// Render the side-by-side table plus a shared-cache summary line.
    pub fn render(&self, title: &str) -> String {
        let mut t = Table::new(&["tuner", "batch", "latency", "FPS", "vs best",
                                 "evals", "computed", "hit rate", "wall"])
            .label_first()
            .with_title(title);
        let best_ms = self.best().map(|o| o.per_sample_ms()).unwrap_or(f64::NAN);
        for o in &self.outcomes {
            t.row(vec![
                o.tuner.clone(),
                o.batch.to_string(),
                fmt_ms(o.predicted_ms),
                format!("{:.1}", o.fps()),
                format!("{:.2}x", o.per_sample_ms() / best_ms),
                format!("{}{}", o.stats.evaluations,
                        if o.stats.truncated { "*" } else { "" }),
                o.stats.cache_misses.to_string(),
                format!("{:.0}%", 100.0 * o.stats.hit_rate()),
                format!("{} us", o.stats.wall_us),
            ]);
        }
        let st = self.engine_stats;
        let truncated = self.outcomes.iter().any(|o| o.stats.truncated);
        format!(
            "{t}\n{}shared cost engine: {} block queries, {} computed \
             ({} cached, {:.1}x fewer computations than unmemoized)\n",
            if truncated { "(* budget-truncated run)\n" } else { "" },
            st.queries(),
            st.misses,
            st.hits,
            st.block_eval_reduction()
        )
    }
}
