//! Side-by-side tuner comparison over one shared context — the Fig. 10 /
//! Section V "strategies and search costs" report as a first-class API —
//! plus its cross-target analog: one backend, one model, many hardware
//! points ([`compare_targets`], rust/docs/DESIGN.md §11).

use std::sync::Mutex;

use crate::accel::{Simulator, Target};
use crate::cost::CostStats;
use crate::graph::Model;
use crate::util::units::fmt_ms;
use crate::util::{ParallelMap, Table};

use super::outcome::{TuningError, TuningOutcome};
use super::request::{TuningContext, TuningRequest};
use super::Tuner;

/// Outcomes of several tuners run sequentially over one shared context
/// (later tuners see earlier tuners' block evaluations as cache hits).
#[derive(Debug, Clone)]
pub struct Comparison {
    /// One outcome per tuner, in run order.
    pub outcomes: Vec<TuningOutcome>,
    /// Engine counters accumulated across the whole comparison.
    pub engine_stats: CostStats,
}

/// Run every tuner over the shared context, in order. The first backend
/// error aborts the comparison.
pub fn compare(cx: &mut TuningContext<'_>, tuners: &mut [Box<dyn Tuner>])
               -> Result<Comparison, TuningError> {
    let mut outcomes = Vec::with_capacity(tuners.len());
    for t in tuners.iter_mut() {
        outcomes.push(t.tune(cx)?);
    }
    Ok(Comparison { outcomes, engine_stats: cx.engine.stats() })
}

/// [`compare`], fanned across `threads` workers. Every tuner runs on a
/// [`TuningContext::fork`] of the shared context, so all workers feed one
/// concurrent cost cache; each distinct block evaluation is still computed
/// exactly once (the shard lock is held across the miss computation), so
/// the schedules, predicted latencies, per-tuner evaluation counts, and the
/// *merged* engine counters are bit-identical to the sequential run. Only
/// the per-tuner hit/miss attribution can shift: which worker pays the one
/// compute for a block both tuners visit depends on arrival order
/// (rust/docs/DESIGN.md §12). `threads <= 1` is exactly [`compare`].
pub fn compare_threaded(cx: &mut TuningContext<'_>, tuners: &mut [Box<dyn Tuner>],
                        threads: usize)
                        -> Result<Comparison, TuningError> {
    if threads <= 1 || tuners.len() <= 1 {
        return compare(cx, tuners);
    }
    struct Job<'t, 'a> {
        tuner: &'t mut Box<dyn Tuner>,
        cx: TuningContext<'a>,
    }
    let jobs: Vec<Mutex<Job<'_, '_>>> = tuners
        .iter_mut()
        .map(|t| Mutex::new(Job { tuner: t, cx: cx.fork() }))
        .collect();
    let results = ParallelMap::new(threads).map(&jobs, |_, job| {
        let mut job = job.lock().expect("comparison worker panicked");
        let Job { tuner, cx } = &mut *job;
        tuner.tune(cx)
    });
    let mut outcomes = Vec::with_capacity(results.len());
    for result in results {
        outcomes.push(result?);
    }
    Ok(Comparison { outcomes, engine_stats: cx.engine.stats() })
}

impl Comparison {
    /// The outcome with the lowest predicted *per-sample* latency (the
    /// joint `(mp, batch)` objective; identical to lowest invocation
    /// latency when every outcome is batch 1).
    pub fn best(&self) -> Option<&TuningOutcome> {
        self.outcomes
            .iter()
            .min_by(|a, b| a.per_sample_ms().total_cmp(&b.per_sample_ms()))
    }

    /// Render the side-by-side table plus a shared-cache summary line.
    pub fn render(&self, title: &str) -> String {
        let mut t = Table::new(&["tuner", "batch", "latency", "FPS", "vs best",
                                 "evals", "computed", "hit rate", "wall"])
            .label_first()
            .with_title(title);
        let best_ms = self.best().map(|o| o.per_sample_ms()).unwrap_or(f64::NAN);
        for o in &self.outcomes {
            t.row(vec![
                o.tuner.clone(),
                o.batch.to_string(),
                fmt_ms(o.predicted_ms),
                format!("{:.1}", o.fps()),
                format!("{:.2}x", o.per_sample_ms() / best_ms),
                format!("{}{}", o.stats.evaluations,
                        if o.stats.truncated { "*" } else { "" }),
                o.stats.cache_misses.to_string(),
                format!("{:.0}%", 100.0 * o.stats.hit_rate()),
                format!("{} us", o.stats.wall_us),
            ]);
        }
        let st = self.engine_stats;
        let truncated = self.outcomes.iter().any(|o| o.stats.truncated);
        format!(
            "{t}\n{}shared cost engine: {} block queries, {} computed \
             ({} cached, {:.1}x fewer computations than unmemoized)\n",
            if truncated { "(* budget-truncated run)\n" } else { "" },
            st.queries(),
            st.misses,
            st.hits,
            st.block_eval_reduction()
        )
    }
}

/// One row of a [`TargetComparison`]: the tuning outcome on one hardware
/// point.
#[derive(Debug, Clone)]
pub struct TargetOutcome {
    pub target: Target,
    pub outcome: TuningOutcome,
}

/// Outcomes of one backend tuning one model across several hardware
/// targets — the cross-target analog of [`Comparison`]. Unlike the
/// same-target comparison there is no shared cost cache: every hardware
/// point prices blocks differently, so each target gets its own engine.
#[derive(Debug, Clone)]
pub struct TargetComparison {
    /// One row per *successfully tuned* target, in the order given to
    /// [`compare_targets`].
    pub rows: Vec<TargetOutcome>,
    /// Targets the backend could not tune (e.g. an explicit `--mps` value
    /// above a small chip's core count), with the per-target error. The
    /// comparison proceeds without them.
    pub skipped: Vec<(Target, TuningError)>,
}

/// Tune `model` with one backend on every target. `template` carries the
/// request knobs (MP/batch candidates, granularity, annealing config,
/// budgets) applied to every hardware point via
/// [`TuningRequest::for_sim`] — pass `&TuningRequest::new(&sim, &model)`
/// for the paper defaults. A template with no explicit MP candidate set
/// lets every target derive its own reduced MP set.
///
/// A target the backend cannot tune — say `--mps 8` on the 4-core edge
/// part — is *skipped* (recorded in [`TargetComparison::skipped`]) rather
/// than aborting the whole comparison; only when every target fails does
/// this return an error, naming the first failing target.
pub fn compare_targets(model: &Model, targets: &[Target], tuner: &mut dyn Tuner,
                       template: &TuningRequest<'_>)
                       -> Result<TargetComparison, TuningError> {
    let mut rows = Vec::with_capacity(targets.len());
    let mut skipped = Vec::new();
    for target in targets {
        let sim = Simulator::new(target.clone());
        let request = template.for_sim(&sim, model);
        match tuner.tune(&mut request.context()) {
            Ok(outcome) => rows.push(TargetOutcome { target: target.clone(), outcome }),
            Err(e) => skipped.push((target.clone(), e)),
        }
    }
    if rows.is_empty() {
        if let Some((target, e)) = skipped.into_iter().next() {
            return Err(TuningError::InvalidRequest(format!(
                "no target could be tuned; first failure on '{}': {e}",
                target.name())));
        }
        return Err(TuningError::InvalidRequest("no targets given".to_string()));
    }
    Ok(TargetComparison { rows, skipped })
}

/// [`compare_targets`], fanned across `threads` workers with a tuner
/// *factory* instead of one mutable backend (each worker needs its own).
/// Hardware points are independent — each gets its own simulator, engine,
/// and freshly made tuner — so every row is bit-identical to the
/// sequential comparison regardless of thread count; only wall-clock
/// changes. Skip-on-error semantics match [`compare_targets`].
pub fn compare_targets_with<F>(model: &Model, targets: &[Target], make_tuner: F,
                               template: &TuningRequest<'_>, threads: usize)
                               -> Result<TargetComparison, TuningError>
where
    F: Fn() -> Box<dyn Tuner> + Sync,
{
    let results = ParallelMap::new(threads).map(targets, |_, target| {
        let sim = Simulator::new(target.clone());
        let request = template.for_sim(&sim, model);
        let mut tuner = make_tuner();
        tuner.tune(&mut request.context())
    });
    let mut rows = Vec::with_capacity(targets.len());
    let mut skipped = Vec::new();
    for (target, result) in targets.iter().zip(results) {
        match result {
            Ok(outcome) => rows.push(TargetOutcome { target: target.clone(), outcome }),
            Err(e) => skipped.push((target.clone(), e)),
        }
    }
    if rows.is_empty() {
        if let Some((target, e)) = skipped.into_iter().next() {
            return Err(TuningError::InvalidRequest(format!(
                "no target could be tuned; first failure on '{}': {e}",
                target.name())));
        }
        return Err(TuningError::InvalidRequest("no targets given".to_string()));
    }
    Ok(TargetComparison { rows, skipped })
}

impl TargetComparison {
    /// The row with the lowest predicted per-sample latency (which hardware
    /// point serves this model fastest).
    pub fn best(&self) -> Option<&TargetOutcome> {
        self.rows
            .iter()
            .min_by(|a, b| a.outcome.per_sample_ms().total_cmp(&b.outcome.per_sample_ms()))
    }

    /// Render the per-target table plus one schedule line per target.
    pub fn render(&self, title: &str) -> String {
        let mut t = Table::new(&["target", "cores", "peak", "BW", "max MP",
                                 "blocks", "latency", "FPS"])
            .label_first()
            .with_title(title);
        for row in &self.rows {
            let spec = row.target.spec();
            let o = &row.outcome;
            let max_mp = o.schedule.blocks.iter().map(|b| b.mp).max().unwrap_or(1);
            t.row(vec![
                row.target.name().to_string(),
                spec.num_cores.to_string(),
                format!("{:.1}T", spec.peak_gflops() / 1000.0),
                format!("{:.1}", spec.mem_bw_gbps),
                max_mp.to_string(),
                o.schedule.num_blocks().to_string(),
                fmt_ms(o.predicted_ms),
                format!("{:.1}", o.fps()),
            ]);
        }
        let mut out = format!("{t}\n");
        for row in &self.rows {
            out.push_str(&format!("{}: {}\n", row.target.name(),
                                  row.outcome.schedule.summary()));
        }
        for (target, e) in &self.skipped {
            out.push_str(&format!("{}: skipped — {e}\n", target.name()));
        }
        if let Some(best) = self.best() {
            out.push_str(&format!(
                "fastest hardware point: {} ({} per sample)\n",
                best.target.name(), fmt_ms(best.outcome.per_sample_ms())));
        }
        out
    }
}
