//! Load-aware core allocation (rust/docs/DESIGN.md §9.3).
//!
//! The paper tunes MP and fusion for *one* inference; under heavy traffic
//! that objective is generally wrong. Parallel efficiency is below 1 (sync
//! and launch overheads grow with MP), so several concurrent requests at a
//! smaller MP can beat full-MP sequential execution in aggregate
//! throughput. The allocator sweeps MP caps per model — reusing the
//! constrained oracle DP through one shared [`crate::cost::CostEngine`]
//! cache per model — and exposes two operating points:
//!
//! - **single-request-optimal**: minimizes predicted per-request latency
//!   (the paper's objective);
//! - **load-aware**: minimizes *core-milliseconds per request* (`cores ×
//!   service_ms`, the reciprocal of per-core throughput density) subject to
//!   a per-request service SLO, which maximizes the SLO-feasible aggregate
//!   throughput of the shared pool.

use crate::accel::Simulator;
use crate::tuner::{OracleDp, Tuner, TuningError, TuningRequest};
use crate::util::Table;

use super::cluster::ModelService;
use super::workload::ModelMix;

/// One candidate operating point for a model: every request reserves
/// `cores` cores for the tuned schedule's predicted `service_ms`.
#[derive(Debug, Clone, PartialEq)]
pub struct OperatingPoint {
    /// Cores a request occupies — the max per-block MP of the schedule the
    /// constrained oracle tuned under this cap.
    pub cores: usize,
    /// Predicted per-request latency of that schedule, ms.
    pub service_ms: f64,
    /// The tuned schedule (summary form, for reports).
    pub schedule: String,
}

impl OperatingPoint {
    /// Core-milliseconds one request consumes: the allocator's load-aware
    /// objective (smaller = more requests per core-second).
    pub fn core_ms(&self) -> f64 {
        self.cores as f64 * self.service_ms
    }
}

/// A model's operating-point sweep plus the two chosen points.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelAllocation {
    pub name: String,
    /// The model's normalized share of the offered load, captured from the
    /// mix at planning time (so capacity math cannot be zipped against a
    /// different mix later).
    pub share: f64,
    /// One point per distinct core occupancy, best service time each.
    pub points: Vec<OperatingPoint>,
    /// Minimum-latency point (the paper's single-request objective).
    pub single: OperatingPoint,
    /// Minimum core-ms point among SLO-feasible candidates.
    pub load_aware: OperatingPoint,
}

impl ModelAllocation {
    /// The load-aware choice differs from the single-request optimum.
    pub fn diverged(&self) -> bool {
        self.single.cores != self.load_aware.cores
    }
}

/// The allocator's output across a model mix.
#[derive(Debug, Clone, PartialEq)]
pub struct AllocationPlan {
    pub models: Vec<ModelAllocation>,
    pub slo_ms: Option<f64>,
}

impl AllocationPlan {
    /// The per-model services the cluster simulates: load-aware points when
    /// `load_aware`, single-request-optimal points otherwise.
    pub fn services(&self, load_aware: bool) -> Vec<ModelService> {
        self.models
            .iter()
            .map(|m| {
                let p = if load_aware { &m.load_aware } else { &m.single };
                ModelService {
                    name: m.name.clone(),
                    cores: p.cores,
                    service_ms: p.service_ms,
                }
            })
            .collect()
    }

    /// Predicted maximum sustainable aggregate rate, requests/second: the
    /// pool's core-milliseconds per second divided by the mix-weighted
    /// core-milliseconds per request (0 when the plan is empty). Shares are
    /// the ones captured from the planning-time mix.
    pub fn predicted_capacity_rps(&self, num_cores: usize,
                                  load_aware: bool) -> f64 {
        let mut core_ms_per_req = 0.0;
        for m in &self.models {
            let p = if load_aware { &m.load_aware } else { &m.single };
            core_ms_per_req += m.share * p.core_ms();
        }
        if core_ms_per_req <= 0.0 {
            return 0.0;
        }
        num_cores as f64 * 1000.0 / core_ms_per_req
    }

    /// Render the per-model comparison table.
    pub fn render(&self) -> String {
        let title = match self.slo_ms {
            Some(slo) => format!(
                "core allocation — single-request vs load-aware (SLO {slo} ms)"),
            None => "core allocation — single-request vs load-aware".to_string(),
        };
        let mut t = Table::new(&["model", "MP*", "lat*", "MP", "lat",
                                 "core-ms*", "core-ms", "diverged"])
            .label_first()
            .with_title(&title);
        for m in &self.models {
            t.row(vec![
                m.name.clone(),
                m.single.cores.to_string(),
                format!("{:.3}", m.single.service_ms),
                m.load_aware.cores.to_string(),
                format!("{:.3}", m.load_aware.service_ms),
                format!("{:.2}", m.single.core_ms()),
                format!("{:.2}", m.load_aware.core_ms()),
                if m.diverged() { "yes".into() } else { "-".to_string() },
            ]);
        }
        let mut out = format!("{t}\n(* = single-request-optimal; lat in ms)\n");
        for m in &self.models {
            out.push_str(&format!("{}: serves {}\n", m.name,
                                  m.load_aware.schedule));
        }
        out
    }
}

/// Sweep each model's MP caps through the constrained oracle DP and pick
/// both operating points. One `TuningRequest` context per model: the caps
/// share the memoized `(block, mp)` cache, so the whole sweep costs barely
/// more than one uncapped search.
pub fn plan_allocations(sim: &Simulator, mix: &ModelMix,
                        slo_ms: Option<f64>) -> Result<AllocationPlan, TuningError> {
    let caps = sim.spec.reduced_mp_set();
    let mut models = Vec::new();
    for (mi, model) in mix.models.iter().enumerate() {
        let request = TuningRequest::new(sim, model);
        let mut cx = request.context();
        let mut points: Vec<OperatingPoint> = Vec::new();
        for &cap in &caps {
            let mps: Vec<usize> =
                caps.iter().copied().filter(|&m| m <= cap).collect();
            cx.set_mp_candidates(mps);
            let out = OracleDp::constrained().tune(&mut cx)?;
            // The request reserves only the cores its schedule ever uses.
            let cores = out
                .schedule
                .blocks
                .iter()
                .map(|b| b.mp)
                .max()
                .unwrap_or(1);
            let point = OperatingPoint {
                cores,
                service_ms: out.predicted_ms,
                schedule: out.schedule.summary(),
            };
            match points.iter().position(|p| p.cores == cores) {
                Some(i) => {
                    if point.service_ms < points[i].service_ms {
                        points[i] = point;
                    }
                }
                None => points.push(point),
            }
        }

        let mut single: Option<&OperatingPoint> = None;
        for p in &points {
            let better = match single {
                None => true,
                Some(b) => (p.service_ms, p.cores) < (b.service_ms, b.cores),
            };
            if better {
                single = Some(p);
            }
        }
        let single = single.expect("cap sweep yields at least one point").clone();

        let mut load_aware: Option<&OperatingPoint> = None;
        for p in &points {
            if let Some(slo) = slo_ms {
                if p.service_ms > slo {
                    continue;
                }
            }
            let better = match load_aware {
                None => true,
                Some(b) => (p.core_ms(), p.service_ms) < (b.core_ms(), b.service_ms),
            };
            if better {
                load_aware = Some(p);
            }
        }
        // No point meets the SLO at all: fall back to the fastest point.
        let load_aware = load_aware.cloned().unwrap_or_else(|| single.clone());

        models.push(ModelAllocation {
            name: model.name.clone(),
            share: mix.share(mi),
            points,
            single,
            load_aware,
        });
    }
    Ok(AllocationPlan { models, slo_ms })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo;

    #[test]
    fn sweep_points_are_consistent() {
        let sim = Simulator::mlu100();
        let mix = ModelMix::uniform(vec![zoo::alexnet()]);
        let plan = plan_allocations(&sim, &mix, None).unwrap();
        assert_eq!(plan.models.len(), 1);
        let m = &plan.models[0];
        assert!(!m.points.is_empty());
        // Occupancies are distinct and within the pool.
        for (i, p) in m.points.iter().enumerate() {
            assert!(p.cores >= 1 && p.cores <= sim.spec.num_cores);
            assert!(p.service_ms > 0.0);
            assert!(m.points[i + 1..].iter().all(|q| q.cores != p.cores));
        }
        // The chosen points obey their objectives over the sweep.
        for p in &m.points {
            assert!(m.single.service_ms <= p.service_ms);
            assert!(m.load_aware.core_ms() <= p.core_ms() + 1e-12);
        }
    }

    #[test]
    fn load_aware_never_costs_more_core_ms() {
        let sim = Simulator::mlu100();
        let mix = ModelMix::uniform(vec![zoo::alexnet(), zoo::mini_cnn()]);
        let plan = plan_allocations(&sim, &mix, None).unwrap();
        for m in &plan.models {
            assert!(m.load_aware.core_ms() <= m.single.core_ms() + 1e-12,
                    "{}: {} vs {}", m.name, m.load_aware.core_ms(),
                    m.single.core_ms());
        }
        // Capacity at the load-aware points is at least the single-request
        // capacity (equal only when nothing diverged).
        let cap_load = plan.predicted_capacity_rps(sim.spec.num_cores, true);
        let cap_single = plan.predicted_capacity_rps(sim.spec.num_cores, false);
        assert!(cap_load >= cap_single);
        assert!(cap_load > 0.0);
    }

    #[test]
    fn slo_constrains_the_load_aware_point() {
        let sim = Simulator::mlu100();
        let mix = ModelMix::uniform(vec![zoo::alexnet()]);
        let free = plan_allocations(&sim, &mix, None).unwrap();
        let m = &free.models[0];
        // A deliberately tight SLO — halfway between the fastest and the
        // unconstrained load-aware point — must push the choice to a faster
        // (more-cores) point when those differ.
        if m.load_aware.service_ms > m.single.service_ms {
            let slo = (m.single.service_ms + m.load_aware.service_ms) / 2.0;
            let tight = plan_allocations(&sim, &mix, Some(slo)).unwrap();
            let tm = &tight.models[0];
            assert!(tm.load_aware.service_ms <= slo);
            assert!(tm.load_aware.core_ms() >= m.load_aware.core_ms() - 1e-12);
        }
        // An impossible SLO falls back to the fastest point.
        let impossible = plan_allocations(&sim, &mix, Some(1e-9)).unwrap();
        assert_eq!(impossible.models[0].load_aware,
                   impossible.models[0].single);
    }

    #[test]
    fn services_and_render() {
        let sim = Simulator::mlu100();
        let mix = ModelMix::uniform(vec![zoo::alexnet(), zoo::mini_cnn()]);
        let plan = plan_allocations(&sim, &mix, Some(100.0)).unwrap();
        let svcs = plan.services(true);
        assert_eq!(svcs.len(), 2);
        assert_eq!(svcs[0].name, "alexnet");
        assert!(svcs.iter().all(|s| s.cores >= 1 && s.service_ms > 0.0));
        let text = plan.render();
        assert!(text.contains("alexnet"), "{text}");
        assert!(text.contains("SLO 100"), "{text}");
    }
}
