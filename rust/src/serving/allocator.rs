//! Load-aware core allocation (rust/docs/DESIGN.md §9.3).
//!
//! The paper tunes MP and fusion for *one* inference; under heavy traffic
//! that objective is generally wrong. Parallel efficiency is below 1 (sync
//! and launch overheads grow with MP), so several concurrent requests at a
//! smaller MP can beat full-MP sequential execution in aggregate
//! throughput. The allocator sweeps MP caps per model — reusing the
//! constrained oracle DP through one shared [`crate::cost::CostEngine`]
//! cache per model — and exposes two operating points:
//!
//! - **single-request-optimal**: minimizes predicted per-request latency
//!   (the paper's objective);
//! - **load-aware**: minimizes *core-milliseconds per request* (`cores ×
//!   service_ms`, the reciprocal of per-core throughput density) subject to
//!   a per-request service SLO, which maximizes the SLO-feasible aggregate
//!   throughput of the shared pool.

use crate::accel::Simulator;
use crate::tuner::{OracleDp, Tuner, TuningError, TuningRequest};
use crate::util::Table;

use super::cluster::{batched_service_ms, ModelService};
use super::workload::ModelMix;

/// One candidate operating point for a model: a batch of `b` requests
/// reserves `cores` cores for the tuned schedule's predicted batched
/// latency `service_at(b)` (`service_ms` is the single-request time).
#[derive(Debug, Clone, PartialEq)]
pub struct OperatingPoint {
    /// Cores a request reserves — the max per-block MP of the schedule the
    /// constrained oracle tuned under this cap.
    pub cores: usize,
    /// Predicted per-request (batch-1) latency of that schedule, ms.
    pub service_ms: f64,
    /// Predicted latency of one batched invocation at batch `index + 1`,
    /// ms (`[0]` equals `service_ms`); derived from the tuned schedule
    /// through the shared engine's batch-aware model.
    pub batch_service_ms: Vec<f64>,
    /// The tuned schedule (summary form, for reports).
    pub schedule: String,
}

impl OperatingPoint {
    /// Predicted invocation latency at `batch` — the same pricing rule the
    /// cluster's [`ModelService::service_at`] applies (one shared
    /// implementation, so the allocator's feasibility/capacity math and the
    /// simulator's invocation pricing cannot drift apart).
    pub fn service_at(&self, batch: usize) -> f64 {
        batched_service_ms(&self.batch_service_ms, self.service_ms, batch)
    }

    /// Core-milliseconds one request consumes at batch 1: the allocator's
    /// load-aware objective (smaller = more requests per core-second).
    pub fn core_ms(&self) -> f64 {
        self.cores as f64 * self.service_ms
    }

    /// Core-milliseconds *per request* when requests ride batch-`b`
    /// invocations: `cores * service_at(b) / b` — the batched load-aware
    /// objective (rust/docs/DESIGN.md §10).
    pub fn core_ms_at(&self, batch: usize) -> f64 {
        self.cores as f64 * self.service_at(batch) / batch as f64
    }
}

/// A model's operating-point sweep plus the two chosen points.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelAllocation {
    pub name: String,
    /// The model's normalized share of the offered load, captured from the
    /// mix at planning time (so capacity math cannot be zipped against a
    /// different mix later).
    pub share: f64,
    /// One point per distinct core occupancy, best service time each.
    pub points: Vec<OperatingPoint>,
    /// Minimum-latency point (the paper's single-request objective).
    pub single: OperatingPoint,
    /// Minimum per-request core-ms point among SLO-feasible `(point,
    /// batch)` candidates.
    pub load_aware: OperatingPoint,
    /// The batch size at which `load_aware` minimizes per-request core-ms
    /// (1 unless the plan swept batches — see
    /// [`AllocationRequest::max_batch`]).
    pub load_aware_batch: usize,
    /// Cost-engine evaluations the tuning sweep spent on this model — what
    /// a fleet plan-cache hit saves (rust/docs/DESIGN.md §15.3).
    pub tuning_evaluations: u64,
}

impl ModelAllocation {
    /// The load-aware choice differs from the single-request optimum.
    pub fn diverged(&self) -> bool {
        self.single.cores != self.load_aware.cores
    }
}

/// The allocator's output across a model mix.
#[derive(Debug, Clone, PartialEq)]
pub struct AllocationPlan {
    pub models: Vec<ModelAllocation>,
    pub slo_ms: Option<f64>,
    /// Registry name of the hardware target every operating point was
    /// priced for (the planning simulator's — rust/docs/DESIGN.md §11).
    pub target: String,
}

impl AllocationPlan {
    /// The per-model services the cluster simulates: load-aware points when
    /// `load_aware`, single-request-optimal points otherwise. Each service
    /// carries its point's batched-latency table, so the `batch` dispatch
    /// policy prices batched invocations with the engine-predicted numbers.
    pub fn services(&self, load_aware: bool) -> Vec<ModelService> {
        self.models
            .iter()
            .map(|m| {
                let p = if load_aware { &m.load_aware } else { &m.single };
                ModelService::new(m.name.clone(), p.cores, p.service_ms)
                    .with_batch_table(p.batch_service_ms.clone())
                    .with_target(self.target.clone())
            })
            .collect()
    }

    /// Predicted maximum sustainable aggregate rate, requests/second: the
    /// pool's core-milliseconds per second divided by the mix-weighted
    /// core-milliseconds per request (0 when the plan is empty). Shares are
    /// the ones captured from the planning-time mix.
    pub fn predicted_capacity_rps(&self, num_cores: usize,
                                  load_aware: bool) -> f64 {
        let mut core_ms_per_req = 0.0;
        for m in &self.models {
            let p = if load_aware { &m.load_aware } else { &m.single };
            core_ms_per_req += m.share * p.core_ms();
        }
        if core_ms_per_req <= 0.0 {
            return 0.0;
        }
        num_cores as f64 * 1000.0 / core_ms_per_req
    }

    /// Predicted maximum sustainable aggregate rate when every model serves
    /// batch-formed invocations at its load-aware batch: the batched
    /// counterpart of [`Self::predicted_capacity_rps`] (identical when no
    /// model batches above 1).
    pub fn predicted_batched_capacity_rps(&self, num_cores: usize) -> f64 {
        let mut core_ms_per_req = 0.0;
        for m in &self.models {
            core_ms_per_req += m.share * m.load_aware.core_ms_at(m.load_aware_batch);
        }
        if core_ms_per_req <= 0.0 {
            return 0.0;
        }
        num_cores as f64 * 1000.0 / core_ms_per_req
    }

    /// Render the per-model comparison table.
    pub fn render(&self) -> String {
        let target = if self.target.is_empty() {
            String::new()
        } else {
            format!(" [{}]", self.target)
        };
        let title = match self.slo_ms {
            Some(slo) => format!(
                "core allocation — single-request vs load-aware (SLO {slo} ms){target}"),
            None => format!("core allocation — single-request vs load-aware{target}"),
        };
        let mut t = Table::new(&["model", "MP*", "lat*", "MP", "lat",
                                 "core-ms*", "core-ms", "diverged"])
            .label_first()
            .with_title(&title);
        for m in &self.models {
            t.row(vec![
                m.name.clone(),
                m.single.cores.to_string(),
                format!("{:.3}", m.single.service_ms),
                m.load_aware.cores.to_string(),
                format!("{:.3}", m.load_aware.service_ms),
                format!("{:.2}", m.single.core_ms()),
                format!("{:.2}", m.load_aware.core_ms()),
                if m.diverged() { "yes".into() } else { "-".to_string() },
            ]);
        }
        let mut out = format!("{t}\n(* = single-request-optimal; lat in ms)\n");
        for m in &self.models {
            if m.load_aware_batch > 1 {
                out.push_str(&format!(
                    "{}: serves {} (batch {}, {:.3} ms/invocation)\n",
                    m.name, m.load_aware.schedule, m.load_aware_batch,
                    m.load_aware.service_at(m.load_aware_batch)));
            } else {
                out.push_str(&format!("{}: serves {}\n", m.name,
                                      m.load_aware.schedule));
            }
        }
        out
    }
}

/// Builder for one allocation plan — the single entry point behind the
/// deprecated [`plan_allocations`] / [`plan_allocations_batched`] free
/// functions, and what [`super::fleet::plan_fleet`] composes per chip kind
/// through the plan cache.
///
/// Defaults: no SLO, batch 1 (no batching), load-aware service selection.
///
/// ```no_run
/// use dlfusion::accel::{Simulator, Target};
/// use dlfusion::serving::{AllocationRequest, ModelMix};
/// use dlfusion::zoo;
///
/// let sim = Simulator::new(Target::mlu100());
/// let mix = ModelMix::uniform(vec![zoo::alexnet()]);
/// let plan = AllocationRequest::new(&sim, &mix)
///     .slo_ms(Some(40.0))
///     .max_batch(8)
///     .plan()
///     .expect("tunable mix");
/// println!("{}", plan.render());
/// ```
#[derive(Debug, Clone)]
pub struct AllocationRequest<'a> {
    sim: &'a Simulator,
    mix: &'a ModelMix,
    slo_ms: Option<f64>,
    max_batch: usize,
    load_aware: bool,
}

impl<'a> AllocationRequest<'a> {
    /// An allocation request for `mix` on `sim`'s target.
    pub fn new(sim: &'a Simulator, mix: &'a ModelMix) -> AllocationRequest<'a> {
        AllocationRequest { sim, mix, slo_ms: None, max_batch: 1, load_aware: true }
    }

    /// Per-request service SLO, ms: the load-aware scan only admits
    /// `(point, batch)` candidates whose invocation latency meets it.
    pub fn slo_ms(mut self, slo_ms: Option<f64>) -> AllocationRequest<'a> {
        self.slo_ms = slo_ms;
        self
    }

    /// Price every tuned schedule at batches `1..=max_batch` (the batch
    /// candidates of the load-aware grid). Must be at least 1; 1 (the
    /// default) means single-request serving.
    pub fn max_batch(mut self, max_batch: usize) -> AllocationRequest<'a> {
        self.max_batch = max_batch;
        self
    }

    /// Whether [`Self::services`] folds the plan to the load-aware points
    /// (default) or the single-request optima. The plan itself always
    /// carries both.
    pub fn load_aware(mut self, load_aware: bool) -> AllocationRequest<'a> {
        self.load_aware = load_aware;
        self
    }

    /// Run the `(mp_cap, batch)` sweep (rust/docs/DESIGN.md §10) and build
    /// the plan.
    ///
    /// Per model, each MP cap runs the constrained oracle DP at batch 1,
    /// and the tuned schedule is then priced at every batch
    /// `1..=max_batch` through the same engine's batch-aware model, giving
    /// each point a batched-latency table. The **load-aware** choice
    /// minimizes per-request core-milliseconds `cores * service_at(b) / b`
    /// over the full `(point, batch)` grid, subject to the invocation
    /// latency `service_at(b)` meeting the SLO (a request's end-to-end
    /// latency is at least its invocation's); the **single-request** choice
    /// stays the paper's batch-1 minimum-latency point. Models carrying a
    /// cut constraint in the mix ([`ModelMix::cuts_for`], DAG-derived
    /// workloads) tune with it applied.
    pub fn plan(self) -> Result<AllocationPlan, TuningError> {
        plan_mix(self.sim, self.mix, self.slo_ms, self.max_batch)
    }

    /// [`Self::plan`], folded to the per-model cluster services at the
    /// requested operating points.
    pub fn services(self) -> Result<Vec<ModelService>, TuningError> {
        let load_aware = self.load_aware;
        Ok(self.plan()?.services(load_aware))
    }
}

/// Sweep each model's MP caps through the constrained oracle DP and pick
/// both operating points. Equivalent to [`AllocationRequest::plan`] with
/// the default batch of 1.
#[deprecated(note = "build an `AllocationRequest`: \
                     AllocationRequest::new(sim, mix).slo_ms(slo).plan()")]
pub fn plan_allocations(sim: &Simulator, mix: &ModelMix,
                        slo_ms: Option<f64>) -> Result<AllocationPlan, TuningError> {
    AllocationRequest::new(sim, mix).slo_ms(slo_ms).plan()
}

/// The `(mp_cap, batch)` operating-point sweep —
/// [`AllocationRequest::plan`] as a free function.
#[deprecated(note = "build an `AllocationRequest` with .max_batch(...)")]
pub fn plan_allocations_batched(sim: &Simulator, mix: &ModelMix,
                                slo_ms: Option<f64>, max_batch: usize)
                                -> Result<AllocationPlan, TuningError> {
    AllocationRequest::new(sim, mix).slo_ms(slo_ms).max_batch(max_batch).plan()
}

/// The sweep body behind [`AllocationRequest::plan`]. One `TuningRequest`
/// context per model: the caps share the memoized `(block, mp)` cache, so
/// the whole sweep costs barely more than one uncapped search. Each model
/// is planned independently (its own request, context, and engine), which
/// is what lets the fleet plan cache reuse single-model plans inside any
/// mix bit-identically.
fn plan_mix(sim: &Simulator, mix: &ModelMix, slo_ms: Option<f64>,
            max_batch: usize) -> Result<AllocationPlan, TuningError> {
    if max_batch == 0 {
        return Err(TuningError::InvalidBatch { batch: 0 });
    }
    let caps = sim.spec.reduced_mp_set();
    let mut models = Vec::new();
    for (mi, model) in mix.models.iter().enumerate() {
        let mut request = TuningRequest::new(sim, model);
        if let Some(cuts) = mix.cuts_for(mi) {
            request = request.allowed_cuts(cuts.to_vec());
        }
        let mut cx = request.context();
        let mut tuning_evaluations: u64 = 0;
        // Every cap outcome, pre-dedup: same-cores schedules from different
        // caps can have different fusion structures, and a structure that is
        // marginally slower at batch 1 can still win the batched grid (its
        // weights amortize differently), so the load-aware scan must see
        // them all.
        let mut candidates: Vec<OperatingPoint> = Vec::new();
        for &cap in &caps {
            let mps: Vec<usize> =
                caps.iter().copied().filter(|&m| m <= cap).collect();
            cx.set_mp_candidates(mps);
            let out = OracleDp::constrained().tune(&mut cx)?;
            tuning_evaluations += out.stats.evaluations;
            // The request reserves only the cores its schedule ever uses.
            let cores = out
                .schedule
                .blocks
                .iter()
                .map(|b| b.mp)
                .max()
                .unwrap_or(1);
            // Price the tuned schedule at every batch the policy may form
            // (all served from the shared (block, mp, batch) cache).
            let batch_service_ms: Vec<f64> = (1..=max_batch)
                .map(|b| cx.engine_mut().schedule_cost_at(&out.schedule, b))
                .collect();
            candidates.push(OperatingPoint {
                cores,
                service_ms: out.predicted_ms,
                batch_service_ms,
                schedule: out.schedule.summary(),
            });
        }
        // The reported sweep keeps one point per distinct core occupancy,
        // best batch-1 service each (the pre-batch surface).
        let mut points: Vec<OperatingPoint> = Vec::new();
        for point in &candidates {
            match points.iter().position(|p| p.cores == point.cores) {
                Some(i) => {
                    if point.service_ms < points[i].service_ms {
                        points[i] = point.clone();
                    }
                }
                None => points.push(point.clone()),
            }
        }

        let mut single: Option<&OperatingPoint> = None;
        for p in &points {
            let better = match single {
                None => true,
                Some(b) => (p.service_ms, p.cores) < (b.service_ms, b.cores),
            };
            if better {
                single = Some(p);
            }
        }
        let single = single.expect("cap sweep yields at least one point").clone();

        // Load-aware: minimum per-request core-ms over the full
        // (candidate, batch) grid — every cap outcome, not just the
        // deduped points — SLO-feasible invocations only. At max_batch = 1
        // this picks exactly the pre-batch objective's point (a dropped
        // duplicate has strictly worse batch-1 service at the same cores,
        // so it can never win the batch-1 grid).
        let mut load_aware: Option<(&OperatingPoint, usize)> = None;
        for p in &candidates {
            for batch in 1..=max_batch {
                let service = p.service_at(batch);
                if let Some(slo) = slo_ms {
                    if service > slo {
                        continue;
                    }
                }
                let key = (p.core_ms_at(batch), service);
                let better = match load_aware {
                    None => true,
                    Some((b, bb)) => key < (b.core_ms_at(bb), b.service_at(bb)),
                };
                if better {
                    load_aware = Some((p, batch));
                }
            }
        }
        // No (point, batch) meets the SLO at all: fall back to the fastest
        // single-request point.
        let (load_aware, load_aware_batch) = match load_aware {
            Some((p, b)) => (p.clone(), b),
            None => (single.clone(), 1),
        };

        models.push(ModelAllocation {
            name: model.name.clone(),
            share: mix.share(mi),
            points,
            single,
            load_aware,
            load_aware_batch,
            tuning_evaluations,
        });
    }
    Ok(AllocationPlan { models, slo_ms, target: sim.target().to_string() })
}

#[cfg(test)]
// The legacy shims stay covered until they are removed.
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::zoo;

    #[test]
    fn sweep_points_are_consistent() {
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let mix = ModelMix::uniform(vec![zoo::alexnet()]);
        let plan = plan_allocations(&sim, &mix, None).unwrap();
        assert_eq!(plan.models.len(), 1);
        let m = &plan.models[0];
        assert!(!m.points.is_empty());
        // Occupancies are distinct and within the pool.
        for (i, p) in m.points.iter().enumerate() {
            assert!(p.cores >= 1 && p.cores <= sim.spec.num_cores);
            assert!(p.service_ms > 0.0);
            assert!(m.points[i + 1..].iter().all(|q| q.cores != p.cores));
        }
        // The chosen points obey their objectives over the sweep.
        for p in &m.points {
            assert!(m.single.service_ms <= p.service_ms);
            assert!(m.load_aware.core_ms() <= p.core_ms() + 1e-12);
        }
    }

    #[test]
    fn load_aware_never_costs_more_core_ms() {
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let mix = ModelMix::uniform(vec![zoo::alexnet(), zoo::mini_cnn()]);
        let plan = plan_allocations(&sim, &mix, None).unwrap();
        for m in &plan.models {
            assert!(m.load_aware.core_ms() <= m.single.core_ms() + 1e-12,
                    "{}: {} vs {}", m.name, m.load_aware.core_ms(),
                    m.single.core_ms());
        }
        // Capacity at the load-aware points is at least the single-request
        // capacity (equal only when nothing diverged).
        let cap_load = plan.predicted_capacity_rps(sim.spec.num_cores, true);
        let cap_single = plan.predicted_capacity_rps(sim.spec.num_cores, false);
        assert!(cap_load >= cap_single);
        assert!(cap_load > 0.0);
    }

    #[test]
    fn slo_constrains_the_load_aware_point() {
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let mix = ModelMix::uniform(vec![zoo::alexnet()]);
        let free = plan_allocations(&sim, &mix, None).unwrap();
        let m = &free.models[0];
        // A deliberately tight SLO — halfway between the fastest and the
        // unconstrained load-aware point — must push the choice to a faster
        // (more-cores) point when those differ.
        if m.load_aware.service_ms > m.single.service_ms {
            let slo = (m.single.service_ms + m.load_aware.service_ms) / 2.0;
            let tight = plan_allocations(&sim, &mix, Some(slo)).unwrap();
            let tm = &tight.models[0];
            assert!(tm.load_aware.service_ms <= slo);
            assert!(tm.load_aware.core_ms() >= m.load_aware.core_ms() - 1e-12);
        }
        // An impossible SLO falls back to the fastest point.
        let impossible = plan_allocations(&sim, &mix, Some(1e-9)).unwrap();
        assert_eq!(impossible.models[0].load_aware,
                   impossible.models[0].single);
    }

    #[test]
    fn batched_sweep_keeps_batch_one_points_and_amortizes() {
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let mix = ModelMix::uniform(vec![zoo::alexnet()]);
        let base = plan_allocations(&sim, &mix, None).unwrap();
        let plan = plan_allocations_batched(&sim, &mix, None, 8).unwrap();
        let m = &plan.models[0];
        let b0 = &base.models[0];
        // The batch sweep does not move the batch-1 geometry.
        assert_eq!(m.single.cores, b0.single.cores);
        assert_eq!(m.single.service_ms, b0.single.service_ms);
        assert_eq!(base.models[0].load_aware_batch, 1);
        for p in &m.points {
            assert_eq!(p.batch_service_ms.len(), 8);
            assert_eq!(p.batch_service_ms[0], p.service_ms);
            for b in 2..=8usize {
                // Invocations get longer with batch, but sub-linearly
                // (weights and overheads amortize).
                assert!(p.service_at(b) >= p.service_at(b - 1), "batch {b}");
                assert!(p.service_at(b) < b as f64 * p.service_ms, "batch {b}");
            }
        }
        // With no SLO the per-sample amortization always pushes the
        // load-aware choice to the largest batch.
        assert_eq!(m.load_aware_batch, 8);
        assert!(m.load_aware.core_ms_at(8) < m.load_aware.core_ms());
        assert!(plan.predicted_batched_capacity_rps(sim.spec.num_cores)
                > plan.predicted_capacity_rps(sim.spec.num_cores, true));
        // And the services carry the table for the batch dispatch policy.
        let svcs = plan.services(true);
        assert_eq!(svcs[0].batch_service_ms.len(), 8);
        assert_eq!(svcs[0].service_at(8), m.load_aware.service_at(8));
    }

    #[test]
    fn slo_constrains_the_batched_choice() {
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let mix = ModelMix::uniform(vec![zoo::alexnet()]);
        let free = plan_allocations_batched(&sim, &mix, None, 8).unwrap();
        let single_ms = free.models[0].single.service_ms;
        // An SLO exactly at the fastest single-request time: every batch-2+
        // invocation is strictly slower, so only the single-request optimum
        // at batch 1 is feasible.
        let tight = plan_allocations_batched(&sim, &mix, Some(single_ms), 8)
            .unwrap();
        let m = &tight.models[0];
        assert_eq!(m.load_aware_batch, 1);
        assert_eq!(m.load_aware.cores, m.single.cores);
        // A looser SLO admits batches, and the chosen invocation meets it.
        let slo = 4.0 * single_ms;
        let loose = plan_allocations_batched(&sim, &mix, Some(slo), 8).unwrap();
        let m = &loose.models[0];
        assert!(m.load_aware.service_at(m.load_aware_batch) <= slo);
        assert!(m.load_aware.core_ms_at(m.load_aware_batch)
                <= m.single.core_ms() + 1e-12);
        // Zero max_batch is rejected, not clamped.
        assert!(plan_allocations_batched(&sim, &mix, None, 0).is_err());
    }

    #[test]
    fn builder_and_deprecated_shims_are_bit_identical() {
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let mix = ModelMix::uniform(vec![zoo::alexnet(), zoo::mini_cnn()]);
        let built = AllocationRequest::new(&sim, &mix)
            .slo_ms(Some(100.0))
            .plan()
            .unwrap();
        assert_eq!(built, plan_allocations(&sim, &mix, Some(100.0)).unwrap());
        let batched = AllocationRequest::new(&sim, &mix).max_batch(4).plan().unwrap();
        assert_eq!(batched,
                   plan_allocations_batched(&sim, &mix, None, 4).unwrap());
        // The sweep accounts its engine evaluations (what a plan-cache hit
        // saves), and the non-load-aware fold picks the single points.
        assert!(built.models.iter().all(|m| m.tuning_evaluations > 0));
        let singles = AllocationRequest::new(&sim, &mix)
            .load_aware(false)
            .services()
            .unwrap();
        for (s, m) in singles.iter().zip(&built.models) {
            assert_eq!(s.cores, m.single.cores);
        }
        // Invalid batch still surfaces through the builder.
        assert!(AllocationRequest::new(&sim, &mix).max_batch(0).plan().is_err());
    }

    #[test]
    fn cut_constraints_thread_into_the_sweep() {
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let model = zoo::alexnet();
        let free = AllocationRequest::new(
            &sim, &ModelMix::uniform(vec![model.clone()])).plan().unwrap();
        // Forbid every interior cut: the whole model must fuse into one
        // block, which can never beat the unconstrained optimum.
        let fused =
            ModelMix::uniform_with_cuts(vec![(model.clone(), Some(Vec::new()))]);
        assert_eq!(fused.cuts_for(0), Some(&[][..]));
        let constrained = AllocationRequest::new(&sim, &fused).plan().unwrap();
        assert!(constrained.models[0].single.service_ms
                >= free.models[0].single.service_ms - 1e-12);
        // A single-model slice of a mix keeps the model's cuts.
        let sliced = fused.single(0);
        assert_eq!(sliced.cuts_for(0), fused.cuts_for(0));
        assert_eq!(AllocationRequest::new(&sim, &sliced).plan().unwrap().models,
                   constrained.models);
    }

    #[test]
    fn services_and_render() {
        let sim = Simulator::new(crate::accel::Target::mlu100());
        let mix = ModelMix::uniform(vec![zoo::alexnet(), zoo::mini_cnn()]);
        let plan = plan_allocations(&sim, &mix, Some(100.0)).unwrap();
        let svcs = plan.services(true);
        assert_eq!(svcs.len(), 2);
        assert_eq!(svcs[0].name, "alexnet");
        assert!(svcs.iter().all(|s| s.cores >= 1 && s.service_ms > 0.0));
        let text = plan.render();
        assert!(text.contains("alexnet"), "{text}");
        assert!(text.contains("SLO 100"), "{text}");
    }
}
