//! The fleet routing layer (rust/docs/DESIGN.md §15.2).
//!
//! One router sits in front of the per-chip event loops: each arriving
//! request is assigned a chip by policy, then passed through admission
//! control (an optional per-chip queue cap) which either injects it into
//! the chip's simulation or sheds it. Every decision is a pure function of
//! the chips' exact simulated state at the arrival instant — no randomness,
//! no wall clock — so the whole fleet run stays deterministic.
//!
//! Over a one-chip fleet every policy degenerates to pass-through (there is
//! only one chip to pick), which is what pins the one-chip fleet
//! bit-identical to the single-pool `serve-sim` path.

/// How the fleet routes each arriving request to a chip.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle chips in fleet order, one request each — load-blind.
    RoundRobin,
    /// Join shortest expected delay: the chip with the smallest
    /// backlog-drain estimate at the arrival instant (ties to the lowest
    /// chip index).
    LeastLoaded,
    /// Every model is pinned to one chip — the [`super::fleet::plan_fleet`]
    /// placement — and all of the model's traffic lands there (perfect
    /// per-chip cache/weight locality, no balancing).
    ModelSharded,
}

impl RoutePolicy {
    /// Parse a CLI policy name.
    pub fn parse(name: &str) -> Result<RoutePolicy, String> {
        match name {
            "round-robin" | "rr" => Ok(RoutePolicy::RoundRobin),
            "least-loaded" | "ll" => Ok(RoutePolicy::LeastLoaded),
            "model-sharded" | "sharded" => Ok(RoutePolicy::ModelSharded),
            other => Err(format!(
                "unknown routing policy '{other}' (known: round-robin, \
                 least-loaded, model-sharded)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round-robin",
            RoutePolicy::LeastLoaded => "least-loaded",
            RoutePolicy::ModelSharded => "model-sharded",
        }
    }
}

/// The routing layer's configuration: a policy plus optional admission
/// control.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouterConfig {
    pub policy: RoutePolicy,
    /// Admission control: a routed request finding this many (or more)
    /// requests already waiting on its chip is shed — rejected outright,
    /// never queued. `None` admits everything.
    pub queue_cap: Option<usize>,
}

impl RouterConfig {
    /// A router with the given policy and no admission control.
    pub fn new(policy: RoutePolicy) -> RouterConfig {
        RouterConfig { policy, queue_cap: None }
    }

    /// Set the per-chip waiting cap (load shedding under overload).
    pub fn queue_cap(mut self, cap: Option<usize>) -> RouterConfig {
        self.queue_cap = cap;
        self
    }
}

/// One chip's load as the router sees it at an arrival instant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChipLoad {
    /// Requests queued (arrived, not yet dispatched).
    pub waiting: usize,
    /// Estimated time to drain running + queued work, ms (normalized by
    /// the chip's pool width).
    pub backlog_ms: f64,
}

/// The per-run router state: policy, placement, and the round-robin
/// cursor. Deterministic by construction — see the module docs.
#[derive(Debug, Clone)]
pub struct Router {
    cfg: RouterConfig,
    /// Model index → chip index (the `plan_fleet` placement), read by
    /// [`RoutePolicy::ModelSharded`].
    shard_of: Vec<usize>,
    next_rr: usize,
}

impl Router {
    pub fn new(cfg: RouterConfig, shard_of: Vec<usize>) -> Router {
        Router { cfg, shard_of, next_rr: 0 }
    }

    /// Pick the chip for a `model` request given every chip's current
    /// load. Round-robin advances its cursor whether or not the request is
    /// later shed — the cycle position is part of the deterministic
    /// contract, not a function of admission outcomes.
    pub fn route(&mut self, model: usize, loads: &[ChipLoad]) -> usize {
        debug_assert!(!loads.is_empty());
        match self.cfg.policy {
            RoutePolicy::RoundRobin => {
                let c = self.next_rr % loads.len();
                self.next_rr += 1;
                c
            }
            RoutePolicy::LeastLoaded => {
                let mut best = 0;
                for (c, load) in loads.iter().enumerate().skip(1) {
                    if load.backlog_ms < loads[best].backlog_ms {
                        best = c;
                    }
                }
                best
            }
            RoutePolicy::ModelSharded => self.shard_of[model],
        }
    }

    /// Admission control: is a request shed when `waiting` requests are
    /// already queued on its routed chip?
    pub fn sheds(&self, waiting: usize) -> bool {
        match self.cfg.queue_cap {
            Some(cap) => waiting >= cap,
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load(waiting: usize, backlog_ms: f64) -> ChipLoad {
        ChipLoad { waiting, backlog_ms }
    }

    #[test]
    fn parse_accepts_names_and_aliases() {
        assert_eq!(RoutePolicy::parse("round-robin"), Ok(RoutePolicy::RoundRobin));
        assert_eq!(RoutePolicy::parse("rr"), Ok(RoutePolicy::RoundRobin));
        assert_eq!(RoutePolicy::parse("least-loaded"), Ok(RoutePolicy::LeastLoaded));
        assert_eq!(RoutePolicy::parse("sharded"), Ok(RoutePolicy::ModelSharded));
        let err = RoutePolicy::parse("nope").unwrap_err();
        assert!(err.contains("unknown routing policy"), "{err}");
        assert!(err.contains("least-loaded"), "{err}");
        assert_eq!(RoutePolicy::parse(RoutePolicy::LeastLoaded.name()),
                   Ok(RoutePolicy::LeastLoaded));
    }

    #[test]
    fn round_robin_cycles_regardless_of_load() {
        let mut r = Router::new(RouterConfig::new(RoutePolicy::RoundRobin),
                                vec![0]);
        let loads = [load(9, 100.0), load(0, 0.0), load(0, 0.0)];
        let picks: Vec<usize> = (0..5).map(|_| r.route(0, &loads)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1]);
    }

    #[test]
    fn least_loaded_picks_smallest_backlog_with_index_ties() {
        let mut r = Router::new(RouterConfig::new(RoutePolicy::LeastLoaded),
                                vec![0]);
        assert_eq!(r.route(0, &[load(0, 5.0), load(0, 2.0), load(0, 4.0)]), 1);
        // Exact tie: lowest chip index wins.
        assert_eq!(r.route(0, &[load(0, 3.0), load(0, 3.0)]), 0);
    }

    #[test]
    fn model_sharded_reads_the_placement() {
        let mut r = Router::new(RouterConfig::new(RoutePolicy::ModelSharded),
                                vec![2, 0]);
        let loads = [load(0, 0.0), load(0, 0.0), load(9, 99.0)];
        assert_eq!(r.route(0, &loads), 2, "placement beats load");
        assert_eq!(r.route(1, &loads), 0);
    }

    #[test]
    fn queue_cap_sheds_at_the_threshold() {
        let r = Router::new(
            RouterConfig::new(RoutePolicy::RoundRobin).queue_cap(Some(3)),
            vec![0]);
        assert!(!r.sheds(2));
        assert!(r.sheds(3));
        assert!(r.sheds(4));
        let open = Router::new(RouterConfig::new(RoutePolicy::RoundRobin),
                               vec![0]);
        assert!(!open.sheds(1_000_000));
    }
}
