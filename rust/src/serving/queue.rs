//! Per-model request queues and the dispatch policies over them
//! (rust/docs/DESIGN.md §9.2).

use std::collections::{BTreeSet, VecDeque};

/// A request waiting for cores, with its resolved operating point (cores to
/// occupy and the predicted service time at that core count).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QueuedRequest {
    pub id: u64,
    pub model: usize,
    pub arrival_ms: f64,
    /// Cores this request occupies while running.
    pub cores: usize,
    /// Predicted service time at that core count, ms.
    pub service_ms: f64,
}

/// Default `max_batch` for the `batch` dispatch policy (CLI `--max-batch`).
pub const DEFAULT_MAX_BATCH: usize = 8;

/// Default `max_wait_ms` for the `batch` dispatch policy (CLI
/// `--batch-wait-ms`).
pub const DEFAULT_BATCH_WAIT_MS: f64 = 2.0;

/// Which queued request runs next when cores free up.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DispatchPolicy {
    /// Earliest arrival first (across all model queues).
    Fifo,
    /// Smallest predicted service time first.
    ShortestJobFirst,
    /// Dynamic batching (rust/docs/DESIGN.md §10): per model, up to
    /// `max_batch` queued requests dispatch as **one** batched invocation
    /// occupying the model's cores for the engine-predicted batched
    /// latency. A partial batch is held for at most `max_wait_ms` after its
    /// oldest request arrived, then flushes at whatever size it reached —
    /// so the policy trades a bounded queueing delay for the weight-fetch
    /// amortization of larger batches. Deliberately not work-conserving.
    Batch { max_batch: usize, max_wait_ms: f64 },
}

impl DispatchPolicy {
    /// The `batch` policy with the default knobs.
    pub fn batching() -> DispatchPolicy {
        DispatchPolicy::Batch {
            max_batch: DEFAULT_MAX_BATCH,
            max_wait_ms: DEFAULT_BATCH_WAIT_MS,
        }
    }

    /// Parse a CLI policy name (`batch` takes the default knobs; the CLI
    /// overrides them from `--max-batch` / `--batch-wait-ms`).
    pub fn parse(name: &str) -> Result<DispatchPolicy, String> {
        match name {
            "fifo" => Ok(DispatchPolicy::Fifo),
            "sjf" | "shortest-job-first" => Ok(DispatchPolicy::ShortestJobFirst),
            "batch" | "batching" => Ok(DispatchPolicy::batching()),
            other => Err(format!(
                "unknown dispatch policy '{other}' (known: fifo, sjf, batch)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DispatchPolicy::Fifo => "fifo",
            DispatchPolicy::ShortestJobFirst => "sjf",
            DispatchPolicy::Batch { .. } => "batch",
        }
    }
}

/// An `f64` with the total order (`f64::total_cmp`) so head keys can live
/// in a `BTreeSet`. Queue keys are validated-positive times, where the
/// total order agrees with the plain `<` the scan-based dispatch used.
#[derive(Debug, Clone, Copy, PartialEq)]
struct OrdF64(f64);

impl Eq for OrdF64 {}

impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// One queue head's position in a dispatch index. Lexicographic by field
/// order; `id` is unique, so `model`/`cores` (carried for the pop and the
/// fit filter) never decide the order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct HeadKey {
    primary: OrdF64,
    secondary: OrdF64,
    id: u64,
    model: usize,
    cores: usize,
}

/// Both indexes' keys for one head: FIFO ranks by `(arrival, id)`, SJF by
/// `(service, arrival, id)` — the same keys the old linear scan compared.
fn head_keys(r: &QueuedRequest) -> (HeadKey, HeadKey) {
    let fifo = HeadKey {
        primary: OrdF64(r.arrival_ms),
        secondary: OrdF64(0.0),
        id: r.id,
        model: r.model,
        cores: r.cores,
    };
    let sjf = HeadKey {
        primary: OrdF64(r.service_ms),
        secondary: OrdF64(r.arrival_ms),
        id: r.id,
        model: r.model,
        cores: r.cores,
    };
    (fifo, sjf)
}

/// Per-model FIFO queues with a policy-driven cross-queue head pick.
///
/// Within a model, requests always dispatch in arrival order; across models
/// the policy ranks the queue *heads* — FIFO by earliest arrival, SJF by
/// shortest predicted service — with `(arrival, id)` as the deterministic
/// tie-break. A head needing more cores than are currently free is skipped
/// so the pool stays work-conserving (documented as fit-filtered dispatch;
/// a blocked wide request does not idle cores a narrow one could use).
///
/// The heads are held in two ordered indexes (one per ranking), so a
/// dispatch pop walks the index from the best head and stops at the first
/// fit instead of re-scanning and re-keying every model queue per pop: the
/// common everything-fits pop touches only the front of one index, and the
/// total count is tracked so [`QueueSet::len`] is O(1). Pinned to the
/// scan-based dispatch order by `dispatch_order_matches_reference_scan`.
#[derive(Debug, Clone, Default)]
pub struct QueueSet {
    queues: Vec<VecDeque<QueuedRequest>>,
    fifo_heads: BTreeSet<HeadKey>,
    sjf_heads: BTreeSet<HeadKey>,
    total: usize,
}

impl QueueSet {
    pub fn new(num_models: usize) -> QueueSet {
        QueueSet {
            queues: (0..num_models).map(|_| VecDeque::new()).collect(),
            fifo_heads: BTreeSet::new(),
            sjf_heads: BTreeSet::new(),
            total: 0,
        }
    }

    /// Drop the current head of `model` from both indexes (no-op when the
    /// queue is empty). Every mutation of a queue front is bracketed by
    /// this and [`QueueSet::index_head`].
    fn unindex_head(&mut self, model: usize) {
        if let Some(head) = self.queues[model].front() {
            let (fifo, sjf) = head_keys(head);
            self.fifo_heads.remove(&fifo);
            self.sjf_heads.remove(&sjf);
        }
    }

    /// Enter the current head of `model` into both indexes (no-op when the
    /// queue is empty).
    fn index_head(&mut self, model: usize) {
        if let Some(head) = self.queues[model].front() {
            let (fifo, sjf) = head_keys(head);
            self.fifo_heads.insert(fifo);
            self.sjf_heads.insert(sjf);
        }
    }

    pub fn push(&mut self, r: QueuedRequest) {
        let was_empty = self.queues[r.model].is_empty();
        self.queues[r.model].push_back(r);
        self.total += 1;
        if was_empty {
            self.index_head(r.model);
        }
    }

    /// Total queued requests across every model.
    pub fn len(&self) -> usize {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Queued requests for one model.
    pub fn len_for(&self, model: usize) -> usize {
        self.queues[model].len()
    }

    /// The oldest queued request for one model (its queue head).
    pub fn head(&self, model: usize) -> Option<&QueuedRequest> {
        self.queues[model].front()
    }

    /// Every queued request, grouped by model (arrival order within each
    /// model) — the fleet router's backlog estimator reads queued service
    /// demand through this without disturbing the head indexes.
    pub fn iter(&self) -> impl Iterator<Item = &QueuedRequest> {
        self.queues.iter().flat_map(|q| q.iter())
    }

    /// Pop up to `n` requests from one model's queue, in arrival order —
    /// the batch former of the `batch` dispatch policy.
    pub fn pop_front_n(&mut self, model: usize, n: usize) -> Vec<QueuedRequest> {
        self.unindex_head(model);
        let take = n.min(self.queues[model].len());
        let out: Vec<QueuedRequest> = self.queues[model].drain(..take).collect();
        self.total -= out.len();
        self.index_head(model);
        out
    }

    /// Pop the best-ranked queue head that fits in `free_cores`, or `None`
    /// if every nonempty queue's head needs more cores than are free.
    pub fn pop_fitting(&mut self, policy: DispatchPolicy,
                       free_cores: usize) -> Option<QueuedRequest> {
        // The batching policy dispatches through the cluster's batch
        // former, not this single-request pop; rank by arrival so the
        // fallback stays total and deterministic.
        let index = match policy {
            DispatchPolicy::ShortestJobFirst => &self.sjf_heads,
            DispatchPolicy::Fifo | DispatchPolicy::Batch { .. } => &self.fifo_heads,
        };
        let model = index
            .iter()
            .find(|key| key.cores <= free_cores)
            .map(|key| key.model)?;
        self.unindex_head(model);
        let r = self.queues[model].pop_front().expect("indexed heads exist");
        self.total -= 1;
        self.index_head(model);
        Some(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, model: usize, arrival: f64, cores: usize,
           service: f64) -> QueuedRequest {
        QueuedRequest { id, model, arrival_ms: arrival, cores, service_ms: service }
    }

    #[test]
    fn parse_policies() {
        assert_eq!(DispatchPolicy::parse("fifo").unwrap(), DispatchPolicy::Fifo);
        assert_eq!(DispatchPolicy::parse("sjf").unwrap(),
                   DispatchPolicy::ShortestJobFirst);
        assert_eq!(DispatchPolicy::parse("shortest-job-first").unwrap(),
                   DispatchPolicy::ShortestJobFirst);
        assert_eq!(DispatchPolicy::parse("batch").unwrap(),
                   DispatchPolicy::Batch { max_batch: DEFAULT_MAX_BATCH,
                                           max_wait_ms: DEFAULT_BATCH_WAIT_MS });
        assert!(DispatchPolicy::parse("lifo").is_err());
        assert_eq!(DispatchPolicy::Fifo.name(), "fifo");
        assert_eq!(DispatchPolicy::batching().name(), "batch");
    }

    #[test]
    fn head_and_pop_front_n_keep_arrival_order() {
        let mut qs = QueueSet::new(2);
        for (id, arrival) in [(0u64, 1.0), (1, 2.0), (2, 3.0)] {
            qs.push(req(id, 0, arrival, 2, 10.0));
        }
        qs.push(req(9, 1, 0.5, 1, 5.0));
        assert_eq!(qs.head(0).unwrap().id, 0);
        assert_eq!(qs.head(1).unwrap().id, 9);
        // Pop is capped at the queue length and preserves order.
        let batch = qs.pop_front_n(0, 2);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        let rest = qs.pop_front_n(0, 99);
        assert_eq!(rest.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2]);
        assert!(qs.head(0).is_none());
        assert_eq!(qs.len(), 1);
    }

    #[test]
    fn fifo_picks_earliest_arrival_across_models() {
        let mut qs = QueueSet::new(2);
        qs.push(req(0, 0, 5.0, 1, 10.0));
        qs.push(req(1, 1, 3.0, 1, 50.0));
        let p = qs.pop_fitting(DispatchPolicy::Fifo, 32).unwrap();
        assert_eq!(p.id, 1);
        assert_eq!(qs.len(), 1);
    }

    #[test]
    fn sjf_picks_shortest_service() {
        let mut qs = QueueSet::new(2);
        qs.push(req(0, 0, 1.0, 1, 50.0));
        qs.push(req(1, 1, 2.0, 1, 10.0));
        let p = qs.pop_fitting(DispatchPolicy::ShortestJobFirst, 32).unwrap();
        assert_eq!(p.id, 1);
    }

    #[test]
    fn ties_break_on_arrival_then_id() {
        let mut qs = QueueSet::new(2);
        qs.push(req(7, 0, 1.0, 1, 10.0));
        qs.push(req(3, 1, 1.0, 1, 10.0));
        let p = qs.pop_fitting(DispatchPolicy::ShortestJobFirst, 32).unwrap();
        assert_eq!(p.id, 3);
    }

    #[test]
    fn oversized_head_is_skipped_not_blocking() {
        let mut qs = QueueSet::new(2);
        qs.push(req(0, 0, 1.0, 16, 10.0)); // earliest, but too wide
        qs.push(req(1, 1, 2.0, 2, 10.0));
        let p = qs.pop_fitting(DispatchPolicy::Fifo, 4).unwrap();
        assert_eq!(p.id, 1);
        // Nothing fits in 1 free core.
        assert!(qs.pop_fitting(DispatchPolicy::Fifo, 1).is_none());
        assert_eq!(qs.len(), 1);
    }

    #[test]
    fn per_model_order_is_fifo_even_under_sjf() {
        let mut qs = QueueSet::new(1);
        qs.push(req(0, 0, 1.0, 1, 50.0));
        qs.push(req(1, 0, 2.0, 1, 5.0)); // shorter but behind in its queue
        let p = qs.pop_fitting(DispatchPolicy::ShortestJobFirst, 32).unwrap();
        assert_eq!(p.id, 0, "only queue heads are candidates");
    }

    #[test]
    fn empty_set_pops_none() {
        let mut qs = QueueSet::new(3);
        assert!(qs.is_empty());
        assert_eq!(qs.len_for(1), 0);
        assert!(qs.pop_fitting(DispatchPolicy::Fifo, 32).is_none());
    }

    /// The pre-index dispatch: scan every queue head, keep the best
    /// `(primary, secondary, id)` key that fits. The indexed pop is pinned
    /// to produce exactly this order.
    fn reference_pop(queues: &mut [VecDeque<QueuedRequest>],
                     policy: DispatchPolicy,
                     free_cores: usize) -> Option<QueuedRequest> {
        let mut best: Option<(usize, (f64, f64, u64))> = None;
        for (m, q) in queues.iter().enumerate() {
            let Some(head) = q.front() else { continue };
            if head.cores > free_cores {
                continue;
            }
            let key = match policy {
                DispatchPolicy::ShortestJobFirst => {
                    (head.service_ms, head.arrival_ms, head.id)
                }
                _ => (head.arrival_ms, 0.0, head.id),
            };
            let better = match best {
                None => true,
                Some((_, best_key)) => key < best_key,
            };
            if better {
                best = Some((m, key));
            }
        }
        let (m, _) = best?;
        queues[m].pop_front()
    }

    #[test]
    fn dispatch_order_matches_reference_scan() {
        for policy in [DispatchPolicy::Fifo, DispatchPolicy::ShortestJobFirst,
                       DispatchPolicy::batching()] {
            let mut qs = QueueSet::new(5);
            let mut reference: Vec<VecDeque<QueuedRequest>> =
                (0..5).map(|_| VecDeque::new()).collect();
            // A deterministic pseudo-random workload with duplicate arrival
            // and service times to exercise every tie-break level.
            let mut state: u64 = 0x9e37_79b9_7f4a_7c15;
            let mut rand = move |n: u64| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (state >> 33) % n
            };
            for id in 0..200u64 {
                let r = req(id, rand(5) as usize, rand(7) as f64,
                            1 + rand(4) as usize, 1.0 + rand(6) as f64);
                qs.push(r);
                reference[r.model].push_back(r);
            }
            // Drain with a cycling core budget so fit-filtering kicks in.
            let mut free = 1usize;
            loop {
                let want = reference_pop(&mut reference, policy, free);
                let got = qs.pop_fitting(policy, free);
                assert_eq!(got, want, "policy {policy:?}, free {free}");
                if got.is_none() && qs.is_empty() {
                    break;
                }
                free = free % 4 + 1;
            }
            assert!(reference.iter().all(|q| q.is_empty()));
        }
    }
}
