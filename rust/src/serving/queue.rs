//! Per-model request queues and the dispatch policies over them
//! (rust/docs/DESIGN.md §9.2).

use std::collections::VecDeque;

/// A request waiting for cores, with its resolved operating point (cores to
/// occupy and the predicted service time at that core count).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QueuedRequest {
    pub id: u64,
    pub model: usize,
    pub arrival_ms: f64,
    /// Cores this request occupies while running.
    pub cores: usize,
    /// Predicted service time at that core count, ms.
    pub service_ms: f64,
}

/// Default `max_batch` for the `batch` dispatch policy (CLI `--max-batch`).
pub const DEFAULT_MAX_BATCH: usize = 8;

/// Default `max_wait_ms` for the `batch` dispatch policy (CLI
/// `--batch-wait-ms`).
pub const DEFAULT_BATCH_WAIT_MS: f64 = 2.0;

/// Which queued request runs next when cores free up.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DispatchPolicy {
    /// Earliest arrival first (across all model queues).
    Fifo,
    /// Smallest predicted service time first.
    ShortestJobFirst,
    /// Dynamic batching (rust/docs/DESIGN.md §10): per model, up to
    /// `max_batch` queued requests dispatch as **one** batched invocation
    /// occupying the model's cores for the engine-predicted batched
    /// latency. A partial batch is held for at most `max_wait_ms` after its
    /// oldest request arrived, then flushes at whatever size it reached —
    /// so the policy trades a bounded queueing delay for the weight-fetch
    /// amortization of larger batches. Deliberately not work-conserving.
    Batch { max_batch: usize, max_wait_ms: f64 },
}

impl DispatchPolicy {
    /// The `batch` policy with the default knobs.
    pub fn batching() -> DispatchPolicy {
        DispatchPolicy::Batch {
            max_batch: DEFAULT_MAX_BATCH,
            max_wait_ms: DEFAULT_BATCH_WAIT_MS,
        }
    }

    /// Parse a CLI policy name (`batch` takes the default knobs; the CLI
    /// overrides them from `--max-batch` / `--batch-wait-ms`).
    pub fn parse(name: &str) -> Result<DispatchPolicy, String> {
        match name {
            "fifo" => Ok(DispatchPolicy::Fifo),
            "sjf" | "shortest-job-first" => Ok(DispatchPolicy::ShortestJobFirst),
            "batch" | "batching" => Ok(DispatchPolicy::batching()),
            other => Err(format!(
                "unknown dispatch policy '{other}' (known: fifo, sjf, batch)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DispatchPolicy::Fifo => "fifo",
            DispatchPolicy::ShortestJobFirst => "sjf",
            DispatchPolicy::Batch { .. } => "batch",
        }
    }
}

/// Per-model FIFO queues with a policy-driven cross-queue head pick.
///
/// Within a model, requests always dispatch in arrival order; across models
/// the policy ranks the queue *heads* — FIFO by earliest arrival, SJF by
/// shortest predicted service — with `(arrival, id)` as the deterministic
/// tie-break. A head needing more cores than are currently free is skipped
/// so the pool stays work-conserving (documented as fit-filtered dispatch;
/// a blocked wide request does not idle cores a narrow one could use).
#[derive(Debug, Clone, Default)]
pub struct QueueSet {
    queues: Vec<VecDeque<QueuedRequest>>,
}

impl QueueSet {
    pub fn new(num_models: usize) -> QueueSet {
        QueueSet { queues: (0..num_models).map(|_| VecDeque::new()).collect() }
    }

    pub fn push(&mut self, r: QueuedRequest) {
        self.queues[r.model].push_back(r);
    }

    /// Total queued requests across every model.
    pub fn len(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.queues.iter().all(|q| q.is_empty())
    }

    /// Queued requests for one model.
    pub fn len_for(&self, model: usize) -> usize {
        self.queues[model].len()
    }

    /// The oldest queued request for one model (its queue head).
    pub fn head(&self, model: usize) -> Option<&QueuedRequest> {
        self.queues[model].front()
    }

    /// Pop up to `n` requests from one model's queue, in arrival order —
    /// the batch former of the `batch` dispatch policy.
    pub fn pop_front_n(&mut self, model: usize, n: usize) -> Vec<QueuedRequest> {
        let take = n.min(self.queues[model].len());
        self.queues[model].drain(..take).collect()
    }

    /// Pop the best-ranked queue head that fits in `free_cores`, or `None`
    /// if every nonempty queue's head needs more cores than are free.
    pub fn pop_fitting(&mut self, policy: DispatchPolicy,
                       free_cores: usize) -> Option<QueuedRequest> {
        // (model, rank key) of the best fitting head; keys are copies so no
        // borrow outlives the scan.
        let mut best: Option<(usize, (f64, f64, u64))> = None;
        for (m, q) in self.queues.iter().enumerate() {
            let Some(head) = q.front() else { continue };
            if head.cores > free_cores {
                continue;
            }
            let key = match policy {
                DispatchPolicy::Fifo => (head.arrival_ms, 0.0, head.id),
                DispatchPolicy::ShortestJobFirst => {
                    (head.service_ms, head.arrival_ms, head.id)
                }
                // The batching policy dispatches through the cluster's batch
                // former, not this single-request pop; rank by arrival so
                // the fallback stays total and deterministic.
                DispatchPolicy::Batch { .. } => (head.arrival_ms, 0.0, head.id),
            };
            let better = match best {
                None => true,
                Some((_, best_key)) => key < best_key,
            };
            if better {
                best = Some((m, key));
            }
        }
        let (m, _) = best?;
        self.queues[m].pop_front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, model: usize, arrival: f64, cores: usize,
           service: f64) -> QueuedRequest {
        QueuedRequest { id, model, arrival_ms: arrival, cores, service_ms: service }
    }

    #[test]
    fn parse_policies() {
        assert_eq!(DispatchPolicy::parse("fifo").unwrap(), DispatchPolicy::Fifo);
        assert_eq!(DispatchPolicy::parse("sjf").unwrap(),
                   DispatchPolicy::ShortestJobFirst);
        assert_eq!(DispatchPolicy::parse("shortest-job-first").unwrap(),
                   DispatchPolicy::ShortestJobFirst);
        assert_eq!(DispatchPolicy::parse("batch").unwrap(),
                   DispatchPolicy::Batch { max_batch: DEFAULT_MAX_BATCH,
                                           max_wait_ms: DEFAULT_BATCH_WAIT_MS });
        assert!(DispatchPolicy::parse("lifo").is_err());
        assert_eq!(DispatchPolicy::Fifo.name(), "fifo");
        assert_eq!(DispatchPolicy::batching().name(), "batch");
    }

    #[test]
    fn head_and_pop_front_n_keep_arrival_order() {
        let mut qs = QueueSet::new(2);
        for (id, arrival) in [(0u64, 1.0), (1, 2.0), (2, 3.0)] {
            qs.push(req(id, 0, arrival, 2, 10.0));
        }
        qs.push(req(9, 1, 0.5, 1, 5.0));
        assert_eq!(qs.head(0).unwrap().id, 0);
        assert_eq!(qs.head(1).unwrap().id, 9);
        // Pop is capped at the queue length and preserves order.
        let batch = qs.pop_front_n(0, 2);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        let rest = qs.pop_front_n(0, 99);
        assert_eq!(rest.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2]);
        assert!(qs.head(0).is_none());
        assert_eq!(qs.len(), 1);
    }

    #[test]
    fn fifo_picks_earliest_arrival_across_models() {
        let mut qs = QueueSet::new(2);
        qs.push(req(0, 0, 5.0, 1, 10.0));
        qs.push(req(1, 1, 3.0, 1, 50.0));
        let p = qs.pop_fitting(DispatchPolicy::Fifo, 32).unwrap();
        assert_eq!(p.id, 1);
        assert_eq!(qs.len(), 1);
    }

    #[test]
    fn sjf_picks_shortest_service() {
        let mut qs = QueueSet::new(2);
        qs.push(req(0, 0, 1.0, 1, 50.0));
        qs.push(req(1, 1, 2.0, 1, 10.0));
        let p = qs.pop_fitting(DispatchPolicy::ShortestJobFirst, 32).unwrap();
        assert_eq!(p.id, 1);
    }

    #[test]
    fn ties_break_on_arrival_then_id() {
        let mut qs = QueueSet::new(2);
        qs.push(req(7, 0, 1.0, 1, 10.0));
        qs.push(req(3, 1, 1.0, 1, 10.0));
        let p = qs.pop_fitting(DispatchPolicy::ShortestJobFirst, 32).unwrap();
        assert_eq!(p.id, 3);
    }

    #[test]
    fn oversized_head_is_skipped_not_blocking() {
        let mut qs = QueueSet::new(2);
        qs.push(req(0, 0, 1.0, 16, 10.0)); // earliest, but too wide
        qs.push(req(1, 1, 2.0, 2, 10.0));
        let p = qs.pop_fitting(DispatchPolicy::Fifo, 4).unwrap();
        assert_eq!(p.id, 1);
        // Nothing fits in 1 free core.
        assert!(qs.pop_fitting(DispatchPolicy::Fifo, 1).is_none());
        assert_eq!(qs.len(), 1);
    }

    #[test]
    fn per_model_order_is_fifo_even_under_sjf() {
        let mut qs = QueueSet::new(1);
        qs.push(req(0, 0, 1.0, 1, 50.0));
        qs.push(req(1, 0, 2.0, 1, 5.0)); // shorter but behind in its queue
        let p = qs.pop_fitting(DispatchPolicy::ShortestJobFirst, 32).unwrap();
        assert_eq!(p.id, 0, "only queue heads are candidates");
    }

    #[test]
    fn empty_set_pops_none() {
        let mut qs = QueueSet::new(3);
        assert!(qs.is_empty());
        assert_eq!(qs.len_for(1), 0);
        assert!(qs.pop_fitting(DispatchPolicy::Fifo, 32).is_none());
    }
}
