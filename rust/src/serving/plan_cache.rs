//! The fleet-wide tuned-plan cache (rust/docs/DESIGN.md §15.3).
//!
//! Tuning a model's operating points — the constrained-oracle `(MP, batch)`
//! sweep behind [`AllocationRequest`] — is the expensive step of serving
//! bring-up. A fleet would naively repeat it once per chip, but the outcome
//! depends only on the model, the chip's hardware target, and the batch
//! candidates (plus the SLO that filters the load-aware choice): chips of
//! the same kind are redundant work. [`PlanCache`] memoizes per-model
//! allocations under the key `(model, target, max_batch)` so each key is
//! tuned exactly once fleet-wide, and accounts the cost-engine evaluations
//! that every hit avoided.
//!
//! Caching per *model* rather than per *mix* is what makes reuse broad:
//! [`AllocationRequest`] plans each model independently (its own tuning
//! context and engine), so a model's cached allocation is bit-identical
//! whether it was first planned alone or inside any mix — only its traffic
//! `share` is mix-dependent, and [`PlanCache::plan_mix`] re-captures that
//! from the current mix on every request.

use std::collections::BTreeMap;

use crate::accel::Simulator;
use crate::tuner::TuningError;

use super::allocator::{AllocationPlan, AllocationRequest, ModelAllocation};
use super::workload::ModelMix;

/// Cumulative cache accounting: how much fleet bring-up the cache avoided.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PlanCacheStats {
    /// Keys served from the cache (sweeps avoided).
    pub hits: u64,
    /// Keys tuned (sweeps actually run).
    pub misses: u64,
    /// Cost-engine evaluations the misses spent.
    pub evals_spent: u64,
    /// Evaluations the hits would have re-spent — the fleet-wide saving.
    pub evals_saved: u64,
}

#[derive(Debug, Clone)]
struct CacheEntry {
    /// The SLO the cached sweep ran under (bit pattern: the load-aware
    /// choice is SLO-dependent, so an entry only serves plans requested
    /// with the same SLO; a mismatch re-tunes and replaces the entry).
    slo_bits: Option<u64>,
    alloc: ModelAllocation,
}

/// Keyed `(model, target, max_batch)` store of tuned per-model allocations.
///
/// Deterministic: a `BTreeMap` keyed by owned strings, no hashing, no
/// wall-clock eviction — a cache lookup can never change what a plan
/// contains, only whether its sweep re-runs.
#[derive(Debug, Clone, Default)]
pub struct PlanCache {
    entries: BTreeMap<(String, String, usize), CacheEntry>,
    stats: PlanCacheStats,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// Cumulative hit/miss/evaluation accounting across every
    /// [`Self::plan_mix`] call so far.
    pub fn stats(&self) -> PlanCacheStats {
        self.stats
    }

    /// Distinct `(model, target, max_batch)` keys tuned so far.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Plan `mix` on `sim`'s target through the cache. Each model resolves
    /// by `(model name, target, max_batch)`: a miss runs one single-model
    /// [`AllocationRequest`] sweep (bit-identical to planning the model
    /// inside the full mix) and stores the allocation; a hit clones it.
    /// Either way the returned allocation's `share` is re-captured from
    /// the *current* mix, so cached entries compose into any plan.
    pub fn plan_mix(&mut self, sim: &Simulator, mix: &ModelMix,
                    slo_ms: Option<f64>, max_batch: usize)
                    -> Result<AllocationPlan, TuningError> {
        let target = sim.target().to_string();
        let slo_bits = slo_ms.map(f64::to_bits);
        let mut models = Vec::with_capacity(mix.models.len());
        for (mi, model) in mix.models.iter().enumerate() {
            let key = (model.name.clone(), target.clone(), max_batch);
            let cached = self
                .entries
                .get(&key)
                .filter(|e| e.slo_bits == slo_bits)
                .map(|e| e.alloc.clone());
            let mut alloc = match cached {
                Some(alloc) => {
                    self.stats.hits += 1;
                    self.stats.evals_saved += alloc.tuning_evaluations;
                    alloc
                }
                None => {
                    let single = mix.single(mi);
                    let plan = AllocationRequest::new(sim, &single)
                        .slo_ms(slo_ms)
                        .max_batch(max_batch)
                        .plan()?;
                    let alloc = plan
                        .models
                        .into_iter()
                        .next()
                        .expect("a one-model mix plans one model");
                    self.stats.misses += 1;
                    self.stats.evals_spent += alloc.tuning_evaluations;
                    self.entries
                        .insert(key, CacheEntry { slo_bits, alloc: alloc.clone() });
                    alloc
                }
            };
            alloc.share = mix.share(mi);
            models.push(alloc);
        }
        Ok(AllocationPlan { models, slo_ms, target })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::Target;
    use crate::zoo;

    #[test]
    fn cache_reuses_keys_and_matches_direct_planning() {
        let sim = Simulator::new(Target::mlu100());
        let mix = ModelMix::uniform(vec![zoo::alexnet(), zoo::mini_cnn()]);
        let direct = AllocationRequest::new(&sim, &mix).max_batch(4).plan().unwrap();

        let mut cache = PlanCache::new();
        assert!(cache.is_empty());
        let first = cache.plan_mix(&sim, &mix, None, 4).unwrap();
        assert_eq!(first, direct, "cached planning is bit-identical");
        assert_eq!(cache.stats().misses, 2);
        assert_eq!(cache.stats().hits, 0);
        assert_eq!(cache.len(), 2);
        assert!(cache.stats().evals_spent > 0);

        // Second plan of the same mix: all hits, same plan, evals saved.
        let second = cache.plan_mix(&sim, &mix, None, 4).unwrap();
        assert_eq!(second, direct);
        assert_eq!(cache.stats().hits, 2);
        assert_eq!(cache.stats().misses, 2);
        assert_eq!(cache.stats().evals_saved, cache.stats().evals_spent);
    }

    #[test]
    fn shares_are_recaptured_from_the_requesting_mix() {
        let sim = Simulator::new(Target::mlu100());
        let models = vec![zoo::alexnet(), zoo::mini_cnn()];
        let uniform = ModelMix::uniform(models.clone());
        let skewed = ModelMix::weighted(models, vec![3.0, 1.0]);
        let mut cache = PlanCache::new();
        let a = cache.plan_mix(&sim, &uniform, None, 1).unwrap();
        let b = cache.plan_mix(&sim, &skewed, None, 1).unwrap();
        assert_eq!(cache.stats().hits, 2, "same keys despite different mix");
        assert_eq!(a.models[0].share, 0.5);
        assert_eq!(b.models[0].share, 0.75);
        // Everything but the share is the cached allocation.
        assert_eq!(a.models[0].points, b.models[0].points);
        assert_eq!(a.models[0].single, b.models[0].single);
    }

    #[test]
    fn distinct_targets_batches_and_slos_are_distinct_work() {
        let sim = Simulator::new(Target::mlu100());
        let edge = Simulator::new(Target::edge4());
        let mix = ModelMix::uniform(vec![zoo::mini_cnn()]);
        let mut cache = PlanCache::new();
        cache.plan_mix(&sim, &mix, None, 1).unwrap();
        cache.plan_mix(&edge, &mix, None, 1).unwrap();
        cache.plan_mix(&sim, &mix, None, 2).unwrap();
        assert_eq!(cache.stats().misses, 3, "target and batch key the cache");
        assert_eq!(cache.len(), 3);
        // A different SLO re-tunes (the load-aware choice depends on it)…
        cache.plan_mix(&sim, &mix, Some(50.0), 1).unwrap();
        assert_eq!(cache.stats().misses, 4);
        // …but does not grow the key space: it replaces the entry.
        assert_eq!(cache.len(), 3);
        cache.plan_mix(&sim, &mix, Some(50.0), 1).unwrap();
        assert_eq!(cache.stats().hits, 1);
    }
}
