//! Seeded arrival-trace generation: weighted multi-model request mixes over
//! the zoo plus the three arrival processes the serving simulator drives
//! (rust/docs/DESIGN.md §9.1).
//!
//! Everything here is a pure function of `(mix, process, n, seed)` — the
//! trace is the deterministic input the event loop replays, so two runs
//! with the same seed produce bit-identical simulations.

use crate::graph::Model;
use crate::util::XorShiftRng;

/// One serving request: which model it asks for (an index into the mix's
/// model list) and when it arrives on the simulated clock.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    pub id: u64,
    pub model: usize,
    pub arrival_ms: f64,
}

/// How requests arrive.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// A fixed population of `concurrency` outstanding requests: the first
    /// `concurrency` trace entries arrive at t=0 and the cluster injects one
    /// replacement per completion (saturation-throughput measurement).
    ClosedLoop { concurrency: usize },
    /// Open-loop Poisson arrivals at `rate_rps` requests/second.
    OpenPoisson { rate_rps: f64 },
    /// Bursts of `burst` simultaneous requests whose burst interarrivals are
    /// Poisson at `rate_rps / burst`, so the long-run offered rate is still
    /// `rate_rps` (tail-latency stressor).
    Bursty { rate_rps: f64, burst: usize },
}

impl ArrivalProcess {
    /// The closed-loop population size, if this is a closed-loop process
    /// (what [`super::cluster::SimulationRun::closed_loop`] takes as its
    /// injection limit).
    pub fn closed_loop_population(&self) -> Option<usize> {
        match *self {
            ArrivalProcess::ClosedLoop { concurrency } => Some(concurrency.max(1)),
            _ => None,
        }
    }
}

/// A weighted multi-model request mix.
#[derive(Debug, Clone)]
pub struct ModelMix {
    pub models: Vec<Model>,
    /// Relative (unnormalized, positive) traffic weights, one per model.
    pub weights: Vec<f64>,
    /// Per-model fusion-legal cut points (layer boundary indices), for
    /// models linearized from a branching DAG (rust/docs/DESIGN.md §13):
    /// the allocator threads them into its tuning sweep so a DAG-derived
    /// model is never fused across an illegal boundary. `None` =
    /// unconstrained (every linear zoo model).
    pub cuts: Vec<Option<Vec<usize>>>,
}

impl ModelMix {
    /// Equal traffic share for every model.
    pub fn uniform(models: Vec<Model>) -> ModelMix {
        let n = models.len();
        ModelMix { models, weights: vec![1.0; n], cuts: vec![None; n] }
    }

    /// Equal traffic share with per-model cut constraints — the DAG-aware
    /// variant of [`ModelMix::uniform`].
    pub fn uniform_with_cuts(entries: Vec<(Model, Option<Vec<usize>>)>) -> ModelMix {
        let (models, cuts): (Vec<_>, Vec<_>) = entries.into_iter().unzip();
        let n = models.len();
        ModelMix { models, weights: vec![1.0; n], cuts }
    }

    /// Explicit traffic weights (must be positive, one per model).
    pub fn weighted(models: Vec<Model>, weights: Vec<f64>) -> ModelMix {
        assert_eq!(models.len(), weights.len(), "one weight per model");
        assert!(weights.iter().all(|&w| w > 0.0), "weights must be positive");
        let n = models.len();
        ModelMix { models, weights, cuts: vec![None; n] }
    }

    pub fn num_models(&self) -> usize {
        self.models.len()
    }

    /// Model `i`'s normalized share of the offered load.
    pub fn share(&self, i: usize) -> f64 {
        let total: f64 = self.weights.iter().sum();
        if total <= 0.0 { 0.0 } else { self.weights[i] / total }
    }

    /// Model `i`'s cut constraint (`None` = every boundary is legal).
    pub fn cuts_for(&self, i: usize) -> Option<&[usize]> {
        self.cuts.get(i).and_then(|c| c.as_deref())
    }

    /// A one-model mix holding model `i`'s entry (weight 1, cuts kept) —
    /// the plan cache's per-model planning unit.
    pub fn single(&self, i: usize) -> ModelMix {
        ModelMix {
            models: vec![self.models[i].clone()],
            weights: vec![1.0],
            cuts: vec![self.cuts.get(i).cloned().flatten()],
        }
    }

    /// Draw a model index with probability proportional to its weight.
    /// `total` is the precomputed weight sum (hoisted out of the per-request
    /// loop by [`generate_trace`]).
    fn sample(&self, rng: &mut XorShiftRng, total: f64) -> usize {
        let mut x = rng.next_f64() * total;
        for (i, &w) in self.weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return i;
            }
        }
        self.weights.len() - 1
    }
}

/// Generate a seeded trace of `n` requests, nondecreasing in arrival time.
pub fn generate_trace(mix: &ModelMix, process: ArrivalProcess, n: usize,
                      seed: u64) -> Vec<Request> {
    assert!(!mix.models.is_empty(), "trace needs at least one model");
    let mut rng = XorShiftRng::new(seed);
    let total_weight: f64 = mix.weights.iter().sum();
    let mut t = 0.0_f64;
    let mut reqs = Vec::with_capacity(n);
    for id in 0..n as u64 {
        let arrival_ms = match process {
            ArrivalProcess::ClosedLoop { .. } => 0.0,
            ArrivalProcess::OpenPoisson { rate_rps } => {
                t += exp_interarrival_ms(&mut rng, rate_rps);
                t
            }
            ArrivalProcess::Bursty { rate_rps, burst } => {
                let burst = burst.max(1) as u64;
                if id % burst == 0 {
                    t += exp_interarrival_ms(&mut rng, rate_rps / burst as f64);
                }
                t
            }
        };
        reqs.push(Request { id, model: mix.sample(&mut rng, total_weight),
                            arrival_ms });
    }
    reqs
}

/// Exponential interarrival time in ms for a rate in requests/second.
fn exp_interarrival_ms(rng: &mut XorShiftRng, rate_rps: f64) -> f64 {
    assert!(rate_rps > 0.0, "arrival rate must be positive, got {rate_rps}");
    // next_f64 is in [0, 1); flip to (0, 1] so ln never sees 0.
    let u = 1.0 - rng.next_f64();
    -u.ln() / rate_rps * 1000.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo;

    fn mix() -> ModelMix {
        ModelMix::uniform(vec![zoo::alexnet(), zoo::mini_cnn()])
    }

    #[test]
    fn same_seed_same_trace() {
        let m = mix();
        let p = ArrivalProcess::OpenPoisson { rate_rps: 100.0 };
        assert_eq!(generate_trace(&m, p, 64, 9), generate_trace(&m, p, 64, 9));
        assert_ne!(generate_trace(&m, p, 64, 9), generate_trace(&m, p, 64, 10));
    }

    #[test]
    fn poisson_arrivals_increase_at_roughly_the_rate() {
        let m = mix();
        let n = 4000;
        let trace = generate_trace(
            &m, ArrivalProcess::OpenPoisson { rate_rps: 250.0 }, n, 3);
        for w in trace.windows(2) {
            assert!(w[1].arrival_ms >= w[0].arrival_ms);
        }
        // n arrivals at 250/s should span about n/250 seconds.
        let span_s = trace.last().unwrap().arrival_ms / 1000.0;
        let expect_s = n as f64 / 250.0;
        assert!((span_s - expect_s).abs() < 0.25 * expect_s,
                "span {span_s} vs {expect_s}");
    }

    #[test]
    fn bursty_groups_share_an_arrival_time() {
        let m = mix();
        let trace = generate_trace(
            &m, ArrivalProcess::Bursty { rate_rps: 100.0, burst: 4 }, 16, 5);
        for chunk in trace.chunks(4) {
            assert!(chunk.iter().all(|r| r.arrival_ms == chunk[0].arrival_ms));
        }
        assert!(trace[4].arrival_ms > trace[0].arrival_ms);
    }

    #[test]
    fn closed_loop_arrives_at_zero() {
        let m = mix();
        let p = ArrivalProcess::ClosedLoop { concurrency: 8 };
        let trace = generate_trace(&m, p, 32, 1);
        assert!(trace.iter().all(|r| r.arrival_ms == 0.0));
        assert_eq!(p.closed_loop_population(), Some(8));
        assert_eq!(ArrivalProcess::OpenPoisson { rate_rps: 1.0 }
                       .closed_loop_population(),
                   None);
    }

    #[test]
    fn mix_samples_follow_weights() {
        let m = ModelMix::weighted(vec![zoo::alexnet(), zoo::mini_cnn()],
                                   vec![3.0, 1.0]);
        assert!((m.share(0) - 0.75).abs() < 1e-12);
        let trace = generate_trace(
            &m, ArrivalProcess::OpenPoisson { rate_rps: 100.0 }, 4000, 11);
        let first = trace.iter().filter(|r| r.model == 0).count();
        let frac = first as f64 / trace.len() as f64;
        assert!((frac - 0.75).abs() < 0.05, "share {frac}");
    }

    #[test]
    fn ids_are_sequential() {
        let m = mix();
        let trace = generate_trace(
            &m, ArrivalProcess::OpenPoisson { rate_rps: 10.0 }, 10, 2);
        for (i, r) in trace.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
    }
}
