//! The event-driven core-pool simulator (rust/docs/DESIGN.md §9.2).
//!
//! A pool of `num_cores` identical cores serves a request trace: each
//! request occupies its model's allocated core count for the allocated
//! operating point's predicted service time (the `CostEngine`-tuned latency
//! — see [`super::allocator`]). Two event kinds drive the clock — arrivals
//! (from the seeded trace) and completions (a deterministic min-heap keyed
//! by `(finish time, start sequence)`). The whole simulation is a pure
//! function of its inputs: no wall clock, no global RNG, ties broken by
//! explicit sequence numbers.

use std::collections::{BinaryHeap, VecDeque};

use super::queue::{DispatchPolicy, QueueSet, QueuedRequest};
use super::workload::Request;

/// The per-model operating point the cluster serves: every request for the
/// model occupies `cores` cores for `service_ms` milliseconds.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelService {
    pub name: String,
    pub cores: usize,
    pub service_ms: f64,
}

/// Scenario configuration for one simulation run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClusterConfig {
    pub num_cores: usize,
    pub policy: DispatchPolicy,
}

/// What happened at one simulated instant (the pinned determinism surface:
/// two runs with the same inputs produce identical event vectors).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimEvent {
    pub time_ms: f64,
    pub kind: SimEventKind,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimEventKind {
    Arrive { id: u64, model: usize },
    Start { id: u64, cores: usize },
    Finish { id: u64, free_cores: usize },
}

/// Per-request completion record.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CompletedRequest {
    pub id: u64,
    pub model: usize,
    pub arrival_ms: f64,
    pub start_ms: f64,
    pub finish_ms: f64,
    pub cores: usize,
}

impl CompletedRequest {
    /// End-to-end latency: arrival to finish.
    pub fn e2e_ms(&self) -> f64 {
        self.finish_ms - self.arrival_ms
    }

    /// Time spent waiting for cores.
    pub fn queue_ms(&self) -> f64 {
        self.start_ms - self.arrival_ms
    }

    /// Time spent running.
    pub fn service_ms(&self) -> f64 {
        self.finish_ms - self.start_ms
    }
}

/// Outcome of one run: the event trace in simulated-time order plus the
/// completion records in finish order.
#[derive(Debug, Clone, PartialEq)]
pub struct SimResult {
    pub events: Vec<SimEvent>,
    pub completed: Vec<CompletedRequest>,
    pub num_cores: usize,
}

impl SimResult {
    /// Simulated span from t=0 to the last completion.
    pub fn makespan_ms(&self) -> f64 {
        self.completed.iter().map(|c| c.finish_ms).fold(0.0, f64::max)
    }

    /// Core-milliseconds actually occupied by running requests.
    pub fn busy_core_ms(&self) -> f64 {
        self.completed
            .iter()
            .map(|c| c.service_ms() * c.cores as f64)
            .sum()
    }

    /// Fraction of the pool's core-time spent serving (0 when nothing ran).
    pub fn utilization(&self) -> f64 {
        let span = self.makespan_ms();
        if span <= 0.0 || self.num_cores == 0 {
            return 0.0;
        }
        self.busy_core_ms() / (span * self.num_cores as f64)
    }

    /// Aggregate completions per second of simulated time (0 when nothing
    /// completed).
    pub fn throughput_rps(&self) -> f64 {
        let span = self.makespan_ms();
        if span <= 0.0 {
            return 0.0;
        }
        self.completed.len() as f64 / (span / 1000.0)
    }
}

/// A running request on the completion heap. `BinaryHeap` is a max-heap, so
/// `Ord` is reversed to pop the *earliest* `(finish_ms, seq)` first; `seq`
/// is the start order, making equal-time pops deterministic.
#[derive(Debug, Clone, Copy)]
struct Completion {
    finish_ms: f64,
    seq: u64,
    start_ms: f64,
    req: QueuedRequest,
}

impl PartialEq for Completion {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for Completion {}

impl PartialOrd for Completion {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Completion {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other
            .finish_ms
            .total_cmp(&self.finish_ms)
            .then(other.seq.cmp(&self.seq))
    }
}

/// Run the discrete-event simulation of `trace` over the core pool.
///
/// `closed_loop`: when `Some(k)`, only the first `k` trace entries arrive up
/// front; each completion injects the next backlogged entry at the
/// completion instant (a fixed-population closed loop). Completions at the
/// same instant as an arrival are processed first, so freed cores are
/// visible to the arrival's dispatch.
pub fn simulate(cfg: &ClusterConfig, services: &[ModelService],
                trace: &[Request], closed_loop: Option<usize>)
                -> Result<SimResult, String> {
    if cfg.num_cores == 0 {
        return Err("cluster has no cores".into());
    }
    for s in services {
        if s.cores == 0 || s.cores > cfg.num_cores {
            return Err(format!(
                "model '{}' allocated {} cores outside 1..={}",
                s.name, s.cores, cfg.num_cores));
        }
        if !(s.service_ms > 0.0) {
            return Err(format!(
                "model '{}' has non-positive service time {} ms",
                s.name, s.service_ms));
        }
    }
    for w in trace.windows(2) {
        if w[1].arrival_ms < w[0].arrival_ms {
            return Err("trace is not sorted by arrival time".into());
        }
    }
    if let Some(r) = trace.iter().find(|r| r.model >= services.len()) {
        return Err(format!(
            "request {} references model {} but only {} are allocated",
            r.id, r.model, services.len()));
    }
    // Closed-loop injections append at completion instants, which stay
    // ordered only because every closed-loop trace arrives at one instant
    // (what `generate_trace` emits for `ArrivalProcess::ClosedLoop`).
    if closed_loop.is_some()
        && trace.windows(2).any(|w| w[1].arrival_ms != w[0].arrival_ms)
    {
        return Err("closed-loop simulation expects a simultaneous-arrival \
                    trace (generate with ArrivalProcess::ClosedLoop)"
            .into());
    }

    let mut arrivals: VecDeque<Request> = trace.iter().copied().collect();
    let mut backlog: VecDeque<Request> = VecDeque::new();
    if let Some(k) = closed_loop {
        let k = k.max(1);
        if arrivals.len() > k {
            backlog = arrivals.split_off(k);
        }
    }

    let mut events = Vec::new();
    let mut completed = Vec::new();
    let mut queues = QueueSet::new(services.len());
    let mut heap: BinaryHeap<Completion> = BinaryHeap::new();
    let mut free = cfg.num_cores;
    let mut seq: u64 = 0;

    loop {
        let next_arrival = arrivals.front().map(|r| r.arrival_ms);
        let next_finish = heap.peek().map(|c| c.finish_ms);
        // Completions first on ties: free cores before dispatching.
        let take_finish = match (next_arrival, next_finish) {
            (None, None) => break,
            (None, Some(_)) => true,
            (Some(_), None) => false,
            (Some(a), Some(f)) => f <= a,
        };
        let now = if take_finish {
            let c = heap.pop().unwrap();
            free += c.req.cores;
            events.push(SimEvent {
                time_ms: c.finish_ms,
                kind: SimEventKind::Finish { id: c.req.id, free_cores: free },
            });
            completed.push(CompletedRequest {
                id: c.req.id,
                model: c.req.model,
                arrival_ms: c.req.arrival_ms,
                start_ms: c.start_ms,
                finish_ms: c.finish_ms,
                cores: c.req.cores,
            });
            if closed_loop.is_some() {
                if let Some(mut nxt) = backlog.pop_front() {
                    nxt.arrival_ms = c.finish_ms;
                    arrivals.push_back(nxt);
                }
            }
            c.finish_ms
        } else {
            let r = arrivals.pop_front().unwrap();
            events.push(SimEvent {
                time_ms: r.arrival_ms,
                kind: SimEventKind::Arrive { id: r.id, model: r.model },
            });
            let svc = &services[r.model];
            queues.push(QueuedRequest {
                id: r.id,
                model: r.model,
                arrival_ms: r.arrival_ms,
                cores: svc.cores,
                service_ms: svc.service_ms,
            });
            r.arrival_ms
        };

        // Work-conserving dispatch at the current instant.
        while let Some(q) = queues.pop_fitting(cfg.policy, free) {
            free -= q.cores;
            events.push(SimEvent {
                time_ms: now,
                kind: SimEventKind::Start { id: q.id, cores: q.cores },
            });
            seq += 1;
            heap.push(Completion {
                finish_ms: now + q.service_ms,
                seq,
                start_ms: now,
                req: q,
            });
        }
    }

    debug_assert!(queues.is_empty(), "validated requests cannot strand");
    debug_assert_eq!(free, cfg.num_cores);
    Ok(SimResult { events, completed, num_cores: cfg.num_cores })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn svc(name: &str, cores: usize, ms: f64) -> ModelService {
        ModelService { name: name.into(), cores, service_ms: ms }
    }

    fn req(id: u64, model: usize, arrival: f64) -> Request {
        Request { id, model, arrival_ms: arrival }
    }

    #[test]
    fn two_core_pool_runs_pair_then_queues_third() {
        let cfg = ClusterConfig { num_cores: 2, policy: DispatchPolicy::Fifo };
        let services = [svc("m", 1, 10.0)];
        let trace = [req(0, 0, 0.0), req(1, 0, 0.0), req(2, 0, 0.0)];
        let r = simulate(&cfg, &services, &trace, None).unwrap();
        assert_eq!(r.completed.len(), 3);
        // 0 and 1 run immediately; 2 waits for the first finish at 10 ms.
        assert_eq!(r.completed[2].id, 2);
        assert_eq!(r.completed[2].start_ms, 10.0);
        assert_eq!(r.completed[2].finish_ms, 20.0);
        assert_eq!(r.completed[2].queue_ms(), 10.0);
        assert_eq!(r.makespan_ms(), 20.0);
        // 30 core-ms busy over 2 cores * 20 ms.
        assert!((r.utilization() - 0.75).abs() < 1e-12);
        assert!((r.throughput_rps() - 150.0).abs() < 1e-9);
    }

    #[test]
    fn event_trace_is_ordered_and_deterministic() {
        let cfg = ClusterConfig { num_cores: 4, policy: DispatchPolicy::Fifo };
        let services = [svc("a", 2, 7.0), svc("b", 1, 3.0)];
        let trace = [req(0, 0, 0.0), req(1, 1, 1.0), req(2, 0, 1.0),
                     req(3, 1, 2.0)];
        let r1 = simulate(&cfg, &services, &trace, None).unwrap();
        let r2 = simulate(&cfg, &services, &trace, None).unwrap();
        assert_eq!(r1, r2);
        for w in r1.events.windows(2) {
            assert!(w[1].time_ms >= w[0].time_ms, "{:?}", r1.events);
        }
        // Every request arrives, starts, and finishes exactly once.
        let count = |f: &dyn Fn(&SimEventKind) -> bool| {
            r1.events.iter().filter(|e| f(&e.kind)).count()
        };
        assert_eq!(count(&|k| matches!(k, SimEventKind::Arrive { .. })), 4);
        assert_eq!(count(&|k| matches!(k, SimEventKind::Start { .. })), 4);
        assert_eq!(count(&|k| matches!(k, SimEventKind::Finish { .. })), 4);
    }

    #[test]
    fn completion_frees_cores_before_simultaneous_arrival() {
        let cfg = ClusterConfig { num_cores: 2, policy: DispatchPolicy::Fifo };
        let services = [svc("m", 2, 10.0)];
        // Second request arrives exactly when the first finishes: it must
        // start immediately (cores freed first), not queue.
        let trace = [req(0, 0, 0.0), req(1, 0, 10.0)];
        let r = simulate(&cfg, &services, &trace, None).unwrap();
        assert_eq!(r.completed[1].queue_ms(), 0.0);
        assert_eq!(r.completed[1].finish_ms, 20.0);
    }

    #[test]
    fn narrow_requests_overtake_a_blocked_wide_head() {
        let cfg = ClusterConfig { num_cores: 4, policy: DispatchPolicy::Fifo };
        let services = [svc("wide", 3, 10.0), svc("narrow", 1, 10.0)];
        // While request 0 runs (3 cores), wide request 1 can't fit in the
        // one free core but narrow request 2 can.
        let trace = [req(0, 0, 0.0), req(1, 0, 1.0), req(2, 1, 2.0)];
        let r = simulate(&cfg, &services, &trace, None).unwrap();
        let by_id = |id: u64| *r.completed.iter().find(|c| c.id == id).unwrap();
        assert_eq!(by_id(2).start_ms, 2.0, "narrow dispatches on arrival");
        assert_eq!(by_id(1).start_ms, 10.0, "wide waits for request 0");
    }

    #[test]
    fn closed_loop_keeps_population_and_injects_on_completion() {
        let cfg = ClusterConfig { num_cores: 2, policy: DispatchPolicy::Fifo };
        let services = [svc("m", 1, 5.0)];
        let trace: Vec<Request> = (0..6).map(|i| req(i, 0, 0.0)).collect();
        let r = simulate(&cfg, &services, &trace, Some(2)).unwrap();
        assert_eq!(r.completed.len(), 6);
        // Population 2 on 2 cores: perfectly pipelined, zero queueing.
        assert!(r.completed.iter().all(|c| c.queue_ms() == 0.0), "{r:?}");
        assert_eq!(r.makespan_ms(), 15.0);
        assert!((r.utilization() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rejects_invalid_inputs() {
        let cfg = ClusterConfig { num_cores: 4, policy: DispatchPolicy::Fifo };
        let err = simulate(&cfg, &[svc("m", 8, 1.0)], &[req(0, 0, 0.0)], None)
            .unwrap_err();
        assert!(err.contains("outside"), "{err}");
        let err = simulate(&cfg, &[svc("m", 1, 0.0)], &[req(0, 0, 0.0)], None)
            .unwrap_err();
        assert!(err.contains("non-positive"), "{err}");
        let err = simulate(&cfg, &[svc("m", 1, 1.0)], &[req(0, 3, 0.0)], None)
            .unwrap_err();
        assert!(err.contains("references model"), "{err}");
        let err = simulate(&cfg, &[svc("m", 1, 1.0)],
                           &[req(0, 0, 5.0), req(1, 0, 1.0)], None)
            .unwrap_err();
        assert!(err.contains("sorted"), "{err}");
        // A closed loop over a spread-out trace is rejected (injection
        // order would not be time-ordered).
        let err = simulate(&cfg, &[svc("m", 1, 1.0)],
                           &[req(0, 0, 0.0), req(1, 0, 5.0)], Some(1))
            .unwrap_err();
        assert!(err.contains("simultaneous"), "{err}");
    }

    #[test]
    fn empty_trace_is_an_empty_result() {
        let cfg = ClusterConfig { num_cores: 2, policy: DispatchPolicy::Fifo };
        let r = simulate(&cfg, &[svc("m", 1, 1.0)], &[], None).unwrap();
        assert!(r.events.is_empty());
        assert_eq!(r.throughput_rps(), 0.0);
        assert_eq!(r.utilization(), 0.0);
    }
}
